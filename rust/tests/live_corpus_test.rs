//! Live-corpus equivalence suite: the epoch-versioned segmented store
//! must be **bitwise** indistinguishable from a from-scratch monolithic
//! rebuild at every quiesced epoch.
//!
//! The property rests on column independence: Sinkhorn target columns
//! never interact, so a segmented solve (base + deltas, deletions
//! COW-emptied) runs the exact same per-column arithmetic as a solve
//! over `EpochView::rebuild_monolithic`. The suite pins that down across
//! S ∈ {1, 2, 3} shards × B ∈ {1, 4} query batches, under concurrent
//! appends against a pinned view, across background-free compaction, and
//! end-to-end through the service (windowed retrieval included). All
//! solves run 1-thread / fixed-iteration so the comparison is exact.

use sinkhorn_wmd::coordinator::{
    DocStore, LiveDocStore, QueryRequest, ServiceConfig, ShardSet, ShardedDocStore, WmdService,
};
use sinkhorn_wmd::corpus::SyntheticCorpus;
use sinkhorn_wmd::parallel::Pool;
use sinkhorn_wmd::sinkhorn::{Prepared, SinkhornConfig, SolveOutput, SolveWorkspace, SparseSolver};
use sinkhorn_wmd::sparse::{Coo, Csr};
use sinkhorn_wmd::util::Pcg64;
use std::sync::Arc;

fn corpus() -> SyntheticCorpus {
    SyntheticCorpus::builder()
        .vocab_size(300)
        .num_docs(30)
        .embedding_dim(8)
        .n_topics(3)
        .num_queries(4)
        .query_words(4, 8)
        .seed(977)
        .build()
}

/// Fixed-iteration config: `tolerance = 0` disables the early exit, so
/// every path executes exactly `max_iter` iterations — no convergence
/// check can order-skew the comparison.
fn cfg() -> SinkhornConfig {
    SinkhornConfig { tolerance: 0.0, max_iter: 12, ..Default::default() }
}

fn delta(vocab: usize, docs: usize, seed: u64) -> Csr {
    let mut rng = Pcg64::new(seed);
    let mut coo = Coo::new(vocab, docs);
    for j in 0..docs {
        for _ in 0..3 {
            coo.push(rng.below(vocab), j, rng.next_f64() + 0.1);
        }
    }
    Csr::from_coo(coo)
}

/// A live store with two delta segments and two tombstones (one in the
/// base segment, one in a delta), quiesced at epoch 4.
fn mutated_live(corpus: &SyntheticCorpus) -> Arc<LiveDocStore> {
    let live = LiveDocStore::new(DocStore::from_synthetic(corpus).into_arc()).into_arc();
    let n = live.num_docs();
    live.append(delta(corpus.vocab_size(), 10, 7), vec![100; 10]);
    live.append(delta(corpus.vocab_size(), 6, 8), vec![200; 6]);
    live.delete(3).unwrap(); // base segment
    live.delete(n + 2).unwrap(); // first delta segment
    live
}

fn assert_bitwise(a: &SolveOutput, b: &SolveOutput, ctx: &str) {
    assert_eq!(a.wmd.len(), b.wmd.len(), "{ctx}: wmd length");
    for (j, (x, y)) in a.wmd.iter().zip(&b.wmd).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: doc {j} ({x} vs {y})");
    }
}

/// From-scratch reference: rebuild the monolithic CSR and solve it as if
/// the store had always been that single matrix.
fn reference(
    solver: &SparseSolver,
    preps: &[&Prepared],
    live: &LiveDocStore,
    pool: &Pool,
) -> Vec<SolveOutput> {
    let mono = live.view().rebuild_monolithic();
    solver.solve_batch_in(&mut SolveWorkspace::new(), preps, &mono, pool)
}

#[test]
fn quiesced_epoch_solve_is_bitwise_monolithic() {
    let corpus = corpus();
    let live = mutated_live(&corpus);
    let view = live.view();
    assert_eq!(view.num_segments(), 3);
    let pool = Pool::new(1);
    let solver = SparseSolver::new(cfg());
    let preps: Vec<Prepared> =
        corpus.queries.iter().map(|q| solver.prepare(&corpus.embeddings, q, &pool)).collect();

    for b in [1usize, 4] {
        let batch: Vec<&Prepared> = preps[..b].iter().collect();
        let refs = reference(&solver, &batch, &live, &pool);

        // S = 1: the segmented batch solve, exactly as the dispatcher
        // runs it for a mutated monolithic store.
        let segs: Vec<(usize, &Csr)> =
            view.segments.iter().map(|s| (s.start, s.c.as_ref())).collect();
        let got = solver.solve_segments_in(
            &mut SolveWorkspace::new(),
            &batch,
            &segs,
            view.num_docs(),
            &pool,
        );
        assert_eq!(got.len(), refs.len());
        for (q, (g, r)) in got.iter().zip(&refs).enumerate() {
            assert_bitwise(g, r, &format!("segmented b={b} q={q}"));
        }

        // S ∈ {2, 3}: shard workers synced to the same epoch view.
        for s in [2usize, 3] {
            let sharded = ShardedDocStore::split(Arc::clone(live.store()), s);
            let mut set = ShardSet::start(sharded, cfg(), 1);
            set.sync(&view);
            let arc_preps: Vec<Arc<Prepared>> =
                preps[..b].iter().map(|p| Arc::new(p.clone())).collect();
            let merged = set.solve_batch(&arc_preps);
            assert_eq!(merged.outputs.len(), refs.len());
            for (q, (g, r)) in merged.outputs.iter().zip(&refs).enumerate() {
                assert_bitwise(g, r, &format!("sharded s={s} b={b} q={q}"));
            }
        }
    }

    // Both tombstones answer +inf, like the empty documents they became.
    let r = reference(&solver, &[&preps[0]], &live, &pool);
    assert!(r[0].wmd[3].is_infinite());
    assert!(r[0].wmd[corpus.num_docs() + 2].is_infinite());
}

#[test]
fn pinned_view_is_immune_to_concurrent_appends() {
    let corpus = corpus();
    let live = mutated_live(&corpus);
    let pool = Pool::new(1);
    let solver = SparseSolver::new(cfg());
    let prep = solver.prepare(&corpus.embeddings, &corpus.queries[0], &pool);

    // Pin the view (what the dispatcher does per popped batch), then
    // hammer the store from another thread while we solve against it.
    let pinned = live.view();
    let epoch = pinned.epoch;
    let baseline = {
        let segs: Vec<(usize, &Csr)> =
            pinned.segments.iter().map(|s| (s.start, s.c.as_ref())).collect();
        solver.solve_segments_in(
            &mut SolveWorkspace::new(),
            &[&prep],
            &segs,
            pinned.num_docs(),
            &pool,
        )
    };
    let writer = {
        let live = Arc::clone(&live);
        let vocab = corpus.vocab_size();
        std::thread::spawn(move || {
            for i in 0..5 {
                live.append(delta(vocab, 4, 90 + i), vec![300 + i as i64; 4]);
            }
        })
    };
    for round in 0..3 {
        let segs: Vec<(usize, &Csr)> =
            pinned.segments.iter().map(|s| (s.start, s.c.as_ref())).collect();
        let again = solver.solve_segments_in(
            &mut SolveWorkspace::new(),
            &[&prep],
            &segs,
            pinned.num_docs(),
            &pool,
        );
        assert_bitwise(&again[0], &baseline[0], &format!("pinned round {round}"));
    }
    writer.join().unwrap();
    assert_eq!(pinned.epoch, epoch, "a pinned view never moves");
    assert_eq!(pinned.num_docs(), corpus.num_docs() + 16);
    assert_eq!(live.view().num_docs(), corpus.num_docs() + 16 + 20);
    assert!(live.epoch() > epoch);
}

#[test]
fn compaction_preserves_answers_bitwise() {
    let corpus = corpus();
    let live = mutated_live(&corpus);
    let pool = Pool::new(1);
    let solver = SparseSolver::new(cfg());
    let prep = solver.prepare(&corpus.embeddings, &corpus.queries[1], &pool);
    let before = reference(&solver, &[&prep], &live, &pool);

    live.compact();
    let view = live.view();
    assert_eq!(view.num_segments(), 1, "compaction folds to one segment");
    let segs: Vec<(usize, &Csr)> = view.segments.iter().map(|s| (s.start, s.c.as_ref())).collect();
    let after = solver.solve_segments_in(
        &mut SolveWorkspace::new(),
        &[&prep],
        &segs,
        view.num_docs(),
        &pool,
    );
    assert_bitwise(&after[0], &before[0], "compacted");
    // Tombstones and timestamps survive the fold.
    assert!(after[0].wmd[3].is_infinite());
    assert_eq!(view.timestamp(corpus.num_docs()), 100);
    assert_eq!(live.stats().compactions, 1);
}

#[test]
fn service_tracks_the_live_store_across_epochs() {
    let corpus = corpus();
    let pool = Pool::new(1);
    let solver = SparseSolver::new(cfg());
    for shards in [1usize, 2] {
        let live = LiveDocStore::new(DocStore::from_synthetic(&corpus).into_arc()).into_arc();
        let service = WmdService::start_live(
            Arc::clone(&live),
            ServiceConfig { threads: 1, shards, sinkhorn: cfg(), ..Default::default() },
            None,
        );
        let n = corpus.num_docs();

        let fresh = service.submit_wait(QueryRequest::new(corpus.queries[0].clone()));
        assert!(fresh.is_ok(), "{:?}", fresh.error);
        assert_eq!(fresh.wmd.len(), n, "shards={shards}");

        live.append(delta(corpus.vocab_size(), 8, 55), vec![1_000; 8]);
        live.delete(5).unwrap();
        let grown = service.submit_wait(QueryRequest::new(corpus.queries[0].clone()));
        assert!(grown.is_ok(), "{:?}", grown.error);
        assert_eq!(grown.wmd.len(), n + 8, "shards={shards}");
        assert!(grown.wmd[5].is_infinite(), "shards={shards}: tombstone must answer +inf");

        // The service's post-append answer is bitwise the from-scratch
        // monolithic rebuild's.
        let prep = solver.prepare(&corpus.embeddings, &corpus.queries[0], &pool);
        let refs = reference(&solver, &[&prep], &live, &pool);
        assert_eq!(grown.wmd.len(), refs[0].wmd.len());
        for (j, (x, y)) in grown.wmd.iter().zip(&refs[0].wmd).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "shards={shards} doc {j} ({x} vs {y})");
        }

        // Windowed retrieval: only documents ingested at ts >= 1000 may
        // appear, i.e. the freshly appended ones.
        let windowed =
            service.submit_wait(QueryRequest::top_k_since(corpus.queries[0].clone(), 5, 1_000));
        assert!(windowed.is_ok(), "{:?}", windowed.error);
        assert!(!windowed.top.is_empty());
        for &(doc, wmd) in &windowed.top {
            assert!(doc >= n, "shards={shards}: doc {doc} predates the window");
            assert!(wmd.is_finite());
        }
        service.shutdown();
    }
}
