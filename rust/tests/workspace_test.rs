//! Dirty-buffer equivalence suite for the zero-alloc hot path: solving
//! query A, then B, then A again through ONE reused [`SolveWorkspace`]
//! must produce bitwise-identical outputs to fresh-allocation solves —
//! across every iterate kernel (fused f64, fused mixed when built in,
//! unfused), batch sizes {1, 4} and S ∈ {1, 2} target-set shards.
//! Everything runs on one thread so "identical" means `assert_eq!` on
//! the raw `f64` vectors, not a tolerance.

use sinkhorn_wmd::coordinator::{DocStore, ShardSet, ShardedDocStore};
use sinkhorn_wmd::corpus::SyntheticCorpus;
use sinkhorn_wmd::parallel::Pool;
use sinkhorn_wmd::sinkhorn::{
    IterateKernel, Precision, Prepared, SinkhornConfig, SolveWorkspace, SparseSolver,
};
use std::sync::Arc;

fn kernels() -> Vec<IterateKernel> {
    let mut ks = vec![
        IterateKernel::Fused { precision: Precision::F64 },
        IterateKernel::Unfused,
    ];
    #[cfg(feature = "mixed-precision")]
    ks.push(IterateKernel::Fused { precision: Precision::Mixed });
    ks
}

fn corpus() -> SyntheticCorpus {
    SyntheticCorpus::builder()
        .vocab_size(500)
        .num_docs(40)
        .embedding_dim(16)
        .n_topics(4)
        .num_queries(4)
        .query_words(5, 12)
        .seed(91)
        .build()
}

#[test]
fn reused_workspace_single_solves_bitwise_identical_across_kernels() {
    let corpus = corpus();
    let pool = Pool::new(1); // serial → bitwise-deterministic solves
    for kernel in kernels() {
        let solver = SparseSolver::new(SinkhornConfig { kernel, ..Default::default() });
        let preps: Vec<Prepared> = corpus
            .queries
            .iter()
            .map(|q| solver.prepare(&corpus.embeddings, q, &pool))
            .collect();
        let mut ws = SolveWorkspace::new();
        // A, then B, then A again: the third solve reads buffers dirtied
        // by a different query shape.
        for &q in &[0usize, 1, 0] {
            let fresh = solver.solve(&preps[q], &corpus.c, &pool);
            let reused = solver.solve_in(&mut ws, &preps[q], &corpus.c, &pool);
            assert_eq!(fresh.wmd, reused.wmd, "{kernel:?} q={q}: dirty buffers leaked");
            assert_eq!(fresh.iterations, reused.iterations, "{kernel:?} q={q}");
            assert_eq!(fresh.converged, reused.converged, "{kernel:?} q={q}");
        }
        let stats = ws.stats();
        assert_eq!(stats.checkouts, 3, "{kernel:?}");
        assert!(stats.bytes_retained > 0, "{kernel:?}");
        assert!(
            stats.grows < stats.checkouts,
            "{kernel:?}: repeating a shape must not regrow the workspace"
        );
    }
}

#[test]
fn reused_workspace_batched_solves_bitwise_identical() {
    let corpus = corpus();
    let pool = Pool::new(1);
    for kernel in kernels() {
        let solver = SparseSolver::new(SinkhornConfig { kernel, ..Default::default() });
        let preps: Vec<Prepared> = corpus
            .queries
            .iter()
            .map(|q| solver.prepare(&corpus.embeddings, q, &pool))
            .collect();
        for b in [1usize, 4] {
            let refs: Vec<&Prepared> = preps[..b].iter().collect();
            let dirty: Vec<&Prepared> = vec![&preps[2]];
            let mut ws = SolveWorkspace::new();
            let fresh = solver.solve_batch(&refs, &corpus.c, &pool);
            let first = solver.solve_batch_in(&mut ws, &refs, &corpus.c, &pool);
            // Interleave a different batch shape to dirty the lanes, then
            // solve the original batch again.
            let _ = solver.solve_batch_in(&mut ws, &dirty, &corpus.c, &pool);
            let again = solver.solve_batch_in(&mut ws, &refs, &corpus.c, &pool);
            assert_eq!(first.len(), b);
            assert_eq!(again.len(), b);
            for q in 0..b {
                assert_eq!(fresh[q].wmd, first[q].wmd, "{kernel:?} b={b} q={q} (cold ws)");
                assert_eq!(fresh[q].wmd, again[q].wmd, "{kernel:?} b={b} q={q} (dirty ws)");
                assert_eq!(fresh[q].iterations, again[q].iterations, "{kernel:?} b={b} q={q}");
                assert_eq!(fresh[q].converged, again[q].converged, "{kernel:?} b={b} q={q}");
            }
        }
    }
}

#[test]
fn reused_shard_worker_workspaces_bitwise_identical_to_monolithic() {
    // S ∈ {1, 2}: every ShardSet worker retains its own workspace across
    // batches. With fixed iterations and one thread per shard, a warm
    // (dirty) set must keep reproducing the monolithic fresh-allocation
    // solve bit for bit.
    let corpus = corpus();
    let store = DocStore::from_synthetic(&corpus).into_arc();
    let config = SinkhornConfig { tolerance: 0.0, max_iter: 12, ..Default::default() };
    let solver = SparseSolver::new(config);
    let pool = Pool::new(1);
    let preps: Vec<Arc<Prepared>> = corpus
        .queries
        .iter()
        .map(|q| Arc::new(solver.prepare(&corpus.embeddings, q, &pool)))
        .collect();
    let prep_refs: Vec<&Prepared> = preps.iter().map(|p| p.as_ref()).collect();
    let monolithic = solver.solve_batch(&prep_refs, &corpus.c, &pool);
    for s in [1usize, 2] {
        let set = ShardSet::start(ShardedDocStore::split(Arc::clone(&store), s), config, 1);
        for b in [1usize, 4] {
            let batch: Vec<Arc<Prepared>> = preps[..b].to_vec();
            // Dirty the workers with the full batch, then solve `batch`
            // on the warm set.
            let _ = set.solve_batch(&preps);
            let out = set.solve_batch(&batch);
            assert_eq!(out.outputs.len(), b);
            for q in 0..b {
                assert_eq!(
                    out.outputs[q].wmd, monolithic[q].wmd,
                    "S={s} b={b} q={q}: warm sharded solve diverged from monolithic"
                );
                assert_eq!(out.outputs[q].iterations, monolithic[q].iterations);
            }
            for ws in &out.workspace {
                assert!(ws.checkouts >= 2, "S={s} b={b}: workers must reuse, not rebuild");
            }
        }
    }
}
