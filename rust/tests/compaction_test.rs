//! Per-document convergence equivalence suite: freezing + active-set
//! compaction against the exact-mode opt-out (`compact_every = 0`).
//!
//! The per-document criterion stops each column at its own convergence
//! check instead of the global max-residual one, so the two modes are
//! *numerically* (not bitwise) equal: a frozen column's `u` stops moving
//! while the reference keeps polishing it below tolerance. At a tight
//! tolerance the residual bound makes that drift vanish — the suite gates
//! the default f64 kernels at **1e-9 relative** against the no-compaction
//! reference, across kernels × batch sizes × shard counts. What *is*
//! bitwise: `compact_every = 0` versus any compaction knobs when the
//! early exit is off, batch versus single solves under compaction, shard
//! merges versus monolithic solves, and dirty-workspace reuse.

use sinkhorn_wmd::corpus::SyntheticCorpus;
use sinkhorn_wmd::parallel::Pool;
use sinkhorn_wmd::sinkhorn::{
    IterateKernel, Precision, Prepared, SinkhornConfig, SolveOutput, SolveWorkspace, SparseSolver,
};

const FUSED_F64: IterateKernel = IterateKernel::Fused { precision: Precision::F64 };

fn corpus() -> SyntheticCorpus {
    SyntheticCorpus::builder()
        .vocab_size(300)
        .num_docs(24)
        .embedding_dim(8)
        .n_topics(3)
        .num_queries(4)
        .query_words(4, 8)
        .seed(131)
        .build()
}

fn skewed_corpus() -> SyntheticCorpus {
    SyntheticCorpus::builder()
        .vocab_size(400)
        .num_docs(32)
        .embedding_dim(8)
        .n_topics(3)
        .tokens_per_doc(20)
        .num_queries(2)
        .query_words(4, 8)
        .seed(137)
        .doc_length_skew(1.1)
        .build()
}

/// Tight-tolerance config: at `tol = 1e-12` the post-freeze drift of the
/// reference (bounded by `tol / (1 − ρ)` with ρ the contraction rate) is
/// far inside the 1e-9 gate. λ = 2 keeps the contraction fast enough
/// that every column reaches 1e-12 well inside `max_iter`.
fn tight(kernel: IterateKernel, compact_every: usize) -> SinkhornConfig {
    SinkhornConfig {
        kernel,
        lambda: 2.0,
        tolerance: 1e-12,
        check_every: 4,
        max_iter: 20_000,
        compact_every,
        ..Default::default()
    }
}

fn prepare_all(corpus: &SyntheticCorpus, pool: &Pool) -> Vec<Prepared> {
    let solver = SparseSolver::new(SinkhornConfig::default());
    corpus.queries.iter().map(|q| solver.prepare(&corpus.embeddings, q, pool)).collect()
}

fn assert_close(a: &SolveOutput, b: &SolveOutput, gate: f64, ctx: &str) {
    assert_eq!(a.wmd.len(), b.wmd.len(), "{ctx}");
    for (j, (&x, &y)) in a.wmd.iter().zip(&b.wmd).enumerate() {
        assert_eq!(x.is_finite(), y.is_finite(), "{ctx} j={j}: finiteness must match");
        if y.is_finite() {
            assert!(
                (x - y).abs() <= gate * (1.0 + y.abs()),
                "{ctx} j={j}: {x} vs {y} exceeds the {gate:.0e} gate"
            );
        }
    }
}

#[test]
fn compaction_matches_no_compaction_reference_across_kernels_and_batches() {
    let corpus = corpus();
    let pool = Pool::new(4);
    let preps = prepare_all(&corpus, &pool);
    for kernel in [FUSED_F64, IterateKernel::Unfused] {
        let reference = SparseSolver::new(tight(kernel, 0));
        let compacting = SparseSolver::new(tight(kernel, 1));
        // B = 1.
        let r = reference.solve(&preps[0], &corpus.c, &pool);
        let c = compacting.solve(&preps[0], &corpus.c, &pool);
        assert!(r.converged && c.converged, "{kernel:?}: both modes must converge");
        assert_close(&c, &r, 1e-9, &format!("{kernel:?} B=1"));
        // Freezing telemetry only on the compacting side.
        assert_eq!(r.conv.frozen_columns, 0, "{kernel:?}: exact mode must not freeze");
        assert_eq!(c.conv.frozen_columns, corpus.c.ncols(), "{kernel:?}: all docs freeze");
        // B = 4 (the unfused kernel falls back to per-query solves, which
        // still exercises freezing without compaction).
        let prefs: Vec<&Prepared> = preps.iter().collect();
        let rs = reference.solve_batch(&prefs, &corpus.c, &pool);
        let cs = compacting.solve_batch(&prefs, &corpus.c, &pool);
        for q in 0..prefs.len() {
            assert!(rs[q].converged && cs[q].converged, "{kernel:?} q={q}");
            assert_close(&cs[q], &rs[q], 1e-9, &format!("{kernel:?} B=4 q={q}"));
        }
    }
}

#[cfg(feature = "mixed-precision")]
#[test]
fn compaction_matches_reference_under_mixed_precision() {
    // The f32 u-mirror can limit-cycle the residual around 1e-8, so the
    // mixed comparison runs at a serviceable 1e-6 tolerance; a frozen
    // column sits within O(tolerance / (1 − ρ)) of where the reference
    // polishes it, so the gate is tolerance-scaled (1e-3 ≈ 1000 × tol),
    // not the f64 suite's 1e-9.
    let corpus = corpus();
    let pool = Pool::new(4);
    let preps = prepare_all(&corpus, &pool);
    let kernel = IterateKernel::Fused { precision: Precision::Mixed };
    let cfg = |compact_every| SinkhornConfig {
        kernel,
        lambda: 3.0,
        tolerance: 1e-6,
        check_every: 4,
        max_iter: 10_000,
        compact_every,
        ..Default::default()
    };
    let reference = SparseSolver::new(cfg(0));
    let compacting = SparseSolver::new(cfg(1));
    let prefs: Vec<&Prepared> = preps.iter().collect();
    let rs = reference.solve_batch(&prefs, &corpus.c, &pool);
    let cs = compacting.solve_batch(&prefs, &corpus.c, &pool);
    for q in 0..prefs.len() {
        assert!(rs[q].converged && cs[q].converged, "q={q}");
        assert_close(&cs[q], &rs[q], 1e-3, &format!("mixed q={q}"));
    }
}

#[test]
fn sharded_compaction_is_bitwise_identical_to_monolithic() {
    // Per-column freezing decisions depend only on that column's own
    // residual, so a column slice freezes (and compacts around) exactly
    // the same columns at the same checks as the monolithic solve — the
    // merge must be bitwise, iterations included.
    let corpus = skewed_corpus();
    let pool = Pool::new(1);
    let preps = prepare_all(&corpus, &pool);
    let solver = SparseSolver::new(SinkhornConfig {
        lambda: 3.0,
        tolerance: 1e-4,
        check_every: 4,
        max_iter: 5_000,
        ..Default::default()
    });
    let full = solver.solve(&preps[0], &corpus.c, &pool);
    assert!(full.converged);
    let n = corpus.c.ncols();
    for cuts in [vec![0, n], vec![0, n / 2, n], vec![0, n / 3, 2 * n / 3, n]] {
        let parts: Vec<(usize, SolveOutput)> = cuts
            .windows(2)
            .map(|w| (w[0], solver.solve(&preps[0], &corpus.c.slice_columns(w[0]..w[1]), &pool)))
            .collect();
        let merged = SolveOutput::merge_shards(n, &parts);
        assert_eq!(merged.wmd, full.wmd, "cuts {cuts:?}: shard merge must be bitwise");
        assert_eq!(merged.iterations, full.iterations, "cuts {cuts:?}");
        assert_eq!(merged.conv.frozen_columns, full.conv.frozen_columns, "cuts {cuts:?}");
    }
}

#[test]
fn batched_compaction_is_bitwise_identical_to_single_solves() {
    // The batch path compacts over the *union* of the active queries'
    // surviving columns; the per-query frozen masks do the fine-grained
    // skipping, so each lane's arithmetic is exactly the single solve's.
    let corpus = skewed_corpus();
    for p in [1usize, 4] {
        let pool = Pool::new(p);
        let preps = prepare_all(&corpus, &pool);
        let solver = SparseSolver::new(SinkhornConfig {
            lambda: 3.0,
            tolerance: 1e-4,
            check_every: 4,
            max_iter: 5_000,
            ..Default::default()
        });
        let prefs: Vec<&Prepared> = preps.iter().collect();
        let outs = solver.solve_batch(&prefs, &corpus.c, &pool);
        for (q, prep) in preps.iter().enumerate() {
            let single = solver.solve(prep, &corpus.c, &pool);
            assert_eq!(outs[q].wmd, single.wmd, "p={p} q={q}");
            assert_eq!(outs[q].iterations, single.iterations, "p={p} q={q}");
            assert_eq!(outs[q].converged, single.converged, "p={p} q={q}");
            assert_eq!(
                outs[q].conv.frozen_columns, single.conv.frozen_columns,
                "p={p} q={q}"
            );
            assert_eq!(
                outs[q].conv.freeze_iters, single.conv.freeze_iters,
                "p={p} q={q}: per-column freeze iterations must match"
            );
        }
    }
}

#[test]
fn exact_mode_knobs_are_inert_and_fixed_iterations_are_bitwise() {
    // With the early exit off (`tolerance = 0`) freezing never engages, so
    // every compaction knob must be a no-op — the run is the pre-compaction
    // fixed-iteration solve, bitwise, whatever the knobs say.
    let corpus = corpus();
    let pool = Pool::new(4);
    let preps = prepare_all(&corpus, &pool);
    let base_cfg = SinkhornConfig { tolerance: 0.0, max_iter: 12, ..Default::default() };
    let base = SparseSolver::new(base_cfg).solve(&preps[0], &corpus.c, &pool);
    for (thr, every) in [(0.75, 0), (0.0, 1), (1.0, 7), (0.5, 1)] {
        let solver = SparseSolver::new(SinkhornConfig {
            compact_threshold: thr,
            compact_every: every,
            ..base_cfg
        });
        let out = solver.solve(&preps[0], &corpus.c, &pool);
        assert_eq!(out.wmd, base.wmd, "thr={thr} every={every}");
        assert_eq!(out.iterations, 12);
        assert_eq!(out.conv.frozen_columns, 0);
        assert_eq!(out.conv.compactions, 0);
    }
    // Same with tolerance on: compact_every = 0 must pin the exact global
    // criterion regardless of the threshold knob.
    let exact_cfg = SinkhornConfig {
        lambda: 2.0,
        tolerance: 1e-6,
        max_iter: 20_000,
        compact_every: 0,
        ..Default::default()
    };
    let a = SparseSolver::new(exact_cfg).solve(&preps[0], &corpus.c, &pool);
    let b = SparseSolver::new(SinkhornConfig { compact_threshold: 0.1, ..exact_cfg })
        .solve(&preps[0], &corpus.c, &pool);
    assert!(a.converged);
    assert_eq!(a.wmd, b.wmd);
    assert_eq!(a.iterations, b.iterations);
}

#[test]
fn dirty_workspace_reuse_is_bitwise_under_compaction() {
    // A → B → A: the compaction scratch (column list, subset prefix,
    // partitions, frozen masks) must fully re-shape on every checkout.
    let a = skewed_corpus();
    let b = corpus();
    let pool = Pool::new(3);
    let preps_a = prepare_all(&a, &pool);
    let preps_b = prepare_all(&b, &pool);
    let solver = SparseSolver::new(SinkhornConfig {
        lambda: 3.0,
        tolerance: 1e-4,
        check_every: 4,
        max_iter: 5_000,
        ..Default::default()
    });
    let prefs_a: Vec<&Prepared> = preps_a.iter().collect();
    let prefs_b: Vec<&Prepared> = preps_b.iter().collect();
    let fresh = solver.solve_batch(&prefs_a, &a.c, &pool);
    let mut ws = SolveWorkspace::new();
    let first = solver.solve_batch_in(&mut ws, &prefs_a, &a.c, &pool);
    let _ = solver.solve_batch_in(&mut ws, &prefs_b, &b.c, &pool);
    let again = solver.solve_batch_in(&mut ws, &prefs_a, &a.c, &pool);
    for q in 0..prefs_a.len() {
        assert_eq!(first[q].wmd, fresh[q].wmd, "q={q}: workspace first use diverged");
        assert_eq!(again[q].wmd, fresh[q].wmd, "q={q}: dirty reuse diverged");
        assert_eq!(again[q].iterations, fresh[q].iterations, "q={q}");
    }
}

#[test]
fn all_columns_freeze_at_the_first_check() {
    // A huge tolerance freezes every non-empty column at the very first
    // convergence check: the solve must stop right there, with the
    // histogram pinned at check_every, and match the exact-mode stop
    // bitwise (freezing happens after the identical update_u pass).
    let corpus = corpus();
    let pool = Pool::new(2);
    let preps = prepare_all(&corpus, &pool);
    let cfg = SinkhornConfig {
        tolerance: 1e9,
        check_every: 4,
        max_iter: 64,
        ..Default::default()
    };
    let out = SparseSolver::new(cfg).solve(&preps[0], &corpus.c, &pool);
    assert!(out.converged);
    assert_eq!(out.iterations, 4);
    assert_eq!(out.conv.frozen_columns, corpus.c.ncols());
    assert_eq!(out.conv.compactions, 0, "nothing left to compact after a full freeze");
    assert_eq!(out.conv.freeze_iters.min, 4);
    assert_eq!(out.conv.freeze_iters.max, 4);
    let exact = SparseSolver::new(SinkhornConfig { compact_every: 0, ..cfg })
        .solve(&preps[0], &corpus.c, &pool);
    assert_eq!(out.wmd, exact.wmd, "first-check freeze must equal the exact-mode stop");
    assert_eq!(out.iterations, exact.iterations);
    // Batched: every lane freezes wholesale at the first check too.
    let prefs: Vec<&Prepared> = preps.iter().take(2).collect();
    for o in SparseSolver::new(cfg).solve_batch(&prefs, &corpus.c, &pool) {
        assert!(o.converged);
        assert_eq!(o.iterations, 4);
        assert_eq!(o.conv.frozen_columns, corpus.c.ncols());
    }
}

#[test]
fn compaction_reduces_nnz_traversed_on_a_skewed_corpus() {
    // The perf claim behind the whole feature: on a skewed corpus the
    // short documents freeze early, compaction drops them from the walk,
    // and the traversed-nnz total lands well under iterations × nnz.
    let corpus = skewed_corpus();
    let pool = Pool::new(4);
    let preps = prepare_all(&corpus, &pool);
    let solver = SparseSolver::new(SinkhornConfig {
        lambda: 3.0,
        tolerance: 1e-4,
        check_every: 4,
        max_iter: 5_000,
        compact_threshold: 0.95,
        compact_every: 1,
        ..Default::default()
    });
    let out = solver.solve(&preps[0], &corpus.c, &pool);
    assert!(out.converged);
    assert!(out.conv.compactions >= 1, "compaction never triggered");
    assert!(
        out.conv.nnz_traversed < out.conv.nnz_full,
        "traversed {} must undercut full {}",
        out.conv.nnz_traversed,
        out.conv.nnz_full
    );
    // The histogram spread is what staggers the freezing: on a skewed
    // corpus the fastest doc freezes strictly earlier than the slowest.
    assert!(out.conv.freeze_iters.min < out.conv.freeze_iters.max);
}
