//! Shard-equivalence suite: merged sharded results must match the
//! unsharded solve — bitwise on one thread with the early exit disabled
//! (fixed iterations), within 1e-9 otherwise — including corpora with
//! empty documents (`+inf` entries must land at the right merged
//! indices) and zero-column shards.

use sinkhorn_wmd::coordinator::{
    DocStore, QueryRequest, ServiceConfig, ShardSet, ShardedDocStore, WmdService,
};
use sinkhorn_wmd::corpus::SyntheticCorpus;
use sinkhorn_wmd::parallel::Pool;
use sinkhorn_wmd::sinkhorn::{Prepared, SinkhornConfig, SparseSolver};
use sinkhorn_wmd::sparse::{Coo, Csr};
use std::sync::Arc;

fn corpus(seed: u64) -> SyntheticCorpus {
    SyntheticCorpus::builder()
        .vocab_size(500)
        .num_docs(40)
        .embedding_dim(16)
        .n_topics(4)
        .num_queries(4)
        .query_words(5, 10)
        .seed(seed)
        .build()
}

/// `c` with the given target columns emptied (empty documents).
fn drop_columns(c: &Csr, kill: &[usize]) -> Csr {
    let mut coo = Coo::new(c.nrows(), c.ncols());
    for (i, j, v) in c.iter() {
        if !kill.contains(&j) {
            coo.push(i, j, v);
        }
    }
    Csr::from_coo(coo)
}

#[test]
fn sharded_solve_is_bitwise_identical_across_shard_counts_and_batch_sizes() {
    let corpus = corpus(61);
    // Empty documents scattered across the column range (first, middle,
    // last): their +inf entries must land at the right merged indices in
    // every sharding.
    let kill = [0usize, 17, 39];
    let c = drop_columns(&corpus.c, &kill);
    let store = DocStore::new(corpus.embeddings.clone(), c).into_arc();
    let config = SinkhornConfig { tolerance: 0.0, max_iter: 12, ..Default::default() };
    let solver = SparseSolver::new(config);
    let pool = Pool::new(1);
    let preps: Vec<Arc<Prepared>> = corpus
        .queries
        .iter()
        .map(|q| Arc::new(solver.prepare(&corpus.embeddings, q, &pool)))
        .collect();
    for s in [1usize, 2, 3] {
        let sharded = ShardedDocStore::split(Arc::clone(&store), s);
        let set = ShardSet::start(sharded, config, 1);
        for bsz in [1usize, 4] {
            let batch: Vec<Arc<Prepared>> = preps[..bsz].to_vec();
            let merged = set.solve_batch(&batch);
            assert_eq!(merged.outputs.len(), bsz);
            let refs: Vec<&Prepared> = batch.iter().map(|p| p.as_ref()).collect();
            let base = solver.solve_batch(&refs, &store.c, &pool);
            for (q, (m, b)) in merged.outputs.iter().zip(&base).enumerate() {
                assert_eq!(m.wmd, b.wmd, "S={s} B={bsz} q={q}: merge must be bitwise");
                assert_eq!(m.iterations, b.iterations, "S={s} B={bsz} q={q}");
                for &k in &kill {
                    assert!(
                        m.wmd[k].is_infinite() && m.wmd[k] > 0.0,
                        "S={s} B={bsz} q={q}: empty doc {k} must merge to +inf, got {}",
                        m.wmd[k]
                    );
                }
                assert!(
                    m.wmd.iter().enumerate().all(|(j, v)| kill.contains(&j) || v.is_finite()),
                    "S={s} B={bsz} q={q}: a non-empty document came back non-finite"
                );
            }
        }
    }
}

#[test]
fn sharded_solve_multithreaded_matches_within_1e9() {
    let corpus = corpus(67);
    let store = DocStore::from_synthetic(&corpus).into_arc();
    let config = SinkhornConfig { tolerance: 0.0, max_iter: 15, ..Default::default() };
    let solver = SparseSolver::new(config);
    let pool = Pool::new(4);
    let preps: Vec<Arc<Prepared>> = corpus
        .queries
        .iter()
        .map(|q| Arc::new(solver.prepare(&corpus.embeddings, q, &pool)))
        .collect();
    let refs: Vec<&Prepared> = preps.iter().map(|p| p.as_ref()).collect();
    let base = solver.solve_batch(&refs, &store.c, &pool);
    for s in [2usize, 3] {
        let sharded = ShardedDocStore::split(Arc::clone(&store), s);
        let set = ShardSet::start(sharded, config, 2);
        let merged = set.solve_batch(&preps);
        for (q, (m, b)) in merged.outputs.iter().zip(&base).enumerate() {
            for (a, v) in m.wmd.iter().zip(&b.wmd) {
                assert!(
                    (a - v).abs() < 1e-9 * (1.0 + v.abs()),
                    "S={s} q={q}: {a} vs {v}"
                );
            }
        }
    }
}

#[test]
fn zero_column_shards_contribute_nothing() {
    let corpus = corpus(71);
    let store = DocStore::from_synthetic(&corpus).into_arc();
    let n = store.num_docs();
    let config = SinkhornConfig { tolerance: 0.0, max_iter: 10, ..Default::default() };
    let solver = SparseSolver::new(config);
    let pool = Pool::new(1);
    let prep = Arc::new(solver.prepare(&corpus.embeddings, corpus.query(0), &pool));
    // Empty shards at the front, middle-adjacent and back of the range.
    let sharded = ShardedDocStore::with_ranges(
        Arc::clone(&store),
        vec![0..0, 0..n / 2, n / 2..n / 2, n / 2..n, n..n],
    );
    let set = ShardSet::start(sharded, config, 1);
    let merged = set.solve_batch(&[Arc::clone(&prep)]);
    let base = solver.solve(&prep, &store.c, &pool);
    assert_eq!(merged.outputs[0].wmd, base.wmd, "empty shards must not perturb the merge");
    assert_eq!(merged.outputs[0].iterations, base.iterations);
    assert_eq!(merged.shard_iterations[0], 0, "zero-column shard runs no iterations");
    assert_eq!(merged.shard_iterations[2], 0);
    assert_eq!(merged.shard_iterations[4], 0);
    assert!(merged.shard_iterations[1] > 0 && merged.shard_iterations[3] > 0);
}

#[test]
fn sharded_solve_with_tolerance_converges_per_shard() {
    // With the residual early exit on, each shard stops once *its own*
    // columns meet the criterion — every document still satisfies the
    // same residual guarantee as an unsharded run.
    let corpus = corpus(73);
    let store = DocStore::from_synthetic(&corpus).into_arc();
    let config = SinkhornConfig {
        lambda: 3.0,
        tolerance: 1e-5,
        max_iter: 5000,
        ..Default::default()
    };
    let solver = SparseSolver::new(config);
    let pool = Pool::new(2);
    let prep = Arc::new(solver.prepare(&corpus.embeddings, corpus.query(0), &pool));
    let sharded = ShardedDocStore::split(Arc::clone(&store), 2);
    let set = ShardSet::start(sharded, config, 2);
    let merged = set.solve_batch(&[Arc::clone(&prep)]);
    let out = &merged.outputs[0];
    assert!(out.converged, "every shard must converge");
    assert!(out.iterations < 5000);
    assert!(out.wmd.iter().all(|v| v.is_finite() && *v >= 0.0));
}

#[test]
fn sharded_service_merges_infinite_entries_at_global_indices() {
    let corpus = corpus(79);
    let kill = [2usize, 21];
    let c = drop_columns(&corpus.c, &kill);
    let store = DocStore::new(corpus.embeddings.clone(), c).into_arc();
    let service = WmdService::start(
        Arc::clone(&store),
        ServiceConfig { threads: 1, shards: 2, shard_threads: 1, ..Default::default() },
        None,
    );
    let resp = service.submit_wait(QueryRequest::new(corpus.query(0).clone()));
    assert!(resp.is_ok(), "{:?}", resp.error);
    assert_eq!(resp.wmd.len(), store.num_docs());
    for &k in &kill {
        assert!(
            resp.wmd[k].is_infinite() && resp.wmd[k] > 0.0,
            "empty doc {k} must merge to +inf, got {}",
            resp.wmd[k]
        );
    }
    assert!(resp.argmin().is_some());
    assert!(!kill.contains(&resp.argmin().unwrap()), "an empty doc won the argmin");
    let snap = service.metrics().snapshot();
    assert_eq!(snap.sharded_solves, 1);
    assert_eq!(snap.shard_solves, 2);
    service.shutdown();
}
