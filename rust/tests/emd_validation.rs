//! Cuturi's theorem, empirically: the Sinkhorn distance converges to the
//! exact EMD as λ → ∞ (paper §2 cites the proof; we validate the
//! implementation against the in-repo exact transportation solver).

use sinkhorn_wmd::corpus::{docs_to_csr, SparseVec, TinyCorpus};
use sinkhorn_wmd::emd::exact_wmd;
use sinkhorn_wmd::parallel::Pool;
use sinkhorn_wmd::sinkhorn::{SinkhornConfig, SparseSolver};
use sinkhorn_wmd::sparse::Dense;
use sinkhorn_wmd::util::Pcg64;

/// Sinkhorn 1-to-1 distance via the one-to-many solver with a single
/// target column.
fn sinkhorn_one_to_one(
    embeddings: &Dense,
    a: &SparseVec,
    b: &SparseVec,
    lambda: f64,
    max_iter: usize,
) -> f64 {
    let c = docs_to_csr(a.dim, std::slice::from_ref(b));
    let pool = Pool::new(2);
    let solver = SparseSolver::new(SinkhornConfig {
        lambda,
        max_iter,
        tolerance: 1e-10,
        check_every: 8,
        ..Default::default()
    });
    solver.wmd_one_to_many(embeddings, a, &c, &pool).wmd[0]
}

fn random_pair(rng: &mut Pcg64, dim: usize, nnz: usize) -> (SparseVec, SparseVec) {
    let mk = |rng: &mut Pcg64| {
        let idx = rng.sample_indices(dim, nnz);
        let counts: Vec<(usize, usize)> = idx.into_iter().map(|i| (i, rng.range(1, 5))).collect();
        SparseVec::from_counts(dim, &counts)
    };
    (mk(rng), mk(rng))
}

#[test]
fn sinkhorn_upper_bounds_and_approaches_exact_emd() {
    let mut rng = Pcg64::new(404);
    let dim = 60;
    let emb = Dense::from_fn(dim, 8, |_, _| rng.next_gaussian() * 0.5);
    for case in 0..5 {
        let (a, b) = random_pair(&mut rng, dim, 5);
        let exact = exact_wmd(&emb, &a, &b);
        // Entropic smoothing keeps the plan away from the optimal vertex,
        // so the regularized transport cost is ≥ exact; the gap shrinks
        // with λ.
        let d_small = sinkhorn_one_to_one(&emb, &a, &b, 5.0, 4000);
        let d_large = sinkhorn_one_to_one(&emb, &a, &b, 40.0, 20000);
        assert!(
            d_small >= exact - 1e-6,
            "case {case}: sinkhorn λ=5 ({d_small}) below exact ({exact})"
        );
        let gap_small = (d_small - exact).abs();
        let gap_large = (d_large - exact).abs();
        assert!(
            gap_large <= gap_small + 1e-9,
            "case {case}: gap did not shrink with λ: {gap_small} -> {gap_large}"
        );
        assert!(
            gap_large < 0.05 * exact.max(0.1),
            "case {case}: λ=40 gap too large: exact={exact} sinkhorn={d_large}"
        );
    }
}

#[test]
fn self_distance_is_near_zero_for_large_lambda() {
    let tiny = TinyCorpus::load();
    let doc = &tiny.docs[0];
    let d = sinkhorn_one_to_one(&tiny.embeddings, doc, doc, 60.0, 20000);
    // Exact EMD(a, a) = 0; entropic smoothing leaves a small positive bias.
    assert!(d >= -1e-12);
    assert!(d < 0.05, "self-distance {d} too large");
}

#[test]
fn tiny_corpus_semantics_match_paper_example() {
    // WMD("Obama speaks to the media in Illinois",
    //     "The President greets the press in Chicago")
    //   < WMD(obama-sentence, food/sports/misc sentences)  — paper Fig. 1.
    let tiny = TinyCorpus::load();
    let query = tiny.histogram("Obama speaks to the media in Illinois").unwrap();
    let president = tiny.histogram("The President greets the press in Chicago").unwrap();
    let food = tiny.histogram("The chef cooks sushi for dinner in Japan").unwrap();
    let misc = tiny.histogram("Amy Adams was in deepFake").unwrap();
    let d = |b: &SparseVec| sinkhorn_one_to_one(&tiny.embeddings, &query, b, 30.0, 8000);
    let d_pres = d(&president);
    let d_food = d(&food);
    let d_misc = d(&misc);
    assert!(d_pres < d_food, "president {d_pres} !< food {d_food}");
    assert!(d_pres < d_misc, "president {d_pres} !< misc {d_misc}");
    // And the exact EMD agrees on the ordering.
    let e_pres = exact_wmd(&tiny.embeddings, &query, &president);
    let e_food = exact_wmd(&tiny.embeddings, &query, &food);
    assert!(e_pres < e_food);
}

#[test]
fn exact_emd_symmetry() {
    let mut rng = Pcg64::new(405);
    let dim = 40;
    let emb = Dense::from_fn(dim, 6, |_, _| rng.next_gaussian());
    for _ in 0..5 {
        let (a, b) = random_pair(&mut rng, dim, 4);
        let ab = exact_wmd(&emb, &a, &b);
        let ba = exact_wmd(&emb, &b, &a);
        assert!((ab - ba).abs() < 1e-9, "{ab} vs {ba}");
    }
}

#[test]
fn exact_emd_triangle_inequality() {
    // EMD with a metric ground cost is a metric; spot-check the triangle
    // inequality on random triples.
    let mut rng = Pcg64::new(406);
    let dim = 30;
    let emb = Dense::from_fn(dim, 5, |_, _| rng.next_gaussian());
    for _ in 0..10 {
        let (a, b) = random_pair(&mut rng, dim, 3);
        let (c, _) = random_pair(&mut rng, dim, 3);
        let ab = exact_wmd(&emb, &a, &b);
        let bc = exact_wmd(&emb, &b, &c);
        let ac = exact_wmd(&emb, &a, &c);
        assert!(ac <= ab + bc + 1e-9, "triangle violated: {ac} > {ab} + {bc}");
    }
}
