//! CI fuzz budget over every parser target (`testing::fuzz`): a fixed
//! master seed so runs are reproducible, scaled by `WMD_FUZZ_ITERS`
//! (default 250 cases per target; the CI job sets a larger budget).
//! Any crash report carries the per-case seed — pin it as a
//! `replay_case` regression in `tests/fuzz_regressions.rs`.

use sinkhorn_wmd::testing::fuzz::{fuzz_all, fuzz_target, TARGETS};

fn budget() -> u64 {
    std::env::var("WMD_FUZZ_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(250)
}

/// The same master seed every run: a CI failure is reproducible locally
/// with nothing but the printed per-case seed.
const MASTER_SEED: u64 = 0x00C0_FFEE_0B5C_0DE5;

#[test]
fn all_parsers_survive_the_fuzz_budget() {
    let iters = budget();
    let crashes = fuzz_all(iters, MASTER_SEED);
    let report: Vec<String> = crashes.iter().map(|c| c.to_string()).collect();
    assert!(
        crashes.is_empty(),
        "{} crash(es) in {iters} cases/target — pin each seed in \
         tests/fuzz_regressions.rs:\n{}",
        crashes.len(),
        report.join("\n")
    );
}

#[test]
fn a_second_seed_lineage_also_survives() {
    // A disjoint case lineage (different master seed) at a small budget:
    // guards against the main seed lineage happening to miss an entire
    // mutation class.
    for target in TARGETS {
        let crashes = fuzz_target(target, 50, MASTER_SEED ^ u64::MAX);
        let report: Vec<String> = crashes.iter().map(|c| c.to_string()).collect();
        assert!(crashes.is_empty(), "[{target}]:\n{}", report.join("\n"));
    }
}
