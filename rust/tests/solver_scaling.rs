//! Cross-cutting solver invariants at a mid-size workload: determinism
//! across thread counts and kernels, Table-2 cost-model sanity, and the
//! paper-scale statistics of the generator.

use sinkhorn_wmd::corpus::SyntheticCorpus;
use sinkhorn_wmd::parallel::Pool;
use sinkhorn_wmd::sinkhorn::{DenseSolver, IterateKernel, Precision, SinkhornConfig, SparseSolver};

fn mid_corpus() -> SyntheticCorpus {
    SyntheticCorpus::builder()
        .vocab_size(4_000)
        .num_docs(200)
        .embedding_dim(64)
        .n_topics(6)
        .num_queries(3)
        .query_words(19, 43)
        .seed(2024)
        .build()
}

#[test]
fn kernels_and_threads_commute_at_mid_scale() {
    let corpus = mid_corpus();
    let config = SinkhornConfig { tolerance: 0.0, max_iter: 10, ..Default::default() };
    let reference = {
        let pool = Pool::new(1);
        SparseSolver::new(config).wmd_one_to_many(&corpus.embeddings, corpus.query(0), &corpus.c, &pool)
    };
    let mut kernels = vec![
        IterateKernel::Fused { precision: Precision::F64 },
        IterateKernel::Unfused,
    ];
    #[cfg(feature = "mixed-precision")]
    kernels.push(IterateKernel::Fused { precision: Precision::Mixed });
    for kernel in kernels {
        // Mixed runs f32 compute panels: its gate is the documented 1e-5
        // bound, not the f64 kernels' 1e-9.
        let tol = if kernel == (IterateKernel::Fused { precision: Precision::F64 })
            || kernel == IterateKernel::Unfused
        {
            1e-9
        } else {
            1e-5
        };
        for p in [2usize, 6] {
            let pool = Pool::new(p);
            let solver = SparseSolver::new(SinkhornConfig { kernel, ..config });
            let out = solver.wmd_one_to_many(&corpus.embeddings, corpus.query(0), &corpus.c, &pool);
            let max_rel = out
                .wmd
                .iter()
                .zip(&reference.wmd)
                .map(|(a, b)| (a - b).abs() / b.abs().max(1e-300))
                .fold(0.0f64, sinkhorn_wmd::util::nan_max2);
            assert!(max_rel < tol, "{kernel:?} p={p}: {max_rel:.2e}");
        }
    }
}

#[test]
fn dense_baseline_agrees_at_mid_scale() {
    let corpus = mid_corpus();
    let pool = Pool::new(4);
    let config = SinkhornConfig { tolerance: 0.0, max_iter: 8, ..Default::default() };
    let sparse = SparseSolver::new(config)
        .wmd_one_to_many(&corpus.embeddings, corpus.query(1), &corpus.c, &pool);
    let (dense, times) =
        DenseSolver::new(config).solve(&corpus.embeddings, corpus.query(1), &corpus.c, &pool);
    let max_rel = sparse
        .wmd
        .iter()
        .zip(&dense.wmd)
        .map(|(a, b)| (a - b).abs() / b.abs().max(1e-300))
        .fold(0.0f64, sinkhorn_wmd::util::nan_max2);
    assert!(max_rel < 1e-9, "dense vs sparse: {max_rel:.2e}");
    // The Table-1 shape: the dense matmul dominates the dense pipeline.
    let rows = times.rows();
    let matmul_pct = rows.iter().find(|r| r.0.contains("KT @ u")).unwrap().2;
    let spmm_pct = rows.iter().find(|r| r.0.contains("dense x sparse")).unwrap().2;
    assert!(
        matmul_pct > spmm_pct,
        "dense matmul ({matmul_pct:.1}%) should dominate the sparse-side spmm ({spmm_pct:.1}%)"
    );
}

#[test]
fn corpus_statistics_track_paper() {
    let corpus = mid_corpus();
    // Queries span the paper's 19..43 range.
    let sizes: Vec<usize> = corpus.queries.iter().map(|q| q.nnz()).collect();
    assert_eq!(sizes[0], 19);
    assert_eq!(*sizes.last().unwrap(), 43);
    // Document density matches the paper's "tens of words per doc".
    let mean = corpus.mean_doc_words();
    assert!((15.0..60.0).contains(&mean), "mean doc words {mean}");
}

#[test]
fn runtime_scales_with_nnz_not_with_dense_size() {
    // Table 2's dominant iterate term is t·nnz·v_r/p: doubling only N
    // (and thus nnz) should roughly double iterate time, while the dense
    // pipeline's V×N term grows the same way — the *sparse* advantage is
    // the V-independence of the iterate. Verify solve time is much less
    // than proportional to V·N.
    let small = SyntheticCorpus::builder()
        .vocab_size(2_000)
        .num_docs(100)
        .embedding_dim(32)
        .num_queries(1)
        .query_words(20, 20)
        .seed(31)
        .build();
    let big_vocab = SyntheticCorpus::builder()
        .vocab_size(16_000) // 8× vocabulary
        .num_docs(100)
        .embedding_dim(32)
        .num_queries(1)
        .query_words(20, 20)
        .seed(31)
        .build();
    let pool = Pool::new(2);
    let config = SinkhornConfig { tolerance: 0.0, max_iter: 30, ..Default::default() };
    let solver = SparseSolver::new(config);
    // Warm + measure the iterate-dominated solve (prepare is excluded:
    // the precompute *is* O(V) by design).
    let prep_s = solver.prepare(&small.embeddings, small.query(0), &pool);
    let prep_b = solver.prepare(&big_vocab.embeddings, big_vocab.query(0), &pool);
    let time = |prep, c: &sinkhorn_wmd::sparse::Csr| {
        let t0 = std::time::Instant::now();
        let _ = solver.solve(prep, c, &pool);
        t0.elapsed().as_secs_f64()
    };
    let _ = time(&prep_s, &small.c);
    let t_small = time(&prep_s, &small.c);
    let t_big = time(&prep_b, &big_vocab.c);
    // nnz is comparable (same docs × words/doc); an 8× vocab must not
    // cost anywhere near 8× — allow generous slack for cache effects.
    assert!(
        t_big < t_small * 4.0,
        "iterate scaled with V: {t_small:.4}s -> {t_big:.4}s"
    );
}
