//! End-to-end ingestion: the checked-in example mini-corpus
//! (`examples/ingest/`) through `.vec` parsing → document streaming →
//! incremental build → v2 snapshot save/load → solve, pinned bitwise
//! against the in-memory corpus; plus the v1/v2 snapshot format matrix.
//!
//! No network, no generated fixtures: everything reads the repository's
//! `examples/ingest/` files (the same ones the README walkthrough uses).

use sinkhorn_wmd::corpus::io::{load_corpus_any, save_corpus, save_corpus_v2};
use sinkhorn_wmd::corpus::{ingest_corpus, DocFormat, SyntheticCorpus};
use sinkhorn_wmd::parallel::Pool;
use sinkhorn_wmd::sinkhorn::{SinkhornConfig, SparseSolver};
use sinkhorn_wmd::Real;
use std::path::PathBuf;

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/ingest").join(name)
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("wmd-ingest-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn example_corpus_ingests_with_expected_shape() {
    let (corpus, stats) =
        ingest_corpus(&fixture("mini.vec"), &fixture("mini_docs.txt"), DocFormat::Text).unwrap();
    // Every word of the 20-word .vec file is used by some document, so
    // the vocabulary filter keeps all of them.
    assert_eq!(corpus.vocab_size(), 20);
    assert_eq!(corpus.embeddings.ncols(), 6);
    assert_eq!(corpus.num_docs(), 7);
    assert!(corpus.has_words());
    assert_eq!(stats.docs, 7);
    assert_eq!(stats.empty_docs, 1, "the all-stopword line is an empty column");
    // "about" and "serves" have no embeddings; the quoted 'dinner' must
    // NOT be OOV (the tokenizer strips quoting apostrophes).
    assert_eq!(stats.tokens_oov, 2);
    // Non-empty columns are unit mass; the empty one carries none.
    let sums = corpus.c.column_sums();
    for s in &sums[..6] {
        assert!((s - 1.0).abs() < 1e-12);
    }
    assert_eq!(sums[6], 0.0);
}

#[test]
fn jsonl_ingest_matches_plaintext_ingest_bitwise() {
    let (text, _) =
        ingest_corpus(&fixture("mini.vec"), &fixture("mini_docs.txt"), DocFormat::Text).unwrap();
    let (jsonl, _) =
        ingest_corpus(&fixture("mini.vec"), &fixture("mini_docs.jsonl"), DocFormat::Jsonl)
            .unwrap();
    assert_eq!(text.c, jsonl.c);
    assert_eq!(text.embeddings, jsonl.embeddings);
    assert_eq!(text.vocab.len(), jsonl.vocab.len());
    for i in 0..text.vocab.len() {
        assert_eq!(text.vocab.word(i), jsonl.vocab.word(i));
    }
}

#[test]
fn v2_snapshot_roundtrips_and_solves_bitwise() {
    let (corpus, _) =
        ingest_corpus(&fixture("mini.vec"), &fixture("mini_docs.txt"), DocFormat::Text).unwrap();
    let dir = tmp_dir("v2");
    let path = dir.join("mini.wmdc");
    save_corpus_v2(&path, &corpus).unwrap();
    let back = load_corpus_any(&path).unwrap();
    assert_eq!(back.embeddings, corpus.embeddings);
    assert_eq!(back.c, corpus.c);
    for i in 0..corpus.vocab.len() {
        assert_eq!(back.vocab.word(i), corpus.vocab.word(i));
    }

    // The same raw-text query against the in-memory corpus and the
    // reloaded snapshot must produce the same histogram and, on one
    // thread, bitwise-identical WMD vectors.
    let text = "Obama speaks to the media in Illinois";
    let q_mem = corpus.text_query(text).unwrap();
    let q_snap = back.text_query(text).unwrap();
    assert_eq!(q_mem, q_snap);
    let pool = Pool::new(1);
    let solver = SparseSolver::new(SinkhornConfig::default());
    let out_mem = solver.wmd_one_to_many(&corpus.embeddings, &q_mem, &corpus.c, &pool);
    let out_snap = solver.wmd_one_to_many(&back.embeddings, &q_snap, &back.c, &pool);
    assert_eq!(out_mem.wmd, out_snap.wmd);
    assert_eq!(out_mem.iterations, out_snap.iterations);

    // Paper §2 semantics: the identical document wins outright, the
    // President/press/Chicago paraphrase beats every unrelated document,
    // and the empty column reports +inf (never ranks).
    let ranked = out_snap.top_k(corpus.num_docs());
    assert_eq!(ranked[0].0, 0, "identical sentence is the nearest document");
    assert!(
        ranked[0].1 < ranked[1].1,
        "identical sentence strictly beats the paraphrase: {ranked:?}"
    );
    assert_eq!(ranked[1].0, 1, "the paraphrase outranks unrelated documents");
    assert_eq!(out_snap.wmd[6], Real::INFINITY, "empty document reports +inf");
    assert_eq!(ranked.len(), 6, "the empty document never ranks");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn v1_snapshots_from_before_ingestion_still_load() {
    // A v1 writer unchanged by this PR: what `gen-corpus --out` produced
    // before must load through both the typed and the generic loader.
    let synthetic = SyntheticCorpus::builder()
        .vocab_size(400)
        .num_docs(30)
        .embedding_dim(12)
        .num_queries(3)
        .query_words(5, 9)
        .seed(23)
        .build();
    let dir = tmp_dir("v1");
    let path = dir.join("v1.wmdc");
    save_corpus(&path, &synthetic).unwrap();
    let generic = load_corpus_any(&path).unwrap();
    assert_eq!(generic.embeddings, synthetic.embeddings);
    assert_eq!(generic.c, synthetic.c);
    assert_eq!(generic.queries, synthetic.queries);
    assert_eq!(generic.doc_topics, synthetic.doc_topics);
    assert!(!generic.has_words(), "v1 snapshots carry no word strings");
    assert!(generic.text_query("anything").is_err());

    // The v1 payload solves identically whether loaded typed or generic.
    let pool = Pool::new(1);
    let solver = SparseSolver::new(SinkhornConfig::default());
    let typed = sinkhorn_wmd::corpus::io::load_corpus(&path).unwrap();
    let a = solver.wmd_one_to_many(&typed.embeddings, &typed.queries[0], &typed.c, &pool);
    let b = solver.wmd_one_to_many(&generic.embeddings, &generic.queries[0], &generic.c, &pool);
    assert_eq!(a.wmd, b.wmd);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn ingested_snapshot_serves_raw_text_queries() {
    // The acceptance path: ingest → save → load → service → raw-text
    // query answered with a ranked result.
    use sinkhorn_wmd::coordinator::{DocStore, QueryRequest, ServiceConfig, WmdService};

    let (corpus, _) =
        ingest_corpus(&fixture("mini.vec"), &fixture("mini_docs.txt"), DocFormat::Text).unwrap();
    let dir = tmp_dir("serve");
    let path = dir.join("mini.wmdc");
    save_corpus_v2(&path, &corpus).unwrap();
    let back = load_corpus_any(&path).unwrap();
    let store = DocStore::from_corpus(&back).into_arc();
    let query = store.text_query("the president speaks to journalists in chicago").unwrap();
    let service = WmdService::start(
        std::sync::Arc::clone(&store),
        ServiceConfig { threads: 1, ..Default::default() },
        None,
    );
    let resp = service.submit_wait(QueryRequest::new(query));
    assert!(resp.is_ok(), "{:?}", resp.error);
    assert_eq!(resp.wmd.len(), 7);
    let best = resp.argmin().unwrap();
    assert!(
        best == 0 || best == 1,
        "a politics/press query must retrieve a politics/press document, got {best}"
    );
    assert_eq!(resp.wmd[6], Real::INFINITY);
    service.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}
