//! Integration tests for the staged bound cascade: bound ordering
//! against the exact EMD, shard-vs-monolithic equivalence through the
//! query service, and recall@k == 1.0 at unbounded budgets.

use sinkhorn_wmd::coordinator::{DocStore, QueryRequest, ServiceConfig, WmdService};
use sinkhorn_wmd::corpus::{SparseVec, SyntheticCorpus};
use sinkhorn_wmd::parallel::Pool;
use sinkhorn_wmd::prune::{
    centroids, evaluate_recall, lcrwmd_lower_bounds, rwmd_lower_bound, wcd_lower_bound,
    CascadeSpec,
};
use sinkhorn_wmd::sinkhorn::SinkhornConfig;
use sinkhorn_wmd::sparse::ops::TransposedPattern;
use sinkhorn_wmd::Real;
use std::sync::Arc;

fn corpus() -> SyntheticCorpus {
    SyntheticCorpus::builder()
        .vocab_size(500)
        .num_docs(48)
        .embedding_dim(12)
        .n_topics(4)
        .num_queries(3)
        .query_words(5, 10)
        .seed(777)
        .build()
}

/// Column `j` of the target CSR as a standalone histogram.
fn doc_histogram(c: &sinkhorn_wmd::sparse::Csr, pattern: &TransposedPattern, j: usize) -> SparseVec {
    let values = c.values();
    let span = pattern.col_ptr[j]..pattern.col_ptr[j + 1];
    SparseVec {
        dim: c.nrows(),
        idx: span.clone().map(|e| pattern.src_row[e]).collect(),
        val: span.map(|e| values[pattern.src_pos[e] as usize]).collect(),
    }
}

#[test]
fn accumulated_stage_bounds_stay_below_exact_emd() {
    // The cascade max-combines per-stage bounds. Validity requires every
    // accumulated bound — max(wcd), max(wcd, lcrwmd), max(wcd, lcrwmd,
    // rwmd) — to lower-bound the exact EMD; accumulation is monotone by
    // construction, so the load-bearing check is `accumulated ≤ exact`
    // after every stage.
    let corpus = corpus();
    let pool = Pool::new(2);
    let cents = centroids(&corpus.embeddings, &corpus.c, &pool);
    let pattern = TransposedPattern::build(&corpus.c);
    let tol = 1e-9;
    for q in 0..3 {
        let query = corpus.query(q);
        let wcd = wcd_lower_bound(&corpus.embeddings, query, &cents, &pool);
        let lc = lcrwmd_lower_bounds(&corpus.embeddings, query, &corpus.c, &pool);
        for j in 0..corpus.c.ncols() {
            let doc = doc_histogram(&corpus.c, &pattern, j);
            if doc.idx.is_empty() {
                assert_eq!(lc[j], Real::INFINITY, "empty doc must bound at +inf");
                continue;
            }
            let exact = sinkhorn_wmd::emd::exact_wmd(&corpus.embeddings, query, &doc);
            let rw = rwmd_lower_bound(&corpus.embeddings, query, &corpus.c, j);
            let acc1 = wcd[j];
            let acc2 = acc1.max(lc[j]);
            let acc3 = acc2.max(rw);
            assert!(acc1 <= acc2 && acc2 <= acc3, "accumulation must tighten monotonically");
            for (stage, acc) in [("wcd", acc1), ("+lcrwmd", acc2), ("+rwmd", acc3)] {
                assert!(
                    acc <= exact + tol * (1.0 + exact.abs()),
                    "q{q} doc{j} {stage}: accumulated bound {acc} exceeds exact EMD {exact}"
                );
            }
        }
    }
}

#[test]
fn raw_bounds_individually_lower_bound_exact_emd() {
    let corpus = corpus();
    let pool = Pool::new(2);
    let pattern = TransposedPattern::build(&corpus.c);
    let query = corpus.query(0);
    let lc = lcrwmd_lower_bounds(&corpus.embeddings, query, &corpus.c, &pool);
    for j in 0..corpus.c.ncols() {
        let doc = doc_histogram(&corpus.c, &pattern, j);
        if doc.idx.is_empty() {
            continue;
        }
        let exact = sinkhorn_wmd::emd::exact_wmd(&corpus.embeddings, query, &doc);
        let rw = rwmd_lower_bound(&corpus.embeddings, query, &corpus.c, j);
        assert!(lc[j] <= exact + 1e-9 * (1.0 + exact.abs()), "doc{j}: lcrwmd {} > {exact}", lc[j]);
        assert!(rw <= exact + 1e-9 * (1.0 + exact.abs()), "doc{j}: rwmd {rw} > {exact}");
    }
}

#[test]
fn sharded_service_top_k_equals_monolithic_for_one_two_three_shards() {
    let corpus = corpus();
    let store = DocStore::from_synthetic(&corpus).into_arc();
    // One thread everywhere + unbounded budgets: the merged shard-local
    // top-ks must reproduce the monolithic answer exactly.
    let mk = |shards: usize| {
        WmdService::start(
            Arc::clone(&store),
            ServiceConfig { threads: 1, shards, shard_threads: 1, ..Default::default() },
            None,
        )
    };
    let mono = mk(1);
    for shards in [2usize, 3] {
        let svc = mk(shards);
        for q in 0..3 {
            let a = mono.submit_wait(QueryRequest::top_k(corpus.query(q).clone(), 6));
            let b = svc.submit_wait(QueryRequest::top_k(corpus.query(q).clone(), 6));
            assert!(a.is_ok() && b.is_ok(), "{:?} / {:?}", a.error, b.error);
            assert_eq!(a.top.len(), 6);
            assert_eq!(a.top, b.top, "q{q}: {shards}-shard cascade diverged");
        }
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.cascade_queries, 3);
        assert_eq!(snap.cascade_wcd_in as usize, 3 * corpus.c.ncols());
        svc.shutdown();
    }
    mono.shutdown();
}

#[test]
fn recall_at_k_is_one_for_every_unbounded_cascade() {
    let corpus = corpus();
    let pool = Pool::new(2);
    let specs = [
        CascadeSpec::parse("wcd,sinkhorn").unwrap(),
        CascadeSpec::parse("wcd,lcrwmd,sinkhorn").unwrap(),
        CascadeSpec::parse("wcd,lcrwmd,rwmd,sinkhorn").unwrap(),
        CascadeSpec::parse("lcrwmd,sinkhorn").unwrap(),
    ];
    let rows = evaluate_recall(
        &corpus.embeddings,
        &corpus.c,
        &corpus.queries,
        SinkhornConfig::default(),
        10,
        &specs,
        &pool,
    );
    assert_eq!(rows.len(), specs.len());
    for r in &rows {
        assert_eq!(r.recall, 1.0, "unbounded `{}` must be exact: {r:?}", r.spec);
        assert!(
            r.exact_evals <= r.total_docs,
            "bounds can only reduce exact evaluations: {r:?}"
        );
    }
}
