//! Three-layer integration: the AOT-compiled L2/L1 artifacts (JAX +
//! Pallas, loaded via PJRT) must reproduce the Rust sparse solver's
//! numbers. Skipped (with a notice) when `artifacts/` hasn't been built —
//! run `make artifacts` first.

use sinkhorn_wmd::coordinator::{DocStore, PjrtBackend};
use sinkhorn_wmd::corpus::{SparseVec, SyntheticCorpus};
use sinkhorn_wmd::dist::precompute_factors;
use sinkhorn_wmd::parallel::Pool;
use sinkhorn_wmd::runtime::{Manifest, Runtime};
use sinkhorn_wmd::sinkhorn::{SinkhornConfig, SparseSolver};
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(Box::leak(dir.into_boxed_path()))
    } else {
        eprintln!("SKIP: artifacts/manifest.json not found — run `make artifacts`");
        None
    }
}

/// Corpus matching the default artifact bucket shapes.
fn bucket_corpus() -> SyntheticCorpus {
    SyntheticCorpus::builder()
        .vocab_size(2048)
        .num_docs(256)
        .embedding_dim(64)
        .n_topics(4)
        .num_queries(2)
        .query_words(10, 20)
        .seed(99)
        .build()
}

/// A query with exactly `v_r` distinct words (bucket-exact, no padding).
fn exact_query(corpus: &SyntheticCorpus, v_r: usize) -> SparseVec {
    let counts: Vec<(usize, usize)> = (0..v_r).map(|k| (37 * k + 11, k % 3 + 1)).collect();
    SparseVec::from_counts(corpus.vocab_size(), &counts)
}

#[test]
fn pjrt_solve_matches_rust_sparse_at_exact_bucket() {
    let Some(dir) = artifacts_dir() else { return };
    let corpus = bucket_corpus();
    let store = DocStore::from_synthetic(&corpus);
    let backend = PjrtBackend::load(dir, &store)
        .expect("backend load")
        .expect("no artifacts match the bucket corpus shape");
    let manifest = Manifest::read(dir).unwrap();
    let pool = Pool::new(4);
    for &v_r in &manifest.v_r_buckets("sinkhorn_solve", 2048, 256) {
        let meta = manifest.find("sinkhorn_solve", v_r, 2048, 256).unwrap();
        let query = exact_query(&corpus, v_r);
        let wmd_pjrt = backend.solve(&query, &store.embeddings).expect("pjrt solve");
        // Same λ and iteration count as the artifact, no early exit.
        let solver = SparseSolver::new(SinkhornConfig {
            lambda: meta.lambda,
            max_iter: meta.max_iter,
            tolerance: 0.0,
            ..Default::default()
        });
        let out = solver.wmd_one_to_many(&corpus.embeddings, &query, &corpus.c, &pool);
        let max_rel = wmd_pjrt
            .iter()
            .zip(&out.wmd)
            .map(|(a, b)| (a - b).abs() / b.abs().max(1e-300))
            .fold(0.0f64, sinkhorn_wmd::util::nan_max2);
        // Tolerance: XLA's matmul accumulation order differs from our
        // 4-lane dot, and the GEMM-form cdist amplifies cancellation
        // noise near zero distances by √ then ×λ — a few 1e-9 relative
        // after 15 iterations is fp-expected, not a logic divergence.
        assert!(
            max_rel < 1e-7,
            "v_r={v_r}: PJRT and Rust sparse disagree by {max_rel:.3e}"
        );
    }
}

#[test]
fn pjrt_padding_perturbation_is_small_at_convergence() {
    // ε-padding changes the transient, not the limit: compare a padded
    // query at high iteration count against the unpadded solve.
    let Some(dir) = artifacts_dir() else { return };
    let corpus = bucket_corpus();
    let store = DocStore::from_synthetic(&corpus);
    let backend = PjrtBackend::load(dir, &store).unwrap().unwrap();
    let pool = Pool::new(4);
    // v_r = 13 pads to bucket 16.
    let query = exact_query(&corpus, 13);
    let bucket = backend.router().bucket_for(13).expect("bucket");
    let padded = backend.router().pad_query(&query, bucket);
    let solver = SparseSolver::new(SinkhornConfig {
        lambda: 10.0,
        max_iter: 400,
        tolerance: 0.0,
        ..Default::default()
    });
    let unpadded = solver.wmd_one_to_many(&corpus.embeddings, &query, &corpus.c, &pool);
    let padded_out = solver.wmd_one_to_many(&corpus.embeddings, &padded, &corpus.c, &pool);
    let max_rel = unpadded
        .wmd
        .iter()
        .zip(&padded_out.wmd)
        .map(|(a, b)| (a - b).abs() / b.abs().max(1e-300))
        .fold(0.0f64, sinkhorn_wmd::util::nan_max2);
    assert!(max_rel < 1e-4, "padding perturbs converged WMD by {max_rel:.3e}");
}

#[test]
fn cdist_k_artifact_matches_rust_precompute() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::read(dir).unwrap();
    let Some(meta) = manifest.artifacts.iter().find(|a| a.variant == "cdist_k") else {
        eprintln!("SKIP: no cdist_k artifact");
        return;
    };
    let corpus = SyntheticCorpus::builder()
        .vocab_size(meta.vocab)
        .num_docs(8)
        .embedding_dim(meta.dim)
        .num_queries(1)
        .query_words(meta.v_r, meta.v_r)
        .seed(7)
        .build();
    let query = &corpus.queries[0];
    assert_eq!(query.nnz(), meta.v_r);
    let rt = Runtime::cpu().expect("pjrt client");
    let art = rt.load(dir, meta).expect("compile cdist_k");
    // Inputs: qvecs, vecs, r.
    let mut qvecs = Vec::new();
    for &w in &query.idx {
        qvecs.extend_from_slice(corpus.embeddings.row(w as usize));
    }
    let outs = art
        .run(&[&qvecs, corpus.embeddings.as_slice(), &query.val])
        .expect("run cdist_k");
    let (kt_jax, kor_jax, km_jax) = (&outs[0], &outs[1], &outs[2]);
    // Rust factors.
    let pool = Pool::new(4);
    let f = precompute_factors(&corpus.embeddings, &query.indices(), &query.val, meta.lambda, &pool);
    for (name, jax, rust) in [
        ("kt", kt_jax, f.kt.as_slice()),
        ("kor_t", kor_jax, f.kor_t.as_slice()),
        ("km_t", km_jax, f.km_t.as_slice()),
    ] {
        assert_eq!(jax.len(), rust.len(), "{name} length");
        let max_mixed = jax
            .iter()
            .zip(rust)
            .map(|(a, b)| (a - b).abs() / (1.0 + b.abs()))
            .fold(0.0f64, sinkhorn_wmd::util::nan_max2);
        // The GEMM-form d² = ‖q‖²+‖y‖²−2q·y has absolute cancellation
        // noise ~1e-16·‖q‖² near d = 0; √ turns that into ~1e-8 on d and
        // exp(−λd) into ~1e-6 on K near self-distances (amplified by 1/r
        // for K_over_r). Both sides do the same math with different
        // rounding orders, so a mixed abs/rel bound of 1e-5 is the honest
        // cross-implementation tolerance (entries away from d≈0 agree to
        // 1e-12).
        assert!(max_mixed < 1e-5, "{name}: L1 Pallas vs Rust differ by {max_mixed:.3e}");
    }
}

#[test]
fn manifest_signatures_are_consistent() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::read(dir).unwrap();
    assert!(!manifest.artifacts.is_empty());
    for a in &manifest.artifacts {
        assert!(dir.join(&a.file).exists(), "{} missing", a.file);
        match a.variant.as_str() {
            "sinkhorn_solve" => {
                assert_eq!(a.inputs.len(), 4, "{}", a.name);
                assert_eq!(a.inputs[0].dims, vec![a.v_r]);
                assert_eq!(a.inputs[2].dims, vec![a.vocab, a.n_docs]);
                assert_eq!(a.outputs[0].dims, vec![a.n_docs]);
            }
            "cdist_k" => {
                assert_eq!(a.outputs.len(), 3, "{}", a.name);
                for o in &a.outputs {
                    assert_eq!(o.dims, vec![a.vocab, a.v_r]);
                }
            }
            other => panic!("unknown variant {other}"),
        }
    }
}
