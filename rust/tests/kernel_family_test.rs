//! Kernel-family equivalence suite: the fused `SDDTMM→DSTMMT` iterate
//! against the `Unfused` ablation baseline over the full grid of batch
//! sizes B ∈ {1, 4}, shard counts S ∈ {1, 2} and dirty-workspace reuse.
//!
//! * `Fused { F64 }` must be **bitwise** identical to `Unfused` at one
//!   thread (same arithmetic in the same ascending-source-row order),
//!   and bitwise invariant across thread counts (column-owned writes,
//!   no atomic scatter).
//! * `Fused { Mixed }` (when the `mixed-precision` feature is in) must
//!   track the f64 solve within the documented 1e-5 relative gate,
//!   report the identical set of `+inf` empty-document lanes, and
//!   preserve the ranking of every pair the f64 solve separates by more
//!   than 1e-4 relative.

use sinkhorn_wmd::coordinator::{DocStore, ShardSet, ShardedDocStore};
use sinkhorn_wmd::corpus::SyntheticCorpus;
use sinkhorn_wmd::parallel::Pool;
use sinkhorn_wmd::sinkhorn::{
    IterateKernel, Precision, Prepared, SinkhornConfig, SolveOutput, SolveWorkspace, SparseSolver,
};
use sinkhorn_wmd::sparse::{Coo, Csr};
use std::sync::Arc;

const FUSED_F64: IterateKernel = IterateKernel::Fused { precision: Precision::F64 };

/// Empty documents at the first, a middle and the last column: their
/// `+inf` lanes must survive every kernel, batch size and sharding.
const KILL: [usize; 3] = [0, 23, 47];

fn corpus() -> SyntheticCorpus {
    SyntheticCorpus::builder()
        .vocab_size(600)
        .num_docs(48)
        .embedding_dim(16)
        .n_topics(4)
        .num_queries(4)
        .query_words(5, 12)
        .seed(77)
        .build()
}

/// `c` with the given target columns emptied (empty documents).
fn drop_columns(c: &Csr, kill: &[usize]) -> Csr {
    let mut coo = Coo::new(c.nrows(), c.ncols());
    for (i, j, v) in c.iter() {
        if !kill.contains(&j) {
            coo.push(i, j, v);
        }
    }
    Csr::from_coo(coo)
}

/// Fixed iterations, early exit off: "equal" means bitwise, not "both
/// converged to the same place".
fn config(kernel: IterateKernel) -> SinkhornConfig {
    SinkhornConfig { kernel, tolerance: 0.0, max_iter: 12, ..Default::default() }
}

fn prepare_all(corpus: &SyntheticCorpus, pool: &Pool) -> Vec<Prepared> {
    let solver = SparseSolver::new(SinkhornConfig::default());
    corpus.queries.iter().map(|q| solver.prepare(&corpus.embeddings, q, pool)).collect()
}

/// Reference: the `Unfused` baseline, monolithic, one thread.
fn unfused_reference(c: &Csr, preps: &[Prepared]) -> Vec<SolveOutput> {
    let pool = Pool::new(1);
    let solver = SparseSolver::new(config(IterateKernel::Unfused));
    let refs: Vec<&Prepared> = preps.iter().collect();
    solver.solve_batch(&refs, c, &pool)
}

#[test]
fn fused_f64_is_bitwise_identical_to_unfused_across_batch_and_reuse() {
    let corpus = corpus();
    let c = drop_columns(&corpus.c, &KILL);
    let pool = Pool::new(1);
    let preps = prepare_all(&corpus, &pool);
    let reference = unfused_reference(&c, &preps);
    let solver = SparseSolver::new(config(FUSED_F64));
    let mut ws = SolveWorkspace::new();
    for b in [1usize, 4] {
        let refs: Vec<&Prepared> = preps[..b].iter().collect();
        let fresh = solver.solve_batch(&refs, &c, &pool);
        // Dirty the workspace with a different batch shape, then solve
        // the same batch through the reused buffers.
        let _ = solver.solve_batch_in(&mut ws, &[&preps[2]], &c, &pool);
        let reused = solver.solve_batch_in(&mut ws, &refs, &c, &pool);
        for q in 0..b {
            assert_eq!(fresh[q].wmd, reference[q].wmd, "b={b} q={q}: fused != unfused");
            assert_eq!(reused[q].wmd, reference[q].wmd, "b={b} q={q}: dirty reuse diverged");
            assert_eq!(fresh[q].iterations, reference[q].iterations, "b={b} q={q}");
        }
    }
}

#[test]
fn fused_f64_is_bitwise_thread_count_invariant() {
    let corpus = corpus();
    let c = drop_columns(&corpus.c, &KILL);
    let pool1 = Pool::new(1);
    let preps = prepare_all(&corpus, &pool1);
    let refs: Vec<&Prepared> = preps.iter().collect();
    let solver = SparseSolver::new(config(FUSED_F64));
    let base = solver.solve_batch(&refs, &c, &pool1);
    for p in [2usize, 5] {
        let pool = Pool::new(p);
        let out = solver.solve_batch(&refs, &c, &pool);
        for q in 0..refs.len() {
            assert_eq!(out[q].wmd, base[q].wmd, "p={p} q={q}: column-owned writes must commute");
        }
    }
}

#[test]
fn sharded_fused_matches_monolithic_unfused_bitwise() {
    let corpus = corpus();
    let c = drop_columns(&corpus.c, &KILL);
    let pool = Pool::new(1);
    let preps = prepare_all(&corpus, &pool);
    let reference = unfused_reference(&c, &preps);
    let store = DocStore::new(corpus.embeddings.clone(), c).into_arc();
    let arcs: Vec<Arc<Prepared>> = preps.into_iter().map(Arc::new).collect();
    for s in [1usize, 2] {
        let set = ShardSet::start(
            ShardedDocStore::split(Arc::clone(&store), s),
            config(FUSED_F64),
            1,
        );
        for b in [1usize, 4] {
            let out = set.solve_batch(&arcs[..b]);
            assert_eq!(out.outputs.len(), b);
            for q in 0..b {
                assert_eq!(
                    out.outputs[q].wmd, reference[q].wmd,
                    "S={s} b={b} q={q}: sharded fused diverged from unfused reference"
                );
            }
        }
    }
}

#[test]
fn steady_state_reuse_stops_growing_the_workspace() {
    let corpus = corpus();
    let c = drop_columns(&corpus.c, &KILL);
    let pool = Pool::new(2);
    let preps = prepare_all(&corpus, &pool);
    let refs: Vec<&Prepared> = preps.iter().collect();
    let mut kernels = vec![FUSED_F64];
    #[cfg(feature = "mixed-precision")]
    kernels.push(IterateKernel::Fused { precision: Precision::Mixed });
    for kernel in kernels {
        let solver = SparseSolver::new(config(kernel));
        let mut ws = SolveWorkspace::new();
        // Warm on the largest shape, then repeat it: every checkout after
        // the first must find all buffers already big enough.
        let _ = solver.solve_batch_in(&mut ws, &refs, &c, &pool);
        let grows_after_warm = ws.stats().grows;
        for _ in 0..3 {
            let _ = solver.solve_batch_in(&mut ws, &refs, &c, &pool);
            let _ = solver.solve_batch_in(&mut ws, &[&preps[1]], &c, &pool);
        }
        let s = ws.stats();
        assert_eq!(
            s.grows, grows_after_warm,
            "{kernel:?}: steady-state solves must not grow the workspace"
        );
        assert_eq!(s.checkouts, 7, "{kernel:?}");
    }
}

#[cfg(feature = "mixed-precision")]
mod mixed {
    use super::*;

    const FUSED_MIXED: IterateKernel = IterateKernel::Fused { precision: Precision::Mixed };

    /// Relative error of every finite lane, and identity of the +inf set.
    fn assert_within_gate(mixed: &SolveOutput, f64_out: &SolveOutput, ctx: &str) {
        assert_eq!(mixed.wmd.len(), f64_out.wmd.len(), "{ctx}");
        for (j, (&m, &d)) in mixed.wmd.iter().zip(&f64_out.wmd).enumerate() {
            assert_eq!(
                m.is_infinite(),
                d.is_infinite(),
                "{ctx} j={j}: +inf empty-document lanes must match exactly"
            );
            if d.is_finite() {
                let rel = (m - d).abs() / d.abs().max(1e-300);
                assert!(rel <= 1e-5, "{ctx} j={j}: rel error {rel:.2e} above the 1e-5 gate");
            }
        }
    }

    /// Every pair the f64 solve separates by > 1e-4 relative must rank
    /// the same way under mixed (ties inside the gate may legally flip).
    fn assert_ordering_preserved(mixed: &SolveOutput, f64_out: &SolveOutput, ctx: &str) {
        let n = f64_out.wmd.len();
        let rank_of = |out: &SolveOutput| {
            let order = out.top_k(n);
            let mut rank = vec![0usize; n];
            for (r, &(j, _)) in order.iter().enumerate() {
                rank[j] = r;
            }
            rank
        };
        let (rm, rd) = (rank_of(mixed), rank_of(f64_out));
        for a in 0..n {
            for b in 0..n {
                let (wa, wb) = (f64_out.wmd[a], f64_out.wmd[b]);
                if !wa.is_finite() || !wb.is_finite() {
                    continue;
                }
                let gap = (wa - wb).abs() / wa.abs().max(wb.abs()).max(1e-300);
                if wa < wb && gap > 1e-4 {
                    assert!(
                        rm[a] < rm[b],
                        "{ctx}: mixed flipped docs {a} (wmd {wa}) and {b} (wmd {wb})"
                    );
                    assert!(rd[a] < rd[b], "{ctx}: top_k disagrees with wmd order");
                }
            }
        }
    }

    #[test]
    fn mixed_tracks_f64_across_batch_shards_and_reuse() {
        let corpus = corpus();
        let c = drop_columns(&corpus.c, &KILL);
        let pool = Pool::new(2);
        let preps = prepare_all(&corpus, &pool);
        let f64_solver = SparseSolver::new(config(FUSED_F64));
        let mixed_solver = SparseSolver::new(config(FUSED_MIXED));
        let refs: Vec<&Prepared> = preps.iter().collect();
        let f64_out = f64_solver.solve_batch(&refs, &c, &pool);
        let mut ws = SolveWorkspace::new();
        for b in [1usize, 4] {
            let batch: Vec<&Prepared> = preps[..b].iter().collect();
            let fresh = mixed_solver.solve_batch(&batch, &c, &pool);
            let _ = mixed_solver.solve_batch_in(&mut ws, &[&preps[2]], &c, &pool);
            let reused = mixed_solver.solve_batch_in(&mut ws, &batch, &c, &pool);
            for q in 0..b {
                let ctx = format!("b={b} q={q}");
                assert_within_gate(&fresh[q], &f64_out[q], &ctx);
                assert_ordering_preserved(&fresh[q], &f64_out[q], &ctx);
                assert_eq!(
                    reused[q].wmd, fresh[q].wmd,
                    "{ctx}: dirty-workspace mixed solve must be bitwise reproducible"
                );
            }
        }
    }

    #[test]
    fn sharded_mixed_stays_within_gate() {
        let corpus = corpus();
        let c = drop_columns(&corpus.c, &KILL);
        let pool = Pool::new(1);
        let preps = prepare_all(&corpus, &pool);
        let f64_solver = SparseSolver::new(config(FUSED_F64));
        let refs: Vec<&Prepared> = preps.iter().collect();
        let f64_out = f64_solver.solve_batch(&refs, &c, &pool);
        let store = DocStore::new(corpus.embeddings.clone(), c).into_arc();
        let arcs: Vec<Arc<Prepared>> = preps.into_iter().map(Arc::new).collect();
        for s in [1usize, 2] {
            let set = ShardSet::start(
                ShardedDocStore::split(Arc::clone(&store), s),
                config(FUSED_MIXED),
                1,
            );
            for b in [1usize, 4] {
                let out = set.solve_batch(&arcs[..b]);
                for q in 0..b {
                    let ctx = format!("S={s} b={b} q={q}");
                    assert_within_gate(&out.outputs[q], &f64_out[q], &ctx);
                    assert_ordering_preserved(&out.outputs[q], &f64_out[q], &ctx);
                }
            }
        }
    }
}
