//! loom-style exhaustive interleaving models of the two condvar protocols
//! in this crate, driven by `testing::interleave` (the in-tree explorer;
//! real `loom` is unavailable offline):
//!
//! 1. [`PoolModel`] — the `Pool::run` submit → execute → join-barrier
//!    handoff (`parallel/pool.rs`): a caller thread publishes a region,
//!    participates as tid 0, then blocks on `done_cv` until every worker's
//!    decrement; workers park on `work_cv` between regions and exit on
//!    shutdown. The model proves, over EVERY schedule: no lost wakeup (a
//!    deadlock would be reported), every thread executes every region
//!    exactly once, and shutdown terminates all workers.
//! 2. [`BatcherModel`] — the `BatchQueue` close-while-consumer-waits path
//!    (`coordinator/batcher.rs`): a consumer parked inside the
//!    `wait_timeout` deadline window must be woken by `close()` and hand
//!    over the partial batch; a push racing with close either lands (and
//!    is delivered) or fails fast — the item is never silently dropped.
//!
//! Model granularity is one critical section per step (see the
//! `interleave` module docs for why that coarsening is sound for
//! mutex-protected state). The expected execution counts are pinned: they
//! were computed by exhaustive enumeration of these exact state machines,
//! and a count change means the model (or the explorer) changed semantics.

use sinkhorn_wmd::testing::interleave::{explore, Model};

// ---------------------------------------------------------------------------
// Pool::run / join-barrier model
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq)]
enum CallerPc {
    /// Lock, publish region (epoch += 1, pending = W), notify `work_cv`.
    Submit,
    /// Run the region body as tid 0 (outside the lock).
    ExecSelf,
    /// Lock, check `pending`; park on `done_cv` if workers are still
    /// running, otherwise retire the region and move on.
    Join,
    /// Lock, set `shutdown`, notify `work_cv` (the `Drop` impl).
    Shutdown,
    /// `JoinHandle::join` on every worker.
    JoinWorkers,
    Done,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum WorkerPc {
    /// One pass of the worker's locked acquire loop: exit on shutdown,
    /// take an unseen region, or park on `work_cv`.
    Acquire,
    /// Run the region body (outside the lock).
    Exec,
    /// Lock, `pending -= 1`, notify `done_cv` when it hits zero.
    Decr,
    Done,
}

/// Thread 0 is the caller; threads `1..=w` are workers.
struct PoolModel {
    w: usize,
    regions: usize,
    // The `JobSlot` state (everything below the waitsets is mutex-guarded
    // in the real code, hence one mutation batch per step).
    epoch: u64,
    has_region: bool,
    pending: usize,
    shutdown: bool,
    // Condvar waitsets: parked threads are *disabled* until a notify step
    // clears them (condvar wait releases the lock atomically, so
    // check-then-park is a single step — exactly the real code's shape).
    work_waiters: Vec<bool>,
    done_waiter: bool,
    caller_pc: CallerPc,
    submitted: usize,
    worker_pc: Vec<WorkerPc>,
    seen_epoch: Vec<u64>,
    executed: Vec<usize>,
}

impl PoolModel {
    fn new(w: usize, regions: usize) -> Self {
        Self {
            w,
            regions,
            epoch: 0,
            has_region: false,
            pending: 0,
            shutdown: false,
            work_waiters: vec![false; w],
            done_waiter: false,
            caller_pc: CallerPc::Submit,
            submitted: 0,
            worker_pc: vec![WorkerPc::Acquire; w],
            seen_epoch: vec![0; w],
            executed: vec![0; w + 1],
        }
    }

    fn notify_work_cv(&mut self) {
        self.work_waiters.iter_mut().for_each(|p| *p = false);
    }
}

impl Model for PoolModel {
    fn threads(&self) -> usize {
        self.w + 1
    }

    fn done(&self, t: usize) -> bool {
        if t == 0 {
            self.caller_pc == CallerPc::Done
        } else {
            self.worker_pc[t - 1] == WorkerPc::Done
        }
    }

    fn enabled(&self, t: usize) -> bool {
        if t == 0 {
            match self.caller_pc {
                CallerPc::Join => !self.done_waiter,
                CallerPc::JoinWorkers => {
                    self.worker_pc.iter().all(|&pc| pc == WorkerPc::Done)
                }
                _ => true,
            }
        } else {
            match self.worker_pc[t - 1] {
                WorkerPc::Acquire => !self.work_waiters[t - 1],
                _ => true,
            }
        }
    }

    fn step(&mut self, t: usize) {
        if t == 0 {
            match self.caller_pc {
                CallerPc::Submit => {
                    self.epoch += 1;
                    self.has_region = true;
                    self.pending = self.w;
                    self.notify_work_cv();
                    self.caller_pc = CallerPc::ExecSelf;
                }
                CallerPc::ExecSelf => {
                    self.executed[0] += 1;
                    self.caller_pc = CallerPc::Join;
                }
                CallerPc::Join => {
                    if self.pending > 0 {
                        self.done_waiter = true;
                    } else {
                        self.has_region = false;
                        self.submitted += 1;
                        self.caller_pc = if self.submitted == self.regions {
                            CallerPc::Shutdown
                        } else {
                            CallerPc::Submit
                        };
                    }
                }
                CallerPc::Shutdown => {
                    self.shutdown = true;
                    self.notify_work_cv();
                    self.caller_pc = CallerPc::JoinWorkers;
                }
                CallerPc::JoinWorkers => self.caller_pc = CallerPc::Done,
                CallerPc::Done => unreachable!(),
            }
        } else {
            let i = t - 1;
            match self.worker_pc[i] {
                WorkerPc::Acquire => {
                    if self.shutdown {
                        self.worker_pc[i] = WorkerPc::Done;
                    } else if self.epoch != self.seen_epoch[i] && self.has_region {
                        self.seen_epoch[i] = self.epoch;
                        self.worker_pc[i] = WorkerPc::Exec;
                    } else {
                        self.work_waiters[i] = true;
                    }
                }
                WorkerPc::Exec => {
                    self.executed[t] += 1;
                    self.worker_pc[i] = WorkerPc::Decr;
                }
                WorkerPc::Decr => {
                    self.pending -= 1;
                    if self.pending == 0 && self.done_waiter {
                        self.done_waiter = false;
                    }
                    self.worker_pc[i] = WorkerPc::Acquire;
                }
                WorkerPc::Done => unreachable!(),
            }
        }
    }

    fn check(&self) {
        assert!(self.pending <= self.w, "pending underflow");
        for (t, &e) in self.executed.iter().enumerate() {
            assert!(e <= self.regions, "thread {t} over-executed: {e}");
        }
    }

    fn check_final(&self) {
        assert_eq!(self.pending, 0);
        assert_eq!(self.submitted, self.regions);
        for (t, &e) in self.executed.iter().enumerate() {
            assert_eq!(e, self.regions, "thread {t} executed {e} of {} regions", self.regions);
        }
    }
}

#[test]
fn pool_one_worker_two_regions_all_schedules() {
    let stats = explore(|| PoolModel::new(1, 2), 50_000);
    // Exact exhaustive counts for this state machine; a change means the
    // protocol model changed, not just noise.
    assert_eq!(stats.executions, 1_922);
    assert_eq!(stats.max_depth, 20);
}

#[test]
fn pool_two_workers_one_region_all_schedules() {
    let stats = explore(|| PoolModel::new(2, 1), 1_000_000);
    assert_eq!(stats.executions, 95_900);
    assert_eq!(stats.max_depth, 18);
}

#[test]
fn pool_one_worker_three_regions_all_schedules() {
    let stats = explore(|| PoolModel::new(1, 3), 1_000_000);
    assert_eq!(stats.executions, 59_582);
    assert_eq!(stats.max_depth, 28);
}

// ---------------------------------------------------------------------------
// BatchQueue close-while-waiting model
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq)]
enum Park {
    /// Inside `cv.wait` (queue was empty).
    Untimed,
    /// Inside `cv.wait_timeout` (holding a batch below the flush bar).
    Timed,
}

/// Thread 0 = consumer (`next_batch` loop until `None`), 1 = producer (one
/// `push`), 2 = closer (`close`), 3 = the clock (fires the `max_wait`
/// deadline). `max_batch` is modeled as unreachable (100), so the only
/// flush triggers are the deadline and close — the exact path the real
/// `close_while_consumer_waits_flushes_immediately` test exercises, but
/// here over every schedule, including push-after-close.
struct BatcherModel {
    queue: usize,
    closed: bool,
    deadline: bool,
    pushed: usize,
    delivered: usize,
    got_none: bool,
    park: Option<Park>,
    consumer_done: bool,
    producer_done: bool,
    closer_done: bool,
    clock_done: bool,
}

impl BatcherModel {
    fn new() -> Self {
        Self {
            queue: 0,
            closed: false,
            deadline: false,
            pushed: 0,
            delivered: 0,
            got_none: false,
            park: None,
            consumer_done: false,
            producer_done: false,
            closer_done: false,
            clock_done: false,
        }
    }
}

impl Model for BatcherModel {
    fn threads(&self) -> usize {
        4
    }

    fn done(&self, t: usize) -> bool {
        match t {
            0 => self.consumer_done,
            1 => self.producer_done,
            2 => self.closer_done,
            _ => self.clock_done,
        }
    }

    fn enabled(&self, t: usize) -> bool {
        if t == 0 {
            match self.park {
                Some(Park::Untimed) => false,
                // A timed wait self-wakes once the deadline lapses.
                Some(Park::Timed) => self.deadline,
                None => true,
            }
        } else {
            true
        }
    }

    fn step(&mut self, t: usize) {
        match t {
            0 => {
                // One locked pass of next_batch's loop (a timeout wake
                // re-acquires the lock and re-checks in the same pass).
                if self.park == Some(Park::Timed) && self.deadline {
                    self.park = None;
                }
                if self.queue > 0 {
                    if self.deadline || self.closed {
                        self.delivered += self.queue;
                        self.queue = 0;
                    } else {
                        self.park = Some(Park::Timed);
                    }
                } else if self.closed {
                    self.got_none = true;
                    self.consumer_done = true;
                } else {
                    self.park = Some(Park::Untimed);
                }
            }
            1 => {
                // push(): fails fast when closed, else enqueue + notify_all.
                if !self.closed {
                    self.queue += 1;
                    self.pushed += 1;
                    self.park = None;
                }
                self.producer_done = true;
            }
            2 => {
                // close(): flag + notify_all.
                self.closed = true;
                self.park = None;
                self.closer_done = true;
            }
            _ => {
                // The max_wait deadline lapses; a timed waiter wakes.
                self.deadline = true;
                if self.park == Some(Park::Timed) {
                    self.park = None;
                }
                self.clock_done = true;
            }
        }
    }

    fn check(&self) {
        assert!(self.queue <= 1);
        assert!(self.delivered <= self.pushed, "delivered an item never pushed");
    }

    fn check_final(&self) {
        assert!(self.got_none, "consumer must terminate via None after close");
        assert_eq!(
            self.delivered, self.pushed,
            "a successfully-pushed item was dropped (or duplicated) across close"
        );
        assert_eq!(self.queue, 0, "queue must be drained at shutdown");
    }
}

#[test]
fn batcher_close_while_waiting_all_schedules() {
    let stats = explore(BatcherModel::new, 10_000);
    // Exhaustive over every producer/closer/deadline interleaving,
    // including push-after-close (delivered == 0) and close-while-parked.
    assert_eq!(stats.executions, 51);
    assert_eq!(stats.max_depth, 8);
}
