//! Integration tests for the `dist` factor-precompute layer: kernel
//! agreement (§6), prepared-factor properties, and the row-restriction
//! contract that `prune/` builds on.

use sinkhorn_wmd::corpus::SyntheticCorpus;
use sinkhorn_wmd::dist::{cdist_gemm, cdist_naive, precompute_factors};
use sinkhorn_wmd::parallel::Pool;
use sinkhorn_wmd::sinkhorn::{Prepared, SinkhornConfig, SparseSolver};
use sinkhorn_wmd::sparse::Dense;

fn corpus() -> SyntheticCorpus {
    SyntheticCorpus::builder()
        .vocab_size(1_500)
        .num_docs(80)
        .embedding_dim(48)
        .n_topics(5)
        .num_queries(3)
        .query_words(7, 21)
        .seed(404)
        .build()
}

/// Gather a query's word embeddings into a `v_r × w` panel.
fn query_panel(corpus: &SyntheticCorpus, q: usize) -> Dense {
    let query = corpus.query(q);
    let w = corpus.embeddings.ncols();
    let mut panel = Dense::zeros(query.nnz(), w);
    for (k, &i) in query.idx.iter().enumerate() {
        panel.row_mut(k).copy_from_slice(corpus.embeddings.row(i as usize));
    }
    panel
}

#[test]
fn cdist_gemm_agrees_with_naive_within_1e9() {
    let corpus = corpus();
    for q in 0..3 {
        let panel = query_panel(&corpus, q);
        let v = corpus.vocab_size();
        let v_r = panel.nrows();
        for p in [1usize, 2, 6] {
            let pool = Pool::new(p);
            let mut naive = Dense::zeros(v, v_r);
            let mut gemm = Dense::zeros(v, v_r);
            cdist_naive(&panel, &corpus.embeddings, &mut naive, &pool);
            cdist_gemm(&panel, &corpus.embeddings, &mut gemm, &pool);
            for (a, b) in gemm.as_slice().iter().zip(naive.as_slice()) {
                assert!(
                    (a - b).abs() <= 1e-9 * (1.0 + b.abs()),
                    "q={q} p={p}: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn precompute_factors_shape_and_positivity() {
    let corpus = corpus();
    let pool = Pool::new(4);
    for q in 0..3 {
        let query = corpus.query(q);
        let f = precompute_factors(&corpus.embeddings, &query.indices(), &query.val, 10.0, &pool);
        let (v, v_r) = (corpus.vocab_size(), query.nnz());
        assert_eq!(f.vocab_size(), v);
        assert_eq!(f.v_r(), v_r);
        for (name, m) in [("kt", &f.kt), ("kor_t", &f.kor_t), ("km_t", &f.km_t)] {
            assert_eq!((m.nrows(), m.ncols()), (v, v_r), "{name} shape");
            assert!(m.as_slice().iter().all(|x| x.is_finite()), "{name} finite");
        }
        // K ∈ (0, 1]; K/r > 0; K⊙M ≥ 0 with zeros exactly at d = 0.
        assert!(f.kt.as_slice().iter().all(|&x| x > 0.0 && x <= 1.0));
        assert!(f.kor_t.as_slice().iter().all(|&x| x > 0.0));
        assert!(f.km_t.as_slice().iter().all(|&x| x >= 0.0));
        assert_eq!(f.r, query.val);
        // The factor triple is internally consistent: kor_t = kt / r.
        for i in (0..v).step_by(97) {
            for k in 0..v_r {
                let expect = f.kt.get(i, k) / f.r[k];
                let got = f.kor_t.get(i, k);
                assert!((got - expect).abs() <= 1e-12 * (1.0 + expect.abs()));
            }
        }
    }
}

#[test]
fn restricted_factors_solve_matches_full_solve() {
    // The sparse kernels only read factor rows where `c` has non-zeros,
    // so restricting both `c` and the factors to any row superset of the
    // support must reproduce the full WMD exactly.
    let corpus = corpus();
    let pool = Pool::new(1); // serial → bitwise-comparable solves
    let config = SinkhornConfig { tolerance: 0.0, max_iter: 12, ..Default::default() };
    let solver = SparseSolver::new(config);
    let query = corpus.query(1);
    let prep = solver.prepare(&corpus.embeddings, query, &pool);
    let full = solver.solve(&prep, &corpus.c, &pool);

    // Support = every vocabulary row that any document uses.
    let row_ptr = corpus.c.row_ptr();
    let support: Vec<usize> =
        (0..corpus.vocab_size()).filter(|&i| row_ptr[i + 1] > row_ptr[i]).collect();
    assert!(support.len() < corpus.vocab_size(), "corpus should have unused words");
    let sub_c = corpus.c.select_rows(&support);
    let sub_prep = Prepared { factors: prep.factors.restrict_rows(&support) };
    assert_eq!(sub_prep.factors.vocab_size(), support.len());
    let restricted = solver.solve(&sub_prep, &sub_c, &pool);

    assert_eq!(full.wmd.len(), restricted.wmd.len());
    for (a, b) in restricted.wmd.iter().zip(&full.wmd) {
        assert_eq!(a, b, "row restriction must not change the WMD");
    }
}

#[test]
fn prepare_then_solve_equals_one_shot() {
    let corpus = corpus();
    let pool = Pool::new(3);
    let solver = SparseSolver::new(SinkhornConfig {
        tolerance: 0.0,
        max_iter: 10,
        ..Default::default()
    });
    let query = corpus.query(2);
    let prep = solver.prepare(&corpus.embeddings, query, &pool);
    let a = solver.solve(&prep, &corpus.c, &pool);
    let b = solver.wmd_one_to_many(&corpus.embeddings, query, &corpus.c, &pool);
    for (x, y) in a.wmd.iter().zip(&b.wmd) {
        assert!((x - y).abs() <= 1e-9 * (1.0 + y.abs()), "{x} vs {y}");
    }
}
