//! Pinned parser-hardening regressions: one hand-reduced malformed input
//! per failure class, per parser. Each case documents a way a parser
//! could plausibly panic (or abort) on untrusted bytes and asserts the
//! structured `Err` instead. When the fuzzer (`tests/fuzz_smoke.rs`)
//! finds a new crash, its report carries a per-case seed — append it here
//! as `assert!(replay_case(target, seed).is_none())` so the fix stays
//! fixed with the exact mutated input, forever reconstructible.

use sinkhorn_wmd::config::RunConfig;
use sinkhorn_wmd::corpus::io::read_corpus_any;
use sinkhorn_wmd::corpus::{read_vec, DocFormat, DocReader};
use sinkhorn_wmd::testing::fuzz::{replay_case, TARGETS};
use std::io::ErrorKind;

// ---------------------------------------------------------------- snapshots

#[test]
fn snapshot_bad_magic_is_invalid_data() {
    let err = read_corpus_any(&mut &b"XMDC\x01\x00\x00\x00"[..]).unwrap_err();
    assert_eq!(err.kind(), ErrorKind::InvalidData);
}

#[test]
fn snapshot_unknown_version_is_invalid_data() {
    let err = read_corpus_any(&mut &b"WMDC\x09\x00\x00\x00"[..]).unwrap_err();
    assert_eq!(err.kind(), ErrorKind::InvalidData);
}

#[test]
fn snapshot_truncated_mid_header_is_eof_not_panic() {
    for cut in [&b""[..], &b"WM"[..], &b"WMDC"[..], &b"WMDC\x02\x00"[..]] {
        assert!(read_corpus_any(&mut &cut[..]).is_err(), "{cut:?} must not load");
    }
}

#[test]
fn snapshot_lying_length_prefix_is_eof_not_oom() {
    // A valid v2 header followed by a section length claiming ~2^64
    // elements and no payload: the reader must hit UnexpectedEof under its
    // preallocation cap, not attempt a multi-EB Vec (the abort-class
    // failure the fuzzer's len-bomb mutation hunts for).
    let mut bytes = b"WMDC\x02\x00\x00\x00".to_vec();
    bytes.extend_from_slice(&u64::MAX.to_le_bytes());
    let err = read_corpus_any(&mut &bytes[..]).unwrap_err();
    assert_eq!(err.kind(), ErrorKind::UnexpectedEof);
}

// --------------------------------------------------------------------- .vec

#[test]
fn vec_bom_header_is_invalid_data() {
    // A UTF-8 BOM glued to the word count: "\u{FEFF}4" is not a usize.
    let err = read_vec("\u{FEFF}4 1\na 1.0\n".as_bytes(), None).unwrap_err();
    assert_eq!(err.kind(), ErrorKind::InvalidData);
}

#[test]
fn vec_crlf_line_endings_still_parse() {
    // Windows-edited .vec files: `lines()` strips the \r, so CRLF must be
    // transparent, not a bogus trailing-field error.
    let v = read_vec(&b"2 2\r\na 1.0 2.0\r\nb 3.0 4.0\r\n"[..], None).unwrap();
    assert_eq!(v.vocab.len(), 2);
    assert_eq!(v.embeddings.row(1), &[3.0, 4.0]);
}

#[test]
fn vec_negative_and_overflowing_header_counts_error() {
    for text in ["-1 2\na 1.0 2.0\n", "99999999999999999999999999 2\na 1.0 2.0\n"] {
        let err = read_vec(text.as_bytes(), None)
            .expect_err(&format!("{text:?} must not parse"));
        assert_eq!(err.kind(), ErrorKind::InvalidData);
    }
}

#[test]
fn vec_nan_and_inf_payloads_are_rejected() {
    // Rust's f64 parser happily accepts "NaN"/"inf" strings; the loader
    // must not let non-finite coordinates into the distance kernels.
    for text in ["1 2\na NaN 1.0\n", "1 2\na 1.0 inf\n", "1 2\na -inf 0.0\n"] {
        let err = read_vec(text.as_bytes(), None)
            .expect_err(&format!("{text:?} must not parse"));
        assert_eq!(err.kind(), ErrorKind::InvalidData);
    }
}

// -------------------------------------------------------------------- jsonl

#[test]
fn jsonl_deep_nesting_is_an_error_not_a_stack_overflow() {
    // The fuzzer-class finding that motivated the depth cap in util/json:
    // unbounded recursive descent on `[[[[…` was a stack-overflow ABORT
    // (not even catchable). Must now surface as an Err item.
    let bomb = format!("{}{}\n", "[".repeat(2_000), "]".repeat(2_000));
    let docs: Vec<_> = DocReader::new(bomb.as_bytes(), DocFormat::Jsonl).collect();
    assert_eq!(docs.len(), 1);
    let err = docs[0].as_ref().unwrap_err();
    assert_eq!(err.kind(), ErrorKind::InvalidData);
    assert!(err.to_string().contains("jsonl line 1"), "{err}");
}

#[test]
fn jsonl_malformed_records_error_with_line_numbers() {
    let stream = concat!(
        "{\"text\": \"fine\"}\n",
        "{\"text\": \"unterminated\n",   // unterminated string
        "{\"text\": 42}\n",              // wrong type for "text"
        "{\"body\": \"no text field\"}\n",
        "not json at all\n",
    );
    let docs: Vec<_> = DocReader::new(stream.as_bytes(), DocFormat::Jsonl).collect();
    assert_eq!(docs.len(), 5);
    assert_eq!(docs[0].as_ref().unwrap(), "fine");
    for (i, doc) in docs.iter().enumerate().skip(1) {
        let err = doc.as_ref().expect_err("malformed record must be Err");
        assert_eq!(err.kind(), ErrorKind::InvalidData);
        assert!(
            err.to_string().contains(&format!("line {}", i + 1)),
            "record {i}: {err}"
        );
    }
}

// ------------------------------------------------------------------- config

#[test]
fn config_overflowing_numbers_are_errors_not_panics() {
    for text in [
        "threads = 99999999999999999999999999\n",
        "threads = 1e99\n",
        "[sinkhorn]\nmax_iter = -3\n",
    ] {
        assert!(RunConfig::from_str(text).is_err(), "{text:?} must not parse");
    }
}

#[test]
fn config_structural_garbage_is_an_error() {
    for text in ["= 5\n", "[sinkhorn\nlambda = 1\n", "[nosuch]\nx = 1\n", "keyonly\n"] {
        assert!(RunConfig::from_str(text).is_err(), "{text:?} must not parse");
    }
}

// -------------------------------------------------------- fuzz-seed pinning

/// Formerly-crashing (or representative) fuzz seeds, replayed
/// byte-identically through the deterministic mutation engine. New fuzzer
/// finds get appended to the relevant target's list with a comment naming
/// the failure; an empty extra list means no crash has survived review.
#[test]
fn pinned_fuzz_seeds_stay_fixed() {
    let pinned: &[(&'static str, &[u64])] = &[
        // The JSON depth cap (see the jsonl stack-overflow test above) was
        // driven by the `[[[[[[[[` hostile token; these seeds exercise the
        // first cases of each target's lineage as canaries.
        ("snapshot-v1", &[1, 2, 3]),
        ("snapshot-v2", &[1, 2, 3]),
        ("vec", &[1, 2, 3]),
        ("jsonl", &[1, 2, 3]),
        ("config", &[1, 2, 3]),
    ];
    // Every target must keep a pinned list — a new parser target without
    // regression coverage fails here, not in review.
    for target in TARGETS {
        assert!(
            pinned.iter().any(|(t, _)| t == target),
            "fuzz target '{target}' has no pinned regression seeds"
        );
    }
    for (target, seeds) in pinned {
        for &seed in *seeds {
            if let Some(crash) = replay_case(target, seed) {
                panic!("pinned case regressed: {crash}");
            }
        }
    }
}
