//! Coordinator invariants, property-style (DESIGN.md §7), via the in-repo
//! mini-proptest framework.

use sinkhorn_wmd::coordinator::{
    Backend, BatchQueue, BatcherConfig, DocStore, QueryRequest, Router, ServiceConfig, WmdService,
};
use sinkhorn_wmd::corpus::SyntheticCorpus;
use sinkhorn_wmd::parallel::{balanced_nnz_partition, even_rows_partition};
use sinkhorn_wmd::sinkhorn::{SinkhornConfig, SparseSolver};
use sinkhorn_wmd::parallel::Pool;
use sinkhorn_wmd::sparse::{Coo, Csr};
use sinkhorn_wmd::testing::property;
use std::time::Duration;

#[test]
fn prop_partition_covers_and_balances() {
    property("nnz partition covers/disjoint/balanced", 60, |g| {
        let nrows = g.usize_in(1..300);
        let mut row_ptr = vec![0usize];
        for _ in 0..nrows {
            let k = g.usize_in(0..9);
            row_ptr.push(row_ptr.last().unwrap() + k);
        }
        let p = g.usize_in(1..17);
        let parts = balanced_nnz_partition(&row_ptr, p);
        assert_eq!(parts.len(), p);
        assert_eq!(parts[0].nnz_start, 0);
        assert_eq!(parts[p - 1].nnz_end, *row_ptr.last().unwrap());
        let mut max = 0;
        let mut min = usize::MAX;
        for (i, w) in parts.iter().enumerate() {
            if i > 0 {
                assert_eq!(parts[i - 1].nnz_end, w.nnz_start);
            }
            max = max.max(w.len());
            min = min.min(w.len());
        }
        assert!(max - min <= 1, "imbalance {max}-{min}");
        // Row split covers the same range.
        let rows = even_rows_partition(&row_ptr, p);
        assert_eq!(rows[p - 1].nnz_end, *row_ptr.last().unwrap());
    });
}

#[test]
fn prop_router_bucket_monotone_and_padding_normalized() {
    property("router buckets + padding", 60, |g| {
        let nb = g.usize_in(1..5);
        let buckets: Vec<usize> = (0..nb).map(|_| g.usize_in(2..64)).collect();
        let router = Router::new(buckets.clone());
        // bucket_for is monotone: larger v_r never gets a smaller bucket.
        let a = g.usize_in(1..70);
        let b = g.usize_in(1..70);
        let (lo, hi) = (a.min(b), a.max(b));
        match (router.bucket_for(lo), router.bucket_for(hi)) {
            (Some(x), Some(y)) => assert!(x <= y),
            (None, Some(_)) => panic!("smaller v_r unroutable but larger routable"),
            _ => {}
        }
        // Padding keeps normalization, sortedness, and per-word mass.
        let dim = g.usize_in(100..400);
        let nnz = g.usize_in(1..20);
        let q = g.histogram(dim, nnz);
        if let Some(bucket) = router.bucket_for(nnz) {
            let padded = router.pad_query(&q, bucket);
            assert_eq!(padded.idx.len(), bucket);
            assert!((padded.sum() - 1.0).abs() < 1e-9);
            for w in padded.idx.windows(2) {
                assert!(w[0] <= w[1]);
            }
            // Per-word mass exactly preserved.
            for (&i, &v) in q.idx.iter().zip(&q.val) {
                let m: f64 = padded
                    .idx
                    .iter()
                    .zip(&padded.val)
                    .filter(|(&pi, _)| pi == i)
                    .map(|(_, &pv)| pv)
                    .sum();
                assert!((m - v).abs() < 1e-12);
            }
        }
    });
}

#[test]
fn prop_batcher_never_drops_never_reorders_within_batch() {
    property("batcher delivery", 20, |g| {
        let max_batch = g.usize_in(1..9);
        let n_items = g.usize_in(1..40);
        let q = BatchQueue::new(BatcherConfig {
            max_batch,
            max_wait: Duration::from_micros(200),
        });
        for i in 0..n_items {
            assert!(q.push(i));
        }
        q.close();
        let mut seen = Vec::new();
        while let Some(batch) = q.next_batch() {
            assert!(!batch.is_empty() && batch.len() <= max_batch);
            seen.extend(batch);
        }
        // FIFO overall (single consumer): order preserved exactly.
        assert_eq!(seen, (0..n_items).collect::<Vec<_>>());
    });
}

#[test]
fn prop_solver_permutation_equivariant() {
    // Permuting the target documents permutes the WMD vector — the
    // coordinator relies on this to shard/rebalance safely.
    let corpus = SyntheticCorpus::builder()
        .vocab_size(300)
        .num_docs(20)
        .embedding_dim(8)
        .num_queries(1)
        .query_words(6, 6)
        .seed(55)
        .build();
    let pool = Pool::new(4);
    let solver = SparseSolver::new(SinkhornConfig {
        tolerance: 0.0,
        max_iter: 10,
        ..Default::default()
    });
    let base = solver.wmd_one_to_many(&corpus.embeddings, corpus.query(0), &corpus.c, &pool);
    property("solver permutation equivariance", 10, |g| {
        // Random permutation of columns.
        let n = corpus.c.ncols();
        let mut perm: Vec<usize> = (0..n).collect();
        g.rng().shuffle(&mut perm);
        let mut coo = Coo::new(corpus.c.nrows(), n);
        for (i, j, v) in corpus.c.iter() {
            coo.push(i, perm[j], v);
        }
        let permuted = Csr::from_coo(coo);
        let out = solver.wmd_one_to_many(&corpus.embeddings, corpus.query(0), &permuted, &pool);
        for j in 0..n {
            let a = base.wmd[j];
            let b = out.wmd[perm[j]];
            assert!((a - b).abs() < 1e-10 * (1.0 + a.abs()), "{a} vs {b}");
        }
    });
}

#[test]
fn service_end_to_end_with_mixed_backends() {
    let corpus = SyntheticCorpus::builder()
        .vocab_size(600)
        .num_docs(50)
        .embedding_dim(16)
        .num_queries(6)
        .query_words(5, 15)
        .seed(77)
        .build();
    let store = DocStore::from_synthetic(&corpus).into_arc();
    let service = WmdService::start(
        store,
        ServiceConfig {
            threads: 3,
            sinkhorn: SinkhornConfig { max_iter: 10, tolerance: 0.0, ..Default::default() },
            ..Default::default()
        },
        None,
    );
    // Interleave sparse and dense requests.
    let mut receivers = Vec::new();
    for (i, q) in corpus.queries.iter().enumerate() {
        let prefer = if i % 2 == 0 { None } else { Some(Backend::DenseRust) };
        receivers.push((
            i,
            service.submit(QueryRequest { query: q.clone(), prefer, top_k: None, since: None }),
        ));
    }
    for (i, rx) in receivers {
        let resp = rx.recv().unwrap();
        assert!(resp.is_ok(), "query {i}: {:?}", resp.error);
        assert_eq!(resp.wmd.len(), 50);
        if i % 2 == 1 {
            assert_eq!(resp.backend, Backend::DenseRust);
        }
    }
    let snap = service.metrics().snapshot();
    assert_eq!(snap.queries, 6);
    assert_eq!(snap.backend_dense, 3);
    assert_eq!(snap.errors, 0);
    service.shutdown();
}

#[test]
fn service_survives_error_storm() {
    let corpus = SyntheticCorpus::builder()
        .vocab_size(200)
        .num_docs(10)
        .embedding_dim(8)
        .num_queries(1)
        .query_words(4, 4)
        .seed(88)
        .build();
    let store = DocStore::from_synthetic(&corpus).into_arc();
    let service = WmdService::start(
        store,
        ServiceConfig { threads: 2, ..Default::default() },
        None,
    );
    // Bad queries (wrong dim) interleaved with good ones.
    use sinkhorn_wmd::corpus::SparseVec;
    for round in 0..5 {
        let bad = SparseVec::from_counts(3, &[(0, 1)]);
        let r1 = service.submit_wait(QueryRequest::new(bad));
        assert!(!r1.is_ok(), "round {round}");
        let r2 = service.submit_wait(QueryRequest::new(corpus.query(0).clone()));
        assert!(r2.is_ok(), "round {round}: service broke after error");
    }
    let snap = service.metrics().snapshot();
    assert_eq!(snap.errors, 5);
    assert_eq!(snap.queries, 5);
    service.shutdown();
}
