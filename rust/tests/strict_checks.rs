//! Negative and positive tests for the `strict-checks` claim tracker
//! (`--features strict-checks`): deliberately-overlapping parallel claims
//! must panic with a diagnostic naming both threads and the overlap range;
//! legitimate partitioning (disjoint chunks, repartitioning across
//! regions, same-thread re-claims) must stay silent.
//!
//! This file is on `testing::lint::UNSAFE_AUDITED`: it calls the unsafe
//! `SharedSlice` API on purpose, including calls that *violate* its
//! contract — which is safe here precisely because strict-checks panics
//! before the second, conflicting write lands on an already-claimed range.
#![cfg(feature = "strict-checks")]

use sinkhorn_wmd::parallel::Pool;
use sinkhorn_wmd::util::SharedSlice;
use std::sync::Mutex;
use std::thread;

/// The claim tracker's region epoch is process-global, so tests that rely
/// on claims surviving (or being reset) must not interleave with other
/// tests' `Pool::run` calls. Serialize every test in this binary.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|p| p.into_inner())
}

fn panic_text(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else {
        String::from("<non-string panic payload>")
    }
}

/// The acceptance test: two named threads claim overlapping ranges of one
/// buffer in the same parallel region; the second claim must panic naming
/// both threads and the exact overlap `[32..40)`.
#[test]
fn overlapping_claims_panic_naming_both_threads() {
    let _guard = serial();
    let mut data = vec![0u64; 64];
    let view = SharedSlice::new(&mut data);
    let err = thread::scope(|s| {
        let t1 = thread::Builder::new()
            .name("even-partition".into())
            .spawn_scoped(s, move || {
                // SAFETY: in-bounds; this thread is the only claimant so far.
                let chunk = unsafe { view.slice_mut(0, 40) };
                chunk.fill(1);
            })
            .unwrap();
        t1.join().expect("first claimant must succeed");

        let t2 = thread::Builder::new()
            .name("odd-partition".into())
            .spawn_scoped(s, move || {
                // SAFETY: never reached as a write — [32..64) overlaps the
                // first thread's [0..40) claim, so strict-checks panics
                // inside slice_mut before the aliasing slice is produced.
                let _ = unsafe { view.slice_mut(32, 32) };
                unreachable!("strict-checks failed to fire on an overlapping claim");
            })
            .unwrap();
        t2.join().expect_err("overlapping claim must panic")
    });
    let msg = panic_text(err);
    assert!(msg.contains("overlap"), "diagnostic lacks 'overlap': {msg}");
    assert!(msg.contains("even-partition"), "diagnostic lacks first thread name: {msg}");
    assert!(msg.contains("odd-partition"), "diagnostic lacks second thread name: {msg}");
    assert!(msg.contains("[32..40)"), "diagnostic lacks the overlap range: {msg}");
    assert!(msg.contains("[32..64)"), "diagnostic lacks the offending claim: {msg}");
}

#[test]
fn out_of_bounds_claim_panics() {
    let _guard = serial();
    let mut data = vec![0u32; 8];
    let view = SharedSlice::new(&mut data);
    let err = thread::scope(|s| {
        let t = thread::Builder::new()
            .name("oob-prober".into())
            .spawn_scoped(s, move || {
                // SAFETY: never reached as a write — [6..10) exceeds len 8,
                // so either the debug bound assert or the strict-checks
                // tracker panics inside slice_mut.
                let _ = unsafe { view.slice_mut(6, 4) };
                unreachable!("out-of-bounds claim must not succeed");
            })
            .unwrap();
        t.join().expect_err("out-of-bounds claim must panic")
    });
    let msg = panic_text(err);
    // Debug builds trip the `debug_assert!` bound check first; release
    // builds reach the tracker's richer message. Either is a hard stop.
    assert!(
        msg.contains("out-of-bounds claim [6..10)") || msg.contains("assertion failed"),
        "unexpected panic message: {msg}"
    );
}

/// Positive leg: a correct disjoint partition through the real pool runs
/// clean under strict-checks and produces the right data.
#[test]
fn disjoint_partition_is_clean() {
    let _guard = serial();
    let n = 1_000;
    let mut data = vec![0u64; n];
    let view = SharedSlice::new(&mut data);
    let pool = Pool::new(4);
    pool.run(|tid, nthreads| {
        let chunk = n.div_ceil(nthreads);
        let start = tid * chunk;
        let end = (start + chunk).min(n);
        if start < end {
            // SAFETY: [start, end) chunks are disjoint across tids.
            let s = unsafe { view.slice_mut(start, end - start) };
            for (off, v) in s.iter_mut().enumerate() {
                *v = (start + off) as u64;
            }
        }
    });
    for (i, &v) in data.iter().enumerate() {
        assert_eq!(v, i as u64);
    }
}

/// Repartitioning the same buffer in a *later* region is legal: `Pool::run`
/// bumps the region epoch, so swapped ownership across regions must not be
/// reported as an overlap (claims only conflict within one region).
#[test]
fn repartitioning_across_regions_is_legal() {
    let _guard = serial();
    let n = 256;
    let mut data = vec![0u64; n];
    let view = SharedSlice::new(&mut data);
    let pool = Pool::new(2);
    pool.run(|tid, _| {
        let (start, len) = if tid == 0 { (0, n / 2) } else { (n / 2, n / 2) };
        // SAFETY: halves are disjoint across the two tids.
        unsafe { view.slice_mut(start, len) }.fill(tid as u64 + 1);
    });
    // Second region: ownership of the halves is swapped. Without the
    // epoch reset this would overlap the first region's claims.
    pool.run(|tid, _| {
        let (start, len) = if tid == 0 { (n / 2, n / 2) } else { (0, n / 2) };
        // SAFETY: halves are disjoint across the two tids.
        unsafe { view.slice_mut(start, len) }.fill(10 + tid as u64);
    });
    assert!(data[..n / 2].iter().all(|&v| v == 11));
    assert!(data[n / 2..].iter().all(|&v| v == 10));
}

/// One thread may re-claim ranges it already owns (per-nnz writes walk the
/// same interval repeatedly); overlap is only an error *across* threads.
#[test]
fn same_thread_overlapping_claims_are_fine() {
    let _guard = serial();
    let mut data = vec![0u8; 32];
    let view = SharedSlice::new(&mut data);
    for i in 0..32 {
        // SAFETY: single-threaded, in-bounds.
        unsafe { view.write(i, i as u8) };
    }
    // SAFETY: single-threaded; overlaps this thread's own prior claims,
    // which the tracker merges rather than reports.
    let s = unsafe { view.slice_mut(8, 16) };
    s.fill(0xAA);
    assert_eq!(data[7], 7);
    assert_eq!(data[8], 0xAA);
    assert_eq!(data[24], 24);
}
