//! Markdown-ish table printer for bench output (the format EXPERIMENTS.md
//! quotes directly).

/// A simple column-aligned table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(header: I) -> Self {
        Self { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) -> &mut Self {
        let row: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(row.len(), self.header.len(), "row arity mismatch");
        self.rows.push(row);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                line.push_str(&format!(" {c:<w$} |"));
            }
            line
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<width$}|", "", width = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        let _ = ncols;
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(["name", "value"]);
        t.row(["short", "1"]);
        t.row(["a-much-longer-name", "22222"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // All lines same width.
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(lines[0].contains("name"));
        assert!(lines[3].contains("22222"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new(["a", "b"]);
        t.row(["only-one"]);
    }
}
