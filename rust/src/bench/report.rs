//! Machine-readable bench output: every headline bench merges its
//! summary rows into one `BENCH_kernels.json` next to the human tables,
//! so successive runs (e.g. fused vs mixed, before vs after a kernel
//! change) can be diffed without scraping stdout.
//!
//! The file is a single JSON object keyed by bench name; each bench
//! overwrites only its own entry (read-modify-write), so running the
//! suite bench-by-bench accumulates one merged report.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Report destination: the `WMD_BENCH_JSON` env var when set, else
/// `BENCH_kernels.json` in the working directory.
pub fn bench_json_path() -> PathBuf {
    match std::env::var("WMD_BENCH_JSON") {
        Ok(p) if !p.is_empty() => PathBuf::from(p),
        _ => PathBuf::from("BENCH_kernels.json"),
    }
}

/// Retrieval-cascade report destination: the `WMD_BENCH_PRUNE_JSON` env
/// var when set, else `BENCH_prune.json` in the working directory. Kept
/// separate from the kernel report so CI can upload it as its own
/// artifact.
pub fn prune_json_path() -> PathBuf {
    match std::env::var("WMD_BENCH_PRUNE_JSON") {
        Ok(p) if !p.is_empty() => PathBuf::from(p),
        _ => PathBuf::from("BENCH_prune.json"),
    }
}

/// Convergence/compaction report destination: the
/// `WMD_BENCH_CONVERGENCE_JSON` env var when set, else
/// `BENCH_convergence.json` in the working directory. Its own file (like
/// the prune report) so CI uploads it as a separate artifact.
pub fn convergence_json_path() -> PathBuf {
    match std::env::var("WMD_BENCH_CONVERGENCE_JSON") {
        Ok(p) if !p.is_empty() => PathBuf::from(p),
        _ => PathBuf::from("BENCH_convergence.json"),
    }
}

/// Streaming-ingest report destination: the `WMD_BENCH_STREAM_JSON` env
/// var when set, else `BENCH_stream.json` in the working directory. Its
/// own file (like the prune report) so CI uploads it as a separate
/// artifact.
pub fn stream_json_path() -> PathBuf {
    match std::env::var("WMD_BENCH_STREAM_JSON") {
        Ok(p) if !p.is_empty() => PathBuf::from(p),
        _ => PathBuf::from("BENCH_stream.json"),
    }
}

/// Merge `entry` under the `bench` key into the report at
/// [`bench_json_path`] and say so on stdout. IO errors are reported, not
/// fatal — a read-only checkout must not kill a bench run.
pub fn write_bench_json(bench: &str, entry: Json) {
    let path = bench_json_path();
    match merge_bench_json(&path, bench, entry) {
        Ok(()) => println!("\n[{bench}] results merged into {}", path.display()),
        Err(e) => eprintln!("[{bench}] could not write {}: {e}", path.display()),
    }
}

/// The testable core: read the existing report (missing or unparseable
/// files start a fresh object), replace this bench's entry, write back.
pub fn merge_bench_json(path: &Path, bench: &str, entry: Json) -> std::io::Result<()> {
    let mut root: BTreeMap<String, Json> = std::fs::read_to_string(path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .and_then(|json| match json {
            Json::Obj(map) => Some(map),
            _ => None,
        })
        .unwrap_or_default();
    root.insert(bench.to_string(), entry);
    std::fs::write(path, Json::Obj(root).to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::obj;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("wmd-bench-report-{}-{name}", std::process::id()))
    }

    #[test]
    fn merge_preserves_other_benches_entries() {
        let path = tmp("merge");
        let _ = std::fs::remove_file(&path);
        merge_bench_json(&path, "ablation_fusion", obj([("rows", vec![1usize, 2].into())]))
            .unwrap();
        merge_bench_json(&path, "headline_speedup", obj([("speedup", 5.0.into())])).unwrap();
        // Overwrite the first entry: the second must survive.
        merge_bench_json(&path, "ablation_fusion", obj([("rows", vec![3usize].into())])).unwrap();
        let root = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let fusion = root.get("ablation_fusion").unwrap();
        assert_eq!(fusion.get("rows").unwrap().as_arr().unwrap().len(), 1);
        assert!(root.get("headline_speedup").unwrap().get("speedup").is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn unparseable_existing_file_starts_fresh() {
        let path = tmp("garbage");
        std::fs::write(&path, "not json {").unwrap();
        merge_bench_json(&path, "b", obj([("ok", true.into())])).unwrap();
        let root = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(root.get("b").unwrap().get("ok"), Some(&Json::Bool(true)));
        let _ = std::fs::remove_file(&path);
    }
}
