//! Bench-harness substrate (criterion is unavailable offline): warmup,
//! adaptive iteration counts, summary statistics, markdown table output,
//! and the host-spec capture that regenerates the paper's Table 3.

pub mod report;
pub mod runner;
pub mod sysinfo;
pub mod table;

pub use report::{
    bench_json_path, convergence_json_path, merge_bench_json, prune_json_path, stream_json_path,
    write_bench_json,
};
pub use runner::{bench_fn, BenchResult, BenchSettings};
pub use sysinfo::SysInfo;
pub use table::Table;
