//! Adaptive micro/macro benchmark runner.

use crate::util::stats::Summary;
use crate::util::fmt_duration;
use std::time::{Duration, Instant};

/// Runner settings. Defaults suit the end-to-end solver benches; kernels
/// with sub-millisecond runtimes get more samples automatically.
#[derive(Clone, Copy, Debug)]
pub struct BenchSettings {
    /// Warmup time before measurement.
    pub warmup: Duration,
    /// Target measurement time (the runner packs as many samples as fit).
    pub measure: Duration,
    /// Lower/upper bounds on the number of recorded samples.
    pub min_samples: usize,
    pub max_samples: usize,
}

impl Default for BenchSettings {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_secs(1),
            min_samples: 3,
            max_samples: 200,
        }
    }
}

impl BenchSettings {
    /// Faster settings for CI-style smoke benches.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            min_samples: 2,
            max_samples: 50,
        }
    }
}

/// A named measurement.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.summary.mean
    }

    pub fn line(&self) -> String {
        format!(
            "{:<44} mean {:>12}  p50 {:>12}  p95 {:>12}  (n={})",
            self.name,
            fmt_duration(self.summary.mean),
            fmt_duration(self.summary.p50),
            fmt_duration(self.summary.p95),
            self.summary.n
        )
    }
}

/// Measure `f`, which performs one complete unit of work per call.
/// The closure's return value is black-boxed to prevent dead-code
/// elimination.
pub fn bench_fn<T>(name: &str, settings: &BenchSettings, mut f: impl FnMut() -> T) -> BenchResult {
    // Warmup.
    let start = Instant::now();
    while start.elapsed() < settings.warmup {
        black_box(f());
    }
    // Measurement.
    let mut samples = Vec::new();
    let start = Instant::now();
    while (start.elapsed() < settings.measure && samples.len() < settings.max_samples)
        || samples.len() < settings.min_samples
    {
        let t = Instant::now();
        black_box(f());
        samples.push(t.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), summary: Summary::from_samples(&samples) }
}

/// Opaque value barrier (std::hint::black_box stabilized in 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_work() {
        let settings = BenchSettings {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(20),
            min_samples: 3,
            max_samples: 1000,
        };
        let r = bench_fn("spin", &settings, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.summary.n >= 3);
        assert!(r.summary.mean > 0.0);
        assert!(r.summary.min <= r.summary.p50 && r.summary.p50 <= r.summary.max);
    }

    #[test]
    fn respects_min_samples_for_slow_fn() {
        let settings = BenchSettings {
            warmup: Duration::ZERO,
            measure: Duration::from_millis(1),
            min_samples: 3,
            max_samples: 10,
        };
        let r = bench_fn("sleepy", &settings, || std::thread::sleep(Duration::from_millis(2)));
        assert!(r.summary.n >= 3);
    }
}
