//! Host capture — regenerates the paper's Table 3 (system specifications)
//! for the machine the benches actually ran on.

use crate::util::num_cpus;

/// Machine description parsed from `/proc` (Linux) with graceful fallback.
#[derive(Clone, Debug, Default)]
pub struct SysInfo {
    pub model_name: String,
    pub logical_cpus: usize,
    pub sockets: usize,
    pub cores_per_socket: usize,
    pub mem_total_kb: u64,
    pub cache_sizes: Vec<(String, String)>,
}

impl SysInfo {
    pub fn capture() -> Self {
        let mut info = SysInfo { logical_cpus: num_cpus(), ..Default::default() };
        if let Ok(cpuinfo) = std::fs::read_to_string("/proc/cpuinfo") {
            let mut physical_ids = std::collections::HashSet::new();
            let mut cores = None;
            for line in cpuinfo.lines() {
                let mut parts = line.splitn(2, ':');
                let key = parts.next().unwrap_or("").trim();
                let val = parts.next().unwrap_or("").trim();
                match key {
                    "model name" if info.model_name.is_empty() => info.model_name = val.to_string(),
                    "physical id" => {
                        physical_ids.insert(val.to_string());
                    }
                    "cpu cores" if cores.is_none() => cores = val.parse::<usize>().ok(),
                    _ => {}
                }
            }
            info.sockets = physical_ids.len().max(1);
            info.cores_per_socket = cores.unwrap_or(info.logical_cpus / info.sockets.max(1));
        }
        if let Ok(meminfo) = std::fs::read_to_string("/proc/meminfo") {
            for line in meminfo.lines() {
                if let Some(rest) = line.strip_prefix("MemTotal:") {
                    info.mem_total_kb = rest
                        .trim()
                        .trim_end_matches(" kB")
                        .trim()
                        .parse()
                        .unwrap_or(0);
                    break;
                }
            }
        }
        // Cache sizes from sysfs (index0.. on cpu0).
        for idx in 0..5 {
            let base = format!("/sys/devices/system/cpu/cpu0/cache/index{idx}");
            let level = std::fs::read_to_string(format!("{base}/level")).ok();
            let size = std::fs::read_to_string(format!("{base}/size")).ok();
            let ctype = std::fs::read_to_string(format!("{base}/type")).ok();
            if let (Some(level), Some(size)) = (level, size) {
                let suffix = match ctype.as_deref().map(str::trim) {
                    Some("Data") => "d",
                    Some("Instruction") => "i",
                    _ => "",
                };
                info.cache_sizes
                    .push((format!("L{}{suffix}", level.trim()), size.trim().to_string()));
            }
        }
        info
    }

    /// Render in the paper's Table-3 shape.
    pub fn table(&self) -> crate::bench::Table {
        let mut t = crate::bench::Table::new(["Platform", "this host"]);
        t.row(["Model", self.model_name.as_str()]);
        t.row(["Logical CPUs", &self.logical_cpus.to_string()]);
        t.row(["#Numa sockets", &self.sockets.to_string()]);
        t.row(["#Cores per socket", &self.cores_per_socket.to_string()]);
        t.row([
            "MemTotal",
            &format!("{:.1} GB", self.mem_total_kb as f64 / 1024.0 / 1024.0),
        ]);
        for (name, size) in &self.cache_sizes {
            t.row([name.as_str(), size.as_str()]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn captures_something() {
        let s = SysInfo::capture();
        assert!(s.logical_cpus >= 1);
        let rendered = s.table().render();
        assert!(rendered.contains("Logical CPUs"));
    }
}
