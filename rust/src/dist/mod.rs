//! The factor-precompute layer: everything that happens between "a query
//! arrives" and "the Sinkhorn iterate can run".
//!
//! The paper's per-query preparation (§4, Fig. 7) is
//!
//! ```text
//!   M  = cdist(vecs[sel], vecs)        (v_r × V Euclidean distances)
//!   K  = exp(−λ·M)
//!   K_over_r = K / r[:, None]
//!   K⊙M                                 (for the final WMD reduction)
//! ```
//!
//! and Table 1 shows it is the *only* dense-side stage the sparse solver
//! keeps, so it is built here as a first-class subsystem instead of a
//! throwaway local:
//!
//! * [`cdist_naive`] / [`cdist_gemm`] — the paper's §6 comparison: the
//!   textbook subtract-square distance vs the blocked
//!   `‖a‖² + ‖b‖² − 2a·b` GEMM formulation (`benches/fig7_cdist_gemm`).
//! * [`precompute_factors`] — the fused pass producing [`QueryFactors`]:
//!   one traversal of the embedding table computes distance, `K`,
//!   `K_over_r` and `K⊙M` per element, so `M` is never materialized.
//! * [`QueryFactors`] — the prepared, cacheable artifact. Stored
//!   **transposed** (`V × v_r`, row-major) so every sparse kernel reads
//!   factor rows with unit stride; [`QueryFactors::restrict_rows`] is the
//!   row-restriction `prune/` composes with to solve candidate
//!   sub-problems without re-running the O(v_r·V·w) precompute, and the
//!   coordinator's prepared-factor cache
//!   ([`crate::coordinator::PreparedCache`]) holds whole `QueryFactors`
//!   so repeated queries skip this layer entirely.

pub mod cdist;
pub mod factors;

pub use cdist::{cdist_gemm, cdist_naive};
pub use factors::{precompute_factors, precompute_factors_in, DistScratch, QueryFactors};
