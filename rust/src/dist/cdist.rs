//! Pairwise Euclidean distance, the paper's §6 dot-product vs GEMM
//! comparison (Fig. 7).
//!
//! Both kernels fill `out[i][k] = ‖query[k] − vecs[i]‖₂` with the output
//! **transposed** (`V × v_r`): the factor consumers read vocabulary rows,
//! so the transposed layout gives them unit-stride access and lets one
//! thread own a whole output row (no synchronization).
//!
//! * [`cdist_naive`] — the textbook 3-op inner loop
//!   `Σ_j (q[k][j] − y[i][j])²`, one query row at a time.
//! * [`cdist_gemm`] — the `‖q‖² + ‖y‖² − 2 q·y` decomposition: per
//!   output element one fused-multiply-add dot plus a rank-1 epilogue
//!   (the matmul-like restructuring the paper evaluates), with `‖q‖²`
//!   hoisted out of the vocabulary loop and `y[i]` resident across the
//!   whole query panel.
//!
//! Exactness note: every norm **and** every cross term goes through the
//! same unrolled [`dot`], so for identical vectors the decomposition
//! cancels bitwise (`q·q + y·y − 2·q·y = 0` exactly) and self-distances
//! are exactly zero — a different accumulation order for the cross term
//! would leave ~√ε·‖q‖ cancellation noise right where `K = exp(−λd)`
//! peaks.

use crate::parallel::Pool;
use crate::sparse::{dot, Dense};
use crate::util::SharedSlice;
use crate::Real;

fn check_shapes(query: &Dense, vecs: &Dense, out: &Dense) {
    assert_eq!(query.ncols(), vecs.ncols(), "embedding width mismatch");
    assert_eq!(out.nrows(), vecs.nrows(), "out rows must cover the vocabulary");
    assert_eq!(out.ncols(), query.nrows(), "out cols must cover the query words");
}

/// Textbook pairwise distance: `out[i][k] = sqrt(Σ_j (q[k][j] − y[i][j])²)`.
/// Parallel over vocabulary rows (each thread owns whole output rows).
pub fn cdist_naive(query: &Dense, vecs: &Dense, out: &mut Dense, pool: &Pool) {
    check_shapes(query, vecs, out);
    let v_r = query.nrows();
    let view = SharedSlice::new(out.as_mut_slice());
    pool.parallel_for(vecs.nrows(), |rows| {
        for i in rows {
            let y = vecs.row(i);
            // SAFETY: row i is owned by exactly one thread.
            let o = unsafe { view.slice_mut(i * v_r, v_r) };
            for (k, ok) in o.iter_mut().enumerate() {
                let q = query.row(k);
                let mut d2 = 0.0;
                for (a, b) in q.iter().zip(y) {
                    let diff = a - b;
                    d2 += diff * diff;
                }
                *ok = d2.sqrt();
            }
        }
    });
}

/// GEMM-formulated pairwise distance (paper §6):
/// `d² = ‖q‖² + ‖y‖² − 2 q·y`, clamped at 0 against cancellation. `‖q‖²`
/// is hoisted out of the vocabulary loop; per vocabulary row `y` stays
/// resident while the query panel streams against it, every product
/// through the shared unrolled [`dot`] (see the module-level exactness
/// note).
pub fn cdist_gemm(query: &Dense, vecs: &Dense, out: &mut Dense, pool: &Pool) {
    check_shapes(query, vecs, out);
    let v_r = query.nrows();
    // ‖q‖² per query word, computed once (the tall-skinny side is tiny).
    let qn: Vec<Real> = (0..v_r).map(|k| dot(query.row(k), query.row(k))).collect();
    let view = SharedSlice::new(out.as_mut_slice());
    pool.parallel_for(vecs.nrows(), |rows| {
        for i in rows {
            let y = vecs.row(i);
            let yn = dot(y, y);
            // SAFETY: row i is owned by exactly one thread.
            let o = unsafe { view.slice_mut(i * v_r, v_r) };
            for (k, ok) in o.iter_mut().enumerate() {
                *ok = gemm_distance(qn[k], yn, dot(query.row(k), y));
            }
        }
    });
}

/// The rank-1 epilogue: `sqrt(max(qn + yn − 2·cross, 0))`.
#[inline(always)]
fn gemm_distance(qn: Real, yn: Real, cross: Real) -> Real {
    (qn + yn - 2.0 * cross).max(0.0).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn random_dense(rng: &mut Pcg64, nrows: usize, ncols: usize) -> Dense {
        Dense::from_fn(nrows, ncols, |_, _| rng.next_f64() * 2.0 - 1.0)
    }

    #[test]
    fn gemm_matches_naive_across_shapes() {
        let mut rng = Pcg64::new(1234);
        // Shapes chosen to hit w not a multiple of the dot unroll, a
        // single-word query, and tiny embeddings.
        for &(v, v_r, w) in &[(50usize, 8usize, 16usize), (33, 7, 31), (64, 1, 300), (10, 3, 5)] {
            let query = random_dense(&mut rng, v_r, w);
            let vecs = random_dense(&mut rng, v, w);
            for p in [1usize, 4] {
                let pool = Pool::new(p);
                let mut a = Dense::zeros(v, v_r);
                let mut b = Dense::zeros(v, v_r);
                cdist_naive(&query, &vecs, &mut a, &pool);
                cdist_gemm(&query, &vecs, &mut b, &pool);
                for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
                    assert!(
                        (x - y).abs() <= 1e-12 * (1.0 + y.abs()),
                        "p={p} v={v} v_r={v_r} w={w}: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn self_distance_is_exactly_zero() {
        // The shared-`dot` accumulation makes the decomposition cancel
        // bitwise for identical vectors — checked at v_r both below and
        // above the dot unroll width.
        let mut rng = Pcg64::new(7);
        let vecs = random_dense(&mut rng, 20, 12);
        let sel = [4usize, 9, 17, 2, 11, 6];
        let mut query = Dense::zeros(sel.len(), 12);
        for (k, &i) in sel.iter().enumerate() {
            query.row_mut(k).copy_from_slice(vecs.row(i));
        }
        let pool = Pool::new(2);
        let mut out = Dense::zeros(20, sel.len());
        cdist_gemm(&query, &vecs, &mut out, &pool);
        for (k, &i) in sel.iter().enumerate() {
            assert_eq!(out.get(i, k), 0.0, "d(sel[{k}], sel[{k}]) must cancel exactly");
        }
    }

    #[test]
    fn distances_are_symmetric_in_roles() {
        // d(a, b) computed with a as query equals d computed with b as
        // query (transposed output).
        let mut rng = Pcg64::new(8);
        let a = random_dense(&mut rng, 6, 10);
        let b = random_dense(&mut rng, 9, 10);
        let pool = Pool::new(1);
        let mut ab = Dense::zeros(9, 6);
        let mut ba = Dense::zeros(6, 9);
        cdist_gemm(&a, &b, &mut ab, &pool);
        cdist_gemm(&b, &a, &mut ba, &pool);
        for i in 0..9 {
            for k in 0..6 {
                let x = ab.get(i, k);
                let y = ba.get(k, i);
                assert!((x - y).abs() < 1e-12, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn thread_count_is_bitwise_irrelevant() {
        // Each output row is computed by one thread with an identical
        // instruction sequence, so the partition cannot change the bits.
        let mut rng = Pcg64::new(9);
        let query = random_dense(&mut rng, 5, 64);
        let vecs = random_dense(&mut rng, 41, 64);
        let mut base = Dense::zeros(41, 5);
        cdist_gemm(&query, &vecs, &mut base, &Pool::new(1));
        for p in [2usize, 3, 8] {
            let mut out = Dense::zeros(41, 5);
            cdist_gemm(&query, &vecs, &mut out, &Pool::new(p));
            assert_eq!(out, base, "p={p}");
        }
    }
}
