//! The prepared per-query factors and their fused precompute.
//!
//! [`precompute_factors`] folds `M = cdist(vecs[sel], vecs)`, `K`,
//! `K_over_r` and `K⊙M` into **one** parallel traversal of the embedding
//! table: per `(vocab row, query word)` pair the distance is computed in
//! the §6 GEMM form and immediately expanded into the three factor
//! entries, so the `v_r × V` distance matrix is never materialized and
//! every factor element is written exactly once (Fig. 7's restructuring,
//! fused one stage further).

use crate::parallel::Pool;
use crate::sparse::{dot, Dense};
use crate::util::SharedSlice;
use crate::Real;

/// The prepared, cacheable per-query artifact: the three factor matrices
/// (stored transposed, `V × v_r` row-major, so sparse kernels read rows
/// with unit stride) plus the query histogram `r` over the selected words.
///
/// Invariants: `kt`, `kor_t`, `km_t` share the shape `vocab_size() × v_r()`;
/// `kt[i][k] = exp(−λ·d(sel[k], i)) ∈ (0, 1]`,
/// `kor_t[i][k] = kt[i][k] / r[k]`, `km_t[i][k] = kt[i][k] · d(sel[k], i)`.
#[derive(Clone, Debug, Default)]
pub struct QueryFactors {
    /// `Kᵀ` — `exp(−λ·M)ᵀ`.
    pub kt: Dense,
    /// `(K / r)ᵀ` — `K` with row `k` divided by `r[k]`.
    pub kor_t: Dense,
    /// `(K ⊙ M)ᵀ` — elementwise product, for the final WMD reduction.
    pub km_t: Dense,
    /// The query's histogram over its selected words (the paper's `r`).
    pub r: Vec<Real>,
}

impl QueryFactors {
    /// Number of selected query words (the paper's `v_r`).
    #[inline]
    pub fn v_r(&self) -> usize {
        self.r.len()
    }

    /// Vocabulary rows the factors cover.
    #[inline]
    pub fn vocab_size(&self) -> usize {
        self.kt.nrows()
    }

    /// Approximate heap footprint — what a bounded factor cache accounts.
    pub fn memory_bytes(&self) -> usize {
        (3 * self.vocab_size() * self.v_r() + self.v_r()) * std::mem::size_of::<Real>()
    }

    /// Restrict the factors to a subset of vocabulary rows: row `t` of the
    /// result is row `rows[t]` of `self`. `r` is untouched — the query
    /// side of the transport problem is unchanged.
    ///
    /// This is the composition point for `prune/`: the sparse kernels only
    /// read factor rows where the target matrix has non-zeros, so solving
    /// against `c.select_rows(rows)` with `restrict_rows(rows)` gives the
    /// same WMD as the full solve while the per-candidate row walk drops
    /// from O(V) to O(|rows|).
    pub fn restrict_rows(&self, rows: &[usize]) -> QueryFactors {
        let mut out = QueryFactors::default();
        self.restrict_rows_into(rows, &mut out);
        out
    }

    /// [`QueryFactors::restrict_rows`] into a caller-owned factor set —
    /// the pruned-retrieval hot loop restricts once per surviving
    /// candidate, so reusing one output's allocations across candidates
    /// keeps that loop off the allocator.
    pub fn restrict_rows_into(&self, rows: &[usize], out: &mut QueryFactors) {
        let v_r = self.v_r();
        let gather = |src: &Dense, dst: &mut Dense| {
            dst.reset(rows.len(), v_r, 0.0);
            for (t, &i) in rows.iter().enumerate() {
                dst.row_mut(t).copy_from_slice(src.row(i));
            }
        };
        gather(&self.kt, &mut out.kt);
        gather(&self.kor_t, &mut out.kor_t);
        gather(&self.km_t, &mut out.km_t);
        out.r.clear();
        out.r.extend_from_slice(&self.r);
    }
}

/// Reusable scratch for the dist-layer precompute: the query panel and
/// its derived per-word vectors, retained across prepares by a
/// [`crate::sinkhorn::SolveWorkspace`]. The three factor matrices are
/// *not* scratch — they are the prepared artifact itself, owned by the
/// returned [`QueryFactors`] (and typically committed to the coordinator's
/// prepared-factor cache), so they must outlive any single solve.
#[derive(Debug, Default)]
pub struct DistScratch {
    /// Selected vocabulary ids as `usize` (the solver-facing `sel` form).
    pub sel: Vec<usize>,
    /// `qvecs[k] = embeddings[sel[k]]` — the gathered query panel.
    qvecs: Dense,
    /// Squared norms of the panel rows.
    qn: Vec<Real>,
    /// `1 / r[k]` per selected word.
    inv_r: Vec<Real>,
}

impl DistScratch {
    /// Heap bytes held by the scratch's backing allocations.
    pub fn retained_bytes(&self) -> usize {
        self.sel.capacity() * std::mem::size_of::<usize>()
            + (self.qvecs.capacity() + self.qn.capacity() + self.inv_r.capacity())
                * std::mem::size_of::<Real>()
    }
}

/// Fused factor precompute: one parallel pass over the vocabulary builds
/// `Kᵀ`, `(K/r)ᵀ` and `(K⊙M)ᵀ` for the selected query words.
///
/// * `embeddings` — the `V × w` table.
/// * `sel` — vocabulary ids of the query's words (repeats allowed — the
///   router's duplicate-split padding produces them).
/// * `vals` — the query histogram over `sel` (`r`, positive).
/// * `lambda` — entropic regularization strength (> 0).
///
/// Each thread owns whole vocabulary rows and runs an identical
/// instruction sequence per row, so the result is bitwise independent of
/// the pool size.
pub fn precompute_factors(
    embeddings: &Dense,
    sel: &[usize],
    vals: &[Real],
    lambda: Real,
    pool: &Pool,
) -> QueryFactors {
    precompute_factors_in(embeddings, sel, vals, lambda, pool, &mut DistScratch::default())
}

/// [`precompute_factors`] with the intermediate panel buffers borrowed
/// from a retained [`DistScratch`] — the prepared-cache *miss* path stops
/// allocating anything but the committed factor matrices themselves.
pub fn precompute_factors_in(
    embeddings: &Dense,
    sel: &[usize],
    vals: &[Real],
    lambda: Real,
    pool: &Pool,
    scratch: &mut DistScratch,
) -> QueryFactors {
    let v = embeddings.nrows();
    let v_r = sel.len();
    assert_eq!(vals.len(), v_r, "sel/vals length mismatch");
    assert!(v_r > 0, "empty query selection");
    assert!(lambda > 0.0, "lambda must be positive");
    assert!(sel.iter().all(|&i| i < v), "selected word out of vocabulary");
    assert!(vals.iter().all(|&x| x > 0.0), "query masses must be positive");

    // Gather the query panel once: `qvecs[k] = embeddings[sel[k]]`.
    let w = embeddings.ncols();
    let qvecs = &mut scratch.qvecs;
    qvecs.reset(v_r, w, 0.0);
    for (k, &i) in sel.iter().enumerate() {
        qvecs.row_mut(k).copy_from_slice(embeddings.row(i));
    }
    let qvecs = &*qvecs;
    let qn = &mut scratch.qn;
    qn.clear();
    qn.extend((0..v_r).map(|k| dot(qvecs.row(k), qvecs.row(k))));
    let qn = &*qn;
    let inv_r = &mut scratch.inv_r;
    inv_r.clear();
    inv_r.extend(vals.iter().map(|&x| 1.0 / x));
    let inv_r = &*inv_r;

    let mut kt = Dense::zeros(v, v_r);
    let mut kor_t = Dense::zeros(v, v_r);
    let mut km_t = Dense::zeros(v, v_r);
    let kt_view = SharedSlice::new(kt.as_mut_slice());
    let kor_view = SharedSlice::new(kor_t.as_mut_slice());
    let km_view = SharedSlice::new(km_t.as_mut_slice());
    pool.parallel_for(v, |rows| {
        for i in rows {
            let y = embeddings.row(i);
            let yn = dot(y, y);
            // SAFETY: row i is owned by exactly one thread.
            let kt_row = unsafe { kt_view.slice_mut(i * v_r, v_r) };
            let kor_row = unsafe { kor_view.slice_mut(i * v_r, v_r) };
            let km_row = unsafe { km_view.slice_mut(i * v_r, v_r) };
            for k in 0..v_r {
                // §6 GEMM form, clamped against cancellation.
                let d2 = (qn[k] + yn - 2.0 * dot(qvecs.row(k), y)).max(0.0);
                let d = d2.sqrt();
                let kv = (-lambda * d).exp();
                kt_row[k] = kv;
                kor_row[k] = kv * inv_r[k];
                km_row[k] = kv * d;
            }
        }
    });

    QueryFactors { kt, kor_t, km_t, r: vals.to_vec() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::SyntheticCorpus;

    fn toy() -> SyntheticCorpus {
        SyntheticCorpus::builder()
            .vocab_size(300)
            .num_docs(20)
            .embedding_dim(24)
            .num_queries(1)
            .query_words(9, 9)
            .seed(61)
            .build()
    }

    #[test]
    fn shapes_and_ranges() {
        let corpus = toy();
        let pool = Pool::new(3);
        let q = corpus.query(0);
        let f = precompute_factors(&corpus.embeddings, &q.indices(), &q.val, 10.0, &pool);
        assert_eq!(f.v_r(), 9);
        assert_eq!(f.vocab_size(), 300);
        for m in [&f.kt, &f.kor_t, &f.km_t] {
            assert_eq!((m.nrows(), m.ncols()), (300, 9));
        }
        assert!(f.kt.as_slice().iter().all(|&x| x > 0.0 && x <= 1.0));
        assert!(f.kor_t.as_slice().iter().all(|&x| x > 0.0));
        assert!(f.km_t.as_slice().iter().all(|&x| x >= 0.0));
        assert!(f.memory_bytes() >= 3 * 300 * 9 * 8);
    }

    #[test]
    fn factor_identities_hold() {
        let corpus = toy();
        let pool = Pool::new(2);
        let q = corpus.query(0);
        let lambda = 7.5;
        let f = precompute_factors(&corpus.embeddings, &q.indices(), &q.val, lambda, &pool);
        // Cross-check against the unfused path: an explicit cdist, then
        // the scalar definitions.
        let mut qvecs = Dense::zeros(q.nnz(), corpus.embeddings.ncols());
        for (k, &i) in q.idx.iter().enumerate() {
            qvecs.row_mut(k).copy_from_slice(corpus.embeddings.row(i as usize));
        }
        let mut m_t = Dense::zeros(300, q.nnz());
        crate::dist::cdist_gemm(&qvecs, &corpus.embeddings, &mut m_t, &pool);
        // The panel micro-kernel and the fused path accumulate the cross
        // term in different orders; compare to fp tolerance, not bits.
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-12 * (1.0 + b.abs());
        for i in 0..300 {
            for k in 0..q.nnz() {
                let d = m_t.get(i, k);
                let kv = (-lambda * d).exp();
                assert!(close(f.kt.get(i, k), kv), "kt[{i}][{k}]");
                assert!(close(f.kor_t.get(i, k), kv / q.val[k]), "kor_t[{i}][{k}]");
                assert!(close(f.km_t.get(i, k), kv * d), "km_t[{i}][{k}]");
            }
        }
    }

    #[test]
    fn selected_words_have_unit_kernel() {
        // d(sel[k], sel[k]) is exactly 0 in the GEMM form (the clamp eats
        // the cancellation), so K at the word's own row is exactly 1.
        let corpus = toy();
        let pool = Pool::new(2);
        let q = corpus.query(0);
        let f = precompute_factors(&corpus.embeddings, &q.indices(), &q.val, 10.0, &pool);
        for (k, &i) in q.idx.iter().enumerate() {
            assert_eq!(f.kt.get(i as usize, k), 1.0);
            assert_eq!(f.km_t.get(i as usize, k), 0.0);
        }
    }

    #[test]
    fn restrict_rows_gathers() {
        let corpus = toy();
        let pool = Pool::new(2);
        let q = corpus.query(0);
        let f = precompute_factors(&corpus.embeddings, &q.indices(), &q.val, 10.0, &pool);
        let rows = vec![0usize, 17, 123, 299];
        let sub = f.restrict_rows(&rows);
        assert_eq!(sub.vocab_size(), 4);
        assert_eq!(sub.v_r(), f.v_r());
        assert_eq!(sub.r, f.r);
        for (t, &i) in rows.iter().enumerate() {
            assert_eq!(sub.kt.row(t), f.kt.row(i));
            assert_eq!(sub.kor_t.row(t), f.kor_t.row(i));
            assert_eq!(sub.km_t.row(t), f.km_t.row(i));
        }
    }

    #[test]
    fn reused_dirty_dist_scratch_matches_fresh() {
        // One DistScratch across differently-shaped prepares: the panel
        // reset must erase every stale value, so the factors are bitwise
        // identical to a fresh-scratch precompute.
        let corpus = toy();
        let pool = Pool::new(2);
        let q = corpus.query(0);
        let mut scratch = DistScratch::default();
        for sel_vals in [
            (vec![5usize, 40, 100], vec![0.25, 0.25, 0.5]),
            (q.indices(), q.val.clone()),
            (vec![7usize], vec![1.0]),
        ] {
            let (sel, vals) = sel_vals;
            let fresh = precompute_factors(&corpus.embeddings, &sel, &vals, 10.0, &pool);
            let reused =
                precompute_factors_in(&corpus.embeddings, &sel, &vals, 10.0, &pool, &mut scratch);
            assert_eq!(fresh.kt, reused.kt);
            assert_eq!(fresh.kor_t, reused.kor_t);
            assert_eq!(fresh.km_t, reused.km_t);
            assert_eq!(fresh.r, reused.r);
        }
        assert!(scratch.retained_bytes() > 0);
    }

    #[test]
    fn pool_size_does_not_change_bits() {
        let corpus = toy();
        let q = corpus.query(0);
        let base = precompute_factors(&corpus.embeddings, &q.indices(), &q.val, 10.0, &Pool::new(1));
        for p in [2usize, 5] {
            let f = precompute_factors(&corpus.embeddings, &q.indices(), &q.val, 10.0, &Pool::new(p));
            assert_eq!(f.kt, base.kt, "p={p}");
            assert_eq!(f.kor_t, base.kor_t);
            assert_eq!(f.km_t, base.km_t);
        }
    }

    #[test]
    fn duplicate_selection_rows_are_consistent() {
        // The router's duplicate-split padding repeats a word id; the
        // repeated columns must be identical except for the 1/r scaling.
        let corpus = toy();
        let pool = Pool::new(2);
        let sel = vec![5usize, 5, 40];
        let vals = vec![0.25, 0.25, 0.5];
        let f = precompute_factors(&corpus.embeddings, &sel, &vals, 10.0, &pool);
        for i in 0..f.vocab_size() {
            assert_eq!(f.kt.get(i, 0), f.kt.get(i, 1));
            assert_eq!(f.km_t.get(i, 0), f.km_t.get(i, 1));
            assert_eq!(f.kor_t.get(i, 0), f.kor_t.get(i, 1));
        }
    }
}
