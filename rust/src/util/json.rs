//! Minimal JSON reader/writer (the offline environment has no `serde`).
//! Supports the subset needed for `artifacts/manifest.json` and metric
//! dumps: objects, arrays, strings, numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is
/// deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Maximum container-nesting depth the parser accepts. The descent is
/// recursive, so without a cap a `[[[[...` byte stream overflows the stack —
/// an abort, not a catchable error — which is fatal for a server parsing
/// untrusted JSONL (found by the structured fuzzer, `testing::fuzz`).
const MAX_DEPTH: usize = 128;

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Object field as a string (`None` when missing or not a string) —
    /// the JSONL document reader's `{"text": ...}` accessor.
    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Json::as_str)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Convenience builder for objects: `obj([("k", v.into()), ...])`.
pub fn obj<I: IntoIterator<Item = (&'static str, Json)>>(entries: I) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    /// Current container-nesting depth, bounded by [`MAX_DEPTH`].
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.nested(Self::object),
            b'[' => self.nested(Self::array),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte '{}'", c as char))),
        }
    }

    /// Run a container parser one level deeper, rejecting pathological
    /// nesting before the process stack does.
    fn nested(
        &mut self,
        inner: fn(&mut Self) -> Result<Json, JsonError>,
    ) -> Result<Json, JsonError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.depth += 1;
        let v = inner(self);
        self.depth -= 1;
        v
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Re-decode UTF-8 from the byte stream.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = obj([
            ("name", "sinkhorn_step".into()),
            ("v_r", 16usize.into()),
            ("ok", true.into()),
            ("shape", vec![16usize, 500].into()),
        ]);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2.5,-3e2],"b":{"c":null,"d":"x\ny"}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[1], Json::Num(2.5));
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[2], Json::Num(-300.0));
        assert_eq!(j.get("b").unwrap().get("d").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(j.get("b").unwrap().get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "Aé");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn deep_nesting_is_an_error_not_a_stack_overflow() {
        // Fuzzer-class regression: unbounded recursion on `[[[[...` used to
        // abort the process. 2_000 levels is far past MAX_DEPTH.
        let bomb = "[".repeat(2_000) + &"]".repeat(2_000);
        let err = Json::parse(&bomb).unwrap_err();
        assert!(err.msg.contains("nesting too deep"), "{err}");
        // ... while legitimate nesting well under the cap still parses.
        let ok = "[".repeat(64) + &"]".repeat(64);
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }
}
