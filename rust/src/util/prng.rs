//! Deterministic PCG-XSL-RR 128/64 pseudo-random generator.
//!
//! The offline build has no `rand` crate; every stochastic component of the
//! repo (synthetic corpora, embeddings, property tests) draws from this
//! generator so runs are reproducible from a single `u64` seed.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed; distinct seeds give independent
    /// streams (the stream id is derived from the seed).
    pub fn new(seed: u64) -> Self {
        let seed = seed as u128;
        // SplitMix-style scrambling of the seed into state + stream.
        let inc = (seed.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1) << 1 | 1;
        let mut rng = Self { state: seed.wrapping_add(0xcafe_f00d_d15e_a5e5), inc };
        rng.next_u64();
        rng
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in `[0, bound)` via Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "below(0) is meaningless");
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= lo.wrapping_neg() % bound {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// deterministic — the discarded twin keeps the stream layout obvious).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k ≤ n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            // Dense regime: shuffle a full index vector.
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            // Sparse regime: rejection-sample into a sorted set.
            let mut chosen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let i = self.below(n);
                if chosen.insert(i) {
                    out.push(i);
                }
            }
            out
        }
    }

    /// Fork an independent child stream (for per-thread / per-doc RNGs).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag.wrapping_mul(0xd134_2543_de82_ef95))
    }
}

/// Sampler for a Zipf(α) distribution over ranks `1..=n`, used by the
/// synthetic corpus generator to mimic natural-language word frequencies.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, alpha: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(alpha);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for v in &mut cdf {
            *v /= total;
        }
        Self { cdf }
    }

    /// Draw a rank in `[0, n)` (0 = most frequent).
    #[inline]
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.next_f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::new(3);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_uniform_ish() {
        #[cfg(not(miri))]
        let n = 100_000;
        #[cfg(miri)]
        let n = 2_000;
        let mut rng = Pcg64::new(4);
        let mut counts = [0usize; 10];
        for _ in 0..n {
            counts[rng.below(10)] += 1;
        }
        for &c in &counts {
            // ±20% band around the expected n/10 per bucket.
            assert!((n / 10 * 4 / 5..n / 10 * 6 / 5).contains(&c), "count {c} out of band");
        }
    }

    #[test]
    fn gaussian_moments() {
        // Tolerances scale roughly with 1/sqrt(n); the miri leg trades
        // statistical power for a run that finishes under interpretation.
        #[cfg(not(miri))]
        let (n, mean_tol, var_tol) = (200_000, 0.02, 0.05);
        #[cfg(miri)]
        let (n, mean_tol, var_tol) = (2_000, 0.1, 0.15);
        let mut rng = Pcg64::new(5);
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.next_gaussian();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < mean_tol, "mean {mean}");
        assert!((var - 1.0).abs() < var_tol, "var {var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::new(6);
        for &(n, k) in &[(10, 10), (100, 5), (1000, 400)] {
            let idx = rng.sample_indices(n, k);
            assert_eq!(idx.len(), k);
            let set: std::collections::HashSet<_> = idx.iter().collect();
            assert_eq!(set.len(), k);
            assert!(idx.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn zipf_rank_ordering() {
        let mut rng = Pcg64::new(8);
        let z = Zipf::new(100, 1.1);
        #[cfg(not(miri))]
        let n = 50_000;
        #[cfg(miri)]
        let n = 2_000;
        let mut counts = vec![0usize; 100];
        for _ in 0..n {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[1] > counts[50]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
