//! Sample statistics used by the bench harness and the asymptotic-fit
//! experiment (Table 2): mean/stddev/percentiles and ordinary
//! least-squares fits.

/// Summary of a sample of measurements (seconds, counts, ...).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn from_samples(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "empty sample set");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        // NaN-safe total order (the PR-2 ranking convention): a NaN sample
        // sorts last instead of panicking mid-bench.
        sorted.sort_by(|a, b| a.total_cmp(b));
        Self {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
            max: sorted[n - 1],
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Ordinary least squares `y ≈ a + b·x`. Returns `(a, b, r²)`.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

/// Multi-variable least squares `y ≈ Σ_k beta_k · x_k` (no intercept),
/// solved by normal equations + Gaussian elimination. Used to fit the
/// paper's Table-2 cost model `t ≈ β₀·(V·v_r·w/p) + β₁·(t_it·nnz·v_r/p)`.
pub fn least_squares(features: &[Vec<f64>], ys: &[f64]) -> Vec<f64> {
    let m = features.len();
    assert!(m > 0);
    let k = features[0].len();
    assert!(features.iter().all(|f| f.len() == k));
    assert_eq!(ys.len(), m);
    // Normal matrix A = XᵀX (k×k), rhs b = Xᵀy.
    let mut a = vec![vec![0.0f64; k + 1]; k];
    for i in 0..k {
        for j in 0..k {
            a[i][j] = (0..m).map(|s| features[s][i] * features[s][j]).sum();
        }
        a[i][k] = (0..m).map(|s| features[s][i] * ys[s]).sum();
    }
    // Gaussian elimination with partial pivoting.
    for col in 0..k {
        let piv = (col..k)
            .max_by(|&p, &q| a[p][col].abs().total_cmp(&a[q][col].abs()))
            .unwrap();
        a.swap(col, piv);
        let d = a[col][col];
        assert!(d.abs() > 1e-300, "singular normal matrix");
        for j in col..=k {
            a[col][j] /= d;
        }
        for row in 0..k {
            if row != col {
                let f = a[row][col];
                for j in col..=k {
                    a[row][j] -= f * a[col][j];
                }
            }
        }
    }
    (0..k).map(|i| a[i][k]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_tolerates_nan_samples() {
        // Regression: `partial_cmp().unwrap()` used to panic here. NaN now
        // sorts last under `total_cmp`, so min/p50 stay finite and max
        // reports the NaN poisoning instead of aborting the bench run.
        let s = Summary::from_samples(&[2.0, f64::NAN, 1.0]);
        assert_eq!(s.min, 1.0);
        assert!(s.max.is_nan());
    }

    #[test]
    fn least_squares_nan_input_fails_with_diagnosis_not_unwrap() {
        // Regression: a NaN feature used to panic inside partial pivoting
        // via `partial_cmp().unwrap()`. Under `total_cmp` the NaN pivot is
        // selected deterministically and rejected by the explicit
        // singularity check — a diagnosable failure, not an opaque unwrap.
        let feats: Vec<Vec<f64>> = vec![vec![1.0, f64::NAN], vec![0.0, 1.0], vec![1.0, 2.0]];
        let ys = vec![1.0, 2.0, 3.0];
        let err = std::panic::catch_unwind(|| least_squares(&feats, &ys)).unwrap_err();
        let msg = err.downcast_ref::<&str>().copied().unwrap_or_default();
        assert!(msg.contains("singular normal matrix"), "unexpected panic: {msg}");
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert!((percentile(&xs, 0.95) - 9.5).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_exact() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [3.0, 5.0, 7.0, 9.0]; // y = 1 + 2x
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-10);
        assert!((b - 2.0).abs() < 1e-10);
        assert!((r2 - 1.0).abs() < 1e-10);
    }

    #[test]
    fn least_squares_two_features() {
        // y = 2*x0 + 0.5*x1, exactly.
        let feats: Vec<Vec<f64>> =
            vec![vec![1.0, 0.0], vec![0.0, 1.0], vec![1.0, 1.0], vec![2.0, 3.0], vec![5.0, 1.0]];
        let ys: Vec<f64> = feats.iter().map(|f| 2.0 * f[0] + 0.5 * f[1]).collect();
        let beta = least_squares(&feats, &ys);
        assert!((beta[0] - 2.0).abs() < 1e-9);
        assert!((beta[1] - 0.5).abs() < 1e-9);
    }
}
