//! Small self-contained utilities: a deterministic PRNG, statistics
//! helpers, a minimal JSON reader/writer (the offline environment has no
//! `serde`), and a shared-slice wrapper for disjoint parallel writes.

pub mod json;
pub mod prng;
pub mod shared;
pub mod stats;

pub use prng::{Pcg64, Zipf};
pub use shared::SharedSlice;

/// Align `n` up to a multiple of `m` (m > 0).
#[inline]
pub fn align_up(n: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    n.div_ceil(m) * m
}

/// Human-readable duration formatting for bench/metric output.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Number of logical CPUs visible to this process.
pub fn num_cpus() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// NaN-propagating maximum over a stream of non-negative values (residuals,
/// |diffs|, load shares), with identity `0.0` for the empty stream.
///
/// This is the mandated replacement for `fold(0.0, f64::max)` on score and
/// gate paths (`lint-rules` denies the latter): `f64::max` returns the
/// *non*-NaN operand, so a NaN residual silently vanishes and a broken
/// solve can pass its convergence gate. Here any NaN poisons the result and
/// the downstream `<` comparison fails loudly.
#[inline]
pub fn nan_max(values: impl IntoIterator<Item = f64>) -> f64 {
    values.into_iter().fold(0.0, nan_max2)
}

/// Binary NaN-propagating max — the `fold` companion of [`nan_max`], for
/// call sites that keep their own iterator chain.
#[inline]
pub fn nan_max2(a: f64, b: f64) -> f64 {
    if a.is_nan() || b.is_nan() {
        f64::NAN
    } else {
        a.max(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn align_up_basics() {
        assert_eq!(align_up(0, 8), 0);
        assert_eq!(align_up(1, 8), 8);
        assert_eq!(align_up(8, 8), 8);
        assert_eq!(align_up(9, 8), 16);
        assert_eq!(align_up(17, 5), 20);
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(2.5).ends_with(" s"));
        assert!(fmt_duration(2.5e-3).ends_with(" ms"));
        assert!(fmt_duration(2.5e-6).ends_with(" µs"));
        assert!(fmt_duration(2.5e-9).ends_with(" ns"));
    }

    #[test]
    fn num_cpus_positive() {
        assert!(num_cpus() >= 1);
    }

    #[test]
    fn nan_max_propagates_nan() {
        assert_eq!(nan_max([1.0, 3.0, 2.0]), 3.0);
        assert_eq!(nan_max([]), 0.0);
        // The whole point: `fold(0.0, f64::max)` would return 2.0 here.
        assert!(nan_max([1.0, f64::NAN, 2.0]).is_nan());
        assert!(nan_max([f64::NAN]).is_nan());
    }
}
