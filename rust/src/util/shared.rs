//! `SharedSlice` — a `Send + Sync` raw view over a mutable slice used for
//! **disjoint** parallel writes from the thread pool (the OpenMP idiom
//! `#pragma omp parallel for` over an output array). Callers must ensure
//! distinct threads write distinct indices; all kernel call-sites in this
//! crate partition the index space before writing.

use std::marker::PhantomData;

/// Unsafe shared mutable view over `&mut [T]` for partitioned parallel
/// writes. Cheap to copy into worker closures.
#[derive(Clone, Copy)]
pub struct SharedSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

// SAFETY: access discipline is "disjoint indices per thread", enforced by
// the partitioning at every call-site.
unsafe impl<T: Send> Send for SharedSlice<'_, T> {}
unsafe impl<T: Send> Sync for SharedSlice<'_, T> {}

impl<'a, T> SharedSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        Self { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: PhantomData }
    }

    #[inline(always)]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline(always)]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write one element. Caller guarantees `i` is owned by this thread.
    ///
    /// # Safety
    /// `i < len` and no other thread reads or writes index `i` concurrently.
    #[inline(always)]
    pub unsafe fn write(&self, i: usize, value: T) {
        debug_assert!(i < self.len);
        unsafe { *self.ptr.add(i) = value };
    }

    /// Read one element.
    ///
    /// # Safety
    /// `i < len` and no other thread writes index `i` concurrently.
    #[inline(always)]
    pub unsafe fn read(&self, i: usize) -> T
    where
        T: Copy,
    {
        debug_assert!(i < self.len);
        unsafe { *self.ptr.add(i) }
    }

    /// Mutable sub-slice `[start, start+len)` owned by the calling thread.
    ///
    /// # Safety
    /// The range is in-bounds and disjoint from every other thread's range.
    #[inline(always)]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &'a mut [T] {
        debug_assert!(start + len <= self.len);
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(start), len) }
    }

    /// Raw pointer access for pointer-arithmetic hot loops (the paper's
    /// "optimized pointer arithmetics" bullet).
    #[inline(always)]
    pub fn as_ptr(&self) -> *mut T {
        self.ptr
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::Pool;

    #[test]
    fn disjoint_parallel_writes() {
        let n = 10_000;
        let mut data = vec![0u64; n];
        let view = SharedSlice::new(&mut data);
        let pool = Pool::new(4);
        pool.run(|tid, nthreads| {
            let chunk = n.div_ceil(nthreads);
            let start = tid * chunk;
            let end = (start + chunk).min(n);
            for i in start..end {
                unsafe { view.write(i, i as u64 * 3) };
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u64 * 3);
        }
    }

    #[test]
    fn slice_mut_partition() {
        let mut data = vec![0u32; 100];
        let view = SharedSlice::new(&mut data);
        let pool = Pool::new(5);
        pool.run(|tid, nthreads| {
            let chunk = 100 / nthreads;
            let s = unsafe { view.slice_mut(tid * chunk, chunk) };
            s.fill(tid as u32 + 1);
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, (i / 20) as u32 + 1);
        }
    }
}
