//! `SharedSlice` — a `Send + Sync` raw view over a mutable slice used for
//! **disjoint** parallel writes from the thread pool (the OpenMP idiom
//! `#pragma omp parallel for` over an output array). Callers must ensure
//! distinct threads write distinct indices; all kernel call-sites in this
//! crate partition the index space before writing.
//!
//! Under the `strict-checks` cargo feature the contract stops being an
//! honor system: every `write`/`slice_mut` records its claimed interval in
//! a per-slice tracker and the process panics on any cross-thread overlap
//! or out-of-bounds claim. [`Pool::run`](crate::parallel::Pool::run) opens
//! a fresh claim region per parallel section, so repartitioning the same
//! buffer across regions (dynamic scheduling, ping-pong buffers) never
//! false-positives. The tracker compiles out entirely when the feature is
//! off — zero cost on release paths.

use std::marker::PhantomData;

/// Unsafe shared mutable view over `&mut [T]` for partitioned parallel
/// writes. Cheap to copy into worker closures.
#[derive(Clone, Copy)]
pub struct SharedSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

// SAFETY: access discipline is "disjoint indices per thread", enforced by
// the partitioning at every call-site (and verified at runtime under the
// `strict-checks` feature).
unsafe impl<T: Send> Send for SharedSlice<'_, T> {}
unsafe impl<T: Send> Sync for SharedSlice<'_, T> {}

impl<'a, T> SharedSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        Self { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: PhantomData }
    }

    #[inline(always)]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline(always)]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write one element. Caller guarantees `i` is owned by this thread.
    ///
    /// # Safety
    /// `i < len` and no other thread reads or writes index `i` concurrently.
    #[inline(always)]
    pub unsafe fn write(&self, i: usize, value: T) {
        debug_assert!(i < self.len);
        #[cfg(feature = "strict-checks")]
        strict::claim(self.ptr as usize, self.len, i, i + 1);
        // SAFETY: caller guarantees `i < len` and exclusive ownership of
        // index `i` within the current parallel region.
        unsafe { *self.ptr.add(i) = value };
    }

    /// Read one element.
    ///
    /// # Safety
    /// `i < len` and no other thread writes index `i` concurrently.
    #[inline(always)]
    pub unsafe fn read(&self, i: usize) -> T
    where
        T: Copy,
    {
        debug_assert!(i < self.len);
        // SAFETY: caller guarantees `i < len` and that no concurrent
        // writer holds index `i`.
        unsafe { *self.ptr.add(i) }
    }

    /// Mutable sub-slice `[start, start+len)` owned by the calling thread.
    ///
    /// # Safety
    /// The range is in-bounds and disjoint from every other thread's range.
    #[inline(always)]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &'a mut [T] {
        // `checked_add`: a corrupt `start` near `usize::MAX` must not wrap
        // past the bound check in debug builds.
        debug_assert!(start.checked_add(len).is_some_and(|end| end <= self.len));
        #[cfg(feature = "strict-checks")]
        strict::claim(self.ptr as usize, self.len, start, start.saturating_add(len));
        // SAFETY: caller guarantees the range is in-bounds and disjoint
        // from every other thread's claimed range for this region.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(start), len) }
    }

    /// Raw pointer access for pointer-arithmetic hot loops (the paper's
    /// "optimized pointer arithmetics" bullet).
    #[inline(always)]
    pub fn as_ptr(&self) -> *mut T {
        self.ptr
    }
}

/// Marks the start of a new parallel region for the `strict-checks` claim
/// tracker. Called by `Pool::run`; a no-op build-wise when the feature is
/// disabled (the cfg'd call-site compiles out).
#[cfg(feature = "strict-checks")]
pub fn strict_begin_region() {
    strict::begin_region();
}

/// Interval-claim tracker behind the `strict-checks` feature.
///
/// Design notes:
/// * Keyed by the slice's base address so `SharedSlice` stays `Copy` with
///   no extra fields — the release-mode layout is unchanged.
/// * A global region epoch (bumped by `Pool::run`) invalidates stale
///   claims lazily: repartitioning the same buffer in a later region is
///   legal, overlapping within one region is not. False negatives across
///   interleaved regions of *different* pools are accepted; false
///   positives are not.
/// * Same-thread claims merge into maximal intervals, so per-nnz claims
///   over a contiguous column range cost O(1) amortized per claim.
#[cfg(feature = "strict-checks")]
mod strict {
    use std::collections::HashMap;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::{Mutex, MutexGuard, OnceLock};
    use std::thread::ThreadId;

    /// Bumped at the start of every parallel region.
    static REGION_EPOCH: AtomicU64 = AtomicU64::new(0);

    struct ThreadClaims {
        id: ThreadId,
        name: String,
        /// Disjoint-or-abutting half-open intervals, unordered.
        ivals: Vec<(usize, usize)>,
    }

    struct SliceClaims {
        epoch: u64,
        claims: Vec<ThreadClaims>,
    }

    fn registry() -> MutexGuard<'static, HashMap<usize, SliceClaims>> {
        static REGISTRY: OnceLock<Mutex<HashMap<usize, SliceClaims>>> = OnceLock::new();
        // A claim panic (the tracker's whole point) poisons the mutex for
        // every later test in the process; recover the inner map instead.
        REGISTRY
            .get_or_init(|| Mutex::new(HashMap::new()))
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    pub(super) fn begin_region() {
        REGION_EPOCH.fetch_add(1, Ordering::SeqCst);
    }

    fn thread_label() -> String {
        std::thread::current().name().unwrap_or("<unnamed>").to_string()
    }

    /// Record the claim `[start, end)` on the slice based at `base` (element
    /// units). Panics on out-of-bounds claims and on overlap with an
    /// interval claimed by a *different* thread in the same region.
    pub(super) fn claim(base: usize, slice_len: usize, start: usize, end: usize) {
        if start == end {
            return;
        }
        if end > slice_len || start > end {
            panic!(
                "SharedSlice strict-checks: out-of-bounds claim [{start}..{end}) by thread \
                 '{}' on slice of len {slice_len} (base {base:#x})",
                thread_label()
            );
        }
        let epoch = REGION_EPOCH.load(Ordering::SeqCst);
        let me = std::thread::current().id();
        let mut map = registry();
        let entry = map
            .entry(base)
            .or_insert_with(|| SliceClaims { epoch, claims: Vec::new() });
        if entry.epoch != epoch {
            // New parallel region: previous partition no longer applies.
            entry.claims.clear();
            entry.epoch = epoch;
        }
        let mut conflict: Option<(String, ThreadId, usize, usize)> = None;
        for other in entry.claims.iter() {
            if other.id == me {
                continue;
            }
            for &(s, e) in &other.ivals {
                if s < end && start < e {
                    conflict = Some((other.name.clone(), other.id, s.max(start), e.min(end)));
                    break;
                }
            }
            if conflict.is_some() {
                break;
            }
        }
        if let Some((other_name, other_id, os, oe)) = conflict {
            let mine = thread_label();
            let my_id = me;
            // Release the registry before unwinding so later tests (and the
            // poison-recovery above) see a consistent tracker.
            drop(map);
            panic!(
                "SharedSlice strict-checks: overlapping parallel claims on slice base \
                 {base:#x}: thread '{mine}' ({my_id:?}) claimed [{start}..{end}) which \
                 overlaps [{os}..{oe}) already claimed by thread '{other_name}' \
                 ({other_id:?}) in the same parallel region — partitioned writes must be \
                 disjoint"
            );
        }
        match entry.claims.iter_mut().find(|c| c.id == me) {
            Some(own) => {
                // Merge with an overlapping-or-abutting own interval when
                // possible; contiguous per-nnz claims stay O(1) intervals.
                for ival in own.ivals.iter_mut() {
                    if start <= ival.1 && ival.0 <= end {
                        ival.0 = ival.0.min(start);
                        ival.1 = ival.1.max(end);
                        return;
                    }
                }
                own.ivals.push((start, end));
            }
            None => entry.claims.push(ThreadClaims {
                id: me,
                name: thread_label(),
                ivals: vec![(start, end)],
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::Pool;

    #[test]
    fn disjoint_parallel_writes() {
        #[cfg(not(miri))]
        let n = 10_000;
        #[cfg(miri)]
        let n = 512;
        let mut data = vec![0u64; n];
        let view = SharedSlice::new(&mut data);
        let pool = Pool::new(4);
        pool.run(|tid, nthreads| {
            let chunk = n.div_ceil(nthreads);
            let start = tid * chunk;
            let end = (start + chunk).min(n);
            for i in start..end {
                // SAFETY: [start, end) ranges are disjoint across tids.
                unsafe { view.write(i, i as u64 * 3) };
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u64 * 3);
        }
    }

    #[test]
    fn slice_mut_partition() {
        let mut data = vec![0u32; 100];
        let view = SharedSlice::new(&mut data);
        let pool = Pool::new(5);
        pool.run(|tid, nthreads| {
            let chunk = 100 / nthreads;
            // SAFETY: each tid claims its own disjoint chunk.
            let s = unsafe { view.slice_mut(tid * chunk, chunk) };
            s.fill(tid as u32 + 1);
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, (i / 20) as u32 + 1);
        }
    }
}
