//! Minimal command-line parser (`clap` is unavailable offline):
//! `binary <subcommand> [--key value] [--flag]`.

use std::collections::BTreeMap;

/// Parsed arguments: one optional subcommand + `--key value` options +
//  bare `--flag` switches.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self, String> {
        let mut args = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(key) = tok.strip_prefix("--") {
                if key.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map_or(false, |n| !n.starts_with("--")) {
                    args.options.insert(key.to_string(), iter.next().unwrap());
                } else {
                    args.flags.push(key.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Self, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String> {
        match self.options.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{name}: cannot parse '{v}'")),
        }
    }

    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
        Ok(self.get_parse(name)?.unwrap_or(default))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_options_flags() {
        let a = parse("serve --threads 8 --verbose --lambda=2.5 extra");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("threads"), Some("8"));
        assert_eq!(a.get("lambda"), Some("2.5"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["extra".to_string()]);
    }

    #[test]
    fn typed_getters() {
        let a = parse("x --n 42");
        assert_eq!(a.get_or("n", 0usize).unwrap(), 42);
        assert_eq!(a.get_or("missing", 7usize).unwrap(), 7);
        assert!(a.get_parse::<usize>("n").unwrap().is_some());
    }

    #[test]
    fn parse_error_on_bad_number() {
        let a = parse("x --n abc");
        assert!(a.get_parse::<usize>("n").is_err());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("run --fast --slow");
        assert!(a.flag("fast") && a.flag("slow"));
        assert_eq!(a.get("fast"), None);
    }
}
