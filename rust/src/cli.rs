//! Minimal command-line parser (`clap` is unavailable offline):
//! `binary <subcommand> [--key value] [--flag] [--] [positional...]`.
//!
//! Boolean switches are **declared** ([`BOOL_FLAGS`]): a bare `--key`
//! outside that list must be followed by a value. Without the
//! declaration, `--verbose corpus.bin` would silently consume the
//! positional `corpus.bin` as the flag's value — the classic greedy-parse
//! bug. A standalone `--` ends option parsing; everything after it is
//! positional (so filenames that start with `--` remain expressible).

use std::collections::BTreeMap;

/// Bare switches the parser recognizes as boolean flags. Everything else
/// written `--key` must carry a value (`--key value` or `--key=value`).
/// `jsonl` forces the `ingest` document reader into JSONL mode regardless
/// of the file extension.
pub const BOOL_FLAGS: &[&str] = &["verbose", "quiet", "help", "jsonl"];

/// Parsed arguments: one optional subcommand + `--key value` options +
//  bare `--flag` switches.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]), with the
    /// crate's standard boolean flags ([`BOOL_FLAGS`]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self, String> {
        Self::parse_with_flags(argv, BOOL_FLAGS)
    }

    /// Parse with an explicit boolean-flag declaration (tests, embedders).
    pub fn parse_with_flags<I: IntoIterator<Item = String>>(
        argv: I,
        bool_flags: &[&str],
    ) -> Result<Self, String> {
        let mut args = Args::default();
        let mut iter = argv.into_iter();
        let mut options_done = false;
        while let Some(tok) = iter.next() {
            if !options_done && tok == "--" {
                options_done = true;
                continue;
            }
            if !options_done {
                if let Some(key) = tok.strip_prefix("--") {
                    if let Some((k, v)) = key.split_once('=') {
                        if k.is_empty() {
                            return Err(format!("malformed option '{tok}'"));
                        }
                        if bool_flags.contains(&k) {
                            return Err(format!("flag --{k} takes no value (got '{v}')"));
                        }
                        args.options.insert(k.to_string(), v.to_string());
                    } else if bool_flags.contains(&key) {
                        args.flags.push(key.to_string());
                    } else {
                        match iter.next() {
                            Some(v) if !v.starts_with("--") => {
                                args.options.insert(key.to_string(), v);
                            }
                            Some(other) => {
                                return Err(format!(
                                    "option --{key} requires a value, found '{other}' \
                                     (use --{key}=VALUE if the value starts with '--')"
                                ));
                            }
                            None => return Err(format!("option --{key} requires a value")),
                        }
                    }
                    continue;
                }
            }
            if args.subcommand.is_none() && args.positional.is_empty() && !options_done {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Self, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String> {
        match self.options.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{name}: cannot parse '{v}'")),
        }
    }

    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
        Ok(self.get_parse(name)?.unwrap_or(default))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_options_flags() {
        let a = parse("serve --threads 8 --verbose --lambda=2.5 extra");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("threads"), Some("8"));
        assert_eq!(a.get("lambda"), Some("2.5"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["extra".to_string()]);
    }

    #[test]
    fn declared_flag_does_not_swallow_positional() {
        // Regression: `--verbose corpus.bin` used to consume the
        // positional as the flag's value.
        let a = parse("solve --verbose corpus.bin");
        assert!(a.flag("verbose"));
        assert_eq!(a.get("verbose"), None);
        assert_eq!(a.positional(), &["corpus.bin".to_string()]);
    }

    #[test]
    fn double_dash_terminates_options() {
        let a = parse("solve --threads 2 -- --not-an-option also-positional");
        assert_eq!(a.get("threads"), Some("2"));
        assert_eq!(
            a.positional(),
            &["--not-an-option".to_string(), "also-positional".to_string()]
        );
        assert!(!a.flag("not-an-option"));
    }

    #[test]
    fn dangling_option_at_end_is_an_error() {
        // Regression: a trailing `--threads` used to become a silent flag.
        let err = Args::parse(["solve", "--threads"].map(String::from)).unwrap_err();
        assert!(err.contains("--threads requires a value"), "{err}");
    }

    #[test]
    fn option_followed_by_option_is_an_error() {
        let err = Args::parse(["solve", "--threads", "--docs", "5"].map(String::from)).unwrap_err();
        assert!(err.contains("--threads requires a value"), "{err}");
    }

    #[test]
    fn declared_flags_may_stack() {
        let a = Args::parse_with_flags(
            ["run", "--fast", "--slow"].map(String::from),
            &["fast", "slow"],
        )
        .unwrap();
        assert!(a.flag("fast") && a.flag("slow"));
        assert_eq!(a.get("fast"), None);
    }

    #[test]
    fn ingest_invocation_parses() {
        let a = parse("ingest --vec emb.vec --docs docs.jsonl --jsonl --out corpus.wmdc");
        assert_eq!(a.subcommand.as_deref(), Some("ingest"));
        assert_eq!(a.get("vec"), Some("emb.vec"));
        assert_eq!(a.get("docs"), Some("docs.jsonl"));
        assert_eq!(a.get("out"), Some("corpus.wmdc"));
        assert!(a.flag("jsonl"));
        assert!(a.positional().is_empty());
    }

    #[test]
    fn typed_getters() {
        let a = parse("x --n 42");
        assert_eq!(a.get_or("n", 0usize).unwrap(), 42);
        assert_eq!(a.get_or("missing", 7usize).unwrap(), 7);
        assert!(a.get_parse::<usize>("n").unwrap().is_some());
    }

    #[test]
    fn parse_error_on_bad_number() {
        let a = parse("x --n abc");
        assert!(a.get_parse::<usize>("n").is_err());
    }

    #[test]
    fn malformed_equals_option_is_an_error() {
        assert!(Args::parse(["x", "--=5"].map(String::from)).is_err());
    }

    #[test]
    fn declared_flag_with_value_is_an_error() {
        // `--verbose=1` must not silently become an option the flag()
        // lookup misses.
        let err = Args::parse(["solve", "--verbose=1"].map(String::from)).unwrap_err();
        assert!(err.contains("--verbose takes no value"), "{err}");
    }
}
