//! The **dense baseline**: a faithful Rust port of the paper's Python
//! implementation (Fig. 2) — dense `Kᵀ@u` products of size `V×N`, sparse
//! element-wise multiply against `c`, a CSC conversion every iteration —
//! with per-stage timers that regenerate Table 1's profile.
//!
//! This solver exists to be *measured against*, not to be fast: it
//! materializes the `V × N` dense intermediate that the sparse transform
//! eliminates (91.9 % + 6.1 % of the baseline's runtime in Table 1).

use crate::dist::QueryFactors;
use crate::parallel::Pool;
use crate::sparse::ops::TransposedPattern;
use crate::sparse::{axpy, Csr, Dense};
use crate::corpus::SparseVec;
use crate::util::SharedSlice;
use crate::Real;
use std::time::{Duration, Instant};

use super::solver::{empty_columns_into, Prepared, SinkhornConfig, SolveOutput};
use super::workspace::SolveWorkspace;

/// Wall-clock per pipeline stage (the Table-1 rows).
#[derive(Clone, Debug, Default)]
pub struct DenseStageTimes {
    /// `M = cdist(vecs[sel], vecs)` + `K`/`K_over_r`/`K⊙M` precompute.
    pub cdist_precompute: Duration,
    /// Dense `Kᵀ @ u` (the `(100000×v_r) @ (v_r×5000)` product).
    pub kt_matmul: Duration,
    /// Sparse elementwise `c.multiply(1 / (Kᵀ@u))`.
    pub sparse_multiply: Duration,
    /// `v.tocsc()` conversion.
    pub tocsc: Duration,
    /// `x = K_over_r @ v_csc` (dense × sparse).
    pub spmm: Duration,
    /// `u = 1/x` updates.
    pub update_u: Duration,
    /// Final `(u ⊙ ((K⊙M)@v)).sum(axis=0)`.
    pub finish: Duration,
}

impl DenseStageTimes {
    pub fn total(&self) -> Duration {
        self.cdist_precompute
            + self.kt_matmul
            + self.sparse_multiply
            + self.tocsc
            + self.spmm
            + self.update_u
            + self.finish
    }

    /// `(stage name, seconds, percent)` rows, Table-1 style.
    pub fn rows(&self) -> Vec<(&'static str, f64, f64)> {
        let total = self.total().as_secs_f64().max(1e-12);
        let mk = |name, d: Duration| (name, d.as_secs_f64(), 100.0 * d.as_secs_f64() / total);
        vec![
            mk("M = cdist(vecs[sel], vecs); K; K_over_r", self.cdist_precompute),
            mk("KT @ u (dense matmul)", self.kt_matmul),
            mk("c.multiply(1/(KT@u)) (sparse elementwise)", self.sparse_multiply),
            mk("v.tocsc()", self.tocsc),
            mk("x = K_over_r @ v_csc (dense x sparse)", self.spmm),
            mk("u = 1.0 / x", self.update_u),
            mk("final (u * ((K*M)@v)).sum(axis=0)", self.finish),
        ]
    }
}

/// The dense Algorithm-1 pipeline.
pub struct DenseSolver {
    config: SinkhornConfig,
    /// Refuse to allocate dense intermediates beyond this (bytes); the
    /// paper-scale `V×N` product is 4 GB — run the baseline scaled down.
    pub max_dense_bytes: usize,
}

impl DenseSolver {
    pub fn new(config: SinkhornConfig) -> Self {
        Self { config, max_dense_bytes: 1 << 31 }
    }

    /// Phase 1, shared with the sparse solver: the `dist`-layer factor
    /// precompute. The dense pipeline's `K`/`K_over_r`/`K⊙M` are the same
    /// numbers, stored transposed.
    pub fn prepare(&self, embeddings: &Dense, query: &SparseVec, pool: &Pool) -> Prepared {
        self.config.prepare(embeddings, query, pool)
    }

    /// Solve one query against all columns of `c`, returning the WMD
    /// vector and the per-stage profile (prepare + iterate).
    pub fn solve(
        &self,
        embeddings: &Dense,
        query: &SparseVec,
        c: &Csr,
        pool: &Pool,
    ) -> (SolveOutput, DenseStageTimes) {
        assert_eq!(embeddings.nrows(), c.nrows());
        // Fail fast on the V×N guard *before* paying the O(v_r·V·w)
        // precompute or allocating the factor matrices.
        let dense_bytes = c.nrows() * c.ncols() * std::mem::size_of::<Real>();
        assert!(
            dense_bytes <= self.max_dense_bytes,
            "dense baseline would allocate {dense_bytes} B for the V x N intermediate; \
             run it at a scaled size (see DESIGN.md §3)"
        );
        let t0 = Instant::now();
        let prep = self.prepare(embeddings, query, pool);
        let cdist_precompute = t0.elapsed();
        let (out, mut times) = self.solve_prepared(&prep, c, pool);
        times.cdist_precompute = cdist_precompute;
        (out, times)
    }

    /// Phase 2: run the dense Algorithm-1 pipeline on already-prepared
    /// factors (borrowed — the caller, e.g. the coordinator's
    /// prepared-factor cache, keeps ownership). The returned profile has
    /// `cdist_precompute` at zero: preparation happened elsewhere.
    ///
    /// Thin allocating wrapper over [`DenseSolver::solve_prepared_in`].
    pub fn solve_prepared(
        &self,
        prep: &Prepared,
        c: &Csr,
        pool: &Pool,
    ) -> (SolveOutput, DenseStageTimes) {
        self.solve_prepared_in(&mut SolveWorkspace::new(), prep, c, pool)
    }

    /// [`DenseSolver::solve_prepared`] with the pipeline state (`x`, `u`,
    /// `(K⊙M)v`, the SDDMM values and the per-iteration CSC pattern)
    /// borrowed from workspace lanes. The per-iteration `tocsc` still
    /// *rebuilds* the pattern — that conversion cost is exactly what the
    /// Table-1 profile measures — but into retained storage, so the
    /// baseline no longer thrashes the allocator while being profiled.
    ///
    /// Deliberate exception: the `V × N` `Kᵀu` intermediate (the 91.9 %
    /// Table-1 plane, gigabytes at paper scale and bounded only by
    /// `max_dense_bytes`) is allocated per call and freed on return. The
    /// workspace is grow-only and long-lived — routing that plane through
    /// it would let a single dense-backend request permanently pin the
    /// dispatcher's arena at `V·N` floats while it serves sparse traffic
    /// whose lanes are `N·v_r`.
    pub fn solve_prepared_in(
        &self,
        ws: &mut SolveWorkspace,
        prep: &Prepared,
        c: &Csr,
        pool: &Pool,
    ) -> (SolveOutput, DenseStageTimes) {
        let v = c.nrows();
        let n = c.ncols();
        assert_eq!(prep.factors.vocab_size(), v, "factors/c vocabulary mismatch");
        let dense_bytes = v * n * std::mem::size_of::<Real>();
        assert!(
            dense_bytes <= self.max_dense_bytes,
            "dense baseline would allocate {dense_bytes} B for the V x N intermediate; \
             run it at a scaled size (see DESIGN.md §3)"
        );
        let bytes_before = ws.begin_checkout();
        ws.ensure_lanes(1);
        let mut times = DenseStageTimes::default();
        let factors = &prep.factors;
        let v_r = factors.v_r();

        let out = {
            let SolveWorkspace { x_t, x_new, u_t, empty, w_buf, pattern, .. } = &mut *ws;
            // Python state layout: x, u are v_r × N row-major. The lanes:
            // x_t[0] = x, u_t[0] = u, x_new[0] = (K⊙M)v for the epilogue —
            // all `v_r × N`, the same footprint as the sparse lanes. The
            // V×N `Kᵀu` plane stays per-call (see the doc above).
            let x = &mut x_t[0];
            let u = &mut u_t[0];
            let kmv = &mut x_new[0];
            let mut ktu = Dense::zeros(v, n);
            let ktu = &mut ktu;
            x.reset(v_r, n, 1.0 / v_r as Real);
            u.reset(v_r, n, 0.0);
            w_buf.clear();
            w_buf.resize(c.nnz(), 0.0);
            let w = w_buf;

            for _ in 0..self.config.max_iter {
                // u = 1 / x
                let t = Instant::now();
                elementwise_recip(x, u, pool);
                times.update_u += t.elapsed();

                // KT @ u  — the dense V×N product.
                let t = Instant::now();
                dense_matmul_kt_u(factors, u, ktu, pool);
                times.kt_matmul += t.elapsed();

                // v = c.multiply(1 / (KT@u)) at the pattern of c.
                let t = Instant::now();
                sparse_multiply(c, ktu, w, pool);
                times.sparse_multiply += t.elapsed();

                // v.tocsc() — full conversion every iteration, like scipy
                // (into retained pattern storage).
                let t = Instant::now();
                pattern.rebuild_from(c);
                times.tocsc += t.elapsed();

                // x = K_over_r @ v_csc (dense × sparse, strided column reads).
                let t = Instant::now();
                dense_spmm_columns(factors, pattern, w, x, pool);
                times.spmm += t.elapsed();
            }

            // Final: u = 1/x; v = c.multiply(1/(KT@u)); WMD = (u*((K⊙M)@v)).sum(0).
            let t = Instant::now();
            elementwise_recip(x, u, pool);
            times.update_u += t.elapsed();
            let t = Instant::now();
            dense_matmul_kt_u(factors, u, ktu, pool);
            times.kt_matmul += t.elapsed();
            let t = Instant::now();
            sparse_multiply(c, ktu, w, pool);
            times.sparse_multiply += t.elapsed();

            let t = Instant::now();
            pattern.rebuild_from(c);
            kmv.reset(v_r, n, 0.0);
            dense_spmm_columns_km(factors, pattern, w, kmv, pool);
            let mut wmd = vec![0.0; n];
            for i in 0..v_r {
                let urow = u.row(i);
                let krow = kmv.row(i);
                for j in 0..n {
                    wmd[j] += urow[j] * krow[j];
                }
            }
            // Empty documents: x[:, j] collapses to 0 after one iteration (no
            // pattern entries feed it), u = 1/x = inf, and inf · 0 above gives
            // NaN — report +inf, matching the sparse solver's contract.
            empty_columns_into(c, empty);
            for (w, &e) in wmd.iter_mut().zip(empty.iter()) {
                if e {
                    *w = Real::INFINITY;
                }
            }
            times.finish = t.elapsed();

            SolveOutput {
                wmd,
                iterations: self.config.max_iter,
                converged: false,
                ..Default::default()
            }
        };
        ws.end_checkout(bytes_before);
        (out, times)
    }
}

/// `u = 1 / x`, parallel elementwise.
fn elementwise_recip(x: &Dense, u: &mut Dense, pool: &Pool) {
    let xs = x.as_slice();
    let view = SharedSlice::new(u.as_mut_slice());
    pool.parallel_for(xs.len(), |range| {
        for i in range {
            // SAFETY: disjoint static chunks.
            unsafe { view.write(i, 1.0 / xs[i]) };
        }
    });
}

/// `ktu = Kᵀ @ u`: `V×v_r` (row-major `kt`) times `v_r×N` → `V×N`.
/// Parallel over vocabulary rows; inner axpy over documents.
fn dense_matmul_kt_u(f: &QueryFactors, u: &Dense, ktu: &mut Dense, pool: &Pool) {
    let v = f.kt.nrows();
    let v_r = f.kt.ncols();
    let n = u.ncols();
    debug_assert_eq!(u.nrows(), v_r);
    let view = SharedSlice::new(ktu.as_mut_slice());
    pool.parallel_for(v, |rows| {
        for i in rows {
            // SAFETY: row i owned by one thread.
            let out = unsafe { view.slice_mut(i * n, n) };
            out.fill(0.0);
            let ktrow = f.kt.row(i);
            for k in 0..v_r {
                axpy(out, ktrow[k], u.row(k));
            }
        }
    });
}

/// `w[e] = c.values[e] / ktu[i, j]` over the pattern of `c`.
fn sparse_multiply(c: &Csr, ktu: &Dense, w: &mut [Real], pool: &Pool) {
    let parts = crate::parallel::balanced_nnz_partition(c.row_ptr(), pool.nthreads());
    let (row_ptr, col_idx, values) = (c.row_ptr(), c.col_idx(), c.values());
    let n = ktu.ncols();
    let view = SharedSlice::new(w);
    pool.run(|tid, _| {
        let part = parts[tid];
        crate::sparse::ops::for_each_nnz_in(part, row_ptr, |e, row| {
            let j = col_idx[e] as usize;
            // SAFETY: nnz ranges disjoint.
            unsafe { view.write(e, values[e] / ktu.as_slice()[row * n + j]) };
        });
    });
}

/// `x = K_over_r @ v_csc`: columns of `K_over_r` are strided reads of
/// `kor_t` rows — the faithful scipy-style dense×sparse.
fn dense_spmm_columns(
    f: &QueryFactors,
    pattern: &TransposedPattern,
    w: &[Real],
    x: &mut Dense,
    pool: &Pool,
) {
    spmm_cols_from(&f.kor_t, pattern, w, x, pool);
}

/// `(K⊙M) @ v_csc` for the epilogue.
fn dense_spmm_columns_km(
    f: &QueryFactors,
    pattern: &TransposedPattern,
    w: &[Real],
    out: &mut Dense,
    pool: &Pool,
) {
    spmm_cols_from(&f.km_t, pattern, w, out, pool);
}

fn spmm_cols_from(
    factor_t: &Dense, // V × v_r
    pattern: &TransposedPattern,
    w: &[Real],
    out: &mut Dense, // v_r × N
    pool: &Pool,
) {
    let v_r = out.nrows();
    let n = out.ncols();
    debug_assert_eq!(factor_t.ncols(), v_r);
    let view = SharedSlice::new(out.as_mut_slice());
    pool.parallel_for(n, |cols| {
        for j in cols {
            // Column j of `out` is strided with stride N — each thread owns
            // whole columns, so writes stay disjoint.
            let mut acc = vec![0.0; v_r];
            for e in pattern.col_ptr[j]..pattern.col_ptr[j + 1] {
                let i = pattern.src_row[e] as usize;
                let val = w[pattern.src_pos[e] as usize];
                axpy(&mut acc, val, factor_t.row(i));
            }
            for (k, &a) in acc.iter().enumerate() {
                // SAFETY: column j owned by this thread.
                unsafe { view.write(k * n + j, a) };
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::SyntheticCorpus;
    use crate::sinkhorn::{SinkhornConfig, SparseSolver};

    #[test]
    fn dense_matches_sparse_solver() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(400)
            .num_docs(30)
            .embedding_dim(12)
            .num_queries(2)
            .query_words(6, 10)
            .seed(23)
            .build();
        let pool = Pool::new(4);
        let config = SinkhornConfig { tolerance: 0.0, max_iter: 12, ..Default::default() };
        let sparse = SparseSolver::new(config);
        let dense = DenseSolver::new(config);
        for q in 0..2 {
            let a = sparse.wmd_one_to_many(&corpus.embeddings, corpus.query(q), &corpus.c, &pool);
            let (b, times) = dense.solve(&corpus.embeddings, corpus.query(q), &corpus.c, &pool);
            for (x, y) in a.wmd.iter().zip(&b.wmd) {
                assert!((x - y).abs() < 1e-9 * (1.0 + y.abs()), "{x} vs {y}");
            }
            assert!(times.total() > Duration::ZERO);
        }
    }

    #[test]
    fn solve_prepared_matches_one_shot_solve() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(350)
            .num_docs(25)
            .embedding_dim(10)
            .num_queries(1)
            .query_words(6, 6)
            .seed(41)
            .build();
        let pool = Pool::new(2);
        let config = SinkhornConfig { tolerance: 0.0, max_iter: 6, ..Default::default() };
        let dense = DenseSolver::new(config);
        let (a, _) = dense.solve(&corpus.embeddings, corpus.query(0), &corpus.c, &pool);
        let prep = dense.prepare(&corpus.embeddings, corpus.query(0), &pool);
        let (b, times) = dense.solve_prepared(&prep, &corpus.c, &pool);
        assert_eq!(a.wmd, b.wmd, "shared factors must give the identical pipeline result");
        assert_eq!(times.cdist_precompute, Duration::ZERO, "preparation happened elsewhere");
    }

    #[test]
    fn empty_document_reports_infinity_like_sparse() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(300)
            .num_docs(20)
            .embedding_dim(8)
            .num_queries(1)
            .query_words(5, 5)
            .seed(37)
            .build();
        // Rebuild c with column 4 emptied.
        let k = 4;
        let mut coo = crate::sparse::Coo::new(corpus.c.nrows(), corpus.c.ncols());
        for (i, j, v) in corpus.c.iter() {
            if j != k {
                coo.push(i, j, v);
            }
        }
        let c = Csr::from_coo(coo);
        let pool = Pool::new(2);
        let config = SinkhornConfig { tolerance: 0.0, max_iter: 8, ..Default::default() };
        let dense = DenseSolver::new(config);
        let (out, _) = dense.solve(&corpus.embeddings, corpus.query(0), &c, &pool);
        assert!(out.wmd[k].is_infinite() && out.wmd[k] > 0.0, "got {}", out.wmd[k]);
        let sparse = SparseSolver::new(config);
        let s = sparse.wmd_one_to_many(&corpus.embeddings, corpus.query(0), &c, &pool);
        for (j, (x, y)) in out.wmd.iter().zip(&s.wmd).enumerate() {
            if j == k {
                continue;
            }
            assert!(x.is_finite(), "dense doc {j} poisoned: {x}");
            assert!((x - y).abs() < 1e-9 * (1.0 + y.abs()), "doc {j}: {x} vs {y}");
        }
        assert_ne!(out.argmin(), Some(k));
    }

    #[test]
    fn stage_rows_sum_to_100_percent() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(300)
            .num_docs(20)
            .embedding_dim(8)
            .num_queries(1)
            .query_words(5, 5)
            .seed(29)
            .build();
        let pool = Pool::new(2);
        let dense = DenseSolver::new(SinkhornConfig { max_iter: 5, ..Default::default() });
        let (_, times) = dense.solve(&corpus.embeddings, corpus.query(0), &corpus.c, &pool);
        let pct: f64 = times.rows().iter().map(|(_, _, p)| p).sum();
        assert!((pct - 100.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "scaled size")]
    fn refuses_paper_scale_dense_intermediate() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(1000)
            .num_docs(100)
            .embedding_dim(4)
            .num_queries(1)
            .query_words(3, 3)
            .seed(31)
            .build();
        let pool = Pool::new(1);
        let mut dense = DenseSolver::new(SinkhornConfig::default());
        dense.max_dense_bytes = 1024; // force the guard
        let _ = dense.solve(&corpus.embeddings, corpus.query(0), &corpus.c, &pool);
    }
}
