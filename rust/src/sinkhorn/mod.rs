//! The Sinkhorn-Knopp WMD solvers.
//!
//! * [`SparseSolver`] — the paper's contribution: the sparse, fused
//!   `SDDMM_SpMM` iteration over the CSR target matrix.
//! * [`dense::DenseSolver`] — the faithful port of the Python/MKL
//!   baseline (Fig. 2): dense `Kᵀ@u` products, sparse element-wise
//!   multiply, CSC conversion — with per-stage timers that regenerate
//!   Table 1.
//!
//! Both compute `WMD[j] = d_M^λ(r, c[:, j])` for one source histogram `r`
//! against all `N` target columns of `c` (Algorithm 1).

pub mod dense;
pub mod solver;
pub mod workspace;

pub use dense::{DenseSolver, DenseStageTimes};
pub use solver::{
    ConvergenceStats, FreezeHistogram, IterateKernel, Precision, Prepared, SinkhornConfig,
    SolveOutput, SparseSolver,
};
pub use workspace::{SolveWorkspace, WorkspaceStats};
