//! The shared-memory parallel **sparse** Sinkhorn-WMD solver (paper §4).
//!
//! Pipeline per query:
//! 1. `prepare` — select the query's non-zero words and precompute the
//!    transposed factors `Kᵀ`, `K_over_rᵀ`, `(K⊙M)ᵀ` in one fused
//!    GEMM-style pass ([`crate::dist::precompute_factors`]).
//! 2. `solve` — iterate `x ← K_over_r @ (c ⊘ (Kᵀ@(1/x)))` with the fused
//!    `SDDTMM→DSTMMT` kernel over the stationary transposed pattern until
//!    `x` stops changing (or `max_iter`), then reduce the WMD vector with
//!    the fused epilogue.
//!
//! Kernel selection is [`IterateKernel`]: the fused family (optionally in
//! [`Precision::Mixed`] — f32 compute panels, f64 accumulation and
//! convergence/WMD reduction) or the `Unfused` SDDMM + atomic-SpMM
//! ablation baseline.

use super::workspace::SolveWorkspace;
use crate::corpus::SparseVec;
use crate::dist::{precompute_factors_in, QueryFactors};
use crate::parallel::{balanced_nnz_partition_into, subset_nnz_prefix_into, NnzRange, Pool};
use crate::sparse::ops::{sddmm, sddtmm_dstmmt_batch, sddtmm_wmd_batch, spmm_atomic, ActiveView};
use crate::sparse::{Csr, Dense, Panel32};
use crate::util::SharedSlice;
use crate::Real;

/// Scalar precision of the fused iterate's compute panels.
///
/// `Mixed` narrows the *stationary* panels (`Kᵀ`, `K_over_rᵀ`) and the
/// `uᵀ` mirror to f32 — halving the iterate's memory traffic and doubling
/// its SIMD width — while every division, accumulation, renormalization,
/// convergence residual and the final WMD reduction stay f64. Measured
/// end-to-end WMD error vs the f64 path is ~2e-9 at paper-scale shapes;
/// the equivalence suite enforces ≤ 1e-5.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Precision {
    /// Full f64 throughout (the default; bitwise-reproducible).
    #[default]
    F64,
    /// f32 compute panels with f64 accumulation (requires the
    /// `mixed-precision` build feature).
    #[cfg(feature = "mixed-precision")]
    Mixed,
}

/// Which iterate kernel the solver uses (ablation: `benches/ablation_fusion`).
///
/// The former `FusedAtomic` / `FusedPrivate` / `FusedTransposed` variants
/// collapsed into the single [`IterateKernel::Fused`] family — the
/// column-owned transposed traversal beat both scatter strategies on
/// every measured shape, so only the best survives, parameterized by
/// [`Precision`]. `Unfused` remains as the one ablation baseline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IterateKernel {
    /// The fused `SDDTMM→DSTMMT` family: one pass over the stationary
    /// transposed pattern per Sinkhorn step, write-owned columns, no
    /// atomics, no private buffers.
    Fused { precision: Precision },
    /// Unfused: SDDMM into a materialized `w`, then atomic SpMM (the
    /// paper's pre-fusion variant, kept as the ablation baseline).
    Unfused,
}

impl Default for IterateKernel {
    fn default() -> Self {
        IterateKernel::Fused { precision: Precision::F64 }
    }
}

impl IterateKernel {
    /// Whether [`SparseSolver::solve_batch`] has a cross-query batched
    /// kernel for this variant (otherwise it falls back to a per-query
    /// loop — callers reporting batching metrics should check this).
    pub fn has_batched_path(self) -> bool {
        matches!(self, IterateKernel::Fused { .. })
    }

    /// Whether this kernel runs the f32 compute panels. Always false when
    /// the `mixed-precision` feature is off (the `Mixed` variant does not
    /// exist then), so callers can branch on it unconditionally.
    pub fn is_mixed(self) -> bool {
        #[cfg(feature = "mixed-precision")]
        {
            matches!(self, IterateKernel::Fused { precision: Precision::Mixed })
        }
        #[cfg(not(feature = "mixed-precision"))]
        {
            false
        }
    }

    /// Stable label for metrics/bench reporting (matches the `kernel=` /
    /// `precision=` config-key vocabulary).
    pub fn label(self) -> &'static str {
        match self {
            #[cfg(feature = "mixed-precision")]
            IterateKernel::Fused { precision: Precision::Mixed } => "fused-mixed",
            IterateKernel::Fused { .. } => "fused-f64",
            IterateKernel::Unfused => "unfused",
        }
    }
}

/// Solver configuration (paper defaults: `λ = −(−10)`… the Python code
/// passes `lamb` pre-negated; here `lambda` is the positive entropic
/// regularization strength and the kernel applies the minus sign).
#[derive(Clone, Copy, Debug)]
pub struct SinkhornConfig {
    /// Entropic regularization strength λ (> 0). Larger → closer to exact
    /// EMD, slower convergence.
    pub lambda: Real,
    /// Hard iteration cap (paper uses a fixed `max_iter`).
    pub max_iter: usize,
    /// Early-exit threshold on the **marginal-feasibility residual**
    /// `max_j ‖u_j ⊙ (K v_j) − r‖₁` — the textbook Sinkhorn stopping
    /// criterion. `0.0` disables the check and always runs `max_iter`
    /// iterations (paper behaviour).
    ///
    /// Why not "while x changes" or a WMD-delta: the iterate can sit on a
    /// *metastable plateau* (a query word exponentially far from a
    /// document's support climbs `u` for hundreds of iterations before
    /// its mass reroutes — the WMD looks converged, then jumps). The
    /// marginal residual sees exactly the undelivered mass during such a
    /// plateau, so it cannot stop early. It costs nothing extra:
    /// `(K v)_k = r_k · x_new_k`, both already in hand.
    pub tolerance: Real,
    /// Evaluate the convergence check every `check_every` iterations.
    pub check_every: usize,
    /// Active-set compaction trigger. With per-document freezing on
    /// (`tolerance > 0` and `compact_every > 0`), the solver rebuilds the
    /// iterate's traversal over the surviving columns once their nnz share
    /// drops below this fraction of the current traversal — and keeps
    /// re-compacting as the active set shrinks further. `0.0` freezes
    /// columns but never compacts the walk. Must lie in `[0, 1]`.
    pub compact_threshold: Real,
    /// Consider compaction every `compact_every`-th convergence check.
    /// `0` is the **exact-mode opt-out**: no per-document freezing and no
    /// compaction — the solver stops on the global max-residual criterion
    /// and is bitwise identical to the pre-compaction implementation.
    pub compact_every: usize,
    /// Iterate kernel choice.
    pub kernel: IterateKernel,
}

impl Default for SinkhornConfig {
    fn default() -> Self {
        Self {
            lambda: 10.0,
            max_iter: 64,
            tolerance: 1e-3,
            check_every: 4,
            compact_threshold: 0.75,
            compact_every: 1,
            kernel: IterateKernel::default(),
        }
    }
}

impl SinkhornConfig {
    /// Check the invariants the solver relies on, with an actionable
    /// message for config files. Rejects `check_every == 0` (it is the
    /// check-cadence divisor in `iterations % check_every`), `max_iter ==
    /// 0`, non-finite/negative `tolerance` and `lambda`, and a
    /// `compact_threshold` outside `[0, 1]`. `compact_every == 0` is
    /// *valid* — the exact-mode opt-out.
    pub fn validate(&self) -> Result<(), String> {
        if !(self.lambda > 0.0 && self.lambda.is_finite()) {
            return Err(format!(
                "sinkhorn.lambda must be positive and finite, got {}",
                self.lambda
            ));
        }
        if self.max_iter == 0 {
            return Err("sinkhorn.max_iter must be at least 1".into());
        }
        if !(self.tolerance >= 0.0 && self.tolerance.is_finite()) {
            return Err(format!(
                "sinkhorn.tolerance must be non-negative and finite, got {} \
                 (use 0 to disable the early exit)",
                self.tolerance
            ));
        }
        if self.check_every == 0 {
            return Err(
                "sinkhorn.check_every must be at least 1 (the convergence check runs \
                 every check_every iterations)"
                    .into(),
            );
        }
        if !(self.compact_threshold >= 0.0 && self.compact_threshold <= 1.0) {
            return Err(format!(
                "sinkhorn.compact_threshold must lie in [0, 1], got {} \
                 (0 freezes columns without compacting the traversal)",
                self.compact_threshold
            ));
        }
        Ok(())
    }

    /// Phase-1 preparation shared by every solver consuming `dist`
    /// factors (sparse and dense alike): select the query's non-zero
    /// words and run the fused precompute with this config's λ.
    pub fn prepare(&self, embeddings: &Dense, query: &SparseVec, pool: &Pool) -> Prepared {
        self.prepare_in(&mut SolveWorkspace::new(), embeddings, query, pool)
    }

    /// [`SinkhornConfig::prepare`] with the selection buffer and the
    /// dist-layer panel scratch borrowed from a retained workspace. The
    /// factor matrices themselves are still freshly allocated — they are
    /// the returned artifact (typically committed to the coordinator's
    /// prepared cache), not scratch.
    pub fn prepare_in(
        &self,
        ws: &mut SolveWorkspace,
        embeddings: &Dense,
        query: &SparseVec,
        pool: &Pool,
    ) -> Prepared {
        assert_eq!(embeddings.nrows(), query.dim, "embedding/vocab mismatch");
        // Take the selection buffer out so the rest of the dist scratch
        // can be borrowed mutably alongside it.
        let mut sel = std::mem::take(&mut ws.dist.sel);
        sel.clear();
        sel.extend(query.idx.iter().map(|&i| i as usize));
        let factors =
            precompute_factors_in(embeddings, &sel, &query.val, self.lambda, pool, &mut ws.dist);
        ws.dist.sel = sel;
        Prepared { factors }
    }
}

/// Precomputed per-query state: factors + the query's histogram.
/// (`Default` is an *empty* prepared slot — a reusable target for
/// [`QueryFactors::restrict_rows_into`], not a solvable query.)
#[derive(Clone, Debug, Default)]
pub struct Prepared {
    pub factors: QueryFactors,
}

impl Prepared {
    #[inline]
    pub fn v_r(&self) -> usize {
        self.factors.v_r()
    }
}

/// Power-of-two histogram of per-column iterations-to-freeze: bucket `b`
/// counts columns that froze in `[2^b, 2^(b+1))` iterations. Columns that
/// never froze are recorded at the solve's final iteration count, so the
/// histogram always describes every non-empty column.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FreezeHistogram {
    /// Columns recorded.
    pub count: u64,
    /// Fewest iterations any column took (`u32::MAX` while empty).
    pub min: u32,
    /// Most iterations any column took.
    pub max: u32,
    /// Power-of-two buckets; the last one is open-ended.
    pub buckets: [u64; 16],
}

impl Default for FreezeHistogram {
    fn default() -> Self {
        Self { count: 0, min: u32::MAX, max: 0, buckets: [0; 16] }
    }
}

impl FreezeHistogram {
    pub fn record(&mut self, iters: u32) {
        self.count += 1;
        self.min = self.min.min(iters);
        self.max = self.max.max(iters);
        let b = (31 - iters.max(1).leading_zeros()).min(15) as usize;
        self.buckets[b] += 1;
    }

    pub fn merge(&mut self, other: &FreezeHistogram) {
        self.count += other.count;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
    }

    /// Median iterations-to-freeze, as the upper bound of the bucket that
    /// crosses half the mass (clamped to the observed `[min, max]`).
    /// `None` while the histogram is empty.
    pub fn p50(&self) -> Option<u32> {
        if self.count == 0 {
            return None;
        }
        let target = (self.count + 1) / 2;
        let mut cum = 0u64;
        for (b, &k) in self.buckets.iter().enumerate() {
            cum += k;
            if cum >= target {
                let hi = if b >= 15 { u32::MAX } else { (1u32 << (b + 1)) - 1 };
                return Some(hi.clamp(self.min, self.max));
            }
        }
        Some(self.max)
    }
}

/// Per-solve convergence telemetry: what the freeze/compaction machinery
/// actually did. Attached to every [`SolveOutput`] and folded into the
/// coordinator's metrics. Under the exact-mode opt-out (`compact_every =
/// 0`) the freeze/compaction counters stay zero and `nnz_traversed ==
/// nnz_full` — the full pattern is walked every iteration.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ConvergenceStats {
    /// Columns whose per-document residual froze before the solve ended.
    pub frozen_columns: usize,
    /// Traversal compactions performed.
    pub compactions: usize,
    /// Pattern entries actually walked by the iterate, summed over
    /// iterations — the quantity compaction shrinks.
    pub nnz_traversed: u64,
    /// What the walk would have cost without compaction
    /// (`iterations × nnz`).
    pub nnz_full: u64,
    /// Per-column iterations-to-freeze distribution.
    pub freeze_iters: FreezeHistogram,
}

impl ConvergenceStats {
    /// Fold another solve's (or shard's) stats in: counters sum, the
    /// histogram merges.
    pub fn merge(&mut self, other: &ConvergenceStats) {
        self.frozen_columns += other.frozen_columns;
        self.compactions += other.compactions;
        self.nnz_traversed += other.nnz_traversed;
        self.nnz_full += other.nnz_full;
        self.freeze_iters.merge(&other.freeze_iters);
    }
}

/// Result of a one-to-many solve.
#[derive(Clone, Debug, Default)]
pub struct SolveOutput {
    /// `wmd[j]` = Sinkhorn distance from the query to target doc `j`.
    pub wmd: Vec<Real>,
    /// Iterations actually executed.
    pub iterations: usize,
    /// Whether the tolerance-based early exit fired.
    pub converged: bool,
    /// Per-document convergence telemetry for this solve.
    pub conv: ConvergenceStats,
}

impl SolveOutput {
    /// Index of the most similar target document. Non-finite distances
    /// (empty documents report `+inf`; a poisoned embedding can produce
    /// NaN) never win.
    pub fn argmin(&self) -> Option<usize> {
        self.wmd
            .iter()
            .enumerate()
            .filter(|(_, v)| v.is_finite())
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
    }

    /// Merge per-shard outputs back into one full-length output. Each
    /// part covers a contiguous column range of the full target set and
    /// is given as `(col_offset, output)`; together the parts must tile
    /// `0..total_docs` exactly (zero-column shards contribute an empty
    /// `wmd` and are fine).
    ///
    /// Merge semantics:
    /// * `wmd[col_offset + j] = part.wmd[j]` — `+inf` empty-document
    ///   entries land at their global indices untouched;
    /// * `iterations` is the **max** over shards (the wall-clock-relevant
    ///   count: shards iterate concurrently);
    /// * `converged` requires every shard to have converged.
    pub fn merge_shards(total_docs: usize, parts: &[(usize, SolveOutput)]) -> SolveOutput {
        let mut wmd = vec![Real::NAN; total_docs];
        let mut covered = 0usize;
        let mut iterations = 0usize;
        let mut converged = true;
        let mut conv = ConvergenceStats::default();
        for (offset, part) in parts {
            assert!(
                offset + part.wmd.len() <= total_docs,
                "shard slice {}..{} out of range for {} documents",
                offset,
                offset + part.wmd.len(),
                total_docs
            );
            wmd[*offset..offset + part.wmd.len()].copy_from_slice(&part.wmd);
            covered += part.wmd.len();
            iterations = iterations.max(part.iterations);
            converged &= part.converged;
            conv.merge(&part.conv);
        }
        assert_eq!(covered, total_docs, "shard slices must tile the target set exactly");
        SolveOutput { wmd, iterations, converged, conv }
    }

    /// Indices of the `k` most similar documents, ascending by distance.
    /// Non-finite distances are excluded (so fewer than `k` entries can
    /// come back); `total_cmp` keeps the comparison panic-free regardless.
    ///
    /// Uses a bounded selection — `select_nth_unstable` to isolate the `k`
    /// smallest, then a sort of just those — so the cost is `O(N + k·log
    /// k)` instead of the full `O(N·log N)` re-sort per call. Ties are
    /// broken by ascending document index, so the returned order is
    /// deterministic (and matches what the old stable full sort produced).
    pub fn top_k(&self, k: usize) -> Vec<(usize, Real)> {
        let mut pairs: Vec<(usize, Real)> =
            self.wmd.iter().copied().enumerate().filter(|(_, v)| v.is_finite()).collect();
        let cmp = |a: &(usize, Real), b: &(usize, Real)| {
            a.1.total_cmp(&b.1).then_with(|| a.0.cmp(&b.0))
        };
        if k == 0 {
            pairs.clear();
            return pairs;
        }
        if k < pairs.len() {
            let _ = pairs.select_nth_unstable_by(k - 1, cmp);
            pairs.truncate(k);
        }
        pairs.sort_unstable_by(cmp);
        pairs
    }
}

/// The sparse parallel solver.
#[derive(Clone, Debug)]
pub struct SparseSolver {
    config: SinkhornConfig,
}

impl SparseSolver {
    pub fn new(config: SinkhornConfig) -> Self {
        if let Err(msg) = config.validate() {
            panic!("invalid Sinkhorn config: {msg}");
        }
        Self { config }
    }

    pub fn config(&self) -> &SinkhornConfig {
        &self.config
    }

    /// Phase 1: select non-zero query words and precompute the factors.
    pub fn prepare(&self, embeddings: &Dense, query: &SparseVec, pool: &Pool) -> Prepared {
        self.config.prepare(embeddings, query, pool)
    }

    /// [`SparseSolver::prepare`] with scratch borrowed from a retained
    /// workspace (see [`SinkhornConfig::prepare_in`]).
    pub fn prepare_in(
        &self,
        ws: &mut SolveWorkspace,
        embeddings: &Dense,
        query: &SparseVec,
        pool: &Pool,
    ) -> Prepared {
        self.config.prepare_in(ws, embeddings, query, pool)
    }

    /// Phase 2: iterate to the WMD vector against all columns of `c`.
    ///
    /// **Empty documents** (target columns with no non-zeros) report
    /// `Real::INFINITY`: there is no transport plan to a document with no
    /// words. Without the guard a zero-support column leaves `x_row` all
    /// zeros, `update_u`'s renormalization divides by a zero mean and
    /// poisons `u` with NaN, while the epilogue sums nothing — the
    /// empty document would score `WMD = 0` and win every argmin.
    ///
    /// Thin allocating wrapper over [`SparseSolver::solve_in`] (a fresh
    /// workspace per call — fine for tests and one-shot use; serving
    /// threads retain one and call `solve_in`).
    pub fn solve(&self, prep: &Prepared, c: &Csr, pool: &Pool) -> SolveOutput {
        self.solve_in(&mut SolveWorkspace::new(), prep, c, pool)
    }

    /// [`SparseSolver::solve`] with every piece of per-solve scratch —
    /// iterate planes, masks, partitions, kernel scratch, f32 panel lanes
    /// in mixed mode — borrowed from `ws` instead of heap-allocated. Once
    /// the workspace is warm, the only remaining allocations are the
    /// returned `wmd` vector (its ownership moves to the caller) and, on
    /// multi-threaded pools, the convergence reduction's per-thread
    /// cells. Numerically identical to `solve`: every borrowed buffer is
    /// re-shaped and re-filled at checkout, so dirty contents cannot leak
    /// (pinned bitwise by `tests/workspace_test.rs`).
    pub fn solve_in(
        &self,
        ws: &mut SolveWorkspace,
        prep: &Prepared,
        c: &Csr,
        pool: &Pool,
    ) -> SolveOutput {
        assert_eq!(c.nrows(), prep.factors.vocab_size(), "c/vocabulary mismatch");
        let mixed = self.config.kernel.is_mixed();
        let bytes_before = ws.begin_checkout();
        ws.ensure_lanes(1);
        if mixed {
            ws.ensure_lo_lanes(1);
        }
        let v_r = prep.v_r();
        let n = c.ncols();
        let f = &prep.factors;
        let out = {
            // Split the workspace into its disjoint scratch sections.
            let SolveWorkspace {
                x_t,
                x_new,
                u_t,
                empty,
                parts,
                col_parts,
                pattern,
                w_buf,
                fused,
                kt_lo,
                kor_lo,
                u_lo,
                frozen,
                resid,
                freeze_iter,
                active_cols,
                act_ptr,
                act_parts,
                ..
            } = &mut *ws;
            empty_columns_into(c, empty);
            // The transposed pattern drives both the fused iterate and the
            // (always-fused) WMD epilogue, so every kernel builds it.
            pattern.rebuild_from(c);
            pattern.column_parts_into(pool.nthreads(), col_parts);

            // x = ones(v_r, N) / v_r, stored transposed (N × v_r); u = 1/x.
            let x_t = &mut x_t[0];
            let x_new = &mut x_new[0];
            let u_t = &mut u_t[0];
            x_t.reset(n, v_r, 1.0 / v_r as Real);
            x_new.reset(n, v_r, 0.0);
            u_t.reset(n, v_r, v_r as Real);
            let mut w_slot: Option<&mut Vec<Real>> = match self.config.kernel {
                IterateKernel::Unfused => {
                    balanced_nnz_partition_into(c.row_ptr(), pool.nthreads(), parts);
                    w_buf.clear();
                    w_buf.resize(c.nnz(), 0.0);
                    Some(w_buf)
                }
                IterateKernel::Fused { .. } => None,
            };
            if mixed {
                // Narrow the stationary factor panels once per solve; the
                // f32 u mirror starts at the same 1/x as the f64 master
                // and is refreshed inside update_u.
                kt_lo[0].reset_from(&f.kt, pool);
                kor_lo[0].reset_from(&f.kor_t, pool);
                u_lo[0].reset(n, v_r, v_r as f32);
            }

            // Per-document convergence state. `freezing` is the default
            // mode (tolerance-based early exit with per-column freezing);
            // `compact_every = 0` opts back into the exact global
            // criterion, bitwise identical to the pre-compaction solver.
            let freezing = self.config.tolerance > 0.0 && self.config.compact_every > 0;
            let can_compact = freezing
                && self.config.compact_threshold > 0.0
                && matches!(self.config.kernel, IterateKernel::Fused { .. });
            frozen.clear();
            frozen.resize(n, false);
            resid.clear();
            resid.resize(n, 0.0);
            freeze_iter.clear();
            freeze_iter.resize(n, 0);
            let full_nnz = c.nnz();
            let mut active_cols_count = empty.iter().filter(|&&e| !e).count();
            let mut active_nnz = full_nnz;
            let mut traversal_nnz = full_nnz;
            let mut compacted = false;
            let mut checks_done = 0usize;
            let mut conv = ConvergenceStats::default();

            let mut iterations = 0;
            let mut converged = false;
            while iterations < self.config.max_iter {
                let view = if freezing {
                    ActiveView {
                        cols: if compacted { Some((&active_cols[..], &act_ptr[..])) } else { None },
                        frozen: Some(&frozen[..]),
                    }
                } else {
                    ActiveView::full()
                };
                let iter_parts: &[NnzRange] = if compacted { act_parts } else { col_parts };
                match self.config.kernel {
                    IterateKernel::Fused { .. } => {
                        if mixed {
                            sddtmm_dstmmt_batch(
                                c,
                                &*pattern,
                                &[&kt_lo[0]],
                                &[&kor_lo[0]],
                                &u_lo[..1],
                                std::slice::from_mut(x_new),
                                &[true],
                                view,
                                pool,
                                iter_parts,
                                fused,
                            );
                        } else {
                            sddtmm_dstmmt_batch(
                                c,
                                &*pattern,
                                &[&f.kt],
                                &[&f.kor_t],
                                std::slice::from_ref(&*u_t),
                                std::slice::from_mut(x_new),
                                &[true],
                                view,
                                pool,
                                iter_parts,
                                fused,
                            );
                        }
                    }
                    IterateKernel::Unfused => {
                        // The unfused baseline never compacts (it walks the
                        // row-major pattern); freezing still pins u rows.
                        let w = w_slot.as_deref_mut().expect("w buffer");
                        sddmm(c, &f.kt, u_t, w, pool, parts);
                        spmm_atomic(c, &w[..], &f.kor_t, x_new, pool, parts);
                    }
                }
                conv.nnz_traversed += traversal_nnz as u64;
                conv.nnz_full += full_nnz as u64;
                iterations += 1;
                let check = self.config.tolerance > 0.0
                    && (iterations % self.config.check_every == 0
                        || iterations == self.config.max_iter);
                // One fused pass: marginal residual (needs the OLD u against
                // the RAW new x) + per-column renormalization + u update.
                update_u(
                    x_new,
                    u_t,
                    &f.r,
                    empty,
                    if freezing { Some(&frozen[..]) } else { None },
                    check,
                    resid,
                    pool,
                    if mixed { Some(&mut u_lo[0]) } else { None },
                );
                std::mem::swap(x_t, x_new);
                if !check {
                    continue;
                }
                if freezing {
                    // Freeze every column whose own marginal residual just
                    // dropped below tolerance: its u row stays pinned from
                    // here on (update_u skipped it next iteration onward).
                    for j in 0..n {
                        if !empty[j] && !frozen[j] && resid[j] <= self.config.tolerance {
                            frozen[j] = true;
                            freeze_iter[j] = iterations as u32;
                            active_cols_count -= 1;
                            active_nnz -= pattern.col_ptr[j + 1] - pattern.col_ptr[j];
                        }
                    }
                    if active_cols_count == 0 {
                        converged = true;
                        break;
                    }
                    checks_done += 1;
                    if can_compact
                        && checks_done % self.config.compact_every == 0
                        && (active_nnz as Real)
                            < self.config.compact_threshold * traversal_nnz as Real
                    {
                        // Compact the traversal to the surviving columns:
                        // subset prefix + nnz-balanced partition over it,
                        // all into retained workspace buffers.
                        active_cols.clear();
                        active_cols
                            .extend((0..n).filter(|&j| !empty[j] && !frozen[j]).map(|j| j as u32));
                        subset_nnz_prefix_into(&pattern.col_ptr, active_cols, act_ptr);
                        balanced_nnz_partition_into(act_ptr, pool.nthreads(), act_parts);
                        compacted = true;
                        traversal_nnz = active_nnz;
                        conv.compactions += 1;
                    }
                } else {
                    // Exact mode: the global max-residual criterion. Max of
                    // f64 is order-independent, so folding the per-column
                    // lanes serially reproduces the old parallel reduction
                    // bitwise.
                    let worst = resid.iter().fold(0.0f64, |w, &r| if r > w { r } else { w });
                    if worst <= self.config.tolerance {
                        converged = true;
                        break;
                    }
                }
            }
            conv.frozen_columns = frozen.iter().filter(|&&fz| fz).count();
            for j in 0..n {
                if !empty[j] {
                    let it =
                        if freeze_iter[j] > 0 { freeze_iter[j] } else { iterations as u32 };
                    conv.freeze_iters.record(it);
                }
            }

            // Epilogue: u is already 1/x for the final x; one more fused
            // pass over the pattern folds v and the (K⊙M) reduction
            // together. Always f64 — in mixed mode the final reduction
            // runs against the f64 u master, not the f32 mirror.
            let mut wmd = vec![0.0; n];
            sddtmm_wmd_batch(
                c,
                &*pattern,
                &[&f.kt],
                &[&f.km_t],
                std::slice::from_ref(&*u_t),
                std::slice::from_mut(&mut wmd),
                pool,
                col_parts,
            );
            for (w, &e) in wmd.iter_mut().zip(empty.iter()) {
                if e {
                    *w = Real::INFINITY;
                }
            }
            SolveOutput { wmd, iterations, converged, conv }
        };
        ws.end_checkout(bytes_before);
        out
    }

    /// Cross-query batched solve: `B` prepared queries against the same
    /// target matrix, iterated in **one fused pass over the transposed
    /// pattern per Sinkhorn step** — each pattern entry updates every
    /// active query's state before the traversal moves on, amortizing the
    /// column walk and its cache misses across the batch (the
    /// coordinator's dispatch path).
    ///
    /// Per-query convergence masks let early-converging queries drop out
    /// of the iterate without stalling the rest; each query's output
    /// (`wmd`, `iterations`, `converged`) matches what the per-query
    /// [`SparseSolver::solve`] would have produced — bitwise, at any
    /// thread count, for the fused f64 kernel (column-owned accumulation
    /// is order-deterministic).
    ///
    /// Kernels without a batched variant ([`IterateKernel::Unfused`], the
    /// ablation baseline) fall back to a per-query loop.
    /// Thin allocating wrapper over [`SparseSolver::solve_batch_in`].
    pub fn solve_batch(&self, preps: &[&Prepared], c: &Csr, pool: &Pool) -> Vec<SolveOutput> {
        self.solve_batch_in(&mut SolveWorkspace::new(), preps, c, pool)
    }

    /// Solve a batch against a **segmented** target set: `segments` are
    /// `(col_start, slice)` pairs that must tile `0..total_docs` (the
    /// live store's base + delta layout). Each segment is an independent
    /// Sinkhorn problem — target columns never interact — so solving the
    /// segments separately and merging by column offset
    /// ([`SolveOutput::merge_shards`]) is bitwise identical to solving the
    /// equivalent monolithic CSR; a single full-range segment takes the
    /// monolithic path outright (same code path, same bits).
    pub fn solve_segments_in(
        &self,
        ws: &mut SolveWorkspace,
        preps: &[&Prepared],
        segments: &[(usize, &Csr)],
        total_docs: usize,
        pool: &Pool,
    ) -> Vec<SolveOutput> {
        if let [(0, c)] = segments {
            debug_assert_eq!(c.ncols(), total_docs);
            return self.solve_batch_in(ws, preps, c, pool);
        }
        let b = preps.len();
        let mut parts: Vec<Vec<(usize, SolveOutput)>> = (0..b).map(|_| Vec::new()).collect();
        for &(start, c) in segments {
            if c.ncols() == 0 {
                continue;
            }
            let outs = self.solve_batch_in(ws, preps, c, pool);
            for (q, out) in outs.into_iter().enumerate() {
                parts[q].push((start, out));
            }
        }
        parts
            .into_iter()
            .map(|p| SolveOutput::merge_shards(total_docs, &p))
            .collect()
    }

    /// [`SparseSolver::solve_batch`] with all per-batch scratch — one
    /// iterate-plane lane per query, shared masks/partitions/pattern,
    /// kernel scratch — borrowed from `ws`. Once warm, nothing
    /// problem-sized is allocated: what remains is the returned per-query
    /// `wmd` vectors, `O(B)` factor-pointer vectors per call, and the
    /// per-check residual reduction's `O(B)` bookkeeping.
    pub fn solve_batch_in(
        &self,
        ws: &mut SolveWorkspace,
        preps: &[&Prepared],
        c: &Csr,
        pool: &Pool,
    ) -> Vec<SolveOutput> {
        if !self.config.kernel.has_batched_path() {
            return preps.iter().map(|&p| self.solve_in(ws, p, c, pool)).collect();
        }
        let b = preps.len();
        if b == 0 {
            return Vec::new();
        }
        for p in preps {
            assert_eq!(c.nrows(), p.factors.vocab_size(), "c/vocabulary mismatch");
        }
        let mixed = self.config.kernel.is_mixed();
        let bytes_before = ws.begin_checkout();
        ws.ensure_lanes(b);
        if mixed {
            ws.ensure_lo_lanes(b);
        }
        let n = c.ncols();
        let out = {
            let SolveWorkspace {
                x_t,
                x_new,
                u_t,
                empty,
                col_parts,
                pattern,
                fused,
                iterations,
                converged,
                active,
                kt_lo,
                kor_lo,
                u_lo,
                frozen,
                resid,
                freeze_iter,
                active_cols,
                act_ptr,
                act_parts,
                ..
            } = &mut *ws;
            empty_columns_into(c, empty);
            // The pattern (and its column partition) is shared by the whole
            // batch — built once, another cross-query amortization.
            pattern.rebuild_from(c);
            pattern.column_parts_into(pool.nthreads(), col_parts);
            let kts: Vec<&Dense> = preps.iter().map(|p| &p.factors.kt).collect();
            let kor_ts: Vec<&Dense> = preps.iter().map(|p| &p.factors.kor_t).collect();
            let km_ts: Vec<&Dense> = preps.iter().map(|p| &p.factors.km_t).collect();
            let rs: Vec<&[Real]> = preps.iter().map(|p| p.factors.r.as_slice()).collect();

            let x_t = &mut x_t[..b];
            let x_new = &mut x_new[..b];
            let u_t = &mut u_t[..b];
            for (q, p) in preps.iter().enumerate() {
                x_t[q].reset(n, p.v_r(), 1.0 / p.v_r() as Real);
                x_new[q].reset(n, p.v_r(), 0.0);
                u_t[q].reset(n, p.v_r(), p.v_r() as Real);
            }
            if mixed {
                for (q, p) in preps.iter().enumerate() {
                    kt_lo[q].reset_from(&p.factors.kt, pool);
                    kor_lo[q].reset_from(&p.factors.kor_t, pool);
                    u_lo[q].reset(n, p.v_r(), p.v_r() as f32);
                }
            }
            let kt_lo_refs: Vec<&Panel32> =
                if mixed { kt_lo[..b].iter().collect() } else { Vec::new() };
            let kor_lo_refs: Vec<&Panel32> =
                if mixed { kor_lo[..b].iter().collect() } else { Vec::new() };
            iterations.clear();
            iterations.resize(b, 0usize);
            converged.clear();
            converged.resize(b, false);
            active.clear();
            active.resize(b, true);

            // Per-(query, column) convergence state, flat B × N. The
            // compacted column list is the *union* of the active queries'
            // survivors, so it always covers every unfrozen (q, j) — the
            // per-query masks do the fine-grained skipping.
            let freezing = self.config.tolerance > 0.0 && self.config.compact_every > 0;
            let can_compact = freezing && self.config.compact_threshold > 0.0;
            frozen.clear();
            frozen.resize(b * n, false);
            resid.clear();
            resid.resize(b * n, 0.0);
            freeze_iter.clear();
            freeze_iter.resize(b * n, 0);
            let full_nnz = c.nnz();
            let n_nonempty = empty.iter().filter(|&&e| !e).count();
            let mut remaining: Vec<usize> = vec![n_nonempty; b];
            let mut convs: Vec<ConvergenceStats> = vec![ConvergenceStats::default(); b];
            let mut traversal_nnz = full_nnz;
            let mut compacted = false;
            let mut checks_done = 0usize;

            let mut iter = 0;
            while iter < self.config.max_iter && active.iter().any(|&a| a) {
                let view = if freezing {
                    ActiveView {
                        cols: if compacted { Some((&active_cols[..], &act_ptr[..])) } else { None },
                        frozen: Some(&frozen[..]),
                    }
                } else {
                    ActiveView::full()
                };
                let iter_parts: &[NnzRange] = if compacted { act_parts } else { col_parts };
                // The u lanes pass straight through as slices — no
                // per-iteration reference-vector rebuild.
                if mixed {
                    sddtmm_dstmmt_batch(
                        c, &*pattern, &kt_lo_refs, &kor_lo_refs, &u_lo[..b], x_new, active, view,
                        pool, iter_parts, fused,
                    );
                } else {
                    sddtmm_dstmmt_batch(
                        c, &*pattern, &kts, &kor_ts, &*u_t, x_new, active, view, pool, iter_parts,
                        fused,
                    );
                }
                for q in 0..b {
                    if active[q] {
                        convs[q].nnz_traversed += traversal_nnz as u64;
                        convs[q].nnz_full += full_nnz as u64;
                    }
                }
                iter += 1;
                let check = self.config.tolerance > 0.0
                    && (iter % self.config.check_every == 0 || iter == self.config.max_iter);
                update_u_batch(
                    x_new,
                    u_t,
                    &rs,
                    empty,
                    active,
                    if freezing { Some(&frozen[..]) } else { None },
                    check,
                    resid,
                    pool,
                    if mixed { Some(&mut u_lo[..b]) } else { None },
                );
                for q in 0..b {
                    if !active[q] {
                        continue;
                    }
                    iterations[q] = iter;
                    std::mem::swap(&mut x_t[q], &mut x_new[q]);
                }
                if !check {
                    continue;
                }
                if freezing {
                    // Per-column freezing, independently per query — the
                    // same decisions a single-query solve of q would make,
                    // so batch results stay bitwise equal to singles.
                    for q in 0..b {
                        if !active[q] {
                            continue;
                        }
                        for j in 0..n {
                            let qj = q * n + j;
                            if !empty[j] && !frozen[qj] && resid[qj] <= self.config.tolerance {
                                frozen[qj] = true;
                                freeze_iter[qj] = iter as u32;
                                remaining[q] -= 1;
                            }
                        }
                        if remaining[q] == 0 {
                            converged[q] = true;
                            active[q] = false;
                        }
                    }
                    checks_done += 1;
                    if can_compact
                        && checks_done % self.config.compact_every == 0
                        && active.iter().any(|&a| a)
                    {
                        let col_alive = |j: usize| {
                            !empty[j] && (0..b).any(|q| active[q] && !frozen[q * n + j])
                        };
                        let union_nnz: usize = (0..n)
                            .filter(|&j| col_alive(j))
                            .map(|j| pattern.col_ptr[j + 1] - pattern.col_ptr[j])
                            .sum();
                        if (union_nnz as Real)
                            < self.config.compact_threshold * traversal_nnz as Real
                        {
                            active_cols.clear();
                            active_cols.extend((0..n).filter(|&j| col_alive(j)).map(|j| j as u32));
                            subset_nnz_prefix_into(&pattern.col_ptr, active_cols, act_ptr);
                            balanced_nnz_partition_into(act_ptr, pool.nthreads(), act_parts);
                            compacted = true;
                            traversal_nnz = union_nnz;
                            for cq in convs.iter_mut().zip(&*active) {
                                if *cq.1 {
                                    cq.0.compactions += 1;
                                }
                            }
                        }
                    }
                } else {
                    for q in 0..b {
                        if !active[q] {
                            continue;
                        }
                        let lane = &resid[q * n..(q + 1) * n];
                        let worst = lane.iter().fold(0.0f64, |w, &r| if r > w { r } else { w });
                        if worst <= self.config.tolerance {
                            converged[q] = true;
                            active[q] = false;
                        }
                    }
                }
            }
            for q in 0..b {
                convs[q].frozen_columns =
                    frozen[q * n..(q + 1) * n].iter().filter(|&&fz| fz).count();
                for j in 0..n {
                    if !empty[j] {
                        let fi = freeze_iter[q * n + j];
                        let it = if fi > 0 { fi } else { iterations[q] as u32 };
                        convs[q].freeze_iters.record(it);
                    }
                }
            }

            // Batched epilogue: every query's final u (frozen at its own
            // convergence point) feeds one shared fused WMD pass — always
            // f64, against the f64 u masters.
            let mut wmds: Vec<Vec<Real>> = (0..b).map(|_| vec![0.0; n]).collect();
            sddtmm_wmd_batch(c, &*pattern, &kts, &km_ts, &*u_t, &mut wmds, pool, col_parts);
            wmds.into_iter()
                .enumerate()
                .map(|(q, mut wmd)| {
                    for (w, &e) in wmd.iter_mut().zip(empty.iter()) {
                        if e {
                            *w = Real::INFINITY;
                        }
                    }
                    SolveOutput {
                        wmd,
                        iterations: iterations[q],
                        converged: converged[q],
                        conv: convs[q],
                    }
                })
                .collect::<Vec<SolveOutput>>()
        };
        ws.end_checkout(bytes_before);
        out
    }

    /// One-shot convenience: prepare + solve.
    pub fn wmd_one_to_many(
        &self,
        embeddings: &Dense,
        query: &SparseVec,
        c: &Csr,
        pool: &Pool,
    ) -> SolveOutput {
        let prep = self.prepare(embeddings, query, pool);
        self.solve(&prep, c, pool)
    }
}

/// Parallel pass over the new iterate, fused like the paper's
/// `update_x_u` but with two additions:
///
/// * **per-column renormalization** — the Sinkhorn iterate map is
///   1-homogeneous per target column (fixed points are rays), so the raw
///   iterate drifts in scale and would overflow over long runs. The WMD
///   is invariant under per-column scaling of `x` (it cancels between
///   `u` and `v`), so each column is rescaled to mean 1.
/// * **marginal residual** — with the *old* `u` (which produced this
///   `x_new`), the plan's row marginal is `u_k · (K v)_k = u_k·r_k·x_k`;
///   the per-document L1 violation `Σ_k |u_k r_k x_k − r_k|` is the
///   convergence criterion. Computed before `u` is overwritten, in the
///   same traversal, only when `check` is set.
///
/// `x_t` is `N × v_r` (transposed), so a *column* of `x` is a *row* here.
///
/// Rows flagged in `empty` (zero-support target columns) are skipped
/// entirely: their iterate row is all zeros, so the mean-1 renormalization
/// would divide by zero and poison `u` with NaN/inf, and their residual
/// (undeliverable mass, constant 1) would block convergence forever. The
/// solve reports those documents as `+inf` in the epilogue instead.
///
/// Rows flagged in `frozen` (per-document convergence, when given) are
/// skipped the same way: their `u` row keeps the value pinned at the check
/// that froze them, which is what the WMD epilogue reads.
///
/// When `u_lo` is given (mixed precision), the freshly written f64 `u`
/// row is also narrowed into the f32 mirror in the same pass — the next
/// iterate reads the mirror, every other consumer reads the f64 master.
/// Mirror rows of empty (or frozen) documents stay stale, matching the
/// skipped f64 rows; the kernels never read them.
///
/// When `check` is set, each processed row's marginal residual is written
/// to its `resid` slot (skipped rows keep their previous value; the caller
/// only inspects unfrozen non-empty slots, or relies on the solve-entry
/// zero fill).
#[allow(clippy::too_many_arguments)]
fn update_u(
    x_new: &mut Dense,
    u_t: &mut Dense,
    r: &[Real],
    empty: &[bool],
    frozen: Option<&[bool]>,
    check: bool,
    resid: &mut [Real],
    pool: &Pool,
    u_lo: Option<&mut Panel32>,
) {
    let n = x_new.nrows();
    let vr = x_new.ncols();
    debug_assert_eq!(r.len(), vr);
    debug_assert_eq!(empty.len(), n);
    debug_assert_eq!(resid.len(), n);
    if let Some(fz) = frozen {
        debug_assert_eq!(fz.len(), n);
    }
    let x_view = SharedSlice::new(x_new.as_mut_slice());
    let u_view = SharedSlice::new(u_t.as_mut_slice());
    let resid_view = SharedSlice::new(resid);
    let u_lo_view: Option<SharedSlice<f32>> = u_lo.map(|p| {
        debug_assert_eq!(p.nrows(), n);
        debug_assert_eq!(p.ncols(), vr);
        SharedSlice::new(p.as_mut_slice())
    });
    pool.parallel_for(n, |rows| {
        for j in rows {
            if empty[j] || frozen.map_or(false, |fz| fz[j]) {
                continue;
            }
            // SAFETY: row j (and resid slot j) is owned by exactly one
            // thread — parallel_for hands out disjoint row ranges.
            let x_row = unsafe { x_view.slice_mut(j * vr, vr) };
            let u_row = unsafe { u_view.slice_mut(j * vr, vr) };
            if check {
                let mut res = 0.0;
                for k in 0..vr {
                    res += (u_row[k] * r[k] * x_row[k] - r[k]).abs();
                }
                unsafe { resid_view.slice_mut(j, 1)[0] = res };
            }
            let mean: Real = x_row.iter().sum::<Real>() / vr as Real;
            let inv_mean = 1.0 / mean;
            for k in 0..vr {
                let xn = x_row[k] * inv_mean;
                x_row[k] = xn;
                u_row[k] = 1.0 / xn;
            }
            if let Some(v) = &u_lo_view {
                // SAFETY: row j of the mirror is owned by this thread.
                let lo = unsafe { v.slice_mut(j * vr, vr) };
                for k in 0..vr {
                    lo[k] = u_row[k] as f32;
                }
            }
        }
    });
}

/// Batched [`update_u`]: one parallel region renormalizes every active
/// query's iterate and writes per-(query, column) residuals into the flat
/// `B × N` `resid` lanes, instead of `B` fork/join barriers per Sinkhorn
/// step. Row-wise arithmetic is identical to the single-query pass, so
/// the batched update is bitwise equivalent per query; since the per-row
/// residual is now a plain owned write (no cross-thread max), no per-check
/// reduction state is allocated at all. `frozen` is the flat `B × N`
/// per-document mask ([`update_u`] semantics per lane); `u_los` mirrors
/// [`update_u`]'s `u_lo` per lane (mixed precision only).
#[allow(clippy::too_many_arguments)]
fn update_u_batch(
    x_new: &mut [Dense],
    u_t: &mut [Dense],
    rs: &[&[Real]],
    empty: &[bool],
    active: &[bool],
    frozen: Option<&[bool]>,
    check: bool,
    resid: &mut [Real],
    pool: &Pool,
    u_los: Option<&mut [Panel32]>,
) {
    let b = x_new.len();
    debug_assert_eq!(u_t.len(), b);
    debug_assert_eq!(rs.len(), b);
    debug_assert_eq!(active.len(), b);
    if b == 0 {
        return;
    }
    let n = x_new[0].nrows();
    debug_assert_eq!(empty.len(), n);
    debug_assert_eq!(resid.len(), b * n);
    if let Some(fz) = frozen {
        debug_assert_eq!(fz.len(), b * n);
    }
    let vrs: Vec<usize> = x_new.iter().map(|x| x.ncols()).collect();
    let x_views: Vec<SharedSlice<Real>> =
        x_new.iter_mut().map(|x| SharedSlice::new(x.as_mut_slice())).collect();
    let u_views: Vec<SharedSlice<Real>> =
        u_t.iter_mut().map(|u| SharedSlice::new(u.as_mut_slice())).collect();
    let resid_view = SharedSlice::new(resid);
    let u_lo_views: Option<Vec<SharedSlice<f32>>> = u_los.map(|ps| {
        debug_assert_eq!(ps.len(), b);
        ps.iter_mut().map(|p| SharedSlice::new(p.as_mut_slice())).collect()
    });
    pool.parallel_for(n, |rows| {
        for j in rows {
            if empty[j] {
                continue;
            }
            for q in 0..b {
                if !active[q] || frozen.map_or(false, |fz| fz[q * n + j]) {
                    continue;
                }
                let vr = vrs[q];
                // SAFETY: row j of query q (and resid slot q·n + j) is
                // owned by exactly one thread.
                let x_row = unsafe { x_views[q].slice_mut(j * vr, vr) };
                let u_row = unsafe { u_views[q].slice_mut(j * vr, vr) };
                let r = rs[q];
                if check {
                    let mut res = 0.0;
                    for k in 0..vr {
                        res += (u_row[k] * r[k] * x_row[k] - r[k]).abs();
                    }
                    unsafe { resid_view.slice_mut(q * n + j, 1)[0] = res };
                }
                let mean: Real = x_row.iter().sum::<Real>() / vr as Real;
                let inv_mean = 1.0 / mean;
                for k in 0..vr {
                    let xn = x_row[k] * inv_mean;
                    x_row[k] = xn;
                    u_row[k] = 1.0 / xn;
                }
                if let Some(vs) = &u_lo_views {
                    // SAFETY: row j of mirror q is owned by this thread.
                    let lo = unsafe { vs[q].slice_mut(j * vr, vr) };
                    for k in 0..vr {
                        lo[k] = u_row[k] as f32;
                    }
                }
            }
        }
    });
}

/// `empty[j]` ⇔ target column `j` has no non-zeros (an empty document),
/// written into a caller-owned (workspace) buffer. Shared with the dense
/// baseline so both in-process backends report the same `WMD = +inf` for
/// empty documents.
pub(crate) fn empty_columns_into(c: &Csr, empty: &mut Vec<bool>) {
    empty.clear();
    empty.resize(c.ncols(), true);
    for &j in c.col_idx() {
        empty[j as usize] = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::SyntheticCorpus;

    fn toy() -> SyntheticCorpus {
        SyntheticCorpus::builder()
            .vocab_size(500)
            .num_docs(40)
            .embedding_dim(16)
            .n_topics(4)
            .num_queries(3)
            .query_words(5, 12)
            .seed(17)
            .build()
    }

    /// Every kernel the build can run (mixed only with its feature on).
    fn all_kernels() -> Vec<IterateKernel> {
        let mut ks = vec![
            IterateKernel::Fused { precision: Precision::F64 },
            IterateKernel::Unfused,
        ];
        #[cfg(feature = "mixed-precision")]
        ks.push(IterateKernel::Fused { precision: Precision::Mixed });
        ks
    }

    #[test]
    fn solve_segments_matches_monolithic_bitwise() {
        let corpus = toy();
        let pool = Pool::new(1);
        let solver = SparseSolver::new(SinkhornConfig {
            tolerance: 0.0,
            max_iter: 12,
            ..Default::default()
        });
        let preps: Vec<Prepared> = corpus
            .queries
            .iter()
            .map(|q| solver.prepare(&corpus.embeddings, q, &pool))
            .collect();
        let refs: Vec<&Prepared> = preps.iter().collect();
        let mono = solver.solve_batch_in(&mut SolveWorkspace::new(), &refs, &corpus.c, &pool);
        let n = corpus.c.ncols();
        for cuts in [vec![0, n], vec![0, 13, n], vec![0, 1, 13, 14, n]] {
            let slices: Vec<(usize, Csr)> = cuts
                .windows(2)
                .map(|w| (w[0], corpus.c.slice_columns(w[0]..w[1])))
                .collect();
            let segs: Vec<(usize, &Csr)> = slices.iter().map(|(s, c)| (*s, c)).collect();
            let seg_outs = solver.solve_segments_in(
                &mut SolveWorkspace::new(),
                &refs,
                &segs,
                n,
                &pool,
            );
            for (q, (a, b)) in mono.iter().zip(&seg_outs).enumerate() {
                assert_eq!(a.wmd, b.wmd, "query {q}, cuts {cuts:?}");
                assert_eq!(a.iterations, b.iterations, "query {q}, cuts {cuts:?}");
            }
        }
    }

    #[test]
    fn all_kernels_agree() {
        let corpus = toy();
        let pool = Pool::new(4);
        let mut outs = Vec::new();
        let kernels = all_kernels();
        for &kernel in &kernels {
            let solver = SparseSolver::new(SinkhornConfig {
                kernel,
                tolerance: 0.0,
                max_iter: 20,
                ..Default::default()
            });
            outs.push(solver.wmd_one_to_many(&corpus.embeddings, corpus.query(0), &corpus.c, &pool));
        }
        for (kernel, o) in kernels.iter().zip(&outs).skip(1) {
            // Mixed precision is error-gated, not exact; f64 kernels agree
            // to rounding.
            let tol = if kernel.is_mixed() { 1e-6 } else { 1e-9 };
            for (a, b) in o.wmd.iter().zip(&outs[0].wmd) {
                assert!((a - b).abs() < tol * (1.0 + b.abs()), "{kernel:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn thread_count_does_not_change_result() {
        let corpus = toy();
        let solver = SparseSolver::new(SinkhornConfig { tolerance: 0.0, max_iter: 15, ..Default::default() });
        let base = {
            let pool = Pool::new(1);
            solver.wmd_one_to_many(&corpus.embeddings, corpus.query(1), &corpus.c, &pool)
        };
        for p in [2usize, 5, 8] {
            let pool = Pool::new(p);
            let out = solver.wmd_one_to_many(&corpus.embeddings, corpus.query(1), &corpus.c, &pool);
            // The default (fused f64) kernel accumulates each column in
            // ascending source-row order at any thread count → bitwise.
            assert_eq!(out.wmd, base.wmd, "p={p}");
        }
    }

    #[test]
    fn converges_with_tolerance() {
        // Sinkhorn's contraction constant degrades as λ grows (Cuturi's
        // accuracy/speed trade-off): at λ=3 the marginal residual reaches
        // 1e-5 in a few thousand iterations (measured); larger λ values
        // take proportionally longer.
        let corpus = toy();
        let pool = Pool::new(4);
        let solver = SparseSolver::new(SinkhornConfig {
            lambda: 3.0,
            tolerance: 1e-5,
            max_iter: 5000,
            ..Default::default()
        });
        let out = solver.wmd_one_to_many(&corpus.embeddings, corpus.query(0), &corpus.c, &pool);
        assert!(out.converged, "did not converge in 5000 iterations");
        assert!(out.iterations < 5000);
        assert!(out.wmd.iter().all(|v| v.is_finite() && *v >= 0.0));
    }

    #[test]
    fn same_topic_docs_are_closer() {
        let corpus = toy();
        let pool = Pool::new(4);
        let solver = SparseSolver::new(SinkhornConfig::default());
        for (qi, &qt) in corpus.query_topics.iter().enumerate() {
            let out = solver.wmd_one_to_many(&corpus.embeddings, corpus.query(qi), &corpus.c, &pool);
            // Mean WMD to same-topic docs < mean WMD to other-topic docs.
            let (mut same, mut ns, mut other, mut no) = (0.0, 0usize, 0.0, 0usize);
            for (j, &dt) in corpus.doc_topics.iter().enumerate() {
                if dt == qt {
                    same += out.wmd[j];
                    ns += 1;
                } else {
                    other += out.wmd[j];
                    no += 1;
                }
            }
            if ns > 0 && no > 0 {
                assert!(
                    same / ns as f64 <= other / no as f64,
                    "query {qi}: same-topic mean not smaller"
                );
            }
        }
    }

    #[test]
    fn top_k_sorted_and_argmin_consistent() {
        let corpus = toy();
        let pool = Pool::new(2);
        let solver = SparseSolver::new(SinkhornConfig::default());
        let out = solver.wmd_one_to_many(&corpus.embeddings, corpus.query(2), &corpus.c, &pool);
        let top = out.top_k(5);
        assert_eq!(top.len(), 5);
        for w in top.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        assert_eq!(out.argmin(), Some(top[0].0));
    }

    /// `c` with target column `k` emptied (an empty document).
    fn drop_column(c: &Csr, k: usize) -> Csr {
        let mut coo = crate::sparse::Coo::new(c.nrows(), c.ncols());
        for (i, j, v) in c.iter() {
            if j != k {
                coo.push(i, j, v);
            }
        }
        Csr::from_coo(coo)
    }

    #[test]
    fn empty_document_ranks_last_with_infinite_wmd() {
        // Regression: a zero-support column used to leave x_row all zero,
        // update_u divided by the zero mean (u poisoned with NaN) and the
        // epilogue summed nothing — the empty doc scored WMD = 0
        // and won every argmin.
        let corpus = toy();
        let pool = Pool::new(4);
        let k = 7;
        let c = drop_column(&corpus.c, k);
        for kernel in all_kernels() {
            let solver = SparseSolver::new(SinkhornConfig { kernel, ..Default::default() });
            let out = solver.wmd_one_to_many(&corpus.embeddings, corpus.query(0), &c, &pool);
            assert!(
                out.wmd[k].is_infinite() && out.wmd[k] > 0.0,
                "{kernel:?}: empty doc must report +inf, got {}",
                out.wmd[k]
            );
            for (j, v) in out.wmd.iter().enumerate() {
                if j != k {
                    assert!(v.is_finite(), "{kernel:?}: doc {j} poisoned: {v}");
                }
            }
            assert_ne!(out.argmin(), Some(k), "{kernel:?}: empty doc won argmin");
            assert!(
                out.top_k(c.ncols()).iter().all(|&(j, _)| j != k),
                "{kernel:?}: empty doc in top_k"
            );
        }
    }

    #[test]
    fn empty_document_does_not_block_convergence() {
        let corpus = toy();
        let pool = Pool::new(2);
        let c = drop_column(&corpus.c, 0);
        let solver = SparseSolver::new(SinkhornConfig {
            lambda: 3.0,
            tolerance: 1e-5,
            max_iter: 5000,
            ..Default::default()
        });
        let out = solver.wmd_one_to_many(&corpus.embeddings, corpus.query(0), &c, &pool);
        assert!(out.converged, "empty column's undeliverable mass stalled the residual");
    }

    #[test]
    fn top_k_bounded_selection_matches_full_sort_and_breaks_ties_by_index() {
        // Regression: top_k used to fully sort the wmd vector per call;
        // the bounded selection must return the same ranking at every k,
        // with exact ties in ascending-index order (deterministic, and
        // identical to what the old stable full sort produced).
        let out = SolveOutput {
            wmd: vec![3.0, 1.0, 2.0, 1.0, Real::NAN, 0.5, Real::INFINITY, 1.0, 2.0],
            iterations: 1,
            converged: true,
            ..Default::default()
        };
        let mut reference: Vec<(usize, Real)> =
            out.wmd.iter().copied().enumerate().filter(|(_, v)| v.is_finite()).collect();
        reference.sort_by(|a, b| a.1.total_cmp(&b.1)); // stable: ties keep index order
        assert_eq!(reference.len(), 7);
        for k in 0..=out.wmd.len() + 1 {
            let top = out.top_k(k);
            assert_eq!(top.len(), k.min(7), "k={k}");
            assert_eq!(&top[..], &reference[..top.len()], "k={k}");
        }
        assert_eq!(out.top_k(4), vec![(5, 0.5), (1, 1.0), (3, 1.0), (7, 1.0)]);
    }

    #[test]
    fn argmin_and_top_k_ignore_nan_and_inf() {
        let out = SolveOutput {
            wmd: vec![Real::NAN, 2.0, Real::INFINITY, 1.0],
            iterations: 1,
            ..Default::default()
        };
        assert_eq!(out.argmin(), Some(3));
        assert_eq!(out.top_k(10), vec![(3, 1.0), (1, 2.0)]);
        let none = SolveOutput {
            wmd: vec![Real::NAN, Real::INFINITY],
            iterations: 1,
            ..Default::default()
        };
        assert_eq!(none.argmin(), None);
        assert!(none.top_k(3).is_empty());
    }

    fn batch_corpus() -> SyntheticCorpus {
        SyntheticCorpus::builder()
            .vocab_size(500)
            .num_docs(40)
            .embedding_dim(16)
            .n_topics(4)
            .num_queries(8)
            .query_words(5, 12)
            .seed(23)
            .build()
    }

    #[test]
    fn solve_batch_agrees_with_solve_across_kernels_and_sizes() {
        let corpus = batch_corpus();
        let pool = Pool::new(4);
        for kernel in all_kernels() {
            // Default tolerance/check cadence so queries converge at
            // different iterations — exercises the per-query masks.
            let solver = SparseSolver::new(SinkhornConfig { kernel, ..Default::default() });
            let preps: Vec<Prepared> = corpus
                .queries
                .iter()
                .map(|q| solver.prepare(&corpus.embeddings, q, &pool))
                .collect();
            let singles: Vec<SolveOutput> =
                preps.iter().map(|p| solver.solve(p, &corpus.c, &pool)).collect();
            for bsz in [1usize, 4, 8] {
                let prefs: Vec<&Prepared> = preps[..bsz].iter().collect();
                let outs = solver.solve_batch(&prefs, &corpus.c, &pool);
                assert_eq!(outs.len(), bsz);
                for (q, (o, s)) in outs.iter().zip(&singles).enumerate() {
                    assert_eq!(o.iterations, s.iterations, "{kernel:?} b={bsz} q={q}");
                    assert_eq!(o.converged, s.converged, "{kernel:?} b={bsz} q={q}");
                    for (a, b) in o.wmd.iter().zip(&s.wmd) {
                        assert!(
                            (a - b).abs() < 1e-9 * (1.0 + b.abs()),
                            "{kernel:?} b={bsz} q={q}: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn solve_batch_is_bitwise_identical_to_solve() {
        // Batched and single-query solves share the per-element
        // accumulation order (column-owned iterate, row-owned update), so
        // the match is bitwise — including in mixed mode, whose f32
        // narrowing is deterministic too.
        let corpus = batch_corpus();
        for p in [1usize, 4] {
            let pool = Pool::new(p);
            for kernel in all_kernels() {
                if kernel == IterateKernel::Unfused {
                    continue; // no batched path (falls back to solve_in)
                }
                let solver = SparseSolver::new(SinkhornConfig { kernel, ..Default::default() });
                let preps: Vec<Prepared> = corpus
                    .queries
                    .iter()
                    .take(4)
                    .map(|q| solver.prepare(&corpus.embeddings, q, &pool))
                    .collect();
                let prefs: Vec<&Prepared> = preps.iter().collect();
                let outs = solver.solve_batch(&prefs, &corpus.c, &pool);
                for (prep, o) in preps.iter().zip(&outs) {
                    let s = solver.solve(prep, &corpus.c, &pool);
                    assert_eq!(o.wmd, s.wmd, "{kernel:?} p={p}");
                }
            }
        }
    }

    #[test]
    fn solve_batch_handles_empty_batch_and_empty_documents() {
        let corpus = batch_corpus();
        let pool = Pool::new(2);
        let solver = SparseSolver::new(SinkhornConfig::default());
        assert!(solver.solve_batch(&[], &corpus.c, &pool).is_empty());
        let k = 3;
        let c = drop_column(&corpus.c, k);
        let preps: Vec<Prepared> = corpus
            .queries
            .iter()
            .take(3)
            .map(|q| solver.prepare(&corpus.embeddings, q, &pool))
            .collect();
        let prefs: Vec<&Prepared> = preps.iter().collect();
        for out in solver.solve_batch(&prefs, &c, &pool) {
            assert!(out.wmd[k].is_infinite() && out.wmd[k] > 0.0);
            assert_ne!(out.argmin(), Some(k));
        }
    }

    #[test]
    fn merge_shards_reassembles_column_slices_bitwise() {
        // Per-column Sinkhorn state is independent of the other columns,
        // so with the early exit disabled (fixed iterations) a column
        // slice solves bitwise-identically to its columns in the full
        // solve — the invariant the sharded dispatch layer rests on.
        let corpus = batch_corpus();
        let pool = Pool::new(1);
        let solver = SparseSolver::new(SinkhornConfig {
            tolerance: 0.0,
            max_iter: 12,
            ..Default::default()
        });
        let prep = solver.prepare(&corpus.embeddings, corpus.query(0), &pool);
        let full = solver.solve(&prep, &corpus.c, &pool);
        let n = corpus.c.ncols();
        for cuts in [vec![0, n], vec![0, n / 2, n], vec![0, 0, n / 3, n]] {
            let parts: Vec<(usize, SolveOutput)> = cuts
                .windows(2)
                .map(|w| {
                    let c = corpus.c.slice_columns(w[0]..w[1]);
                    // Zero-column slices skip the solver, like the shard
                    // runtime does.
                    let out = if c.ncols() == 0 {
                        SolveOutput { converged: true, ..Default::default() }
                    } else {
                        solver.solve(&prep, &c, &pool)
                    };
                    (w[0], out)
                })
                .collect();
            let merged = SolveOutput::merge_shards(n, &parts);
            assert_eq!(merged.wmd, full.wmd, "cuts {cuts:?}: shard merge must be bitwise");
            assert_eq!(merged.iterations, full.iterations, "cuts {cuts:?}");
        }
    }

    #[test]
    #[should_panic(expected = "tile the target set")]
    fn merge_shards_rejects_gaps() {
        let part =
            SolveOutput { wmd: vec![1.0, 2.0], iterations: 1, converged: true, ..Default::default() };
        let _ = SolveOutput::merge_shards(3, &[(0, part)]);
    }

    #[test]
    fn more_iterations_monotonically_stabilize() {
        // The iterate map is a contraction in practice: successive outputs
        // should approach a fixed point (delta shrinks).
        let corpus = toy();
        let pool = Pool::new(4);
        let wmd_at = |iters: usize| {
            let solver = SparseSolver::new(SinkhornConfig {
                tolerance: 0.0,
                max_iter: iters,
                ..Default::default()
            });
            solver.wmd_one_to_many(&corpus.embeddings, corpus.query(0), &corpus.c, &pool).wmd
        };
        let a = wmd_at(5);
        let b = wmd_at(40);
        let c = wmd_at(80);
        let diff_ab: f64 = crate::util::nan_max(a.iter().zip(&b).map(|(x, y)| (x - y).abs()));
        let diff_bc: f64 = crate::util::nan_max(b.iter().zip(&c).map(|(x, y)| (x - y).abs()));
        assert!(diff_bc < diff_ab, "no stabilization: {diff_ab} -> {diff_bc}");
    }

    #[test]
    fn validate_rejects_bad_configs_with_actionable_messages() {
        let ok = SinkhornConfig::default();
        assert!(ok.validate().is_ok());
        // compact_every = 0 is the exact-mode opt-out, not an error.
        assert!(SinkhornConfig { compact_every: 0, ..ok }.validate().is_ok());
        // tolerance = 0 disables the early exit, also valid.
        assert!(SinkhornConfig { tolerance: 0.0, ..ok }.validate().is_ok());
        let cases: Vec<(SinkhornConfig, &str)> = vec![
            (SinkhornConfig { lambda: 0.0, ..ok }, "sinkhorn.lambda"),
            (SinkhornConfig { lambda: -1.0, ..ok }, "sinkhorn.lambda"),
            (SinkhornConfig { lambda: Real::NAN, ..ok }, "sinkhorn.lambda"),
            (SinkhornConfig { max_iter: 0, ..ok }, "sinkhorn.max_iter"),
            (SinkhornConfig { tolerance: -1e-3, ..ok }, "sinkhorn.tolerance"),
            (SinkhornConfig { tolerance: Real::INFINITY, ..ok }, "sinkhorn.tolerance"),
            (SinkhornConfig { check_every: 0, ..ok }, "sinkhorn.check_every"),
            (SinkhornConfig { compact_threshold: -0.1, ..ok }, "sinkhorn.compact_threshold"),
            (SinkhornConfig { compact_threshold: 1.5, ..ok }, "sinkhorn.compact_threshold"),
            (SinkhornConfig { compact_threshold: Real::NAN, ..ok }, "sinkhorn.compact_threshold"),
        ];
        for (cfg, key) in cases {
            let err = cfg.validate().expect_err(key);
            assert!(err.contains(key), "message {err:?} should name {key}");
        }
    }

    #[test]
    #[should_panic(expected = "invalid Sinkhorn config")]
    fn solver_constructor_panics_on_invalid_config() {
        let _ = SparseSolver::new(SinkhornConfig { check_every: 0, ..Default::default() });
    }

    #[test]
    fn freeze_histogram_buckets_min_max_and_p50() {
        let mut h = FreezeHistogram::default();
        assert_eq!(h.p50(), None);
        // Power-of-two buckets: 1 → bucket 0, 2..3 → 1, 4..7 → 2, …
        for it in [1u32, 2, 3, 4, 4, 7, 8] {
            h.record(it);
        }
        assert_eq!(h.count, 7);
        assert_eq!(h.min, 1);
        assert_eq!(h.max, 8);
        assert_eq!(h.buckets[0], 1);
        assert_eq!(h.buckets[1], 2);
        assert_eq!(h.buckets[2], 3);
        assert_eq!(h.buckets[3], 1);
        // target = 4: cumulative crosses at bucket 2 → upper bound 7.
        assert_eq!(h.p50(), Some(7));
        // record(0) is clamped into bucket 0 (columns freeze at iter ≥ 1).
        let mut z = FreezeHistogram::default();
        z.record(0);
        assert_eq!(z.buckets[0], 1);
        // Huge iteration counts land in the open-ended last bucket.
        let mut big = FreezeHistogram::default();
        big.record(u32::MAX);
        assert_eq!(big.buckets[15], 1);
        assert_eq!(big.p50(), Some(u32::MAX));
    }

    #[test]
    fn freeze_histogram_and_stats_merge() {
        let mut a = FreezeHistogram::default();
        a.record(2);
        a.record(5);
        let mut b = FreezeHistogram::default();
        b.record(40);
        a.merge(&b);
        assert_eq!(a.count, 3);
        assert_eq!(a.min, 2);
        assert_eq!(a.max, 40);
        // Merging an empty histogram must not disturb min (u32::MAX sentinel).
        a.merge(&FreezeHistogram::default());
        assert_eq!(a.min, 2);
        let mut s = ConvergenceStats {
            frozen_columns: 3,
            compactions: 1,
            nnz_traversed: 100,
            nnz_full: 200,
            freeze_iters: a,
        };
        let t = ConvergenceStats {
            frozen_columns: 2,
            compactions: 0,
            nnz_traversed: 50,
            nnz_full: 60,
            freeze_iters: b,
        };
        s.merge(&t);
        assert_eq!(s.frozen_columns, 5);
        assert_eq!(s.compactions, 1);
        assert_eq!(s.nnz_traversed, 150);
        assert_eq!(s.nnz_full, 260);
        assert_eq!(s.freeze_iters.count, 4);
    }

    #[test]
    fn default_mode_reports_convergence_stats() {
        // The default config freezes per document: every non-empty column
        // of a converged solve must be frozen, the histogram must cover
        // all of them, and the traversal accounting must be consistent.
        let corpus = toy();
        let pool = Pool::new(4);
        let solver = SparseSolver::new(SinkhornConfig {
            lambda: 3.0,
            tolerance: 1e-4,
            max_iter: 5000,
            ..Default::default()
        });
        let out = solver.wmd_one_to_many(&corpus.embeddings, corpus.query(0), &corpus.c, &pool);
        assert!(out.converged);
        let nonempty = corpus.c.ncols(); // synthetic corpora have no empty docs
        assert_eq!(out.conv.frozen_columns, nonempty);
        assert_eq!(out.conv.freeze_iters.count, nonempty as u64);
        assert!(out.conv.freeze_iters.max as usize <= out.iterations);
        assert!(out.conv.nnz_traversed <= out.conv.nnz_full);
        assert_eq!(out.conv.nnz_full, out.iterations as u64 * corpus.c.nnz() as u64);
        // Exact mode opts out: all-zero telemetry.
        let exact = SparseSolver::new(SinkhornConfig {
            lambda: 3.0,
            tolerance: 1e-4,
            max_iter: 5000,
            compact_every: 0,
            ..Default::default()
        });
        let out = exact.wmd_one_to_many(&corpus.embeddings, corpus.query(0), &corpus.c, &pool);
        assert_eq!(out.conv.frozen_columns, 0);
        assert_eq!(out.conv.compactions, 0);
    }

    #[cfg(feature = "mixed-precision")]
    #[test]
    fn mixed_precision_tracks_f64_within_gate() {
        // The solver-level error gate: mixed WMD within 1e-5 relative of
        // the f64 fused path, identical argmin on this corpus.
        let corpus = toy();
        let pool = Pool::new(4);
        let f64_solver = SparseSolver::new(SinkhornConfig {
            kernel: IterateKernel::Fused { precision: Precision::F64 },
            ..Default::default()
        });
        let mixed_solver = SparseSolver::new(SinkhornConfig {
            kernel: IterateKernel::Fused { precision: Precision::Mixed },
            ..Default::default()
        });
        for qi in 0..3 {
            let hi = f64_solver.wmd_one_to_many(&corpus.embeddings, corpus.query(qi), &corpus.c, &pool);
            let lo = mixed_solver.wmd_one_to_many(&corpus.embeddings, corpus.query(qi), &corpus.c, &pool);
            for (a, b) in lo.wmd.iter().zip(&hi.wmd) {
                assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "q={qi}: {a} vs {b}");
            }
            assert_eq!(lo.argmin(), hi.argmin(), "q={qi}");
        }
    }
}
