//! The shared-memory parallel **sparse** Sinkhorn-WMD solver (paper §4).
//!
//! Pipeline per query:
//! 1. `prepare` — select the query's non-zero words and precompute the
//!    transposed factors `Kᵀ`, `K_over_rᵀ`, `(K⊙M)ᵀ` in one fused
//!    GEMM-style pass ([`crate::dist::precompute_factors`]).
//! 2. `solve` — iterate `x ← K_over_r @ (c ⊘ (Kᵀ@(1/x)))` with the fused
//!    `SDDMM_SpMM` kernel until `x` stops changing (or `max_iter`), then
//!    reduce the WMD vector with the type-2 kernel.

use crate::dist::{precompute_factors, QueryFactors};
use crate::parallel::{balanced_nnz_partition, NnzRange, Pool};
use crate::sparse::ops::{
    fused_type1, fused_type1_private, fused_type1_transposed, fused_type2, sddmm, spmm_atomic,
    PrivateBuffers, TransposedPattern,
};
use crate::sparse::{Csr, Dense};
use crate::corpus::SparseVec;
use crate::util::SharedSlice;
use crate::Real;

/// Which iterate kernel the solver uses (ablation: `benches/ablation_fusion`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum IterateKernel {
    /// The paper's fused SDDMM_SpMM with atomic scatter (Fig. 4).
    #[default]
    FusedAtomic,
    /// Fused with per-thread private buffers + reduction (atomic-free).
    FusedPrivate,
    /// Fused over the transposed (column-owned) pattern: atomic-free and
    /// scratch-free; the pattern is built once per query (§9-style reuse).
    FusedTransposed,
    /// Unfused: SDDMM into a materialized `w`, then SpMM (the paper's
    /// pre-fusion variant, kept as the ablation baseline).
    Unfused,
}

/// Solver configuration (paper defaults: `λ = −(−10)`… the Python code
/// passes `lamb` pre-negated; here `lambda` is the positive entropic
/// regularization strength and the kernel applies the minus sign).
#[derive(Clone, Copy, Debug)]
pub struct SinkhornConfig {
    /// Entropic regularization strength λ (> 0). Larger → closer to exact
    /// EMD, slower convergence.
    pub lambda: Real,
    /// Hard iteration cap (paper uses a fixed `max_iter`).
    pub max_iter: usize,
    /// Early-exit threshold on the **marginal-feasibility residual**
    /// `max_j ‖u_j ⊙ (K v_j) − r‖₁` — the textbook Sinkhorn stopping
    /// criterion. `0.0` disables the check and always runs `max_iter`
    /// iterations (paper behaviour).
    ///
    /// Why not "while x changes" or a WMD-delta: the iterate can sit on a
    /// *metastable plateau* (a query word exponentially far from a
    /// document's support climbs `u` for hundreds of iterations before
    /// its mass reroutes — the WMD looks converged, then jumps). The
    /// marginal residual sees exactly the undelivered mass during such a
    /// plateau, so it cannot stop early. It costs nothing extra:
    /// `(K v)_k = r_k · x_new_k`, both already in hand.
    pub tolerance: Real,
    /// Evaluate the convergence check every `check_every` iterations.
    pub check_every: usize,
    /// Iterate kernel choice.
    pub kernel: IterateKernel,
}

impl Default for SinkhornConfig {
    fn default() -> Self {
        Self { lambda: 10.0, max_iter: 64, tolerance: 1e-3, check_every: 4, kernel: IterateKernel::default() }
    }
}

impl SinkhornConfig {
    /// Phase-1 preparation shared by every solver consuming `dist`
    /// factors (sparse and dense alike): select the query's non-zero
    /// words and run the fused precompute with this config's λ.
    pub fn prepare(&self, embeddings: &Dense, query: &SparseVec, pool: &Pool) -> Prepared {
        assert_eq!(embeddings.nrows(), query.dim, "embedding/vocab mismatch");
        let sel = query.indices();
        let factors = precompute_factors(embeddings, &sel, &query.val, self.lambda, pool);
        Prepared { factors }
    }
}

/// Precomputed per-query state: factors + the query's histogram.
#[derive(Clone, Debug)]
pub struct Prepared {
    pub factors: QueryFactors,
}

impl Prepared {
    #[inline]
    pub fn v_r(&self) -> usize {
        self.factors.v_r()
    }
}

/// Result of a one-to-many solve.
#[derive(Clone, Debug)]
pub struct SolveOutput {
    /// `wmd[j]` = Sinkhorn distance from the query to target doc `j`.
    pub wmd: Vec<Real>,
    /// Iterations actually executed.
    pub iterations: usize,
    /// Whether the tolerance-based early exit fired.
    pub converged: bool,
}

impl SolveOutput {
    /// Index of the most similar target document.
    pub fn argmin(&self) -> Option<usize> {
        self.wmd
            .iter()
            .enumerate()
            .filter(|(_, v)| v.is_finite())
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
    }

    /// Indices of the `k` most similar documents, ascending by distance.
    pub fn top_k(&self, k: usize) -> Vec<(usize, Real)> {
        let mut pairs: Vec<(usize, Real)> =
            self.wmd.iter().copied().enumerate().filter(|(_, v)| v.is_finite()).collect();
        pairs.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        pairs.truncate(k);
        pairs
    }
}

/// The sparse parallel solver.
#[derive(Clone, Debug)]
pub struct SparseSolver {
    config: SinkhornConfig,
}

impl SparseSolver {
    pub fn new(config: SinkhornConfig) -> Self {
        assert!(config.lambda > 0.0, "lambda must be positive");
        assert!(config.max_iter >= 1);
        assert!(config.check_every >= 1);
        Self { config }
    }

    pub fn config(&self) -> &SinkhornConfig {
        &self.config
    }

    /// Phase 1: select non-zero query words and precompute the factors.
    pub fn prepare(&self, embeddings: &Dense, query: &SparseVec, pool: &Pool) -> Prepared {
        self.config.prepare(embeddings, query, pool)
    }

    /// Phase 2: iterate to the WMD vector against all columns of `c`.
    pub fn solve(&self, prep: &Prepared, c: &Csr, pool: &Pool) -> SolveOutput {
        assert_eq!(c.nrows(), prep.factors.vocab_size(), "c/vocabulary mismatch");
        let v_r = prep.v_r();
        let n = c.ncols();
        let f = &prep.factors;
        let parts = balanced_nnz_partition(c.row_ptr(), pool.nthreads());

        // x = ones(v_r, N) / v_r, stored transposed (N × v_r); u = 1/x.
        let mut x_t = Dense::filled(n, v_r, 1.0 / v_r as Real);
        let mut x_new = Dense::zeros(n, v_r);
        let mut u_t = Dense::filled(n, v_r, v_r as Real);
        let mut scratch = match self.config.kernel {
            IterateKernel::FusedPrivate => Some(PrivateBuffers::new(pool.nthreads(), n, v_r)),
            _ => None,
        };
        let mut w_buf = match self.config.kernel {
            IterateKernel::Unfused => Some(vec![0.0; c.nnz()]),
            _ => None,
        };
        let transposed = match self.config.kernel {
            IterateKernel::FusedTransposed => {
                let tp = TransposedPattern::build(c);
                let col_parts = tp.column_parts(pool.nthreads());
                Some((tp, col_parts))
            }
            _ => None,
        };

        let mut iterations = 0;
        let mut converged = false;
        while iterations < self.config.max_iter {
            self.iterate_once(
                c, f, &u_t, &mut x_new, pool, &parts, &mut scratch, &mut w_buf, &transposed,
            );
            iterations += 1;
            let check = self.config.tolerance > 0.0
                && (iterations % self.config.check_every == 0
                    || iterations == self.config.max_iter);
            // One fused pass: marginal residual (needs the OLD u against
            // the RAW new x) + per-column renormalization + u update.
            let residual = update_u(&mut x_new, &mut u_t, &f.r, check, pool);
            std::mem::swap(&mut x_t, &mut x_new);
            if check && residual <= self.config.tolerance {
                converged = true;
                break;
            }
        }

        // Epilogue: u is already 1/x for the final x; one more SDDMM over
        // the pattern folds v and the (K⊙M) reduction together.
        let mut wmd = vec![0.0; n];
        fused_type2(c, &f.kt, &f.km_t, &u_t, &mut wmd, pool, &parts);
        SolveOutput { wmd, iterations, converged }
    }

    /// One-shot convenience: prepare + solve.
    pub fn wmd_one_to_many(
        &self,
        embeddings: &Dense,
        query: &SparseVec,
        c: &Csr,
        pool: &Pool,
    ) -> SolveOutput {
        let prep = self.prepare(embeddings, query, pool);
        self.solve(&prep, c, pool)
    }

    #[allow(clippy::too_many_arguments)]
    fn iterate_once(
        &self,
        c: &Csr,
        f: &QueryFactors,
        u_t: &Dense,
        x_new: &mut Dense,
        pool: &Pool,
        parts: &[NnzRange],
        scratch: &mut Option<PrivateBuffers>,
        w_buf: &mut Option<Vec<Real>>,
        transposed: &Option<(TransposedPattern, Vec<NnzRange>)>,
    ) {
        match self.config.kernel {
            IterateKernel::FusedAtomic => {
                fused_type1(c, &f.kt, &f.kor_t, u_t, x_new, pool, parts);
            }
            IterateKernel::FusedPrivate => {
                fused_type1_private(
                    c, &f.kt, &f.kor_t, u_t, x_new, pool, parts,
                    scratch.as_mut().expect("scratch"),
                );
            }
            IterateKernel::FusedTransposed => {
                let (tp, col_parts) = transposed.as_ref().expect("pattern");
                fused_type1_transposed(c, tp, &f.kt, &f.kor_t, u_t, x_new, pool, col_parts);
            }
            IterateKernel::Unfused => {
                let w = w_buf.as_mut().expect("w buffer");
                sddmm(c, &f.kt, u_t, w, pool, parts);
                spmm_atomic(c, w, &f.kor_t, x_new, pool, parts);
            }
        }
    }
}

/// Parallel pass over the new iterate, fused like the paper's
/// `update_x_u` but with two additions:
///
/// * **per-column renormalization** — the Sinkhorn iterate map is
///   1-homogeneous per target column (fixed points are rays), so the raw
///   iterate drifts in scale and would overflow over long runs. The WMD
///   is invariant under per-column scaling of `x` (it cancels between
///   `u` and `v`), so each column is rescaled to mean 1.
/// * **marginal residual** — with the *old* `u` (which produced this
///   `x_new`), the plan's row marginal is `u_k · (K v)_k = u_k·r_k·x_k`;
///   the per-document L1 violation `Σ_k |u_k r_k x_k − r_k|` is the
///   convergence criterion. Computed before `u` is overwritten, in the
///   same traversal, only when `check` is set.
///
/// `x_t` is `N × v_r` (transposed), so a *column* of `x` is a *row* here.
/// Returns the max residual over documents (0.0 when not checking).
fn update_u(x_new: &mut Dense, u_t: &mut Dense, r: &[Real], check: bool, pool: &Pool) -> Real {
    let n = x_new.nrows();
    let vr = x_new.ncols();
    debug_assert_eq!(r.len(), vr);
    let x_view = SharedSlice::new(x_new.as_mut_slice());
    let u_view = SharedSlice::new(u_t.as_mut_slice());
    pool.parallel_reduce(
        n,
        0.0f64,
        |rows, worst| {
            for j in rows {
                // SAFETY: row j is owned by exactly one thread.
                let x_row = unsafe { x_view.slice_mut(j * vr, vr) };
                let u_row = unsafe { u_view.slice_mut(j * vr, vr) };
                if check {
                    let mut res = 0.0;
                    for k in 0..vr {
                        res += (u_row[k] * r[k] * x_row[k] - r[k]).abs();
                    }
                    if res > *worst {
                        *worst = res;
                    }
                }
                let mean: Real = x_row.iter().sum::<Real>() / vr as Real;
                let inv_mean = 1.0 / mean;
                for k in 0..vr {
                    let xn = x_row[k] * inv_mean;
                    x_row[k] = xn;
                    u_row[k] = 1.0 / xn;
                }
            }
        },
        Real::max,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::SyntheticCorpus;

    fn toy() -> SyntheticCorpus {
        SyntheticCorpus::builder()
            .vocab_size(500)
            .num_docs(40)
            .embedding_dim(16)
            .n_topics(4)
            .num_queries(3)
            .query_words(5, 12)
            .seed(17)
            .build()
    }

    #[test]
    fn all_kernels_agree() {
        let corpus = toy();
        let pool = Pool::new(4);
        let mut outs = Vec::new();
        for kernel in [
            IterateKernel::FusedAtomic,
            IterateKernel::FusedPrivate,
            IterateKernel::FusedTransposed,
            IterateKernel::Unfused,
        ] {
            let solver = SparseSolver::new(SinkhornConfig {
                kernel,
                tolerance: 0.0,
                max_iter: 20,
                ..Default::default()
            });
            outs.push(solver.wmd_one_to_many(&corpus.embeddings, corpus.query(0), &corpus.c, &pool));
        }
        for o in &outs[1..] {
            for (a, b) in o.wmd.iter().zip(&outs[0].wmd) {
                assert!((a - b).abs() < 1e-9 * (1.0 + b.abs()), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn thread_count_does_not_change_result() {
        let corpus = toy();
        let solver = SparseSolver::new(SinkhornConfig { tolerance: 0.0, max_iter: 15, ..Default::default() });
        let base = {
            let pool = Pool::new(1);
            solver.wmd_one_to_many(&corpus.embeddings, corpus.query(1), &corpus.c, &pool)
        };
        for p in [2usize, 5, 8] {
            let pool = Pool::new(p);
            let out = solver.wmd_one_to_many(&corpus.embeddings, corpus.query(1), &corpus.c, &pool);
            for (a, b) in out.wmd.iter().zip(&base.wmd) {
                assert!((a - b).abs() < 1e-9 * (1.0 + b.abs()), "p={p}");
            }
        }
    }

    #[test]
    fn converges_with_tolerance() {
        // Sinkhorn's contraction constant degrades as λ grows (Cuturi's
        // accuracy/speed trade-off): at λ=3 the marginal residual reaches
        // 1e-5 in a few thousand iterations (measured); larger λ values
        // take proportionally longer.
        let corpus = toy();
        let pool = Pool::new(4);
        let solver = SparseSolver::new(SinkhornConfig {
            lambda: 3.0,
            tolerance: 1e-5,
            max_iter: 5000,
            ..Default::default()
        });
        let out = solver.wmd_one_to_many(&corpus.embeddings, corpus.query(0), &corpus.c, &pool);
        assert!(out.converged, "did not converge in 5000 iterations");
        assert!(out.iterations < 5000);
        assert!(out.wmd.iter().all(|v| v.is_finite() && *v >= 0.0));
    }

    #[test]
    fn same_topic_docs_are_closer() {
        let corpus = toy();
        let pool = Pool::new(4);
        let solver = SparseSolver::new(SinkhornConfig::default());
        for (qi, &qt) in corpus.query_topics.iter().enumerate() {
            let out = solver.wmd_one_to_many(&corpus.embeddings, corpus.query(qi), &corpus.c, &pool);
            // Mean WMD to same-topic docs < mean WMD to other-topic docs.
            let (mut same, mut ns, mut other, mut no) = (0.0, 0usize, 0.0, 0usize);
            for (j, &dt) in corpus.doc_topics.iter().enumerate() {
                if dt == qt {
                    same += out.wmd[j];
                    ns += 1;
                } else {
                    other += out.wmd[j];
                    no += 1;
                }
            }
            if ns > 0 && no > 0 {
                assert!(
                    same / ns as f64 <= other / no as f64,
                    "query {qi}: same-topic mean not smaller"
                );
            }
        }
    }

    #[test]
    fn top_k_sorted_and_argmin_consistent() {
        let corpus = toy();
        let pool = Pool::new(2);
        let solver = SparseSolver::new(SinkhornConfig::default());
        let out = solver.wmd_one_to_many(&corpus.embeddings, corpus.query(2), &corpus.c, &pool);
        let top = out.top_k(5);
        assert_eq!(top.len(), 5);
        for w in top.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        assert_eq!(out.argmin(), Some(top[0].0));
    }

    #[test]
    fn more_iterations_monotonically_stabilize() {
        // The iterate map is a contraction in practice: successive outputs
        // should approach a fixed point (delta shrinks).
        let corpus = toy();
        let pool = Pool::new(4);
        let wmd_at = |iters: usize| {
            let solver = SparseSolver::new(SinkhornConfig {
                tolerance: 0.0,
                max_iter: iters,
                ..Default::default()
            });
            solver.wmd_one_to_many(&corpus.embeddings, corpus.query(0), &corpus.c, &pool).wmd
        };
        let a = wmd_at(5);
        let b = wmd_at(40);
        let c = wmd_at(80);
        let diff_ab: f64 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        let diff_bc: f64 = b.iter().zip(&c).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(diff_bc < diff_ab, "no stabilization: {diff_ab} -> {diff_bc}");
    }
}
