//! The shared-memory parallel **sparse** Sinkhorn-WMD solver (paper §4).
//!
//! Pipeline per query:
//! 1. `prepare` — select the query's non-zero words and precompute the
//!    transposed factors `Kᵀ`, `K_over_rᵀ`, `(K⊙M)ᵀ` in one fused
//!    GEMM-style pass ([`crate::dist::precompute_factors`]).
//! 2. `solve` — iterate `x ← K_over_r @ (c ⊘ (Kᵀ@(1/x)))` with the fused
//!    `SDDTMM→DSTMMT` kernel over the stationary transposed pattern until
//!    `x` stops changing (or `max_iter`), then reduce the WMD vector with
//!    the fused epilogue.
//!
//! Kernel selection is [`IterateKernel`]: the fused family (optionally in
//! [`Precision::Mixed`] — f32 compute panels, f64 accumulation and
//! convergence/WMD reduction) or the `Unfused` SDDMM + atomic-SpMM
//! ablation baseline.

use super::workspace::SolveWorkspace;
use crate::corpus::SparseVec;
use crate::dist::{precompute_factors_in, QueryFactors};
use crate::parallel::{balanced_nnz_partition_into, Pool};
use crate::sparse::ops::{sddmm, sddtmm_dstmmt_batch, sddtmm_wmd_batch, spmm_atomic};
use crate::sparse::{Csr, Dense, Panel32};
use crate::util::SharedSlice;
use crate::Real;

/// Scalar precision of the fused iterate's compute panels.
///
/// `Mixed` narrows the *stationary* panels (`Kᵀ`, `K_over_rᵀ`) and the
/// `uᵀ` mirror to f32 — halving the iterate's memory traffic and doubling
/// its SIMD width — while every division, accumulation, renormalization,
/// convergence residual and the final WMD reduction stay f64. Measured
/// end-to-end WMD error vs the f64 path is ~2e-9 at paper-scale shapes;
/// the equivalence suite enforces ≤ 1e-5.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Precision {
    /// Full f64 throughout (the default; bitwise-reproducible).
    #[default]
    F64,
    /// f32 compute panels with f64 accumulation (requires the
    /// `mixed-precision` build feature).
    #[cfg(feature = "mixed-precision")]
    Mixed,
}

/// Which iterate kernel the solver uses (ablation: `benches/ablation_fusion`).
///
/// The former `FusedAtomic` / `FusedPrivate` / `FusedTransposed` variants
/// collapsed into the single [`IterateKernel::Fused`] family — the
/// column-owned transposed traversal beat both scatter strategies on
/// every measured shape, so only the best survives, parameterized by
/// [`Precision`]. `Unfused` remains as the one ablation baseline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IterateKernel {
    /// The fused `SDDTMM→DSTMMT` family: one pass over the stationary
    /// transposed pattern per Sinkhorn step, write-owned columns, no
    /// atomics, no private buffers.
    Fused { precision: Precision },
    /// Unfused: SDDMM into a materialized `w`, then atomic SpMM (the
    /// paper's pre-fusion variant, kept as the ablation baseline).
    Unfused,
}

impl Default for IterateKernel {
    fn default() -> Self {
        IterateKernel::Fused { precision: Precision::F64 }
    }
}

impl IterateKernel {
    /// Whether [`SparseSolver::solve_batch`] has a cross-query batched
    /// kernel for this variant (otherwise it falls back to a per-query
    /// loop — callers reporting batching metrics should check this).
    pub fn has_batched_path(self) -> bool {
        matches!(self, IterateKernel::Fused { .. })
    }

    /// Whether this kernel runs the f32 compute panels. Always false when
    /// the `mixed-precision` feature is off (the `Mixed` variant does not
    /// exist then), so callers can branch on it unconditionally.
    pub fn is_mixed(self) -> bool {
        #[cfg(feature = "mixed-precision")]
        {
            matches!(self, IterateKernel::Fused { precision: Precision::Mixed })
        }
        #[cfg(not(feature = "mixed-precision"))]
        {
            false
        }
    }

    /// Stable label for metrics/bench reporting (matches the `kernel=` /
    /// `precision=` config-key vocabulary).
    pub fn label(self) -> &'static str {
        match self {
            #[cfg(feature = "mixed-precision")]
            IterateKernel::Fused { precision: Precision::Mixed } => "fused-mixed",
            IterateKernel::Fused { .. } => "fused-f64",
            IterateKernel::Unfused => "unfused",
        }
    }
}

/// Solver configuration (paper defaults: `λ = −(−10)`… the Python code
/// passes `lamb` pre-negated; here `lambda` is the positive entropic
/// regularization strength and the kernel applies the minus sign).
#[derive(Clone, Copy, Debug)]
pub struct SinkhornConfig {
    /// Entropic regularization strength λ (> 0). Larger → closer to exact
    /// EMD, slower convergence.
    pub lambda: Real,
    /// Hard iteration cap (paper uses a fixed `max_iter`).
    pub max_iter: usize,
    /// Early-exit threshold on the **marginal-feasibility residual**
    /// `max_j ‖u_j ⊙ (K v_j) − r‖₁` — the textbook Sinkhorn stopping
    /// criterion. `0.0` disables the check and always runs `max_iter`
    /// iterations (paper behaviour).
    ///
    /// Why not "while x changes" or a WMD-delta: the iterate can sit on a
    /// *metastable plateau* (a query word exponentially far from a
    /// document's support climbs `u` for hundreds of iterations before
    /// its mass reroutes — the WMD looks converged, then jumps). The
    /// marginal residual sees exactly the undelivered mass during such a
    /// plateau, so it cannot stop early. It costs nothing extra:
    /// `(K v)_k = r_k · x_new_k`, both already in hand.
    pub tolerance: Real,
    /// Evaluate the convergence check every `check_every` iterations.
    pub check_every: usize,
    /// Iterate kernel choice.
    pub kernel: IterateKernel,
}

impl Default for SinkhornConfig {
    fn default() -> Self {
        Self { lambda: 10.0, max_iter: 64, tolerance: 1e-3, check_every: 4, kernel: IterateKernel::default() }
    }
}

impl SinkhornConfig {
    /// Phase-1 preparation shared by every solver consuming `dist`
    /// factors (sparse and dense alike): select the query's non-zero
    /// words and run the fused precompute with this config's λ.
    pub fn prepare(&self, embeddings: &Dense, query: &SparseVec, pool: &Pool) -> Prepared {
        self.prepare_in(&mut SolveWorkspace::new(), embeddings, query, pool)
    }

    /// [`SinkhornConfig::prepare`] with the selection buffer and the
    /// dist-layer panel scratch borrowed from a retained workspace. The
    /// factor matrices themselves are still freshly allocated — they are
    /// the returned artifact (typically committed to the coordinator's
    /// prepared cache), not scratch.
    pub fn prepare_in(
        &self,
        ws: &mut SolveWorkspace,
        embeddings: &Dense,
        query: &SparseVec,
        pool: &Pool,
    ) -> Prepared {
        assert_eq!(embeddings.nrows(), query.dim, "embedding/vocab mismatch");
        // Take the selection buffer out so the rest of the dist scratch
        // can be borrowed mutably alongside it.
        let mut sel = std::mem::take(&mut ws.dist.sel);
        sel.clear();
        sel.extend(query.idx.iter().map(|&i| i as usize));
        let factors =
            precompute_factors_in(embeddings, &sel, &query.val, self.lambda, pool, &mut ws.dist);
        ws.dist.sel = sel;
        Prepared { factors }
    }
}

/// Precomputed per-query state: factors + the query's histogram.
/// (`Default` is an *empty* prepared slot — a reusable target for
/// [`QueryFactors::restrict_rows_into`], not a solvable query.)
#[derive(Clone, Debug, Default)]
pub struct Prepared {
    pub factors: QueryFactors,
}

impl Prepared {
    #[inline]
    pub fn v_r(&self) -> usize {
        self.factors.v_r()
    }
}

/// Result of a one-to-many solve.
#[derive(Clone, Debug)]
pub struct SolveOutput {
    /// `wmd[j]` = Sinkhorn distance from the query to target doc `j`.
    pub wmd: Vec<Real>,
    /// Iterations actually executed.
    pub iterations: usize,
    /// Whether the tolerance-based early exit fired.
    pub converged: bool,
}

impl SolveOutput {
    /// Index of the most similar target document. Non-finite distances
    /// (empty documents report `+inf`; a poisoned embedding can produce
    /// NaN) never win.
    pub fn argmin(&self) -> Option<usize> {
        self.wmd
            .iter()
            .enumerate()
            .filter(|(_, v)| v.is_finite())
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
    }

    /// Merge per-shard outputs back into one full-length output. Each
    /// part covers a contiguous column range of the full target set and
    /// is given as `(col_offset, output)`; together the parts must tile
    /// `0..total_docs` exactly (zero-column shards contribute an empty
    /// `wmd` and are fine).
    ///
    /// Merge semantics:
    /// * `wmd[col_offset + j] = part.wmd[j]` — `+inf` empty-document
    ///   entries land at their global indices untouched;
    /// * `iterations` is the **max** over shards (the wall-clock-relevant
    ///   count: shards iterate concurrently);
    /// * `converged` requires every shard to have converged.
    pub fn merge_shards(total_docs: usize, parts: &[(usize, SolveOutput)]) -> SolveOutput {
        let mut wmd = vec![Real::NAN; total_docs];
        let mut covered = 0usize;
        let mut iterations = 0usize;
        let mut converged = true;
        for (offset, part) in parts {
            assert!(
                offset + part.wmd.len() <= total_docs,
                "shard slice {}..{} out of range for {} documents",
                offset,
                offset + part.wmd.len(),
                total_docs
            );
            wmd[*offset..offset + part.wmd.len()].copy_from_slice(&part.wmd);
            covered += part.wmd.len();
            iterations = iterations.max(part.iterations);
            converged &= part.converged;
        }
        assert_eq!(covered, total_docs, "shard slices must tile the target set exactly");
        SolveOutput { wmd, iterations, converged }
    }

    /// Indices of the `k` most similar documents, ascending by distance.
    /// Non-finite distances are excluded (so fewer than `k` entries can
    /// come back); `total_cmp` keeps the comparison panic-free regardless.
    ///
    /// Uses a bounded selection — `select_nth_unstable` to isolate the `k`
    /// smallest, then a sort of just those — so the cost is `O(N + k·log
    /// k)` instead of the full `O(N·log N)` re-sort per call. Ties are
    /// broken by ascending document index, so the returned order is
    /// deterministic (and matches what the old stable full sort produced).
    pub fn top_k(&self, k: usize) -> Vec<(usize, Real)> {
        let mut pairs: Vec<(usize, Real)> =
            self.wmd.iter().copied().enumerate().filter(|(_, v)| v.is_finite()).collect();
        let cmp = |a: &(usize, Real), b: &(usize, Real)| {
            a.1.total_cmp(&b.1).then_with(|| a.0.cmp(&b.0))
        };
        if k == 0 {
            pairs.clear();
            return pairs;
        }
        if k < pairs.len() {
            let _ = pairs.select_nth_unstable_by(k - 1, cmp);
            pairs.truncate(k);
        }
        pairs.sort_unstable_by(cmp);
        pairs
    }
}

/// The sparse parallel solver.
#[derive(Clone, Debug)]
pub struct SparseSolver {
    config: SinkhornConfig,
}

impl SparseSolver {
    pub fn new(config: SinkhornConfig) -> Self {
        assert!(config.lambda > 0.0, "lambda must be positive");
        assert!(config.max_iter >= 1);
        assert!(config.check_every >= 1);
        Self { config }
    }

    pub fn config(&self) -> &SinkhornConfig {
        &self.config
    }

    /// Phase 1: select non-zero query words and precompute the factors.
    pub fn prepare(&self, embeddings: &Dense, query: &SparseVec, pool: &Pool) -> Prepared {
        self.config.prepare(embeddings, query, pool)
    }

    /// [`SparseSolver::prepare`] with scratch borrowed from a retained
    /// workspace (see [`SinkhornConfig::prepare_in`]).
    pub fn prepare_in(
        &self,
        ws: &mut SolveWorkspace,
        embeddings: &Dense,
        query: &SparseVec,
        pool: &Pool,
    ) -> Prepared {
        self.config.prepare_in(ws, embeddings, query, pool)
    }

    /// Phase 2: iterate to the WMD vector against all columns of `c`.
    ///
    /// **Empty documents** (target columns with no non-zeros) report
    /// `Real::INFINITY`: there is no transport plan to a document with no
    /// words. Without the guard a zero-support column leaves `x_row` all
    /// zeros, `update_u`'s renormalization divides by a zero mean and
    /// poisons `u` with NaN, while the epilogue sums nothing — the
    /// empty document would score `WMD = 0` and win every argmin.
    ///
    /// Thin allocating wrapper over [`SparseSolver::solve_in`] (a fresh
    /// workspace per call — fine for tests and one-shot use; serving
    /// threads retain one and call `solve_in`).
    pub fn solve(&self, prep: &Prepared, c: &Csr, pool: &Pool) -> SolveOutput {
        self.solve_in(&mut SolveWorkspace::new(), prep, c, pool)
    }

    /// [`SparseSolver::solve`] with every piece of per-solve scratch —
    /// iterate planes, masks, partitions, kernel scratch, f32 panel lanes
    /// in mixed mode — borrowed from `ws` instead of heap-allocated. Once
    /// the workspace is warm, the only remaining allocations are the
    /// returned `wmd` vector (its ownership moves to the caller) and, on
    /// multi-threaded pools, the convergence reduction's per-thread
    /// cells. Numerically identical to `solve`: every borrowed buffer is
    /// re-shaped and re-filled at checkout, so dirty contents cannot leak
    /// (pinned bitwise by `tests/workspace_test.rs`).
    pub fn solve_in(
        &self,
        ws: &mut SolveWorkspace,
        prep: &Prepared,
        c: &Csr,
        pool: &Pool,
    ) -> SolveOutput {
        assert_eq!(c.nrows(), prep.factors.vocab_size(), "c/vocabulary mismatch");
        let mixed = self.config.kernel.is_mixed();
        let bytes_before = ws.begin_checkout();
        ws.ensure_lanes(1);
        if mixed {
            ws.ensure_lo_lanes(1);
        }
        let v_r = prep.v_r();
        let n = c.ncols();
        let f = &prep.factors;
        let out = {
            // Split the workspace into its disjoint scratch sections.
            let SolveWorkspace {
                x_t,
                x_new,
                u_t,
                empty,
                parts,
                col_parts,
                pattern,
                w_buf,
                fused,
                kt_lo,
                kor_lo,
                u_lo,
                ..
            } = &mut *ws;
            empty_columns_into(c, empty);
            // The transposed pattern drives both the fused iterate and the
            // (always-fused) WMD epilogue, so every kernel builds it.
            pattern.rebuild_from(c);
            pattern.column_parts_into(pool.nthreads(), col_parts);

            // x = ones(v_r, N) / v_r, stored transposed (N × v_r); u = 1/x.
            let x_t = &mut x_t[0];
            let x_new = &mut x_new[0];
            let u_t = &mut u_t[0];
            x_t.reset(n, v_r, 1.0 / v_r as Real);
            x_new.reset(n, v_r, 0.0);
            u_t.reset(n, v_r, v_r as Real);
            let mut w_slot: Option<&mut Vec<Real>> = match self.config.kernel {
                IterateKernel::Unfused => {
                    balanced_nnz_partition_into(c.row_ptr(), pool.nthreads(), parts);
                    w_buf.clear();
                    w_buf.resize(c.nnz(), 0.0);
                    Some(w_buf)
                }
                IterateKernel::Fused { .. } => None,
            };
            if mixed {
                // Narrow the stationary factor panels once per solve; the
                // f32 u mirror starts at the same 1/x as the f64 master
                // and is refreshed inside update_u.
                kt_lo[0].reset_from(&f.kt, pool);
                kor_lo[0].reset_from(&f.kor_t, pool);
                u_lo[0].reset(n, v_r, v_r as f32);
            }

            let mut iterations = 0;
            let mut converged = false;
            while iterations < self.config.max_iter {
                match self.config.kernel {
                    IterateKernel::Fused { .. } => {
                        if mixed {
                            sddtmm_dstmmt_batch(
                                c,
                                &*pattern,
                                &[&kt_lo[0]],
                                &[&kor_lo[0]],
                                &u_lo[..1],
                                std::slice::from_mut(x_new),
                                &[true],
                                pool,
                                col_parts,
                                fused,
                            );
                        } else {
                            sddtmm_dstmmt_batch(
                                c,
                                &*pattern,
                                &[&f.kt],
                                &[&f.kor_t],
                                std::slice::from_ref(&*u_t),
                                std::slice::from_mut(x_new),
                                &[true],
                                pool,
                                col_parts,
                                fused,
                            );
                        }
                    }
                    IterateKernel::Unfused => {
                        let w = w_slot.as_deref_mut().expect("w buffer");
                        sddmm(c, &f.kt, u_t, w, pool, parts);
                        spmm_atomic(c, &w[..], &f.kor_t, x_new, pool, parts);
                    }
                }
                iterations += 1;
                let check = self.config.tolerance > 0.0
                    && (iterations % self.config.check_every == 0
                        || iterations == self.config.max_iter);
                // One fused pass: marginal residual (needs the OLD u against
                // the RAW new x) + per-column renormalization + u update.
                let residual = update_u(
                    x_new,
                    u_t,
                    &f.r,
                    empty,
                    check,
                    pool,
                    if mixed { Some(&mut u_lo[0]) } else { None },
                );
                std::mem::swap(x_t, x_new);
                if check && residual <= self.config.tolerance {
                    converged = true;
                    break;
                }
            }

            // Epilogue: u is already 1/x for the final x; one more fused
            // pass over the pattern folds v and the (K⊙M) reduction
            // together. Always f64 — in mixed mode the final reduction
            // runs against the f64 u master, not the f32 mirror.
            let mut wmd = vec![0.0; n];
            sddtmm_wmd_batch(
                c,
                &*pattern,
                &[&f.kt],
                &[&f.km_t],
                std::slice::from_ref(&*u_t),
                std::slice::from_mut(&mut wmd),
                pool,
                col_parts,
            );
            for (w, &e) in wmd.iter_mut().zip(empty.iter()) {
                if e {
                    *w = Real::INFINITY;
                }
            }
            SolveOutput { wmd, iterations, converged }
        };
        ws.end_checkout(bytes_before);
        out
    }

    /// Cross-query batched solve: `B` prepared queries against the same
    /// target matrix, iterated in **one fused pass over the transposed
    /// pattern per Sinkhorn step** — each pattern entry updates every
    /// active query's state before the traversal moves on, amortizing the
    /// column walk and its cache misses across the batch (the
    /// coordinator's dispatch path).
    ///
    /// Per-query convergence masks let early-converging queries drop out
    /// of the iterate without stalling the rest; each query's output
    /// (`wmd`, `iterations`, `converged`) matches what the per-query
    /// [`SparseSolver::solve`] would have produced — bitwise, at any
    /// thread count, for the fused f64 kernel (column-owned accumulation
    /// is order-deterministic).
    ///
    /// Kernels without a batched variant ([`IterateKernel::Unfused`], the
    /// ablation baseline) fall back to a per-query loop.
    /// Thin allocating wrapper over [`SparseSolver::solve_batch_in`].
    pub fn solve_batch(&self, preps: &[&Prepared], c: &Csr, pool: &Pool) -> Vec<SolveOutput> {
        self.solve_batch_in(&mut SolveWorkspace::new(), preps, c, pool)
    }

    /// [`SparseSolver::solve_batch`] with all per-batch scratch — one
    /// iterate-plane lane per query, shared masks/partitions/pattern,
    /// kernel scratch — borrowed from `ws`. Once warm, nothing
    /// problem-sized is allocated: what remains is the returned per-query
    /// `wmd` vectors, `O(B)` factor-pointer vectors per call, and the
    /// per-check residual reduction's `O(B)` bookkeeping.
    pub fn solve_batch_in(
        &self,
        ws: &mut SolveWorkspace,
        preps: &[&Prepared],
        c: &Csr,
        pool: &Pool,
    ) -> Vec<SolveOutput> {
        if !self.config.kernel.has_batched_path() {
            return preps.iter().map(|&p| self.solve_in(ws, p, c, pool)).collect();
        }
        let b = preps.len();
        if b == 0 {
            return Vec::new();
        }
        for p in preps {
            assert_eq!(c.nrows(), p.factors.vocab_size(), "c/vocabulary mismatch");
        }
        let mixed = self.config.kernel.is_mixed();
        let bytes_before = ws.begin_checkout();
        ws.ensure_lanes(b);
        if mixed {
            ws.ensure_lo_lanes(b);
        }
        let n = c.ncols();
        let out = {
            let SolveWorkspace {
                x_t,
                x_new,
                u_t,
                empty,
                col_parts,
                pattern,
                fused,
                iterations,
                converged,
                active,
                kt_lo,
                kor_lo,
                u_lo,
                ..
            } = &mut *ws;
            empty_columns_into(c, empty);
            // The pattern (and its column partition) is shared by the whole
            // batch — built once, another cross-query amortization.
            pattern.rebuild_from(c);
            pattern.column_parts_into(pool.nthreads(), col_parts);
            let kts: Vec<&Dense> = preps.iter().map(|p| &p.factors.kt).collect();
            let kor_ts: Vec<&Dense> = preps.iter().map(|p| &p.factors.kor_t).collect();
            let km_ts: Vec<&Dense> = preps.iter().map(|p| &p.factors.km_t).collect();
            let rs: Vec<&[Real]> = preps.iter().map(|p| p.factors.r.as_slice()).collect();

            let x_t = &mut x_t[..b];
            let x_new = &mut x_new[..b];
            let u_t = &mut u_t[..b];
            for (q, p) in preps.iter().enumerate() {
                x_t[q].reset(n, p.v_r(), 1.0 / p.v_r() as Real);
                x_new[q].reset(n, p.v_r(), 0.0);
                u_t[q].reset(n, p.v_r(), p.v_r() as Real);
            }
            if mixed {
                for (q, p) in preps.iter().enumerate() {
                    kt_lo[q].reset_from(&p.factors.kt, pool);
                    kor_lo[q].reset_from(&p.factors.kor_t, pool);
                    u_lo[q].reset(n, p.v_r(), p.v_r() as f32);
                }
            }
            let kt_lo_refs: Vec<&Panel32> =
                if mixed { kt_lo[..b].iter().collect() } else { Vec::new() };
            let kor_lo_refs: Vec<&Panel32> =
                if mixed { kor_lo[..b].iter().collect() } else { Vec::new() };
            iterations.clear();
            iterations.resize(b, 0usize);
            converged.clear();
            converged.resize(b, false);
            active.clear();
            active.resize(b, true);

            let mut iter = 0;
            while iter < self.config.max_iter && active.iter().any(|&a| a) {
                // The u lanes pass straight through as slices — no
                // per-iteration reference-vector rebuild.
                if mixed {
                    sddtmm_dstmmt_batch(
                        c, &*pattern, &kt_lo_refs, &kor_lo_refs, &u_lo[..b], x_new, active,
                        pool, col_parts, fused,
                    );
                } else {
                    sddtmm_dstmmt_batch(
                        c, &*pattern, &kts, &kor_ts, &*u_t, x_new, active, pool, col_parts,
                        fused,
                    );
                }
                iter += 1;
                let check = self.config.tolerance > 0.0
                    && (iter % self.config.check_every == 0 || iter == self.config.max_iter);
                let residuals = update_u_batch(
                    x_new,
                    u_t,
                    &rs,
                    empty,
                    active,
                    check,
                    pool,
                    if mixed { Some(&mut u_lo[..b]) } else { None },
                );
                for q in 0..b {
                    if !active[q] {
                        continue;
                    }
                    iterations[q] = iter;
                    std::mem::swap(&mut x_t[q], &mut x_new[q]);
                    if check && residuals[q] <= self.config.tolerance {
                        converged[q] = true;
                        active[q] = false;
                    }
                }
            }

            // Batched epilogue: every query's final u (frozen at its own
            // convergence point) feeds one shared fused WMD pass — always
            // f64, against the f64 u masters.
            let mut wmds: Vec<Vec<Real>> = (0..b).map(|_| vec![0.0; n]).collect();
            sddtmm_wmd_batch(c, &*pattern, &kts, &km_ts, &*u_t, &mut wmds, pool, col_parts);
            wmds.into_iter()
                .enumerate()
                .map(|(q, mut wmd)| {
                    for (w, &e) in wmd.iter_mut().zip(empty.iter()) {
                        if e {
                            *w = Real::INFINITY;
                        }
                    }
                    SolveOutput { wmd, iterations: iterations[q], converged: converged[q] }
                })
                .collect::<Vec<SolveOutput>>()
        };
        ws.end_checkout(bytes_before);
        out
    }

    /// One-shot convenience: prepare + solve.
    pub fn wmd_one_to_many(
        &self,
        embeddings: &Dense,
        query: &SparseVec,
        c: &Csr,
        pool: &Pool,
    ) -> SolveOutput {
        let prep = self.prepare(embeddings, query, pool);
        self.solve(&prep, c, pool)
    }
}

/// Parallel pass over the new iterate, fused like the paper's
/// `update_x_u` but with two additions:
///
/// * **per-column renormalization** — the Sinkhorn iterate map is
///   1-homogeneous per target column (fixed points are rays), so the raw
///   iterate drifts in scale and would overflow over long runs. The WMD
///   is invariant under per-column scaling of `x` (it cancels between
///   `u` and `v`), so each column is rescaled to mean 1.
/// * **marginal residual** — with the *old* `u` (which produced this
///   `x_new`), the plan's row marginal is `u_k · (K v)_k = u_k·r_k·x_k`;
///   the per-document L1 violation `Σ_k |u_k r_k x_k − r_k|` is the
///   convergence criterion. Computed before `u` is overwritten, in the
///   same traversal, only when `check` is set.
///
/// `x_t` is `N × v_r` (transposed), so a *column* of `x` is a *row* here.
///
/// Rows flagged in `empty` (zero-support target columns) are skipped
/// entirely: their iterate row is all zeros, so the mean-1 renormalization
/// would divide by zero and poison `u` with NaN/inf, and their residual
/// (undeliverable mass, constant 1) would block convergence forever. The
/// solve reports those documents as `+inf` in the epilogue instead.
///
/// When `u_lo` is given (mixed precision), the freshly written f64 `u`
/// row is also narrowed into the f32 mirror in the same pass — the next
/// iterate reads the mirror, every other consumer reads the f64 master.
/// Mirror rows of empty documents stay stale, matching the skipped f64
/// rows; the kernels never read them (empty columns have no entries).
///
/// Returns the max residual over documents (0.0 when not checking).
fn update_u(
    x_new: &mut Dense,
    u_t: &mut Dense,
    r: &[Real],
    empty: &[bool],
    check: bool,
    pool: &Pool,
    u_lo: Option<&mut Panel32>,
) -> Real {
    let n = x_new.nrows();
    let vr = x_new.ncols();
    debug_assert_eq!(r.len(), vr);
    debug_assert_eq!(empty.len(), n);
    let x_view = SharedSlice::new(x_new.as_mut_slice());
    let u_view = SharedSlice::new(u_t.as_mut_slice());
    let u_lo_view: Option<SharedSlice<f32>> = u_lo.map(|p| {
        debug_assert_eq!(p.nrows(), n);
        debug_assert_eq!(p.ncols(), vr);
        SharedSlice::new(p.as_mut_slice())
    });
    pool.parallel_reduce(
        n,
        0.0f64,
        |rows, worst| {
            for j in rows {
                if empty[j] {
                    continue;
                }
                // SAFETY: row j is owned by exactly one thread.
                let x_row = unsafe { x_view.slice_mut(j * vr, vr) };
                let u_row = unsafe { u_view.slice_mut(j * vr, vr) };
                if check {
                    let mut res = 0.0;
                    for k in 0..vr {
                        res += (u_row[k] * r[k] * x_row[k] - r[k]).abs();
                    }
                    if res > *worst {
                        *worst = res;
                    }
                }
                let mean: Real = x_row.iter().sum::<Real>() / vr as Real;
                let inv_mean = 1.0 / mean;
                for k in 0..vr {
                    let xn = x_row[k] * inv_mean;
                    x_row[k] = xn;
                    u_row[k] = 1.0 / xn;
                }
                if let Some(v) = &u_lo_view {
                    // SAFETY: row j of the mirror is owned by this thread.
                    let lo = unsafe { v.slice_mut(j * vr, vr) };
                    for k in 0..vr {
                        lo[k] = u_row[k] as f32;
                    }
                }
            }
        },
        Real::max,
    )
}

/// Batched [`update_u`]: one parallel region renormalizes every active
/// query's iterate and computes per-query residuals (the per-query
/// convergence masks), instead of `B` fork/join barriers per Sinkhorn
/// step. Row-wise arithmetic is identical to the single-query pass, so
/// the batched update is bitwise equivalent per query. `u_los` mirrors
/// [`update_u`]'s `u_lo` per lane (mixed precision only).
#[allow(clippy::too_many_arguments)]
fn update_u_batch(
    x_new: &mut [Dense],
    u_t: &mut [Dense],
    rs: &[&[Real]],
    empty: &[bool],
    active: &[bool],
    check: bool,
    pool: &Pool,
    u_los: Option<&mut [Panel32]>,
) -> Vec<Real> {
    let b = x_new.len();
    debug_assert_eq!(u_t.len(), b);
    debug_assert_eq!(rs.len(), b);
    debug_assert_eq!(active.len(), b);
    if b == 0 {
        return Vec::new();
    }
    let n = x_new[0].nrows();
    debug_assert_eq!(empty.len(), n);
    let vrs: Vec<usize> = x_new.iter().map(|x| x.ncols()).collect();
    let x_views: Vec<SharedSlice<Real>> =
        x_new.iter_mut().map(|x| SharedSlice::new(x.as_mut_slice())).collect();
    let u_views: Vec<SharedSlice<Real>> =
        u_t.iter_mut().map(|u| SharedSlice::new(u.as_mut_slice())).collect();
    let u_lo_views: Option<Vec<SharedSlice<f32>>> = u_los.map(|ps| {
        debug_assert_eq!(ps.len(), b);
        ps.iter_mut().map(|p| SharedSlice::new(p.as_mut_slice())).collect()
    });
    pool.parallel_reduce(
        n,
        vec![0.0f64; b],
        |rows, worst| {
            for j in rows {
                if empty[j] {
                    continue;
                }
                for q in 0..b {
                    if !active[q] {
                        continue;
                    }
                    let vr = vrs[q];
                    // SAFETY: row j of query q is owned by exactly one thread.
                    let x_row = unsafe { x_views[q].slice_mut(j * vr, vr) };
                    let u_row = unsafe { u_views[q].slice_mut(j * vr, vr) };
                    let r = rs[q];
                    if check {
                        let mut res = 0.0;
                        for k in 0..vr {
                            res += (u_row[k] * r[k] * x_row[k] - r[k]).abs();
                        }
                        if res > worst[q] {
                            worst[q] = res;
                        }
                    }
                    let mean: Real = x_row.iter().sum::<Real>() / vr as Real;
                    let inv_mean = 1.0 / mean;
                    for k in 0..vr {
                        let xn = x_row[k] * inv_mean;
                        x_row[k] = xn;
                        u_row[k] = 1.0 / xn;
                    }
                    if let Some(vs) = &u_lo_views {
                        // SAFETY: row j of mirror q is owned by this thread.
                        let lo = unsafe { vs[q].slice_mut(j * vr, vr) };
                        for k in 0..vr {
                            lo[k] = u_row[k] as f32;
                        }
                    }
                }
            }
        },
        |a, c| a.into_iter().zip(c).map(|(x, y)| x.max(y)).collect(),
    )
}

/// `empty[j]` ⇔ target column `j` has no non-zeros (an empty document),
/// written into a caller-owned (workspace) buffer. Shared with the dense
/// baseline so both in-process backends report the same `WMD = +inf` for
/// empty documents.
pub(crate) fn empty_columns_into(c: &Csr, empty: &mut Vec<bool>) {
    empty.clear();
    empty.resize(c.ncols(), true);
    for &j in c.col_idx() {
        empty[j as usize] = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::SyntheticCorpus;

    fn toy() -> SyntheticCorpus {
        SyntheticCorpus::builder()
            .vocab_size(500)
            .num_docs(40)
            .embedding_dim(16)
            .n_topics(4)
            .num_queries(3)
            .query_words(5, 12)
            .seed(17)
            .build()
    }

    /// Every kernel the build can run (mixed only with its feature on).
    fn all_kernels() -> Vec<IterateKernel> {
        let mut ks = vec![
            IterateKernel::Fused { precision: Precision::F64 },
            IterateKernel::Unfused,
        ];
        #[cfg(feature = "mixed-precision")]
        ks.push(IterateKernel::Fused { precision: Precision::Mixed });
        ks
    }

    #[test]
    fn all_kernels_agree() {
        let corpus = toy();
        let pool = Pool::new(4);
        let mut outs = Vec::new();
        let kernels = all_kernels();
        for &kernel in &kernels {
            let solver = SparseSolver::new(SinkhornConfig {
                kernel,
                tolerance: 0.0,
                max_iter: 20,
                ..Default::default()
            });
            outs.push(solver.wmd_one_to_many(&corpus.embeddings, corpus.query(0), &corpus.c, &pool));
        }
        for (kernel, o) in kernels.iter().zip(&outs).skip(1) {
            // Mixed precision is error-gated, not exact; f64 kernels agree
            // to rounding.
            let tol = if kernel.is_mixed() { 1e-6 } else { 1e-9 };
            for (a, b) in o.wmd.iter().zip(&outs[0].wmd) {
                assert!((a - b).abs() < tol * (1.0 + b.abs()), "{kernel:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn thread_count_does_not_change_result() {
        let corpus = toy();
        let solver = SparseSolver::new(SinkhornConfig { tolerance: 0.0, max_iter: 15, ..Default::default() });
        let base = {
            let pool = Pool::new(1);
            solver.wmd_one_to_many(&corpus.embeddings, corpus.query(1), &corpus.c, &pool)
        };
        for p in [2usize, 5, 8] {
            let pool = Pool::new(p);
            let out = solver.wmd_one_to_many(&corpus.embeddings, corpus.query(1), &corpus.c, &pool);
            // The default (fused f64) kernel accumulates each column in
            // ascending source-row order at any thread count → bitwise.
            assert_eq!(out.wmd, base.wmd, "p={p}");
        }
    }

    #[test]
    fn converges_with_tolerance() {
        // Sinkhorn's contraction constant degrades as λ grows (Cuturi's
        // accuracy/speed trade-off): at λ=3 the marginal residual reaches
        // 1e-5 in a few thousand iterations (measured); larger λ values
        // take proportionally longer.
        let corpus = toy();
        let pool = Pool::new(4);
        let solver = SparseSolver::new(SinkhornConfig {
            lambda: 3.0,
            tolerance: 1e-5,
            max_iter: 5000,
            ..Default::default()
        });
        let out = solver.wmd_one_to_many(&corpus.embeddings, corpus.query(0), &corpus.c, &pool);
        assert!(out.converged, "did not converge in 5000 iterations");
        assert!(out.iterations < 5000);
        assert!(out.wmd.iter().all(|v| v.is_finite() && *v >= 0.0));
    }

    #[test]
    fn same_topic_docs_are_closer() {
        let corpus = toy();
        let pool = Pool::new(4);
        let solver = SparseSolver::new(SinkhornConfig::default());
        for (qi, &qt) in corpus.query_topics.iter().enumerate() {
            let out = solver.wmd_one_to_many(&corpus.embeddings, corpus.query(qi), &corpus.c, &pool);
            // Mean WMD to same-topic docs < mean WMD to other-topic docs.
            let (mut same, mut ns, mut other, mut no) = (0.0, 0usize, 0.0, 0usize);
            for (j, &dt) in corpus.doc_topics.iter().enumerate() {
                if dt == qt {
                    same += out.wmd[j];
                    ns += 1;
                } else {
                    other += out.wmd[j];
                    no += 1;
                }
            }
            if ns > 0 && no > 0 {
                assert!(
                    same / ns as f64 <= other / no as f64,
                    "query {qi}: same-topic mean not smaller"
                );
            }
        }
    }

    #[test]
    fn top_k_sorted_and_argmin_consistent() {
        let corpus = toy();
        let pool = Pool::new(2);
        let solver = SparseSolver::new(SinkhornConfig::default());
        let out = solver.wmd_one_to_many(&corpus.embeddings, corpus.query(2), &corpus.c, &pool);
        let top = out.top_k(5);
        assert_eq!(top.len(), 5);
        for w in top.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        assert_eq!(out.argmin(), Some(top[0].0));
    }

    /// `c` with target column `k` emptied (an empty document).
    fn drop_column(c: &Csr, k: usize) -> Csr {
        let mut coo = crate::sparse::Coo::new(c.nrows(), c.ncols());
        for (i, j, v) in c.iter() {
            if j != k {
                coo.push(i, j, v);
            }
        }
        Csr::from_coo(coo)
    }

    #[test]
    fn empty_document_ranks_last_with_infinite_wmd() {
        // Regression: a zero-support column used to leave x_row all zero,
        // update_u divided by the zero mean (u poisoned with NaN) and the
        // epilogue summed nothing — the empty doc scored WMD = 0
        // and won every argmin.
        let corpus = toy();
        let pool = Pool::new(4);
        let k = 7;
        let c = drop_column(&corpus.c, k);
        for kernel in all_kernels() {
            let solver = SparseSolver::new(SinkhornConfig { kernel, ..Default::default() });
            let out = solver.wmd_one_to_many(&corpus.embeddings, corpus.query(0), &c, &pool);
            assert!(
                out.wmd[k].is_infinite() && out.wmd[k] > 0.0,
                "{kernel:?}: empty doc must report +inf, got {}",
                out.wmd[k]
            );
            for (j, v) in out.wmd.iter().enumerate() {
                if j != k {
                    assert!(v.is_finite(), "{kernel:?}: doc {j} poisoned: {v}");
                }
            }
            assert_ne!(out.argmin(), Some(k), "{kernel:?}: empty doc won argmin");
            assert!(
                out.top_k(c.ncols()).iter().all(|&(j, _)| j != k),
                "{kernel:?}: empty doc in top_k"
            );
        }
    }

    #[test]
    fn empty_document_does_not_block_convergence() {
        let corpus = toy();
        let pool = Pool::new(2);
        let c = drop_column(&corpus.c, 0);
        let solver = SparseSolver::new(SinkhornConfig {
            lambda: 3.0,
            tolerance: 1e-5,
            max_iter: 5000,
            ..Default::default()
        });
        let out = solver.wmd_one_to_many(&corpus.embeddings, corpus.query(0), &c, &pool);
        assert!(out.converged, "empty column's undeliverable mass stalled the residual");
    }

    #[test]
    fn top_k_bounded_selection_matches_full_sort_and_breaks_ties_by_index() {
        // Regression: top_k used to fully sort the wmd vector per call;
        // the bounded selection must return the same ranking at every k,
        // with exact ties in ascending-index order (deterministic, and
        // identical to what the old stable full sort produced).
        let out = SolveOutput {
            wmd: vec![3.0, 1.0, 2.0, 1.0, Real::NAN, 0.5, Real::INFINITY, 1.0, 2.0],
            iterations: 1,
            converged: true,
        };
        let mut reference: Vec<(usize, Real)> =
            out.wmd.iter().copied().enumerate().filter(|(_, v)| v.is_finite()).collect();
        reference.sort_by(|a, b| a.1.total_cmp(&b.1)); // stable: ties keep index order
        assert_eq!(reference.len(), 7);
        for k in 0..=out.wmd.len() + 1 {
            let top = out.top_k(k);
            assert_eq!(top.len(), k.min(7), "k={k}");
            assert_eq!(&top[..], &reference[..top.len()], "k={k}");
        }
        assert_eq!(out.top_k(4), vec![(5, 0.5), (1, 1.0), (3, 1.0), (7, 1.0)]);
    }

    #[test]
    fn argmin_and_top_k_ignore_nan_and_inf() {
        let out = SolveOutput {
            wmd: vec![Real::NAN, 2.0, Real::INFINITY, 1.0],
            iterations: 1,
            converged: false,
        };
        assert_eq!(out.argmin(), Some(3));
        assert_eq!(out.top_k(10), vec![(3, 1.0), (1, 2.0)]);
        let none = SolveOutput {
            wmd: vec![Real::NAN, Real::INFINITY],
            iterations: 1,
            converged: false,
        };
        assert_eq!(none.argmin(), None);
        assert!(none.top_k(3).is_empty());
    }

    fn batch_corpus() -> SyntheticCorpus {
        SyntheticCorpus::builder()
            .vocab_size(500)
            .num_docs(40)
            .embedding_dim(16)
            .n_topics(4)
            .num_queries(8)
            .query_words(5, 12)
            .seed(23)
            .build()
    }

    #[test]
    fn solve_batch_agrees_with_solve_across_kernels_and_sizes() {
        let corpus = batch_corpus();
        let pool = Pool::new(4);
        for kernel in all_kernels() {
            // Default tolerance/check cadence so queries converge at
            // different iterations — exercises the per-query masks.
            let solver = SparseSolver::new(SinkhornConfig { kernel, ..Default::default() });
            let preps: Vec<Prepared> = corpus
                .queries
                .iter()
                .map(|q| solver.prepare(&corpus.embeddings, q, &pool))
                .collect();
            let singles: Vec<SolveOutput> =
                preps.iter().map(|p| solver.solve(p, &corpus.c, &pool)).collect();
            for bsz in [1usize, 4, 8] {
                let prefs: Vec<&Prepared> = preps[..bsz].iter().collect();
                let outs = solver.solve_batch(&prefs, &corpus.c, &pool);
                assert_eq!(outs.len(), bsz);
                for (q, (o, s)) in outs.iter().zip(&singles).enumerate() {
                    assert_eq!(o.iterations, s.iterations, "{kernel:?} b={bsz} q={q}");
                    assert_eq!(o.converged, s.converged, "{kernel:?} b={bsz} q={q}");
                    for (a, b) in o.wmd.iter().zip(&s.wmd) {
                        assert!(
                            (a - b).abs() < 1e-9 * (1.0 + b.abs()),
                            "{kernel:?} b={bsz} q={q}: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn solve_batch_is_bitwise_identical_to_solve() {
        // Batched and single-query solves share the per-element
        // accumulation order (column-owned iterate, row-owned update), so
        // the match is bitwise — including in mixed mode, whose f32
        // narrowing is deterministic too.
        let corpus = batch_corpus();
        for p in [1usize, 4] {
            let pool = Pool::new(p);
            for kernel in all_kernels() {
                if kernel == IterateKernel::Unfused {
                    continue; // no batched path (falls back to solve_in)
                }
                let solver = SparseSolver::new(SinkhornConfig { kernel, ..Default::default() });
                let preps: Vec<Prepared> = corpus
                    .queries
                    .iter()
                    .take(4)
                    .map(|q| solver.prepare(&corpus.embeddings, q, &pool))
                    .collect();
                let prefs: Vec<&Prepared> = preps.iter().collect();
                let outs = solver.solve_batch(&prefs, &corpus.c, &pool);
                for (prep, o) in preps.iter().zip(&outs) {
                    let s = solver.solve(prep, &corpus.c, &pool);
                    assert_eq!(o.wmd, s.wmd, "{kernel:?} p={p}");
                }
            }
        }
    }

    #[test]
    fn solve_batch_handles_empty_batch_and_empty_documents() {
        let corpus = batch_corpus();
        let pool = Pool::new(2);
        let solver = SparseSolver::new(SinkhornConfig::default());
        assert!(solver.solve_batch(&[], &corpus.c, &pool).is_empty());
        let k = 3;
        let c = drop_column(&corpus.c, k);
        let preps: Vec<Prepared> = corpus
            .queries
            .iter()
            .take(3)
            .map(|q| solver.prepare(&corpus.embeddings, q, &pool))
            .collect();
        let prefs: Vec<&Prepared> = preps.iter().collect();
        for out in solver.solve_batch(&prefs, &c, &pool) {
            assert!(out.wmd[k].is_infinite() && out.wmd[k] > 0.0);
            assert_ne!(out.argmin(), Some(k));
        }
    }

    #[test]
    fn merge_shards_reassembles_column_slices_bitwise() {
        // Per-column Sinkhorn state is independent of the other columns,
        // so with the early exit disabled (fixed iterations) a column
        // slice solves bitwise-identically to its columns in the full
        // solve — the invariant the sharded dispatch layer rests on.
        let corpus = batch_corpus();
        let pool = Pool::new(1);
        let solver = SparseSolver::new(SinkhornConfig {
            tolerance: 0.0,
            max_iter: 12,
            ..Default::default()
        });
        let prep = solver.prepare(&corpus.embeddings, corpus.query(0), &pool);
        let full = solver.solve(&prep, &corpus.c, &pool);
        let n = corpus.c.ncols();
        for cuts in [vec![0, n], vec![0, n / 2, n], vec![0, 0, n / 3, n]] {
            let parts: Vec<(usize, SolveOutput)> = cuts
                .windows(2)
                .map(|w| {
                    let c = corpus.c.slice_columns(w[0]..w[1]);
                    // Zero-column slices skip the solver, like the shard
                    // runtime does.
                    let out = if c.ncols() == 0 {
                        SolveOutput { wmd: Vec::new(), iterations: 0, converged: true }
                    } else {
                        solver.solve(&prep, &c, &pool)
                    };
                    (w[0], out)
                })
                .collect();
            let merged = SolveOutput::merge_shards(n, &parts);
            assert_eq!(merged.wmd, full.wmd, "cuts {cuts:?}: shard merge must be bitwise");
            assert_eq!(merged.iterations, full.iterations, "cuts {cuts:?}");
        }
    }

    #[test]
    #[should_panic(expected = "tile the target set")]
    fn merge_shards_rejects_gaps() {
        let part = SolveOutput { wmd: vec![1.0, 2.0], iterations: 1, converged: true };
        let _ = SolveOutput::merge_shards(3, &[(0, part)]);
    }

    #[test]
    fn more_iterations_monotonically_stabilize() {
        // The iterate map is a contraction in practice: successive outputs
        // should approach a fixed point (delta shrinks).
        let corpus = toy();
        let pool = Pool::new(4);
        let wmd_at = |iters: usize| {
            let solver = SparseSolver::new(SinkhornConfig {
                tolerance: 0.0,
                max_iter: iters,
                ..Default::default()
            });
            solver.wmd_one_to_many(&corpus.embeddings, corpus.query(0), &corpus.c, &pool).wmd
        };
        let a = wmd_at(5);
        let b = wmd_at(40);
        let c = wmd_at(80);
        let diff_ab: f64 = crate::util::nan_max(a.iter().zip(&b).map(|(x, y)| (x - y).abs()));
        let diff_bc: f64 = crate::util::nan_max(b.iter().zip(&c).map(|(x, y)| (x - y).abs()));
        assert!(diff_bc < diff_ab, "no stabilization: {diff_ab} -> {diff_bc}");
    }

    #[cfg(feature = "mixed-precision")]
    #[test]
    fn mixed_precision_tracks_f64_within_gate() {
        // The solver-level error gate: mixed WMD within 1e-5 relative of
        // the f64 fused path, identical argmin on this corpus.
        let corpus = toy();
        let pool = Pool::new(4);
        let f64_solver = SparseSolver::new(SinkhornConfig {
            kernel: IterateKernel::Fused { precision: Precision::F64 },
            ..Default::default()
        });
        let mixed_solver = SparseSolver::new(SinkhornConfig {
            kernel: IterateKernel::Fused { precision: Precision::Mixed },
            ..Default::default()
        });
        for qi in 0..3 {
            let hi = f64_solver.wmd_one_to_many(&corpus.embeddings, corpus.query(qi), &corpus.c, &pool);
            let lo = mixed_solver.wmd_one_to_many(&corpus.embeddings, corpus.query(qi), &corpus.c, &pool);
            for (a, b) in lo.wmd.iter().zip(&hi.wmd) {
                assert!((a - b).abs() <= 1e-5 * (1.0 + b.abs()), "q={qi}: {a} vs {b}");
            }
            assert_eq!(lo.argmin(), hi.argmin(), "q={qi}");
        }
    }
}
