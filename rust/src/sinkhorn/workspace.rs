//! The reusable per-solve scratch arena — the zero-alloc hot path.
//!
//! Every `solve`/`solve_batch`/`prepare` used to heap-allocate its scratch
//! (the `N × v_r` iterate planes, convergence masks, kernel partials,
//! transposed patterns, …) on every call. At serving rates that is
//! allocator churn and cache-cold memory on the hottest loop in the
//! system. A [`SolveWorkspace`] bundles all of it as **grow-only**
//! buffers: checked out by each solve, retained across solves, so a
//! steady-state serving thread stops touching the allocator once the
//! workspace has seen its largest problem shape.
//!
//! Ownership model (who holds one):
//!
//! * the coordinator's dispatcher thread — one long-lived workspace for
//!   the monolithic sparse path and the in-process dense baseline;
//! * each [`crate::coordinator::ShardSet`] worker — its own workspace,
//!   naturally sized to its column slice;
//! * the pruned retrieval — borrows the caller's workspace for both its
//!   WCD/RWMD scratch and the per-candidate sub-solves;
//! * tests/benches — the thin allocating wrappers (`solve`, `solve_batch`,
//!   `solve_prepared`, `retrieve`) construct a fresh one per call, so the
//!   pre-workspace API keeps working unchanged.
//!
//! Checked-out buffers are **dirty**: every entry point re-shapes and
//! re-fills what it reads (`Dense::reset`, `clear` + `resize`/`extend`),
//! which the dirty-buffer equivalence suite (`tests/workspace_test.rs`)
//! pins down bitwise against fresh-allocation solves.

use crate::dist::DistScratch;
use crate::parallel::NnzRange;
use crate::prune::PruneScratch;
use crate::sparse::ops::{FusedScratch, TransposedPattern};
use crate::sparse::{Dense, Panel32};
use crate::Real;

/// Point-in-time workspace counters, exposed through the coordinator's
/// `workspace:` metrics so buffer reuse is observable in production
/// (per shard: each [`crate::coordinator::ShardBatchOutput`] carries its
/// workers' snapshots).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkspaceStats {
    /// Heap bytes currently retained by the workspace's buffers.
    pub bytes_retained: usize,
    /// Solves that checked this workspace out.
    pub checkouts: u64,
    /// Checkouts that had to grow at least one buffer — in steady state
    /// this stops increasing, which is exactly the zero-alloc property.
    pub grows: u64,
}

impl WorkspaceStats {
    /// Fold another workspace's counters in (bytes and counts both sum) —
    /// how the service aggregates dispatcher + per-shard workspaces.
    pub fn merged(self, other: WorkspaceStats) -> WorkspaceStats {
        WorkspaceStats {
            bytes_retained: self.bytes_retained + other.bytes_retained,
            checkouts: self.checkouts + other.checkouts,
            grows: self.grows + other.grows,
        }
    }
}

/// The arena. Construct once per long-lived solving thread with
/// [`SolveWorkspace::new`] and pass to the `*_in` solver entry points
/// (`SparseSolver::{solve_in, solve_batch_in, prepare_in}`,
/// `DenseSolver::solve_prepared_in`, `CascadeRetrieval::retrieve_in`).
#[derive(Debug, Default)]
pub struct SolveWorkspace {
    /// Per-query iterate planes, one lane per batch slot: `x` (transposed),
    /// the next iterate, and `u`. The dense baseline borrows lanes of the
    /// same arrays for its `x`/`u`/`Kᵀu`/`(K⊙M)v` state.
    pub(crate) x_t: Vec<Dense>,
    pub(crate) x_new: Vec<Dense>,
    pub(crate) u_t: Vec<Dense>,
    /// `empty[j]` ⇔ target column `j` has no support.
    pub(crate) empty: Vec<bool>,
    /// nnz-balanced row partition of the target CSR.
    pub(crate) parts: Vec<NnzRange>,
    /// Column partition of the transposed pattern.
    pub(crate) col_parts: Vec<NnzRange>,
    /// Transposed pattern of `c` (the fused `SDDTMM→DSTMMT` kernels and
    /// the dense baseline's per-iteration `tocsc`).
    pub(crate) pattern: TransposedPattern,
    /// Materialized SDDMM values for the `Unfused` ablation kernel (and
    /// the dense baseline's sparse-multiply output).
    pub(crate) w_buf: Vec<Real>,
    /// Scratch passed into the fused kernels (batch active lists).
    pub(crate) fused: FusedScratch,
    /// f32 shadow panels for `Precision::Mixed`, one lane per batch slot:
    /// narrowed copies of the stationary `Kᵀ` / `K_over_rᵀ` factors and
    /// the `uᵀ` mirror refreshed each iteration. Grow-only like the f64
    /// planes; empty (zero bytes) unless a mixed solve runs.
    pub(crate) kt_lo: Vec<Panel32>,
    pub(crate) kor_lo: Vec<Panel32>,
    pub(crate) u_lo: Vec<Panel32>,
    /// Batch bookkeeping: per-query iteration counts, convergence flags
    /// and active masks.
    pub(crate) iterations: Vec<usize>,
    pub(crate) converged: Vec<bool>,
    pub(crate) active: Vec<bool>,
    /// Per-document convergence state, flat `B × N`: the frozen mask the
    /// kernels skip on, the per-column marginal residual lanes
    /// `update_u*` fills at each check, and the iteration each column
    /// froze at (0 ⇔ never froze — columns start at iteration 1).
    pub(crate) frozen: Vec<bool>,
    pub(crate) resid: Vec<Real>,
    pub(crate) freeze_iter: Vec<u32>,
    /// Active-set compaction scratch: surviving column list, its subset
    /// nnz prefix over the pattern's `col_ptr`, and the nnz-balanced
    /// partition of that prefix.
    pub(crate) active_cols: Vec<u32>,
    pub(crate) act_ptr: Vec<usize>,
    pub(crate) act_parts: Vec<NnzRange>,
    /// dist-layer prepare scratch (query panel, norms, reciprocal masses).
    pub(crate) dist: DistScratch,
    /// Pruned-retrieval scratch (WCD vector, candidate order, supports,
    /// restricted factors).
    pub(crate) prune: PruneScratch,
    checkouts: u64,
    grows: u64,
}

impl SolveWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Cumulative counters — see [`WorkspaceStats`].
    pub fn stats(&self) -> WorkspaceStats {
        WorkspaceStats {
            bytes_retained: self.bytes_retained(),
            checkouts: self.checkouts,
            grows: self.grows,
        }
    }

    /// Heap bytes currently retained across all buffers (capacities, not
    /// lengths — what a future solve can use without allocating).
    pub fn bytes_retained(&self) -> usize {
        use std::mem::size_of;
        let planes: usize = self
            .x_t
            .iter()
            .chain(&self.x_new)
            .chain(&self.u_t)
            .map(|d| d.capacity() * size_of::<Real>())
            .sum();
        let lo_planes: usize = self
            .kt_lo
            .iter()
            .chain(&self.kor_lo)
            .chain(&self.u_lo)
            .map(|p| p.capacity() * size_of::<f32>())
            .sum();
        planes
            + lo_planes
            + self.empty.capacity() * size_of::<bool>()
            + (self.parts.capacity() + self.col_parts.capacity()) * size_of::<NnzRange>()
            + self.pattern.retained_bytes()
            + self.w_buf.capacity() * size_of::<Real>()
            + self.fused.retained_bytes()
            + self.iterations.capacity() * size_of::<usize>()
            + (self.converged.capacity() + self.active.capacity()) * size_of::<bool>()
            + self.frozen.capacity() * size_of::<bool>()
            + self.resid.capacity() * size_of::<Real>()
            + self.freeze_iter.capacity() * size_of::<u32>()
            + self.active_cols.capacity() * size_of::<u32>()
            + self.act_ptr.capacity() * size_of::<usize>()
            + self.act_parts.capacity() * size_of::<NnzRange>()
            + self.dist.retained_bytes()
            + self.prune.retained_bytes()
    }

    /// Start of a solve's checkout: bump the counter, snapshot the
    /// retained bytes so [`SolveWorkspace::end_checkout`] can detect
    /// whether this solve had to grow anything.
    pub(crate) fn begin_checkout(&mut self) -> usize {
        self.checkouts += 1;
        self.bytes_retained()
    }

    /// End of a solve's checkout (pass the value `begin_checkout`
    /// returned): a net capacity increase counts as one grow.
    pub(crate) fn end_checkout(&mut self, bytes_before: usize) {
        if self.bytes_retained() > bytes_before {
            self.grows += 1;
        }
    }

    /// Make sure at least `b` lanes exist in each plane array (new lanes
    /// start empty; the solver shapes them with `Dense::reset`).
    pub(crate) fn ensure_lanes(&mut self, b: usize) {
        for lanes in [&mut self.x_t, &mut self.x_new, &mut self.u_t] {
            while lanes.len() < b {
                lanes.push(Dense::default());
            }
        }
    }

    /// Like [`SolveWorkspace::ensure_lanes`] for the f32 mixed-precision
    /// shadow panels — only mixed solves call this, so f64-only serving
    /// threads never pay for the lanes.
    pub(crate) fn ensure_lo_lanes(&mut self, b: usize) {
        for lanes in [&mut self.kt_lo, &mut self.kor_lo, &mut self.u_lo] {
            while lanes.len() < b {
                lanes.push(Panel32::default());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_workspace_is_empty() {
        let ws = SolveWorkspace::new();
        let s = ws.stats();
        assert_eq!(s.checkouts, 0);
        assert_eq!(s.grows, 0);
        assert_eq!(s.bytes_retained, 0);
    }

    #[test]
    fn checkout_accounting_counts_grows_once_per_growing_solve() {
        let mut ws = SolveWorkspace::new();
        let before = ws.begin_checkout();
        ws.ensure_lanes(2);
        ws.x_t[0].reset(8, 4, 0.0);
        ws.end_checkout(before);
        let s1 = ws.stats();
        assert_eq!(s1.checkouts, 1);
        assert_eq!(s1.grows, 1);
        assert!(s1.bytes_retained >= 8 * 4 * std::mem::size_of::<Real>());
        // Same shape again: no growth.
        let before = ws.begin_checkout();
        ws.ensure_lanes(2);
        ws.x_t[0].reset(8, 4, 1.0);
        ws.end_checkout(before);
        let s2 = ws.stats();
        assert_eq!(s2.checkouts, 2);
        assert_eq!(s2.grows, 1, "steady-state checkout must not count as a grow");
        assert_eq!(s2.bytes_retained, s1.bytes_retained);
    }

    #[test]
    fn stats_merge_sums_fields() {
        let a = WorkspaceStats { bytes_retained: 100, checkouts: 3, grows: 1 };
        let b = WorkspaceStats { bytes_retained: 50, checkouts: 2, grows: 2 };
        let m = a.merged(b);
        assert_eq!(m, WorkspaceStats { bytes_retained: 150, checkouts: 5, grows: 3 });
    }
}
