//! Exact Earth Mover's Distance — the `O(V³ log V)` flow-based baseline
//! the paper compares against (Kusner et al.'s original WMD formulation).
//!
//! Implemented as successive-shortest-path min-cost flow with Johnson
//! potentials on the bipartite transportation graph. Each augmentation
//! saturates a source's remaining supply or a sink's remaining demand, so
//! at most `m + n` Dijkstra passes run — exact, robust to real-valued
//! masses, no simplex degeneracy handling.
//!
//! Used by the test-suite (and `examples/quickstart`) to validate Cuturi's
//! theorem empirically: the Sinkhorn distance converges to the exact EMD
//! as `λ → ∞`.

use crate::Real;

/// Result of an exact transportation solve.
#[derive(Clone, Debug)]
pub struct EmdSolution {
    /// Total transport cost `Σ flow[i][j] · cost[i][j]`.
    pub cost: Real,
    /// Dense transport plan, `m × n` row-major.
    pub flow: Vec<Real>,
    pub m: usize,
    pub n: usize,
}

impl EmdSolution {
    #[inline]
    pub fn flow_at(&self, i: usize, j: usize) -> Real {
        self.flow[i * self.n + j]
    }
}

/// Exact EMD between histograms `supply` (m sources) and `demand`
/// (n sinks), with `cost(i, j)` the unit transport cost. Both histograms
/// must have equal total mass (the WMD setting: both sum to 1).
///
/// Complexity `O((m+n) · mn · log)` — fine for the document sizes where
/// the exact baseline is meaningful (tens of words).
pub fn exact_emd(supply: &[Real], demand: &[Real], cost: impl Fn(usize, usize) -> Real) -> EmdSolution {
    let m = supply.len();
    let n = demand.len();
    assert!(m > 0 && n > 0);
    let total_s: Real = supply.iter().sum();
    let total_d: Real = demand.iter().sum();
    assert!(
        (total_s - total_d).abs() <= 1e-9 * total_s.max(total_d).max(1.0),
        "unbalanced transportation problem: {total_s} vs {total_d}"
    );
    assert!(supply.iter().all(|&s| s >= 0.0) && demand.iter().all(|&d| d >= 0.0));

    // Materialize costs once; validate non-negativity (distances are ≥ 0).
    let mut c = vec![0.0; m * n];
    for i in 0..m {
        for j in 0..n {
            let v = cost(i, j);
            assert!(v >= 0.0 && v.is_finite(), "cost({i},{j}) = {v}");
            c[i * n + j] = v;
        }
    }

    let mut remaining_s = supply.to_vec();
    let mut remaining_d = demand.to_vec();
    let mut flow = vec![0.0; m * n];
    // Johnson potentials for sources and sinks.
    let mut pot_s = vec![0.0; m];
    let mut pot_t = vec![0.0; n];
    const EPS: Real = 1e-15;

    loop {
        // Any remaining mass to ship?
        let live_sources: Vec<usize> =
            (0..m).filter(|&i| remaining_s[i] > EPS).collect();
        if live_sources.is_empty() {
            break;
        }

        // Multi-source Dijkstra over the bipartite residual graph.
        // Nodes: sources 0..m, sinks m..m+n.
        let inf = Real::INFINITY;
        let mut dist = vec![inf; m + n];
        let mut parent = vec![usize::MAX; m + n]; // parent node index
        let mut visited = vec![false; m + n];
        for &s in &live_sources {
            dist[s] = 0.0;
        }
        // Binary heap keyed by distance.
        let mut heap = std::collections::BinaryHeap::new();
        for &s in &live_sources {
            heap.push(HeapItem { dist: 0.0, node: s });
        }
        let mut reached_sink: Option<usize> = None;
        while let Some(HeapItem { dist: d, node }) = heap.pop() {
            if visited[node] {
                continue;
            }
            visited[node] = true;
            if node >= m && remaining_d[node - m] > EPS {
                reached_sink = Some(node - m);
                break;
            }
            if node < m {
                // Forward arcs source i → every sink j (reduced cost).
                let i = node;
                for j in 0..n {
                    let rc = c[i * n + j] + pot_s[i] - pot_t[j];
                    debug_assert!(rc >= -1e-7, "negative reduced cost {rc}");
                    let nd = d + rc.max(0.0);
                    if nd < dist[m + j] {
                        dist[m + j] = nd;
                        parent[m + j] = i;
                        heap.push(HeapItem { dist: nd, node: m + j });
                    }
                }
            } else {
                // Backward arcs sink j → source i exist where flow > 0.
                let j = node - m;
                for i in 0..m {
                    if flow[i * n + j] > EPS {
                        let rc = -(c[i * n + j] + pot_s[i] - pot_t[j]);
                        debug_assert!(rc >= -1e-7);
                        let nd = d + rc.max(0.0);
                        if nd < dist[i] {
                            dist[i] = nd;
                            parent[i] = m + j;
                            heap.push(HeapItem { dist: nd, node: i });
                        }
                    }
                }
            }
        }

        let sink = reached_sink.expect("balanced problem must admit an augmenting path");

        // Update potentials. With early termination, distances of
        // non-finalized nodes are not shortest yet; the standard fix is
        // to cap every update at the target's distance, which preserves
        // non-negative reduced costs on all arcs.
        let dt = dist[m + sink];
        for i in 0..m {
            pot_s[i] += dist[i].min(dt);
        }
        for j in 0..n {
            pot_t[j] += dist[m + j].min(dt);
        }

        // Trace the path back, find the bottleneck.
        let mut path = Vec::new(); // (i, j, forward?)
        let mut node = m + sink;
        let mut bottleneck = remaining_d[sink];
        while parent[node] != usize::MAX {
            let p = parent[node];
            if node >= m {
                // forward arc p (source) → node (sink)
                path.push((p, node - m, true));
            } else {
                // backward arc p (sink) → node (source): reduces flow[node][p-m]
                bottleneck = bottleneck.min(flow[node * n + (p - m)]);
                path.push((node, p - m, false));
            }
            node = p;
        }
        debug_assert!(node < m, "path must start at a source");
        bottleneck = bottleneck.min(remaining_s[node]);
        debug_assert!(bottleneck > 0.0);

        // Apply the augmentation.
        remaining_s[node] -= bottleneck;
        remaining_d[sink] -= bottleneck;
        for &(i, j, forward) in &path {
            if forward {
                flow[i * n + j] += bottleneck;
            } else {
                flow[i * n + j] -= bottleneck;
            }
        }
    }

    let cost_total: Real = (0..m * n).map(|e| flow[e] * c[e]).sum();
    EmdSolution { cost: cost_total, flow, m, n }
}

/// Exact 1-to-1 WMD: EMD between two normalized histograms under the
/// embedding Euclidean metric.
pub fn exact_wmd(
    embeddings: &crate::sparse::Dense,
    a: &crate::corpus::SparseVec,
    b: &crate::corpus::SparseVec,
) -> Real {
    let ai = a.indices();
    let bi = b.indices();
    exact_emd(&a.val, &b.val, |i, j| {
        let x = embeddings.row(ai[i]);
        let y = embeddings.row(bi[j]);
        x.iter().zip(y).map(|(p, q)| (p - q) * (p - q)).sum::<Real>().sqrt()
    })
    .cost
}

/// Max-heap item ordered by **smallest** distance (reversed ordering).
#[derive(PartialEq)]
struct HeapItem {
    dist: Real,
    node: usize,
}

impl Eq for HeapItem {}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other
            .dist
            .partial_cmp(&self.dist)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| other.node.cmp(&self.node))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn identity_transport_is_free() {
        let s = [0.5, 0.5];
        let sol = exact_emd(&s, &s, |i, j| if i == j { 0.0 } else { 10.0 });
        assert!(sol.cost.abs() < 1e-12);
        assert!((sol.flow_at(0, 0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn simple_two_point_transport() {
        // All mass at source 0 must split 0.3/0.7 across sinks.
        let sol = exact_emd(&[1.0], &[0.3, 0.7], |_, j| if j == 0 { 1.0 } else { 2.0 });
        assert!((sol.cost - (0.3 + 1.4)).abs() < 1e-12);
    }

    #[test]
    fn crossing_assignment_resolved_optimally() {
        // cost matrix [[0, 1], [1, 0]] with uniform masses: optimal = 0.
        let sol = exact_emd(&[0.5, 0.5], &[0.5, 0.5], |i, j| if i == j { 0.0 } else { 1.0 });
        assert!(sol.cost.abs() < 1e-12);
        // Anti-diagonal assignment forced:
        let sol2 = exact_emd(&[0.5, 0.5], &[0.5, 0.5], |i, j| if i == j { 1.0 } else { 0.0 });
        assert!(sol2.cost.abs() < 1e-12);
    }

    #[test]
    fn flow_conserves_marginals() {
        let mut rng = Pcg64::new(101);
        for _ in 0..20 {
            let m = rng.range(1, 8);
            let n = rng.range(1, 8);
            let mut s: Vec<f64> = (0..m).map(|_| rng.next_f64() + 0.1).collect();
            let mut d: Vec<f64> = (0..n).map(|_| rng.next_f64() + 0.1).collect();
            let st: f64 = s.iter().sum();
            let dt: f64 = d.iter().sum();
            s.iter_mut().for_each(|x| *x /= st);
            d.iter_mut().for_each(|x| *x /= dt);
            let costs: Vec<f64> = (0..m * n).map(|_| rng.next_f64() * 5.0).collect();
            let sol = exact_emd(&s, &d, |i, j| costs[i * n + j]);
            for i in 0..m {
                let out: f64 = (0..n).map(|j| sol.flow_at(i, j)).sum();
                assert!((out - s[i]).abs() < 1e-9, "row {i} marginal");
            }
            for j in 0..n {
                let inc: f64 = (0..m).map(|i| sol.flow_at(i, j)).sum();
                assert!((inc - d[j]).abs() < 1e-9, "col {j} marginal");
            }
            assert!(sol.flow.iter().all(|&f| f >= -1e-12));
        }
    }

    #[test]
    fn optimal_vs_brute_force_assignment() {
        // Uniform masses over k points: EMD*k = min-cost perfect matching;
        // brute-force over permutations for k ≤ 5.
        let mut rng = Pcg64::new(102);
        for k in 2..=5usize {
            let masses = vec![1.0 / k as f64; k];
            let costs: Vec<f64> = (0..k * k).map(|_| rng.next_f64() * 3.0).collect();
            let sol = exact_emd(&masses, &masses, |i, j| costs[i * k + j]);
            // Brute force all permutations.
            let mut perm: Vec<usize> = (0..k).collect();
            let mut best = f64::INFINITY;
            permute(&mut perm, 0, &mut |p| {
                let c: f64 = p.iter().enumerate().map(|(i, &j)| costs[i * k + j]).sum();
                best = best.min(c);
            });
            let expected = best / k as f64;
            assert!(
                (sol.cost - expected).abs() < 1e-9,
                "k={k}: emd {} vs matching {expected}",
                sol.cost
            );
        }
    }

    fn permute(p: &mut Vec<usize>, i: usize, f: &mut impl FnMut(&[usize])) {
        if i == p.len() {
            f(p);
            return;
        }
        for j in i..p.len() {
            p.swap(i, j);
            permute(p, i + 1, f);
            p.swap(i, j);
        }
    }

    #[test]
    #[should_panic(expected = "unbalanced")]
    fn rejects_unbalanced_masses() {
        let _ = exact_emd(&[1.0], &[0.5], |_, _| 1.0);
    }
}
