//! WCD — the word-centroid-distance lower bound:
//! `WCD(r, c_j) = ‖Xᵀr − Xᵀc_j‖₂ ≤ WMD(r, c_j)` (Jensen/convexity of the
//! norm over the transport plan's marginals).

use crate::corpus::SparseVec;
use crate::parallel::Pool;
use crate::sparse::{Csr, Dense};
use crate::util::SharedSlice;
use crate::Real;

/// Mass-weighted centroid embedding of every target document:
/// `centroids[j, :] = Σ_i c[i, j] · embeddings[i, :]` — one O(nnz·w)
/// corpus pass, reused across queries.
pub fn centroids(embeddings: &Dense, c: &Csr, pool: &Pool) -> Dense {
    let n = c.ncols();
    let w = embeddings.ncols();
    assert_eq!(embeddings.nrows(), c.nrows());
    let mut out = Dense::zeros(n, w);
    // Column-owned accumulation via the transposed pattern (no atomics).
    let tp = crate::sparse::ops::TransposedPattern::build(c);
    let values = c.values();
    let view = SharedSlice::new(out.as_mut_slice());
    let col_parts = tp.column_parts(pool.nthreads());
    pool.run(|tid, _| {
        let part = col_parts[tid];
        crate::sparse::ops::for_each_nnz_in(part, &tp.col_ptr, |e, j| {
            let i = tp.src_row[e] as usize;
            let mass = values[tp.src_pos[e] as usize];
            // SAFETY: column j (row j of `out`) is owned by this thread.
            let row = unsafe { view.slice_mut(j * w, w) };
            crate::sparse::axpy(row, mass, embeddings.row(i));
        });
    });
    out
}

/// Centroid of a single sparse histogram.
pub fn query_centroid(embeddings: &Dense, q: &SparseVec) -> Vec<Real> {
    let w = embeddings.ncols();
    let mut acc = vec![0.0; w];
    for (&i, &mass) in q.idx.iter().zip(&q.val) {
        crate::sparse::axpy(&mut acc, mass, embeddings.row(i as usize));
    }
    acc
}

/// WCD of a query against every document (given precomputed centroids).
pub fn wcd_lower_bound(
    embeddings: &Dense,
    query: &SparseVec,
    doc_centroids: &Dense,
    pool: &Pool,
) -> Vec<Real> {
    let mut out = Vec::new();
    wcd_lower_bound_into(embeddings, query, doc_centroids, pool, &mut out);
    out
}

/// [`wcd_lower_bound`] into a caller-owned buffer — the retrieval
/// workspace retains it across queries.
pub fn wcd_lower_bound_into(
    embeddings: &Dense,
    query: &SparseVec,
    doc_centroids: &Dense,
    pool: &Pool,
    out: &mut Vec<Real>,
) {
    let qc = query_centroid(embeddings, query);
    let n = doc_centroids.nrows();
    out.clear();
    out.resize(n, 0.0);
    let view = SharedSlice::new(out.as_mut_slice());
    pool.parallel_for(n, |range| {
        for j in range {
            let row = doc_centroids.row(j);
            let mut acc = 0.0;
            for (a, b) in qc.iter().zip(row) {
                let d = a - b;
                acc += d * d;
            }
            // SAFETY: disjoint chunks.
            unsafe { view.write(j, acc.sqrt()) };
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{docs_to_csr, SyntheticCorpus};
    use crate::emd::exact_wmd;

    #[test]
    fn centroid_of_single_word_doc_is_embedding() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(50)
            .num_docs(5)
            .embedding_dim(8)
            .num_queries(1)
            .query_words(3, 3)
            .seed(1)
            .build();
        let doc = crate::corpus::SparseVec::from_counts(50, &[(7, 3)]);
        let c = docs_to_csr(50, &[doc]);
        let pool = Pool::new(2);
        let cents = centroids(&corpus.embeddings, &c, &pool);
        for k in 0..8 {
            assert!((cents.get(0, k) - corpus.embeddings.get(7, k)).abs() < 1e-12);
        }
    }

    #[test]
    fn wcd_lower_bounds_exact_wmd() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(200)
            .num_docs(25)
            .embedding_dim(12)
            .num_queries(2)
            .query_words(4, 8)
            .seed(2)
            .build();
        let pool = Pool::new(2);
        let cents = centroids(&corpus.embeddings, &corpus.c, &pool);
        for q in &corpus.queries {
            let wcd = wcd_lower_bound(&corpus.embeddings, q, &cents, &pool);
            for (j, doc) in corpus.docs.iter().enumerate() {
                let exact = exact_wmd(&corpus.embeddings, q, doc);
                assert!(
                    wcd[j] <= exact + 1e-9,
                    "WCD {} exceeds exact WMD {} for doc {j}",
                    wcd[j],
                    exact
                );
            }
        }
    }

    #[test]
    fn parallel_centroids_match_serial() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(150)
            .num_docs(30)
            .embedding_dim(10)
            .num_queries(1)
            .query_words(3, 3)
            .seed(3)
            .build();
        let serial = centroids(&corpus.embeddings, &corpus.c, &Pool::new(1));
        let parallel = centroids(&corpus.embeddings, &corpus.c, &Pool::new(4));
        assert!(serial.max_abs_diff(&parallel) < 1e-12);
    }
}
