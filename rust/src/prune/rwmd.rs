//! RWMD — the relaxed word mover's distance: drop the incoming-marginal
//! constraint, so each query word ships all its mass to the closest word
//! of the target document:
//!
//! `RWMD(r, c_j) = Σ_k r_k · min_{i ∈ supp(c_j)} m(k, i) ≤ EMD(r, c_j)`
//!
//! (Any feasible plan moves `r_k` mass from word `k` at per-unit cost at
//! least the minimum distance, so the relaxation lower-bounds every plan.)
//!
//! Document supports come from the CSC view of the target set
//! ([`TransposedPattern`]): column `j`'s entries are one contiguous span,
//! so per-document support discovery is O(|supp(c_j)|) — batching callers
//! build the pattern once (O(nnz)) and amortize it over every document.

use crate::corpus::SparseVec;
use crate::sparse::ops::TransposedPattern;
use crate::sparse::Dense;
use crate::Real;

/// RWMD of `query` against target document `j` (column of `c`).
///
/// Convenience entry point: builds the CSC view of `c` for one document
/// (O(nnz)). Callers scoring many documents should build the
/// [`TransposedPattern`] once and call [`rwmd_from_pattern`] per document
/// — that is what the retrieval cascade's RWMD stage does.
pub fn rwmd_lower_bound(
    embeddings: &Dense,
    query: &SparseVec,
    c: &crate::sparse::Csr,
    j: usize,
) -> Real {
    let pattern = TransposedPattern::build(c);
    rwmd_from_pattern(embeddings, query, &pattern, j)
}

/// RWMD of `query` against document `j`, reading the support directly out
/// of a prebuilt CSC view — O(|supp(c_j)| · v_r · w), no per-call support
/// materialization.
pub fn rwmd_from_pattern(
    embeddings: &Dense,
    query: &SparseVec,
    pattern: &TransposedPattern,
    j: usize,
) -> Real {
    let span = pattern.col_ptr[j]..pattern.col_ptr[j + 1];
    if span.is_empty() {
        // Empty target document: WMD is +inf (no feasible transport), so
        // the lower bound is too — it never wins an argmin and never
        // triggers an exact evaluation.
        return Real::INFINITY;
    }
    rwmd_over(embeddings, query, span.map(|e| pattern.src_row[e] as usize))
}

/// RWMD given the target document's word support (preferred entry point
/// when the caller already holds supports). An empty support means an
/// empty document: the bound is `+inf`, matching the empty-doc semantics
/// of the exact solver (empty columns score `+inf`, never win argmin).
pub fn rwmd_with_support(embeddings: &Dense, query: &SparseVec, support: &[usize]) -> Real {
    if support.is_empty() {
        return Real::INFINITY;
    }
    rwmd_over(embeddings, query, support.iter().copied())
}

/// The shared kernel: Σ_k r_k · min over the (non-empty) row iterator of
/// ‖e_k − e_i‖.
fn rwmd_over<I>(embeddings: &Dense, query: &SparseVec, rows: I) -> Real
where
    I: Iterator<Item = usize> + Clone,
{
    let w = embeddings.ncols();
    let mut total = 0.0;
    for (&k, &mass) in query.idx.iter().zip(&query.val) {
        let qe = embeddings.row(k as usize);
        let mut best = Real::INFINITY;
        for i in rows.clone() {
            let ye = embeddings.row(i);
            let mut acc = 0.0;
            for d in 0..w {
                let diff = qe[d] - ye[d];
                acc += diff * diff;
            }
            if acc < best {
                best = acc;
            }
        }
        total += mass * best.sqrt();
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::SyntheticCorpus;
    use crate::emd::exact_wmd;

    #[test]
    fn rwmd_lower_bounds_exact_and_is_tighter_than_zero() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(200)
            .num_docs(20)
            .embedding_dim(12)
            .num_queries(2)
            .query_words(4, 8)
            .seed(5)
            .build();
        let pattern = TransposedPattern::build(&corpus.c);
        for q in &corpus.queries {
            for (j, doc) in corpus.docs.iter().enumerate() {
                let exact = exact_wmd(&corpus.embeddings, q, doc);
                let lb = rwmd_from_pattern(&corpus.embeddings, q, &pattern, j);
                assert!(lb <= exact + 1e-9, "RWMD {lb} > exact {exact} (doc {j})");
                assert!(lb >= 0.0);
            }
        }
    }

    #[test]
    fn one_shot_entry_point_matches_pattern_entry_point() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(120)
            .num_docs(8)
            .embedding_dim(8)
            .num_queries(1)
            .query_words(4, 6)
            .seed(17)
            .build();
        let pattern = TransposedPattern::build(&corpus.c);
        let q = corpus.query(0);
        for j in 0..corpus.c.ncols() {
            let a = rwmd_lower_bound(&corpus.embeddings, q, &corpus.c, j);
            let b = rwmd_from_pattern(&corpus.embeddings, q, &pattern, j);
            assert_eq!(a, b, "doc {j}: one-shot and pattern entry points disagree");
        }
    }

    #[test]
    fn empty_support_scores_plus_infinity_not_panic() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(60)
            .num_docs(3)
            .embedding_dim(6)
            .num_queries(1)
            .query_words(3, 3)
            .seed(9)
            .build();
        let q = corpus.query(0);
        // Empty support = empty document: +inf, matching the solver's
        // empty-column semantics (never wins argmin, never crashes).
        assert_eq!(rwmd_with_support(&corpus.embeddings, q, &[]), Real::INFINITY);
        // Same through the pattern path: an all-zero column.
        let doc = crate::corpus::SparseVec::empty(60);
        let full = crate::corpus::SparseVec::from_counts(60, &[(1, 2), (4, 1)]);
        let c = crate::corpus::docs_to_csr(60, &[full, doc]);
        let pattern = TransposedPattern::build(&c);
        assert_eq!(rwmd_from_pattern(&corpus.embeddings, q, &pattern, 1), Real::INFINITY);
        assert!(rwmd_from_pattern(&corpus.embeddings, q, &pattern, 0).is_finite());
    }

    #[test]
    fn rwmd_zero_iff_query_support_subset() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(100)
            .num_docs(4)
            .embedding_dim(8)
            .num_queries(1)
            .query_words(3, 3)
            .seed(6)
            .build();
        let q = corpus.query(0);
        // Target that contains exactly the query words: RWMD = 0.
        let support: Vec<usize> = q.indices();
        assert!(rwmd_with_support(&corpus.embeddings, q, &support).abs() < 1e-12);
        // Distant support: strictly positive.
        let far: Vec<usize> = (0..100).filter(|i| !support.contains(i)).take(3).collect();
        assert!(rwmd_with_support(&corpus.embeddings, q, &far) > 0.0);
    }

    #[test]
    fn combined_bound_valid_and_tighter_than_either() {
        // Neither bound dominates pointwise (on topic-clustered synthetic
        // corpora WCD is often the tighter one — centroids separate well
        // while every doc contains a few near words). The retrieval
        // cascade therefore max-combines the per-stage bounds; verify that
        // the combined bound stays below the exact WMD and improves on
        // each component somewhere.
        let corpus = SyntheticCorpus::builder()
            .vocab_size(300)
            .num_docs(30)
            .embedding_dim(12)
            .num_queries(1)
            .query_words(6, 6)
            .seed(7)
            .build();
        let pool = crate::parallel::Pool::new(2);
        let cents = super::super::wcd::centroids(&corpus.embeddings, &corpus.c, &pool);
        let q = corpus.query(0);
        let wcd = super::super::wcd::wcd_lower_bound(&corpus.embeddings, q, &cents, &pool);
        let pattern = TransposedPattern::build(&corpus.c);
        let mut rwmd_beats_wcd = 0usize;
        let mut wcd_beats_rwmd = 0usize;
        for (j, doc) in corpus.docs.iter().enumerate() {
            let rw = rwmd_from_pattern(&corpus.embeddings, q, &pattern, j);
            let combined = rw.max(wcd[j]);
            let exact = exact_wmd(&corpus.embeddings, q, doc);
            assert!(combined <= exact + 1e-9, "combined bound {combined} > exact {exact}");
            if rw > wcd[j] {
                rwmd_beats_wcd += 1;
            } else if wcd[j] > rw {
                wcd_beats_rwmd += 1;
            }
        }
        // The combination is meaningful: both components win somewhere.
        assert!(rwmd_beats_wcd + wcd_beats_rwmd > 0);
    }
}
