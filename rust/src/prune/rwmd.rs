//! RWMD — the relaxed word mover's distance: drop the incoming-marginal
//! constraint, so each query word ships all its mass to the closest word
//! of the target document:
//!
//! `RWMD(r, c_j) = Σ_k r_k · min_{i ∈ supp(c_j)} m(k, i) ≤ EMD(r, c_j)`
//!
//! (Any feasible plan moves `r_k` mass from word `k` at per-unit cost at
//! least the minimum distance, so the relaxation lower-bounds every plan.)

use crate::corpus::SparseVec;
use crate::sparse::{Csr, Dense};
use crate::Real;

/// RWMD of `query` against target document `j` (column of `c`).
/// Cost: `O(|supp(c_j)| · v_r · w)` — used inside the pruned retrieval
/// loop only for candidates that survive the WCD ordering.
pub fn rwmd_lower_bound(embeddings: &Dense, query: &SparseVec, c: &Csr, j: usize) -> Real {
    // Collect the support of column j. `c` is CSR by vocab rows; for the
    // retrieval loop we fetch via the transposed scan of the column —
    // acceptable because callers batch by document.
    let mut support: Vec<usize> = Vec::new();
    for (row, cols_vals) in (0..c.nrows()).map(|r| (r, c.row(r))) {
        let (cols, _) = cols_vals;
        if cols.binary_search(&(j as u32)).is_ok() {
            support.push(row);
        }
    }
    rwmd_with_support(embeddings, query, &support)
}

/// RWMD given the target document's word support (preferred entry point:
/// the retrieval pipeline precomputes supports from the CSC view).
pub fn rwmd_with_support(embeddings: &Dense, query: &SparseVec, support: &[usize]) -> Real {
    assert!(!support.is_empty(), "empty target document");
    let w = embeddings.ncols();
    let mut total = 0.0;
    for (&k, &mass) in query.idx.iter().zip(&query.val) {
        let qe = embeddings.row(k as usize);
        let mut best = Real::INFINITY;
        for &i in support {
            let ye = embeddings.row(i);
            let mut acc = 0.0;
            for d in 0..w {
                let diff = qe[d] - ye[d];
                acc += diff * diff;
            }
            if acc < best {
                best = acc;
            }
        }
        total += mass * best.sqrt();
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::SyntheticCorpus;
    use crate::emd::exact_wmd;

    #[test]
    fn rwmd_lower_bounds_exact_and_is_tighter_than_zero() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(200)
            .num_docs(20)
            .embedding_dim(12)
            .num_queries(2)
            .query_words(4, 8)
            .seed(5)
            .build();
        for q in &corpus.queries {
            for (j, doc) in corpus.docs.iter().enumerate() {
                let exact = exact_wmd(&corpus.embeddings, q, doc);
                let lb = rwmd_lower_bound(&corpus.embeddings, q, &corpus.c, j);
                assert!(lb <= exact + 1e-9, "RWMD {lb} > exact {exact} (doc {j})");
                assert!(lb >= 0.0);
            }
        }
    }

    #[test]
    fn rwmd_zero_iff_query_support_subset() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(100)
            .num_docs(4)
            .embedding_dim(8)
            .num_queries(1)
            .query_words(3, 3)
            .seed(6)
            .build();
        let q = corpus.query(0);
        // Target that contains exactly the query words: RWMD = 0.
        let support: Vec<usize> = q.indices();
        assert!(rwmd_with_support(&corpus.embeddings, q, &support).abs() < 1e-12);
        // Distant support: strictly positive.
        let far: Vec<usize> = (0..100).filter(|i| !support.contains(i)).take(3).collect();
        assert!(rwmd_with_support(&corpus.embeddings, q, &far) > 0.0);
    }

    #[test]
    fn combined_bound_valid_and_tighter_than_either() {
        // Neither bound dominates pointwise (on topic-clustered synthetic
        // corpora WCD is often the tighter one — centroids separate well
        // while every doc contains a few near words). The retrieval
        // pipeline therefore prunes on max(WCD, RWMD); verify that the
        // combined bound stays below the exact WMD and improves on each
        // component somewhere.
        let corpus = SyntheticCorpus::builder()
            .vocab_size(300)
            .num_docs(30)
            .embedding_dim(12)
            .num_queries(1)
            .query_words(6, 6)
            .seed(7)
            .build();
        let pool = crate::parallel::Pool::new(2);
        let cents = super::super::wcd::centroids(&corpus.embeddings, &corpus.c, &pool);
        let q = corpus.query(0);
        let wcd = super::super::wcd::wcd_lower_bound(&corpus.embeddings, q, &cents, &pool);
        let mut rwmd_beats_wcd = 0usize;
        let mut wcd_beats_rwmd = 0usize;
        for (j, doc) in corpus.docs.iter().enumerate() {
            let rw = rwmd_lower_bound(&corpus.embeddings, q, &corpus.c, j);
            let combined = rw.max(wcd[j]);
            let exact = exact_wmd(&corpus.embeddings, q, doc);
            assert!(combined <= exact + 1e-9, "combined bound {combined} > exact {exact}");
            if rw > wcd[j] {
                rwmd_beats_wcd += 1;
            } else if wcd[j] > rw {
                wcd_beats_rwmd += 1;
            }
        }
        // The combination is meaningful: both components win somewhere.
        assert!(rwmd_beats_wcd + wcd_beats_rwmd > 0);
    }
}
