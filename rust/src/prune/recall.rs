//! Recall@k harness: measure the cascade's speed/quality trade as a
//! number instead of a guess.
//!
//! The reference ranking is the **no-prune cascade** (`"sinkhorn"` alone)
//! — every document evaluated exactly through the same per-candidate
//! sub-solve machinery the budgeted cascades use, so an unbounded cascade
//! reproduces it *identically* (recall@k = 1.0 by construction, the CI
//! smoke gate) and any recall loss is purely a budget effect, never
//! solver noise. Speedup is wall-clock of the reference pass over the
//! cascade pass, both through one retained workspace after a warm-up
//! query.

use crate::corpus::SparseVec;
use crate::parallel::Pool;
use crate::sinkhorn::{SinkhornConfig, SolveWorkspace};
use crate::sparse::ops::TransposedPattern;
use crate::sparse::{Csr, Dense};
use crate::util::json::{obj, Json};
use crate::Real;
use std::time::Instant;

use super::{centroids, CascadeRetrieval, CascadeSpec, PrunedTopK};

/// One measured (cascade spec, k) setting.
#[derive(Clone, Debug)]
pub struct RecallRow {
    /// Rendered cascade spec, e.g. `"wcd:200,lcrwmd:50,sinkhorn"`.
    pub spec: String,
    pub k: usize,
    pub queries: usize,
    /// Mean over queries of |cascade top-k ∩ exact top-k| / |exact top-k|.
    pub recall: f64,
    /// `exact_ms / cascade_ms` — > 1 means the cascade is faster than
    /// evaluating every document exactly.
    pub speedup: f64,
    pub cascade_ms: f64,
    pub exact_ms: f64,
    /// Exact Sinkhorn evaluations across all queries (vs
    /// `total_docs` = documents × queries for the no-prune reference).
    pub exact_evals: usize,
    pub total_docs: usize,
}

/// Run every spec over every query and score against the exact top-k.
#[allow(clippy::too_many_arguments)]
pub fn evaluate_recall(
    embeddings: &Dense,
    c: &Csr,
    queries: &[SparseVec],
    config: SinkhornConfig,
    k: usize,
    specs: &[CascadeSpec],
    pool: &Pool,
) -> Vec<RecallRow> {
    assert!(!queries.is_empty(), "recall evaluation needs at least one query");
    assert!(k >= 1);
    let cents = centroids(embeddings, c, pool);
    let exact = CascadeRetrieval::new(config, CascadeSpec::parse("sinkhorn").unwrap());
    let mut ws = SolveWorkspace::new();
    // Warm-up (grow the workspace once), then the timed pass.
    let _ = exact.retrieve_in(&mut ws, embeddings, &queries[0], c, &cents, pool, k);
    let started = Instant::now();
    let reference: Vec<PrunedTopK> = queries
        .iter()
        .map(|q| exact.retrieve_in(&mut ws, embeddings, q, c, &cents, pool, k))
        .collect();
    let exact_ms = started.elapsed().as_secs_f64() * 1e3;

    specs
        .iter()
        .map(|spec| {
            let retrieval = CascadeRetrieval::new(config, spec.clone());
            let _ = retrieval.retrieve_in(&mut ws, embeddings, &queries[0], c, &cents, pool, k);
            let started = Instant::now();
            let outs: Vec<PrunedTopK> = queries
                .iter()
                .map(|q| retrieval.retrieve_in(&mut ws, embeddings, q, c, &cents, pool, k))
                .collect();
            let cascade_ms = started.elapsed().as_secs_f64() * 1e3;
            let mut recall_sum = 0.0;
            let mut exact_evals = 0;
            let mut total_docs = 0;
            for (out, exact) in outs.iter().zip(&reference) {
                exact_evals += out.stats.exact_evals;
                total_docs += out.stats.total_docs;
                if exact.top.is_empty() {
                    recall_sum += 1.0;
                } else {
                    let hits = out
                        .top
                        .iter()
                        .filter(|(j, _)| exact.top.iter().any(|&(je, _)| je == *j))
                        .count();
                    recall_sum += hits as f64 / exact.top.len() as f64;
                }
            }
            RecallRow {
                spec: spec.render(),
                k,
                queries: queries.len(),
                recall: recall_sum / queries.len() as f64,
                speedup: exact_ms / cascade_ms.max(1e-9),
                cascade_ms,
                exact_ms,
                exact_evals,
                total_docs,
            }
        })
        .collect()
}

/// Synthesize queries from document histograms — the fallback when a
/// corpus ships no query set (ingested snapshots): up to `limit`
/// non-empty documents, strided across the corpus so topical clusters
/// are all represented. Column spans of the CSC view are row-ascending,
/// so the resulting histograms are valid `SparseVec`s; mass is
/// re-normalized to 1.
pub fn queries_from_docs(c: &Csr, limit: usize) -> Vec<SparseVec> {
    let pattern = TransposedPattern::build(c);
    let values = c.values();
    let n = c.ncols();
    let step = (n / limit.max(1)).max(1);
    let mut out = Vec::new();
    let mut j = 0;
    while j < n && out.len() < limit {
        let span = pattern.col_ptr[j]..pattern.col_ptr[j + 1];
        let total: Real =
            span.clone().map(|e| values[pattern.src_pos[e] as usize]).sum();
        if !span.is_empty() && total > 0.0 && total.is_finite() {
            out.push(SparseVec {
                dim: c.nrows(),
                idx: span.clone().map(|e| pattern.src_row[e]).collect(),
                val: span.map(|e| values[pattern.src_pos[e] as usize] / total).collect(),
            });
        }
        j += step;
    }
    out
}

/// The rows as a JSON array — one `BENCH_prune.json` entry per harness
/// run, written through [`crate::bench::merge_bench_json`].
pub fn rows_json(rows: &[RecallRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                obj([
                    ("spec", Json::Str(r.spec.clone())),
                    ("k", Json::Num(r.k as f64)),
                    ("queries", Json::Num(r.queries as f64)),
                    ("recall", Json::Num(r.recall)),
                    ("speedup", Json::Num(r.speedup)),
                    ("cascade_ms", Json::Num(r.cascade_ms)),
                    ("exact_ms", Json::Num(r.exact_ms)),
                    ("exact_evals", Json::Num(r.exact_evals as f64)),
                    ("total_docs", Json::Num(r.total_docs as f64)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{docs_to_csr, SyntheticCorpus};

    fn corpus() -> SyntheticCorpus {
        SyntheticCorpus::builder()
            .vocab_size(400)
            .num_docs(50)
            .embedding_dim(12)
            .n_topics(4)
            .num_queries(3)
            .query_words(5, 9)
            .seed(404)
            .build()
    }

    #[test]
    fn unbounded_cascades_have_perfect_recall() {
        let corpus = corpus();
        let pool = Pool::new(2);
        let specs = [
            CascadeSpec::default(),
            CascadeSpec::parse("wcd,lcrwmd,rwmd,sinkhorn").unwrap(),
            CascadeSpec::parse("wcd,rwmd,sinkhorn").unwrap(),
        ];
        let rows = evaluate_recall(
            &corpus.embeddings,
            &corpus.c,
            &corpus.queries,
            SinkhornConfig::default(),
            5,
            &specs,
            &pool,
        );
        for r in &rows {
            assert_eq!(r.recall, 1.0, "unbounded `{}` must be exact: {r:?}", r.spec);
            assert_eq!(r.total_docs, 50 * 3);
            assert!(r.cascade_ms > 0.0 && r.exact_ms > 0.0);
        }
    }

    #[test]
    fn budgets_cap_exact_evals_and_recall_stays_a_fraction() {
        let corpus = corpus();
        let pool = Pool::new(2);
        let specs = [CascadeSpec::parse("wcd:8,sinkhorn").unwrap()];
        let rows = evaluate_recall(
            &corpus.embeddings,
            &corpus.c,
            &corpus.queries,
            SinkhornConfig::default(),
            5,
            &specs,
            &pool,
        );
        let r = &rows[0];
        assert!(r.exact_evals <= 8 * 3, "budget 8 × 3 queries: {r:?}");
        assert!((0.0..=1.0).contains(&r.recall), "{r:?}");
    }

    #[test]
    fn queries_from_docs_skips_empty_and_normalizes() {
        let mut docs = vec![SparseVec::empty(40)];
        docs.push(SparseVec::from_counts(40, &[(3, 2), (7, 1)]));
        docs.push(SparseVec::from_counts(40, &[(1, 1), (9, 4)]));
        let c = docs_to_csr(40, &docs);
        let qs = queries_from_docs(&c, 8);
        assert_eq!(qs.len(), 2, "the empty document must be skipped");
        for q in &qs {
            assert_eq!(q.dim, 40);
            let mass: Real = q.val.iter().sum();
            assert!((mass - 1.0).abs() < 1e-12);
            for w in q.idx.windows(2) {
                assert!(w[0] < w[1], "indices must be ascending");
            }
        }
    }

    #[test]
    fn rows_serialize_to_json() {
        let rows = vec![RecallRow {
            spec: "wcd,lcrwmd,sinkhorn".into(),
            k: 10,
            queries: 4,
            recall: 1.0,
            speedup: 3.5,
            cascade_ms: 10.0,
            exact_ms: 35.0,
            exact_evals: 64,
            total_docs: 200,
        }];
        let json = rows_json(&rows);
        let text = json.to_string();
        let parsed = Json::parse(&text).unwrap();
        let row = &parsed.as_arr().unwrap()[0];
        assert_eq!(row.get_str("spec"), Some("wcd,lcrwmd,sinkhorn"));
        assert_eq!(row.get("recall").unwrap().as_f64(), Some(1.0));
        assert_eq!(row.get("exact_evals").unwrap().as_usize(), Some(64));
    }
}
