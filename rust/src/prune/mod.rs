//! Pruned retrieval — the lower-bound pipeline from Kusner et al. that
//! the paper cites in §2 (*"Several pruning ideas have been proposed in
//! [7] to speed up the document retrieval process that reduces the number
//! of expensive WMD evaluations per query"*).
//!
//! Two classic lower bounds on WMD:
//!
//! * **WCD** (word-centroid distance): `‖X·r − X·c_j‖₂` — the distance
//!   between mass-weighted centroid embeddings. O(w) per document after
//!   an O(nnz·w) corpus pass. Loose but nearly free.
//! * **RWMD** (relaxed WMD): drop one marginal constraint; each query
//!   word ships all its mass to the *closest* word of the target
//!   document. Much tighter; O(nnz·v_r) per corpus.
//!
//! [`PrunedRetrieval`] composes them: rank all docs by WCD, take the top
//! `k` exactly, then visit the rest in WCD order computing RWMD; a doc
//! whose RWMD exceeds the current k-th best exact WMD is discarded
//! without running Sinkhorn. Both bounds and the final ranking are
//! validated against the exact solver in tests.

pub mod rwmd;
pub mod wcd;

pub use rwmd::rwmd_lower_bound;
pub use wcd::{centroids, wcd_lower_bound, wcd_lower_bound_into};

use crate::corpus::SparseVec;
use crate::parallel::Pool;
use crate::sinkhorn::{Prepared, SinkhornConfig, SolveWorkspace, SparseSolver};
use crate::sparse::ops::TransposedPattern;
use crate::sparse::{Csr, Dense};
use crate::Real;

/// Reusable pruned-retrieval scratch — the WCD vector, candidate order,
/// CSC view of the target set, per-candidate word supports and the
/// restricted factor set. Held inside a [`SolveWorkspace`] (its `prune`
/// section), so one workspace serves both the retrieval bookkeeping and
/// the per-candidate exact sub-solves.
#[derive(Debug, Default)]
pub struct PruneScratch {
    /// Per-document WCD lower bounds.
    wcd: Vec<Real>,
    /// Candidate visit order (ascending WCD).
    order: Vec<usize>,
    /// CSC view of `c` (per-document word supports in O(nnz) total).
    pattern: TransposedPattern,
    /// Current candidate's word support.
    support: Vec<usize>,
    /// Reusable restricted-factor target for the candidate sub-problems.
    sub_prep: Option<Prepared>,
    /// Recycled backing vectors for the per-candidate sub-problem CSR
    /// (reclaimed after each solve via [`Csr::into_parts`]).
    sub_row_ptr: Vec<usize>,
    sub_col_idx: Vec<u32>,
    sub_vals: Vec<Real>,
}

impl PruneScratch {
    /// Heap bytes held by the scratch's backing allocations.
    pub(crate) fn retained_bytes(&self) -> usize {
        use std::mem::size_of;
        let sub = self.sub_prep.as_ref().map_or(0, |p| {
            (p.factors.kt.capacity()
                + p.factors.kor_t.capacity()
                + p.factors.km_t.capacity()
                + p.factors.r.capacity())
                * size_of::<Real>()
        });
        self.wcd.capacity() * size_of::<Real>()
            + (self.order.capacity() + self.support.capacity() + self.sub_row_ptr.capacity())
                * size_of::<usize>()
            + self.pattern.retained_bytes()
            + self.sub_col_idx.capacity() * size_of::<u32>()
            + self.sub_vals.capacity() * size_of::<Real>()
            + sub
    }
}

/// Statistics from one pruned retrieval.
#[derive(Clone, Debug, Default)]
pub struct PruneStats {
    pub total_docs: usize,
    /// Documents whose exact WMD was computed.
    pub exact_evals: usize,
    /// Documents discarded by the RWMD bound.
    pub pruned_by_rwmd: usize,
}

/// Result of a pruned k-NN retrieval: the exact top-k plus statistics.
#[derive(Clone, Debug)]
pub struct PrunedTopK {
    /// `(doc, wmd)` ascending by distance — exact Sinkhorn values.
    pub top: Vec<(usize, Real)>,
    pub stats: PruneStats,
}

/// Merge per-shard pruned retrievals into the global top-k. Each part
/// covers one column slice of the target set and is given as
/// `(col_offset, PrunedTopK)`: local doc ids are rebased by their shard
/// offset, the union is re-ranked (`total_cmp`, so a NaN-free sort), and
/// stats are summed. Every shard must have retrieved at least `k`
/// candidates (or all of its documents) for the merged top-k to be exact
/// — the same local-top-k ⊇ global-top-k argument as any distributed
/// retrieval.
pub fn merge_topk(parts: &[(usize, PrunedTopK)], k: usize) -> PrunedTopK {
    let mut top: Vec<(usize, Real)> = parts
        .iter()
        .flat_map(|(off, p)| p.top.iter().map(move |&(j, d)| (off + j, d)))
        .collect();
    top.sort_by(|a, b| a.1.total_cmp(&b.1));
    top.truncate(k);
    let mut stats = PruneStats::default();
    for (_, p) in parts {
        stats.total_docs += p.stats.total_docs;
        stats.exact_evals += p.stats.exact_evals;
        stats.pruned_by_rwmd += p.stats.pruned_by_rwmd;
    }
    PrunedTopK { top, stats }
}

/// k-NN retrieval with WCD prefetch ordering + RWMD pruning.
pub struct PrunedRetrieval {
    solver: SparseSolver,
    k: usize,
}

impl PrunedRetrieval {
    pub fn new(config: SinkhornConfig, k: usize) -> Self {
        assert!(k >= 1);
        Self { solver: SparseSolver::new(config), k }
    }

    /// Exact top-k under the Sinkhorn WMD, evaluating as few documents as
    /// the bounds allow. `doc_centroids` comes from [`centroids`] (one
    /// corpus-wide precompute, reused across queries).
    ///
    /// Soundness caveat (inherited from Kusner et al.): RWMD lower-bounds
    /// the *exact* EMD; the Sinkhorn distance upper-bounds it. Pruning on
    /// `rwmd > current_kth` is exact for EMD and (slightly conservative ⇒
    /// still safe) for the Sinkhorn distance, because sinkhorn ≥ emd ≥
    /// rwmd for every document.
    pub fn retrieve(
        &self,
        embeddings: &Dense,
        query: &SparseVec,
        c: &Csr,
        doc_centroids: &Dense,
        pool: &Pool,
    ) -> PrunedTopK {
        self.retrieve_in(&mut SolveWorkspace::new(), embeddings, query, c, doc_centroids, pool)
    }

    /// [`PrunedRetrieval::retrieve`] with all retrieval scratch — the WCD
    /// vector, candidate order, CSC view, supports, restricted factors,
    /// the per-candidate sub-problem CSR (recycled through
    /// [`Csr::into_parts`]) — and the exact sub-solves borrowing from one
    /// retained workspace. Once warm, the only per-candidate allocation
    /// left is each sub-solve's one-element `wmd` output vector.
    pub fn retrieve_in(
        &self,
        ws: &mut SolveWorkspace,
        embeddings: &Dense,
        query: &SparseVec,
        c: &Csr,
        doc_centroids: &Dense,
        pool: &Pool,
    ) -> PrunedTopK {
        let n = c.ncols();
        let k = self.k.min(n);
        let mut stats = PruneStats { total_docs: n, ..Default::default() };

        // The prune section moves out of the workspace for the duration
        // of the retrieval, so the candidate sub-solves can check the same
        // workspace out for their own lanes.
        let mut ps = std::mem::take(&mut ws.prune);

        // Phase 1: WCD ordering (cheap) + one transposed pass over `c`
        // for per-document word supports (O(nnz) total — scanning rows
        // per candidate would cost O(N·V) and dwarf the savings).
        wcd_lower_bound_into(embeddings, query, doc_centroids, pool, &mut ps.wcd);
        ps.order.clear();
        ps.order.extend(0..n);
        {
            // total_cmp: a NaN distance (poisoned embedding, degenerate
            // doc) sorts last instead of panicking the whole retrieval.
            let wcd = &ps.wcd;
            ps.order.sort_by(|&a, &b| wcd[a].total_cmp(&wcd[b]));
        }
        ps.pattern.rebuild_from(c);

        // Phase 2: exact WMD for the k WCD-nearest docs. Each candidate
        // is solved on a sub-problem restricted to its word support —
        // zero rows of `c` touch no kernel, and the restriction turns a
        // per-eval O(V·iters) row walk into O(|supp|·v_r·iters).
        let prep = self.solver.prepare_in(ws, embeddings, query, pool);
        let values = c.values();
        // Sub-problems are a few dozen non-zeros: fork/join barriers would
        // dominate, so they run on an inline (1-thread) pool regardless of
        // the caller's parallelism.
        let serial = Pool::new(1);
        let solver = &self.solver;
        let mut top: Vec<(usize, Real)> = Vec::with_capacity(k + 1);
        let mut eval_exact = |j: usize,
                              top: &mut Vec<(usize, Real)>,
                              stats: &mut PruneStats,
                              ws: &mut SolveWorkspace,
                              ps: &mut PruneScratch| {
            let span = ps.pattern.col_ptr[j]..ps.pattern.col_ptr[j + 1];
            {
                let (support, pattern) = (&mut ps.support, &ps.pattern);
                support.clear();
                support.extend(span.clone().map(|e| pattern.src_row[e] as usize));
            }
            // Sub-problem CSR from recycled backing vectors (reclaimed
            // below via `into_parts`): |supp| rows × 1 column.
            let m = ps.support.len();
            {
                let (vals, pattern) = (&mut ps.sub_vals, &ps.pattern);
                vals.clear();
                vals.extend(span.clone().map(|e| values[pattern.src_pos[e] as usize]));
            }
            let mut row_ptr = std::mem::take(&mut ps.sub_row_ptr);
            row_ptr.clear();
            row_ptr.extend(0..=m);
            let mut col_idx = std::mem::take(&mut ps.sub_col_idx);
            col_idx.clear();
            col_idx.resize(m, 0u32);
            let sub_c = crate::sparse::Csr::from_parts(
                m,
                1,
                row_ptr,
                col_idx,
                std::mem::take(&mut ps.sub_vals),
            );
            let sub_prep = ps.sub_prep.get_or_insert_with(Prepared::default);
            prep.factors.restrict_rows_into(&ps.support, &mut sub_prep.factors);
            let d = solver.solve_in(ws, sub_prep, &sub_c, &serial).wmd[0];
            let (_, _, row_ptr, col_idx, vals) = sub_c.into_parts();
            ps.sub_row_ptr = row_ptr;
            ps.sub_col_idx = col_idx;
            ps.sub_vals = vals;
            stats.exact_evals += 1;
            // Non-finite distances (empty doc → +inf, NaN embeddings)
            // never enter the top-k; total_cmp keeps the sort panic-free.
            if d.is_finite() {
                top.push((j, d));
                top.sort_by(|a, b| a.1.total_cmp(&b.1));
                top.truncate(k);
            }
        };
        // Indexed loops (not iterators) because `ps` must be reborrowed
        // mutably inside the body for the candidate evaluations.
        #[allow(clippy::needless_range_loop)]
        for idx in 0..k {
            let j = ps.order[idx];
            eval_exact(j, &mut top, &mut stats, ws, &mut ps);
        }

        // Phase 3: the rest in WCD order, pruned by max(WCD, RWMD) —
        // both lower-bound the exact EMD, so their max is a valid (and
        // tighter) bound; neither dominates pointwise.
        #[allow(clippy::needless_range_loop)]
        for idx in k..n {
            let j = ps.order[idx];
            // The k-th best bound is only valid once k finite candidates
            // are in hand (non-finite evaluations don't enter `top`).
            let kth = if top.len() < k {
                Real::INFINITY
            } else {
                top.last().map(|&(_, d)| d).unwrap_or(Real::INFINITY)
            };
            let lb = {
                let (support, pattern) = (&mut ps.support, &ps.pattern);
                support.clear();
                support.extend(
                    (pattern.col_ptr[j]..pattern.col_ptr[j + 1])
                        .map(|e| pattern.src_row[e] as usize),
                );
                ps.wcd[j].max(rwmd::rwmd_with_support(embeddings, query, &ps.support))
            };
            if lb > kth {
                stats.pruned_by_rwmd += 1;
                continue;
            }
            eval_exact(j, &mut top, &mut stats, ws, &mut ps);
        }
        ws.prune = ps;
        PrunedTopK { top, stats }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::SyntheticCorpus;

    fn corpus() -> SyntheticCorpus {
        SyntheticCorpus::builder()
            .vocab_size(600)
            .num_docs(60)
            .embedding_dim(16)
            .n_topics(4)
            .num_queries(3)
            .query_words(6, 12)
            .seed(303)
            .build()
    }

    #[test]
    fn pruned_topk_equals_bruteforce_topk() {
        let corpus = corpus();
        let pool = Pool::new(2);
        let config = SinkhornConfig {
            lambda: 20.0,
            max_iter: 4000,
            tolerance: 1e-9,
            ..Default::default()
        };
        let cents = centroids(&corpus.embeddings, &corpus.c, &pool);
        let retrieval = PrunedRetrieval::new(config, 5);
        for q in 0..3 {
            let query = corpus.query(q);
            // Brute force.
            let solver = SparseSolver::new(config);
            let brute = solver.wmd_one_to_many(&corpus.embeddings, query, &corpus.c, &pool);
            let brute_top = brute.top_k(5);
            // Pruned.
            let pruned =
                retrieval.retrieve(&corpus.embeddings, query, &corpus.c, &cents, &pool);
            assert_eq!(pruned.top.len(), 5);
            for (i, ((ja, da), (jb, db))) in pruned.top.iter().zip(&brute_top).enumerate() {
                // Distances must agree; doc ids may swap only on exact ties.
                assert!(
                    (da - db).abs() < 1e-6 * (1.0 + db.abs()),
                    "q{q} rank {i}: {ja}:{da} vs {jb}:{db}"
                );
            }
        }
    }

    #[test]
    fn nan_distances_do_not_panic_retrieval() {
        // Poison the embedding of a word that appears only on the document
        // side: the affected documents' WCD/RWMD/WMD all go NaN. Ranking
        // must not panic (f64::total_cmp) and NaN documents must never
        // enter the returned top-k.
        let mut corpus = corpus();
        let query = corpus.query(0).clone();
        let poisoned = (0..corpus.vocab_size())
            .find(|&i| {
                let has_doc_nnz = corpus.c.row_ptr()[i] < corpus.c.row_ptr()[i + 1];
                has_doc_nnz && !query.idx.contains(&(i as u32))
            })
            .expect("a document word outside the query");
        corpus.embeddings.row_mut(poisoned).fill(f64::NAN);
        let pool = Pool::new(2);
        let cents = centroids(&corpus.embeddings, &corpus.c, &pool);
        let retrieval = PrunedRetrieval::new(SinkhornConfig::default(), 5);
        let out = retrieval.retrieve(&corpus.embeddings, &query, &corpus.c, &cents, &pool);
        assert!(!out.top.is_empty(), "finite documents must still rank");
        assert!(out.top.iter().all(|&(_, d)| d.is_finite()));
        for w in out.top.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn sharded_pruned_retrieval_matches_unsharded() {
        // Per-shard retrieval: each shard ranks/prunes its own column
        // slice with its own centroids (centroids of a slice equal the
        // corresponding rows of the full centroid matrix); the merged
        // local top-ks must reproduce the unsharded top-k.
        let corpus = corpus();
        let pool = Pool::new(2);
        let config = SinkhornConfig {
            lambda: 20.0,
            max_iter: 4000,
            tolerance: 1e-9,
            ..Default::default()
        };
        let k = 5;
        let retrieval = PrunedRetrieval::new(config, k);
        let n = corpus.c.ncols();
        let cents = centroids(&corpus.embeddings, &corpus.c, &pool);
        let query = corpus.query(0);
        let whole = retrieval.retrieve(&corpus.embeddings, query, &corpus.c, &cents, &pool);
        for cuts in [vec![0, n / 2, n], vec![0, n / 3, 2 * n / 3, n]] {
            let parts: Vec<(usize, PrunedTopK)> = cuts
                .windows(2)
                .map(|w| {
                    let slice = corpus.c.slice_columns(w[0]..w[1]);
                    let slice_cents = centroids(&corpus.embeddings, &slice, &pool);
                    let local =
                        retrieval.retrieve(&corpus.embeddings, query, &slice, &slice_cents, &pool);
                    (w[0], local)
                })
                .collect();
            let merged = merge_topk(&parts, k);
            assert_eq!(merged.top.len(), k);
            assert_eq!(merged.stats.total_docs, n);
            for (i, ((ja, da), (jb, db))) in merged.top.iter().zip(&whole.top).enumerate() {
                assert!(
                    (da - db).abs() < 1e-6 * (1.0 + db.abs()),
                    "cuts {cuts:?} rank {i}: {ja}:{da} vs {jb}:{db}"
                );
            }
        }
    }

    #[test]
    fn retrieve_in_with_reused_workspace_matches_fresh() {
        // A, then B, then A again through ONE workspace (dirty buffers)
        // must reproduce fresh-workspace retrievals exactly — order,
        // distances and pruning decisions alike.
        let corpus = corpus();
        let pool = Pool::new(2);
        let config = SinkhornConfig {
            lambda: 20.0,
            max_iter: 2000,
            tolerance: 1e-8,
            ..Default::default()
        };
        let cents = centroids(&corpus.embeddings, &corpus.c, &pool);
        let retrieval = PrunedRetrieval::new(config, 4);
        let mut ws = SolveWorkspace::new();
        for q in [0usize, 1, 0] {
            let fresh =
                retrieval.retrieve(&corpus.embeddings, corpus.query(q), &corpus.c, &cents, &pool);
            let reused = retrieval.retrieve_in(
                &mut ws,
                &corpus.embeddings,
                corpus.query(q),
                &corpus.c,
                &cents,
                &pool,
            );
            assert_eq!(fresh.top, reused.top, "q={q}: reused workspace changed the top-k");
            assert_eq!(fresh.stats.exact_evals, reused.stats.exact_evals, "q={q}");
            assert_eq!(fresh.stats.pruned_by_rwmd, reused.stats.pruned_by_rwmd, "q={q}");
        }
        let stats = ws.stats();
        assert!(stats.checkouts > 0, "sub-solves must check the workspace out");
        assert!(stats.bytes_retained > 0);
    }

    #[test]
    fn pruning_actually_prunes() {
        let corpus = corpus();
        let pool = Pool::new(2);
        let config = SinkhornConfig {
            lambda: 20.0,
            max_iter: 2000,
            tolerance: 1e-8,
            ..Default::default()
        };
        let cents = centroids(&corpus.embeddings, &corpus.c, &pool);
        let retrieval = PrunedRetrieval::new(config, 3);
        let out = retrieval.retrieve(&corpus.embeddings, corpus.query(0), &corpus.c, &cents, &pool);
        assert_eq!(out.stats.total_docs, 60);
        assert!(
            out.stats.pruned_by_rwmd > 0,
            "no documents pruned: {:?}",
            out.stats
        );
        assert_eq!(
            out.stats.exact_evals + out.stats.pruned_by_rwmd,
            out.stats.total_docs
        );
    }
}
