//! Pruned retrieval — the lower-bound cascade from Kusner et al. and
//! Atasu et al. that the paper cites in §2 (*"Several pruning ideas have
//! been proposed in [7] to speed up the document retrieval process that
//! reduces the number of expensive WMD evaluations per query"*).
//!
//! Three lower bounds on WMD, composable as cascade stages:
//!
//! * **WCD** (word-centroid distance): `‖Xᵀr − Xᵀc_j‖₂` — distance
//!   between mass-weighted centroid embeddings. O(w) per document after
//!   an O(nnz·w) corpus pass. Loose but nearly free.
//! * **LC-RWMD** (linear-complexity relaxed WMD, Atasu et al.
//!   1711.07227): each *corpus* word ships its mass to the closest query
//!   word — one corpus-wide `z` pass plus an O(nnz) gather. The cheap
//!   middle tier.
//! * **RWMD** (relaxed WMD): each *query* word ships its mass to the
//!   closest word of the target document. Tightest; O(|supp|·v_r·w) per
//!   document.
//!
//! [`CascadeRetrieval`] composes them as a configurable
//! [`CascadeSpec`] (e.g. `"wcd,lcrwmd,sinkhorn"`): every [`BoundStage`]
//! max-combines its bound into the accumulated per-document bound,
//! survivors are re-ranked and cut to the stage budget, and the final
//! Sinkhorn stage evaluates survivors exactly in bound order, pruning
//! once the bound exceeds the current k-th best. Bounds, ranking and the
//! cascade itself are validated against the exact solver in tests; the
//! [`recall`] harness turns budgeted-cascade quality into a measured
//! recall@k number.

pub mod cascade;
pub mod lcrwmd;
pub mod recall;
pub mod rwmd;
pub mod wcd;

pub use cascade::{
    BoundStage, CascadeRetrieval, CascadeSpec, StageCx, StageKind, StageSpec,
};
pub use lcrwmd::lcrwmd_lower_bounds;
pub use recall::{evaluate_recall, queries_from_docs, RecallRow};
pub use rwmd::{rwmd_from_pattern, rwmd_lower_bound, rwmd_with_support};
pub use wcd::{centroids, wcd_lower_bound, wcd_lower_bound_into};

use crate::sinkhorn::Prepared;
use crate::sparse::ops::TransposedPattern;
use crate::Real;

/// Reusable retrieval scratch — the accumulated bound vector, candidate
/// order, CSC view of the target set, per-stage scratch, the current
/// candidate's word support and the restricted factor set. Held inside a
/// [`SolveWorkspace`](crate::sinkhorn::SolveWorkspace) (its `prune`
/// section), so one workspace serves both the retrieval bookkeeping and
/// the per-candidate exact sub-solves.
#[derive(Debug, Default)]
pub struct PruneScratch {
    /// Accumulated (max-combined) per-document lower bounds.
    bound: Vec<Real>,
    /// Surviving candidates, ascending by accumulated bound.
    order: Vec<usize>,
    /// CSC view of `c` (per-document word supports in O(nnz) total).
    pattern: TransposedPattern,
    /// Bound-stage scratch (LC-RWMD `z` vector and friends).
    stage: cascade::StageScratch,
    /// Current candidate's word support.
    support: Vec<usize>,
    /// Reusable restricted-factor target for the candidate sub-problems.
    sub_prep: Option<Prepared>,
    /// Recycled backing vectors for the per-candidate sub-problem CSR
    /// (reclaimed after each solve via [`crate::sparse::Csr::into_parts`]).
    sub_row_ptr: Vec<usize>,
    sub_col_idx: Vec<u32>,
    sub_vals: Vec<Real>,
}

impl PruneScratch {
    /// Heap bytes held by the scratch's backing allocations.
    pub(crate) fn retained_bytes(&self) -> usize {
        use std::mem::size_of;
        let sub = self.sub_prep.as_ref().map_or(0, |p| {
            (p.factors.kt.capacity()
                + p.factors.kor_t.capacity()
                + p.factors.km_t.capacity()
                + p.factors.r.capacity())
                * size_of::<Real>()
        });
        self.bound.capacity() * size_of::<Real>()
            + (self.order.capacity() + self.support.capacity() + self.sub_row_ptr.capacity())
                * size_of::<usize>()
            + self.pattern.retained_bytes()
            + self.stage.retained_bytes()
            + self.sub_col_idx.capacity() * size_of::<u32>()
            + self.sub_vals.capacity() * size_of::<Real>()
            + sub
    }
}

/// Candidates in/out of one cascade stage (the sinkhorn row reports
/// exact evaluations as its `candidates_out`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StageStats {
    pub stage: &'static str,
    pub candidates_in: usize,
    pub candidates_out: usize,
}

/// Statistics from one cascade retrieval.
#[derive(Clone, Debug, Default)]
pub struct PruneStats {
    pub total_docs: usize,
    /// Documents whose exact WMD was computed.
    pub exact_evals: usize,
    /// Documents discarded because their accumulated lower bound exceeded
    /// the k-th best exact distance (stage-budget cuts are visible in
    /// `stages` instead).
    pub pruned_by_bound: usize,
    /// Per-stage candidate flow, in cascade order (bound stages first,
    /// `"sinkhorn"` last).
    pub stages: Vec<StageStats>,
}

/// Result of a pruned k-NN retrieval: the top-k plus statistics. Exact
/// (equal to brute force) whenever the cascade ran unbounded.
#[derive(Clone, Debug)]
pub struct PrunedTopK {
    /// `(doc, wmd)` ascending by distance — exact Sinkhorn values.
    pub top: Vec<(usize, Real)>,
    pub stats: PruneStats,
}

/// Merge per-shard pruned retrievals into the global top-k. Each part
/// covers one column slice of the target set and is given as
/// `(col_offset, PrunedTopK)`: local doc ids are rebased by their shard
/// offset, the union is re-ranked (`total_cmp` with index tie-break, so a
/// NaN-free deterministic sort), and stats are summed stage-wise. Every
/// shard must have retrieved at least `k` candidates (or all of its
/// documents) for the merged top-k to be exact — the same
/// local-top-k ⊇ global-top-k argument as any distributed retrieval.
pub fn merge_topk(parts: &[(usize, PrunedTopK)], k: usize) -> PrunedTopK {
    let mut top: Vec<(usize, Real)> = parts
        .iter()
        .flat_map(|(off, p)| p.top.iter().map(move |&(j, d)| (off + j, d)))
        .collect();
    top.sort_by(|a, b| a.1.total_cmp(&b.1).then_with(|| a.0.cmp(&b.0)));
    top.truncate(k);
    let mut stats = PruneStats::default();
    for (_, p) in parts {
        stats.total_docs += p.stats.total_docs;
        stats.exact_evals += p.stats.exact_evals;
        stats.pruned_by_bound += p.stats.pruned_by_bound;
        // Shards run the same cascade, so stage lists align positionally.
        for (i, st) in p.stats.stages.iter().enumerate() {
            if i == stats.stages.len() {
                stats.stages.push(*st);
            } else {
                debug_assert_eq!(stats.stages[i].stage, st.stage);
                stats.stages[i].candidates_in += st.candidates_in;
                stats.stages[i].candidates_out += st.candidates_out;
            }
        }
    }
    PrunedTopK { top, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::SyntheticCorpus;
    use crate::parallel::Pool;
    use crate::sinkhorn::{SinkhornConfig, SolveWorkspace, SparseSolver};

    fn corpus() -> SyntheticCorpus {
        SyntheticCorpus::builder()
            .vocab_size(600)
            .num_docs(60)
            .embedding_dim(16)
            .n_topics(4)
            .num_queries(3)
            .query_words(6, 12)
            .seed(303)
            .build()
    }

    fn tight_config() -> SinkhornConfig {
        SinkhornConfig { lambda: 20.0, max_iter: 4000, tolerance: 1e-9, ..Default::default() }
    }

    #[test]
    fn cascade_topk_equals_bruteforce_topk() {
        let corpus = corpus();
        let pool = Pool::new(2);
        let config = tight_config();
        let cents = centroids(&corpus.embeddings, &corpus.c, &pool);
        for spec in ["sinkhorn", "wcd,lcrwmd,sinkhorn", "wcd,lcrwmd,rwmd,sinkhorn"] {
            let retrieval = CascadeRetrieval::new(config, CascadeSpec::parse(spec).unwrap());
            for q in 0..3 {
                let query = corpus.query(q);
                // Brute force.
                let solver = SparseSolver::new(config);
                let brute = solver.wmd_one_to_many(&corpus.embeddings, query, &corpus.c, &pool);
                let brute_top = brute.top_k(5);
                // Cascade (unbounded budgets ⇒ exact).
                let pruned =
                    retrieval.retrieve(&corpus.embeddings, query, &corpus.c, &cents, &pool, 5);
                assert_eq!(pruned.top.len(), 5);
                for (i, ((ja, da), (jb, db))) in pruned.top.iter().zip(&brute_top).enumerate() {
                    // Distances must agree; doc ids may swap only on ties.
                    assert!(
                        (da - db).abs() < 1e-6 * (1.0 + db.abs()),
                        "spec {spec} q{q} rank {i}: {ja}:{da} vs {jb}:{db}"
                    );
                }
            }
        }
    }

    #[test]
    fn nan_distances_do_not_panic_retrieval() {
        // Poison the embedding of a word that appears only on the document
        // side: the affected documents' bounds and WMD all go NaN. Ranking
        // must not panic (f64::total_cmp) and NaN documents must never
        // enter the returned top-k.
        let mut corpus = corpus();
        let query = corpus.query(0).clone();
        let poisoned = (0..corpus.vocab_size())
            .find(|&i| {
                let has_doc_nnz = corpus.c.row_ptr()[i] < corpus.c.row_ptr()[i + 1];
                has_doc_nnz && !query.idx.contains(&(i as u32))
            })
            .expect("a document word outside the query");
        corpus.embeddings.row_mut(poisoned).fill(f64::NAN);
        let pool = Pool::new(2);
        let cents = centroids(&corpus.embeddings, &corpus.c, &pool);
        let retrieval = CascadeRetrieval::new(SinkhornConfig::default(), CascadeSpec::default());
        let out = retrieval.retrieve(&corpus.embeddings, &query, &corpus.c, &cents, &pool, 5);
        assert!(!out.top.is_empty(), "finite documents must still rank");
        assert!(out.top.iter().all(|&(_, d)| d.is_finite()));
        for w in out.top.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn sharded_cascade_retrieval_matches_unsharded() {
        // Per-shard retrieval: each shard ranks/prunes its own column
        // slice with its own centroids (centroids of a slice equal the
        // corresponding rows of the full centroid matrix); the merged
        // local top-ks must reproduce the unsharded top-k.
        let corpus = corpus();
        let pool = Pool::new(2);
        let config = tight_config();
        let k = 5;
        let retrieval = CascadeRetrieval::new(config, CascadeSpec::default());
        let n = corpus.c.ncols();
        let cents = centroids(&corpus.embeddings, &corpus.c, &pool);
        let query = corpus.query(0);
        let whole = retrieval.retrieve(&corpus.embeddings, query, &corpus.c, &cents, &pool, k);
        for cuts in [vec![0, n / 2, n], vec![0, n / 3, 2 * n / 3, n]] {
            let parts: Vec<(usize, PrunedTopK)> = cuts
                .windows(2)
                .map(|w| {
                    let slice = corpus.c.slice_columns(w[0]..w[1]);
                    let slice_cents = centroids(&corpus.embeddings, &slice, &pool);
                    let local = retrieval
                        .retrieve(&corpus.embeddings, query, &slice, &slice_cents, &pool, k);
                    (w[0], local)
                })
                .collect();
            let merged = merge_topk(&parts, k);
            assert_eq!(merged.top.len(), k);
            assert_eq!(merged.stats.total_docs, n);
            for (i, ((ja, da), (jb, db))) in merged.top.iter().zip(&whole.top).enumerate() {
                assert!(
                    (da - db).abs() < 1e-6 * (1.0 + db.abs()),
                    "cuts {cuts:?} rank {i}: {ja}:{da} vs {jb}:{db}"
                );
            }
        }
    }

    #[test]
    fn retrieve_in_with_reused_workspace_matches_fresh() {
        // A, then B, then A again through ONE workspace (dirty buffers)
        // must reproduce fresh-workspace retrievals exactly — order,
        // distances and pruning decisions alike.
        let corpus = corpus();
        let pool = Pool::new(2);
        let config =
            SinkhornConfig { lambda: 20.0, max_iter: 2000, tolerance: 1e-8, ..Default::default() };
        let cents = centroids(&corpus.embeddings, &corpus.c, &pool);
        let retrieval = CascadeRetrieval::new(config, CascadeSpec::default());
        let mut ws = SolveWorkspace::new();
        for q in [0usize, 1, 0] {
            let fresh = retrieval
                .retrieve(&corpus.embeddings, corpus.query(q), &corpus.c, &cents, &pool, 4);
            let reused = retrieval.retrieve_in(
                &mut ws,
                &corpus.embeddings,
                corpus.query(q),
                &corpus.c,
                &cents,
                &pool,
                4,
            );
            assert_eq!(fresh.top, reused.top, "q={q}: reused workspace changed the top-k");
            assert_eq!(fresh.stats.exact_evals, reused.stats.exact_evals, "q={q}");
            assert_eq!(fresh.stats.pruned_by_bound, reused.stats.pruned_by_bound, "q={q}");
            assert_eq!(fresh.stats.stages, reused.stats.stages, "q={q}");
        }
        let stats = ws.stats();
        assert!(stats.checkouts > 0, "sub-solves must check the workspace out");
        assert!(stats.bytes_retained > 0);
    }

    #[test]
    fn pruning_actually_prunes_and_stage_flow_balances() {
        let corpus = corpus();
        let pool = Pool::new(2);
        let config =
            SinkhornConfig { lambda: 20.0, max_iter: 2000, tolerance: 1e-8, ..Default::default() };
        let cents = centroids(&corpus.embeddings, &corpus.c, &pool);
        let retrieval = CascadeRetrieval::new(config, CascadeSpec::default());
        let out =
            retrieval.retrieve(&corpus.embeddings, corpus.query(0), &corpus.c, &cents, &pool, 3);
        assert_eq!(out.stats.total_docs, 60);
        assert!(out.stats.pruned_by_bound > 0, "no documents pruned: {:?}", out.stats);
        // Unbounded budgets: every stage passes all candidates through;
        // the sinkhorn stage accounts for every survivor.
        assert_eq!(out.stats.stages.len(), 3);
        for st in &out.stats.stages {
            assert_eq!(st.candidates_in, 60, "{st:?}");
        }
        let sink = out.stats.stages.last().unwrap();
        assert_eq!(sink.stage, "sinkhorn");
        assert_eq!(sink.candidates_out, out.stats.exact_evals);
        assert_eq!(out.stats.exact_evals + out.stats.pruned_by_bound, out.stats.total_docs);
    }
}
