//! The staged bound cascade: retrieval as a pipeline of pluggable
//! [`BoundStage`]s, each tightening a per-document lower bound on the
//! query↔document WMD, followed by exact Sinkhorn evaluation of the
//! survivors.
//!
//! Every stage sees the accumulated bound vector and **max-combines** its
//! own bound into it: each per-stage bound lower-bounds the exact EMD
//! (and the Sinkhorn distance above it), so their running maximum is a
//! valid — and monotonically tightening — bound. After scoring, the
//! surviving candidate list is re-sorted by accumulated bound and cut to
//! the stage's budget, so later (more expensive) stages only pay for the
//! candidates the cheaper bounds could not separate.
//!
//! The stock cascade is `wcd,lcrwmd,sinkhorn` — the near-free centroid
//! ordering, then Atasu et al.'s corpus-wide linear-complexity relaxed
//! WMD, then the exact solve. A per-candidate `rwmd` stage (tighter,
//! O(|supp|·v_r·w) per doc) can be spliced in; `sinkhorn` alone is the
//! no-prune exact baseline.

use crate::corpus::SparseVec;
use crate::parallel::Pool;
use crate::sinkhorn::{Prepared, SinkhornConfig, SolveWorkspace, SparseSolver};
use crate::sparse::ops::TransposedPattern;
use crate::sparse::{Csr, Dense};
use crate::util::SharedSlice;
use crate::Real;

use super::{lcrwmd, rwmd, wcd, PruneScratch, PruneStats, PrunedTopK, StageStats};

/// The bound stages the cascade knows how to build.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StageKind {
    /// Word-centroid distance: `‖Xᵀr − Xᵀc_j‖₂`. O(w) per doc.
    Wcd,
    /// Linear-complexity RWMD (doc→query direction, corpus-wide z pass).
    LcRwmd,
    /// Per-candidate relaxed WMD (query→doc direction). Tightest, priciest.
    Rwmd,
}

impl StageKind {
    pub fn name(&self) -> &'static str {
        match self {
            StageKind::Wcd => "wcd",
            StageKind::LcRwmd => "lcrwmd",
            StageKind::Rwmd => "rwmd",
        }
    }

    fn parse(name: &str) -> Option<StageKind> {
        match name {
            "wcd" => Some(StageKind::Wcd),
            "lcrwmd" => Some(StageKind::LcRwmd),
            "rwmd" => Some(StageKind::Rwmd),
            _ => None,
        }
    }
}

/// One configured bound stage: which bound, and how many candidates may
/// survive it (`0` = unbounded). A stage never cuts below the requested
/// `k`, so budgets bound *work*, not the answer length.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StageSpec {
    pub kind: StageKind,
    pub budget: usize,
}

/// A parsed cascade description, e.g. `"wcd:200,lcrwmd:50,sinkhorn"`:
/// comma-separated `name[:budget]` entries, `sinkhorn` (the exact solve)
/// mandatory and last — its budget caps the number of exact evaluations.
/// `"sinkhorn"` alone is the no-prune exact baseline.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CascadeSpec {
    pub stages: Vec<StageSpec>,
    /// Max exact Sinkhorn evaluations (`0` = unbounded).
    pub sinkhorn_budget: usize,
}

impl Default for CascadeSpec {
    /// The stock three-tier cascade, all budgets unbounded:
    /// `wcd,lcrwmd,sinkhorn`.
    fn default() -> Self {
        CascadeSpec {
            stages: vec![
                StageSpec { kind: StageKind::Wcd, budget: 0 },
                StageSpec { kind: StageKind::LcRwmd, budget: 0 },
            ],
            sinkhorn_budget: 0,
        }
    }
}

impl CascadeSpec {
    pub fn parse(s: &str) -> Result<CascadeSpec, String> {
        let toks: Vec<&str> = s.split(',').map(str::trim).filter(|t| !t.is_empty()).collect();
        if toks.is_empty() {
            return Err("empty cascade spec".into());
        }
        let mut stages = Vec::new();
        let mut sinkhorn_budget = None;
        for (i, tok) in toks.iter().enumerate() {
            let (name, budget) = match tok.split_once(':') {
                Some((n, b)) => {
                    let b: usize = b
                        .trim()
                        .parse()
                        .map_err(|_| format!("bad budget in cascade stage `{tok}`"))?;
                    (n.trim(), b)
                }
                None => (*tok, 0),
            };
            if name == "sinkhorn" {
                if i != toks.len() - 1 {
                    return Err("`sinkhorn` must be the final cascade stage".into());
                }
                sinkhorn_budget = Some(budget);
            } else {
                let kind = StageKind::parse(name)
                    .ok_or_else(|| format!("unknown cascade stage `{name}`"))?;
                if stages.iter().any(|s: &StageSpec| s.kind == kind) {
                    return Err(format!("duplicate cascade stage `{name}`"));
                }
                stages.push(StageSpec { kind, budget });
            }
        }
        let sinkhorn_budget =
            sinkhorn_budget.ok_or_else(|| "cascade must end with `sinkhorn`".to_string())?;
        Ok(CascadeSpec { stages, sinkhorn_budget })
    }

    /// Round-trips through [`CascadeSpec::parse`].
    pub fn render(&self) -> String {
        let mut out = String::new();
        let tok = |out: &mut String, name: &str, budget: usize| {
            if !out.is_empty() {
                out.push(',');
            }
            out.push_str(name);
            if budget != 0 {
                out.push(':');
                out.push_str(&budget.to_string());
            }
        };
        for s in &self.stages {
            tok(&mut out, s.kind.name(), s.budget);
        }
        tok(&mut out, "sinkhorn", self.sinkhorn_budget);
        out
    }

    /// True when no stage cuts candidates: the cascade is guaranteed to
    /// return the exact top-k (bounds only reorder and prune soundly).
    pub fn is_unbounded(&self) -> bool {
        self.sinkhorn_budget == 0 && self.stages.iter().all(|s| s.budget == 0)
    }
}

/// Grow-only scratch shared by the bound stages; lives inside
/// [`PruneScratch`] so the steady state allocates nothing.
#[derive(Debug, Default)]
pub struct StageScratch {
    /// LC-RWMD per-vocabulary-row min distance to the query.
    pub(crate) z: Vec<Real>,
    /// Which vocabulary rows the survivors actually touch.
    pub(crate) z_needed: Vec<bool>,
}

impl StageScratch {
    pub(crate) fn retained_bytes(&self) -> usize {
        self.z.capacity() * std::mem::size_of::<Real>() + self.z_needed.capacity()
    }
}

/// Everything a bound stage may read or tighten. `bound` is indexed by
/// document id (not survivor position) and stages must only *raise* it
/// (max-combine) for documents listed in `survivors`.
pub struct StageCx<'a> {
    pub embeddings: &'a Dense,
    pub query: &'a SparseVec,
    /// `c.values()` — nnz values addressed through `pattern.src_pos`.
    pub values: &'a [Real],
    /// CSC view of the target set.
    pub pattern: &'a TransposedPattern,
    pub doc_centroids: &'a Dense,
    pub pool: &'a Pool,
    pub survivors: &'a [usize],
    pub bound: &'a mut [Real],
    pub scratch: &'a mut StageScratch,
}

/// A pluggable cascade stage: score every surviving candidate, tightening
/// the accumulated lower bound in place.
pub trait BoundStage: Send + Sync {
    fn kind(&self) -> StageKind;
    fn score(&self, cx: &mut StageCx<'_>);
}

/// [`StageKind::Wcd`] — centroid distance, parallel over survivors.
pub struct WcdStage;

impl BoundStage for WcdStage {
    fn kind(&self) -> StageKind {
        StageKind::Wcd
    }

    fn score(&self, cx: &mut StageCx<'_>) {
        let qc = wcd::query_centroid(cx.embeddings, cx.query);
        let (survivors, doc_centroids) = (cx.survivors, cx.doc_centroids);
        let view = SharedSlice::new(cx.bound);
        cx.pool.parallel_for(survivors.len(), |range| {
            for p in range {
                let j = survivors[p];
                let mut acc = 0.0;
                for (a, b) in qc.iter().zip(doc_centroids.row(j)) {
                    let d = a - b;
                    acc += d * d;
                }
                // SAFETY: survivor ids are unique → disjoint writes.
                let cell = unsafe { view.slice_mut(j, 1) };
                cell[0] = cell[0].max(acc.sqrt());
            }
        });
    }
}

/// [`StageKind::LcRwmd`] — one corpus-wide `z` pass (restricted to the
/// vocabulary rows the survivors touch), then an O(|supp|) gather per
/// survivor.
pub struct LcRwmdStage;

impl BoundStage for LcRwmdStage {
    fn kind(&self) -> StageKind {
        StageKind::LcRwmd
    }

    fn score(&self, cx: &mut StageCx<'_>) {
        let v = cx.embeddings.nrows();
        let StageScratch { z, z_needed } = &mut *cx.scratch;
        z_needed.clear();
        z_needed.resize(v, false);
        for &j in cx.survivors {
            for e in cx.pattern.col_ptr[j]..cx.pattern.col_ptr[j + 1] {
                z_needed[cx.pattern.src_row[e] as usize] = true;
            }
        }
        lcrwmd::query_min_dists_into(cx.embeddings, cx.query, z_needed, cx.pool, z);
        let z: &[Real] = z;
        let (survivors, pattern, values) = (cx.survivors, cx.pattern, cx.values);
        let view = SharedSlice::new(cx.bound);
        cx.pool.parallel_for(survivors.len(), |range| {
            for p in range {
                let j = survivors[p];
                let lb = lcrwmd::lcrwmd_from_pattern(values, pattern, z, j);
                // SAFETY: survivor ids are unique → disjoint writes.
                let cell = unsafe { view.slice_mut(j, 1) };
                cell[0] = cell[0].max(lb);
            }
        });
    }
}

/// [`StageKind::Rwmd`] — the per-candidate relaxed WMD, parallel over
/// survivors (supports read straight out of the CSC spans).
pub struct RwmdStage;

impl BoundStage for RwmdStage {
    fn kind(&self) -> StageKind {
        StageKind::Rwmd
    }

    fn score(&self, cx: &mut StageCx<'_>) {
        let (embeddings, query, survivors, pattern) =
            (cx.embeddings, cx.query, cx.survivors, cx.pattern);
        let view = SharedSlice::new(cx.bound);
        cx.pool.parallel_for(survivors.len(), |range| {
            for p in range {
                let j = survivors[p];
                let lb = rwmd::rwmd_from_pattern(embeddings, query, pattern, j);
                // SAFETY: survivor ids are unique → disjoint writes.
                let cell = unsafe { view.slice_mut(j, 1) };
                cell[0] = cell[0].max(lb);
            }
        });
    }
}

fn build_stage(kind: StageKind) -> Box<dyn BoundStage> {
    match kind {
        StageKind::Wcd => Box::new(WcdStage),
        StageKind::LcRwmd => Box::new(LcRwmdStage),
        StageKind::Rwmd => Box::new(RwmdStage),
    }
}

/// k-NN retrieval through a configured bound cascade, ending in exact
/// Sinkhorn evaluation of the survivors.
pub struct CascadeRetrieval {
    solver: SparseSolver,
    spec: CascadeSpec,
    stages: Vec<Box<dyn BoundStage>>,
}

impl CascadeRetrieval {
    pub fn new(config: SinkhornConfig, spec: CascadeSpec) -> Self {
        let stages = spec.stages.iter().map(|s| build_stage(s.kind)).collect();
        Self { solver: SparseSolver::new(config), spec, stages }
    }

    pub fn spec(&self) -> &CascadeSpec {
        &self.spec
    }

    /// One-shot retrieval (fresh workspace). `doc_centroids` comes from
    /// [`wcd::centroids`] — one corpus-wide precompute reused across
    /// queries.
    pub fn retrieve(
        &self,
        embeddings: &Dense,
        query: &SparseVec,
        c: &Csr,
        doc_centroids: &Dense,
        pool: &Pool,
        k: usize,
    ) -> PrunedTopK {
        self.retrieve_in(&mut SolveWorkspace::new(), embeddings, query, c, doc_centroids, pool, k)
    }

    /// Retrieval with all scratch borrowed from one retained workspace —
    /// bound vectors, candidate order, CSC view, stage scratch, restricted
    /// factors and the per-candidate sub-problem CSR are all grow-only.
    #[allow(clippy::too_many_arguments)]
    pub fn retrieve_in(
        &self,
        ws: &mut SolveWorkspace,
        embeddings: &Dense,
        query: &SparseVec,
        c: &Csr,
        doc_centroids: &Dense,
        pool: &Pool,
        k: usize,
    ) -> PrunedTopK {
        let prep = self.solver.prepare_in(ws, embeddings, query, pool);
        self.retrieve_prepared_in(ws, embeddings, query, &prep, c, doc_centroids, pool, k)
    }

    /// [`CascadeRetrieval::retrieve_in`] with the query's factor
    /// precompute already in hand (the dispatcher's `PreparedCache` path).
    ///
    /// Soundness: every stage bound lower-bounds the exact EMD, and
    /// sinkhorn ≥ emd ≥ bound for every document — so pruning on
    /// `bound > current_kth` keeps the exact (Sinkhorn) top-k intact at
    /// unbounded budgets. Budgets trade recall for work; the recall
    /// harness measures exactly that trade.
    #[allow(clippy::too_many_arguments)]
    pub fn retrieve_prepared_in(
        &self,
        ws: &mut SolveWorkspace,
        embeddings: &Dense,
        query: &SparseVec,
        prep: &Prepared,
        c: &Csr,
        doc_centroids: &Dense,
        pool: &Pool,
        k: usize,
    ) -> PrunedTopK {
        self.retrieve_prepared_masked_in(
            ws,
            embeddings,
            query,
            prep,
            c,
            doc_centroids,
            pool,
            k,
            None,
        )
    }

    /// [`CascadeRetrieval::retrieve_prepared_in`] with an optional
    /// admission mask: when `allowed` is given (length `c.ncols()`),
    /// documents with `allowed[j] == false` never enter the candidate
    /// list — the live store's deleted documents and out-of-time-window
    /// documents are bound at `+inf` in effect, exactly like empty
    /// documents. `allowed == None` is bit-for-bit the legacy path.
    #[allow(clippy::too_many_arguments)]
    pub fn retrieve_prepared_masked_in(
        &self,
        ws: &mut SolveWorkspace,
        embeddings: &Dense,
        query: &SparseVec,
        prep: &Prepared,
        c: &Csr,
        doc_centroids: &Dense,
        pool: &Pool,
        k: usize,
        allowed: Option<&[bool]>,
    ) -> PrunedTopK {
        let n = c.ncols();
        let k = k.min(n);
        let mut stats = PruneStats { total_docs: n, ..Default::default() };
        if k == 0 {
            return PrunedTopK { top: Vec::new(), stats };
        }
        if let Some(mask) = allowed {
            assert_eq!(mask.len(), n, "admission mask must cover every document");
        }

        // The prune section moves out of the workspace for the duration of
        // the retrieval, so the candidate sub-solves can check the same
        // workspace out for their own lanes.
        let mut ps = std::mem::take(&mut ws.prune);
        ps.pattern.rebuild_from(c);
        ps.bound.clear();
        ps.bound.resize(n, 0.0);
        ps.order.clear();
        match allowed {
            Some(mask) => ps.order.extend((0..n).filter(|&j| mask[j])),
            None => ps.order.extend(0..n),
        }
        let values = c.values();

        // Bound stages: score all survivors, re-rank by the accumulated
        // bound (ascending; NaN-safe, index tie-break so shards and reruns
        // agree bitwise), cut to the stage budget.
        for (stage, spec) in self.stages.iter().zip(&self.spec.stages) {
            let candidates_in = ps.order.len();
            {
                let PruneScratch { bound, order, pattern, stage: scratch, .. } = &mut ps;
                let mut cx = StageCx {
                    embeddings,
                    query,
                    values,
                    pattern,
                    doc_centroids,
                    pool,
                    survivors: order,
                    bound,
                    scratch,
                };
                stage.score(&mut cx);
            }
            {
                let bound = &ps.bound;
                ps.order
                    .sort_by(|&a, &b| bound[a].total_cmp(&bound[b]).then_with(|| a.cmp(&b)));
            }
            if spec.budget != 0 {
                ps.order.truncate(spec.budget.max(k));
            }
            stats.stages.push(StageStats {
                stage: stage.kind().name(),
                candidates_in,
                candidates_out: ps.order.len(),
            });
        }

        // Sinkhorn stage: exact evaluation in accumulated-bound order.
        // Each candidate is solved on a sub-problem restricted to its word
        // support — zero rows of `c` touch no kernel, and the restriction
        // turns a per-eval O(V·iters) row walk into O(|supp|·v_r·iters).
        // Sub-problems are a few dozen non-zeros: fork/join barriers would
        // dominate, so they run on an inline (1-thread) pool regardless of
        // the caller's parallelism.
        let serial = Pool::new(1);
        let solver = &self.solver;
        let survivors_in = ps.order.len();
        let mut top: Vec<(usize, Real)> = Vec::with_capacity(k + 1);
        let mut eval_exact = |j: usize,
                              top: &mut Vec<(usize, Real)>,
                              stats: &mut PruneStats,
                              ws: &mut SolveWorkspace,
                              ps: &mut PruneScratch| {
            let span = ps.pattern.col_ptr[j]..ps.pattern.col_ptr[j + 1];
            {
                let (support, pattern) = (&mut ps.support, &ps.pattern);
                support.clear();
                support.extend(span.clone().map(|e| pattern.src_row[e] as usize));
            }
            // Sub-problem CSR from recycled backing vectors (reclaimed
            // below via `into_parts`): |supp| rows × 1 column.
            let m = ps.support.len();
            {
                let (vals, pattern) = (&mut ps.sub_vals, &ps.pattern);
                vals.clear();
                vals.extend(span.clone().map(|e| values[pattern.src_pos[e] as usize]));
            }
            let mut row_ptr = std::mem::take(&mut ps.sub_row_ptr);
            row_ptr.clear();
            row_ptr.extend(0..=m);
            let mut col_idx = std::mem::take(&mut ps.sub_col_idx);
            col_idx.clear();
            col_idx.resize(m, 0u32);
            let sub_c = crate::sparse::Csr::from_parts(
                m,
                1,
                row_ptr,
                col_idx,
                std::mem::take(&mut ps.sub_vals),
            );
            let sub_prep = ps.sub_prep.get_or_insert_with(Prepared::default);
            prep.factors.restrict_rows_into(&ps.support, &mut sub_prep.factors);
            let d = solver.solve_in(ws, sub_prep, &sub_c, &serial).wmd[0];
            let (_, _, row_ptr, col_idx, vals) = sub_c.into_parts();
            ps.sub_row_ptr = row_ptr;
            ps.sub_col_idx = col_idx;
            ps.sub_vals = vals;
            stats.exact_evals += 1;
            // Non-finite distances (empty doc → +inf, NaN embeddings)
            // never enter the top-k; total_cmp keeps the sort panic-free.
            if d.is_finite() {
                top.push((j, d));
                top.sort_by(|a, b| a.1.total_cmp(&b.1).then_with(|| a.0.cmp(&b.0)));
                top.truncate(k);
            }
        };
        #[allow(clippy::needless_range_loop)]
        for idx in 0..ps.order.len() {
            let j = ps.order[idx];
            // The k-th best distance only prunes once k finite candidates
            // are in hand (non-finite evaluations don't enter `top`).
            if top.len() >= k {
                let kth = top.last().map_or(Real::INFINITY, |&(_, d)| d);
                // Survivors are sorted by accumulated bound: once one
                // exceeds the k-th best, everything after it does too.
                if ps.bound[j] > kth {
                    stats.pruned_by_bound += ps.order.len() - idx;
                    break;
                }
            }
            if self.spec.sinkhorn_budget != 0 && stats.exact_evals >= self.spec.sinkhorn_budget {
                break;
            }
            eval_exact(j, &mut top, &mut stats, ws, &mut ps);
        }
        stats.stages.push(StageStats {
            stage: "sinkhorn",
            candidates_in: survivors_in,
            candidates_out: stats.exact_evals,
        });
        ws.prune = ps;
        PrunedTopK { top, stats }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parse_render_roundtrip() {
        for s in ["sinkhorn", "wcd,lcrwmd,sinkhorn", "wcd:200,lcrwmd:50,sinkhorn:25",
            "wcd,rwmd:10,sinkhorn", "wcd,lcrwmd,rwmd,sinkhorn"]
        {
            let spec = CascadeSpec::parse(s).unwrap();
            assert_eq!(spec.render(), s, "roundtrip failed for `{s}`");
            assert_eq!(CascadeSpec::parse(&spec.render()).unwrap(), spec);
        }
        assert_eq!(CascadeSpec::default().render(), "wcd,lcrwmd,sinkhorn");
        assert!(CascadeSpec::default().is_unbounded());
        assert!(!CascadeSpec::parse("wcd:9,sinkhorn").unwrap().is_unbounded());
    }

    #[test]
    fn spec_parse_rejects_malformed() {
        for s in [
            "",
            "wcd",                    // no sinkhorn
            "sinkhorn,wcd",           // sinkhorn not last
            "wcd,wcd,sinkhorn",       // duplicate stage
            "warp,sinkhorn",          // unknown stage
            "wcd:abc,sinkhorn",       // bad budget
            "wcd:-3,sinkhorn",        // negative budget
        ] {
            assert!(CascadeSpec::parse(s).is_err(), "`{s}` should be rejected");
        }
        // Whitespace is tolerated.
        let spec = CascadeSpec::parse(" wcd : 16 , lcrwmd , sinkhorn ").unwrap();
        assert_eq!(spec.render(), "wcd:16,lcrwmd,sinkhorn");
    }

    #[test]
    fn budget_never_cuts_below_k() {
        use crate::corpus::SyntheticCorpus;
        let corpus = SyntheticCorpus::builder()
            .vocab_size(200)
            .num_docs(30)
            .embedding_dim(10)
            .num_queries(1)
            .query_words(4, 6)
            .seed(77)
            .build();
        let pool = Pool::new(2);
        let cents = wcd::centroids(&corpus.embeddings, &corpus.c, &pool);
        let spec = CascadeSpec::parse("wcd:1,lcrwmd:1,sinkhorn").unwrap();
        let retrieval = CascadeRetrieval::new(SinkhornConfig::default(), spec);
        let out =
            retrieval.retrieve(&corpus.embeddings, corpus.query(0), &corpus.c, &cents, &pool, 5);
        assert_eq!(out.top.len(), 5, "budget 1 must still yield k=5 results");
        for st in &out.stats.stages {
            if st.stage != "sinkhorn" {
                assert_eq!(st.candidates_out, 5, "stage {} cut below k", st.stage);
            }
        }
    }

    #[test]
    fn admission_mask_excludes_documents_and_none_is_bitwise_legacy() {
        use crate::corpus::SyntheticCorpus;
        let corpus = SyntheticCorpus::builder()
            .vocab_size(200)
            .num_docs(30)
            .embedding_dim(10)
            .num_queries(1)
            .query_words(4, 6)
            .seed(79)
            .build();
        let pool = Pool::new(1);
        let retrieval = CascadeRetrieval::new(SinkhornConfig::default(), CascadeSpec::default());
        let cents = wcd::centroids(&corpus.embeddings, &corpus.c, &pool);
        let solver = SparseSolver::new(SinkhornConfig::default());
        let prep = solver.prepare(&corpus.embeddings, corpus.query(0), &pool);
        let mut ws = SolveWorkspace::new();
        let unmasked = retrieval.retrieve_prepared_in(
            &mut ws, &corpus.embeddings, corpus.query(0), &prep, &corpus.c, &cents, &pool, 5,
        );
        // Mask out the unmasked winners: none of them may come back.
        let mut allowed = vec![true; corpus.c.ncols()];
        for &(j, _) in &unmasked.top {
            allowed[j] = false;
        }
        let masked = retrieval.retrieve_prepared_masked_in(
            &mut ws,
            &corpus.embeddings,
            corpus.query(0),
            &prep,
            &corpus.c,
            &cents,
            &pool,
            5,
            Some(&allowed),
        );
        assert_eq!(masked.top.len(), 5);
        for (j, _) in &masked.top {
            assert!(allowed[*j], "masked-out document {j} surfaced");
        }
        // An all-true mask is the identity.
        let all = vec![true; corpus.c.ncols()];
        let same = retrieval.retrieve_prepared_masked_in(
            &mut ws,
            &corpus.embeddings,
            corpus.query(0),
            &prep,
            &corpus.c,
            &cents,
            &pool,
            5,
            Some(&all),
        );
        assert_eq!(same.top, unmasked.top);
    }

    #[test]
    fn sinkhorn_budget_caps_exact_evals() {
        use crate::corpus::SyntheticCorpus;
        let corpus = SyntheticCorpus::builder()
            .vocab_size(200)
            .num_docs(40)
            .embedding_dim(10)
            .num_queries(1)
            .query_words(4, 6)
            .seed(78)
            .build();
        let pool = Pool::new(2);
        let cents = wcd::centroids(&corpus.embeddings, &corpus.c, &pool);
        let spec = CascadeSpec::parse("wcd,sinkhorn:7").unwrap();
        let retrieval = CascadeRetrieval::new(SinkhornConfig::default(), spec);
        let out =
            retrieval.retrieve(&corpus.embeddings, corpus.query(0), &corpus.c, &cents, &pool, 3);
        assert!(out.stats.exact_evals <= 7, "{:?}", out.stats);
        assert_eq!(out.top.len(), 3);
    }
}
