//! LC-RWMD — the *linear-complexity* relaxed WMD of Atasu et al.
//! (arXiv:1711.07227): bound one query against the **whole corpus** in a
//! single pass, instead of per-document.
//!
//! The trick is to relax the *outgoing* marginal (the transpose of the
//! per-document RWMD direction): each corpus word ships all its mass to
//! the closest **query** word. The per-unit shipping cost
//!
//! `z[i] = min_{k ∈ supp(r)} ‖e_i − e_k‖`
//!
//! depends only on the vocabulary word `i` and the query — not on the
//! document — so it is computed **once** for every vocabulary word that
//! actually occurs in the corpus (O(V′·v_r·w), V′ = occupied vocab rows),
//! and every document's bound is then a plain weighted sum gathered
//! through the CSC view:
//!
//! `LCRWMD(r, c_j) = Σ_{i ∈ supp(c_j)} c[i, j] · z[i] ≤ EMD(r, c_j)`
//!
//! — O(nnz) for the entire corpus. Compare per-document RWMD at
//! O(nnz·v_r·w) total: LC-RWMD is the cheap middle tier of the retrieval
//! cascade, between the near-free WCD ordering and the per-candidate
//! RWMD refinement.
//!
//! Empty documents score `+inf` (the exact solver's empty-column
//! semantics), not the vacuous Σ over nothing = 0.

use crate::corpus::SparseVec;
use crate::parallel::Pool;
use crate::sparse::ops::TransposedPattern;
use crate::sparse::{Csr, Dense};
use crate::util::SharedSlice;
use crate::Real;

/// Compute `z[i] = min_k ‖e_i − e_k‖` over the query's words, for every
/// vocabulary row `i` with `needed[i]` set (others are left at 0 and must
/// not be read). Parallel over the vocabulary; the output buffer is
/// caller-owned and grow-only.
pub fn query_min_dists_into(
    embeddings: &Dense,
    query: &SparseVec,
    needed: &[bool],
    pool: &Pool,
    z: &mut Vec<Real>,
) {
    let v = embeddings.nrows();
    assert_eq!(needed.len(), v);
    let w = embeddings.ncols();
    z.clear();
    z.resize(v, 0.0);
    let view = SharedSlice::new(z.as_mut_slice());
    pool.parallel_for(v, |range| {
        for i in range {
            if !needed[i] {
                continue;
            }
            let ye = embeddings.row(i);
            let mut best = Real::INFINITY;
            for &k in &query.idx {
                let qe = embeddings.row(k as usize);
                let mut acc = 0.0;
                for d in 0..w {
                    let diff = qe[d] - ye[d];
                    acc += diff * diff;
                }
                if acc < best {
                    best = acc;
                }
            }
            // SAFETY: disjoint vocabulary chunks.
            unsafe { view.write(i, best.sqrt()) };
        }
    });
}

/// Gather one document's LC-RWMD bound out of the CSC view:
/// `Σ_e values[src_pos[e]] · z[src_row[e]]` over column `j`'s span.
/// Empty columns score `+inf`.
pub fn lcrwmd_from_pattern(
    values: &[Real],
    pattern: &TransposedPattern,
    z: &[Real],
    j: usize,
) -> Real {
    let span = pattern.col_ptr[j]..pattern.col_ptr[j + 1];
    if span.is_empty() {
        return Real::INFINITY;
    }
    let mut acc = 0.0;
    for e in span {
        acc += values[pattern.src_pos[e] as usize] * z[pattern.src_row[e] as usize];
    }
    acc
}

/// LC-RWMD of `query` against every document of `c` — the convenience
/// (allocating) entry point used by tests and one-shot callers. The
/// cascade's LC-RWMD stage runs the same two kernels through the
/// workspace scratch instead.
pub fn lcrwmd_lower_bounds(
    embeddings: &Dense,
    query: &SparseVec,
    c: &Csr,
    pool: &Pool,
) -> Vec<Real> {
    let pattern = TransposedPattern::build(c);
    let mut needed = vec![false; c.nrows()];
    for &i in pattern.src_row.iter() {
        needed[i as usize] = true;
    }
    let mut z = Vec::new();
    query_min_dists_into(embeddings, query, &needed, pool, &mut z);
    let values = c.values();
    (0..c.ncols()).map(|j| lcrwmd_from_pattern(values, &pattern, &z, j)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::SyntheticCorpus;
    use crate::emd::exact_wmd;

    #[test]
    fn lcrwmd_lower_bounds_exact_wmd() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(250)
            .num_docs(25)
            .embedding_dim(12)
            .num_queries(2)
            .query_words(4, 8)
            .seed(23)
            .build();
        let pool = Pool::new(2);
        for q in &corpus.queries {
            let lb = lcrwmd_lower_bounds(&corpus.embeddings, q, &corpus.c, &pool);
            for (j, doc) in corpus.docs.iter().enumerate() {
                let exact = exact_wmd(&corpus.embeddings, q, doc);
                assert!(
                    lb[j] <= exact + 1e-9,
                    "LC-RWMD {} > exact {exact} for doc {j}",
                    lb[j]
                );
                assert!(lb[j] >= 0.0);
            }
        }
    }

    #[test]
    fn lcrwmd_zero_when_document_words_subset_of_query() {
        // Every document word at zero distance from a query word → the
        // relaxed plan ships everything for free.
        let corpus = SyntheticCorpus::builder()
            .vocab_size(80)
            .num_docs(2)
            .embedding_dim(8)
            .num_queries(1)
            .query_words(4, 4)
            .seed(31)
            .build();
        let q = corpus.query(0);
        let idx = q.indices();
        let doc = crate::corpus::SparseVec::from_counts(
            80,
            &[(idx[0] as u32, 2), (idx[1] as u32, 1)],
        );
        let c = crate::corpus::docs_to_csr(80, &[doc]);
        let pool = Pool::new(1);
        let lb = lcrwmd_lower_bounds(&corpus.embeddings, q, &c, &pool);
        assert!(lb[0].abs() < 1e-12, "subset support must bound at zero, got {}", lb[0]);
    }

    #[test]
    fn empty_document_scores_plus_infinity() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(60)
            .num_docs(2)
            .embedding_dim(6)
            .num_queries(1)
            .query_words(3, 3)
            .seed(37)
            .build();
        let full = crate::corpus::SparseVec::from_counts(60, &[(2, 1), (5, 2)]);
        let empty = crate::corpus::SparseVec::empty(60);
        let c = crate::corpus::docs_to_csr(60, &[full, empty]);
        let pool = Pool::new(1);
        let lb = lcrwmd_lower_bounds(&corpus.embeddings, corpus.query(0), &c, &pool);
        assert!(lb[0].is_finite());
        assert_eq!(lb[1], Real::INFINITY);
    }

    #[test]
    fn parallel_min_dists_match_serial() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(150)
            .num_docs(15)
            .embedding_dim(10)
            .num_queries(1)
            .query_words(5, 7)
            .seed(41)
            .build();
        let q = corpus.query(0);
        let needed = vec![true; 150];
        let mut serial = Vec::new();
        let mut parallel = Vec::new();
        query_min_dists_into(&corpus.embeddings, q, &needed, &Pool::new(1), &mut serial);
        query_min_dists_into(&corpus.embeddings, q, &needed, &Pool::new(4), &mut parallel);
        assert_eq!(serial, parallel, "z must be pool-size invariant");
    }
}
