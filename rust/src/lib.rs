//! # sinkhorn-wmd
//!
//! A shared-memory parallel Sinkhorn-Knopp solver for the Word Mover's
//! Distance (WMD), reproducing *"An Efficient Shared-memory Parallel
//! Sinkhorn-Knopp Algorithm to Compute the Word Mover's Distance"*
//! (Tithi & Petrini, 2020).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L3 (this crate)** — the paper's contribution: a sparse, fused
//!   `SDDMM_SpMM` Sinkhorn iteration with nnz-balanced work partitioning
//!   over a hand-rolled OpenMP-style thread pool, wrapped in a query
//!   service (router → batcher → scheduler → workers).
//! * **L2** — the dense baseline written in JAX (`python/compile/model.py`),
//!   AOT-lowered to HLO text and executed from Rust through PJRT
//!   ([`runtime`]). Python never runs on the request path.
//! * **L1** — Pallas kernels for the compute hot-spots
//!   (`python/compile/kernels/`), lowered into the same HLO artifacts.
//!
//! ## Unsafe policy
//!
//! Every `unsafe` block lives in one of the audited modules listed in
//! [`testing::lint::UNSAFE_AUDITED`], carries a `SAFETY:` comment, and every
//! `unsafe fn` documents its contract under a `# Safety` heading. The
//! `strict-checks` cargo feature turns the honor-system partition contract of
//! [`util::SharedSlice`] into a runtime-verified one (see
//! `README.md` § Correctness tooling), and `cargo run --bin lint-rules`
//! enforces the policy mechanically in CI.
//!
//! ## Quickstart
//!
//! ```no_run
//! use sinkhorn_wmd::corpus::SyntheticCorpus;
//! use sinkhorn_wmd::sinkhorn::{SinkhornConfig, SparseSolver};
//! use sinkhorn_wmd::parallel::Pool;
//!
//! let corpus = SyntheticCorpus::builder()
//!     .vocab_size(10_000)
//!     .num_docs(500)
//!     .embedding_dim(300)
//!     .seed(42)
//!     .build();
//! let pool = Pool::new(8);
//! let solver = SparseSolver::new(SinkhornConfig::default());
//! let prep = solver.prepare(&corpus.embeddings, corpus.query(0), &pool);
//! let wmd = solver.solve(&prep, &corpus.c, &pool);
//! println!("closest doc: {:?}", wmd.argmin());
//! ```
#![deny(unsafe_op_in_unsafe_fn)]

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod corpus;
pub mod dist;
pub mod emd;
pub mod parallel;
pub mod prune;
pub mod runtime;
pub mod sinkhorn;
pub mod sparse;
pub mod testing;
pub mod util;

/// Crate-wide floating point type for solver state (the paper uses fp64).
pub type Real = f64;
