//! SDDMM — *sampled* dense-dense matrix multiplication — and the **panel
//! primitives** the fused kernel family is built on.
//!
//! For the Sinkhorn iterate `v = c ⊘ (Kᵀ@u)`, the dense product `Kᵀ@u`
//! (`V×N`, 91.9 % of the Python baseline's runtime, Table 1) is needed
//! only where `c` is non-zero (~0.0035 % of entries). The kernel computes
//! exactly those `nnz(c)` dot products:
//!
//! `w[e] = combine(c.values[e], ⟨KTᵀ[row(e), :], uᵀ[col(e), :]⟩)`
//!
//! Both operands are stored transposed (`V×v_r` and `N×v_r` row-major) so
//! the inner dot is unit-stride on both sides — the paper's "on the fly
//! transpose for unit stride data access".
//!
//! Exports:
//!
//! * [`PanelElem`] / [`Panel`] — the scalar-type seam of the fused
//!   `SDDTMM→DSTMMT` family ([`crate::sparse::ops::fused`]): a panel
//!   element knows how to run the unit-stride dot and widening axpy over
//!   the dense `v_r` panels with fixed-width chunked accumulators, in f64
//!   (bitwise-compatible with the classic [`crate::sparse::dot`]) or in
//!   f32 (8-wide lanes, widened to f64 once per reduction — the
//!   mixed-precision compute panels).
//! * [`sddmm`] / [`sddmm_serial`] — the standalone SDDMM used by the
//!   `Unfused` ablation baseline (and tests).

use super::for_each_nnz_in;
use crate::parallel::{NnzRange, Pool};
use crate::sparse::{dot, Csr, Dense, Panel32};
use crate::util::SharedSlice;
use crate::Real;

/// A scalar type the fused kernels' dense inner loops can run in. The
/// contract keeps every cross-element *reduction* in f64 (`dot` returns
/// f64, `axpy` accumulates into an f64 row): only the panel operands and
/// their products drop precision in the f32 instantiation.
pub trait PanelElem: Copy + Send + Sync + 'static {
    /// Narrow from the solver's f64 master value.
    fn from_real(x: Real) -> Self;
    /// Unit-stride panel dot product, widened to f64.
    fn dot(a: &[Self], b: &[Self]) -> Real;
    /// `out[k] += w · b[k]` with f64 accumulation (widening axpy).
    fn axpy(out: &mut [Real], w: Real, b: &[Self]);
}

impl PanelElem for f64 {
    #[inline(always)]
    fn from_real(x: Real) -> f64 {
        x
    }

    /// Delegates to the classic 4-way-unrolled [`crate::sparse::dot`] —
    /// the f64 instantiation of the fused family is bitwise identical to
    /// the pre-family kernels.
    #[inline(always)]
    fn dot(a: &[f64], b: &[f64]) -> Real {
        dot(a, b)
    }

    #[inline(always)]
    fn axpy(out: &mut [Real], w: Real, b: &[f64]) {
        crate::sparse::axpy(out, w, b);
    }
}

impl PanelElem for f32 {
    #[inline(always)]
    fn from_real(x: Real) -> f32 {
        x as f32
    }

    /// 8-wide f32 lane accumulators (twice the f64 kernel's SIMD width on
    /// AVX), widened to f64 once at the lane reduction. Worst-case
    /// relative error of the f32 product accumulation is `O(v_r · ε_f32)`
    /// ≈ 3e-6 at the paper's `v_r ≤ 43`; the measured end-to-end WMD error
    /// of the mixed solve is ~2e-9 (the Sinkhorn contraction damps
    /// per-iteration panel noise — see the equivalence suite's 1e-5 gate).
    #[inline]
    fn dot(a: &[f32], b: &[f32]) -> Real {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = [0.0f32; 8];
        let chunks = a.len() / 8;
        // SAFETY: pointer-arithmetic hot loop (bounds checks hoisted),
        // mirroring the f64 `dot`. Every offset is `< a.len()` == `b.len()`
        // (asserted above): `c * 8 + 7 < chunks * 8 <= a.len()` in the
        // unrolled body and `i < a.len()` in the tail.
        unsafe {
            let pa = a.as_ptr();
            let pb = b.as_ptr();
            for c in 0..chunks {
                let i = c * 8;
                acc[0] += *pa.add(i) * *pb.add(i);
                acc[1] += *pa.add(i + 1) * *pb.add(i + 1);
                acc[2] += *pa.add(i + 2) * *pb.add(i + 2);
                acc[3] += *pa.add(i + 3) * *pb.add(i + 3);
                acc[4] += *pa.add(i + 4) * *pb.add(i + 4);
                acc[5] += *pa.add(i + 5) * *pb.add(i + 5);
                acc[6] += *pa.add(i + 6) * *pb.add(i + 6);
                acc[7] += *pa.add(i + 7) * *pb.add(i + 7);
            }
            let mut tail = 0.0f32;
            for i in chunks * 8..a.len() {
                tail += *pa.add(i) * *pb.add(i);
            }
            let lo = (acc[0] + acc[1]) + (acc[2] + acc[3]);
            let hi = (acc[4] + acc[5]) + (acc[6] + acc[7]);
            ((lo + hi) + tail) as Real
        }
    }

    /// The scale `w` stays f64 (it is a ratio of f64 values and can be
    /// large when the SDDMM dot is small); each f32 panel element widens
    /// into the f64 multiply-accumulate.
    #[inline]
    fn axpy(out: &mut [Real], w: Real, b: &[f32]) {
        debug_assert_eq!(out.len(), b.len());
        for (o, &x) in out.iter_mut().zip(b) {
            *o += w * x as Real;
        }
    }
}

/// Row-major panel storage the fused kernels read: [`Dense`] for the f64
/// path, [`Panel32`] for the mixed-precision compute panels. Rows are
/// unit-stride `v_r` slices in both.
pub trait Panel: Sync {
    type Elem: PanelElem;
    fn nrows(&self) -> usize;
    fn ncols(&self) -> usize;
    fn row(&self, i: usize) -> &[Self::Elem];
}

impl Panel for Dense {
    type Elem = Real;

    #[inline(always)]
    fn nrows(&self) -> usize {
        Dense::nrows(self)
    }

    #[inline(always)]
    fn ncols(&self) -> usize {
        Dense::ncols(self)
    }

    #[inline(always)]
    fn row(&self, i: usize) -> &[Real] {
        Dense::row(self, i)
    }
}

impl Panel for Panel32 {
    type Elem = f32;

    #[inline(always)]
    fn nrows(&self) -> usize {
        Panel32::nrows(self)
    }

    #[inline(always)]
    fn ncols(&self) -> usize {
        Panel32::ncols(self)
    }

    #[inline(always)]
    fn row(&self, i: usize) -> &[f32] {
        Panel32::row(self, i)
    }
}

/// Parallel SDDMM with divide-combine (the Sinkhorn `v` update):
/// `w[e] = c.values[e] / ⟨kt[row], u_t[col]⟩`.
///
/// * `c`: CSR `V×N` — the sampling pattern and numerator.
/// * `kt`: dense `V×v_r` (`Kᵀ`).
/// * `u_t`: dense `N×v_r` (`uᵀ`).
/// * `w`: output, `len == c.nnz()`, in CSR order of `c`.
///
/// Each nnz is written by exactly one thread ("mutually exclusively and
/// hence we do not need any atomics there", §4).
pub fn sddmm(c: &Csr, kt: &Dense, u_t: &Dense, w: &mut [Real], pool: &Pool, parts: &[NnzRange]) {
    assert_eq!(w.len(), c.nnz());
    assert_eq!(kt.nrows(), c.nrows());
    assert_eq!(u_t.nrows(), c.ncols());
    assert_eq!(kt.ncols(), u_t.ncols());
    let w_view = SharedSlice::new(w);
    let (row_ptr, col_idx, values) = (c.row_ptr(), c.col_idx(), c.values());
    pool.run(|tid, _nt| {
        let part = parts[tid];
        for_each_nnz_in(part, row_ptr, |e, row| {
            let j = col_idx[e] as usize;
            let s = dot(kt.row(row), u_t.row(j));
            // SAFETY: nnz partitions are disjoint across threads.
            unsafe { w_view.write(e, values[e] / s) };
        });
    });
}

/// Serial reference SDDMM (divide-combine), used by tests and the
/// single-thread baseline.
pub fn sddmm_serial(c: &Csr, kt: &Dense, u_t: &Dense, w: &mut [Real]) {
    assert_eq!(w.len(), c.nnz());
    for (e, (row, col, cval)) in c.iter().enumerate() {
        w[e] = cval / dot(kt.row(row), u_t.row(col));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::balanced_nnz_partition;
    use crate::sparse::Coo;
    use crate::util::Pcg64;

    fn random_inputs(rng: &mut Pcg64, v: usize, n: usize, vr: usize, nnz: usize) -> (Csr, Dense, Dense) {
        let mut coo = Coo::new(v, n);
        for _ in 0..nnz {
            coo.push(rng.below(v), rng.below(n), rng.next_f64() + 0.1);
        }
        let c = Csr::from_coo(coo);
        let kt = Dense::from_fn(v, vr, |_, _| rng.next_f64() + 0.05);
        let u_t = Dense::from_fn(n, vr, |_, _| rng.next_f64() + 0.05);
        (c, kt, u_t)
    }

    /// Dense oracle: full Kᵀ@u then elementwise divide at the pattern.
    fn dense_oracle(c: &Csr, kt: &Dense, u_t: &Dense) -> Vec<Real> {
        let ktu = kt.matmul(&u_t.transpose()); // V×N
        c.iter().map(|(i, j, v)| v / ktu.get(i, j)).collect()
    }

    #[test]
    fn matches_dense_oracle() {
        let mut rng = Pcg64::new(51);
        for _ in 0..10 {
            let (c, kt, u_t) = random_inputs(&mut rng, 30, 12, 7, 80);
            let oracle = dense_oracle(&c, &kt, &u_t);
            let pool = Pool::new(4);
            let parts = balanced_nnz_partition(c.row_ptr(), pool.nthreads());
            let mut w = vec![0.0; c.nnz()];
            sddmm(&c, &kt, &u_t, &mut w, &pool, &parts);
            for (a, b) in w.iter().zip(&oracle) {
                assert!((a - b).abs() < 1e-12 * (1.0 + b.abs()), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn parallel_equals_serial_any_thread_count() {
        let mut rng = Pcg64::new(52);
        let (c, kt, u_t) = random_inputs(&mut rng, 100, 40, 16, 600);
        let mut w_serial = vec![0.0; c.nnz()];
        sddmm_serial(&c, &kt, &u_t, &mut w_serial);
        for p in [1usize, 2, 3, 7, 16] {
            let pool = Pool::new(p);
            let parts = balanced_nnz_partition(c.row_ptr(), p);
            let mut w = vec![0.0; c.nnz()];
            sddmm(&c, &kt, &u_t, &mut w, &pool, &parts);
            assert_eq!(w, w_serial, "p={p}");
        }
    }

    #[test]
    fn empty_pattern_is_noop() {
        let c = Csr::from_coo(Coo::new(5, 5));
        let kt = Dense::filled(5, 3, 1.0);
        let u_t = Dense::filled(5, 3, 1.0);
        let pool = Pool::new(2);
        let parts = balanced_nnz_partition(c.row_ptr(), 2);
        let mut w: Vec<Real> = vec![];
        sddmm(&c, &kt, &u_t, &mut w, &pool, &parts);
    }
}
