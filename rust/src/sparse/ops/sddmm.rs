//! SDDMM — *sampled* dense-dense matrix multiplication.
//!
//! For the Sinkhorn iterate `v = c ⊘ (Kᵀ@u)`, the dense product `Kᵀ@u`
//! (`V×N`, 91.9 % of the Python baseline's runtime, Table 1) is needed
//! only where `c` is non-zero (~0.0035 % of entries). The kernel computes
//! exactly those `nnz(c)` dot products:
//!
//! `w[e] = combine(c.values[e], ⟨KTᵀ[row(e), :], uᵀ[col(e), :]⟩)`
//!
//! Both operands are stored transposed (`V×v_r` and `N×v_r` row-major) so
//! the inner dot is unit-stride on both sides — the paper's "on the fly
//! transpose for unit stride data access".

use super::for_each_nnz_in;
use crate::parallel::{NnzRange, Pool};
use crate::sparse::{dot, Csr, Dense};
use crate::util::SharedSlice;
use crate::Real;

/// Parallel SDDMM with divide-combine (the Sinkhorn `v` update):
/// `w[e] = c.values[e] / ⟨kt[row], u_t[col]⟩`.
///
/// * `c`: CSR `V×N` — the sampling pattern and numerator.
/// * `kt`: dense `V×v_r` (`Kᵀ`).
/// * `u_t`: dense `N×v_r` (`uᵀ`).
/// * `w`: output, `len == c.nnz()`, in CSR order of `c`.
///
/// Each nnz is written by exactly one thread ("mutually exclusively and
/// hence we do not need any atomics there", §4).
pub fn sddmm(c: &Csr, kt: &Dense, u_t: &Dense, w: &mut [Real], pool: &Pool, parts: &[NnzRange]) {
    assert_eq!(w.len(), c.nnz());
    assert_eq!(kt.nrows(), c.nrows());
    assert_eq!(u_t.nrows(), c.ncols());
    assert_eq!(kt.ncols(), u_t.ncols());
    let w_view = SharedSlice::new(w);
    let (row_ptr, col_idx, values) = (c.row_ptr(), c.col_idx(), c.values());
    pool.run(|tid, _nt| {
        let part = parts[tid];
        for_each_nnz_in(part, row_ptr, |e, row| {
            let j = col_idx[e] as usize;
            let s = dot(kt.row(row), u_t.row(j));
            // SAFETY: nnz partitions are disjoint across threads.
            unsafe { w_view.write(e, values[e] / s) };
        });
    });
}

/// Serial reference SDDMM (divide-combine), used by tests and the
/// single-thread baseline.
pub fn sddmm_serial(c: &Csr, kt: &Dense, u_t: &Dense, w: &mut [Real]) {
    assert_eq!(w.len(), c.nnz());
    for (e, (row, col, cval)) in c.iter().enumerate() {
        w[e] = cval / dot(kt.row(row), u_t.row(col));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::balanced_nnz_partition;
    use crate::sparse::Coo;
    use crate::util::Pcg64;

    fn random_inputs(rng: &mut Pcg64, v: usize, n: usize, vr: usize, nnz: usize) -> (Csr, Dense, Dense) {
        let mut coo = Coo::new(v, n);
        for _ in 0..nnz {
            coo.push(rng.below(v), rng.below(n), rng.next_f64() + 0.1);
        }
        let c = Csr::from_coo(coo);
        let kt = Dense::from_fn(v, vr, |_, _| rng.next_f64() + 0.05);
        let u_t = Dense::from_fn(n, vr, |_, _| rng.next_f64() + 0.05);
        (c, kt, u_t)
    }

    /// Dense oracle: full Kᵀ@u then elementwise divide at the pattern.
    fn dense_oracle(c: &Csr, kt: &Dense, u_t: &Dense) -> Vec<Real> {
        let ktu = kt.matmul(&u_t.transpose()); // V×N
        c.iter().map(|(i, j, v)| v / ktu.get(i, j)).collect()
    }

    #[test]
    fn matches_dense_oracle() {
        let mut rng = Pcg64::new(51);
        for _ in 0..10 {
            let (c, kt, u_t) = random_inputs(&mut rng, 30, 12, 7, 80);
            let oracle = dense_oracle(&c, &kt, &u_t);
            let pool = Pool::new(4);
            let parts = balanced_nnz_partition(c.row_ptr(), pool.nthreads());
            let mut w = vec![0.0; c.nnz()];
            sddmm(&c, &kt, &u_t, &mut w, &pool, &parts);
            for (a, b) in w.iter().zip(&oracle) {
                assert!((a - b).abs() < 1e-12 * (1.0 + b.abs()), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn parallel_equals_serial_any_thread_count() {
        let mut rng = Pcg64::new(52);
        let (c, kt, u_t) = random_inputs(&mut rng, 100, 40, 16, 600);
        let mut w_serial = vec![0.0; c.nnz()];
        sddmm_serial(&c, &kt, &u_t, &mut w_serial);
        for p in [1usize, 2, 3, 7, 16] {
            let pool = Pool::new(p);
            let parts = balanced_nnz_partition(c.row_ptr(), p);
            let mut w = vec![0.0; c.nnz()];
            sddmm(&c, &kt, &u_t, &mut w, &pool, &parts);
            assert_eq!(w, w_serial, "p={p}");
        }
    }

    #[test]
    fn empty_pattern_is_noop() {
        let c = Csr::from_coo(Coo::new(5, 5));
        let kt = Dense::filled(5, 3, 1.0);
        let u_t = Dense::filled(5, 3, 1.0);
        let pool = Pool::new(2);
        let parts = balanced_nnz_partition(c.row_ptr(), 2);
        let mut w: Vec<Real> = vec![];
        sddmm(&c, &kt, &u_t, &mut w, &pool, &parts);
    }
}
