//! The paper's sparse kernels over CSR `c`:
//!
//! * [`sddmm`] — sampled dense-dense matmul: a dot product *only* at the
//!   non-zero positions of `c` (Fig. 3 left).
//! * [`spmm`] — sparse × dense scatter (Fig. 3 right), atomic and
//!   pattern-transposed (atomic-free) variants.
//! * [`fused`] — the paper's new `SDDMM_SpMM` kernel: one CSR pass,
//!   SDDMM values fed straight into the SpMM accumulation (Fig. 4 left);
//!   `type1` produces the next iterate `x`, `type2` produces the final
//!   WMD reduction.
//!
//! All kernels take a precomputed nnz-balanced partition
//! ([`crate::parallel::balanced_nnz_partition`]) so benches can ablate the
//! partitioning strategy independently of the kernel.

pub mod fused;
pub mod sddmm;
pub mod spmm;

pub use fused::{
    fused_type1, fused_type1_batch, fused_type1_private, fused_type1_transposed,
    fused_type1_transposed_batch, fused_type2, fused_type2_batch, FusedScratch, PrivateBuffers,
};
pub use sddmm::{sddmm, sddmm_serial};
pub use spmm::{spmm_atomic, spmm_serial, spmm_transposed, TransposedPattern};

use crate::parallel::NnzRange;

/// Walk a thread's nnz range `[part.nnz_start, part.nnz_end)` keeping the
/// current row in sync with the cursor, starting at `part.start_row`
/// (found by binary search in the partitioner). Calls `f(e, row)` per nnz.
#[inline]
pub(crate) fn for_each_nnz_in(part: NnzRange, row_ptr: &[usize], mut f: impl FnMut(usize, usize)) {
    let mut row = part.start_row;
    for e in part.nnz_start..part.nnz_end {
        // Advance past row boundaries (handles empty rows).
        while e >= row_ptr[row + 1] {
            row += 1;
        }
        f(e, row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::balanced_nnz_partition;
    use crate::sparse::{Coo, Csr};
    use crate::util::Pcg64;

    #[test]
    fn cursor_visits_every_nnz_with_correct_row() {
        let mut rng = Pcg64::new(41);
        for _ in 0..30 {
            let nrows = rng.range(1, 40);
            let mut coo = Coo::new(nrows, 10);
            for _ in 0..rng.below(120) {
                coo.push(rng.below(nrows), rng.below(10), 1.0);
            }
            let m = Csr::from_coo(coo);
            for p in [1usize, 3, 8] {
                let mut seen = vec![None::<usize>; m.nnz()];
                for part in balanced_nnz_partition(m.row_ptr(), p) {
                    for_each_nnz_in(part, m.row_ptr(), |e, row| {
                        assert!(seen[e].is_none(), "nnz {e} visited twice");
                        seen[e] = Some(row);
                    });
                }
                // Every nnz visited exactly once with its true row.
                for (e, row) in seen.iter().enumerate() {
                    let row = row.expect("nnz not visited");
                    assert!(m.row_ptr()[row] <= e && e < m.row_ptr()[row + 1]);
                }
            }
        }
    }
}
