//! The paper's sparse kernels over CSR `c`:
//!
//! * [`fused`] — **the** hot path: the single fused `SDDTMM→DSTMMT`
//!   family. One traversal of the stationary transposed pattern per
//!   Sinkhorn step computes each sampled dot product and immediately
//!   feeds it to the column-owned axpy accumulation (no atomics, no
//!   private buffers); generic over batch width and the panel scalar
//!   (f64, or f32 compute panels for the mixed-precision mode).
//! * [`sddmm`] — standalone sampled dense-dense matmul (Fig. 3 left) plus
//!   the [`Panel`]/[`PanelElem`] primitives the fused family is built on.
//! * [`spmm`] — standalone atomic scatter (Fig. 3 right) and the
//!   [`TransposedPattern`]. `sddmm` + `spmm_atomic` form the `Unfused`
//!   ablation baseline.
//!
//! The fused kernels take a precomputed nnz-balanced *column* partition
//! ([`TransposedPattern::column_parts`]); the unfused pair takes the
//! row-major partition ([`crate::parallel::balanced_nnz_partition`]) —
//! both precomputed so benches can ablate the partitioning strategy
//! independently of the kernel.

pub mod fused;
pub mod sddmm;
pub mod spmm;

pub use fused::{sddtmm_dstmmt_batch, sddtmm_wmd_batch, ActiveView, FusedScratch};
pub use sddmm::{sddmm, sddmm_serial, Panel, PanelElem};
pub use spmm::{spmm_atomic, spmm_serial, TransposedPattern};

use crate::parallel::NnzRange;

/// Walk a thread's nnz range `[part.nnz_start, part.nnz_end)` keeping the
/// current row in sync with the cursor, starting at `part.start_row`
/// (found by binary search in the partitioner). Calls `f(e, row)` per nnz.
#[inline]
pub(crate) fn for_each_nnz_in(part: NnzRange, row_ptr: &[usize], mut f: impl FnMut(usize, usize)) {
    let mut row = part.start_row;
    for e in part.nnz_start..part.nnz_end {
        // Advance past row boundaries (handles empty rows).
        while e >= row_ptr[row + 1] {
            row += 1;
        }
        f(e, row);
    }
}

/// [`for_each_nnz_in`] over a compacted *subset* of columns: `sub_ptr` is
/// the subset nnz prefix ([`crate::parallel::subset_nnz_prefix_into`]) of
/// `cols` under the full `col_ptr`, and `part` addresses subset-nnz
/// coordinates (`start_row` is a subset *position*). Calls `f(e, j)` with
/// `e` the entry's index in the **full** pattern and `j` the global column
/// — so the kernel body is identical to the full-traversal one; only the
/// walk shrinks to the surviving columns (the solver's active-set
/// compaction). Entries of a column are visited in the same ascending
/// order as the full traversal, which keeps compacted iterates bitwise
/// equal per column.
#[inline]
pub(crate) fn for_each_nnz_in_subset(
    part: NnzRange,
    sub_ptr: &[usize],
    cols: &[u32],
    col_ptr: &[usize],
    mut f: impl FnMut(usize, usize),
) {
    let mut s = part.start_row;
    for es in part.nnz_start..part.nnz_end {
        while es >= sub_ptr[s + 1] {
            s += 1;
        }
        let j = cols[s] as usize;
        let e = col_ptr[j] + (es - sub_ptr[s]);
        f(e, j);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::{balanced_nnz_partition, subset_nnz_prefix_into};
    use crate::sparse::{Coo, Csr};
    use crate::util::Pcg64;

    #[test]
    fn cursor_visits_every_nnz_with_correct_row() {
        let mut rng = Pcg64::new(41);
        for _ in 0..30 {
            let nrows = rng.range(1, 40);
            let mut coo = Coo::new(nrows, 10);
            for _ in 0..rng.below(120) {
                coo.push(rng.below(nrows), rng.below(10), 1.0);
            }
            let m = Csr::from_coo(coo);
            for p in [1usize, 3, 8] {
                let mut seen = vec![None::<usize>; m.nnz()];
                for part in balanced_nnz_partition(m.row_ptr(), p) {
                    for_each_nnz_in(part, m.row_ptr(), |e, row| {
                        assert!(seen[e].is_none(), "nnz {e} visited twice");
                        seen[e] = Some(row);
                    });
                }
                // Every nnz visited exactly once with its true row.
                for (e, row) in seen.iter().enumerate() {
                    let row = row.expect("nnz not visited");
                    assert!(m.row_ptr()[row] <= e && e < m.row_ptr()[row + 1]);
                }
            }
        }
    }

    #[test]
    fn subset_cursor_visits_exactly_the_subset_in_full_order() {
        let mut rng = Pcg64::new(42);
        for _ in 0..30 {
            let nrows = rng.range(1, 40);
            let mut coo = Coo::new(nrows, 10);
            for _ in 0..rng.below(150) {
                coo.push(rng.below(nrows), rng.below(10), 1.0);
            }
            let m = Csr::from_coo(coo);
            let rp = m.row_ptr();
            let subset: Vec<u32> =
                (0..nrows as u32).filter(|_| rng.next_f64() < 0.5).collect();
            let mut sub_ptr = Vec::new();
            subset_nnz_prefix_into(rp, &subset, &mut sub_ptr);
            for p in [1usize, 3, 8] {
                let mut visited: Vec<(usize, usize)> = Vec::new();
                for part in balanced_nnz_partition(&sub_ptr, p) {
                    for_each_nnz_in_subset(part, &sub_ptr, &subset, rp, |e, row| {
                        visited.push((e, row));
                    });
                }
                // Exactly the subset rows' entries, each once, in full-
                // traversal (ascending-entry) order per row.
                let expected: Vec<(usize, usize)> = subset
                    .iter()
                    .flat_map(|&r| {
                        let r = r as usize;
                        (rp[r]..rp[r + 1]).map(move |e| (e, r))
                    })
                    .collect();
                assert_eq!(visited, expected, "p={p}");
            }
        }
    }
}
