//! SpMM — sparse × dense scatter: `x = K_over_r @ v` where `v` shares the
//! sparsity pattern of `c` and is given by its CSR-ordered values `w`.
//!
//! With `x` stored transposed (`N×v_r`) and `K_over_rᵀ` stored `V×v_r`,
//! the update per non-zero `(i, j)` is the unit-stride axpy
//! `xᵀ[j, :] += w[e] · K_over_rᵀ[i, :]`.
//!
//! Exports:
//! * [`spmm_atomic`] — the paper's Fig. 3 kernel: nnz-partitioned, scatter
//!   guarded by atomics (`#pragma omp atomic`). Together with
//!   [`crate::sparse::ops::sddmm`] it forms the `Unfused` ablation
//!   baseline in the solver.
//! * [`spmm_serial`] — serial reference used by tests.
//! * [`TransposedPattern`] — the one-time column-major view of `c`'s
//!   pattern (iteration-invariant, grow-only rebuild) that the fused
//!   `SDDTMM→DSTMMT` family ([`crate::sparse::ops::fused`]) walks for its
//!   atomic-free, column-owned traversal. The former standalone
//!   `spmm_transposed` kernel was absorbed into that family.

use super::for_each_nnz_in;
use crate::parallel::{balanced_nnz_partition, AtomicF64Slice, NnzRange, Pool};
use crate::sparse::{axpy, Csr, Dense};
use crate::Real;

/// Paper-faithful atomic SpMM. `x_t` (`N×v_r`) is zeroed, then every
/// non-zero scatters into it under per-element atomics.
pub fn spmm_atomic(
    c: &Csr,
    w: &[Real],
    kor_t: &Dense,
    x_t: &mut Dense,
    pool: &Pool,
    parts: &[NnzRange],
) {
    assert_eq!(w.len(), c.nnz());
    assert_eq!(kor_t.nrows(), c.nrows());
    assert_eq!(x_t.nrows(), c.ncols());
    let vr = kor_t.ncols();
    assert_eq!(x_t.ncols(), vr);
    x_t.fill(0.0);
    // Serial fast path (§Perf): a CAS loop per element costs ~7× even
    // without contention, so a single thread writes directly.
    if pool.nthreads() == 1 {
        for (e, (row, col, _)) in c.iter().enumerate() {
            axpy(x_t.row_mut(col), w[e], kor_t.row(row));
        }
        return;
    }
    let x_atomic = AtomicF64Slice::new(x_t.as_mut_slice());
    let (row_ptr, col_idx) = (c.row_ptr(), c.col_idx());
    pool.run(|tid, _nt| {
        let part = parts[tid];
        for_each_nnz_in(part, row_ptr, |e, row| {
            let j = col_idx[e] as usize;
            let s = w[e];
            let k_row = kor_t.row(row);
            let base = j * vr;
            for (k, &kv) in k_row.iter().enumerate() {
                x_atomic.fetch_add(base + k, s * kv);
            }
        });
    });
}

/// Serial reference SpMM.
pub fn spmm_serial(c: &Csr, w: &[Real], kor_t: &Dense, x_t: &mut Dense) {
    assert_eq!(w.len(), c.nnz());
    x_t.fill(0.0);
    for (e, (row, col, _)) in c.iter().enumerate() {
        axpy(x_t.row_mut(col), w[e], kor_t.row(row));
    }
}

/// Precomputed transpose of a CSR *pattern*: for each column `j`, the list
/// of (source row, CSR value position) pairs. Built once per query (the
/// pattern of `c` is iteration-invariant), reused every Sinkhorn step.
#[derive(Clone, Debug, Default)]
pub struct TransposedPattern {
    /// `col_ptr[j]..col_ptr[j+1]` spans column `j`'s entries.
    pub col_ptr: Vec<usize>,
    /// Source row of each entry, in column-major order.
    pub src_row: Vec<u32>,
    /// Position in the CSR `values`/`w` array of each entry.
    pub src_pos: Vec<u32>,
}

impl TransposedPattern {
    pub fn build(c: &Csr) -> Self {
        let mut tp = Self::default();
        tp.rebuild_from(c);
        tp
    }

    /// Rebuild the pattern of `c` in place, reusing the three backing
    /// allocations (grow-only) — the form a retained [`crate::sinkhorn::
    /// SolveWorkspace`] uses so repeated solves stop touching the
    /// allocator. Unlike [`TransposedPattern::build`] this also avoids the
    /// transient cursor clone: `col_ptr[j]` doubles as column `j`'s write
    /// cursor during the scatter (it then holds column `j`'s *end*, i.e.
    /// the old `col_ptr[j + 1]`), and one right-shift restores the pointer
    /// array.
    pub fn rebuild_from(&mut self, c: &Csr) {
        let ncols = c.ncols();
        let nnz = c.nnz();
        self.col_ptr.clear();
        self.col_ptr.resize(ncols + 1, 0);
        for &j in c.col_idx() {
            self.col_ptr[j as usize + 1] += 1;
        }
        for j in 0..ncols {
            self.col_ptr[j + 1] += self.col_ptr[j];
        }
        self.src_row.clear();
        self.src_row.resize(nnz, 0);
        self.src_pos.clear();
        self.src_pos.resize(nnz, 0);
        for (e, (i, j, _)) in c.iter().enumerate() {
            let dst = self.col_ptr[j];
            self.col_ptr[j] += 1;
            self.src_row[dst] = i as u32;
            self.src_pos[dst] = e as u32;
        }
        for j in (1..=ncols).rev() {
            self.col_ptr[j] = self.col_ptr[j - 1];
        }
        if !self.col_ptr.is_empty() {
            self.col_ptr[0] = 0;
        }
    }

    /// nnz-balanced partition over *columns* (each thread owns whole
    /// columns, hence whole `xᵀ` rows — no atomics).
    pub fn column_parts(&self, nthreads: usize) -> Vec<NnzRange> {
        balanced_nnz_partition(&self.col_ptr, nthreads)
    }

    /// [`TransposedPattern::column_parts`] into a caller-owned buffer.
    pub fn column_parts_into(&self, nthreads: usize, out: &mut Vec<NnzRange>) {
        crate::parallel::balanced_nnz_partition_into(&self.col_ptr, nthreads, out);
    }

    /// Heap bytes held by the pattern's backing allocations.
    pub fn retained_bytes(&self) -> usize {
        self.col_ptr.capacity() * std::mem::size_of::<usize>()
            + (self.src_row.capacity() + self.src_pos.capacity()) * std::mem::size_of::<u32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;
    use crate::util::Pcg64;

    fn random_case(rng: &mut Pcg64, v: usize, n: usize, vr: usize, nnz: usize) -> (Csr, Vec<Real>, Dense) {
        let mut coo = Coo::new(v, n);
        for _ in 0..nnz {
            coo.push(rng.below(v), rng.below(n), rng.next_f64() + 0.1);
        }
        let c = Csr::from_coo(coo);
        let w: Vec<Real> = (0..c.nnz()).map(|_| rng.next_f64() - 0.3).collect();
        let kor_t = Dense::from_fn(v, vr, |_, _| rng.next_f64());
        (c, w, kor_t)
    }

    /// Dense oracle: materialize v (sparse, values w at pattern of c) and
    /// compute K_over_r @ v densely, then transpose.
    fn dense_oracle(c: &Csr, w: &[Real], kor_t: &Dense) -> Dense {
        let kor = kor_t.transpose(); // v_r × V
        let mut vmat = Dense::zeros(c.nrows(), c.ncols());
        for (e, (i, j, _)) in c.iter().enumerate() {
            vmat.set(i, j, w[e]);
        }
        kor.matmul(&vmat).transpose() // N × v_r
    }

    #[test]
    fn atomic_matches_oracle() {
        let mut rng = Pcg64::new(61);
        for p in [1usize, 4, 9] {
            let (c, w, kor_t) = random_case(&mut rng, 25, 13, 6, 70);
            let oracle = dense_oracle(&c, &w, &kor_t);
            let pool = Pool::new(p);
            let parts = balanced_nnz_partition(c.row_ptr(), p);
            let mut x_t = Dense::zeros(13, 6);
            spmm_atomic(&c, &w, &kor_t, &mut x_t, &pool, &parts);
            assert!(x_t.max_abs_diff(&oracle) < 1e-12, "p={p}");
        }
    }

    #[test]
    fn rebuild_from_reuses_dirty_pattern_bitwise() {
        let mut rng = Pcg64::new(64);
        let (big, _, _) = random_case(&mut rng, 50, 23, 6, 300);
        let (small, _, _) = random_case(&mut rng, 20, 9, 4, 60);
        let mut tp = TransposedPattern::build(&big);
        // Shrink onto a smaller matrix, then regrow onto the big one: both
        // must match a fresh build exactly, with no allocation on regrow.
        tp.rebuild_from(&small);
        let fresh_small = TransposedPattern::build(&small);
        assert_eq!(tp.col_ptr, fresh_small.col_ptr);
        assert_eq!(tp.src_row, fresh_small.src_row);
        assert_eq!(tp.src_pos, fresh_small.src_pos);
        let bytes = tp.retained_bytes();
        tp.rebuild_from(&big);
        let fresh_big = TransposedPattern::build(&big);
        assert_eq!(tp.col_ptr, fresh_big.col_ptr);
        assert_eq!(tp.src_row, fresh_big.src_row);
        assert_eq!(tp.src_pos, fresh_big.src_pos);
        assert_eq!(tp.retained_bytes(), bytes, "regrow within capacity must not allocate");
    }

    #[test]
    fn transposed_pattern_is_column_sorted_permutation() {
        let mut rng = Pcg64::new(63);
        let (c, _, _) = random_case(&mut rng, 30, 11, 4, 90);
        let tp = TransposedPattern::build(&c);
        assert_eq!(*tp.col_ptr.last().unwrap(), c.nnz());
        // src_pos is a permutation of 0..nnz.
        let mut pos: Vec<u32> = tp.src_pos.clone();
        pos.sort_unstable();
        assert_eq!(pos, (0..c.nnz() as u32).collect::<Vec<_>>());
        // Each entry agrees with the CSR triplet.
        let triplets: Vec<(usize, usize, Real)> = c.iter().collect();
        for j in 0..c.ncols() {
            for e in tp.col_ptr[j]..tp.col_ptr[j + 1] {
                let (ti, tj, _) = triplets[tp.src_pos[e] as usize];
                assert_eq!(tj, j);
                assert_eq!(ti, tp.src_row[e] as usize);
            }
        }
    }
}
