//! The fused **SDDTMM→DSTMMT** kernel family — one pass over the
//! *stationary transposed* pattern per Sinkhorn step.
//!
//! The paper's `SDDMM_SpMM` fusion ("the output values from SDDMM can be
//! fed directly to the SpMM and would not need to be stored in memory",
//! §4) is taken one step further here, following the authors' PIUMA
//! follow-up (arXiv:2107.06433): the iterate is reformulated over the
//! transposed corpus pattern (`cT`-resident `sddtmm`/`dstmmt`), so each
//! thread owns whole documents — columns of `c`, i.e. rows of `xᵀ` — and
//! the SDDMM value feeds the SpMM axpy with **no atomics and no
//! per-thread private buffers**. One traversal per step, write-owned
//! output, and the document's `uᵀ` row stays hot across the column's
//! entries (the cache-reuse idea of the paper's §9 tiling discussion).
//!
//! Exactly two kernels remain, both batched (`B = 1` is the single-query
//! case — pass one-element slices):
//!
//! * [`sddtmm_dstmmt_batch`] — the solver-loop iterate
//!   `xᵀ[j,:] += (c[i,j] / ⟨ktᵀ[i,:], uᵀ[j,:]⟩) · kor_tᵀ[i,:]`, generic
//!   over the panel scalar ([`Panel`]): `Dense` panels run the classic
//!   f64 path, [`crate::sparse::Panel32`] panels run the mixed-precision
//!   f32 compute path (f64 division and accumulation throughout — see
//!   [`PanelElem`]).
//! * [`sddtmm_wmd_batch`] — the epilogue
//!   `WMD[j] += w · ⟨km_tᵀ[i,:], uᵀ[j,:]⟩`, always f64 (it is the final
//!   reduction the mixed mode is gated against). Column ownership makes
//!   it atomic-free *and* partial-buffer-free: slot `j` is owned by the
//!   thread that owns column `j`.
//!
//! Because every column is accumulated in ascending source-row order
//! regardless of the thread count, both kernels are **bitwise
//! thread-count-invariant** — the equivalence suite asserts this, and it
//! is what lets `tests/kernel_family_test.rs` demand bitwise equality
//! between sharded and monolithic solves.
//!
//! The unfused SDDMM + `spmm_atomic` pair survives as the `Unfused`
//! ablation baseline in the solver; the former `type1` / `type1_private`
//! / `type1_transposed` / `type2` variants (and their `_batch` twins)
//! collapsed into this family.

use super::sddmm::{Panel, PanelElem};
use super::{for_each_nnz_in, for_each_nnz_in_subset};
use crate::parallel::{NnzRange, Pool};
use crate::sparse::{dot, Csr, Dense};
use crate::util::SharedSlice;
use crate::Real;

/// Reusable scratch for the fused kernels, passed in by the caller instead
/// of allocated per call (the zero-alloc hot-path contract: a retained
/// [`crate::sinkhorn::SolveWorkspace`] owns one and its buffers are
/// grow-only, so steady-state kernel invocations never touch the
/// allocator). After the column-owned rewrite the only scratch left is
/// the active-query index list — the per-thread partial buffers of the
/// retired `type2` reduction are gone.
#[derive(Debug, Default)]
pub struct FusedScratch {
    /// Indices of the active (not yet converged) queries of a batch.
    act: Vec<usize>,
}

impl FusedScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Heap bytes held by the scratch's backing allocations.
    pub fn retained_bytes(&self) -> usize {
        self.act.capacity() * std::mem::size_of::<usize>()
    }
}

/// The iterate's view of the target columns — how the solver's
/// per-document convergence tracking reaches the kernel.
///
/// * `cols`: when set, the traversal is **compacted** to the given column
///   subset — `(cols, sub_ptr)` with `sub_ptr` the subset nnz prefix
///   ([`crate::parallel::subset_nnz_prefix_into`] over the pattern's
///   `col_ptr`). The caller's `col_parts` must then partition `sub_ptr`,
///   and only the subset's `xᵀ` rows are zeroed/written. `None` walks the
///   full pattern (today's behaviour).
/// * `frozen`: flat `B × N` mask (`frozen[q·N + j]`): a column already
///   converged for query `q` is skipped — no dot/axpy runs for it, so its
///   `xᵀ` row is dead weight (zeroed by the full clear, or left stale
///   under compaction; the solver's pinned state lives in `u`, which the
///   WMD epilogue reads). `None` means nothing is frozen and the
///   arithmetic is bitwise identical to the pre-compaction kernel.
#[derive(Clone, Copy, Debug, Default)]
pub struct ActiveView<'a> {
    /// Compacted column subset (ascending) and its subset nnz prefix.
    pub cols: Option<(&'a [u32], &'a [usize])>,
    /// Flat `B × N` per-(query, column) frozen mask.
    pub frozen: Option<&'a [bool]>,
}

impl ActiveView<'_> {
    /// Full traversal, nothing frozen — the exact-mode view.
    pub fn full() -> Self {
        Self::default()
    }
}

/// Fused batched iterate over the stationary transposed pattern
/// (SDDTMM→DSTMMT): for each pattern entry `(i, j)` and each *active*
/// query `q`,
///
/// `w = c[i,j] / ⟨kts[q][i,:], u_ts[q][j,:]⟩` then
/// `x_ts[q][j,:] += w · kor_ts[q][i,:]`
///
/// with the dot and axpy running in the panel scalar (`P::Elem`) and the
/// division/accumulation in f64 ([`PanelElem`] contract). One pattern
/// traversal serves the whole batch: the column cursor, `c[i,j]` and the
/// `src_row`/`src_pos` loads are paid once per nnz instead of once per
/// (nnz, query).
///
/// Atomic-free: a thread owns whole columns `j` (the column partition
/// never splits a column), hence row `j` of every query's `xᵀ`. Queries
/// whose `active[q]` is false (already converged) are skipped without
/// stalling the rest of the batch; their `x_ts[q]` is untouched. The
/// finer-grained [`ActiveView`] masks individual (query, column) pairs —
/// frozen columns keep their pinned `xᵀ` row — and can compact the
/// traversal itself to the surviving columns (`view.cols`, in which case
/// `col_parts` partitions the subset prefix instead of the full
/// `col_ptr`).
///
/// `u_ts` is a plain `&[P]` (not `&[&P]`): the per-query `u` states live
/// contiguously in the solver workspace's lanes, so the per-iteration
/// call needs no reference-vector rebuild — the factor panels, by
/// contrast, point into `B` separately-owned `Prepared` values.
#[allow(clippy::too_many_arguments)]
pub fn sddtmm_dstmmt_batch<P: Panel>(
    c: &Csr,
    tp: &super::spmm::TransposedPattern,
    kts: &[&P],
    kor_ts: &[&P],
    u_ts: &[P],
    x_ts: &mut [Dense],
    active: &[bool],
    view: ActiveView<'_>,
    pool: &Pool,
    col_parts: &[NnzRange],
    scratch: &mut FusedScratch,
) {
    let b = kts.len();
    debug_assert_eq!(kor_ts.len(), b);
    debug_assert_eq!(u_ts.len(), b);
    debug_assert_eq!(x_ts.len(), b);
    debug_assert_eq!(active.len(), b);
    scratch.act.clear();
    scratch.act.extend((0..b).filter(|&q| active[q]));
    let act: &[usize] = &scratch.act;
    if act.is_empty() {
        return;
    }
    let n = tp.col_ptr.len() - 1;
    if let Some(fr) = view.frozen {
        debug_assert_eq!(fr.len(), b * n);
    }
    for &q in act {
        let vr = kts[q].ncols();
        debug_assert_eq!(kor_ts[q].ncols(), vr);
        debug_assert_eq!(u_ts[q].ncols(), vr);
        debug_assert_eq!(x_ts[q].ncols(), vr);
        debug_assert_eq!(kts[q].nrows(), c.nrows());
        debug_assert_eq!(u_ts[q].nrows(), c.ncols());
        debug_assert_eq!(x_ts[q].nrows() + 1, tp.col_ptr.len());
        match view.cols {
            // Compacted: only the surviving columns' accumulator rows are
            // reset — frozen rows keep their pinned values (never read
            // again, but cheaper than a full-plane clear).
            Some((cols, _)) => {
                for &j in cols {
                    x_ts[q].row_mut(j as usize).fill(0.0);
                }
            }
            None => x_ts[q].fill(0.0),
        }
    }
    let values = c.values();
    let frozen = view.frozen;
    let x_views: Vec<SharedSlice<Real>> =
        x_ts.iter_mut().map(|x| SharedSlice::new(x.as_mut_slice())).collect();
    pool.run(|tid, _nt| {
        let part = col_parts[tid];
        let body = |e: usize, j: usize| {
            let i = tp.src_row[e] as usize;
            let cv = values[tp.src_pos[e] as usize];
            for &q in act {
                if let Some(fr) = frozen {
                    if fr[q * n + j] {
                        continue;
                    }
                }
                let u_row = u_ts[q].row(j);
                let w = cv / <P::Elem as PanelElem>::dot(kts[q].row(i), u_row);
                let vr = kts[q].ncols();
                // SAFETY: column j (row j of every query's x) is owned by
                // this thread — the column partition never splits a column.
                let x_row = unsafe { x_views[q].slice_mut(j * vr, vr) };
                <P::Elem as PanelElem>::axpy(x_row, w, kor_ts[q].row(i));
            }
        };
        match view.cols {
            // Same per-entry body either way: the subset cursor hands out
            // full-pattern entry indices in the same ascending per-column
            // order, so compaction never changes a column's accumulation.
            Some((cols, sub_ptr)) => for_each_nnz_in_subset(part, sub_ptr, cols, &tp.col_ptr, body),
            None => for_each_nnz_in(part, &tp.col_ptr, body),
        }
    });
}

/// Fused batched epilogue over the stationary transposed pattern: the
/// final WMD vector of every query in one traversal.
///
/// `WMD[j] = Σ_{(i,j) ∈ nnz(c)} (c[i,j] / ⟨ktᵀ[i], uᵀ[j]⟩) · ⟨km_tᵀ[i], uᵀ[j]⟩`
///
/// equals `(u ⊙ ((K⊙M) @ v)).sum(axis=0)` from Algorithm 1. The scatter
/// target is one scalar per document, and the thread that owns column `j`
/// owns slot `j` — so unlike the retired partial-buffer `type2`, no
/// per-thread `nthreads·B·N` scratch and no post-region reduction exist
/// at all. Always f64: this is the reduction the mixed-precision mode is
/// error-gated against, so it never drops precision.
#[allow(clippy::too_many_arguments)]
pub fn sddtmm_wmd_batch(
    c: &Csr,
    tp: &super::spmm::TransposedPattern,
    kts: &[&Dense],
    km_ts: &[&Dense],
    u_ts: &[Dense],
    wmds: &mut [Vec<Real>],
    pool: &Pool,
    col_parts: &[NnzRange],
) {
    let b = kts.len();
    debug_assert_eq!(km_ts.len(), b);
    debug_assert_eq!(u_ts.len(), b);
    assert_eq!(wmds.len(), b);
    if b == 0 {
        return;
    }
    let n = tp.col_ptr.len() - 1;
    debug_assert_eq!(c.ncols(), n);
    for wmd in wmds.iter_mut() {
        assert_eq!(wmd.len(), n);
        wmd.fill(0.0);
    }
    let values = c.values();
    let wmd_views: Vec<SharedSlice<Real>> =
        wmds.iter_mut().map(|w| SharedSlice::new(w.as_mut_slice())).collect();
    pool.run(|tid, _nt| {
        let part = col_parts[tid];
        for_each_nnz_in(part, &tp.col_ptr, |e, j| {
            let i = tp.src_row[e] as usize;
            let cv = values[tp.src_pos[e] as usize];
            for (q, view) in wmd_views.iter().enumerate() {
                let u_row = u_ts[q].row(j);
                let w = cv / dot(kts[q].row(i), u_row);
                // SAFETY: slot j of every query's wmd is owned by this
                // thread — the column partition never splits a column.
                let slot = unsafe { view.slice_mut(j, 1) };
                slot[0] += w * dot(km_ts[q].row(i), u_row);
            }
        });
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::ops::{sddmm_serial, spmm_serial, TransposedPattern};
    use crate::sparse::{Coo, Panel32};
    use crate::util::Pcg64;

    fn case(rng: &mut Pcg64, v: usize, n: usize, vr: usize, nnz: usize) -> (Csr, Dense, Dense, Dense, Dense) {
        let mut coo = Coo::new(v, n);
        for _ in 0..nnz {
            coo.push(rng.below(v), rng.below(n), rng.next_f64() + 0.1);
        }
        let c = Csr::from_coo(coo);
        let kt = Dense::from_fn(v, vr, |_, _| rng.next_f64() + 0.2);
        let kor_t = Dense::from_fn(v, vr, |_, _| rng.next_f64() + 0.2);
        let km_t = Dense::from_fn(v, vr, |_, _| rng.next_f64());
        let u_t = Dense::from_fn(n, vr, |_, _| rng.next_f64() + 0.2);
        (c, kt, kor_t, km_t, u_t)
    }

    /// Single-query convenience over the batched iterate.
    #[allow(clippy::too_many_arguments)]
    fn iterate_single(
        c: &Csr,
        tp: &TransposedPattern,
        kt: &Dense,
        kor_t: &Dense,
        u_t: &Dense,
        x_t: &mut Dense,
        pool: &Pool,
        col_parts: &[NnzRange],
    ) {
        sddtmm_dstmmt_batch(
            c,
            tp,
            &[kt],
            &[kor_t],
            std::slice::from_ref(u_t),
            std::slice::from_mut(x_t),
            &[true],
            ActiveView::full(),
            pool,
            col_parts,
            &mut FusedScratch::new(),
        );
    }

    #[test]
    fn iterate_matches_unfused_serial_reference() {
        let mut rng = Pcg64::new(71);
        for p in [1usize, 4, 8] {
            let (c, kt, kor_t, _km, u_t) = case(&mut rng, 35, 14, 6, 120);
            // Unfused serial reference: SDDMM then SpMM.
            let mut w = vec![0.0; c.nnz()];
            sddmm_serial(&c, &kt, &u_t, &mut w);
            let mut x_ref = Dense::zeros(14, 6);
            spmm_serial(&c, &w, &kor_t, &mut x_ref);
            let pool = Pool::new(p);
            let tp = TransposedPattern::build(&c);
            let col_parts = tp.column_parts(p);
            let mut x_t = Dense::zeros(14, 6);
            iterate_single(&c, &tp, &kt, &kor_t, &u_t, &mut x_t, &pool, &col_parts);
            assert!(x_t.max_abs_diff(&x_ref) < 1e-11, "p={p}");
        }
    }

    #[test]
    fn iterate_is_bitwise_thread_count_invariant() {
        let mut rng = Pcg64::new(74);
        let (c, kt, kor_t, _km, u_t) = case(&mut rng, 60, 25, 7, 400);
        let tp = TransposedPattern::build(&c);
        let pool1 = Pool::new(1);
        let cp1 = tp.column_parts(1);
        let mut x_ref = Dense::zeros(25, 7);
        iterate_single(&c, &tp, &kt, &kor_t, &u_t, &mut x_ref, &pool1, &cp1);
        for p in [2usize, 4, 7] {
            let pool = Pool::new(p);
            let col_parts = tp.column_parts(p);
            let mut x_t = Dense::zeros(25, 7);
            iterate_single(&c, &tp, &kt, &kor_t, &u_t, &mut x_t, &pool, &col_parts);
            // Each column accumulates in ascending source-row order no
            // matter which thread owns it → bitwise equal.
            assert_eq!(x_t, x_ref, "p={p}");
        }
    }

    /// A batch of queries over one shared pattern, with per-query v_r.
    fn batch_case(
        rng: &mut Pcg64,
        v: usize,
        n: usize,
        nnz: usize,
        vrs: &[usize],
    ) -> (Csr, Vec<Dense>, Vec<Dense>, Vec<Dense>, Vec<Dense>) {
        let mut coo = Coo::new(v, n);
        for _ in 0..nnz {
            coo.push(rng.below(v), rng.below(n), rng.next_f64() + 0.1);
        }
        let c = Csr::from_coo(coo);
        let kts: Vec<Dense> =
            vrs.iter().map(|&vr| Dense::from_fn(v, vr, |_, _| rng.next_f64() + 0.2)).collect();
        let kor_ts: Vec<Dense> =
            vrs.iter().map(|&vr| Dense::from_fn(v, vr, |_, _| rng.next_f64() + 0.2)).collect();
        let km_ts: Vec<Dense> =
            vrs.iter().map(|&vr| Dense::from_fn(v, vr, |_, _| rng.next_f64())).collect();
        let u_ts: Vec<Dense> =
            vrs.iter().map(|&vr| Dense::from_fn(n, vr, |_, _| rng.next_f64() + 0.2)).collect();
        (c, kts, kor_ts, km_ts, u_ts)
    }

    fn refs(ms: &[Dense]) -> Vec<&Dense> {
        ms.iter().collect()
    }

    #[test]
    fn batch_equals_per_query_bitwise() {
        let mut rng = Pcg64::new(83);
        let vrs = [5usize, 8, 4];
        let (c, kts, kor_ts, _km, u_ts) = batch_case(&mut rng, 55, 21, 320, &vrs);
        let tp = TransposedPattern::build(&c);
        for p in [1usize, 4, 6] {
            let pool = Pool::new(p);
            let col_parts = tp.column_parts(p);
            let mut expected = Vec::new();
            for q in 0..vrs.len() {
                let mut x = Dense::zeros(21, vrs[q]);
                iterate_single(&c, &tp, &kts[q], &kor_ts[q], &u_ts[q], &mut x, &pool, &col_parts);
                expected.push(x);
            }
            let mut x_ts: Vec<Dense> = vrs.iter().map(|&vr| Dense::zeros(21, vr)).collect();
            sddtmm_dstmmt_batch(
                &c, &tp, &refs(&kts), &refs(&kor_ts), &u_ts, &mut x_ts,
                &[true; 3], ActiveView::full(), &pool, &col_parts, &mut FusedScratch::new(),
            );
            for q in 0..vrs.len() {
                // Same per-column accumulation order → bitwise equal.
                assert_eq!(x_ts[q], expected[q], "p={p} q={q}");
            }
        }
    }

    #[test]
    fn batch_skips_inactive_queries() {
        let mut rng = Pcg64::new(82);
        let vrs = [4usize, 6, 5];
        let (c, kts, kor_ts, _km, u_ts) = batch_case(&mut rng, 30, 12, 150, &vrs);
        let tp = TransposedPattern::build(&c);
        let pool = Pool::new(3);
        let col_parts = tp.column_parts(3);
        // Sentinel-fill: an inactive (converged) query's x must be untouched.
        let mut x_ts: Vec<Dense> = vrs.iter().map(|&vr| Dense::filled(12, vr, 7.0)).collect();
        sddtmm_dstmmt_batch(
            &c, &tp, &refs(&kts), &refs(&kor_ts), &u_ts, &mut x_ts,
            &[true, false, true], ActiveView::full(), &pool, &col_parts, &mut FusedScratch::new(),
        );
        assert!(x_ts[1].as_slice().iter().all(|&v| v == 7.0), "inactive query was written");
        let mut expected = Dense::zeros(12, vrs[0]);
        iterate_single(&c, &tp, &kts[0], &kor_ts[0], &u_ts[0], &mut expected, &pool, &col_parts);
        assert_eq!(x_ts[0], expected);
    }

    #[test]
    fn reused_dirty_scratch_matches_fresh_scratch() {
        // One FusedScratch across differently-shaped calls: the act-list
        // rebuild at entry must erase every stale index.
        let mut rng = Pcg64::new(75);
        let mut scratch = FusedScratch::new();
        // Seed the scratch with a wide all-active batch first.
        let vrs_big = [3usize, 5, 4, 6, 7];
        let (c0, kts0, kor_ts0, _km0, u_ts0) = batch_case(&mut rng, 40, 16, 200, &vrs_big);
        let tp0 = TransposedPattern::build(&c0);
        let pool = Pool::new(3);
        let mut x0: Vec<Dense> = vrs_big.iter().map(|&vr| Dense::zeros(16, vr)).collect();
        sddtmm_dstmmt_batch(
            &c0, &tp0, &refs(&kts0), &refs(&kor_ts0), &u_ts0, &mut x0,
            &[true; 5], ActiveView::full(), &pool, &tp0.column_parts(3), &mut scratch,
        );
        // Now a narrower, partially-active batch with the dirty scratch.
        let vrs = [4usize, 6];
        let (c, kts, kor_ts, _km, u_ts) = batch_case(&mut rng, 25, 10, 120, &vrs);
        let tp = TransposedPattern::build(&c);
        let col_parts = tp.column_parts(3);
        let mut fresh: Vec<Dense> = vrs.iter().map(|&vr| Dense::filled(10, vr, 7.0)).collect();
        sddtmm_dstmmt_batch(
            &c, &tp, &refs(&kts), &refs(&kor_ts), &u_ts, &mut fresh,
            &[false, true], ActiveView::full(), &pool, &col_parts, &mut FusedScratch::new(),
        );
        let mut reused: Vec<Dense> = vrs.iter().map(|&vr| Dense::filled(10, vr, 7.0)).collect();
        sddtmm_dstmmt_batch(
            &c, &tp, &refs(&kts), &refs(&kor_ts), &u_ts, &mut reused,
            &[false, true], ActiveView::full(), &pool, &col_parts, &mut scratch,
        );
        assert_eq!(fresh[0], reused[0], "dirty scratch touched an inactive query");
        assert_eq!(fresh[1], reused[1], "dirty scratch perturbed the iterate");
        assert!(scratch.retained_bytes() > 0);
    }

    #[test]
    fn f32_panels_match_f64_within_error_bound() {
        let mut rng = Pcg64::new(91);
        let (c, kt, kor_t, _km, u_t) = case(&mut rng, 50, 20, 13, 260);
        let tp = TransposedPattern::build(&c);
        for p in [1usize, 4] {
            let pool = Pool::new(p);
            let col_parts = tp.column_parts(p);
            let mut x64 = Dense::zeros(20, 13);
            iterate_single(&c, &tp, &kt, &kor_t, &u_t, &mut x64, &pool, &col_parts);
            let mut kt_lo = Panel32::new();
            kt_lo.reset_from(&kt, &pool);
            let mut kor_lo = Panel32::new();
            kor_lo.reset_from(&kor_t, &pool);
            let mut u_lo = Panel32::new();
            u_lo.reset_from(&u_t, &pool);
            let mut x32 = Dense::zeros(20, 13);
            sddtmm_dstmmt_batch(
                &c,
                &tp,
                &[&kt_lo],
                &[&kor_lo],
                std::slice::from_ref(&u_lo),
                std::slice::from_mut(&mut x32),
                &[true],
                ActiveView::full(),
                &pool,
                &col_parts,
                &mut FusedScratch::new(),
            );
            // Single-step panel error is O(v_r · ε_f32) relative — far
            // inside 1e-4 at these scales (end-to-end solves land ~1e-9;
            // the solver-level gate is 1e-5).
            for (a, b) in x32.as_slice().iter().zip(x64.as_slice()) {
                assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "p={p}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn frozen_mask_matches_reference_on_unfrozen_rows() {
        let mut rng = Pcg64::new(93);
        for p in [1usize, 3, 8] {
            let (c, kt, kor_t, _km, u_t) = case(&mut rng, 45, 18, 6, 260);
            let tp = TransposedPattern::build(&c);
            let pool = Pool::new(p);
            let col_parts = tp.column_parts(p);
            let mut x_ref = Dense::zeros(18, 6);
            iterate_single(&c, &tp, &kt, &kor_t, &u_t, &mut x_ref, &pool, &col_parts);
            let frozen: Vec<bool> = (0..18).map(|_| rng.next_f64() < 0.4).collect();
            let mut x_t = Dense::filled(18, 6, 7.0);
            sddtmm_dstmmt_batch(
                &c,
                &tp,
                &[&kt],
                &[&kor_t],
                std::slice::from_ref(&u_t),
                std::slice::from_mut(&mut x_t),
                &[true],
                ActiveView { cols: None, frozen: Some(&frozen) },
                &pool,
                &col_parts,
                &mut FusedScratch::new(),
            );
            for j in 0..18 {
                if frozen[j] {
                    // Frozen rows are cleared by the full zeroing pass but
                    // never accumulated into.
                    assert!(x_t.row(j).iter().all(|&v| v == 0.0), "p={p} j={j}");
                } else {
                    // Unfrozen rows accumulate in the same order → bitwise.
                    assert_eq!(x_t.row(j), x_ref.row(j), "p={p} j={j}");
                }
            }
        }
    }

    #[test]
    fn compacted_traversal_bitwise_equals_full_on_surviving_columns() {
        use crate::parallel::{balanced_nnz_partition, subset_nnz_prefix_into};
        let mut rng = Pcg64::new(94);
        let vrs = [5usize, 7];
        let n = 22;
        let (c, kts, kor_ts, _km, u_ts) = batch_case(&mut rng, 50, n, 340, &vrs);
        let tp = TransposedPattern::build(&c);
        // Per-query frozen masks; the compacted column list is the union of
        // the queries' survivors — exactly what the solver builds.
        let frozen: Vec<bool> = (0..vrs.len() * n).map(|_| rng.next_f64() < 0.5).collect();
        let cols: Vec<u32> = (0..n as u32)
            .filter(|&j| (0..vrs.len()).any(|q| !frozen[q * n + j as usize]))
            .collect();
        let mut sub_ptr = Vec::new();
        subset_nnz_prefix_into(&tp.col_ptr, &cols, &mut sub_ptr);
        for p in [1usize, 3, 8] {
            let pool = Pool::new(p);
            // Reference: full traversal with the same frozen mask.
            let full_parts = tp.column_parts(p);
            let mut x_ref: Vec<Dense> = vrs.iter().map(|&vr| Dense::zeros(n, vr)).collect();
            sddtmm_dstmmt_batch(
                &c, &tp, &refs(&kts), &refs(&kor_ts), &u_ts, &mut x_ref,
                &[true; 2], ActiveView { cols: None, frozen: Some(&frozen) },
                &pool, &full_parts, &mut FusedScratch::new(),
            );
            // Compacted: partition the subset prefix, sentinel-fill to prove
            // non-subset rows are never touched.
            let sub_parts = balanced_nnz_partition(&sub_ptr, p);
            let mut x_cmp: Vec<Dense> = vrs.iter().map(|&vr| Dense::filled(n, vr, 7.0)).collect();
            sddtmm_dstmmt_batch(
                &c, &tp, &refs(&kts), &refs(&kor_ts), &u_ts, &mut x_cmp,
                &[true; 2], ActiveView { cols: Some((&cols, &sub_ptr)), frozen: Some(&frozen) },
                &pool, &sub_parts, &mut FusedScratch::new(),
            );
            for q in 0..vrs.len() {
                for j in 0..n {
                    if !cols.contains(&(j as u32)) {
                        assert!(
                            x_cmp[q].row(j).iter().all(|&v| v == 7.0),
                            "p={p} q={q} j={j}: non-subset row touched"
                        );
                    } else if frozen[q * n + j] {
                        // In the union but frozen for this query: zeroed,
                        // never accumulated.
                        assert!(x_cmp[q].row(j).iter().all(|&v| v == 0.0), "p={p} q={q} j={j}");
                    } else {
                        // Same ascending per-column accumulation → bitwise.
                        assert_eq!(x_cmp[q].row(j), x_ref[q].row(j), "p={p} q={q} j={j}");
                    }
                }
            }
        }
    }

    #[test]
    fn wmd_epilogue_equals_dense_formula() {
        let mut rng = Pcg64::new(73);
        for p in [1usize, 4] {
            let (c, kt, _kor, km_t, u_t) = case(&mut rng, 20, 9, 5, 60);
            // Dense oracle: v = c / (KT@u) at pattern; WMD = (u * (KM@v)).sum(0).
            let u = u_t.transpose(); // v_r × N
            let ktu = kt.matmul(&u_t.transpose()); // V×N
            let mut vdense = Dense::zeros(c.nrows(), c.ncols());
            for (i, j, cv) in c.iter() {
                vdense.set(i, j, cv / ktu.get(i, j));
            }
            let km = km_t.transpose(); // v_r × V
            let kmv = km.matmul(&vdense); // v_r × N
            let mut oracle = vec![0.0; c.ncols()];
            for jj in 0..c.ncols() {
                for ii in 0..u.nrows() {
                    oracle[jj] += u.get(ii, jj) * kmv.get(ii, jj);
                }
            }
            let pool = Pool::new(p);
            let tp = TransposedPattern::build(&c);
            let col_parts = tp.column_parts(p);
            let mut wmd = vec![0.0; c.ncols()];
            sddtmm_wmd_batch(
                &c,
                &tp,
                &[&kt],
                &[&km_t],
                std::slice::from_ref(&u_t),
                std::slice::from_mut(&mut wmd),
                &pool,
                &col_parts,
            );
            for (a, b) in wmd.iter().zip(&oracle) {
                assert!((a - b).abs() < 1e-11 * (1.0 + b.abs()), "p={p}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn wmd_epilogue_batch_bitwise_matches_single_and_threads() {
        let mut rng = Pcg64::new(84);
        let vrs = [6usize, 3, 8, 5];
        let (c, kts, _kor, km_ts, u_ts) = batch_case(&mut rng, 40, 15, 200, &vrs);
        let tp = TransposedPattern::build(&c);
        let pool1 = Pool::new(1);
        let cp1 = tp.column_parts(1);
        let mut singles: Vec<Vec<Real>> = Vec::new();
        for q in 0..vrs.len() {
            let mut wmd = vec![0.0; 15];
            sddtmm_wmd_batch(
                &c,
                &tp,
                &[&kts[q]],
                &[&km_ts[q]],
                std::slice::from_ref(&u_ts[q]),
                std::slice::from_mut(&mut wmd),
                &pool1,
                &cp1,
            );
            singles.push(wmd);
        }
        for p in [1usize, 4, 7] {
            let pool = Pool::new(p);
            let col_parts = tp.column_parts(p);
            let mut wmds: Vec<Vec<Real>> = (0..vrs.len()).map(|_| vec![0.0; 15]).collect();
            sddtmm_wmd_batch(
                &c, &tp, &refs(&kts), &refs(&km_ts), &u_ts, &mut wmds, &pool, &col_parts,
            );
            for q in 0..vrs.len() {
                // Ascending-row per-slot accumulation order in every
                // configuration → bitwise equal.
                assert_eq!(wmds[q], singles[q], "p={p} q={q}");
            }
        }
    }
}
