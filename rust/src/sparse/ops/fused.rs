//! The paper's new kernel: **SDDMM_SpMM** — one pass over the CSR that
//! computes each SDDMM value and immediately feeds it to the SpMM
//! accumulation ("the output values from SDDMM can be fed directly to the
//! SpMM and would not need to be stored in memory", §4).
//!
//! * [`fused_type1`] — the solver-loop iterate:
//!   `x = K_over_r @ (c ⊘ (Kᵀ@u))`, scatter under atomics (paper Fig. 4).
//! * [`fused_type1_private`] — atomic-free variant with per-thread output
//!   buffers + tree reduction (perf-pass alternative; see §Perf).
//! * [`fused_type2`] — the epilogue:
//!   `WMD[j] = Σ_e w_e · ⟨(K⊙M)ᵀ[row], uᵀ[col]⟩`, which is algebraically
//!   `(u ⊙ ((K⊙M) @ v)).sum(axis=0)` restricted to the pattern of `c`.

use super::for_each_nnz_in;
use crate::parallel::{AtomicF64Slice, NnzRange, Pool};
use crate::sparse::{axpy, dot, Csr, Dense};
use crate::util::SharedSlice;
use crate::Real;

/// Fused iterate (type 1): for each nnz `(i, j)` of `c`,
/// `w = c[i,j] / ⟨ktᵀ[i,:], uᵀ[j,:]⟩` then `xᵀ[j,:] += w · kor_tᵀ[i,:]`
/// (atomic adds — threads share output rows).
pub fn fused_type1(
    c: &Csr,
    kt: &Dense,
    kor_t: &Dense,
    u_t: &Dense,
    x_t: &mut Dense,
    pool: &Pool,
    parts: &[NnzRange],
) {
    let vr = kt.ncols();
    debug_assert_eq!(kor_t.ncols(), vr);
    debug_assert_eq!(u_t.ncols(), vr);
    debug_assert_eq!(x_t.ncols(), vr);
    debug_assert_eq!(kt.nrows(), c.nrows());
    debug_assert_eq!(u_t.nrows(), c.ncols());
    x_t.fill(0.0);
    // Serial fast path: a CAS-loop per element costs ~7× even without
    // contention (it defeats vectorization of the axpy), so a single
    // thread writes directly (§Perf in EXPERIMENTS.md).
    if pool.nthreads() == 1 {
        let (row_ptr, col_idx, values) = (c.row_ptr(), c.col_idx(), c.values());
        let x = x_t.as_mut_slice();
        for row in 0..c.nrows() {
            let kt_row = kt.row(row);
            let kor_row = kor_t.row(row);
            for e in row_ptr[row]..row_ptr[row + 1] {
                let j = col_idx[e] as usize;
                let w = values[e] / dot(kt_row, u_t.row(j));
                axpy(&mut x[j * vr..(j + 1) * vr], w, kor_row);
            }
        }
        return;
    }
    let x_atomic = AtomicF64Slice::new(x_t.as_mut_slice());
    let (row_ptr, col_idx, values) = (c.row_ptr(), c.col_idx(), c.values());
    pool.run(|tid, _nt| {
        let part = parts[tid];
        for_each_nnz_in(part, row_ptr, |e, row| {
            let j = col_idx[e] as usize;
            let u_row = u_t.row(j);
            // SDDMM step.
            let s = dot(kt.row(row), u_row);
            let w = values[e] / s;
            // SpMM step, fused: no w store, straight into x.
            let k_row = kor_t.row(row);
            let base = j * vr;
            for (k, &kv) in k_row.iter().enumerate() {
                x_atomic.fetch_add(base + k, w * kv);
            }
        });
    });
}

/// Fused iterate with per-thread private accumulation buffers: each thread
/// scatters into its own `N×v_r` copy; buffers are then reduced in
/// parallel over disjoint slices. Trades `p·N·v_r` scratch memory for
/// atomic-free inner loops.
pub struct PrivateBuffers {
    bufs: Vec<Vec<Real>>,
}

impl PrivateBuffers {
    pub fn new(nthreads: usize, n: usize, vr: usize) -> Self {
        Self { bufs: (0..nthreads).map(|_| vec![0.0; n * vr]).collect() }
    }

    pub fn matches(&self, nthreads: usize, len: usize) -> bool {
        self.bufs.len() == nthreads && self.bufs.first().map_or(false, |b| b.len() == len)
    }
}

pub fn fused_type1_private(
    c: &Csr,
    kt: &Dense,
    kor_t: &Dense,
    u_t: &Dense,
    x_t: &mut Dense,
    pool: &Pool,
    parts: &[NnzRange],
    scratch: &mut PrivateBuffers,
) {
    let vr = kt.ncols();
    let len = x_t.nrows() * vr;
    assert!(scratch.matches(pool.nthreads(), len), "scratch shape mismatch");
    let (row_ptr, col_idx, values) = (c.row_ptr(), c.col_idx(), c.values());
    // Phase 1: private scatter. Each thread owns scratch.bufs[tid].
    {
        let buf_ptrs: Vec<SharedSlice<Real>> =
            scratch.bufs.iter_mut().map(|b| SharedSlice::new(b.as_mut_slice())).collect();
        pool.run(|tid, _nt| {
            let part = parts[tid];
            // SAFETY: buffer `tid` is written only by thread `tid`.
            let buf = unsafe { buf_ptrs[tid].slice_mut(0, len) };
            buf.fill(0.0);
            for_each_nnz_in(part, row_ptr, |e, row| {
                let j = col_idx[e] as usize;
                let w = values[e] / dot(kt.row(row), u_t.row(j));
                axpy(&mut buf[j * vr..(j + 1) * vr], w, kor_t.row(row));
            });
        });
    }
    // Phase 2: parallel reduction over disjoint element ranges.
    let bufs = &scratch.bufs;
    let x_view = SharedSlice::new(x_t.as_mut_slice());
    pool.run(|tid, nt| {
        let r = crate::parallel::static_chunk(len, tid, nt);
        // SAFETY: element ranges are disjoint per thread.
        let out = unsafe { x_view.slice_mut(r.start, r.len()) };
        out.fill(0.0);
        for buf in bufs {
            for (o, &v) in out.iter_mut().zip(&buf[r.clone()]) {
                *o += v;
            }
        }
    });
}

/// Fused iterate over the **transposed pattern** — atomic-free: each
/// thread owns whole documents (columns of `c`, i.e. rows of `xᵀ`), so
/// the SDDMM value feeds the SpMM axpy with no synchronization at all.
/// The pattern is built once per query (`c`'s sparsity is
/// iteration-invariant) and reused across all Sinkhorn iterations; the
/// document's `uᵀ` row also stays hot across the column's entries —
/// the cache-reuse idea of the paper's §9 tiling discussion.
pub fn fused_type1_transposed(
    c: &Csr,
    tp: &super::spmm::TransposedPattern,
    kt: &Dense,
    kor_t: &Dense,
    u_t: &Dense,
    x_t: &mut Dense,
    pool: &Pool,
    col_parts: &[NnzRange],
) {
    let vr = kt.ncols();
    debug_assert_eq!(x_t.nrows() + 1, tp.col_ptr.len());
    debug_assert_eq!(x_t.ncols(), vr);
    x_t.fill(0.0);
    let values = c.values();
    let x_view = SharedSlice::new(x_t.as_mut_slice());
    pool.run(|tid, _nt| {
        let part = col_parts[tid];
        for_each_nnz_in(part, &tp.col_ptr, |e, j| {
            let i = tp.src_row[e] as usize;
            let u_row = u_t.row(j);
            let w = values[tp.src_pos[e] as usize] / dot(kt.row(i), u_row);
            // SAFETY: column j (x_t row j) is owned by this thread — the
            // column partition never splits a column.
            let x_row = unsafe { x_view.slice_mut(j * vr, vr) };
            axpy(x_row, w, kor_t.row(i));
        });
    });
}

/// Fused epilogue (type 2): the final WMD vector.
///
/// `WMD[j] = Σ_{(i,j) ∈ nnz(c)} (c[i,j] / ⟨ktᵀ[i], uᵀ[j]⟩) · ⟨km_tᵀ[i], uᵀ[j]⟩`
///
/// equals `(u ⊙ ((K⊙M) @ v)).sum(axis=0)` from Algorithm 1. Accumulated in
/// per-thread partial vectors (length `N`), reduced after the region — the
/// scatter target is a scalar per doc, so privatization is cheap.
pub fn fused_type2(
    c: &Csr,
    kt: &Dense,
    km_t: &Dense,
    u_t: &Dense,
    wmd: &mut [Real],
    pool: &Pool,
    parts: &[NnzRange],
) {
    let n = c.ncols();
    assert_eq!(wmd.len(), n);
    let nthreads = pool.nthreads();
    let mut partials = vec![0.0; nthreads * n];
    let (row_ptr, col_idx, values) = (c.row_ptr(), c.col_idx(), c.values());
    {
        let pview = SharedSlice::new(&mut partials);
        pool.run(|tid, _nt| {
            let part = parts[tid];
            // SAFETY: each thread owns partial slice tid.
            let acc = unsafe { pview.slice_mut(tid * n, n) };
            for_each_nnz_in(part, row_ptr, |e, row| {
                let j = col_idx[e] as usize;
                let u_row = u_t.row(j);
                let w = values[e] / dot(kt.row(row), u_row);
                acc[j] += w * dot(km_t.row(row), u_row);
            });
        });
    }
    wmd.fill(0.0);
    for t in 0..nthreads {
        for j in 0..n {
            wmd[j] += partials[t * n + j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::balanced_nnz_partition;
    use crate::sparse::ops::{sddmm_serial, spmm_serial};
    use crate::sparse::Coo;
    use crate::util::Pcg64;

    fn case(rng: &mut Pcg64, v: usize, n: usize, vr: usize, nnz: usize) -> (Csr, Dense, Dense, Dense, Dense) {
        let mut coo = Coo::new(v, n);
        for _ in 0..nnz {
            coo.push(rng.below(v), rng.below(n), rng.next_f64() + 0.1);
        }
        let c = Csr::from_coo(coo);
        let kt = Dense::from_fn(v, vr, |_, _| rng.next_f64() + 0.2);
        let kor_t = Dense::from_fn(v, vr, |_, _| rng.next_f64() + 0.2);
        let km_t = Dense::from_fn(v, vr, |_, _| rng.next_f64());
        let u_t = Dense::from_fn(n, vr, |_, _| rng.next_f64() + 0.2);
        (c, kt, kor_t, km_t, u_t)
    }

    #[test]
    fn type1_equals_unfused() {
        let mut rng = Pcg64::new(71);
        for p in [1usize, 4, 8] {
            let (c, kt, kor_t, _km, u_t) = case(&mut rng, 35, 14, 6, 120);
            // Unfused serial reference: SDDMM then SpMM.
            let mut w = vec![0.0; c.nnz()];
            sddmm_serial(&c, &kt, &u_t, &mut w);
            let mut x_ref = Dense::zeros(14, 6);
            spmm_serial(&c, &w, &kor_t, &mut x_ref);
            // Fused parallel.
            let pool = Pool::new(p);
            let parts = balanced_nnz_partition(c.row_ptr(), p);
            let mut x_t = Dense::zeros(14, 6);
            fused_type1(&c, &kt, &kor_t, &u_t, &mut x_t, &pool, &parts);
            assert!(x_t.max_abs_diff(&x_ref) < 1e-11, "p={p}");
        }
    }

    #[test]
    fn type1_private_equals_atomic() {
        let mut rng = Pcg64::new(72);
        for p in [1usize, 3, 6] {
            let (c, kt, kor_t, _km, u_t) = case(&mut rng, 50, 21, 9, 300);
            let pool = Pool::new(p);
            let parts = balanced_nnz_partition(c.row_ptr(), p);
            let mut x_a = Dense::zeros(21, 9);
            fused_type1(&c, &kt, &kor_t, &u_t, &mut x_a, &pool, &parts);
            let mut x_p = Dense::zeros(21, 9);
            let mut scratch = PrivateBuffers::new(p, 21, 9);
            fused_type1_private(&c, &kt, &kor_t, &u_t, &mut x_p, &pool, &parts, &mut scratch);
            assert!(x_a.max_abs_diff(&x_p) < 1e-11, "p={p}");
        }
    }

    #[test]
    fn type1_transposed_equals_atomic() {
        let mut rng = Pcg64::new(74);
        for p in [1usize, 4, 7] {
            let (c, kt, kor_t, _km, u_t) = case(&mut rng, 60, 25, 7, 400);
            let pool = Pool::new(p);
            let parts = balanced_nnz_partition(c.row_ptr(), p);
            let mut x_a = Dense::zeros(25, 7);
            fused_type1(&c, &kt, &kor_t, &u_t, &mut x_a, &pool, &parts);
            let tp = crate::sparse::ops::TransposedPattern::build(&c);
            let col_parts = tp.column_parts(p);
            let mut x_t = Dense::zeros(25, 7);
            fused_type1_transposed(&c, &tp, &kt, &kor_t, &u_t, &mut x_t, &pool, &col_parts);
            assert!(x_a.max_abs_diff(&x_t) < 1e-11, "p={p}");
        }
    }

    #[test]
    fn type2_equals_dense_formula() {
        let mut rng = Pcg64::new(73);
        for p in [1usize, 4] {
            let (c, kt, _kor, km_t, u_t) = case(&mut rng, 20, 9, 5, 60);
            // Dense oracle: v = c / (KT@u) at pattern; WMD = (u * (KM@v)).sum(0).
            let u = u_t.transpose(); // v_r × N... careful: u in Algorithm 1 is v_r×N
            let ktu = kt.matmul(&u_t.transpose()); // V×N
            let mut vdense = Dense::zeros(c.nrows(), c.ncols());
            for (i, j, cv) in c.iter() {
                vdense.set(i, j, cv / ktu.get(i, j));
            }
            let km = km_t.transpose(); // v_r × V
            let kmv = km.matmul(&vdense); // v_r × N
            let mut oracle = vec![0.0; c.ncols()];
            for jj in 0..c.ncols() {
                for ii in 0..u.nrows() {
                    oracle[jj] += u.get(ii, jj) * kmv.get(ii, jj);
                }
            }
            let pool = Pool::new(p);
            let parts = balanced_nnz_partition(c.row_ptr(), p);
            let mut wmd = vec![0.0; c.ncols()];
            fused_type2(&c, &kt, &km_t, &u_t, &mut wmd, &pool, &parts);
            for (a, b) in wmd.iter().zip(&oracle) {
                assert!((a - b).abs() < 1e-11 * (1.0 + b.abs()), "p={p}: {a} vs {b}");
            }
        }
    }
}
