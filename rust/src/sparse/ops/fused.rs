//! The paper's new kernel: **SDDMM_SpMM** — one pass over the CSR that
//! computes each SDDMM value and immediately feeds it to the SpMM
//! accumulation ("the output values from SDDMM can be fed directly to the
//! SpMM and would not need to be stored in memory", §4).
//!
//! * [`fused_type1`] — the solver-loop iterate:
//!   `x = K_over_r @ (c ⊘ (Kᵀ@u))`, scatter under atomics (paper Fig. 4).
//! * [`fused_type1_private`] — atomic-free variant with per-thread output
//!   buffers + tree reduction (perf-pass alternative; see §Perf).
//! * [`fused_type2`] — the epilogue:
//!   `WMD[j] = Σ_e w_e · ⟨(K⊙M)ᵀ[row], uᵀ[col]⟩`, which is algebraically
//!   `(u ⊙ ((K⊙M) @ v)).sum(axis=0)` restricted to the pattern of `c`.
//! * [`fused_type1_batch`] / [`fused_type1_transposed_batch`] /
//!   [`fused_type2_batch`] — cross-query batched variants: one CSR
//!   traversal serves `B` prepared queries (per-query stride, per-query
//!   active mask), amortizing the pattern walk across concurrent solves.

use super::for_each_nnz_in;
use crate::parallel::{AtomicF64Slice, NnzRange, Pool};
use crate::sparse::{axpy, dot, Csr, Dense};
use crate::util::SharedSlice;
use crate::Real;

/// Reusable scratch for the fused kernels, passed in by the caller instead
/// of allocated per call (the zero-alloc hot-path contract: a retained
/// [`crate::sinkhorn::SolveWorkspace`] owns one and its buffers are
/// grow-only, so steady-state kernel invocations never touch the
/// allocator).
#[derive(Debug, Default)]
pub struct FusedScratch {
    /// Per-thread partial accumulators for the type-2 reduction
    /// (`nthreads · N` scalars single-query, `nthreads · B · N` batched).
    partials: Vec<Real>,
    /// Indices of the active (not yet converged) queries of a batch.
    act: Vec<usize>,
}

impl FusedScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Heap bytes held by the scratch's backing allocations.
    pub fn retained_bytes(&self) -> usize {
        self.partials.capacity() * std::mem::size_of::<Real>()
            + self.act.capacity() * std::mem::size_of::<usize>()
    }
}

/// Fused iterate (type 1): for each nnz `(i, j)` of `c`,
/// `w = c[i,j] / ⟨ktᵀ[i,:], uᵀ[j,:]⟩` then `xᵀ[j,:] += w · kor_tᵀ[i,:]`
/// (atomic adds — threads share output rows).
pub fn fused_type1(
    c: &Csr,
    kt: &Dense,
    kor_t: &Dense,
    u_t: &Dense,
    x_t: &mut Dense,
    pool: &Pool,
    parts: &[NnzRange],
) {
    let vr = kt.ncols();
    debug_assert_eq!(kor_t.ncols(), vr);
    debug_assert_eq!(u_t.ncols(), vr);
    debug_assert_eq!(x_t.ncols(), vr);
    debug_assert_eq!(kt.nrows(), c.nrows());
    debug_assert_eq!(u_t.nrows(), c.ncols());
    x_t.fill(0.0);
    // Serial fast path: a CAS-loop per element costs ~7× even without
    // contention (it defeats vectorization of the axpy), so a single
    // thread writes directly (§Perf in EXPERIMENTS.md).
    if pool.nthreads() == 1 {
        let (row_ptr, col_idx, values) = (c.row_ptr(), c.col_idx(), c.values());
        let x = x_t.as_mut_slice();
        for row in 0..c.nrows() {
            let kt_row = kt.row(row);
            let kor_row = kor_t.row(row);
            for e in row_ptr[row]..row_ptr[row + 1] {
                let j = col_idx[e] as usize;
                let w = values[e] / dot(kt_row, u_t.row(j));
                axpy(&mut x[j * vr..(j + 1) * vr], w, kor_row);
            }
        }
        return;
    }
    let x_atomic = AtomicF64Slice::new(x_t.as_mut_slice());
    let (row_ptr, col_idx, values) = (c.row_ptr(), c.col_idx(), c.values());
    pool.run(|tid, _nt| {
        let part = parts[tid];
        for_each_nnz_in(part, row_ptr, |e, row| {
            let j = col_idx[e] as usize;
            let u_row = u_t.row(j);
            // SDDMM step.
            let s = dot(kt.row(row), u_row);
            let w = values[e] / s;
            // SpMM step, fused: no w store, straight into x.
            let k_row = kor_t.row(row);
            let base = j * vr;
            for (k, &kv) in k_row.iter().enumerate() {
                x_atomic.fetch_add(base + k, w * kv);
            }
        });
    });
}

/// Fused iterate with per-thread private accumulation buffers: each thread
/// scatters into its own `N×v_r` copy; buffers are then reduced in
/// parallel over disjoint slices. Trades `p·N·v_r` scratch memory for
/// atomic-free inner loops.
#[derive(Debug, Default)]
pub struct PrivateBuffers {
    bufs: Vec<Vec<Real>>,
}

impl PrivateBuffers {
    pub fn new(nthreads: usize, n: usize, vr: usize) -> Self {
        let mut bufs = Self::default();
        bufs.ensure(nthreads, n * vr);
        bufs
    }

    /// Shape the buffers to `nthreads × len`, reusing the backing
    /// allocations (grow-only) — the workspace checkout path.
    pub fn ensure(&mut self, nthreads: usize, len: usize) {
        self.bufs.truncate(nthreads);
        while self.bufs.len() < nthreads {
            self.bufs.push(Vec::new());
        }
        for b in &mut self.bufs {
            b.clear();
            b.resize(len, 0.0);
        }
    }

    pub fn matches(&self, nthreads: usize, len: usize) -> bool {
        self.bufs.len() == nthreads && self.bufs.first().map_or(false, |b| b.len() == len)
    }

    /// Heap bytes held by the buffers' backing allocations.
    pub fn retained_bytes(&self) -> usize {
        self.bufs.iter().map(|b| b.capacity() * std::mem::size_of::<Real>()).sum::<usize>()
            + self.bufs.capacity() * std::mem::size_of::<Vec<Real>>()
    }
}

#[allow(clippy::too_many_arguments)]
pub fn fused_type1_private(
    c: &Csr,
    kt: &Dense,
    kor_t: &Dense,
    u_t: &Dense,
    x_t: &mut Dense,
    pool: &Pool,
    parts: &[NnzRange],
    scratch: &mut PrivateBuffers,
) {
    let vr = kt.ncols();
    let len = x_t.nrows() * vr;
    assert!(scratch.matches(pool.nthreads(), len), "scratch shape mismatch");
    let (row_ptr, col_idx, values) = (c.row_ptr(), c.col_idx(), c.values());
    // Phase 1: private scatter. Each thread owns scratch.bufs[tid].
    {
        let buf_ptrs: Vec<SharedSlice<Real>> =
            scratch.bufs.iter_mut().map(|b| SharedSlice::new(b.as_mut_slice())).collect();
        pool.run(|tid, _nt| {
            let part = parts[tid];
            // SAFETY: buffer `tid` is written only by thread `tid`.
            let buf = unsafe { buf_ptrs[tid].slice_mut(0, len) };
            buf.fill(0.0);
            for_each_nnz_in(part, row_ptr, |e, row| {
                let j = col_idx[e] as usize;
                let w = values[e] / dot(kt.row(row), u_t.row(j));
                axpy(&mut buf[j * vr..(j + 1) * vr], w, kor_t.row(row));
            });
        });
    }
    // Phase 2: parallel reduction over disjoint element ranges.
    let bufs = &scratch.bufs;
    let x_view = SharedSlice::new(x_t.as_mut_slice());
    pool.run(|tid, nt| {
        let r = crate::parallel::static_chunk(len, tid, nt);
        // SAFETY: element ranges are disjoint per thread.
        let out = unsafe { x_view.slice_mut(r.start, r.len()) };
        out.fill(0.0);
        for buf in bufs {
            for (o, &v) in out.iter_mut().zip(&buf[r.clone()]) {
                *o += v;
            }
        }
    });
}

/// Fused iterate over the **transposed pattern** — atomic-free: each
/// thread owns whole documents (columns of `c`, i.e. rows of `xᵀ`), so
/// the SDDMM value feeds the SpMM axpy with no synchronization at all.
/// The pattern is built once per query (`c`'s sparsity is
/// iteration-invariant) and reused across all Sinkhorn iterations; the
/// document's `uᵀ` row also stays hot across the column's entries —
/// the cache-reuse idea of the paper's §9 tiling discussion.
#[allow(clippy::too_many_arguments)]
pub fn fused_type1_transposed(
    c: &Csr,
    tp: &super::spmm::TransposedPattern,
    kt: &Dense,
    kor_t: &Dense,
    u_t: &Dense,
    x_t: &mut Dense,
    pool: &Pool,
    col_parts: &[NnzRange],
) {
    let vr = kt.ncols();
    debug_assert_eq!(x_t.nrows() + 1, tp.col_ptr.len());
    debug_assert_eq!(x_t.ncols(), vr);
    x_t.fill(0.0);
    let values = c.values();
    let x_view = SharedSlice::new(x_t.as_mut_slice());
    pool.run(|tid, _nt| {
        let part = col_parts[tid];
        for_each_nnz_in(part, &tp.col_ptr, |e, j| {
            let i = tp.src_row[e] as usize;
            let u_row = u_t.row(j);
            let w = values[tp.src_pos[e] as usize] / dot(kt.row(i), u_row);
            // SAFETY: column j (x_t row j) is owned by this thread — the
            // column partition never splits a column.
            let x_row = unsafe { x_view.slice_mut(j * vr, vr) };
            axpy(x_row, w, kor_t.row(i));
        });
    });
}

/// Fused epilogue (type 2): the final WMD vector.
///
/// `WMD[j] = Σ_{(i,j) ∈ nnz(c)} (c[i,j] / ⟨ktᵀ[i], uᵀ[j]⟩) · ⟨km_tᵀ[i], uᵀ[j]⟩`
///
/// equals `(u ⊙ ((K⊙M) @ v)).sum(axis=0)` from Algorithm 1. Accumulated in
/// per-thread partial vectors (length `N`), reduced after the region — the
/// scatter target is a scalar per doc, so privatization is cheap.
#[allow(clippy::too_many_arguments)]
pub fn fused_type2(
    c: &Csr,
    kt: &Dense,
    km_t: &Dense,
    u_t: &Dense,
    wmd: &mut [Real],
    pool: &Pool,
    parts: &[NnzRange],
    scratch: &mut FusedScratch,
) {
    let n = c.ncols();
    assert_eq!(wmd.len(), n);
    let nthreads = pool.nthreads();
    let partials = &mut scratch.partials;
    partials.clear();
    partials.resize(nthreads * n, 0.0);
    let (row_ptr, col_idx, values) = (c.row_ptr(), c.col_idx(), c.values());
    {
        let pview = SharedSlice::new(partials.as_mut_slice());
        pool.run(|tid, _nt| {
            let part = parts[tid];
            // SAFETY: each thread owns partial slice tid.
            let acc = unsafe { pview.slice_mut(tid * n, n) };
            for_each_nnz_in(part, row_ptr, |e, row| {
                let j = col_idx[e] as usize;
                let u_row = u_t.row(j);
                let w = values[e] / dot(kt.row(row), u_row);
                acc[j] += w * dot(km_t.row(row), u_row);
            });
        });
    }
    wmd.fill(0.0);
    for t in 0..nthreads {
        for j in 0..n {
            wmd[j] += partials[t * n + j];
        }
    }
}

/// Cross-query batched fused iterate (type 1): one traversal of the CSR
/// serves `B` queries. Per nnz `(i, j)` the row cursor, column index and
/// `c[i,j]` are read **once**, then every *active* query `q` runs its own
/// SDDMM + scatter with its own stride `v_r(q)`:
/// `w = c[i,j] / ⟨kts[q][i,:], u_ts[q][j,:]⟩`, `x_ts[q][j,:] += w · kor_ts[q][i,:]`.
///
/// This is the amortization the dispatcher batches for (PIUMA follow-up,
/// arXiv:2107.06433): the pattern walk, its branch logic and the `c`
/// cache misses are paid once per nnz instead of once per (nnz, query).
/// Queries whose `active[q]` is false (already converged) are skipped
/// without stalling the rest of the batch; their `x_ts[q]` is untouched.
///
/// All per-query shapes follow the single-query [`fused_type1`]
/// contract; the batch slices must share length `B`. `u_ts` is a plain
/// `&[Dense]` (not `&[&Dense]`): the per-query `u` states live
/// contiguously in the solver workspace's lanes, so the per-iteration
/// call needs no reference-vector rebuild — the factor slices, by
/// contrast, point into `B` separately-owned `Prepared` values.
#[allow(clippy::too_many_arguments)]
pub fn fused_type1_batch(
    c: &Csr,
    kts: &[&Dense],
    kor_ts: &[&Dense],
    u_ts: &[Dense],
    x_ts: &mut [Dense],
    active: &[bool],
    pool: &Pool,
    parts: &[NnzRange],
    scratch: &mut FusedScratch,
) {
    let b = kts.len();
    debug_assert_eq!(kor_ts.len(), b);
    debug_assert_eq!(u_ts.len(), b);
    debug_assert_eq!(x_ts.len(), b);
    debug_assert_eq!(active.len(), b);
    for q in 0..b {
        let vr = kts[q].ncols();
        debug_assert_eq!(kor_ts[q].ncols(), vr);
        debug_assert_eq!(u_ts[q].ncols(), vr);
        debug_assert_eq!(x_ts[q].ncols(), vr);
        debug_assert_eq!(kts[q].nrows(), c.nrows());
        debug_assert_eq!(u_ts[q].nrows(), c.ncols());
    }
    scratch.act.clear();
    scratch.act.extend((0..b).filter(|&q| active[q]));
    let act: &[usize] = &scratch.act;
    if act.is_empty() {
        return;
    }
    let (row_ptr, col_idx, values) = (c.row_ptr(), c.col_idx(), c.values());
    // Serial fast path: direct writes, same rationale as fused_type1.
    if pool.nthreads() == 1 {
        for &q in act {
            x_ts[q].fill(0.0);
        }
        for row in 0..c.nrows() {
            for e in row_ptr[row]..row_ptr[row + 1] {
                let j = col_idx[e] as usize;
                let cv = values[e];
                for &q in act {
                    let vr = kts[q].ncols();
                    let w = cv / dot(kts[q].row(row), u_ts[q].row(j));
                    let x = x_ts[q].as_mut_slice();
                    axpy(&mut x[j * vr..(j + 1) * vr], w, kor_ts[q].row(row));
                }
            }
        }
        return;
    }
    for &q in act {
        x_ts[q].fill(0.0);
    }
    let x_atomics: Vec<AtomicF64Slice> =
        x_ts.iter_mut().map(|x| AtomicF64Slice::new(x.as_mut_slice())).collect();
    pool.run(|tid, _nt| {
        let part = parts[tid];
        for_each_nnz_in(part, row_ptr, |e, row| {
            let j = col_idx[e] as usize;
            let cv = values[e];
            for &q in act {
                let u_row = u_ts[q].row(j);
                let w = cv / dot(kts[q].row(row), u_row);
                let k_row = kor_ts[q].row(row);
                let base = j * k_row.len();
                let xa = &x_atomics[q];
                for (k, &kv) in k_row.iter().enumerate() {
                    xa.fetch_add(base + k, w * kv);
                }
            }
        });
    });
}

/// Cross-query batched fused iterate over the **transposed pattern** —
/// atomic-free: the pattern (and its column partition) is shared by the
/// whole batch, so a thread that owns column `j` owns row `j` of *every*
/// query's `xᵀ`. Batch semantics match [`fused_type1_batch`].
#[allow(clippy::too_many_arguments)]
pub fn fused_type1_transposed_batch(
    c: &Csr,
    tp: &super::spmm::TransposedPattern,
    kts: &[&Dense],
    kor_ts: &[&Dense],
    u_ts: &[Dense],
    x_ts: &mut [Dense],
    active: &[bool],
    pool: &Pool,
    col_parts: &[NnzRange],
    scratch: &mut FusedScratch,
) {
    let b = kts.len();
    debug_assert_eq!(kor_ts.len(), b);
    debug_assert_eq!(u_ts.len(), b);
    debug_assert_eq!(x_ts.len(), b);
    debug_assert_eq!(active.len(), b);
    scratch.act.clear();
    scratch.act.extend((0..b).filter(|&q| active[q]));
    let act: &[usize] = &scratch.act;
    if act.is_empty() {
        return;
    }
    for &q in act {
        debug_assert_eq!(x_ts[q].nrows() + 1, tp.col_ptr.len());
        debug_assert_eq!(x_ts[q].ncols(), kts[q].ncols());
        x_ts[q].fill(0.0);
    }
    let values = c.values();
    let x_views: Vec<SharedSlice<Real>> =
        x_ts.iter_mut().map(|x| SharedSlice::new(x.as_mut_slice())).collect();
    pool.run(|tid, _nt| {
        let part = col_parts[tid];
        for_each_nnz_in(part, &tp.col_ptr, |e, j| {
            let i = tp.src_row[e] as usize;
            let cv = values[tp.src_pos[e] as usize];
            for &q in act {
                let u_row = u_ts[q].row(j);
                let w = cv / dot(kts[q].row(i), u_row);
                let vr = kts[q].ncols();
                // SAFETY: column j (row j of every query's x) is owned by
                // this thread — the column partition never splits a column.
                let x_row = unsafe { x_views[q].slice_mut(j * vr, vr) };
                axpy(x_row, w, kor_ts[q].row(i));
            }
        });
    });
}

/// Cross-query batched fused epilogue (type 2): the final WMD vector of
/// every query in one CSR pass. Per-thread partials are `B·N` scalars
/// (`acc[q·N + j]`), reduced after the region in the same thread order as
/// the single-query [`fused_type2`], so given identical `u` the batched
/// reduction is bitwise identical to `B` single-query reductions.
#[allow(clippy::too_many_arguments)]
pub fn fused_type2_batch(
    c: &Csr,
    kts: &[&Dense],
    km_ts: &[&Dense],
    u_ts: &[Dense],
    wmds: &mut [Vec<Real>],
    pool: &Pool,
    parts: &[NnzRange],
    scratch: &mut FusedScratch,
) {
    let b = kts.len();
    debug_assert_eq!(km_ts.len(), b);
    debug_assert_eq!(u_ts.len(), b);
    assert_eq!(wmds.len(), b);
    let n = c.ncols();
    for wmd in wmds.iter() {
        assert_eq!(wmd.len(), n);
    }
    if b == 0 {
        return;
    }
    let nthreads = pool.nthreads();
    let partials = &mut scratch.partials;
    partials.clear();
    partials.resize(nthreads * b * n, 0.0);
    let (row_ptr, col_idx, values) = (c.row_ptr(), c.col_idx(), c.values());
    {
        let pview = SharedSlice::new(partials.as_mut_slice());
        pool.run(|tid, _nt| {
            let part = parts[tid];
            // SAFETY: each thread owns partial slice tid.
            let acc = unsafe { pview.slice_mut(tid * b * n, b * n) };
            for_each_nnz_in(part, row_ptr, |e, row| {
                let j = col_idx[e] as usize;
                let cv = values[e];
                for q in 0..b {
                    let u_row = u_ts[q].row(j);
                    let w = cv / dot(kts[q].row(row), u_row);
                    acc[q * n + j] += w * dot(km_ts[q].row(row), u_row);
                }
            });
        });
    }
    for (q, wmd) in wmds.iter_mut().enumerate() {
        wmd.fill(0.0);
        for t in 0..nthreads {
            let acc = &partials[t * b * n + q * n..t * b * n + (q + 1) * n];
            for (o, &v) in wmd.iter_mut().zip(acc) {
                *o += v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::balanced_nnz_partition;
    use crate::sparse::ops::{sddmm_serial, spmm_serial};
    use crate::sparse::Coo;
    use crate::util::Pcg64;

    fn case(rng: &mut Pcg64, v: usize, n: usize, vr: usize, nnz: usize) -> (Csr, Dense, Dense, Dense, Dense) {
        let mut coo = Coo::new(v, n);
        for _ in 0..nnz {
            coo.push(rng.below(v), rng.below(n), rng.next_f64() + 0.1);
        }
        let c = Csr::from_coo(coo);
        let kt = Dense::from_fn(v, vr, |_, _| rng.next_f64() + 0.2);
        let kor_t = Dense::from_fn(v, vr, |_, _| rng.next_f64() + 0.2);
        let km_t = Dense::from_fn(v, vr, |_, _| rng.next_f64());
        let u_t = Dense::from_fn(n, vr, |_, _| rng.next_f64() + 0.2);
        (c, kt, kor_t, km_t, u_t)
    }

    #[test]
    fn type1_equals_unfused() {
        let mut rng = Pcg64::new(71);
        for p in [1usize, 4, 8] {
            let (c, kt, kor_t, _km, u_t) = case(&mut rng, 35, 14, 6, 120);
            // Unfused serial reference: SDDMM then SpMM.
            let mut w = vec![0.0; c.nnz()];
            sddmm_serial(&c, &kt, &u_t, &mut w);
            let mut x_ref = Dense::zeros(14, 6);
            spmm_serial(&c, &w, &kor_t, &mut x_ref);
            // Fused parallel.
            let pool = Pool::new(p);
            let parts = balanced_nnz_partition(c.row_ptr(), p);
            let mut x_t = Dense::zeros(14, 6);
            fused_type1(&c, &kt, &kor_t, &u_t, &mut x_t, &pool, &parts);
            assert!(x_t.max_abs_diff(&x_ref) < 1e-11, "p={p}");
        }
    }

    #[test]
    fn type1_private_equals_atomic() {
        let mut rng = Pcg64::new(72);
        for p in [1usize, 3, 6] {
            let (c, kt, kor_t, _km, u_t) = case(&mut rng, 50, 21, 9, 300);
            let pool = Pool::new(p);
            let parts = balanced_nnz_partition(c.row_ptr(), p);
            let mut x_a = Dense::zeros(21, 9);
            fused_type1(&c, &kt, &kor_t, &u_t, &mut x_a, &pool, &parts);
            let mut x_p = Dense::zeros(21, 9);
            let mut scratch = PrivateBuffers::new(p, 21, 9);
            fused_type1_private(&c, &kt, &kor_t, &u_t, &mut x_p, &pool, &parts, &mut scratch);
            assert!(x_a.max_abs_diff(&x_p) < 1e-11, "p={p}");
        }
    }

    #[test]
    fn type1_transposed_equals_atomic() {
        let mut rng = Pcg64::new(74);
        for p in [1usize, 4, 7] {
            let (c, kt, kor_t, _km, u_t) = case(&mut rng, 60, 25, 7, 400);
            let pool = Pool::new(p);
            let parts = balanced_nnz_partition(c.row_ptr(), p);
            let mut x_a = Dense::zeros(25, 7);
            fused_type1(&c, &kt, &kor_t, &u_t, &mut x_a, &pool, &parts);
            let tp = crate::sparse::ops::TransposedPattern::build(&c);
            let col_parts = tp.column_parts(p);
            let mut x_t = Dense::zeros(25, 7);
            fused_type1_transposed(&c, &tp, &kt, &kor_t, &u_t, &mut x_t, &pool, &col_parts);
            assert!(x_a.max_abs_diff(&x_t) < 1e-11, "p={p}");
        }
    }

    #[test]
    fn type2_equals_dense_formula() {
        let mut rng = Pcg64::new(73);
        for p in [1usize, 4] {
            let (c, kt, _kor, km_t, u_t) = case(&mut rng, 20, 9, 5, 60);
            // Dense oracle: v = c / (KT@u) at pattern; WMD = (u * (KM@v)).sum(0).
            let u = u_t.transpose(); // v_r × N... careful: u in Algorithm 1 is v_r×N
            let ktu = kt.matmul(&u_t.transpose()); // V×N
            let mut vdense = Dense::zeros(c.nrows(), c.ncols());
            for (i, j, cv) in c.iter() {
                vdense.set(i, j, cv / ktu.get(i, j));
            }
            let km = km_t.transpose(); // v_r × V
            let kmv = km.matmul(&vdense); // v_r × N
            let mut oracle = vec![0.0; c.ncols()];
            for jj in 0..c.ncols() {
                for ii in 0..u.nrows() {
                    oracle[jj] += u.get(ii, jj) * kmv.get(ii, jj);
                }
            }
            let pool = Pool::new(p);
            let parts = balanced_nnz_partition(c.row_ptr(), p);
            let mut wmd = vec![0.0; c.ncols()];
            fused_type2(&c, &kt, &km_t, &u_t, &mut wmd, &pool, &parts, &mut FusedScratch::new());
            for (a, b) in wmd.iter().zip(&oracle) {
                assert!((a - b).abs() < 1e-11 * (1.0 + b.abs()), "p={p}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn reused_dirty_scratch_matches_fresh_scratch() {
        // One FusedScratch across differently-shaped type-2 calls: the
        // clear+resize at checkout must erase every stale partial.
        let mut rng = Pcg64::new(75);
        let mut scratch = FusedScratch::new();
        for (v, n, vr, nnz) in [(30usize, 12usize, 5usize, 150usize), (18, 7, 3, 40), (40, 20, 8, 280)] {
            let (c, kt, _kor, km_t, u_t) = case(&mut rng, v, n, vr, nnz);
            let pool = Pool::new(3);
            let parts = balanced_nnz_partition(c.row_ptr(), 3);
            let mut fresh = vec![0.0; n];
            fused_type2(&c, &kt, &km_t, &u_t, &mut fresh, &pool, &parts, &mut FusedScratch::new());
            let mut reused = vec![0.0; n];
            fused_type2(&c, &kt, &km_t, &u_t, &mut reused, &pool, &parts, &mut scratch);
            assert_eq!(fresh, reused, "dirty scratch perturbed the type-2 reduction");
        }
        assert!(scratch.retained_bytes() > 0);
    }

    /// A batch of queries over one shared pattern, with per-query v_r.
    fn batch_case(
        rng: &mut Pcg64,
        v: usize,
        n: usize,
        nnz: usize,
        vrs: &[usize],
    ) -> (Csr, Vec<Dense>, Vec<Dense>, Vec<Dense>, Vec<Dense>) {
        let mut coo = Coo::new(v, n);
        for _ in 0..nnz {
            coo.push(rng.below(v), rng.below(n), rng.next_f64() + 0.1);
        }
        let c = Csr::from_coo(coo);
        let kts: Vec<Dense> =
            vrs.iter().map(|&vr| Dense::from_fn(v, vr, |_, _| rng.next_f64() + 0.2)).collect();
        let kor_ts: Vec<Dense> =
            vrs.iter().map(|&vr| Dense::from_fn(v, vr, |_, _| rng.next_f64() + 0.2)).collect();
        let km_ts: Vec<Dense> =
            vrs.iter().map(|&vr| Dense::from_fn(v, vr, |_, _| rng.next_f64())).collect();
        let u_ts: Vec<Dense> =
            vrs.iter().map(|&vr| Dense::from_fn(n, vr, |_, _| rng.next_f64() + 0.2)).collect();
        (c, kts, kor_ts, km_ts, u_ts)
    }

    fn refs(ms: &[Dense]) -> Vec<&Dense> {
        ms.iter().collect()
    }

    #[test]
    fn type1_batch_equals_per_query() {
        let mut rng = Pcg64::new(81);
        let vrs = [3usize, 7, 5, 9];
        let (c, kts, kor_ts, _km, u_ts) = batch_case(&mut rng, 45, 18, 250, &vrs);
        for p in [1usize, 4, 7] {
            let pool = Pool::new(p);
            let parts = balanced_nnz_partition(c.row_ptr(), p);
            // Per-query reference.
            let mut expected = Vec::new();
            for q in 0..vrs.len() {
                let mut x = Dense::zeros(18, vrs[q]);
                fused_type1(&c, &kts[q], &kor_ts[q], &u_ts[q], &mut x, &pool, &parts);
                expected.push(x);
            }
            // Batched, all active.
            let mut x_ts: Vec<Dense> = vrs.iter().map(|&vr| Dense::zeros(18, vr)).collect();
            fused_type1_batch(
                &c, &refs(&kts), &refs(&kor_ts), &u_ts, &mut x_ts,
                &[true; 4], &pool, &parts, &mut FusedScratch::new(),
            );
            for q in 0..vrs.len() {
                assert!(x_ts[q].max_abs_diff(&expected[q]) < 1e-11, "p={p} q={q}");
            }
        }
    }

    #[test]
    fn type1_batch_skips_inactive_queries() {
        let mut rng = Pcg64::new(82);
        let vrs = [4usize, 6, 5];
        let (c, kts, kor_ts, _km, u_ts) = batch_case(&mut rng, 30, 12, 150, &vrs);
        let pool = Pool::new(3);
        let parts = balanced_nnz_partition(c.row_ptr(), 3);
        // Sentinel-fill: an inactive (converged) query's x must be untouched.
        let mut x_ts: Vec<Dense> = vrs.iter().map(|&vr| Dense::filled(12, vr, 7.0)).collect();
        fused_type1_batch(
            &c, &refs(&kts), &refs(&kor_ts), &u_ts, &mut x_ts,
            &[true, false, true], &pool, &parts, &mut FusedScratch::new(),
        );
        assert!(x_ts[1].as_slice().iter().all(|&v| v == 7.0), "inactive query was written");
        let mut expected = Dense::zeros(12, vrs[0]);
        fused_type1(&c, &kts[0], &kor_ts[0], &u_ts[0], &mut expected, &pool, &parts);
        assert!(x_ts[0].max_abs_diff(&expected) < 1e-11);
    }

    #[test]
    fn type1_transposed_batch_equals_per_query() {
        let mut rng = Pcg64::new(83);
        let vrs = [5usize, 8, 4];
        let (c, kts, kor_ts, _km, u_ts) = batch_case(&mut rng, 55, 21, 320, &vrs);
        let tp = crate::sparse::ops::TransposedPattern::build(&c);
        for p in [1usize, 4, 6] {
            let pool = Pool::new(p);
            let col_parts = tp.column_parts(p);
            let mut expected = Vec::new();
            for q in 0..vrs.len() {
                let mut x = Dense::zeros(21, vrs[q]);
                fused_type1_transposed(
                    &c, &tp, &kts[q], &kor_ts[q], &u_ts[q], &mut x, &pool, &col_parts,
                );
                expected.push(x);
            }
            let mut x_ts: Vec<Dense> = vrs.iter().map(|&vr| Dense::zeros(21, vr)).collect();
            fused_type1_transposed_batch(
                &c, &tp, &refs(&kts), &refs(&kor_ts), &u_ts, &mut x_ts,
                &[true; 3], &pool, &col_parts, &mut FusedScratch::new(),
            );
            for q in 0..vrs.len() {
                // Same per-column accumulation order → bitwise equal.
                assert_eq!(x_ts[q], expected[q], "p={p} q={q}");
            }
        }
    }

    #[test]
    fn type2_batch_equals_per_query() {
        let mut rng = Pcg64::new(84);
        let vrs = [6usize, 3, 8, 5];
        let (c, kts, _kor, km_ts, u_ts) = batch_case(&mut rng, 40, 15, 200, &vrs);
        for p in [1usize, 4] {
            let pool = Pool::new(p);
            let parts = balanced_nnz_partition(c.row_ptr(), p);
            let mut wmds: Vec<Vec<Real>> = (0..vrs.len()).map(|_| vec![0.0; 15]).collect();
            fused_type2_batch(
                &c, &refs(&kts), &refs(&km_ts), &u_ts, &mut wmds, &pool, &parts,
                &mut FusedScratch::new(),
            );
            for q in 0..vrs.len() {
                let mut expected = vec![0.0; 15];
                fused_type2(
                    &c, &kts[q], &km_ts[q], &u_ts[q], &mut expected, &pool, &parts,
                    &mut FusedScratch::new(),
                );
                // Same traversal and reduction order → bitwise equal.
                assert_eq!(wmds[q], expected, "p={p} q={q}");
            }
        }
    }
}
