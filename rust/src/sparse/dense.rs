//! Row-major dense matrix used for the solver state (`x`, `u` transposed),
//! precomputed factors (`Kᵀ`, `K_over_rᵀ`, `(K⊙M)ᵀ`) and the dense
//! baseline pipeline — plus [`Panel32`], the f32 shadow panel the
//! mixed-precision kernel path reads.

use crate::Real;

/// Row-major dense matrix of `Real` (f64). (`Default` is the empty
/// `0 × 0` matrix — the state of a workspace plane before its first
/// [`Dense::reset`].)
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Dense {
    nrows: usize,
    ncols: usize,
    data: Vec<Real>,
}

impl Dense {
    pub fn zeros(nrows: usize, ncols: usize) -> Self {
        Self { nrows, ncols, data: vec![0.0; nrows * ncols] }
    }

    /// Reshape in place to `nrows × ncols` with every element set to
    /// `value`. Grow-only: the backing allocation is kept when the new
    /// shape fits its capacity, so a reused workspace plane stops touching
    /// the allocator once it has seen its largest shape.
    pub fn reset(&mut self, nrows: usize, ncols: usize, value: Real) {
        self.nrows = nrows;
        self.ncols = ncols;
        self.data.clear();
        self.data.resize(nrows * ncols, value);
    }

    /// Elements the backing allocation can hold without reallocating —
    /// what a workspace retains across solves.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.data.capacity()
    }

    pub fn filled(nrows: usize, ncols: usize, value: Real) -> Self {
        Self { nrows, ncols, data: vec![value; nrows * ncols] }
    }

    pub fn from_vec(nrows: usize, ncols: usize, data: Vec<Real>) -> Self {
        assert_eq!(data.len(), nrows * ncols, "shape/data mismatch");
        Self { nrows, ncols, data }
    }

    /// Build from a row-generator closure.
    pub fn from_fn(nrows: usize, ncols: usize, mut f: impl FnMut(usize, usize) -> Real) -> Self {
        let mut data = Vec::with_capacity(nrows * ncols);
        for i in 0..nrows {
            for j in 0..ncols {
                data.push(f(i, j));
            }
        }
        Self { nrows, ncols, data }
    }

    #[inline(always)]
    pub fn nrows(&self) -> usize {
        self.nrows
    }

    #[inline(always)]
    pub fn ncols(&self) -> usize {
        self.ncols
    }

    #[inline(always)]
    pub fn get(&self, i: usize, j: usize) -> Real {
        debug_assert!(i < self.nrows && j < self.ncols);
        self.data[i * self.ncols + j]
    }

    #[inline(always)]
    pub fn set(&mut self, i: usize, j: usize, v: Real) {
        debug_assert!(i < self.nrows && j < self.ncols);
        self.data[i * self.ncols + j] = v;
    }

    #[inline(always)]
    pub fn row(&self, i: usize) -> &[Real] {
        debug_assert!(i < self.nrows);
        &self.data[i * self.ncols..(i + 1) * self.ncols]
    }

    #[inline(always)]
    pub fn row_mut(&mut self, i: usize) -> &mut [Real] {
        debug_assert!(i < self.nrows);
        &mut self.data[i * self.ncols..(i + 1) * self.ncols]
    }

    #[inline(always)]
    pub fn as_slice(&self) -> &[Real] {
        &self.data
    }

    #[inline(always)]
    pub fn as_mut_slice(&mut self) -> &mut [Real] {
        &mut self.data
    }

    pub fn fill(&mut self, v: Real) {
        self.data.fill(v);
    }

    /// Out-of-place transpose.
    pub fn transpose(&self) -> Dense {
        let mut out = Dense::zeros(self.ncols, self.nrows);
        for i in 0..self.nrows {
            for j in 0..self.ncols {
                out.data[j * self.nrows + i] = self.data[i * self.ncols + j];
            }
        }
        out
    }

    /// Dense matmul `self @ rhs` — reference implementation (ikj loop
    /// order); the performance-relevant GEMM-form kernel lives in
    /// [`crate::dist::cdist_gemm`] and the dense baseline uses the
    /// parallel version in `sinkhorn::dense`.
    pub fn matmul(&self, rhs: &Dense) -> Dense {
        assert_eq!(self.ncols, rhs.nrows, "matmul shape mismatch");
        let mut out = Dense::zeros(self.nrows, rhs.ncols);
        for i in 0..self.nrows {
            for k in 0..self.ncols {
                let a = self.data[i * self.ncols + k];
                if a == 0.0 {
                    continue;
                }
                let rrow = &rhs.data[k * rhs.ncols..(k + 1) * rhs.ncols];
                let orow = &mut out.data[i * rhs.ncols..(i + 1) * rhs.ncols];
                for j in 0..rhs.ncols {
                    orow[j] += a * rrow[j];
                }
            }
        }
        out
    }

    /// Element-wise map in place.
    pub fn map_inplace(&mut self, f: impl Fn(Real) -> Real) {
        for v in &mut self.data {
            *v = f(*v);
        }
    }

    /// Max |a - b| over all elements. NaN anywhere poisons the result
    /// (`nan_max`) so equivalence gates cannot pass on NaN garbage.
    pub fn max_abs_diff(&self, other: &Dense) -> Real {
        assert_eq!((self.nrows, self.ncols), (other.nrows, other.ncols));
        crate::util::nan_max(self.data.iter().zip(&other.data).map(|(a, b)| (a - b).abs()))
    }
}

/// Row-major `f32` panel: the reduced-precision shadow of a [`Dense`]
/// factor (or iterate) plane that the mixed-precision fused kernel reads.
/// Same grow-only reuse contract as [`Dense::reset`], so workspace-resident
/// panels stop touching the allocator once warm. Conversion from the f64
/// master copy is one parallel pass ([`Panel32::reset_from`]); the f64
/// plane stays the source of truth.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Panel32 {
    nrows: usize,
    ncols: usize,
    data: Vec<f32>,
}

impl Panel32 {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reshape in place to `nrows × ncols` with every element set to
    /// `value` (grow-only, like [`Dense::reset`]).
    pub fn reset(&mut self, nrows: usize, ncols: usize, value: f32) {
        self.nrows = nrows;
        self.ncols = ncols;
        self.data.clear();
        self.data.resize(nrows * ncols, value);
    }

    /// Reshape to `src`'s shape and fill with the f32-rounded copy of its
    /// elements — the per-solve panel conversion of the mixed-precision
    /// path. Parallelized over element chunks; the pass is a tiny fraction
    /// of a solve (one read + narrow-store per element, once per checkout,
    /// vs. `max_iter` kernel passes over the same bytes).
    pub fn reset_from(&mut self, src: &Dense, pool: &crate::parallel::Pool) {
        self.nrows = src.nrows();
        self.ncols = src.ncols();
        let len = self.nrows * self.ncols;
        self.data.clear();
        self.data.resize(len, 0.0);
        let s = src.as_slice();
        if pool.nthreads() == 1 || len < (1 << 14) {
            for (d, &v) in self.data.iter_mut().zip(s) {
                *d = v as f32;
            }
            return;
        }
        let view = crate::util::SharedSlice::new(self.data.as_mut_slice());
        pool.run(|tid, nt| {
            let r = crate::parallel::static_chunk(len, tid, nt);
            // SAFETY: element chunks are disjoint per thread.
            let out = unsafe { view.slice_mut(r.start, r.len()) };
            for (d, &v) in out.iter_mut().zip(&s[r.clone()]) {
                *d = v as f32;
            }
        });
    }

    /// Elements the backing allocation can hold without reallocating.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.data.capacity()
    }

    #[inline(always)]
    pub fn nrows(&self) -> usize {
        self.nrows
    }

    #[inline(always)]
    pub fn ncols(&self) -> usize {
        self.ncols
    }

    #[inline(always)]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.nrows);
        &self.data[i * self.ncols..(i + 1) * self.ncols]
    }

    #[inline(always)]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    #[inline(always)]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }
}

/// Unit-stride dot product with 4-way unrolling — the innermost loop of
/// every SDDMM in the solver (the paper's "basic unrolling ...
/// vectorizations" bullet). Written so LLVM autovectorizes to AVX.
#[inline]
pub fn dot(a: &[Real], b: &[Real]) -> Real {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    // SAFETY: pointer-arithmetic hot loop (bounds checks hoisted). Every
    // offset is `< a.len()` == `b.len()` (asserted above): `c * 4 + 3 <
    // chunks * 4 <= a.len()` in the unrolled body, `i < a.len()` in the
    // tail.
    unsafe {
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        for c in 0..chunks {
            let i = c * 4;
            acc[0] += *pa.add(i) * *pb.add(i);
            acc[1] += *pa.add(i + 1) * *pb.add(i + 1);
            acc[2] += *pa.add(i + 2) * *pb.add(i + 2);
            acc[3] += *pa.add(i + 3) * *pb.add(i + 3);
        }
        let mut tail = 0.0;
        for i in chunks * 4..a.len() {
            tail += *pa.add(i) * *pb.add(i);
        }
        (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
    }
}

/// `out[k] += s * b[k]` — the axpy used in the SpMM accumulation.
#[inline]
pub fn axpy(out: &mut [Real], s: Real, b: &[Real]) {
    debug_assert_eq!(out.len(), b.len());
    for (o, &x) in out.iter_mut().zip(b) {
        *o += s * x;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_set_roundtrip() {
        let mut m = Dense::zeros(3, 4);
        m.set(1, 2, 5.5);
        assert_eq!(m.get(1, 2), 5.5);
        assert_eq!(m.get(0, 0), 0.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 5.5, 0.0]);
    }

    #[test]
    fn transpose_involution() {
        let m = Dense::from_fn(3, 5, |i, j| (i * 10 + j) as f64);
        let t = m.transpose();
        assert_eq!(t.nrows(), 5);
        assert_eq!(t.get(2, 1), m.get(1, 2));
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn matmul_identity() {
        let m = Dense::from_fn(4, 4, |i, j| (i + 2 * j) as f64);
        let id = Dense::from_fn(4, 4, |i, j| if i == j { 1.0 } else { 0.0 });
        assert_eq!(m.matmul(&id), m);
        assert_eq!(id.matmul(&m), m);
    }

    #[test]
    fn matmul_known_values() {
        let a = Dense::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Dense::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.as_slice(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn dot_matches_naive() {
        for n in [0usize, 1, 3, 4, 7, 64, 301] {
            let a: Vec<f64> = (0..n).map(|i| (i as f64) * 0.5 - 3.0).collect();
            let b: Vec<f64> = (0..n).map(|i| 1.0 / (i as f64 + 1.0)).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-9 * (1.0 + naive.abs()), "n={n}");
        }
    }

    #[test]
    fn reset_reshapes_without_reallocating_within_capacity() {
        let mut m = Dense::zeros(10, 8);
        m.set(3, 3, 7.0);
        let cap = m.capacity();
        m.reset(4, 5, 1.5);
        assert_eq!((m.nrows(), m.ncols()), (4, 5));
        assert!(m.as_slice().iter().all(|&v| v == 1.5), "dirty data must not leak");
        assert_eq!(m.capacity(), cap, "shrinking reset keeps the allocation");
        m.reset(10, 8, 0.0);
        assert_eq!(m.capacity(), cap, "regrowing within capacity keeps the allocation");
        assert!(m.as_slice().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn axpy_accumulates() {
        let mut out = vec![1.0, 2.0, 3.0];
        axpy(&mut out, 2.0, &[10.0, 20.0, 30.0]);
        assert_eq!(out, vec![21.0, 42.0, 63.0]);
    }

    #[test]
    fn panel32_reset_from_converts_and_reuses_allocation() {
        use crate::parallel::Pool;
        let src = Dense::from_fn(20, 7, |i, j| (i as f64 + 1.0) / (j as f64 + 3.0));
        let mut p = Panel32::new();
        for threads in [1usize, 4] {
            let pool = Pool::new(threads);
            p.reset_from(&src, &pool);
            assert_eq!((p.nrows(), p.ncols()), (20, 7));
            for i in 0..20 {
                for (j, &v) in p.row(i).iter().enumerate() {
                    assert_eq!(v, src.get(i, j) as f32, "({i},{j})");
                }
            }
        }
        // Shrink then regrow within capacity: no reallocation.
        let cap = p.capacity();
        let small = Dense::from_fn(3, 2, |i, j| (i * 2 + j) as f64);
        p.reset_from(&small, &Pool::new(1));
        assert_eq!(p.capacity(), cap);
        p.reset_from(&src, &Pool::new(2));
        assert_eq!(p.capacity(), cap, "regrow within capacity must not allocate");
    }

    #[test]
    fn panel32_parallel_conversion_matches_serial_above_chunk_threshold() {
        use crate::parallel::Pool;
        // Large enough to take the parallel path (len ≥ 2^14).
        let src = Dense::from_fn(300, 64, |i, j| (i as f64) * 0.37 - (j as f64) * 1.21);
        let mut serial = Panel32::new();
        serial.reset_from(&src, &Pool::new(1));
        let mut parallel = Panel32::new();
        parallel.reset_from(&src, &Pool::new(5));
        assert_eq!(serial, parallel);
    }
}
