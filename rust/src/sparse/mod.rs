//! Sparse-matrix substrate: COO/CSR/CSC storage, conversions, and the
//! paper's sparse kernels (the fused `SDDTMM→DSTMMT` family plus the
//! unfused SDDMM/SpMM baseline pair).
//!
//! The Sinkhorn target-histogram matrix `c` is `V × N` with density
//! ~0.0035 % at paper scale; every iterate touches it once, so the CSR
//! layout plus nnz-balanced partitioning dominates the solver's runtime
//! profile (paper Table 1: 98 % of time in the sparse-masked products).

pub mod coo;
pub mod csc;
pub mod csr;
pub mod dense;
pub mod ops;

pub use coo::Coo;
pub use csc::Csc;
pub use csr::Csr;
pub use dense::{axpy, dot, Dense, Panel32};
