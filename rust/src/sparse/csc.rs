//! CSC (compressed sparse column) matrix. The Python baseline calls
//! `v.tocsc()` every iteration (Table 1); we keep the format (and the
//! conversion) so the dense-baseline port is faithful, while the sparse
//! fused kernel never needs it.

use super::{Csr, Dense};
use crate::Real;

/// CSC sparse matrix: `col_ptr` (len `ncols+1`), `row_idx`/`values`
/// (len nnz), rows ascending within each column.
#[derive(Clone, Debug, PartialEq)]
pub struct Csc {
    nrows: usize,
    ncols: usize,
    col_ptr: Vec<usize>,
    row_idx: Vec<u32>,
    values: Vec<Real>,
}

impl Csc {
    /// Internal: reinterpret a CSR-of-the-transpose as CSC of the original.
    pub(crate) fn from_transposed_csr(t: Csr) -> Self {
        Self {
            nrows: t.ncols(),
            ncols: t.nrows(),
            col_ptr: t.row_ptr().to_vec(),
            row_idx: t.col_idx().to_vec(),
            values: t.values().to_vec(),
        }
    }

    pub fn from_csr(m: &Csr) -> Self {
        m.to_csc()
    }

    #[inline(always)]
    pub fn nrows(&self) -> usize {
        self.nrows
    }

    #[inline(always)]
    pub fn ncols(&self) -> usize {
        self.ncols
    }

    #[inline(always)]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    #[inline(always)]
    pub fn col_ptr(&self) -> &[usize] {
        &self.col_ptr
    }

    #[inline(always)]
    pub fn row_idx(&self) -> &[u32] {
        &self.row_idx
    }

    #[inline(always)]
    pub fn values(&self) -> &[Real] {
        &self.values
    }

    /// `(row_idx, values)` of column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> (&[u32], &[Real]) {
        let span = self.col_ptr[j]..self.col_ptr[j + 1];
        (&self.row_idx[span.clone()], &self.values[span])
    }

    pub fn to_dense(&self) -> Dense {
        let mut d = Dense::zeros(self.nrows, self.ncols);
        for j in 0..self.ncols {
            let (rows, vals) = self.col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                d.set(i as usize, j, v);
            }
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;
    use crate::util::Pcg64;

    #[test]
    fn csc_matches_csr() {
        let mut rng = Pcg64::new(31);
        for _ in 0..20 {
            let (nr, nc) = (rng.range(1, 20), rng.range(1, 20));
            let mut coo = Coo::new(nr, nc);
            for _ in 0..rng.below(50) {
                coo.push(rng.below(nr), rng.below(nc), rng.next_f64());
            }
            let csr = Csr::from_coo(coo);
            let csc = Csc::from_csr(&csr);
            assert_eq!(csc.nnz(), csr.nnz());
            assert_eq!(csc.to_dense(), csr.to_dense());
        }
    }

    #[test]
    fn column_access() {
        let mut coo = Coo::new(4, 3);
        coo.push(0, 1, 1.0);
        coo.push(2, 1, 2.0);
        coo.push(3, 0, 3.0);
        let csc = Csc::from_csr(&Csr::from_coo(coo));
        let (rows, vals) = csc.col(1);
        assert_eq!(rows, &[0, 2]);
        assert_eq!(vals, &[1.0, 2.0]);
        let (rows0, vals0) = csc.col(0);
        assert_eq!(rows0, &[3]);
        assert_eq!(vals0, &[3.0]);
        assert!(csc.col(2).0.is_empty());
    }
}
