//! CSR (compressed sparse row) matrix — the working format for `c`
//! (vocab × docs target histograms) in the sparse Sinkhorn solver.

use super::{Coo, Csc, Dense};
use crate::Real;

/// CSR sparse matrix: `row_ptr` (len `nrows+1`), `col_idx`/`values`
/// (len nnz), columns ascending within each row.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    nrows: usize,
    ncols: usize,
    row_ptr: Vec<usize>,
    col_idx: Vec<u32>,
    values: Vec<Real>,
}

impl Csr {
    /// Build from COO (the triplets are compacted first).
    pub fn from_coo(mut coo: Coo) -> Self {
        coo.compact();
        let mut row_ptr = vec![0usize; coo.nrows + 1];
        for &r in &coo.rows {
            row_ptr[r as usize + 1] += 1;
        }
        for i in 0..coo.nrows {
            row_ptr[i + 1] += row_ptr[i];
        }
        Self {
            nrows: coo.nrows,
            ncols: coo.ncols,
            row_ptr,
            col_idx: coo.cols,
            values: coo.values,
        }
    }

    /// Build directly from parts (validated; panics on invalid input).
    pub fn from_parts(
        nrows: usize,
        ncols: usize,
        row_ptr: Vec<usize>,
        col_idx: Vec<u32>,
        values: Vec<Real>,
    ) -> Self {
        Self::try_from_parts(nrows, ncols, row_ptr, col_idx, values).expect("invalid CSR parts")
    }

    /// Fallible [`Csr::from_parts`] for untrusted input (snapshot loading):
    /// a malformed structure comes back as `Err`, never a panic.
    pub fn try_from_parts(
        nrows: usize,
        ncols: usize,
        row_ptr: Vec<usize>,
        col_idx: Vec<u32>,
        values: Vec<Real>,
    ) -> Result<Self, String> {
        let m = Self { nrows, ncols, row_ptr, col_idx, values };
        m.validate()?;
        Ok(m)
    }

    /// Decompose back into `(nrows, ncols, row_ptr, col_idx, values)` —
    /// the inverse of [`Csr::from_parts`]. Lets callers that build many
    /// short-lived sub-matrices (the pruned retrieval's per-candidate
    /// sub-problems) reclaim the backing allocations for reuse.
    pub fn into_parts(self) -> (usize, usize, Vec<usize>, Vec<u32>, Vec<Real>) {
        (self.nrows, self.ncols, self.row_ptr, self.col_idx, self.values)
    }

    /// Build from a dense matrix, keeping entries with |v| > 0.
    pub fn from_dense(d: &Dense) -> Self {
        let mut coo = Coo::new(d.nrows(), d.ncols());
        for i in 0..d.nrows() {
            for j in 0..d.ncols() {
                let v = d.get(i, j);
                if v != 0.0 {
                    coo.push(i, j, v);
                }
            }
        }
        Self::from_coo(coo)
    }

    /// Structural + ordering invariants.
    pub fn validate(&self) -> Result<(), String> {
        // checked_sub, not `nrows + 1`: a crafted snapshot can claim
        // `nrows == usize::MAX` (the addition would overflow) together
        // with an empty row_ptr (the indexing below would panic).
        if self.row_ptr.len().checked_sub(1) != Some(self.nrows) {
            return Err("row_ptr length".into());
        }
        if self.row_ptr[0] != 0 || *self.row_ptr.last().unwrap() != self.values.len() {
            return Err("row_ptr endpoints".into());
        }
        if self.col_idx.len() != self.values.len() {
            return Err("col/val length mismatch".into());
        }
        for i in 0..self.nrows {
            // Bounds before monotonicity before slicing: a corrupted
            // row_ptr must produce an Err here, not an out-of-bounds
            // panic in the slice below.
            if self.row_ptr[i] > self.row_ptr[i + 1] || self.row_ptr[i + 1] > self.values.len() {
                return Err(format!("row_ptr not monotone at {i}"));
            }
            let cols = &self.col_idx[self.row_ptr[i]..self.row_ptr[i + 1]];
            for w in cols.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("columns not strictly ascending in row {i}"));
                }
            }
            if let Some(&c) = cols.last() {
                if c as usize >= self.ncols {
                    return Err(format!("column out of range in row {i}"));
                }
            }
        }
        Ok(())
    }

    #[inline(always)]
    pub fn nrows(&self) -> usize {
        self.nrows
    }

    #[inline(always)]
    pub fn ncols(&self) -> usize {
        self.ncols
    }

    #[inline(always)]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    #[inline(always)]
    pub fn row_ptr(&self) -> &[usize] {
        &self.row_ptr
    }

    #[inline(always)]
    pub fn col_idx(&self) -> &[u32] {
        &self.col_idx
    }

    #[inline(always)]
    pub fn values(&self) -> &[Real] {
        &self.values
    }

    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.nrows as f64 * self.ncols as f64)
    }

    /// `(col_idx, values)` of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[Real]) {
        let span = self.row_ptr[i]..self.row_ptr[i + 1];
        (&self.col_idx[span.clone()], &self.values[span])
    }

    /// Iterate `(row, col, value)` triplets in CSR order.
    pub fn iter(&self) -> impl Iterator<Item = (usize, usize, Real)> + '_ {
        (0..self.nrows).flat_map(move |i| {
            let (cols, vals) = self.row(i);
            cols.iter().zip(vals).map(move |(&c, &v)| (i, c as usize, v))
        })
    }

    pub fn get(&self, i: usize, j: usize) -> Real {
        let (cols, vals) = self.row(i);
        match cols.binary_search(&(j as u32)) {
            Ok(k) => vals[k],
            Err(_) => 0.0,
        }
    }

    pub fn to_dense(&self) -> Dense {
        let mut d = Dense::zeros(self.nrows, self.ncols);
        for (i, j, v) in self.iter() {
            d.set(i, j, v);
        }
        d
    }

    /// CSR of the transpose (counting sort over columns, O(nnz + ncols)).
    pub fn transpose(&self) -> Csr {
        let mut row_ptr = vec![0usize; self.ncols + 1];
        for &c in &self.col_idx {
            row_ptr[c as usize + 1] += 1;
        }
        for i in 0..self.ncols {
            row_ptr[i + 1] += row_ptr[i];
        }
        let mut cursor = row_ptr.clone();
        let mut col_idx = vec![0u32; self.nnz()];
        let mut values = vec![0.0; self.nnz()];
        for (i, j, v) in self.iter() {
            let dst = cursor[j];
            cursor[j] += 1;
            col_idx[dst] = i as u32;
            values[dst] = v;
        }
        Csr { nrows: self.ncols, ncols: self.nrows, row_ptr, col_idx, values }
    }

    /// Convert to CSC (same numbers, column-major compression).
    pub fn to_csc(&self) -> Csc {
        let t = self.transpose();
        Csc::from_transposed_csr(t)
    }

    /// Scale each column `j` by `s[j]` (used to column-normalize `c`).
    pub fn scale_columns(&mut self, s: &[Real]) {
        assert_eq!(s.len(), self.ncols);
        for (c, v) in self.col_idx.iter().zip(self.values.iter_mut()) {
            *v *= s[*c as usize];
        }
    }

    /// Per-column sums (length `ncols`).
    pub fn column_sums(&self) -> Vec<Real> {
        let mut sums = vec![0.0; self.ncols];
        for (c, v) in self.col_idx.iter().zip(&self.values) {
            sums[*c as usize] += *v;
        }
        sums
    }

    /// Keep only the columns in `keep` (old column `keep[t]` becomes new
    /// column `t`). Used by the pruned-retrieval pipeline to solve against
    /// a single candidate document.
    pub fn select_columns(&self, keep: &[usize]) -> Csr {
        let remap: std::collections::HashMap<u32, u32> = keep
            .iter()
            .enumerate()
            .map(|(new, &old)| (old as u32, new as u32))
            .collect();
        let mut row_ptr = Vec::with_capacity(self.nrows + 1);
        row_ptr.push(0usize);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        for i in 0..self.nrows {
            let (cols, vals) = self.row(i);
            let mut pairs: Vec<(u32, Real)> = cols
                .iter()
                .zip(vals)
                .filter_map(|(c, &v)| remap.get(c).map(|&nc| (nc, v)))
                .collect();
            pairs.sort_unstable_by_key(|&(c, _)| c);
            for (c, v) in pairs {
                col_idx.push(c);
                values.push(v);
            }
            row_ptr.push(col_idx.len());
        }
        Csr { nrows: self.nrows, ncols: keep.len(), row_ptr, col_idx, values }
    }

    /// Contiguous column slice `[range.start, range.end)`: keeps every
    /// row, holds exactly the entries whose column falls in the range,
    /// columns rebased to `0..range.len()`. This is the shard
    /// constructor — concatenating the slices of a partition of
    /// `0..ncols` (in order) reproduces the matrix column-for-column.
    /// Columns are ascending within each row, so each row contributes one
    /// contiguous sub-slice found by binary search: O(nnz_kept + nrows·log).
    pub fn slice_columns(&self, range: std::ops::Range<usize>) -> Csr {
        assert!(
            range.start <= range.end && range.end <= self.ncols,
            "column range {range:?} out of bounds for {} columns",
            self.ncols
        );
        let lo = range.start as u32;
        let hi = range.end as u32;
        let mut row_ptr = Vec::with_capacity(self.nrows + 1);
        row_ptr.push(0usize);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        for i in 0..self.nrows {
            let (cols, vals) = self.row(i);
            let a = cols.partition_point(|&c| c < lo);
            let b = cols.partition_point(|&c| c < hi);
            col_idx.extend(cols[a..b].iter().map(|&c| c - lo));
            values.extend_from_slice(&vals[a..b]);
            row_ptr.push(col_idx.len());
        }
        Csr { nrows: self.nrows, ncols: range.len(), row_ptr, col_idx, values }
    }

    /// Concatenate matrices column-wise (all must share `nrows`). The
    /// inverse of slicing a partition: `concat_columns(&parts)` where the
    /// parts are `slice_columns` of consecutive ranges reproduces the
    /// original matrix entry-for-entry. This is the compaction primitive
    /// for the live store — folding delta segments into the base CSR.
    pub fn concat_columns(parts: &[&Csr]) -> Csr {
        assert!(!parts.is_empty(), "concat_columns needs at least one part");
        let nrows = parts[0].nrows;
        let mut ncols = 0usize;
        let mut nnz = 0usize;
        for p in parts {
            assert_eq!(p.nrows, nrows, "concat_columns: row-count mismatch");
            ncols = ncols
                .checked_add(p.ncols)
                .expect("concat_columns: column count overflow");
            nnz += p.values.len();
        }
        assert!(ncols <= u32::MAX as usize + 1, "concat_columns: too many columns for u32 ids");
        let mut row_ptr = Vec::with_capacity(nrows + 1);
        row_ptr.push(0usize);
        let mut col_idx = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        for i in 0..nrows {
            let mut offset = 0u32;
            for p in parts {
                let (cols, vals) = p.row(i);
                col_idx.extend(cols.iter().map(|&c| c + offset));
                values.extend_from_slice(vals);
                offset += p.ncols as u32;
            }
            row_ptr.push(col_idx.len());
        }
        Csr { nrows, ncols, row_ptr, col_idx, values }
    }

    /// Copy with the given columns emptied (all entries dropped; the
    /// column itself remains, so ids are stable). A deleted document in
    /// the live store becomes an empty column, which the solver already
    /// maps to `WMD = +inf` — the same semantics as an empty ingest doc.
    pub fn with_columns_emptied(&self, drop: &[usize]) -> Csr {
        let mut dead = vec![false; self.ncols];
        for &j in drop {
            assert!(j < self.ncols, "column {j} out of range for {} columns", self.ncols);
            dead[j] = true;
        }
        let mut row_ptr = Vec::with_capacity(self.nrows + 1);
        row_ptr.push(0usize);
        let mut col_idx = Vec::with_capacity(self.col_idx.len());
        let mut values = Vec::with_capacity(self.values.len());
        for i in 0..self.nrows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                if !dead[c as usize] {
                    col_idx.push(c);
                    values.push(v);
                }
            }
            row_ptr.push(col_idx.len());
        }
        Csr { nrows: self.nrows, ncols: self.ncols, row_ptr, col_idx, values }
    }

    /// Keep only the rows in `keep` (by index, ascending); the result has
    /// `keep.len()` rows. Used to restrict `c` to a query's support.
    pub fn select_rows(&self, keep: &[usize]) -> Csr {
        let mut row_ptr = Vec::with_capacity(keep.len() + 1);
        row_ptr.push(0);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        for &r in keep {
            let (cols, vals) = self.row(r);
            col_idx.extend_from_slice(cols);
            values.extend_from_slice(vals);
            row_ptr.push(col_idx.len());
        }
        Csr { nrows: keep.len(), ncols: self.ncols, row_ptr, col_idx, values }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    pub(crate) fn random_csr(rng: &mut Pcg64, nrows: usize, ncols: usize, nnz: usize) -> Csr {
        let mut coo = Coo::new(nrows, ncols);
        for _ in 0..nnz {
            coo.push(rng.below(nrows), rng.below(ncols), rng.next_f64() + 0.01);
        }
        Csr::from_coo(coo)
    }

    #[test]
    fn from_coo_roundtrip_dense() {
        let mut rng = Pcg64::new(21);
        for _ in 0..20 {
            let (nr, nc, nnz) = (rng.range(1, 20), rng.range(1, 20), rng.below(60));
            let m = random_csr(&mut rng, nr, nc, nnz);
            m.validate().unwrap();
            let d = m.to_dense();
            let back = Csr::from_dense(&d);
            assert_eq!(back.to_dense(), d);
        }
    }

    #[test]
    fn transpose_matches_dense_transpose() {
        let mut rng = Pcg64::new(22);
        for _ in 0..20 {
            let (nr, nc, nnz) = (rng.range(1, 15), rng.range(1, 15), rng.below(40));
            let m = random_csr(&mut rng, nr, nc, nnz);
            let t = m.transpose();
            t.validate().unwrap();
            assert_eq!(t.to_dense(), m.to_dense().transpose());
        }
    }

    #[test]
    fn get_reads_entries() {
        let mut coo = Coo::new(3, 4);
        coo.push(1, 2, 7.0);
        coo.push(1, 0, 3.0);
        let m = Csr::from_coo(coo);
        assert_eq!(m.get(1, 2), 7.0);
        assert_eq!(m.get(1, 0), 3.0);
        assert_eq!(m.get(0, 0), 0.0);
        assert_eq!(m.get(2, 3), 0.0);
    }

    #[test]
    fn column_sums_and_scaling() {
        let mut coo = Coo::new(3, 2);
        coo.push(0, 0, 1.0);
        coo.push(1, 0, 3.0);
        coo.push(2, 1, 2.0);
        let mut m = Csr::from_coo(coo);
        assert_eq!(m.column_sums(), vec![4.0, 2.0]);
        m.scale_columns(&[0.25, 0.5]);
        assert_eq!(m.column_sums(), vec![1.0, 1.0]);
    }

    #[test]
    fn select_rows_subset() {
        let mut rng = Pcg64::new(23);
        let m = random_csr(&mut rng, 10, 8, 30);
        let keep = vec![1usize, 4, 9];
        let s = m.select_rows(&keep);
        s.validate().unwrap();
        assert_eq!(s.nrows(), 3);
        for (new_i, &old_i) in keep.iter().enumerate() {
            for j in 0..8 {
                assert_eq!(s.get(new_i, j), m.get(old_i, j));
            }
        }
    }

    #[test]
    fn slice_columns_partitions_reassemble() {
        let mut rng = Pcg64::new(77);
        for _ in 0..10 {
            let (nr, nc, nnz) = (rng.range(1, 15), rng.range(2, 20), rng.below(60));
            let m = random_csr(&mut rng, nr, nc, nnz);
            let cut1 = rng.below(nc + 1);
            let cut2 = cut1 + rng.below(nc + 1 - cut1);
            let ranges = [0..cut1, cut1..cut2, cut2..nc];
            let mut total_nnz = 0;
            for r in ranges.clone() {
                let s = m.slice_columns(r.clone());
                s.validate().unwrap();
                assert_eq!(s.nrows(), nr);
                assert_eq!(s.ncols(), r.len());
                total_nnz += s.nnz();
                for i in 0..nr {
                    for (jj, j) in r.clone().enumerate() {
                        assert_eq!(s.get(i, jj), m.get(i, j));
                    }
                }
            }
            assert_eq!(total_nnz, m.nnz(), "slices must partition the nnz");
        }
    }

    #[test]
    fn slice_columns_empty_range() {
        let mut rng = Pcg64::new(78);
        let m = random_csr(&mut rng, 6, 9, 20);
        let s = m.slice_columns(4..4);
        s.validate().unwrap();
        assert_eq!(s.ncols(), 0);
        assert_eq!(s.nnz(), 0);
        assert_eq!(s.nrows(), 6);
    }

    #[test]
    fn concat_columns_inverts_slice_partition() {
        let mut rng = Pcg64::new(79);
        for _ in 0..10 {
            let (nr, nc, nnz) = (rng.range(1, 15), rng.range(2, 20), rng.below(60));
            let m = random_csr(&mut rng, nr, nc, nnz);
            let cut1 = rng.below(nc + 1);
            let cut2 = cut1 + rng.below(nc + 1 - cut1);
            let parts: Vec<Csr> = [0..cut1, cut1..cut2, cut2..nc]
                .into_iter()
                .map(|r| m.slice_columns(r))
                .collect();
            let refs: Vec<&Csr> = parts.iter().collect();
            let back = Csr::concat_columns(&refs);
            back.validate().unwrap();
            assert_eq!(back, m, "concat of a slice partition must be bitwise the original");
        }
    }

    #[test]
    fn concat_columns_single_part_is_identity() {
        let mut rng = Pcg64::new(80);
        let m = random_csr(&mut rng, 5, 7, 20);
        assert_eq!(Csr::concat_columns(&[&m]), m);
    }

    #[test]
    #[should_panic(expected = "row-count mismatch")]
    fn concat_columns_rejects_row_mismatch() {
        let mut rng = Pcg64::new(81);
        let a = random_csr(&mut rng, 4, 3, 8);
        let b = random_csr(&mut rng, 5, 3, 8);
        let _ = Csr::concat_columns(&[&a, &b]);
    }

    #[test]
    fn with_columns_emptied_drops_entries_keeps_shape() {
        let mut rng = Pcg64::new(82);
        let m = random_csr(&mut rng, 6, 9, 30);
        let out = m.with_columns_emptied(&[2, 7]);
        out.validate().unwrap();
        assert_eq!(out.nrows(), m.nrows());
        assert_eq!(out.ncols(), m.ncols());
        for i in 0..m.nrows() {
            for j in 0..m.ncols() {
                let want = if j == 2 || j == 7 { 0.0 } else { m.get(i, j) };
                assert_eq!(out.get(i, j), want);
            }
        }
        // Emptying nothing is the identity.
        assert_eq!(m.with_columns_emptied(&[]), m);
    }

    #[test]
    fn try_from_parts_rejects_invalid() {
        // Non-monotone row_ptr.
        assert!(Csr::try_from_parts(2, 2, vec![0, 2, 1], vec![0], vec![1.0]).is_err());
        // Out-of-range column.
        assert!(Csr::try_from_parts(1, 2, vec![0, 1], vec![5], vec![1.0]).is_err());
        // nrows == usize::MAX with an empty row_ptr: `nrows + 1` would
        // overflow (debug) or wrap to 0 and index out of bounds (release).
        assert!(Csr::try_from_parts(usize::MAX, 1, vec![], vec![], vec![]).is_err());
        assert!(Csr::try_from_parts(0, 1, vec![], vec![], vec![]).is_err());
        // Good parts round-trip.
        let m = Csr::try_from_parts(2, 2, vec![0, 1, 2], vec![1, 0], vec![2.0, 3.0]).unwrap();
        assert_eq!(m.get(0, 1), 2.0);
        assert_eq!(m.get(1, 0), 3.0);
    }

    #[test]
    fn duplicate_coo_entries_sum() {
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.5);
        coo.push(0, 0, 2.5);
        let m = Csr::from_coo(coo);
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.get(0, 0), 4.0);
    }
}
