//! COO (triplet) sparse format — the assembly format: corpus builders emit
//! triplets, which are sorted/deduplicated into CSR.

use crate::Real;

/// Coordinate-format sparse matrix (row, col, value triplets).
#[derive(Clone, Debug, Default)]
pub struct Coo {
    pub nrows: usize,
    pub ncols: usize,
    pub rows: Vec<u32>,
    pub cols: Vec<u32>,
    pub values: Vec<Real>,
}

impl Coo {
    pub fn new(nrows: usize, ncols: usize) -> Self {
        assert!(nrows <= u32::MAX as usize && ncols <= u32::MAX as usize);
        Self { nrows, ncols, rows: Vec::new(), cols: Vec::new(), values: Vec::new() }
    }

    pub fn with_capacity(nrows: usize, ncols: usize, cap: usize) -> Self {
        let mut c = Self::new(nrows, ncols);
        c.rows.reserve(cap);
        c.cols.reserve(cap);
        c.values.reserve(cap);
        c
    }

    #[inline]
    pub fn push(&mut self, row: usize, col: usize, value: Real) {
        debug_assert!(row < self.nrows && col < self.ncols);
        self.rows.push(row as u32);
        self.cols.push(col as u32);
        self.values.push(value);
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Sort by (row, col) and sum duplicate coordinates.
    pub fn compact(&mut self) {
        let n = self.nnz();
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_unstable_by_key(|&e| (self.rows[e], self.cols[e]));
        let mut rows = Vec::with_capacity(n);
        let mut cols = Vec::with_capacity(n);
        let mut values = Vec::with_capacity(n);
        for &e in &order {
            if let (Some(&lr), Some(&lc)) = (rows.last(), cols.last()) {
                if lr == self.rows[e] && lc == self.cols[e] {
                    *values.last_mut().unwrap() += self.values[e];
                    continue;
                }
            }
            rows.push(self.rows[e]);
            cols.push(self.cols[e]);
            values.push(self.values[e]);
        }
        self.rows = rows;
        self.cols = cols;
        self.values = values;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_nnz() {
        let mut m = Coo::new(4, 4);
        m.push(0, 1, 1.0);
        m.push(3, 2, 2.0);
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    fn compact_sorts_and_dedups() {
        let mut m = Coo::new(3, 3);
        m.push(2, 2, 1.0);
        m.push(0, 1, 2.0);
        m.push(2, 2, 3.0);
        m.push(0, 0, 4.0);
        m.compact();
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.rows, vec![0, 0, 2]);
        assert_eq!(m.cols, vec![0, 1, 2]);
        assert_eq!(m.values, vec![4.0, 2.0, 4.0]);
    }
}
