//! Compile + execute one HLO-text artifact: input marshalling (f64
//! literals), shape checking against the manifest signature, tuple
//! unpacking.

use super::artifact::ArtifactMeta;
use super::Runtime;
use crate::Real;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// A compiled artifact ready for repeated execution.
pub struct LoadedArtifact {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

pub(crate) fn load_artifact(rt: &Runtime, dir: &Path, meta: &ArtifactMeta) -> Result<LoadedArtifact> {
    let path = dir.join(&meta.file);
    let path_str = path
        .to_str()
        .ok_or_else(|| anyhow!("non-utf8 artifact path {path:?}"))?;
    let proto = xla::HloModuleProto::from_text_file(path_str)
        .with_context(|| format!("parsing HLO text {path:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = rt
        .client()
        .compile(&comp)
        .with_context(|| format!("compiling artifact '{}'", meta.name))?;
    Ok(LoadedArtifact { meta: meta.clone(), exe })
}

impl LoadedArtifact {
    /// Execute with f64 input tensors (row-major, matching the manifest
    /// signature order). Returns the output tensors as flat f64 vectors.
    pub fn run(&self, inputs: &[&[Real]]) -> Result<Vec<Vec<Real>>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "artifact '{}' expects {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (spec, data) in self.meta.inputs.iter().zip(inputs) {
            if data.len() != spec.element_count() {
                bail!(
                    "artifact '{}' input '{}': expected {} elements ({:?}), got {}",
                    self.meta.name,
                    spec.name,
                    spec.element_count(),
                    spec.dims,
                    data.len()
                );
            }
            let dims: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .with_context(|| format!("reshaping input '{}'", spec.name))?;
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        let parts = result.to_tuple()?;
        if parts.len() != self.meta.outputs.len() {
            bail!(
                "artifact '{}' returned {} outputs, manifest says {}",
                self.meta.name,
                parts.len(),
                self.meta.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (spec, lit) in self.meta.outputs.iter().zip(parts) {
            let v: Vec<Real> = lit
                .to_vec()
                .with_context(|| format!("reading output '{}'", spec.name))?;
            if v.len() != spec.element_count() {
                bail!(
                    "artifact '{}' output '{}': expected {} elements, got {}",
                    self.meta.name,
                    spec.name,
                    spec.element_count(),
                    v.len()
                );
            }
            out.push(v);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    //! Executor tests that need real artifacts live in
    //! `rust/tests/runtime_artifacts.rs` (they are skipped when
    //! `artifacts/` has not been built). Here we only test input
    //! validation against a fabricated meta + a trivially compiled graph,
    //! which requires a PJRT client — also gated.

    use super::*;

    fn pjrt_available() -> bool {
        Runtime::cpu().is_ok()
    }

    #[test]
    fn client_reports_platform() {
        if !pjrt_available() {
            eprintln!("skipping: PJRT CPU client unavailable");
            return;
        }
        let rt = Runtime::cpu().unwrap();
        assert!(rt.device_count() >= 1);
        assert!(!rt.platform_name().is_empty());
    }
}
