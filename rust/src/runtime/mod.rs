//! PJRT runtime — loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO **text**; see `/opt/xla-example/README.md`
//! for why text, not serialized protos) and executes them from the Rust
//! request path. Python never runs at serve time.

pub mod artifact;
pub mod executor;

pub use artifact::{ArtifactMeta, Manifest, TensorSpec};
pub use executor::LoadedArtifact;

use anyhow::Result;

/// Shared PJRT CPU client. Creating a client is expensive; the coordinator
/// holds one for the process lifetime.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    pub(crate) fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load + compile one artifact by metadata entry.
    pub fn load(&self, dir: &std::path::Path, meta: &ArtifactMeta) -> Result<LoadedArtifact> {
        executor::load_artifact(self, dir, meta)
    }

    /// Load the manifest and compile every artifact in it.
    pub fn load_all(&self, dir: &std::path::Path) -> Result<Vec<LoadedArtifact>> {
        let manifest = Manifest::read(dir)?;
        manifest.artifacts.iter().map(|m| self.load(dir, m)).collect()
    }
}
