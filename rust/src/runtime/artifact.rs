//! Artifact manifest: `artifacts/manifest.json`, written by `aot.py`,
//! read here with the in-repo JSON parser. Each entry describes one
//! HLO-text file: its variant, shape bucket, and input/output signature.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// One named tensor in an artifact signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }
}

/// Metadata of one compiled artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    /// Unique artifact name, e.g. `sinkhorn_solve_vr16_v2048_n256`.
    pub name: String,
    /// Variant family: `sinkhorn_solve` | `cdist_k` | `sinkhorn_step`.
    pub variant: String,
    /// HLO text filename inside the artifacts dir.
    pub file: String,
    /// Shape bucket.
    pub v_r: usize,
    pub vocab: usize,
    pub n_docs: usize,
    pub dim: usize,
    /// Solver parameters baked into the graph.
    pub max_iter: usize,
    pub lambda: f64,
    /// Ordered input/output signature.
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Whether the L1 Pallas kernel path was used when lowering.
    pub pallas: bool,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Read `<dir>/manifest.json`.
    pub fn read(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let root = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let arr = root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest: missing 'artifacts' array"))?;
        let mut artifacts = Vec::with_capacity(arr.len());
        for item in arr {
            artifacts.push(parse_meta(item)?);
        }
        Ok(Self { artifacts })
    }

    /// Find by variant and shape bucket.
    pub fn find(&self, variant: &str, v_r: usize, vocab: usize, n_docs: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.variant == variant && a.v_r == v_r && a.vocab == vocab && a.n_docs == n_docs)
    }

    /// All v_r buckets available for a `(variant, vocab, n_docs)` pair,
    /// ascending — the router picks the smallest bucket ≥ the query size.
    pub fn v_r_buckets(&self, variant: &str, vocab: usize, n_docs: usize) -> Vec<usize> {
        let mut buckets: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.variant == variant && a.vocab == vocab && a.n_docs == n_docs)
            .map(|a| a.v_r)
            .collect();
        buckets.sort_unstable();
        buckets.dedup();
        buckets
    }
}

fn parse_meta(j: &Json) -> Result<ArtifactMeta> {
    let s = |key: &str| -> Result<String> {
        j.get(key)
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| anyhow!("manifest entry: missing string '{key}'"))
    };
    let u = |key: &str| -> Result<usize> {
        j.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest entry: missing integer '{key}'"))
    };
    let tensors = |key: &str| -> Result<Vec<TensorSpec>> {
        let arr = j
            .get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest entry: missing '{key}'"))?;
        arr.iter()
            .map(|t| {
                let pair = t.as_arr().ok_or_else(|| anyhow!("bad tensor spec in '{key}'"))?;
                if pair.len() != 2 {
                    bail!("tensor spec must be [name, dims]");
                }
                let name = pair[0].as_str().ok_or_else(|| anyhow!("tensor name"))?.to_string();
                let dims = pair[1]
                    .as_arr()
                    .ok_or_else(|| anyhow!("tensor dims"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("tensor dim")))
                    .collect::<Result<Vec<usize>>>()?;
                Ok(TensorSpec { name, dims })
            })
            .collect()
    };
    Ok(ArtifactMeta {
        name: s("name")?,
        variant: s("variant")?,
        file: s("file")?,
        v_r: u("v_r")?,
        vocab: u("vocab")?,
        n_docs: u("n_docs")?,
        dim: u("dim")?,
        max_iter: u("max_iter")?,
        lambda: j
            .get("lambda")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("manifest entry: missing 'lambda'"))?,
        inputs: tensors("inputs")?,
        outputs: tensors("outputs")?,
        pallas: matches!(j.get("pallas"), Some(Json::Bool(true))),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": [
        {"name": "sinkhorn_solve_vr16_v2048_n256", "variant": "sinkhorn_solve",
         "file": "sinkhorn_solve_vr16_v2048_n256.hlo.txt",
         "v_r": 16, "vocab": 2048, "n_docs": 256, "dim": 64,
         "max_iter": 15, "lambda": 10.0, "pallas": true,
         "inputs": [["r", [16]], ["qvecs", [16, 64]], ["c", [2048, 256]], ["vecs", [2048, 64]]],
         "outputs": [["wmd", [256]]]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = &m.artifacts[0];
        assert_eq!(a.variant, "sinkhorn_solve");
        assert_eq!(a.v_r, 16);
        assert!(a.pallas);
        assert_eq!(a.inputs.len(), 4);
        assert_eq!(a.inputs[2].dims, vec![2048, 256]);
        assert_eq!(a.outputs[0].element_count(), 256);
    }

    #[test]
    fn find_and_buckets() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.find("sinkhorn_solve", 16, 2048, 256).is_some());
        assert!(m.find("sinkhorn_solve", 8, 2048, 256).is_none());
        assert_eq!(m.v_r_buckets("sinkhorn_solve", 2048, 256), vec![16]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"artifacts": [{"name": "x"}]}"#).is_err());
    }
}
