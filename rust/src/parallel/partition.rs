//! Work partitioning for CSR traversals.
//!
//! The paper (§4, "load-balancing"): *"we have divided the number of
//! non-zeros in c matrix evenly among the threads and each thread in
//! parallel determines its starting exploration point inside the CSR using
//! a binary search which guarantees an equal work distribution across
//! threads."* [`balanced_nnz_partition`] implements exactly that;
//! [`even_rows_partition`] is the naive row split kept as the ablation
//! baseline (`benches/ablation_balance.rs`).

/// A thread's share of CSR non-zeros: the half-open nnz range
/// `[nnz_start, nnz_end)` plus the row containing `nnz_start`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NnzRange {
    /// First nnz index owned by this thread.
    pub nnz_start: usize,
    /// One past the last nnz index owned by this thread.
    pub nnz_end: usize,
    /// Row containing `nnz_start` (first row with `row_ptr[r+1] > nnz_start`).
    pub start_row: usize,
}

impl NnzRange {
    #[inline]
    pub fn len(&self) -> usize {
        self.nnz_end - self.nnz_start
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.nnz_start == self.nnz_end
    }
}

/// Split `nnz` non-zeros evenly across `nthreads`, locating each thread's
/// starting row by binary search over `row_ptr` (cost `O(log V)` per
/// thread, as in the paper's analysis).
///
/// `row_ptr` is the CSR row pointer of length `nrows + 1` with
/// `row_ptr[nrows] == nnz`.
pub fn balanced_nnz_partition(row_ptr: &[usize], nthreads: usize) -> Vec<NnzRange> {
    let mut parts = Vec::new();
    balanced_nnz_partition_into(row_ptr, nthreads, &mut parts);
    parts
}

/// [`balanced_nnz_partition`] writing into a caller-owned buffer — the
/// allocation-free form the solver workspace uses on the hot path (the
/// buffer's capacity is retained across solves).
pub fn balanced_nnz_partition_into(row_ptr: &[usize], nthreads: usize, out: &mut Vec<NnzRange>) {
    assert!(!row_ptr.is_empty());
    assert!(nthreads >= 1);
    let nnz = *row_ptr.last().unwrap();
    out.clear();
    out.extend((0..nthreads).map(|t| {
        let nnz_start = t * nnz / nthreads;
        let nnz_end = (t + 1) * nnz / nthreads;
        NnzRange { nnz_start, nnz_end, start_row: row_of(row_ptr, nnz_start) }
    }));
}

/// Cumulative nnz prefix of a *subset* of rows (or columns): `out[s+1] -
/// out[s]` is the nnz count of `subset[s]` under `ptr`. The resulting
/// prefix array is exactly the `row_ptr` shape [`balanced_nnz_partition_into`]
/// expects, so a partition over the subset is the composition of the two —
/// the building block of the solver's active-set compaction (partition the
/// surviving columns of a mostly-frozen solve without rebuilding the
/// pattern). Writes into a caller-owned grow-only buffer; subset indices
/// are `u32` to match [`crate::sparse::ops::TransposedPattern`]'s entry
/// index width.
pub fn subset_nnz_prefix_into(ptr: &[usize], subset: &[u32], out: &mut Vec<usize>) {
    out.clear();
    out.reserve(subset.len() + 1);
    out.push(0);
    let mut acc = 0usize;
    for &j in subset {
        let j = j as usize;
        acc += ptr[j + 1] - ptr[j];
        out.push(acc);
    }
}

/// Row containing nnz index `k`: the last row `r` with `row_ptr[r] <= k`.
/// For `k == nnz` returns `nrows` (the end sentinel). Skips empty rows.
#[inline]
pub fn row_of(row_ptr: &[usize], k: usize) -> usize {
    // partition_point gives the first index with row_ptr[i] > k; the row is
    // that index minus one. Empty rows share a row_ptr value; the row that
    // *contains* k is the last one whose start is <= k and whose end is > k,
    // which is exactly `partition_point - 1` on the strictly-increasing
    // subsequence; for runs of equal values we land past the empty rows.
    row_ptr.partition_point(|&p| p <= k).saturating_sub(1)
}

/// Naive split: rows divided evenly regardless of their nnz counts.
/// Returned in the same `NnzRange` shape for a drop-in ablation.
pub fn even_rows_partition(row_ptr: &[usize], nthreads: usize) -> Vec<NnzRange> {
    let nrows = row_ptr.len() - 1;
    (0..nthreads)
        .map(|t| {
            let rows = super::static_chunk(nrows, t, nthreads);
            NnzRange {
                nnz_start: row_ptr[rows.start],
                nnz_end: row_ptr[rows.end],
                start_row: rows.start,
            }
        })
        .collect()
}

/// Imbalance factor of a partition: `max share / mean share` (1.0 = perfect).
pub fn imbalance(parts: &[NnzRange]) -> f64 {
    let total: usize = parts.iter().map(|p| p.len()).sum();
    if total == 0 {
        return 1.0;
    }
    let mean = total as f64 / parts.len() as f64;
    let max = parts.iter().map(|p| p.len()).max().unwrap() as f64;
    max / mean
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn random_row_ptr(rng: &mut Pcg64, nrows: usize, max_row_nnz: usize) -> Vec<usize> {
        let mut rp = Vec::with_capacity(nrows + 1);
        rp.push(0);
        for _ in 0..nrows {
            let k = rng.below(max_row_nnz + 1);
            rp.push(rp.last().unwrap() + k);
        }
        rp
    }

    #[test]
    fn covers_all_nnz_disjointly() {
        let mut rng = Pcg64::new(11);
        for _ in 0..50 {
            let nrows = rng.range(1, 200);
            let rp = random_row_ptr(&mut rng, nrows, 17);
            let nnz = *rp.last().unwrap();
            for p in [1usize, 2, 5, 16] {
                let parts = balanced_nnz_partition(&rp, p);
                assert_eq!(parts.len(), p);
                assert_eq!(parts[0].nnz_start, 0);
                assert_eq!(parts[p - 1].nnz_end, nnz);
                for w in parts.windows(2) {
                    assert_eq!(w[0].nnz_end, w[1].nnz_start);
                }
            }
        }
    }

    #[test]
    fn balanced_within_one() {
        let mut rng = Pcg64::new(12);
        let rp = random_row_ptr(&mut rng, 1000, 9);
        for p in [2usize, 7, 32] {
            let parts = balanced_nnz_partition(&rp, p);
            let sizes: Vec<usize> = parts.iter().map(|x| x.len()).collect();
            let max = *sizes.iter().max().unwrap();
            let min = *sizes.iter().min().unwrap();
            assert!(max - min <= 1, "p={p} sizes={sizes:?}");
        }
    }

    #[test]
    fn start_row_is_correct() {
        let rp = vec![0usize, 3, 3, 3, 7, 10];
        // nnz index 0,1,2 -> row 0; 3..7 -> row 3 (rows 1,2 empty); 7..10 -> row 4.
        assert_eq!(row_of(&rp, 0), 0);
        assert_eq!(row_of(&rp, 2), 0);
        assert_eq!(row_of(&rp, 3), 3);
        assert_eq!(row_of(&rp, 6), 3);
        assert_eq!(row_of(&rp, 7), 4);
        assert_eq!(row_of(&rp, 9), 4);
    }

    #[test]
    fn start_row_contains_start_nnz() {
        let mut rng = Pcg64::new(13);
        for _ in 0..50 {
            let nrows = rng.range(1, 300);
            let rp = random_row_ptr(&mut rng, nrows, 11);
            for p in [3usize, 8] {
                for part in balanced_nnz_partition(&rp, p) {
                    if part.is_empty() {
                        continue;
                    }
                    let r = part.start_row;
                    assert!(rp[r] <= part.nnz_start, "{rp:?} {part:?}");
                    assert!(rp[r + 1] > part.nnz_start, "{rp:?} {part:?}");
                }
            }
        }
    }

    #[test]
    fn skewed_matrix_balance_beats_row_split() {
        // One pathological heavy row followed by many light rows.
        let mut rp = vec![0usize, 10_000];
        for i in 1..100 {
            rp.push(10_000 + i);
        }
        let nnz_parts = balanced_nnz_partition(&rp, 8);
        let row_parts = even_rows_partition(&rp, 8);
        assert!(imbalance(&nnz_parts) < 1.01);
        assert!(imbalance(&row_parts) > 4.0);
    }

    #[test]
    fn empty_matrix() {
        let rp = vec![0usize, 0, 0];
        let parts = balanced_nnz_partition(&rp, 4);
        assert!(parts.iter().all(|p| p.is_empty()));
    }

    #[test]
    fn subset_prefix_matches_per_row_counts() {
        let mut rng = Pcg64::new(14);
        for _ in 0..30 {
            let nrows = rng.range(1, 120);
            let rp = random_row_ptr(&mut rng, nrows, 13);
            // Random strictly-ascending subset.
            let subset: Vec<u32> =
                (0..nrows as u32).filter(|_| rng.next_f64() < 0.4).collect();
            let mut prefix = Vec::new();
            subset_nnz_prefix_into(&rp, &subset, &mut prefix);
            assert_eq!(prefix.len(), subset.len() + 1);
            assert_eq!(prefix[0], 0);
            for (s, &j) in subset.iter().enumerate() {
                let j = j as usize;
                assert_eq!(prefix[s + 1] - prefix[s], rp[j + 1] - rp[j]);
            }
        }
    }

    #[test]
    fn subset_prefix_partitions_like_a_row_ptr() {
        // The prefix composes with the nnz partitioner: a balanced split of
        // the subset covers its nnz disjointly and start_row is a subset
        // *position* (not a global row id).
        let rp = vec![0usize, 5, 5, 9, 20, 21, 30];
        let subset = vec![0u32, 3, 5];
        let mut prefix = Vec::new();
        subset_nnz_prefix_into(&rp, &subset, &mut prefix);
        assert_eq!(prefix, vec![0, 5, 16, 25]);
        let parts = balanced_nnz_partition(&prefix, 3);
        assert_eq!(parts[0].nnz_start, 0);
        assert_eq!(parts[2].nnz_end, 25);
        for w in parts.windows(2) {
            assert_eq!(w[0].nnz_end, w[1].nnz_start);
        }
        for part in &parts {
            if !part.is_empty() {
                assert!(prefix[part.start_row] <= part.nnz_start);
                assert!(prefix[part.start_row + 1] > part.nnz_start);
            }
        }
    }

    #[test]
    fn subset_prefix_reuses_dirty_buffer() {
        let rp = vec![0usize, 2, 6, 7];
        let mut prefix = vec![99usize; 40];
        subset_nnz_prefix_into(&rp, &[1, 2], &mut prefix);
        assert_eq!(prefix, vec![0, 4, 5]);
        subset_nnz_prefix_into(&rp, &[], &mut prefix);
        assert_eq!(prefix, vec![0]);
    }
}
