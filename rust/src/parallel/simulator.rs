//! Multicore scaling simulator — the hardware substitution for the
//! paper's 56-core CLX0 / 96-core CLX1 testbeds (DESIGN.md §3: this
//! container exposes a single core, so strong-scaling *curves* are
//! produced by a calibrated analytical model driven by the real kernel's
//! measured single-thread time and its real work partition).
//!
//! Model (per parallel kernel invocation):
//!
//! ```text
//! T(p) = T_comp(1) · share_max(p)            compute, perfectly parallel
//!        / contention(p)                      ...until bandwidth saturates
//!      + n_barriers · τ_barrier · log2(p)     pool fork/join (paper's log p)
//!
//! share_max(p)   = max_t work_t / total work  (from the REAL partition —
//!                  nnz-balanced or row-split — so load imbalance is
//!                  faithfully reflected)
//! contention(p)  = 1 / (f_mem · min(1, S_bw/p_socket_cores_used) + f_cmp)
//!                  — the memory-bound fraction f_mem of the kernel stops
//!                  scaling once the socket's bandwidth saturates at S_bw
//!                  cores; the compute fraction keeps scaling.
//! ```
//!
//! Crossing sockets multiplies available bandwidth (more memory
//! controllers) but adds a remote-access penalty — reproducing the
//! paper's Fig 5 "scales across sockets but with a dip past 2 sockets"
//! and Fig 6's post-48-core decline.

use super::NnzRange;

/// A simulated machine topology (defaults resemble the paper's CLX1).
#[derive(Clone, Copy, Debug)]
pub struct Topology {
    pub sockets: usize,
    pub cores_per_socket: usize,
    /// Cores per socket at which memory bandwidth saturates.
    pub bw_saturation_cores: usize,
    /// Fractional throughput penalty when a kernel spans sockets
    /// (remote accesses + coherence), applied to the memory-bound part.
    pub numa_penalty: f64,
}

impl Topology {
    /// Paper CLX1: 4 sockets × 24 cores.
    pub fn clx1() -> Self {
        Self { sockets: 4, cores_per_socket: 24, bw_saturation_cores: 12, numa_penalty: 0.1 }
    }

    /// Paper CLX0: 2 sockets × 28 cores.
    pub fn clx0() -> Self {
        Self { sockets: 2, cores_per_socket: 28, bw_saturation_cores: 14, numa_penalty: 0.1 }
    }

    pub fn total_cores(&self) -> usize {
        self.sockets * self.cores_per_socket
    }
}

/// A kernel's cost character, calibrated from a real measurement.
#[derive(Clone, Copy, Debug)]
pub struct KernelProfile {
    /// Measured single-thread wall time (seconds) for one invocation.
    pub t1: f64,
    /// Memory-bound fraction of the kernel (0 = pure compute, 1 = pure
    /// streaming). The fused SDDMM_SpMM streams `KT`/`K_over_r` rows with
    /// one fma per element → strongly memory-bound (≈ 0.7–0.8 measured on
    /// CLX-class parts for 8 B/flop kernels).
    pub mem_fraction: f64,
    /// Barrier (fork/join) cost per invocation, seconds·log2(p).
    pub barrier_cost: f64,
    /// Invocations per solve (e.g. Sinkhorn iterations).
    pub invocations: usize,
}

/// Predicted time/speedup for one thread count.
#[derive(Clone, Copy, Debug)]
pub struct Prediction {
    pub threads: usize,
    pub time: f64,
    pub speedup: f64,
    pub efficiency: f64,
}

/// Simulate a kernel over a thread sweep on `topo`, given the real
/// per-thread work shares produced by the partitioner.
///
/// `shares(p)` returns the per-thread work fractions for `p` threads
/// (they need not be balanced — pass the row-split partition to model the
/// ablation).
pub fn simulate(
    profile: &KernelProfile,
    topo: &Topology,
    threads: &[usize],
    mut shares: impl FnMut(usize) -> Vec<f64>,
) -> Vec<Prediction> {
    assert!(profile.t1 > 0.0);
    assert!((0.0..=1.0).contains(&profile.mem_fraction));
    let mut out = Vec::with_capacity(threads.len());
    for &p in threads {
        assert!(p >= 1 && p <= topo.total_cores(), "p={p} exceeds topology");
        let share = shares(p);
        assert_eq!(share.len(), p);
        let total: f64 = share.iter().sum();
        let share_max = crate::util::nan_max(share.iter().cloned()) / total.max(1e-300);

        // How many sockets are in use?
        let sockets_used = p.div_ceil(topo.cores_per_socket);
        // Memory throughput factor: ideal aggregate streaming rate grows
        // with p; achievable rate is capped at `bw_saturation_cores`
        // core-equivalents per used socket, derated by the NUMA penalty
        // once the kernel spans sockets.
        let achievable = (sockets_used * topo.bw_saturation_cores) as f64
            / (1.0 + topo.numa_penalty * (sockets_used as f64 - 1.0));
        let bw_scale = (achievable / p as f64).min(1.0);
        // Effective parallel throughput of one thread's share:
        //   compute part scales with p (share_max already has 1/p);
        //   memory part additionally capped by bandwidth.
        let f_mem = profile.mem_fraction;
        let f_cmp = 1.0 - f_mem;
        // Time for the critical thread: the compute part scales with its
        // share; the memory part additionally runs at min(1, bw_scale) of
        // its ideal rate once bandwidth saturates.
        let t_comp = profile.t1 * share_max * f_cmp;
        let t_mem = profile.t1 * share_max * f_mem / bw_scale.min(1.0).max(1e-9);
        let t_barrier = if p > 1 {
            profile.barrier_cost * (p as f64).log2()
        } else {
            0.0
        } * profile.invocations as f64;
        let time = t_comp + t_mem + t_barrier;
        let speedup = profile.t1 / time;
        out.push(Prediction { threads: p, time, speedup, efficiency: speedup / p as f64 });
    }
    out
}

/// Convenience: shares from an [`NnzRange`] partitioner.
pub fn shares_from_parts(parts: &[NnzRange]) -> Vec<f64> {
    parts.iter().map(|r| r.len() as f64).collect()
}

/// Thread sweep for a topology: 1, 2, 4, … up to total cores, always
/// including socket boundaries (the paper's Fig 5 x-axis).
pub fn sweep(topo: &Topology) -> Vec<usize> {
    let mut ts = vec![1usize];
    while ts.last().unwrap() * 2 <= topo.total_cores() {
        ts.push(ts.last().unwrap() * 2);
    }
    for s in 1..=topo.sockets {
        let c = s * topo.cores_per_socket;
        if !ts.contains(&c) {
            ts.push(c);
        }
    }
    ts.sort_unstable();
    ts
}

#[cfg(test)]
mod tests {
    use super::*;

    fn balanced_shares(p: usize) -> Vec<f64> {
        vec![1.0 / p as f64; p]
    }

    fn profile() -> KernelProfile {
        KernelProfile { t1: 1.0, mem_fraction: 0.7, barrier_cost: 2e-6, invocations: 32 }
    }

    #[test]
    fn single_thread_is_identity() {
        let preds = simulate(&profile(), &Topology::clx1(), &[1], balanced_shares);
        assert!((preds[0].time - 1.0).abs() < 1e-9);
        assert!((preds[0].speedup - 1.0).abs() < 1e-9);
    }

    #[test]
    fn speedup_monotone_then_saturates_within_socket() {
        let topo = Topology::clx0();
        let ts: Vec<usize> = vec![1, 2, 4, 8, 14, 28];
        let preds = simulate(&profile(), &topo, &ts, balanced_shares);
        // Monotone nondecreasing until saturation; strictly increasing early.
        assert!(preds[1].speedup > 1.7);
        assert!(preds[2].speedup > preds[1].speedup);
        // At 28 cores speedup well below linear (bandwidth bound).
        let s28 = preds.last().unwrap().speedup;
        assert!(s28 < 28.0 * 0.9, "too linear: {s28}");
        assert!(s28 > 4.0, "too pessimistic: {s28}");
    }

    #[test]
    fn paper_band_on_clx_topologies() {
        // The paper: 14x on 28 cores (CLX0), 16x on 24 cores (CLX1),
        // 67x on 96 cores. The default profile should land in those bands
        // (±50% — it's a model, the *shape* matters).
        let prof = KernelProfile { t1: 1.0, mem_fraction: 0.55, barrier_cost: 1e-6, invocations: 32 };
        let c0 = simulate(&prof, &Topology::clx0(), &[28], balanced_shares)[0].speedup;
        assert!((7.0..21.0).contains(&c0), "CLX0 28-core speedup {c0}");
        let c1 = simulate(&prof, &Topology::clx1(), &[24, 96], balanced_shares);
        let s24 = c1[0].speedup;
        let s96 = c1[1].speedup;
        assert!((8.0..24.0).contains(&s24), "CLX1 24-core speedup {s24}");
        assert!(s96 > s24 * 1.5, "no cross-socket scaling: {s24} -> {s96}");
        assert!(s96 < 96.0 * 0.85, "unrealistically linear across sockets: {s96}");
    }

    #[test]
    fn imbalance_hurts() {
        let topo = Topology::clx0();
        let balanced = simulate(&profile(), &topo, &[8], balanced_shares)[0].speedup;
        let skewed = simulate(&profile(), &topo, &[8], |p| {
            let mut s = vec![0.5 / (p as f64 - 1.0); p];
            s[0] = 0.5; // one thread owns half the work
            s
        })[0]
        .speedup;
        assert!(skewed < balanced * 0.6, "imbalance not reflected: {balanced} vs {skewed}");
    }

    #[test]
    fn barrier_cost_matters_at_high_p() {
        let topo = Topology::clx1();
        let cheap = KernelProfile { barrier_cost: 0.0, ..profile() };
        let dear = KernelProfile { barrier_cost: 1e-3, ..profile() };
        let s_cheap = simulate(&cheap, &topo, &[96], balanced_shares)[0].speedup;
        let s_dear = simulate(&dear, &topo, &[96], balanced_shares)[0].speedup;
        assert!(s_dear < s_cheap);
    }

    #[test]
    fn sweep_includes_socket_boundaries() {
        let ts = sweep(&Topology::clx1());
        assert!(ts.contains(&1) && ts.contains(&24) && ts.contains(&48) && ts.contains(&96));
        for w in ts.windows(2) {
            assert!(w[0] < w[1]);
        }
    }
}
