//! Shared-memory parallelism substrate — the OpenMP surrogate.
//!
//! The paper's implementation is C/OpenMP (`#pragma omp parallel for`,
//! static scheduling, atomics in the type-1 SpMM scatter). This module
//! provides the equivalent primitives on `std`:
//!
//! * [`Pool`] — a persistent worker pool executing SPMD regions
//!   (`pool.run(|tid, nthreads| ...)`) and `parallel_for` loops with
//!   static or dynamic (guided) chunking.
//! * [`partition`] — work partitioning, including the paper's
//!   nnz-balanced split: each thread binary-searches its starting
//!   position inside the CSR `row_ptr` so every thread owns the same
//!   number of non-zeros ("guarantees an equal work distribution across
//!   threads", §4).

pub mod atomic;
pub mod partition;
pub mod pool;
pub mod simulator;

pub use atomic::AtomicF64Slice;
pub use partition::{
    balanced_nnz_partition, balanced_nnz_partition_into, even_rows_partition,
    subset_nnz_prefix_into, NnzRange,
};
pub use pool::Pool;

/// Static contiguous chunk of `0..n` for thread `tid` of `nthreads`.
/// The first `n % nthreads` threads get one extra element.
#[inline]
pub fn static_chunk(n: usize, tid: usize, nthreads: usize) -> std::ops::Range<usize> {
    debug_assert!(tid < nthreads);
    let base = n / nthreads;
    let rem = n % nthreads;
    let start = tid * base + tid.min(rem);
    let len = base + usize::from(tid < rem);
    start..(start + len).min(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_chunks_cover_and_disjoint() {
        for n in [0usize, 1, 7, 64, 1000, 1001] {
            for p in [1usize, 2, 3, 8, 17] {
                let mut covered = vec![false; n];
                let mut prev_end = 0;
                for t in 0..p {
                    let r = static_chunk(n, t, p);
                    assert_eq!(r.start, prev_end, "n={n} p={p} t={t}");
                    prev_end = r.end;
                    for i in r {
                        assert!(!covered[i]);
                        covered[i] = true;
                    }
                }
                assert_eq!(prev_end, n);
                assert!(covered.iter().all(|&c| c));
            }
        }
    }

    #[test]
    fn static_chunks_balanced() {
        for n in [100usize, 101, 999] {
            for p in [3usize, 7, 16] {
                let sizes: Vec<usize> = (0..p).map(|t| static_chunk(n, t, p).len()).collect();
                let max = *sizes.iter().max().unwrap();
                let min = *sizes.iter().min().unwrap();
                assert!(max - min <= 1, "n={n} p={p} sizes={sizes:?}");
            }
        }
    }
}
