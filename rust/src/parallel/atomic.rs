//! Atomic f64 accumulation — the Rust equivalent of the paper's
//! `#pragma omp atomic` update in the SpMM scatter (Fig. 3, line 5).

use std::sync::atomic::{AtomicU64, Ordering};

/// View a mutable f64 slice as atomics for concurrent `+=` scatter.
/// All access during the view's lifetime must go through atomic ops.
pub struct AtomicF64Slice<'a> {
    cells: &'a [AtomicU64],
}

impl<'a> AtomicF64Slice<'a> {
    /// Reinterpret `&mut [f64]` as `&[AtomicU64]`.
    pub fn new(data: &'a mut [f64]) -> Self {
        // SAFETY: the mutable borrow guarantees exclusive write provenance
        // for the borrow's lifetime; `f64` and `AtomicU64` have identical
        // size/alignment (both 8/8); and a shared reference to an
        // interior-mutable type may write through provenance derived from
        // `as_mut_ptr` (the `as *const` cast changes only the type, not the
        // provenance). All writes during the borrow go through atomic ops.
        let cells = unsafe {
            std::slice::from_raw_parts(data.as_mut_ptr() as *const AtomicU64, data.len())
        };
        Self { cells }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.cells.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// `data[i] += v` via CAS loop (x86-64 has no native f64 fetch-add).
    #[inline]
    pub fn fetch_add(&self, i: usize, v: f64) {
        let cell = &self.cells[i];
        let mut cur = cell.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + v).to_bits();
            match cell.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Non-atomic read — only valid when no concurrent writers exist
    /// (e.g. after the parallel region's implicit barrier).
    #[inline]
    pub fn load(&self, i: usize) -> f64 {
        f64::from_bits(self.cells[i].load(Ordering::Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::Pool;

    #[test]
    fn concurrent_adds_are_lossless() {
        let mut data = vec![0.0f64; 16];
        let view = AtomicF64Slice::new(&mut data);
        let pool = Pool::new(8);
        #[cfg(not(miri))]
        let per_thread = 10_000;
        #[cfg(miri)]
        let per_thread = 256;
        pool.run(|_tid, _nt| {
            for k in 0..per_thread {
                view.fetch_add(k % 16, 1.0);
            }
        });
        drop(view);
        let total: f64 = data.iter().sum();
        assert_eq!(total, (8 * per_thread) as f64);
    }

    #[test]
    fn fetch_add_accumulates_fractions() {
        let mut data = vec![0.0f64; 1];
        let view = AtomicF64Slice::new(&mut data);
        for _ in 0..1000 {
            view.fetch_add(0, 0.25);
        }
        assert_eq!(view.load(0), 250.0);
    }
}
