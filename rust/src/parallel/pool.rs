//! Persistent worker pool executing SPMD regions, the moral equivalent of
//! an OpenMP parallel region. Workers park on a condvar between regions so
//! the per-region overhead is one broadcast + one join barrier (the
//! `O(log p)` term in the paper's cost model), not a thread spawn.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Type-erased SPMD region: called once per worker with `(tid, nthreads)`.
type Region = *const (dyn Fn(usize, usize) + Sync);

struct Shared {
    /// Current region pointer + epoch. `None` means "no work".
    job: Mutex<JobSlot>,
    work_cv: Condvar,
    done_cv: Condvar,
}

struct JobSlot {
    /// Incremented for each region; workers run a region exactly once.
    epoch: u64,
    /// Raw pointer to the caller's closure, valid while `pending > 0`.
    region: Option<Region>,
    /// Workers still running the current region.
    pending: usize,
    /// First worker panic message of the current region, re-raised on the
    /// submitting thread after the join barrier.
    panic_msg: Option<String>,
    shutdown: bool,
}

// SAFETY: `region` is only dereferenced while the submitting thread blocks
// in `Pool::run`, which keeps the referent alive; the Mutex provides the
// necessary synchronization for the pointer itself.
unsafe impl Send for JobSlot {}

/// A fixed-size worker pool. `Pool::new(1)` degenerates to inline
/// execution on the caller (so a "1 thread" bench measures zero pool
/// overhead, matching a sequential OpenMP run).
pub struct Pool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    nthreads: usize,
    /// Dynamic-scheduling cursor shared with workers via `run`.
    cursor: Arc<AtomicUsize>,
}

impl Pool {
    /// Spawn a pool with `nthreads` workers (including the caller: the
    /// caller itself executes tid 0, so only `nthreads - 1` OS threads are
    /// created — mirroring OpenMP where the master thread participates).
    pub fn new(nthreads: usize) -> Self {
        assert!(nthreads >= 1, "pool needs at least one thread");
        let shared = Arc::new(Shared {
            job: Mutex::new(JobSlot {
                epoch: 0,
                region: None,
                pending: 0,
                panic_msg: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(nthreads.saturating_sub(1));
        for tid in 1..nthreads {
            let shared = Arc::clone(&shared);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("wmd-worker-{tid}"))
                    .spawn(move || worker_loop(shared, tid, nthreads))
                    .expect("spawn worker"),
            );
        }
        Self { shared, handles, nthreads, cursor: Arc::new(AtomicUsize::new(0)) }
    }

    /// Number of threads (including the caller).
    #[inline]
    pub fn nthreads(&self) -> usize {
        self.nthreads
    }

    /// Execute an SPMD region: `f(tid, nthreads)` runs once on every
    /// thread, and `run` returns after all have finished (implicit
    /// barrier, like the end of an OpenMP parallel region).
    ///
    /// A panic on any lane (worker or caller) still drains the barrier —
    /// the region closure lives on this stack frame, so unwinding past the
    /// barrier while workers hold the raw region pointer would be a
    /// use-after-free. Worker panics are re-raised here after the join.
    pub fn run<F>(&self, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        #[cfg(feature = "strict-checks")]
        crate::util::shared::strict_begin_region();
        if self.nthreads == 1 {
            f(0, 1);
            return;
        }
        let region_ref: &(dyn Fn(usize, usize) + Sync) = &f;
        // SAFETY: we erase the lifetime; the closure outlives the region
        // because this function blocks until `pending == 0` even when a
        // lane panics (see below).
        let region: Region = unsafe { std::mem::transmute(region_ref) };
        {
            let mut slot = self.shared.job.lock().unwrap();
            debug_assert!(slot.region.is_none(), "nested Pool::run on the same pool");
            slot.epoch += 1;
            slot.region = Some(region);
            slot.pending = self.nthreads - 1;
            slot.panic_msg = None;
            self.shared.work_cv.notify_all();
        }
        // The caller participates as tid 0. Catch its panic so the join
        // barrier below always runs before `f` is dropped.
        let caller_result =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(0, self.nthreads)));
        // Join barrier.
        let worker_panic = {
            let mut slot = self.shared.job.lock().unwrap();
            while slot.pending > 0 {
                slot = self.shared.done_cv.wait(slot).unwrap();
            }
            slot.region = None;
            slot.panic_msg.take()
        };
        if let Err(payload) = caller_result {
            std::panic::resume_unwind(payload);
        }
        if let Some(msg) = worker_panic {
            panic!("worker thread panicked in parallel region: {msg}");
        }
    }

    /// Statically-chunked parallel for over `0..n`: each thread receives
    /// one contiguous range (OpenMP `schedule(static)`).
    pub fn parallel_for<F>(&self, n: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        self.run(|tid, nt| {
            let r = super::static_chunk(n, tid, nt);
            if !r.is_empty() {
                f(r);
            }
        });
    }

    /// Dynamically-chunked parallel for (OpenMP `schedule(dynamic, chunk)`)
    /// — threads grab `chunk`-sized ranges from a shared cursor. Used where
    /// per-row cost is irregular.
    pub fn parallel_for_dynamic<F>(&self, n: usize, chunk: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        assert!(chunk > 0);
        self.cursor.store(0, Ordering::Relaxed);
        let cursor = &self.cursor;
        self.run(|_tid, _nt| loop {
            let start = cursor.fetch_add(chunk, Ordering::Relaxed);
            if start >= n {
                break;
            }
            f(start..(start + chunk).min(n));
        });
    }

    /// Per-thread reduction: every thread computes a partial value over its
    /// static chunk; partials are combined on the caller.
    pub fn parallel_reduce<T, F, R>(&self, n: usize, identity: T, f: F, reduce: R) -> T
    where
        T: Clone + Send + Sync,
        F: Fn(Range<usize>, &mut T) + Sync,
        R: Fn(T, T) -> T,
    {
        // Serial fast path: no partial cells to allocate. `reduce(identity,
        // acc)` (not `acc` alone) keeps the result bitwise identical to the
        // general path's fold, whatever `reduce` does with the identity.
        if self.nthreads == 1 {
            let mut acc = identity.clone();
            if n > 0 {
                f(0..n, &mut acc);
            }
            return reduce(identity, acc);
        }
        let partials: Vec<Mutex<T>> =
            (0..self.nthreads).map(|_| Mutex::new(identity.clone())).collect();
        self.run(|tid, nt| {
            let r = super::static_chunk(n, tid, nt);
            let mut acc = identity.clone();
            if !r.is_empty() {
                f(r, &mut acc);
            }
            *partials[tid].lock().unwrap() = acc;
        });
        partials
            .into_iter()
            .map(|m| m.into_inner().unwrap())
            .fold(identity, |a, b| reduce(a, b))
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut slot = self.shared.job.lock().unwrap();
            slot.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, tid: usize, nthreads: usize) {
    let mut seen_epoch = 0u64;
    loop {
        let region = {
            let mut slot = shared.job.lock().unwrap();
            loop {
                if slot.shutdown {
                    return;
                }
                if slot.epoch != seen_epoch {
                    if let Some(r) = slot.region {
                        seen_epoch = slot.epoch;
                        break r;
                    }
                }
                slot = shared.work_cv.wait(slot).unwrap();
            }
        };
        // SAFETY: the submitter blocks in `run` until we decrement
        // `pending`, keeping the closure alive. Catch a panicking region so
        // the decrement below always happens — a skipped decrement would
        // deadlock the submitter's join barrier.
        let result =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
                (*region)(tid, nthreads)
            }));
        let mut slot = shared.job.lock().unwrap();
        if let Err(payload) = result {
            slot.panic_msg.get_or_insert_with(|| crate::testing::payload_message(&payload));
        }
        slot.pending -= 1;
        if slot.pending == 0 {
            shared.done_cv.notify_all();
        }
        drop(slot);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_executes_once_per_thread() {
        for p in [1usize, 2, 4, 8] {
            let pool = Pool::new(p);
            let hits = AtomicUsize::new(0);
            let tids = Mutex::new(Vec::new());
            pool.run(|tid, nt| {
                assert_eq!(nt, p);
                hits.fetch_add(1, Ordering::SeqCst);
                tids.lock().unwrap().push(tid);
            });
            assert_eq!(hits.load(Ordering::SeqCst), p);
            let mut seen = tids.into_inner().unwrap();
            seen.sort_unstable();
            assert_eq!(seen, (0..p).collect::<Vec<_>>());
        }
    }

    #[test]
    fn worker_threads_are_named_for_profilers() {
        // `wmd-worker-{tid}` in every profiler/debugger, alongside the
        // coordinator's `wmd-dispatch` and `wmd-shard-{i}` threads. tid 0
        // is the caller and keeps its own name.
        let pool = Pool::new(3);
        let names = Mutex::new(Vec::new());
        pool.run(|tid, _| {
            let name = std::thread::current().name().map(|s| s.to_string());
            names.lock().unwrap().push((tid, name));
        });
        for (tid, name) in names.into_inner().unwrap() {
            if tid > 0 {
                assert_eq!(name.as_deref(), Some(format!("wmd-worker-{tid}").as_str()));
            }
        }
    }

    #[test]
    fn parallel_for_covers_range() {
        let pool = Pool::new(4);
        #[cfg(not(miri))]
        let n = 100_000;
        #[cfg(miri)]
        let n = 1_000;
        let sum = AtomicU64::new(0);
        pool.parallel_for(n, |r| {
            let local: u64 = r.map(|i| i as u64).sum();
            sum.fetch_add(local, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::SeqCst), (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn parallel_for_dynamic_covers_range() {
        let pool = Pool::new(3);
        #[cfg(not(miri))]
        let n = 10_007;
        #[cfg(miri)]
        let n = 257;
        let count = AtomicUsize::new(0);
        pool.parallel_for_dynamic(n, 64, |r| {
            count.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::SeqCst), n);
    }

    #[test]
    fn reduce_sums() {
        let pool = Pool::new(4);
        let n = 1_000;
        let total = pool.parallel_reduce(
            n,
            0u64,
            |r, acc| {
                for i in r {
                    *acc += i as u64;
                }
            },
            |a, b| a + b,
        );
        assert_eq!(total, (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn sequential_pool_runs_inline() {
        let pool = Pool::new(1);
        let hits = AtomicUsize::new(0);
        pool.run(|tid, nt| {
            assert_eq!((tid, nt), (0, 1));
            hits.fetch_add(1, Ordering::Relaxed);
        });
        pool.parallel_for(10, |r| {
            hits.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 11);
    }

    #[test]
    fn many_regions_back_to_back() {
        let pool = Pool::new(4);
        let counter = AtomicUsize::new(0);
        #[cfg(not(miri))]
        let regions = 200;
        #[cfg(miri)]
        let regions = 20;
        for _ in 0..regions {
            pool.run(|_, _| {
                counter.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(counter.load(Ordering::SeqCst), regions * 4);
    }

    #[test]
    fn worker_panic_propagates_to_submitter() {
        let pool = Pool::new(4);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(|tid, _| {
                if tid == 2 {
                    panic!("lane {tid} exploded");
                }
            });
        }))
        .unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string panic>".into());
        assert!(msg.contains("lane 2 exploded"), "payload lost: {msg}");
        // The pool stays usable: the barrier drained, region cleared.
        let hits = AtomicUsize::new(0);
        pool.run(|_, _| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn caller_panic_drains_barrier_before_unwinding() {
        let pool = Pool::new(4);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(|tid, _| {
                if tid == 0 {
                    panic!("caller lane panicked");
                }
            });
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<&str>().copied().unwrap_or("<non-string panic>");
        assert!(msg.contains("caller lane panicked"), "payload lost: {msg}");
        let hits = AtomicUsize::new(0);
        pool.run(|_, _| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn borrows_stack_data() {
        let pool = Pool::new(4);
        let data: Vec<u64> = (0..1000).collect();
        let sum = AtomicU64::new(0);
        pool.parallel_for(data.len(), |r| {
            let local: u64 = data[r].iter().sum();
            sum.fetch_add(local, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 999 * 1000 / 2);
    }
}
