//! Run configuration: a TOML-subset file format (`key = value` lines under
//! `[section]` headers — no external TOML crate offline) plus programmatic
//! defaults. Used by the CLI binary and the examples.

use crate::prune::CascadeSpec;
use crate::sinkhorn::{IterateKernel, Precision, SinkhornConfig};
use crate::Real;
use std::collections::BTreeMap;
use std::path::Path;

/// Corpus-scale parameters (defaults are the laptop-scale workload;
/// `paper_scale()` matches the paper's evaluation).
#[derive(Clone, Debug, PartialEq)]
pub struct CorpusConfig {
    pub vocab_size: usize,
    pub num_docs: usize,
    pub embedding_dim: usize,
    pub n_topics: usize,
    pub tokens_per_doc: usize,
    pub num_queries: usize,
    pub query_words_min: usize,
    pub query_words_max: usize,
    pub seed: u64,
    /// Document-length skew exponent. `0` keeps every document at
    /// `tokens_per_doc` (the uniform default); `> 0` draws lengths from a
    /// power law (Pareto with shape `alpha = doc_length_skew`) so a few
    /// documents are much longer than the rest — the workload where
    /// per-document convergence tracking pays off most.
    pub doc_length_skew: f64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        Self {
            vocab_size: 10_000,
            num_docs: 500,
            embedding_dim: 300,
            n_topics: 8,
            tokens_per_doc: 60,
            num_queries: 10,
            query_words_min: 19,
            query_words_max: 43,
            seed: 42,
            doc_length_skew: 0.0,
        }
    }
}

impl CorpusConfig {
    /// The paper's full-scale workload: V = 100 k, N = 5 000, w = 300,
    /// source docs of 19–43 words.
    pub fn paper_scale() -> Self {
        Self { vocab_size: 100_000, num_docs: 5_000, ..Default::default() }
    }

    pub fn build(&self) -> crate::corpus::SyntheticCorpus {
        crate::corpus::SyntheticCorpus::builder()
            .vocab_size(self.vocab_size)
            .num_docs(self.num_docs)
            .embedding_dim(self.embedding_dim)
            .n_topics(self.n_topics)
            .tokens_per_doc(self.tokens_per_doc)
            .doc_length_skew(self.doc_length_skew)
            .num_queries(self.num_queries)
            .query_words(self.query_words_min, self.query_words_max)
            .seed(self.seed)
            .build()
    }
}

/// Top-level run configuration.
#[derive(Clone, Debug, Default)]
pub struct RunConfig {
    pub corpus: CorpusConfig,
    pub sinkhorn: SinkhornConfig,
    /// Worker threads (0 → all logical CPUs).
    pub threads: usize,
    /// Target-set shards for the query service (0 or 1 → one monolithic
    /// pool; `S ≥ 2` → S column slices, each with its own pool).
    pub shards: usize,
    /// Retrieval cascade for top-k queries: `[prune]`
    /// `cascade = "wcd,lcrwmd,sinkhorn"`, per-stage budgets as
    /// `name:budget` (e.g. `"wcd:2000,lcrwmd:500,sinkhorn:100"`).
    pub prune: CascadeSpec,
    /// Directory of AOT artifacts.
    pub artifacts_dir: String,
    /// Live-corpus compaction threshold (`[live] compact_segments`): fold
    /// the delta segments back into the base CSR once the view holds this
    /// many segments. `0` (default) disables background compaction.
    pub compact_segments: usize,
    /// Compactor poll interval in milliseconds (`[live]
    /// compact_interval_ms`); `0` (default) means the service default.
    pub compact_interval_ms: u64,
}

impl RunConfig {
    pub fn threads(&self) -> usize {
        if self.threads == 0 {
            crate::util::num_cpus()
        } else {
            self.threads
        }
    }

    /// Shard count for the service (`0` in the file means "unsharded").
    pub fn shards(&self) -> usize {
        self.shards.max(1)
    }

    /// Compactor poll interval (`0` in the file means the service
    /// default, 250 ms).
    pub fn compact_interval_ms(&self) -> u64 {
        if self.compact_interval_ms == 0 {
            250
        } else {
            self.compact_interval_ms
        }
    }

    /// Parse a TOML-subset file: `[section]` headers, `key = value` lines,
    /// `#` comments. Unknown keys are rejected (typo safety).
    pub fn from_file(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
        Self::from_str(&text)
    }

    pub fn from_str(text: &str) -> Result<Self, String> {
        let mut cfg = RunConfig {
            artifacts_dir: "artifacts".to_string(),
            ..Default::default()
        };
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            let value = value.trim().trim_matches('"');
            cfg.apply(&section, key, value)
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        }
        // Cross-key invariants the per-line parser cannot see: reject
        // configs the solver would panic on, with the offending key named.
        cfg.sinkhorn.validate()?;
        Ok(cfg)
    }

    fn apply(&mut self, section: &str, key: &str, value: &str) -> Result<(), String> {
        fn p<T: std::str::FromStr>(v: &str) -> Result<T, String> {
            v.parse().map_err(|_| format!("cannot parse '{v}'"))
        }
        match (section, key) {
            ("", "threads") => self.threads = p(value)?,
            ("", "shards") => self.shards = p(value)?,
            ("", "artifacts_dir") => self.artifacts_dir = value.to_string(),
            ("corpus", "vocab_size") => self.corpus.vocab_size = p(value)?,
            ("corpus", "num_docs") => self.corpus.num_docs = p(value)?,
            ("corpus", "embedding_dim") => self.corpus.embedding_dim = p(value)?,
            ("corpus", "n_topics") => self.corpus.n_topics = p(value)?,
            ("corpus", "tokens_per_doc") => self.corpus.tokens_per_doc = p(value)?,
            ("corpus", "num_queries") => self.corpus.num_queries = p(value)?,
            ("corpus", "query_words_min") => self.corpus.query_words_min = p(value)?,
            ("corpus", "query_words_max") => self.corpus.query_words_max = p(value)?,
            ("corpus", "seed") => self.corpus.seed = p(value)?,
            ("corpus", "doc_length_skew") => {
                let skew: f64 = p(value)?;
                if !(skew >= 0.0 && skew.is_finite()) {
                    return Err(format!(
                        "corpus.doc_length_skew must be non-negative and finite, got {skew} \
                         (0 keeps uniform document lengths)"
                    ));
                }
                self.corpus.doc_length_skew = skew;
            }
            ("sinkhorn", "lambda") => self.sinkhorn.lambda = p::<Real>(value)?,
            ("sinkhorn", "max_iter") => self.sinkhorn.max_iter = p(value)?,
            ("sinkhorn", "tolerance") => self.sinkhorn.tolerance = p::<Real>(value)?,
            ("sinkhorn", "check_every") => self.sinkhorn.check_every = p(value)?,
            ("sinkhorn", "compact_threshold") => {
                self.sinkhorn.compact_threshold = p::<Real>(value)?
            }
            ("sinkhorn", "compact_every") => self.sinkhorn.compact_every = p(value)?,
            ("sinkhorn", "kernel") => {
                self.sinkhorn.kernel = match value {
                    // Preserve an already-set precision when re-selecting
                    // the fused family (key order in the file must not
                    // matter).
                    "fused" => match self.sinkhorn.kernel {
                        IterateKernel::Fused { precision } => IterateKernel::Fused { precision },
                        IterateKernel::Unfused => {
                            IterateKernel::Fused { precision: Precision::default() }
                        }
                    },
                    "unfused" => match self.sinkhorn.kernel {
                        IterateKernel::Fused { precision } if precision != Precision::F64 => {
                            return Err(
                                "kernel 'unfused' has no mixed-precision mode".to_string()
                            )
                        }
                        _ => IterateKernel::Unfused,
                    },
                    "fused_atomic" | "fused_private" | "fused_transposed" => {
                        return Err(format!(
                            "kernel '{value}' was retired by the kernel-family \
                             consolidation; use \"fused\" (with precision = \"f64\" \
                             or \"mixed\") or \"unfused\""
                        ))
                    }
                    other => return Err(format!("unknown kernel '{other}'")),
                }
            }
            ("sinkhorn", "precision") => {
                let precision = match value {
                    "f64" => Precision::F64,
                    #[cfg(feature = "mixed-precision")]
                    "mixed" => Precision::Mixed,
                    #[cfg(not(feature = "mixed-precision"))]
                    "mixed" => {
                        return Err(
                            "precision 'mixed' requires building with the \
                             `mixed-precision` feature"
                                .to_string(),
                        )
                    }
                    other => return Err(format!("unknown precision '{other}'")),
                };
                self.sinkhorn.kernel = match self.sinkhorn.kernel {
                    IterateKernel::Fused { .. } => IterateKernel::Fused { precision },
                    IterateKernel::Unfused if precision == Precision::F64 => {
                        IterateKernel::Unfused
                    }
                    IterateKernel::Unfused => {
                        return Err("kernel 'unfused' has no mixed-precision mode".to_string())
                    }
                };
            }
            ("prune", "cascade") => self.prune = CascadeSpec::parse(value)?,
            ("live", "compact_segments") => self.compact_segments = p(value)?,
            ("live", "compact_interval_ms") => self.compact_interval_ms = p(value)?,
            (s, k) => return Err(format!("unknown key [{s}] {k}")),
        }
        Ok(())
    }

    /// Render back to the file format (used by `gen-config`).
    pub fn render(&self) -> String {
        let mut top = BTreeMap::new();
        top.insert("threads", self.threads.to_string());
        top.insert("shards", self.shards.to_string());
        top.insert("artifacts_dir", format!("\"{}\"", self.artifacts_dir));
        let (kernel, precision) = match self.sinkhorn.kernel {
            #[cfg(feature = "mixed-precision")]
            IterateKernel::Fused { precision: Precision::Mixed } => ("fused", "mixed"),
            IterateKernel::Fused { .. } => ("fused", "f64"),
            IterateKernel::Unfused => ("unfused", "f64"),
        };
        format!(
            "# sinkhorn-wmd run configuration\n\
             threads = {}\nshards = {}\nartifacts_dir = {}\n\n\
             [corpus]\nvocab_size = {}\nnum_docs = {}\nembedding_dim = {}\n\
             n_topics = {}\ntokens_per_doc = {}\ndoc_length_skew = {}\nnum_queries = {}\n\
             query_words_min = {}\nquery_words_max = {}\nseed = {}\n\n\
             [sinkhorn]\nlambda = {}\nmax_iter = {}\ntolerance = {}\n\
             check_every = {}\ncompact_threshold = {}\ncompact_every = {}\n\
             kernel = \"{}\"\nprecision = \"{}\"\n\n\
             [prune]\ncascade = \"{}\"\n\n\
             [live]\ncompact_segments = {}\ncompact_interval_ms = {}\n",
            top["threads"],
            top["shards"],
            top["artifacts_dir"],
            self.corpus.vocab_size,
            self.corpus.num_docs,
            self.corpus.embedding_dim,
            self.corpus.n_topics,
            self.corpus.tokens_per_doc,
            self.corpus.doc_length_skew,
            self.corpus.num_queries,
            self.corpus.query_words_min,
            self.corpus.query_words_max,
            self.corpus.seed,
            self.sinkhorn.lambda,
            self.sinkhorn.max_iter,
            self.sinkhorn.tolerance,
            self.sinkhorn.check_every,
            self.sinkhorn.compact_threshold,
            self.sinkhorn.compact_every,
            kernel,
            precision,
            self.prune.render(),
            self.compact_segments,
            self.compact_interval_ms,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let cfg = RunConfig {
            threads: 8,
            shards: 4,
            artifacts_dir: "artifacts".into(),
            corpus: CorpusConfig { vocab_size: 1234, ..Default::default() },
            sinkhorn: SinkhornConfig { lambda: 7.5, kernel: IterateKernel::Unfused, ..Default::default() },
            prune: CascadeSpec::parse("wcd:2000,lcrwmd:500,sinkhorn:100").unwrap(),
            compact_segments: 6,
            compact_interval_ms: 100,
        };
        let text = cfg.render();
        let back = RunConfig::from_str(&text).unwrap();
        assert_eq!(back.threads, 8);
        assert_eq!(back.shards, 4);
        assert_eq!(back.corpus.vocab_size, 1234);
        assert_eq!(back.sinkhorn.lambda, 7.5);
        assert_eq!(back.sinkhorn.kernel, IterateKernel::Unfused);
        assert_eq!(back.prune.render(), "wcd:2000,lcrwmd:500,sinkhorn:100");
        assert_eq!(back.compact_segments, 6);
        assert_eq!(back.compact_interval_ms, 100);
    }

    #[test]
    fn live_section_parses_and_defaults() {
        let cfg =
            RunConfig::from_str("[live]\ncompact_segments = 3\ncompact_interval_ms = 50\n")
                .unwrap();
        assert_eq!(cfg.compact_segments, 3);
        assert_eq!(cfg.compact_interval_ms(), 50);
        // Defaults: compaction off, interval falls back to the service's.
        let cfg = RunConfig::default();
        assert_eq!(cfg.compact_segments, 0);
        assert_eq!(cfg.compact_interval_ms, 0);
        assert_eq!(cfg.compact_interval_ms(), 250);
    }

    #[test]
    fn parses_prune_cascade_key() {
        let cfg = RunConfig::from_str("[prune]\ncascade = \"wcd,rwmd,sinkhorn\"\n").unwrap();
        assert_eq!(cfg.prune.render(), "wcd,rwmd,sinkhorn");
        assert_eq!(RunConfig::default().prune, CascadeSpec::default());
        let err = RunConfig::from_str("[prune]\ncascade = \"wcd\"\n").unwrap_err();
        assert!(err.contains("sinkhorn"), "{err}");
        let err = RunConfig::from_str("[prune]\nbogus = 1\n").unwrap_err();
        assert!(err.contains("unknown key"), "{err}");
    }

    #[test]
    fn rejects_unknown_keys() {
        assert!(RunConfig::from_str("nonsense = 3").is_err());
        assert!(RunConfig::from_str("[corpus]\nbogus = 3").is_err());
    }

    #[test]
    fn parses_kernel_and_precision() {
        let cfg = RunConfig::from_str("[sinkhorn]\nkernel = \"fused\"\nprecision = \"f64\"\n")
            .unwrap();
        assert_eq!(cfg.sinkhorn.kernel, IterateKernel::Fused { precision: Precision::F64 });
        let cfg = RunConfig::from_str("[sinkhorn]\nkernel = \"unfused\"\n").unwrap();
        assert_eq!(cfg.sinkhorn.kernel, IterateKernel::Unfused);
    }

    #[cfg(feature = "mixed-precision")]
    #[test]
    fn mixed_precision_roundtrips_and_is_key_order_independent() {
        let cfg = RunConfig {
            sinkhorn: SinkhornConfig {
                kernel: IterateKernel::Fused { precision: Precision::Mixed },
                ..Default::default()
            },
            ..Default::default()
        };
        let back = RunConfig::from_str(&cfg.render()).unwrap();
        assert_eq!(back.sinkhorn.kernel, IterateKernel::Fused { precision: Precision::Mixed });
        // precision before kernel must mean the same thing.
        let cfg = RunConfig::from_str("[sinkhorn]\nprecision = \"mixed\"\nkernel = \"fused\"\n")
            .unwrap();
        assert_eq!(cfg.sinkhorn.kernel, IterateKernel::Fused { precision: Precision::Mixed });
    }

    #[test]
    fn rejects_retired_and_unknown_kernel_names() {
        for name in ["fused_atomic", "fused_private", "fused_transposed"] {
            let err = RunConfig::from_str(&format!("[sinkhorn]\nkernel = \"{name}\"\n"))
                .unwrap_err();
            assert!(err.contains("retired"), "{err}");
        }
        let err = RunConfig::from_str("[sinkhorn]\nkernel = \"simd9000\"\n").unwrap_err();
        assert!(err.contains("unknown kernel"), "{err}");
    }

    #[test]
    fn rejects_unknown_precision_and_mixed_unfused() {
        let err = RunConfig::from_str("[sinkhorn]\nprecision = \"f16\"\n").unwrap_err();
        assert!(err.contains("unknown precision"), "{err}");
        #[cfg(feature = "mixed-precision")]
        {
            let err = RunConfig::from_str(
                "[sinkhorn]\nkernel = \"unfused\"\nprecision = \"mixed\"\n",
            )
            .unwrap_err();
            assert!(err.contains("no mixed-precision mode"), "{err}");
            let err = RunConfig::from_str(
                "[sinkhorn]\nprecision = \"mixed\"\nkernel = \"unfused\"\n",
            )
            .unwrap_err();
            assert!(err.contains("no mixed-precision mode"), "{err}");
        }
        #[cfg(not(feature = "mixed-precision"))]
        {
            let err = RunConfig::from_str("[sinkhorn]\nprecision = \"mixed\"\n").unwrap_err();
            assert!(err.contains("mixed-precision` feature"), "{err}");
        }
    }

    #[test]
    fn parses_and_roundtrips_convergence_keys() {
        let cfg = RunConfig::from_str(
            "[sinkhorn]\ncompact_threshold = 0.5\ncompact_every = 2\n\
             [corpus]\ndoc_length_skew = 1.5\n",
        )
        .unwrap();
        assert_eq!(cfg.sinkhorn.compact_threshold, 0.5);
        assert_eq!(cfg.sinkhorn.compact_every, 2);
        assert_eq!(cfg.corpus.doc_length_skew, 1.5);
        let back = RunConfig::from_str(&cfg.render()).unwrap();
        assert_eq!(back.sinkhorn.compact_threshold, 0.5);
        assert_eq!(back.sinkhorn.compact_every, 2);
        assert_eq!(back.corpus.doc_length_skew, 1.5);
        // compact_every = 0 is the exact-mode opt-out, legal in files too.
        let cfg = RunConfig::from_str("[sinkhorn]\ncompact_every = 0\n").unwrap();
        assert_eq!(cfg.sinkhorn.compact_every, 0);
    }

    #[test]
    fn rejects_invalid_sinkhorn_values_at_parse_time() {
        // The solver would panic on these; the parser must catch them
        // with the key named in the message instead.
        let err = RunConfig::from_str("[sinkhorn]\ncheck_every = 0\n").unwrap_err();
        assert!(err.contains("sinkhorn.check_every"), "{err}");
        let err = RunConfig::from_str("[sinkhorn]\ntolerance = -0.5\n").unwrap_err();
        assert!(err.contains("sinkhorn.tolerance"), "{err}");
        let err = RunConfig::from_str("[sinkhorn]\nmax_iter = 0\n").unwrap_err();
        assert!(err.contains("sinkhorn.max_iter"), "{err}");
        let err = RunConfig::from_str("[sinkhorn]\nlambda = 0\n").unwrap_err();
        assert!(err.contains("sinkhorn.lambda"), "{err}");
        let err = RunConfig::from_str("[sinkhorn]\ncompact_threshold = 1.5\n").unwrap_err();
        assert!(err.contains("sinkhorn.compact_threshold"), "{err}");
        let err = RunConfig::from_str("[corpus]\ndoc_length_skew = -1\n").unwrap_err();
        assert!(err.contains("doc_length_skew"), "{err}");
    }

    #[test]
    fn comments_and_blank_lines() {
        let cfg = RunConfig::from_str("# hi\n\nthreads = 4 # trailing\n").unwrap();
        assert_eq!(cfg.threads, 4);
    }

    #[test]
    fn threads_zero_means_all() {
        let cfg = RunConfig::default();
        assert!(cfg.threads() >= 1);
    }

    #[test]
    fn shards_zero_means_unsharded() {
        let cfg = RunConfig::default();
        assert_eq!(cfg.shards, 0);
        assert_eq!(cfg.shards(), 1);
        assert_eq!(RunConfig::from_str("shards = 3").unwrap().shards(), 3);
    }

    #[test]
    fn paper_scale_dimensions() {
        let c = CorpusConfig::paper_scale();
        assert_eq!(c.vocab_size, 100_000);
        assert_eq!(c.num_docs, 5_000);
        assert_eq!(c.embedding_dim, 300);
    }
}
