//! `sinkhorn-wmd` — CLI for the parallel Sinkhorn-Knopp WMD system.
//!
//! Subcommands:
//!   info          host specs (Table 3) + artifact manifest
//!   gen-corpus    build a synthetic corpus, print its statistics
//!   ingest        build a v2 snapshot from .vec embeddings + documents
//!   query         WMD of a sentence against the tiny real corpus
//!   solve         run queries on a corpus (synthetic or snapshot)
//!   evaluate      recall@k of the retrieval cascade vs the exact top-k
//!   serve-demo    drive the batched query service
//!   gen-config    print a default config file

use sinkhorn_wmd::cli::Args;
use sinkhorn_wmd::config::RunConfig;
use sinkhorn_wmd::coordinator::{
    Backend, DocStore, LiveDocStore, QueryRequest, ServiceConfig, WmdService,
};
use sinkhorn_wmd::corpus::{Corpus, DocFormat, DocReader, IngestBuilder, SparseVec, TinyCorpus};
use sinkhorn_wmd::parallel::Pool;
use sinkhorn_wmd::sinkhorn::{SinkhornConfig, SparseSolver};
use sinkhorn_wmd::bench::{SysInfo, Table};
use sinkhorn_wmd::prune::{evaluate_recall, queries_from_docs, CascadeSpec};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

/// Wall-clock seconds since the Unix epoch — the ingest timestamp the
/// live-corpus paths stamp on appended documents.
fn now_secs() -> i64 {
    std::time::SystemTime::now()
        .duration_since(std::time::SystemTime::UNIX_EPOCH)
        .map(|d| d.as_secs() as i64)
        .unwrap_or(0)
}

const USAGE: &str = "\
sinkhorn-wmd <subcommand> [options]

Subcommands:
  info                         host specs + loaded artifact manifest
  gen-corpus [--vocab N] [--docs N] [--dim N] [--seed S] [--out FILE]
  ingest --vec emb.vec --docs docs.txt --out corpus.wmdc [--jsonl]
                               build a v2 snapshot from real embeddings +
                               a document stream (one doc per line, or
                               JSONL {\"text\": ...})
  ingest --append snapshot.wmdc --docs new.txt --out corpus.wmdc
         [--jsonl] [--timestamp T]
                               append a document stream to an existing
                               snapshot as a new delta segment; writes a
                               v3 (live) snapshot with per-doc timestamps
  query --text \"...\"           WMD against the tiny real corpus
  solve [--threads P] [--queries K] [--vocab N] [--docs N]
        [--corpus FILE] [--text \"...\"]
  evaluate [--corpus FILE] [--k K] [--queries N] [--threads P]
           [--cascades \"spec;spec\"] [--require-recall X] [--json FILE]
                               recall@k + speedup of the bound cascade
                               (WCD -> LC-RWMD -> Sinkhorn) against the
                               exact top-k; writes a BENCH_prune.json row
  serve-demo [--threads P] [--shards S] [--requests K] [--prefer sparse|dense|pjrt]
             [--corpus FILE] [--text \"...\"] [--top-k K] [--window-secs S]
             [--stream docs.txt] [--stream-batch B] [--compact-segments M]
                               drive the batched query service; with
                               --stream, documents are appended live while
                               queries are answered (the tweet-firehose
                               scenario); --window-secs restricts --top-k
                               answers to recently ingested documents
  gen-config                   print a default run configuration

Common options:
  --config FILE                load a RunConfig file (TOML subset)
  --corpus FILE                load a WMDC snapshot (v1, v2 or v3) instead
                               of generating a synthetic corpus
  --text \"...\"                 raw-text query, histogrammed against the
                               snapshot's vocabulary (v2/v3 snapshots only)
";

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("info") => cmd_info(&args),
        Some("gen-corpus") => cmd_gen_corpus(&args),
        Some("ingest") => cmd_ingest(&args),
        Some("query") => cmd_query(&args),
        Some("solve") => cmd_solve(&args),
        Some("evaluate") => cmd_evaluate(&args),
        Some("serve-demo") => cmd_serve_demo(&args),
        Some("gen-config") => {
            println!("{}", RunConfig::default().render());
            Ok(())
        }
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn load_config(args: &Args) -> Result<RunConfig, String> {
    match args.get("config") {
        Some(path) => RunConfig::from_file(std::path::Path::new(path)),
        None => Ok(RunConfig { artifacts_dir: "artifacts".into(), ..Default::default() }),
    }
}

fn cmd_info(args: &Args) -> Result<(), String> {
    let cfg = load_config(args)?;
    println!("== Host (paper Table 3) ==");
    SysInfo::capture().table().print();
    println!();
    let dir = std::path::Path::new(&cfg.artifacts_dir);
    match sinkhorn_wmd::runtime::Manifest::read(dir) {
        Ok(m) => {
            println!("== AOT artifacts ({}) ==", cfg.artifacts_dir);
            let mut t = Table::new(["name", "variant", "v_r", "vocab", "n_docs", "dim", "pallas"]);
            for a in &m.artifacts {
                t.row([
                    a.name.clone(),
                    a.variant.clone(),
                    a.v_r.to_string(),
                    a.vocab.to_string(),
                    a.n_docs.to_string(),
                    a.dim.to_string(),
                    a.pallas.to_string(),
                ]);
            }
            t.print();
        }
        Err(e) => println!("(no artifacts: {e:#})"),
    }
    Ok(())
}

fn cmd_gen_corpus(args: &Args) -> Result<(), String> {
    let mut cfg = load_config(args)?;
    cfg.corpus.vocab_size = args.get_or("vocab", cfg.corpus.vocab_size)?;
    cfg.corpus.num_docs = args.get_or("docs", cfg.corpus.num_docs)?;
    cfg.corpus.embedding_dim = args.get_or("dim", cfg.corpus.embedding_dim)?;
    cfg.corpus.seed = args.get_or("seed", cfg.corpus.seed)?;
    let t0 = Instant::now();
    let corpus = cfg.corpus.build();
    if let Some(out) = args.get("out") {
        sinkhorn_wmd::corpus::io::save_corpus(std::path::Path::new(out), &corpus)
            .map_err(|e| format!("saving corpus: {e}"))?;
        println!("saved corpus to {out}");
    }
    println!(
        "built corpus in {:.2}s: V={} N={} w={} nnz(c)={} density={:.6}% mean-words/doc={:.1}",
        t0.elapsed().as_secs_f64(),
        corpus.vocab_size(),
        corpus.num_docs(),
        corpus.embeddings.ncols(),
        corpus.c.nnz(),
        corpus.density() * 100.0,
        corpus.mean_doc_words(),
    );
    for (i, q) in corpus.queries.iter().enumerate() {
        println!("  query {i}: v_r={}", q.nnz());
    }
    Ok(())
}

fn cmd_query(args: &Args) -> Result<(), String> {
    let text = args.get("text").ok_or("query requires --text \"...\"")?;
    let tiny = TinyCorpus::load();
    let query = tiny
        .histogram(text)
        .ok_or("no in-vocabulary words in the query (tiny corpus has ~48 words)")?;
    let store = DocStore::from_tiny(&tiny);
    let pool = Pool::new(args.get_or("threads", 2)?);
    let solver = SparseSolver::new(SinkhornConfig { lambda: 30.0, ..Default::default() });
    let out = solver.wmd_one_to_many(&store.embeddings, &query, &store.c, &pool);
    println!("query: {text:?}  (v_r={})", query.nnz());
    let mut t = Table::new(["rank", "wmd", "label", "sentence"]);
    for (rank, (j, d)) in out.top_k(store.num_docs()).into_iter().enumerate() {
        t.row([
            (rank + 1).to_string(),
            format!("{d:.4}"),
            store.labels[j].clone(),
            store.texts[j].clone(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_ingest(args: &Args) -> Result<(), String> {
    if let Some(snapshot) = args.get("append") {
        return cmd_ingest_append(args, snapshot);
    }
    let vec_path = args.get("vec").ok_or("ingest requires --vec emb.vec")?;
    let docs_path = args.get("docs").ok_or("ingest requires --docs docs.txt")?;
    let out = args.get("out").ok_or("ingest requires --out corpus.wmdc")?;
    let format = if args.flag("jsonl") {
        DocFormat::Jsonl
    } else {
        DocFormat::infer(Path::new(docs_path))
    };
    let t0 = Instant::now();
    let (corpus, stats) =
        sinkhorn_wmd::corpus::ingest_corpus(Path::new(vec_path), Path::new(docs_path), format)
            .map_err(|e| format!("ingest: {e}"))?;
    let built = t0.elapsed();
    sinkhorn_wmd::corpus::io::save_corpus_v2(Path::new(out), &corpus)
        .map_err(|e| format!("saving snapshot: {e}"))?;
    println!(
        "ingested {} docs in {:.2}s ({:?} mode): V={} w={} nnz(c)={} density={:.6}%",
        stats.docs,
        built.as_secs_f64(),
        format,
        corpus.vocab_size(),
        corpus.embeddings.ncols(),
        corpus.c.nnz(),
        corpus.density() * 100.0,
    );
    println!(
        "tokens: {} kept, {} out-of-vocabulary; {} empty document(s) (WMD = +inf columns)",
        stats.tokens_kept, stats.tokens_oov, stats.empty_docs
    );
    println!("saved v2 snapshot to {out}");
    Ok(())
}

/// `ingest --append`: stream new documents through the delta path of an
/// existing snapshot — histogrammed against the **persisted** vocabulary,
/// drained as one delta segment, concatenated after the existing columns
/// — and write the result as a v3 (live) snapshot. Existing documents
/// round-trip bit for bit; the new ones carry `--timestamp` (default:
/// now) for time-windowed retrieval.
fn cmd_ingest_append(args: &Args, snapshot: &str) -> Result<(), String> {
    let docs_path = args.get("docs").ok_or("ingest --append requires --docs docs.txt")?;
    let out = args.get("out").ok_or("ingest --append requires --out corpus.wmdc")?;
    let format = if args.flag("jsonl") {
        DocFormat::Jsonl
    } else {
        DocFormat::infer(Path::new(docs_path))
    };
    let t0 = Instant::now();
    let (corpus, meta) = sinkhorn_wmd::corpus::io::load_corpus_live(Path::new(snapshot))
        .map_err(|e| format!("loading snapshot: {e}"))?;
    if !corpus.has_words() {
        return Err("--append needs a snapshot with word strings (v2/v3); a v1 synthetic \
                    snapshot cannot histogram new text"
            .into());
    }
    if !corpus.doc_topics.is_empty() {
        return Err("--append does not support snapshots with per-document topic labels \
                    (appended documents have none)"
            .into());
    }
    let n = corpus.c.ncols();
    let mut live = meta.unwrap_or_else(|| sinkhorn_wmd::corpus::io::LiveMeta {
        segment_starts: vec![0],
        timestamps: vec![0; n],
        deleted: vec![],
    });
    let mut builder = IngestBuilder::new(corpus.vocab.clone(), corpus.embeddings.clone());
    let reader = DocReader::open_as(Path::new(docs_path), format)
        .map_err(|e| format!("opening documents: {e}"))?;
    for doc in reader {
        builder.push_text(&doc.map_err(|e| format!("reading documents: {e}"))?);
    }
    let stats = builder.stats();
    let delta = builder.drain_delta();
    let appended = delta.ncols();
    let ts = args.get_or("timestamp", now_secs())?;
    let corpus = if appended == 0 {
        corpus
    } else {
        live.segment_starts.push(n);
        live.timestamps.resize(n + appended, ts);
        let c = sinkhorn_wmd::sparse::Csr::concat_columns(&[&corpus.c, &delta]);
        Corpus { c, ..corpus }
    };
    sinkhorn_wmd::corpus::io::save_corpus_v3(Path::new(out), &corpus, &live)
        .map_err(|e| format!("saving snapshot: {e}"))?;
    println!(
        "appended {appended} docs in {:.2}s ({format:?} mode): {} -> {} docs, {} segment(s), \
         nnz(c)={}",
        t0.elapsed().as_secs_f64(),
        n,
        corpus.c.ncols(),
        live.segment_starts.len(),
        corpus.c.nnz(),
    );
    println!(
        "tokens: {} kept, {} out-of-vocabulary; {} empty document(s) (WMD = +inf columns)",
        stats.tokens_kept, stats.tokens_oov, stats.empty_docs
    );
    println!("saved v3 snapshot to {out} (timestamp {ts})");
    Ok(())
}

/// Resolve the query set for `solve`/`serve-demo`: `--text` histogrammed
/// against the corpus vocabulary when given, else the corpus's own
/// pre-built queries.
fn resolve_queries(corpus: &Corpus, args: &Args) -> Result<Vec<SparseVec>, String> {
    if let Some(text) = args.get("text") {
        return Ok(vec![corpus.text_query(text)?]);
    }
    if corpus.queries.is_empty() {
        return Err("corpus has no pre-built queries — pass --text \"...\"".into());
    }
    Ok(corpus.queries.clone())
}

fn cmd_solve(args: &Args) -> Result<(), String> {
    let mut cfg = load_config(args)?;
    cfg.corpus.vocab_size = args.get_or("vocab", cfg.corpus.vocab_size)?;
    cfg.corpus.num_docs = args.get_or("docs", cfg.corpus.num_docs)?;
    cfg.corpus.num_queries = args.get_or("queries", cfg.corpus.num_queries)?;
    let threads = args.get_or("threads", cfg.threads())?;
    let corpus = if let Some(path) = args.get("corpus") {
        println!("loading corpus from {path} ...");
        sinkhorn_wmd::corpus::io::load_corpus_any(Path::new(path))
            .map_err(|e| format!("loading corpus: {e}"))?
    } else {
        println!("building corpus V={} N={} ...", cfg.corpus.vocab_size, cfg.corpus.num_docs);
        cfg.corpus.build().into_corpus()
    };
    let queries = resolve_queries(&corpus, args)?;
    let pool = Pool::new(threads);
    let solver = SparseSolver::new(cfg.sinkhorn);
    println!(
        "solving {} queries on {} threads (λ={}, max_iter={})",
        queries.len(),
        threads,
        cfg.sinkhorn.lambda,
        cfg.sinkhorn.max_iter
    );
    let mut t = Table::new(["query", "v_r", "iters", "time", "best doc", "best wmd"]);
    for (i, q) in queries.iter().enumerate() {
        let t0 = Instant::now();
        let out = solver.wmd_one_to_many(&corpus.embeddings, q, &corpus.c, &pool);
        let dt = t0.elapsed();
        let (best_doc, best_wmd) = best_match_cells(&out);
        t.row([
            i.to_string(),
            q.nnz().to_string(),
            out.iterations.to_string(),
            format!("{:.1} ms", dt.as_secs_f64() * 1e3),
            best_doc,
            best_wmd,
        ]);
    }
    t.print();
    Ok(())
}

/// Table cells for a solve's best match. An all-pruned or all-non-finite
/// result (e.g. every target document empty) has no argmin — report
/// "no match" instead of aborting the CLI.
fn best_match_cells(out: &sinkhorn_wmd::sinkhorn::SolveOutput) -> (String, String) {
    match out.argmin() {
        Some(best) => (best.to_string(), format!("{:.4}", out.wmd[best])),
        None => ("-".to_string(), "no match".to_string()),
    }
}

/// `evaluate`: recall@k of budgeted cascades against the exact top-k
/// (the `"sinkhorn"`-only no-prune cascade), plus wall-clock speedup.
/// With `--require-recall X` every *unbounded* cascade must reach X —
/// the CI smoke gate (unbounded cascades are exact by construction, so
/// anything below 1.0 is a soundness bug, not a tuning issue).
fn cmd_evaluate(args: &Args) -> Result<(), String> {
    let cfg = load_config(args)?;
    let threads = args.get_or("threads", cfg.threads())?;
    let k = args.get_or("k", 10usize)?;
    if k == 0 {
        return Err("--k must be at least 1".into());
    }
    let corpus = if let Some(path) = args.get("corpus") {
        println!("loading corpus from {path} ...");
        sinkhorn_wmd::corpus::io::load_corpus_any(Path::new(path))
            .map_err(|e| format!("loading corpus: {e}"))?
    } else {
        println!("building corpus V={} N={} ...", cfg.corpus.vocab_size, cfg.corpus.num_docs);
        cfg.corpus.build().into_corpus()
    };
    let n = corpus.c.ncols();
    let queries = if corpus.queries.is_empty() {
        // Ingested snapshots ship no query set: sample documents as
        // queries (leave-one-in; the query's own document ranks first,
        // which cancels out since cascade and reference share it).
        queries_from_docs(&corpus.c, args.get_or("queries", 8usize)?)
    } else {
        corpus.queries.clone()
    };
    if queries.is_empty() {
        return Err("no queries: corpus has none and every document is empty".into());
    }
    let specs: Vec<CascadeSpec> = match args.get("cascades") {
        Some(list) => list
            .split(';')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(CascadeSpec::parse)
            .collect::<Result<_, _>>()?,
        None => {
            // The stock sweep: each bound tier alone-with-sinkhorn, the
            // full cascade, and one budgeted setting scaled to the corpus.
            let b_wcd = (n / 4).max(4 * k);
            let b_lc = (n / 10).max(2 * k);
            vec![
                CascadeSpec::parse("wcd,sinkhorn").unwrap(),
                CascadeSpec::parse("wcd,lcrwmd,sinkhorn").unwrap(),
                CascadeSpec::parse("wcd,lcrwmd,rwmd,sinkhorn").unwrap(),
                CascadeSpec::parse(&format!("wcd:{b_wcd},lcrwmd:{b_lc},sinkhorn")).unwrap(),
            ]
        }
    };
    let pool = Pool::new(threads);
    println!(
        "recall@{k}: {} queries x {} documents, {} cascades, {} threads",
        queries.len(),
        n,
        specs.len(),
        threads
    );
    let rows =
        evaluate_recall(&corpus.embeddings, &corpus.c, &queries, cfg.sinkhorn, k, &specs, &pool);
    let mut t = Table::new(["cascade", "recall", "speedup", "cascade ms", "exact ms", "evals"]);
    for r in &rows {
        t.row([
            r.spec.clone(),
            format!("{:.4}", r.recall),
            format!("{:.2}x", r.speedup),
            format!("{:.1}", r.cascade_ms),
            format!("{:.1}", r.exact_ms),
            format!("{}/{}", r.exact_evals, r.total_docs),
        ]);
    }
    t.print();
    let json_path = args
        .get("json")
        .map(PathBuf::from)
        .unwrap_or_else(sinkhorn_wmd::bench::prune_json_path);
    let entry = sinkhorn_wmd::prune::recall::rows_json(&rows);
    sinkhorn_wmd::bench::merge_bench_json(&json_path, "recall_at_k", entry)
        .map_err(|e| format!("writing {}: {e}", json_path.display()))?;
    println!("results merged into {}", json_path.display());
    if let Some(min) = args.get("require-recall") {
        let min: f64 = min.parse().map_err(|_| format!("bad --require-recall '{min}'"))?;
        let mut gated = 0;
        for (spec, r) in specs.iter().zip(&rows) {
            if spec.is_unbounded() {
                gated += 1;
                if r.recall < min {
                    return Err(format!(
                        "recall gate failed: `{}` reached {:.4} < {min}",
                        r.spec, r.recall
                    ));
                }
            }
        }
        if gated == 0 {
            return Err("--require-recall needs at least one unbounded cascade to gate".into());
        }
        println!("recall gate passed: {gated} unbounded cascade(s) at recall >= {min}");
    }
    Ok(())
}

fn cmd_serve_demo(args: &Args) -> Result<(), String> {
    let cfg = load_config(args)?;
    let threads = args.get_or("threads", cfg.threads())?;
    let shards = args.get_or("shards", cfg.shards())?;
    let prefer = match args.get("prefer").unwrap_or("sparse") {
        "sparse" => Backend::SparseRust,
        "dense" => Backend::DenseRust,
        "pjrt" => Backend::DensePjrt,
        other => return Err(format!("unknown backend '{other}'")),
    };
    let corpus = if let Some(path) = args.get("corpus") {
        println!("loading corpus from {path} ...");
        sinkhorn_wmd::corpus::io::load_corpus_any(Path::new(path))
            .map_err(|e| format!("loading corpus: {e}"))?
    } else {
        cfg.corpus.build().into_corpus()
    };
    let queries = resolve_queries(&corpus, args)?;
    // A raw-text query defaults to one request (the interactive case);
    // synthetic streams keep the old 20-request default.
    let default_requests = if args.get("text").is_some() { 1 } else { 20 };
    let requests = args.get_or("requests", default_requests)?;
    let top_k: usize = args.get_or("top-k", 0)?;
    let window_secs: i64 = args.get_or("window-secs", 0)?;
    let stream = args.get("stream").map(String::from);
    let store = DocStore::from_corpus(&corpus).into_arc();
    let labels = store.labels.clone();
    let pjrt_dir = (prefer == Backend::DensePjrt)
        .then(|| std::path::PathBuf::from(&cfg.artifacts_dir));
    // The service always runs over a live store (a static one is just a
    // live store nobody mutates — epoch 0 keeps every legacy code path).
    // Background compaction only makes sense when documents stream in.
    let compact_default = if stream.is_some() { 4 } else { cfg.compact_segments };
    let live = LiveDocStore::new(store).into_arc();
    let service = WmdService::start_live(
        Arc::clone(&live),
        ServiceConfig {
            threads,
            shards,
            sinkhorn: cfg.sinkhorn,
            prefer,
            cascade: cfg.prune.clone(),
            compact_segments: args.get_or("compact-segments", compact_default)?,
            compact_interval_ms: cfg.compact_interval_ms(),
            ..Default::default()
        },
        pjrt_dir,
    );
    if shards >= 2 {
        println!("sharded dispatch: {shards} target-set shards");
    }
    // The firehose: a feeder thread histograms the streamed documents
    // against the snapshot vocabulary and appends them in delta segments
    // while the main thread keeps submitting queries.
    let feeder = match &stream {
        Some(path) => {
            if !corpus.has_words() {
                return Err("--stream needs a snapshot with word strings (v2/v3) to \
                            histogram new documents"
                    .into());
            }
            let format = if args.flag("jsonl") {
                DocFormat::Jsonl
            } else {
                DocFormat::infer(Path::new(path))
            };
            let docs = DocReader::open_as(Path::new(path), format)
                .and_then(|r| r.collect::<std::io::Result<Vec<String>>>())
                .map_err(|e| format!("reading stream documents: {e}"))?;
            let batch = args.get_or("stream-batch", 64usize)?.max(1);
            let mut builder =
                IngestBuilder::new(corpus.vocab.clone(), corpus.embeddings.clone());
            let live = Arc::clone(&live);
            println!("streaming {} documents in batches of {batch} while serving ...", docs.len());
            Some(std::thread::spawn(move || {
                let mut appended = 0usize;
                for chunk in docs.chunks(batch) {
                    for d in chunk {
                        builder.push_text(d);
                    }
                    let delta = builder.drain_delta();
                    let k = delta.ncols();
                    live.append(delta, vec![now_secs(); k]);
                    appended += k;
                    // A trickle, not one bulk load: give query batches a
                    // chance to interleave with (and pin epochs between)
                    // the appends.
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                appended
            }))
        }
        None => None,
    };
    let make_request = |q: SparseVec| {
        if top_k > 0 {
            if window_secs > 0 {
                QueryRequest::top_k_since(q, top_k, now_secs() - window_secs)
            } else {
                QueryRequest::top_k(q, top_k)
            }
        } else {
            QueryRequest::new(q)
        }
    };
    println!("submitting {requests} requests ...");
    let t0 = Instant::now();
    let receivers: Vec<_> = (0..requests)
        .map(|i| service.submit(make_request(queries[i % queries.len()].clone())))
        .collect();
    let mut ok = 0;
    let mut first_response = None;
    for rx in receivers {
        match rx.recv() {
            Ok(resp) if resp.is_ok() => {
                ok += 1;
                first_response.get_or_insert(resp);
            }
            Ok(resp) => eprintln!("request failed: {}", resp.error.unwrap_or_default()),
            Err(_) => eprintln!("request dropped"),
        }
    }
    let wall = t0.elapsed();
    println!(
        "done: {ok}/{requests} ok in {:.2}s ({:.1} queries/s)",
        wall.as_secs_f64(),
        requests as f64 / wall.as_secs_f64()
    );
    if let Some(handle) = feeder {
        let appended = handle.join().map_err(|_| "stream feeder panicked".to_string())?;
        let s = live.stats();
        println!(
            "streamed {appended} documents; live store: epoch={} segments={} docs={} \
             compactions={}",
            s.epoch, s.segments, s.num_docs, s.compactions
        );
    }
    println!("metrics: {}", service.metrics().snapshot().report());
    // For a raw-text query, show the answer, not just throughput.
    if let (Some(text), Some(resp)) = (args.get("text"), first_response) {
        let ranked = if top_k > 0 {
            resp.top
        } else {
            let out = sinkhorn_wmd::sinkhorn::SolveOutput {
                wmd: resp.wmd,
                iterations: resp.iterations,
                converged: true,
                ..Default::default()
            };
            out.top_k(5)
        };
        println!("\nquery: {text:?}");
        let mut t = Table::new(["rank", "doc", "wmd", "label"]);
        for (rank, (j, d)) in ranked.into_iter().enumerate() {
            t.row([
                (rank + 1).to_string(),
                j.to_string(),
                format!("{d:.4}"),
                labels.get(j).cloned().unwrap_or_default(),
            ]);
        }
        t.print();
    }
    service.shutdown();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use sinkhorn_wmd::sinkhorn::SolveOutput;
    use sinkhorn_wmd::Real;

    #[test]
    fn no_match_when_every_distance_is_non_finite() {
        let out = SolveOutput {
            wmd: vec![Real::INFINITY, Real::NAN, Real::INFINITY],
            iterations: 4,
            ..Default::default()
        };
        assert_eq!(best_match_cells(&out), ("-".to_string(), "no match".to_string()));
        let out = SolveOutput::default();
        assert_eq!(best_match_cells(&out).1, "no match");
    }

    #[test]
    fn best_match_formats_finite_minimum() {
        let out = SolveOutput {
            wmd: vec![2.5, Real::INFINITY, 1.25],
            iterations: 4,
            converged: true,
            ..Default::default()
        };
        assert_eq!(best_match_cells(&out), ("2".to_string(), "1.2500".to_string()));
    }

    #[test]
    fn resolve_queries_prefers_text_and_errors_when_neither() {
        let tiny = TinyCorpus::load();
        let mut corpus = Corpus {
            embeddings: tiny.embeddings.clone(),
            vocab: tiny.vocab.clone(),
            word_topic: vec![],
            c: sinkhorn_wmd::corpus::docs_to_csr(tiny.vocab.len(), &tiny.docs),
            doc_topics: vec![],
            queries: vec![],
            query_topics: vec![],
        };
        let args = Args::parse(
            ["serve-demo", "--text", "obama speaks to the media"].map(String::from),
        )
        .unwrap();
        let qs = resolve_queries(&corpus, &args).unwrap();
        assert_eq!(qs.len(), 1);
        assert!(qs[0].nnz() >= 2);
        // No --text and no pre-built queries: a helpful error.
        let bare = Args::parse(["serve-demo"].map(String::from)).unwrap();
        assert!(resolve_queries(&corpus, &bare).is_err());
        // Pre-built queries flow through untouched.
        corpus.queries = vec![qs[0].clone()];
        assert_eq!(resolve_queries(&corpus, &bare).unwrap(), corpus.queries);
    }

    #[test]
    fn solve_invocation_parses_flags_and_positionals() {
        // The CLI surface cmd_solve sees: a declared boolean flag followed
        // by a positional must not lose the positional.
        let args = Args::parse(
            ["solve", "--threads", "2", "--verbose", "corpus.bin"].map(String::from),
        )
        .unwrap();
        assert_eq!(args.subcommand.as_deref(), Some("solve"));
        assert_eq!(args.get_or("threads", 0usize).unwrap(), 2);
        assert!(args.flag("verbose"));
        assert_eq!(args.positional(), &["corpus.bin".to_string()]);
    }
}
