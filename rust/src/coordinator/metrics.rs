//! Service metrics: lock-free counters + a log₂ latency histogram.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Number of log₂ latency buckets: bucket `i` covers `[2^i, 2^{i+1}) µs`.
const BUCKETS: usize = 32;

/// Buckets of the iterations-to-freeze histogram (mirrors
/// [`crate::sinkhorn::FreezeHistogram`]).
const FREEZE_BUCKETS: usize = 16;

/// An atomic running minimum whose "empty" state is `u64::MAX` (the
/// derive-friendly wrapper `fetch_min` needs — a plain `AtomicU64`
/// defaults to 0, which would absorb every later minimum).
#[derive(Debug)]
struct AtomicMin(AtomicU64);

impl Default for AtomicMin {
    fn default() -> Self {
        Self(AtomicU64::new(u64::MAX))
    }
}

impl AtomicMin {
    fn record(&self, v: u64) {
        self.0.fetch_min(v, Ordering::Relaxed);
    }

    /// The minimum seen so far, or `None` if nothing was recorded.
    fn load(&self) -> Option<u64> {
        match self.0.load(Ordering::Relaxed) {
            u64::MAX => None,
            v => Some(v),
        }
    }
}

/// Shared service metrics. All methods are thread-safe.
#[derive(Debug, Default)]
pub struct Metrics {
    queries: AtomicU64,
    batches: AtomicU64,
    errors: AtomicU64,
    total_latency_ns: AtomicU64,
    latency_hist: [AtomicU64; BUCKETS],
    backend_sparse: AtomicU64,
    backend_dense: AtomicU64,
    backend_pjrt: AtomicU64,
    prepare_cache_hits: AtomicU64,
    prepare_cache_misses: AtomicU64,
    batched_solves: AtomicU64,
    batched_queries: AtomicU64,
    kernel_fused_f64: AtomicU64,
    kernel_fused_mixed: AtomicU64,
    kernel_unfused: AtomicU64,
    sharded_solves: AtomicU64,
    shard_solves: AtomicU64,
    shard_iterations: AtomicU64,
    workspace_bytes: AtomicU64,
    workspace_checkouts: AtomicU64,
    workspace_grows: AtomicU64,
    cascade_queries: AtomicU64,
    cascade_wcd_in: AtomicU64,
    cascade_wcd_out: AtomicU64,
    cascade_lcrwmd_in: AtomicU64,
    cascade_lcrwmd_out: AtomicU64,
    cascade_rwmd_in: AtomicU64,
    cascade_rwmd_out: AtomicU64,
    cascade_sinkhorn_in: AtomicU64,
    cascade_sinkhorn_out: AtomicU64,
    pruned_solves: AtomicU64,
    conv_frozen_cols: AtomicU64,
    conv_compactions: AtomicU64,
    conv_nnz_traversed: AtomicU64,
    conv_nnz_full: AtomicU64,
    freeze_cols: AtomicU64,
    freeze_min: AtomicMin,
    freeze_max: AtomicU64,
    freeze_hist: [AtomicU64; FREEZE_BUCKETS],
    live_epoch: AtomicU64,
    live_segments: AtomicU64,
    live_docs: AtomicU64,
    live_deleted: AtomicU64,
    live_base_nnz: AtomicU64,
    live_delta_nnz: AtomicU64,
    live_compactions: AtomicU64,
    live_compaction_ms: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_query(&self, latency: Duration, backend: super::Backend) {
        self.queries.fetch_add(1, Ordering::Relaxed);
        let ns = latency.as_nanos() as u64;
        self.total_latency_ns.fetch_add(ns, Ordering::Relaxed);
        let us = (ns / 1_000).max(1);
        let bucket = (63 - us.leading_zeros() as usize).min(BUCKETS - 1);
        self.latency_hist[bucket].fetch_add(1, Ordering::Relaxed);
        match backend {
            super::Backend::SparseRust => &self.backend_sparse,
            super::Backend::DenseRust => &self.backend_dense,
            super::Backend::DensePjrt => &self.backend_pjrt,
        }
        .fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, _size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// One prepared-factor cache lookup on the serving path.
    pub fn record_prepare_cache(&self, hit: bool) {
        if hit {
            self.prepare_cache_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.prepare_cache_misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// One cross-query batched solve serving `size` (≥ 2) queries in a
    /// single fused pass over `c`.
    pub fn record_batched_solve(&self, size: usize) {
        self.batched_solves.fetch_add(1, Ordering::Relaxed);
        self.batched_queries.fetch_add(size as u64, Ordering::Relaxed);
    }

    /// `queries` sparse-backend queries solved under `kernel` — recorded
    /// once per sparse batch so the serving kernel/precision mix is
    /// visible in production.
    pub fn record_kernel_queries(&self, kernel: crate::sinkhorn::IterateKernel, queries: u64) {
        use crate::sinkhorn::{IterateKernel, Precision};
        match kernel {
            #[cfg(feature = "mixed-precision")]
            IterateKernel::Fused { precision: Precision::Mixed } => &self.kernel_fused_mixed,
            IterateKernel::Fused { precision: Precision::F64 } => &self.kernel_fused_f64,
            IterateKernel::Unfused => &self.kernel_unfused,
        }
        .fetch_add(queries, Ordering::Relaxed);
    }

    /// One sharded dispatch: `shards` per-shard solves answered a batch,
    /// executing `iterations` Sinkhorn iterations in total across all
    /// (shard, query) pairs — the per-shard counts folded together.
    pub fn record_sharded_solve(&self, shards: usize, iterations: u64) {
        self.sharded_solves.fetch_add(1, Ordering::Relaxed);
        self.shard_solves.fetch_add(shards as u64, Ordering::Relaxed);
        self.shard_iterations.fetch_add(iterations, Ordering::Relaxed);
    }

    /// Publish the current solve-workspace counters (gauges, not
    /// counters: the caller passes the aggregate over the dispatcher's
    /// own workspace and every shard worker's — see
    /// [`crate::sinkhorn::WorkspaceStats::merged`]).
    pub fn record_workspace(&self, stats: crate::sinkhorn::WorkspaceStats) {
        self.workspace_bytes.store(stats.bytes_retained as u64, Ordering::Relaxed);
        self.workspace_checkouts.store(stats.checkouts, Ordering::Relaxed);
        self.workspace_grows.store(stats.grows, Ordering::Relaxed);
    }

    /// One top-k retrieval through the bound cascade: fold the per-stage
    /// candidates-in/out counts and the exact solves the bounds avoided
    /// into the running totals. Sharded retrievals arrive pre-merged
    /// ([`crate::prune::merge_topk`] sums the shard-local stage stats).
    pub fn record_cascade(&self, stats: &crate::prune::PruneStats) {
        self.cascade_queries.fetch_add(1, Ordering::Relaxed);
        for s in &stats.stages {
            let (cin, cout) = match s.stage {
                "wcd" => (&self.cascade_wcd_in, &self.cascade_wcd_out),
                "lcrwmd" => (&self.cascade_lcrwmd_in, &self.cascade_lcrwmd_out),
                "rwmd" => (&self.cascade_rwmd_in, &self.cascade_rwmd_out),
                "sinkhorn" => (&self.cascade_sinkhorn_in, &self.cascade_sinkhorn_out),
                _ => continue,
            };
            cin.fetch_add(s.candidates_in as u64, Ordering::Relaxed);
            cout.fetch_add(s.candidates_out as u64, Ordering::Relaxed);
        }
        let pruned = stats.total_docs.saturating_sub(stats.exact_evals);
        self.pruned_solves.fetch_add(pruned as u64, Ordering::Relaxed);
    }

    /// Fold one solve's per-document convergence telemetry in: frozen
    /// columns, compactions and nnz traversal counters sum; the
    /// iterations-to-freeze histogram merges bucket-wise (min via
    /// `fetch_min`, max via `fetch_max`), so the serving-wide min/p50/max
    /// is exact over every solve recorded.
    pub fn record_convergence(&self, conv: &crate::sinkhorn::ConvergenceStats) {
        self.conv_frozen_cols.fetch_add(conv.frozen_columns as u64, Ordering::Relaxed);
        self.conv_compactions.fetch_add(conv.compactions as u64, Ordering::Relaxed);
        self.conv_nnz_traversed.fetch_add(conv.nnz_traversed, Ordering::Relaxed);
        self.conv_nnz_full.fetch_add(conv.nnz_full, Ordering::Relaxed);
        let h = &conv.freeze_iters;
        if h.count == 0 {
            return;
        }
        self.freeze_cols.fetch_add(h.count, Ordering::Relaxed);
        self.freeze_min.record(h.min as u64);
        self.freeze_max.fetch_max(h.max as u64, Ordering::Relaxed);
        for (slot, &k) in self.freeze_hist.iter().zip(&h.buckets) {
            if k > 0 {
                slot.fetch_add(k, Ordering::Relaxed);
            }
        }
    }

    /// Publish the live-store shape (gauges: last write wins — the
    /// dispatcher records the pinned view of every popped batch, so these
    /// track the store the answers were actually computed against).
    pub fn record_live(&self, stats: &super::LiveStoreStats) {
        self.live_epoch.store(stats.epoch, Ordering::Relaxed);
        self.live_segments.store(stats.segments as u64, Ordering::Relaxed);
        self.live_docs.store(stats.num_docs as u64, Ordering::Relaxed);
        self.live_deleted.store(stats.deleted as u64, Ordering::Relaxed);
        self.live_base_nnz.store(stats.base_nnz as u64, Ordering::Relaxed);
        self.live_delta_nnz.store(stats.delta_nnz as u64, Ordering::Relaxed);
        self.live_compactions.store(stats.compactions, Ordering::Relaxed);
        self.live_compaction_ms.store(stats.compaction_ms, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let queries = self.queries.load(Ordering::Relaxed);
        let hist: Vec<u64> = self.latency_hist.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        MetricsSnapshot {
            queries,
            batches: self.batches.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            mean_latency: if queries == 0 {
                Duration::ZERO
            } else {
                Duration::from_nanos(self.total_latency_ns.load(Ordering::Relaxed) / queries)
            },
            p50_latency: percentile_from_hist(&hist, 0.50),
            p95_latency: percentile_from_hist(&hist, 0.95),
            backend_sparse: self.backend_sparse.load(Ordering::Relaxed),
            backend_dense: self.backend_dense.load(Ordering::Relaxed),
            backend_pjrt: self.backend_pjrt.load(Ordering::Relaxed),
            prepare_cache_hits: self.prepare_cache_hits.load(Ordering::Relaxed),
            prepare_cache_misses: self.prepare_cache_misses.load(Ordering::Relaxed),
            batched_solves: self.batched_solves.load(Ordering::Relaxed),
            batched_queries: self.batched_queries.load(Ordering::Relaxed),
            kernel_fused_f64: self.kernel_fused_f64.load(Ordering::Relaxed),
            kernel_fused_mixed: self.kernel_fused_mixed.load(Ordering::Relaxed),
            kernel_unfused: self.kernel_unfused.load(Ordering::Relaxed),
            sharded_solves: self.sharded_solves.load(Ordering::Relaxed),
            shard_solves: self.shard_solves.load(Ordering::Relaxed),
            shard_iterations: self.shard_iterations.load(Ordering::Relaxed),
            workspace_bytes: self.workspace_bytes.load(Ordering::Relaxed),
            workspace_checkouts: self.workspace_checkouts.load(Ordering::Relaxed),
            workspace_grows: self.workspace_grows.load(Ordering::Relaxed),
            cascade_queries: self.cascade_queries.load(Ordering::Relaxed),
            cascade_wcd_in: self.cascade_wcd_in.load(Ordering::Relaxed),
            cascade_wcd_out: self.cascade_wcd_out.load(Ordering::Relaxed),
            cascade_lcrwmd_in: self.cascade_lcrwmd_in.load(Ordering::Relaxed),
            cascade_lcrwmd_out: self.cascade_lcrwmd_out.load(Ordering::Relaxed),
            cascade_rwmd_in: self.cascade_rwmd_in.load(Ordering::Relaxed),
            cascade_rwmd_out: self.cascade_rwmd_out.load(Ordering::Relaxed),
            cascade_sinkhorn_in: self.cascade_sinkhorn_in.load(Ordering::Relaxed),
            cascade_sinkhorn_out: self.cascade_sinkhorn_out.load(Ordering::Relaxed),
            pruned_solves: self.pruned_solves.load(Ordering::Relaxed),
            conv_frozen_cols: self.conv_frozen_cols.load(Ordering::Relaxed),
            conv_compactions: self.conv_compactions.load(Ordering::Relaxed),
            conv_nnz_traversed: self.conv_nnz_traversed.load(Ordering::Relaxed),
            conv_nnz_full: self.conv_nnz_full.load(Ordering::Relaxed),
            freeze_iters: {
                // Reassemble the serving-wide histogram so p50 comes from
                // the same bucket logic the per-solve stats use.
                let mut h = crate::sinkhorn::FreezeHistogram {
                    count: self.freeze_cols.load(Ordering::Relaxed),
                    min: self.freeze_min.load().map_or(u32::MAX, |v| v.min(u32::MAX as u64) as u32),
                    max: self.freeze_max.load(Ordering::Relaxed).min(u32::MAX as u64) as u32,
                    buckets: [0; FREEZE_BUCKETS],
                };
                for (dst, src) in h.buckets.iter_mut().zip(&self.freeze_hist) {
                    *dst = src.load(Ordering::Relaxed);
                }
                h
            },
            live_epoch: self.live_epoch.load(Ordering::Relaxed),
            live_segments: self.live_segments.load(Ordering::Relaxed),
            live_docs: self.live_docs.load(Ordering::Relaxed),
            live_deleted: self.live_deleted.load(Ordering::Relaxed),
            live_base_nnz: self.live_base_nnz.load(Ordering::Relaxed),
            live_delta_nnz: self.live_delta_nnz.load(Ordering::Relaxed),
            live_compactions: self.live_compactions.load(Ordering::Relaxed),
            live_compaction_ms: self.live_compaction_ms.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time view of the metrics.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricsSnapshot {
    pub queries: u64,
    pub batches: u64,
    pub errors: u64,
    pub mean_latency: Duration,
    /// Bucketed percentile (upper bound of the log₂ bucket).
    pub p50_latency: Duration,
    pub p95_latency: Duration,
    pub backend_sparse: u64,
    pub backend_dense: u64,
    pub backend_pjrt: u64,
    /// Prepared-factor cache lookups that reused cached `dist` factors.
    pub prepare_cache_hits: u64,
    /// Lookups that ran `precompute_factors` (plus uncached solves: 0/0
    /// when the cache is disabled).
    pub prepare_cache_misses: u64,
    /// Cross-query batched solves executed (each serving ≥ 2 queries in
    /// one fused pass over `c`).
    pub batched_solves: u64,
    /// Queries answered through a batched solve.
    pub batched_queries: u64,
    /// Sparse-backend queries solved per iterate kernel/precision
    /// (`kernel = "fused"` with `precision = "f64"` / `"mixed"`, or the
    /// `"unfused"` ablation baseline).
    pub kernel_fused_f64: u64,
    pub kernel_fused_mixed: u64,
    pub kernel_unfused: u64,
    /// Batches dispatched through the sharded (multi-pool) path.
    pub sharded_solves: u64,
    /// Per-shard solves executed (`sharded_solves × S` with a fixed
    /// shard count).
    pub shard_solves: u64,
    /// Sinkhorn iterations summed over every (shard, query) pair of the
    /// sharded dispatches — the per-shard iteration counts folded in.
    pub shard_iterations: u64,
    /// Heap bytes retained by the solve workspaces (dispatcher + every
    /// shard worker) — the arena the zero-alloc hot path reuses.
    pub workspace_bytes: u64,
    /// Solves that checked a workspace out.
    pub workspace_checkouts: u64,
    /// Checkouts that had to grow a buffer. Flat in steady state; a
    /// climbing value means the serving shapes keep exceeding what the
    /// workspaces have seen (reuse is not kicking in).
    pub workspace_grows: u64,
    /// Top-k queries answered through the retrieval cascade.
    pub cascade_queries: u64,
    /// Per-stage candidates in/out, summed over every cascade query (and
    /// over shards for sharded retrievals). `in − out` is what the stage
    /// pruned.
    pub cascade_wcd_in: u64,
    pub cascade_wcd_out: u64,
    pub cascade_lcrwmd_in: u64,
    pub cascade_lcrwmd_out: u64,
    pub cascade_rwmd_in: u64,
    pub cascade_rwmd_out: u64,
    pub cascade_sinkhorn_in: u64,
    pub cascade_sinkhorn_out: u64,
    /// Exact Sinkhorn sub-solves the cascade's bounds avoided
    /// (`total_docs − exact_evals`, summed over cascade queries).
    pub pruned_solves: u64,
    /// Target columns frozen by per-document convergence, summed over
    /// every sparse solve recorded.
    pub conv_frozen_cols: u64,
    /// Active-set traversal compactions performed.
    pub conv_compactions: u64,
    /// Pattern entries actually walked by the iterate (what compaction
    /// shrinks) vs what the full traversal would have cost.
    pub conv_nnz_traversed: u64,
    pub conv_nnz_full: u64,
    /// Serving-wide iterations-to-freeze distribution (exact min/max;
    /// p50 at power-of-two bucket resolution).
    pub freeze_iters: crate::sinkhorn::FreezeHistogram,
    /// Live-store gauges, as of the last batch the dispatcher pinned:
    /// the epoch, segment count, document count (appended docs included)
    /// and tombstone count of the serving view.
    pub live_epoch: u64,
    pub live_segments: u64,
    pub live_docs: u64,
    pub live_deleted: u64,
    /// Non-zeros in the base segment vs in the delta segments — the
    /// delta share is the fraction of the target set compaction would
    /// fold back into the base.
    pub live_base_nnz: u64,
    pub live_delta_nnz: u64,
    /// Background compactions completed, and the milliseconds they took
    /// in total (off the query path).
    pub live_compactions: u64,
    pub live_compaction_ms: u64,
}

fn percentile_from_hist(hist: &[u64], q: f64) -> Duration {
    let total: u64 = hist.iter().sum();
    if total == 0 {
        return Duration::ZERO;
    }
    let target = (q * total as f64).ceil() as u64;
    let mut acc = 0;
    for (i, &count) in hist.iter().enumerate() {
        acc += count;
        if acc >= target {
            return Duration::from_micros(1u64 << (i + 1));
        }
    }
    Duration::from_micros(1u64 << hist.len())
}

impl MetricsSnapshot {
    pub fn report(&self) -> String {
        // Min reads 0 (not the u32::MAX sentinel) while nothing froze.
        let freeze_min = if self.freeze_iters.count == 0 { 0 } else { self.freeze_iters.min };
        let freeze_p50 = self.freeze_iters.p50().unwrap_or(0);
        format!(
            "queries={} batches={} errors={} mean={:?} p50≤{:?} p95≤{:?} \
             backends: sparse={} dense={} pjrt={} prep-cache: hits={} misses={} \
             batched: solves={} queries={} \
             kernels: fused-f64={} fused-mixed={} unfused={} \
             sharded: batches={} shard-solves={} shard-iters={} \
             workspace: bytes={} checkouts={} grows={} \
             cascade: queries={} wcd={}/{} lcrwmd={}/{} rwmd={}/{} sinkhorn={}/{} \
             pruned-solves={} \
             convergence: frozen-cols={} compactions={} nnz-traversed={} nnz-full={} \
             freeze-iters: min={} p50≤{} max={} \
             live: epoch={} segments={} docs={} deleted={} delta-nnz={}/{} \
             compactions={} compaction-ms={}",
            self.queries,
            self.batches,
            self.errors,
            self.mean_latency,
            self.p50_latency,
            self.p95_latency,
            self.backend_sparse,
            self.backend_dense,
            self.backend_pjrt,
            self.prepare_cache_hits,
            self.prepare_cache_misses,
            self.batched_solves,
            self.batched_queries,
            self.kernel_fused_f64,
            self.kernel_fused_mixed,
            self.kernel_unfused,
            self.sharded_solves,
            self.shard_solves,
            self.shard_iterations,
            self.workspace_bytes,
            self.workspace_checkouts,
            self.workspace_grows,
            self.cascade_queries,
            self.cascade_wcd_in,
            self.cascade_wcd_out,
            self.cascade_lcrwmd_in,
            self.cascade_lcrwmd_out,
            self.cascade_rwmd_in,
            self.cascade_rwmd_out,
            self.cascade_sinkhorn_in,
            self.cascade_sinkhorn_out,
            self.pruned_solves,
            self.conv_frozen_cols,
            self.conv_compactions,
            self.conv_nnz_traversed,
            self.conv_nnz_full,
            freeze_min,
            freeze_p50,
            self.freeze_iters.max,
            self.live_epoch,
            self.live_segments,
            self.live_docs,
            self.live_deleted,
            self.live_delta_nnz,
            self.live_base_nnz + self.live_delta_nnz,
            self.live_compactions,
            self.live_compaction_ms
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Backend;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_query(Duration::from_micros(100), Backend::SparseRust);
        m.record_query(Duration::from_micros(200), Backend::SparseRust);
        m.record_query(Duration::from_millis(5), Backend::DensePjrt);
        m.record_batch(3);
        let s = m.snapshot();
        assert_eq!(s.queries, 3);
        assert_eq!(s.batches, 1);
        assert_eq!(s.backend_sparse, 2);
        assert_eq!(s.backend_pjrt, 1);
        assert!(s.mean_latency >= Duration::from_micros(100));
        assert!(s.p95_latency >= s.p50_latency);
    }

    #[test]
    fn concurrent_recording() {
        let m = std::sync::Arc::new(Metrics::new());
        let mut handles = vec![];
        for _ in 0..4 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    m.record_query(Duration::from_micros(50), Backend::SparseRust);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.snapshot().queries, 4000);
    }

    #[test]
    fn empty_snapshot() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.queries, 0);
        assert_eq!(s.mean_latency, Duration::ZERO);
        assert_eq!(s.p50_latency, Duration::ZERO);
        assert_eq!(s.prepare_cache_hits, 0);
        assert_eq!(s.prepare_cache_misses, 0);
    }

    #[test]
    fn batched_solve_counters() {
        let m = Metrics::new();
        m.record_batched_solve(4);
        m.record_batched_solve(2);
        let s = m.snapshot();
        assert_eq!(s.batched_solves, 2);
        assert_eq!(s.batched_queries, 6);
        assert!(s.report().contains("batched: solves=2 queries=6"));
    }

    #[test]
    fn kernel_query_counters() {
        use crate::sinkhorn::{IterateKernel, Precision};
        let m = Metrics::new();
        m.record_kernel_queries(IterateKernel::Fused { precision: Precision::F64 }, 3);
        m.record_kernel_queries(IterateKernel::Unfused, 1);
        m.record_kernel_queries(IterateKernel::Fused { precision: Precision::F64 }, 2);
        let s = m.snapshot();
        assert_eq!(s.kernel_fused_f64, 5);
        assert_eq!(s.kernel_unfused, 1);
        assert_eq!(s.kernel_fused_mixed, 0);
        assert!(s.report().contains("kernels: fused-f64=5 fused-mixed=0 unfused=1"));
        #[cfg(feature = "mixed-precision")]
        {
            m.record_kernel_queries(IterateKernel::Fused { precision: Precision::Mixed }, 4);
            assert_eq!(m.snapshot().kernel_fused_mixed, 4);
        }
    }

    #[test]
    fn sharded_solve_counters() {
        let m = Metrics::new();
        m.record_sharded_solve(4, 128);
        m.record_sharded_solve(4, 64);
        let s = m.snapshot();
        assert_eq!(s.sharded_solves, 2);
        assert_eq!(s.shard_solves, 8);
        assert_eq!(s.shard_iterations, 192);
        assert!(s.report().contains("sharded: batches=2 shard-solves=8 shard-iters=192"));
    }

    #[test]
    fn workspace_gauges_reflect_last_record() {
        use crate::sinkhorn::WorkspaceStats;
        let m = Metrics::new();
        m.record_workspace(WorkspaceStats { bytes_retained: 4096, checkouts: 7, grows: 2 });
        m.record_workspace(WorkspaceStats { bytes_retained: 8192, checkouts: 9, grows: 2 });
        let s = m.snapshot();
        assert_eq!(s.workspace_bytes, 8192, "gauge: last write wins");
        assert_eq!(s.workspace_checkouts, 9);
        assert_eq!(s.workspace_grows, 2);
        assert!(s.report().contains("workspace: bytes=8192 checkouts=9 grows=2"));
    }

    #[test]
    fn cascade_counters_fold_per_stage_stats() {
        use crate::prune::{PruneStats, StageStats};
        let m = Metrics::new();
        let stats = PruneStats {
            total_docs: 100,
            exact_evals: 12,
            pruned_by_bound: 88,
            stages: vec![
                StageStats { stage: "wcd", candidates_in: 100, candidates_out: 40 },
                StageStats { stage: "lcrwmd", candidates_in: 40, candidates_out: 40 },
                StageStats { stage: "sinkhorn", candidates_in: 40, candidates_out: 12 },
            ],
        };
        m.record_cascade(&stats);
        m.record_cascade(&stats);
        let s = m.snapshot();
        assert_eq!(s.cascade_queries, 2);
        assert_eq!(s.cascade_wcd_in, 200);
        assert_eq!(s.cascade_wcd_out, 80);
        assert_eq!(s.cascade_lcrwmd_in, 80);
        assert_eq!(s.cascade_sinkhorn_out, 24);
        assert_eq!(s.cascade_rwmd_in, 0, "no rwmd stage ran");
        assert_eq!(s.pruned_solves, 176);
        assert!(s
            .report()
            .contains("cascade: queries=2 wcd=200/80 lcrwmd=80/80 rwmd=0/0 sinkhorn=80/24"));
        assert!(s.report().contains("pruned-solves=176"));
    }

    #[test]
    fn convergence_counters_fold_solve_stats() {
        use crate::sinkhorn::{ConvergenceStats, FreezeHistogram};
        let m = Metrics::new();
        let mut h1 = FreezeHistogram::default();
        h1.record(4);
        h1.record(9);
        let mut h2 = FreezeHistogram::default();
        h2.record(2);
        m.record_convergence(&ConvergenceStats {
            frozen_columns: 10,
            compactions: 2,
            nnz_traversed: 700,
            nnz_full: 1000,
            freeze_iters: h1,
        });
        m.record_convergence(&ConvergenceStats {
            frozen_columns: 5,
            compactions: 0,
            nnz_traversed: 300,
            nnz_full: 400,
            freeze_iters: h2,
        });
        let s = m.snapshot();
        assert_eq!(s.conv_frozen_cols, 15);
        assert_eq!(s.conv_compactions, 2);
        assert_eq!(s.conv_nnz_traversed, 1000);
        assert_eq!(s.conv_nnz_full, 1400);
        assert_eq!(s.freeze_iters.count, 3);
        assert_eq!(s.freeze_iters.min, 2);
        assert_eq!(s.freeze_iters.max, 9);
        assert!(s.report().contains(
            "convergence: frozen-cols=15 compactions=2 nnz-traversed=1000 nnz-full=1400"
        ));
        assert!(s.report().contains("freeze-iters: min=2"));
        assert!(s.report().contains("max=9"));
    }

    #[test]
    fn convergence_min_reads_zero_before_any_freeze() {
        use crate::sinkhorn::ConvergenceStats;
        let m = Metrics::new();
        // Exact-mode solves carry an empty histogram — min must stay the
        // sentinel internally and read 0 in the report.
        m.record_convergence(&ConvergenceStats {
            nnz_traversed: 10,
            nnz_full: 10,
            ..Default::default()
        });
        let s = m.snapshot();
        assert_eq!(s.freeze_iters.count, 0);
        assert!(s.report().contains("freeze-iters: min=0 p50≤0 max=0"));
    }

    #[test]
    fn live_store_gauges_reflect_last_record() {
        use crate::coordinator::LiveStoreStats;
        let m = Metrics::new();
        m.record_live(&LiveStoreStats {
            epoch: 3,
            segments: 2,
            num_docs: 45,
            deleted: 1,
            base_nnz: 900,
            delta_nnz: 100,
            compactions: 0,
            compaction_ms: 0,
        });
        m.record_live(&LiveStoreStats {
            epoch: 4,
            segments: 1,
            num_docs: 45,
            deleted: 1,
            base_nnz: 980,
            delta_nnz: 0,
            compactions: 1,
            compaction_ms: 7,
        });
        let s = m.snapshot();
        assert_eq!(s.live_epoch, 4, "gauge: last write wins");
        assert_eq!(s.live_segments, 1);
        assert_eq!(s.live_docs, 45);
        assert_eq!(s.live_deleted, 1);
        assert_eq!(s.live_base_nnz, 980);
        assert_eq!(s.live_delta_nnz, 0);
        assert_eq!(s.live_compactions, 1);
        assert!(s.report().contains(
            "live: epoch=4 segments=1 docs=45 deleted=1 delta-nnz=0/980 \
             compactions=1 compaction-ms=7"
        ));
    }

    #[test]
    fn prepare_cache_counters() {
        let m = Metrics::new();
        m.record_prepare_cache(false);
        m.record_prepare_cache(true);
        m.record_prepare_cache(true);
        let s = m.snapshot();
        assert_eq!(s.prepare_cache_hits, 2);
        assert_eq!(s.prepare_cache_misses, 1);
        assert!(s.report().contains("hits=2"));
    }
}
