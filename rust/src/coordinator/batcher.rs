//! Dynamic batching queue: requests accumulate until the batch is full or
//! the oldest request has waited `max_wait` — the standard serving-system
//! trade-off between throughput (amortized pool scheduling) and latency.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Flush as soon as this many requests are queued.
    pub max_batch: usize,
    /// Flush once the oldest queued request is this old.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

struct Inner<T> {
    queue: VecDeque<(T, Instant)>,
    closed: bool,
}

/// MPSC batch queue: many producers `push`, one consumer `next_batch`.
pub struct BatchQueue<T> {
    config: BatcherConfig,
    inner: Mutex<Inner<T>>,
    cv: Condvar,
}

impl<T> BatchQueue<T> {
    pub fn new(config: BatcherConfig) -> Self {
        assert!(config.max_batch >= 1);
        Self {
            config,
            inner: Mutex::new(Inner { queue: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a request. Returns `false` if the queue is closed.
    pub fn push(&self, item: T) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return false;
        }
        inner.queue.push_back((item, Instant::now()));
        self.cv.notify_all();
        true
    }

    /// Dequeue the next batch. Blocks until at least one item is available
    /// and the flush condition holds. Returns `None` once the queue is
    /// closed *and* drained.
    pub fn next_batch(&self) -> Option<Vec<T>> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if !inner.queue.is_empty() {
                let oldest = inner.queue.front().unwrap().1;
                let full = inner.queue.len() >= self.config.max_batch;
                let waited = oldest.elapsed();
                if full || waited >= self.config.max_wait || inner.closed {
                    let take = inner.queue.len().min(self.config.max_batch);
                    let batch: Vec<T> =
                        inner.queue.drain(..take).map(|(item, _)| item).collect();
                    return Some(batch);
                }
                // Wait out the remaining window (or a new push).
                let remaining = self.config.max_wait - waited;
                let (guard, _) = self.cv.wait_timeout(inner, remaining).unwrap();
                inner = guard;
            } else if inner.closed {
                return None;
            } else {
                inner = self.cv.wait(inner).unwrap();
            }
        }
    }

    /// Close the queue; producers fail fast, the consumer drains then
    /// receives `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn flushes_on_full_batch() {
        let q = BatchQueue::new(BatcherConfig { max_batch: 3, max_wait: Duration::from_secs(60) });
        for i in 0..3 {
            assert!(q.push(i));
        }
        let batch = q.next_batch().unwrap();
        assert_eq!(batch, vec![0, 1, 2]);
    }

    #[test]
    fn flushes_on_deadline() {
        // A single queued item — far below max_batch — must still come out
        // once its wait window lapses, in arrival order and fully drained.
        //
        // The old version started its stopwatch AFTER the push and asserted
        // `elapsed >= window - 1ms`: any descheduling between push and
        // stopwatch start shrinks the measured wait below the queue's real
        // (push-anchored) deadline, so the test flaked under CI load. The
        // stopwatch now starts BEFORE the push: the flush fires no earlier
        // than push + window ≥ start + window, a lower bound scheduling
        // delays can only lengthen — this still catches an early-flush
        // regression (next_batch ignoring max_wait) without the flake.
        let window = Duration::from_millis(5);
        let q = BatchQueue::new(BatcherConfig { max_batch: 100, max_wait: window });
        let t = Instant::now();
        q.push(42);
        q.push(43);
        let batch = q.next_batch().unwrap();
        assert!(t.elapsed() >= window, "flushed before the wait window");
        assert_eq!(batch, vec![42, 43], "deadline flush must preserve arrival order");
        assert!(q.is_empty(), "deadline flush must drain everything queued");
    }

    #[test]
    fn close_while_consumer_waits_flushes_immediately() {
        // The consumer sits inside the deadline wait (60 s window); a
        // close from another thread must wake it and hand over the partial
        // batch at once — the test would time out otherwise.
        let q = Arc::new(BatchQueue::new(BatcherConfig {
            max_batch: 100,
            max_wait: Duration::from_secs(60),
        }));
        q.push(7);
        let closer = {
            let q = q.clone();
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(20));
                q.close();
            })
        };
        assert_eq!(q.next_batch().unwrap(), vec![7]);
        assert!(q.next_batch().is_none(), "closed and drained → None");
        closer.join().unwrap();
    }

    #[test]
    fn close_drains_then_none() {
        let q = BatchQueue::new(BatcherConfig { max_batch: 10, max_wait: Duration::from_secs(60) });
        q.push(1);
        q.push(2);
        q.close();
        assert!(!q.push(3));
        assert_eq!(q.next_batch().unwrap(), vec![1, 2]);
        assert!(q.next_batch().is_none());
    }

    #[test]
    fn concurrent_producers_all_delivered() {
        let q = Arc::new(BatchQueue::new(BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(1),
        }));
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..100 {
                        assert!(q.push(p * 1000 + i));
                    }
                })
            })
            .collect();
        let consumer = {
            let q = q.clone();
            std::thread::spawn(move || {
                let mut seen = Vec::new();
                while let Some(batch) = q.next_batch() {
                    assert!(batch.len() <= 16);
                    seen.extend(batch);
                    if seen.len() == 400 {
                        break;
                    }
                }
                seen
            })
        };
        for p in producers {
            p.join().unwrap();
        }
        let mut seen = consumer.join().unwrap();
        seen.sort_unstable();
        assert_eq!(seen.len(), 400);
        seen.dedup();
        assert_eq!(seen.len(), 400, "duplicates delivered");
    }

    #[test]
    fn batches_never_exceed_max() {
        let q = BatchQueue::new(BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) });
        for i in 0..10 {
            q.push(i);
        }
        q.close();
        let mut total = 0;
        while let Some(b) = q.next_batch() {
            assert!(b.len() <= 4);
            total += b.len();
        }
        assert_eq!(total, 10);
    }
}
