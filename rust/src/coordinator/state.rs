//! Coordinator state: the immutable document store shared by every
//! worker — embeddings + the `V × N` target matrix + optional metadata —
//! and the per-dispatcher [`PreparedCache`] of `dist`-layer query factors.

use crate::corpus::{Corpus, SparseVec, SyntheticCorpus, TinyCorpus, Vocabulary};
use crate::sinkhorn::Prepared;
use crate::sparse::{Csr, Dense};
use crate::Real;
use std::sync::Arc;

/// The target-set state loaded once at startup and shared (`Arc`) across
/// the service, benches and examples.
#[derive(Clone, Debug)]
pub struct DocStore {
    pub embeddings: Dense,
    pub c: Csr,
    /// Word strings aligned with the embedding rows, when known (ingested
    /// corpora): enables raw-text queries via [`DocStore::text_query`].
    pub vocab: Option<Vocabulary>,
    /// Optional human-readable text per target document.
    pub texts: Vec<String>,
    /// Optional label per target document (classification examples).
    pub labels: Vec<String>,
}

impl DocStore {
    pub fn new(embeddings: Dense, c: Csr) -> Self {
        assert_eq!(embeddings.nrows(), c.nrows(), "embeddings/c vocab mismatch");
        Self { embeddings, c, vocab: None, texts: Vec::new(), labels: Vec::new() }
    }

    pub fn with_texts(mut self, texts: Vec<String>) -> Self {
        assert_eq!(texts.len(), self.c.ncols());
        self.texts = texts;
        self
    }

    pub fn with_labels(mut self, labels: Vec<String>) -> Self {
        assert_eq!(labels.len(), self.c.ncols());
        self.labels = labels;
        self
    }

    pub fn with_vocab(mut self, vocab: Vocabulary) -> Self {
        assert_eq!(vocab.len(), self.c.nrows(), "vocabulary/c vocab mismatch");
        self.vocab = Some(vocab);
        self
    }

    pub fn from_synthetic(corpus: &SyntheticCorpus) -> Self {
        Self::new(corpus.embeddings.clone(), corpus.c.clone())
            .with_labels(corpus.doc_topics.iter().map(|t| format!("topic-{t}")).collect())
    }

    /// Build from a generic corpus (ingested or any loaded snapshot):
    /// keeps the vocabulary when the word strings are known, and lowers
    /// topic metadata into labels when present.
    pub fn from_corpus(corpus: &Corpus) -> Self {
        let mut store = Self::new(corpus.embeddings.clone(), corpus.c.clone());
        if corpus.has_words() {
            store = store.with_vocab(corpus.vocab.clone());
        }
        if corpus.doc_topics.len() == corpus.num_docs() {
            store = store
                .with_labels(corpus.doc_topics.iter().map(|t| format!("topic-{t}")).collect());
        }
        store
    }

    pub fn from_tiny(tiny: &TinyCorpus) -> Self {
        let c = crate::corpus::docs_to_csr(tiny.vocab.len(), &tiny.docs);
        Self::new(tiny.embeddings.clone(), c)
            .with_vocab(tiny.vocab.clone())
            .with_texts(tiny.sentences.iter().map(|s| s.to_string()).collect())
            .with_labels(tiny.labels.iter().map(|l| l.to_string()).collect())
    }

    /// Histogram a raw text query over this store's vocabulary — the
    /// shared [`Vocabulary::text_histogram`] pipeline, so the service
    /// and the CLI can never preprocess the same text differently.
    /// `Err` when the store has no word strings or nothing survives
    /// filtering; the result always passes [`DocStore::check_query`].
    pub fn text_query(&self, text: &str) -> Result<SparseVec, String> {
        let vocab = self
            .vocab
            .as_ref()
            .ok_or("this document store has no vocabulary words — raw-text queries need an \
                    ingested (v2) corpus")?;
        vocab.text_histogram(text)
    }

    pub fn vocab_size(&self) -> usize {
        self.c.nrows()
    }

    pub fn num_docs(&self) -> usize {
        self.c.ncols()
    }

    /// Validate a query against this store. Enforces every structural
    /// invariant the `dist` precompute asserts (`SparseVec` fields are
    /// public, so a hand-built query can violate them) — a malformed
    /// request must come back as a per-request error, never panic the
    /// shared dispatcher thread.
    pub fn check_query(&self, query: &SparseVec) -> Result<(), String> {
        if query.dim != self.vocab_size() {
            return Err(format!(
                "query dimension {} does not match vocabulary {}",
                query.dim,
                self.vocab_size()
            ));
        }
        if query.idx.len() != query.val.len() {
            return Err(format!(
                "query idx/val length mismatch: {} vs {}",
                query.idx.len(),
                query.val.len()
            ));
        }
        if query.nnz() == 0 {
            return Err("query has no words".into());
        }
        let mut prev: Option<u32> = None;
        for (&i, &v) in query.idx.iter().zip(&query.val) {
            if i as usize >= query.dim {
                return Err(format!("query word {i} out of vocabulary {}", query.dim));
            }
            // Strictly increasing: a *repeated* index would double-count
            // the word's mass in the factors and alias two distinct
            // histograms onto one PreparedKey content identity.
            if let Some(p) = prev {
                if i <= p {
                    return Err(format!(
                        "query indices are not strictly increasing ({p} then {i})"
                    ));
                }
            }
            prev = Some(i);
            if !v.is_finite() || v <= 0.0 {
                return Err(format!("query mass {v} for word {i} is not positive"));
            }
        }
        let sum = query.sum();
        if (sum - 1.0).abs() > 1e-6 {
            return Err(format!("query mass {sum} is not normalized"));
        }
        Ok(())
    }

    pub fn into_arc(self) -> Arc<Self> {
        Arc::new(self)
    }
}

/// Content key of a prepared query: the full histogram plus the λ the
/// factors were built with, plus the store epoch the entry was admitted
/// under. Two requests share an entry iff every word, every mass bit and
/// λ agree — float bits, not float equality, so NaN or −0.0 oddities can
/// never alias distinct factor sets. The epoch rides along for live
/// corpora: a mutation bumps it, so a post-append query can never be
/// served an entry admitted before the append (the factors themselves
/// depend only on embeddings + query, but staleness must be observable
/// and testable at the cache boundary).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PreparedKey {
    dim: usize,
    idx: Vec<u32>,
    val_bits: Vec<u64>,
    lambda_bits: u64,
    epoch: u64,
}

impl PreparedKey {
    /// Key for a static store (epoch 0 forever).
    pub fn new(query: &SparseVec, lambda: Real) -> Self {
        Self::with_epoch(query, lambda, 0)
    }

    /// Key pinned to a live store epoch.
    pub fn with_epoch(query: &SparseVec, lambda: Real, epoch: u64) -> Self {
        Self {
            dim: query.dim,
            idx: query.idx.clone(),
            val_bits: query.val.iter().map(|v| v.to_bits()).collect(),
            lambda_bits: lambda.to_bits(),
            epoch,
        }
    }

    /// FNV-1a fingerprint — the cheap first-pass comparison; full key
    /// equality is always checked behind it (collisions cannot serve the
    /// wrong factors, only slow a lookup down).
    pub fn fingerprint(&self) -> u64 {
        const FNV_PRIME: u64 = 0x100_0000_01b3;
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut eat = |x: u64| {
            for b in x.to_le_bytes() {
                h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
            }
        };
        eat(self.dim as u64);
        eat(self.lambda_bits);
        eat(self.epoch);
        eat(self.idx.len() as u64);
        for &i in &self.idx {
            eat(i as u64);
        }
        for &v in &self.val_bits {
            eat(v);
        }
        h
    }
}

struct CacheEntry {
    fingerprint: u64,
    key: PreparedKey,
    prep: Arc<Prepared>,
    last_used: u64,
}

/// Bounded LRU cache of prepared query factors, keyed on the query's
/// content fingerprint. Owned by the dispatcher thread (no interior
/// locking): a repeated query skips the O(v_r·V·w) `dist` precompute on
/// the hot serving path and reuses the exact same [`Prepared`] value, so
/// a warm solve is bitwise identical to the cold one that filled the
/// entry. Entries are handed out as `Arc<Prepared>` clones so the
/// dispatcher can hold a whole batch of prepared queries at once (the
/// cross-query batched solve) without borrowing the cache for the
/// duration of the solve.
pub struct PreparedCache {
    capacity: usize,
    /// Byte budget over the cached factors (entry count alone is a poor
    /// bound: one entry is ~`24·V·v_r` bytes, ~100 MB at paper scale).
    max_bytes: usize,
    tick: u64,
    entries: Vec<CacheEntry>,
    /// Running sum of `prep.factors.memory_bytes()` over `entries`,
    /// maintained on insert/evict so the eviction loop is O(evictions),
    /// not O(entries) per iteration.
    bytes: usize,
}

impl PreparedCache {
    /// A cache holding at most `capacity` prepared queries (≥ 1; a
    /// disabled cache is represented by not constructing one), with no
    /// byte budget — compose with [`PreparedCache::with_max_bytes`].
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "use Option<PreparedCache> to disable caching");
        Self { capacity, max_bytes: usize::MAX, tick: 0, entries: Vec::new(), bytes: 0 }
    }

    /// Additionally bound the factor bytes held; LRU entries are evicted
    /// until the budget holds. A single entry above the budget is still
    /// cached (the alternative — preparing it on every request — costs
    /// the same memory transiently and all the time).
    pub fn with_max_bytes(mut self, max_bytes: usize) -> Self {
        assert!(max_bytes > 0, "use Option<PreparedCache> to disable caching");
        self.max_bytes = max_bytes;
        self
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Approximate heap held by the cached factors (O(1): a running
    /// total maintained on insert/evict).
    pub fn memory_bytes(&self) -> usize {
        debug_assert_eq!(
            self.bytes,
            self.entries.iter().map(|e| e.prep.factors.memory_bytes()).sum::<usize>(),
            "running byte total out of sync with entries"
        );
        self.bytes
    }

    /// Look up `key`, preparing and inserting on a miss (evicting the
    /// least-recently-used entry at capacity). Returns the cached factors
    /// (an `Arc` clone, independent of the cache's lifetime) and whether
    /// this was a hit.
    pub fn get_or_insert_with(
        &mut self,
        key: PreparedKey,
        prepare: impl FnOnce() -> Prepared,
    ) -> (Arc<Prepared>, bool) {
        self.tick += 1;
        let tick = self.tick;
        let fp = key.fingerprint();
        let found = self.entries.iter().position(|e| e.fingerprint == fp && e.key == key);
        if let Some(pos) = found {
            self.entries[pos].last_used = tick;
            return (Arc::clone(&self.entries[pos].prep), true);
        }
        let prep = Arc::new(prepare());
        // Evict (LRU first) until both bounds admit the new entry. Done
        // before the push so the fresh entry is never its own victim.
        let new_bytes = prep.factors.memory_bytes();
        while !self.entries.is_empty()
            && (self.entries.len() >= self.capacity
                || self.bytes + new_bytes > self.max_bytes)
        {
            let lru = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(i, _)| i)
                .expect("checked non-empty");
            let evicted = self.entries.swap_remove(lru);
            self.bytes -= evicted.prep.factors.memory_bytes();
        }
        let entry = CacheEntry { fingerprint: fp, key, prep: Arc::clone(&prep), last_used: tick };
        self.bytes += new_bytes;
        self.entries.push(entry);
        (prep, false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_tiny_consistent() {
        let tiny = TinyCorpus::load();
        let store = DocStore::from_tiny(&tiny);
        assert_eq!(store.num_docs(), tiny.docs.len());
        assert_eq!(store.texts.len(), store.num_docs());
        assert_eq!(store.labels.len(), store.num_docs());
    }

    #[test]
    fn text_query_builds_a_checkable_histogram() {
        let tiny = TinyCorpus::load();
        let store = DocStore::from_tiny(&tiny);
        let q = store.text_query("Obama speaks to the media in Illinois").unwrap();
        assert_eq!(q.nnz(), 4);
        assert!(store.check_query(&q).is_ok());
        assert!(store.text_query("zzz totally unknown words").is_err());
        // A store without word strings cannot histogram text.
        let wordless = DocStore::new(store.embeddings.clone(), store.c.clone());
        assert!(wordless.text_query("obama").is_err());
    }

    #[test]
    fn check_query_validates() {
        let tiny = TinyCorpus::load();
        let store = DocStore::from_tiny(&tiny);
        let good = tiny.histogram("obama speaks media").unwrap();
        assert!(store.check_query(&good).is_ok());
        let wrong_dim = SparseVec::from_counts(3, &[(0, 1)]);
        assert!(store.check_query(&wrong_dim).is_err());
    }

    fn dummy_prep(tag: f64) -> Prepared {
        Prepared {
            factors: crate::dist::QueryFactors {
                kt: Dense::filled(4, 2, tag),
                kor_t: Dense::filled(4, 2, tag),
                km_t: Dense::filled(4, 2, tag),
                r: vec![0.5, 0.5],
            },
        }
    }

    fn key(words: &[(usize, usize)], lambda: f64) -> PreparedKey {
        PreparedKey::new(&SparseVec::from_counts(100, words), lambda)
    }

    #[test]
    fn cache_hits_repeat_and_skips_prepare() {
        let mut cache = PreparedCache::new(4);
        let calls = std::cell::Cell::new(0usize);
        let mk = |tag: f64| {
            calls.set(calls.get() + 1);
            dummy_prep(tag)
        };
        let (p, hit) = cache.get_or_insert_with(key(&[(3, 1), (7, 2)], 10.0), || mk(1.0));
        assert!(!hit);
        assert_eq!(p.factors.kt.get(0, 0), 1.0);
        let (p, hit) = cache.get_or_insert_with(key(&[(3, 1), (7, 2)], 10.0), || mk(2.0));
        assert!(hit, "repeated query must hit");
        assert_eq!(p.factors.kt.get(0, 0), 1.0, "hit returns the original factors");
        assert_eq!(calls.get(), 1, "prepare ran once");
        assert_eq!(cache.len(), 1);
        assert!(cache.memory_bytes() > 0);
    }

    #[test]
    fn cache_distinguishes_lambda_and_content() {
        let mut cache = PreparedCache::new(4);
        let (_, h1) = cache.get_or_insert_with(key(&[(1, 1)], 10.0), || dummy_prep(1.0));
        let (_, h2) = cache.get_or_insert_with(key(&[(1, 1)], 20.0), || dummy_prep(2.0));
        let (_, h3) = cache.get_or_insert_with(key(&[(2, 1)], 10.0), || dummy_prep(3.0));
        assert!(!h1 && !h2 && !h3);
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn cache_evicts_least_recently_used() {
        let mut cache = PreparedCache::new(2);
        let a = || key(&[(1, 1)], 10.0);
        let b = || key(&[(2, 1)], 10.0);
        let c = || key(&[(3, 1)], 10.0);
        cache.get_or_insert_with(a(), || dummy_prep(1.0));
        cache.get_or_insert_with(b(), || dummy_prep(2.0));
        // Touch `a` so `b` becomes the LRU, then insert `c`.
        assert!(cache.get_or_insert_with(a(), || unreachable!()).1);
        cache.get_or_insert_with(c(), || dummy_prep(3.0));
        assert_eq!(cache.len(), 2);
        assert!(cache.get_or_insert_with(a(), || dummy_prep(9.0)).1, "a survived");
        assert!(!cache.get_or_insert_with(b(), || dummy_prep(9.0)).1, "b was evicted");
    }

    #[test]
    fn cache_byte_budget_evicts() {
        // Each dummy entry is 3·4·2·8 + 2·8 = 208 bytes; budget two.
        let entry_bytes = dummy_prep(0.0).factors.memory_bytes();
        let mut cache = PreparedCache::new(100).with_max_bytes(2 * entry_bytes);
        cache.get_or_insert_with(key(&[(1, 1)], 10.0), || dummy_prep(1.0));
        cache.get_or_insert_with(key(&[(2, 1)], 10.0), || dummy_prep(2.0));
        assert_eq!(cache.len(), 2);
        cache.get_or_insert_with(key(&[(3, 1)], 10.0), || dummy_prep(3.0));
        assert_eq!(cache.len(), 2, "byte budget must evict");
        assert!(cache.memory_bytes() <= 2 * entry_bytes);
        assert!(!cache.get_or_insert_with(key(&[(1, 1)], 10.0), || dummy_prep(1.0)).1);
    }

    #[test]
    fn running_byte_total_stays_consistent_under_churn() {
        // memory_bytes() debug-asserts the running total against a full
        // recompute; churn through inserts, hits and both eviction kinds
        // (count bound and byte budget) to exercise every update site.
        let entry_bytes = dummy_prep(0.0).factors.memory_bytes();
        let mut cache = PreparedCache::new(3).with_max_bytes(2 * entry_bytes);
        assert_eq!(cache.memory_bytes(), 0);
        for round in 0..10usize {
            cache.get_or_insert_with(key(&[(round % 5 + 1, 1)], 10.0), || dummy_prep(1.0));
            assert!(cache.memory_bytes() <= 2 * entry_bytes);
            assert_eq!(cache.memory_bytes(), cache.len() * entry_bytes);
        }
        assert_eq!(cache.len(), 2, "byte budget holds two entries");
    }

    #[test]
    fn check_query_rejects_malformed_hand_built_queries() {
        let tiny = TinyCorpus::load();
        let store = DocStore::from_tiny(&tiny);
        let dim = store.vocab_size();
        // Zero-mass entry, normalized sum: must be rejected, not panic
        // the dispatcher inside precompute_factors.
        let zero_mass = SparseVec { dim, idx: vec![0, 1], val: vec![1.0, 0.0] };
        assert!(store.check_query(&zero_mass).is_err());
        // Out-of-vocabulary index with matching dim.
        let oov = SparseVec { dim, idx: vec![dim as u32], val: vec![1.0] };
        assert!(store.check_query(&oov).is_err());
        // Unsorted indices.
        let unsorted = SparseVec { dim, idx: vec![2, 1], val: vec![0.5, 0.5] };
        assert!(store.check_query(&unsorted).is_err());
        // Duplicate index: sorted, normalized, but the repeated word
        // double-counts mass and defeats the PreparedKey content dedup.
        let duplicated = SparseVec { dim, idx: vec![1, 1], val: vec![0.5, 0.5] };
        assert!(store.check_query(&duplicated).is_err());
        // idx/val length mismatch.
        let ragged = SparseVec { dim, idx: vec![1], val: vec![0.5, 0.5] };
        assert!(store.check_query(&ragged).is_err());
        // NaN mass.
        let nan = SparseVec { dim, idx: vec![1], val: vec![f64::NAN] };
        assert!(store.check_query(&nan).is_err());
    }

    #[test]
    fn fingerprint_is_content_stable() {
        assert_eq!(key(&[(5, 2), (9, 1)], 10.0).fingerprint(), key(&[(5, 2), (9, 1)], 10.0).fingerprint());
        assert_ne!(key(&[(5, 2)], 10.0).fingerprint(), key(&[(5, 3)], 10.0).fingerprint());
    }

    #[test]
    fn epoch_partitions_the_key_space() {
        // Same query, same λ, different store epoch: distinct keys and
        // (overwhelmingly) distinct fingerprints — a live-store mutation
        // must never serve factors cached under an older epoch.
        let q = SparseVec::from_counts(100, &[(5, 2), (9, 1)]);
        let zero = PreparedKey::new(&q, 10.0);
        let same = PreparedKey::with_epoch(&q, 10.0, 0);
        let later = PreparedKey::with_epoch(&q, 10.0, 3);
        assert_eq!(zero, same, "new() is the epoch-0 key");
        assert_eq!(zero.fingerprint(), same.fingerprint());
        assert_ne!(zero, later);
        assert_ne!(zero.fingerprint(), later.fingerprint());
    }
}
