//! Coordinator state: the immutable document store shared by every
//! worker — embeddings + the `V × N` target matrix + optional metadata.

use crate::corpus::{SparseVec, SyntheticCorpus, TinyCorpus};
use crate::sparse::{Csr, Dense};
use std::sync::Arc;

/// The target-set state loaded once at startup and shared (`Arc`) across
/// the service, benches and examples.
#[derive(Clone, Debug)]
pub struct DocStore {
    pub embeddings: Dense,
    pub c: Csr,
    /// Optional human-readable text per target document.
    pub texts: Vec<String>,
    /// Optional label per target document (classification examples).
    pub labels: Vec<String>,
}

impl DocStore {
    pub fn new(embeddings: Dense, c: Csr) -> Self {
        assert_eq!(embeddings.nrows(), c.nrows(), "embeddings/c vocab mismatch");
        Self { embeddings, c, texts: Vec::new(), labels: Vec::new() }
    }

    pub fn with_texts(mut self, texts: Vec<String>) -> Self {
        assert_eq!(texts.len(), self.c.ncols());
        self.texts = texts;
        self
    }

    pub fn with_labels(mut self, labels: Vec<String>) -> Self {
        assert_eq!(labels.len(), self.c.ncols());
        self.labels = labels;
        self
    }

    pub fn from_synthetic(corpus: &SyntheticCorpus) -> Self {
        Self::new(corpus.embeddings.clone(), corpus.c.clone())
            .with_labels(corpus.doc_topics.iter().map(|t| format!("topic-{t}")).collect())
    }

    pub fn from_tiny(tiny: &TinyCorpus) -> Self {
        let c = crate::corpus::docs_to_csr(tiny.vocab.len(), &tiny.docs);
        Self::new(tiny.embeddings.clone(), c)
            .with_texts(tiny.sentences.iter().map(|s| s.to_string()).collect())
            .with_labels(tiny.labels.iter().map(|l| l.to_string()).collect())
    }

    pub fn vocab_size(&self) -> usize {
        self.c.nrows()
    }

    pub fn num_docs(&self) -> usize {
        self.c.ncols()
    }

    /// Validate a query against this store.
    pub fn check_query(&self, query: &SparseVec) -> Result<(), String> {
        if query.dim != self.vocab_size() {
            return Err(format!(
                "query dimension {} does not match vocabulary {}",
                query.dim,
                self.vocab_size()
            ));
        }
        if query.nnz() == 0 {
            return Err("query has no words".into());
        }
        let sum = query.sum();
        if (sum - 1.0).abs() > 1e-6 {
            return Err(format!("query mass {sum} is not normalized"));
        }
        Ok(())
    }

    pub fn into_arc(self) -> Arc<Self> {
        Arc::new(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_tiny_consistent() {
        let tiny = TinyCorpus::load();
        let store = DocStore::from_tiny(&tiny);
        assert_eq!(store.num_docs(), tiny.docs.len());
        assert_eq!(store.texts.len(), store.num_docs());
        assert_eq!(store.labels.len(), store.num_docs());
    }

    #[test]
    fn check_query_validates() {
        let tiny = TinyCorpus::load();
        let store = DocStore::from_tiny(&tiny);
        let good = tiny.histogram("obama speaks media").unwrap();
        assert!(store.check_query(&good).is_ok());
        let wrong_dim = SparseVec::from_counts(3, &[(0, 1)]);
        assert!(store.check_query(&wrong_dim).is_err());
    }
}
