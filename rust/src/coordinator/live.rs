//! The live (mutable) document store: an epoch-versioned, segmented view
//! of the target corpus, built for the paper's motivating workload —
//! "finding whether a given tweet is similar to any other tweets happened
//! in a day" (§1) — where documents stream **in** while queries run.
//!
//! Layout: one base CSR segment plus an ordered list of immutable delta
//! segments (each drained from [`crate::corpus::IngestBuilder`]) and a
//! deletion tombstone list, all behind a monotonically increasing epoch.
//! Every mutation (append / delete / compaction) publishes a new
//! [`EpochView`]; a view is a handful of `Arc` clones, so readers pin one
//! per batch and resolve against it for the batch's whole lifetime —
//! concurrent mutations never move data under an in-flight solve.
//!
//! Consistency contract (gated by `tests/live_corpus_test.rs`): at any
//! quiesced epoch, solving over the segments and merging by column offset
//! ([`crate::sinkhorn::SolveOutput::merge_shards`]) is **bitwise
//! identical** to solving over the equivalent monolithic rebuild
//! ([`EpochView::rebuild_monolithic`]). Deletions empty the owning
//! segment's column copy-on-write — the established empty-document
//! `WMD = +inf` semantics — so the equivalence includes iteration counts.

use super::state::DocStore;
use crate::sparse::Csr;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// One immutable column segment of the live corpus.
#[derive(Clone, Debug)]
pub struct Segment {
    /// `V × n_seg` histogram slice: local column `j` is global document
    /// `start + j`.
    pub c: Arc<Csr>,
    /// Global id of this segment's first document.
    pub start: usize,
    /// Per-document ingest timestamps (unix seconds; `0` for documents
    /// whose snapshot predates timestamping). Length `c.ncols()`.
    pub timestamps: Arc<Vec<i64>>,
}

impl Segment {
    pub fn num_docs(&self) -> usize {
        self.c.ncols()
    }

    /// The global document range this segment owns.
    pub fn range(&self) -> std::ops::Range<usize> {
        self.start..self.start + self.num_docs()
    }
}

/// A consistent snapshot of the live corpus at one epoch. Cloning is
/// cheap (`Arc` bumps); everything reachable from a view is immutable.
#[derive(Clone, Debug)]
pub struct EpochView {
    pub epoch: u64,
    /// Ordered, contiguous segments; `segments[0]` is the base.
    pub segments: Vec<Segment>,
    /// Sorted global ids of deleted documents. Their columns are already
    /// empty in the segments (deletion is copy-on-write); the tombstones
    /// let retrieval skip them outright and metrics count them.
    pub deleted: Arc<Vec<usize>>,
}

impl EpochView {
    pub fn num_docs(&self) -> usize {
        self.segments.last().map_or(0, |s| s.start + s.num_docs())
    }

    pub fn num_segments(&self) -> usize {
        self.segments.len()
    }

    pub fn is_deleted(&self, doc: usize) -> bool {
        self.deleted.binary_search(&doc).is_ok()
    }

    /// Ingest timestamp of a global document id.
    pub fn timestamp(&self, doc: usize) -> i64 {
        let seg = self.owning_segment(doc).expect("document id out of range");
        self.segments[seg].timestamps[doc - self.segments[seg].start]
    }

    /// Index of the segment owning global document `doc`.
    pub fn owning_segment(&self, doc: usize) -> Option<usize> {
        if doc >= self.num_docs() {
            return None;
        }
        let i = self.segments.partition_point(|s| s.start <= doc);
        Some(i - 1)
    }

    /// Non-zeros held by the delta segments (everything after the base).
    pub fn delta_nnz(&self) -> usize {
        self.segments.iter().skip(1).map(|s| s.c.nnz()).sum()
    }

    pub fn total_nnz(&self) -> usize {
        self.segments.iter().map(|s| s.c.nnz()).sum()
    }

    /// Fold every segment into one monolithic CSR — the reference the
    /// equivalence tests rebuild from scratch, and the compactor's merge
    /// primitive. Deleted columns are already empty, so the result *is*
    /// the store a from-scratch monolithic build (with the same deletions
    /// applied) would produce.
    pub fn rebuild_monolithic(&self) -> Csr {
        let refs: Vec<&Csr> = self.segments.iter().map(|s| s.c.as_ref()).collect();
        Csr::concat_columns(&refs)
    }

    /// The retrieval admission mask: `allowed[d]` ⇔ document `d` is not
    /// deleted and (when `since` is given) was ingested at or after
    /// `since`. Returns `None` when every document is admitted — the
    /// cascade then runs its unmasked (bitwise-legacy) path.
    pub fn allowed_mask(&self, since: Option<i64>) -> Option<Vec<bool>> {
        if self.deleted.is_empty() && since.is_none() {
            return None;
        }
        let mut allowed = vec![true; self.num_docs()];
        for &d in self.deleted.iter() {
            allowed[d] = false;
        }
        if let Some(cutoff) = since {
            for seg in &self.segments {
                for (j, &ts) in seg.timestamps.iter().enumerate() {
                    if ts < cutoff {
                        allowed[seg.start + j] = false;
                    }
                }
            }
        }
        if allowed.iter().all(|&b| b) {
            return None; // the window admits everything — unmasked path
        }
        Some(allowed)
    }
}

/// Gauges for the metrics report line.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LiveStoreStats {
    pub epoch: u64,
    pub segments: usize,
    pub num_docs: usize,
    pub deleted: usize,
    pub base_nnz: usize,
    pub delta_nnz: usize,
    pub compactions: u64,
    pub compaction_ms: u64,
}

struct LiveInner {
    view: EpochView,
    compactions: u64,
    compaction_ms: u64,
}

/// The mutable corpus handle: a [`DocStore`] (embeddings, vocabulary and
/// query validation — the vocabulary is frozen; appends are vocab-stable)
/// plus the epoch-versioned segment state. `append`/`delete`/`compact`
/// are safe from any thread; readers take [`LiveDocStore::view`] once per
/// batch and never lock again.
pub struct LiveDocStore {
    store: Arc<DocStore>,
    inner: Mutex<LiveInner>,
}

impl LiveDocStore {
    /// Wrap a static store: one base segment (an `Arc` clone of the
    /// store's CSR — no copy), epoch 0, all timestamps 0.
    pub fn new(store: Arc<DocStore>) -> Self {
        let ts = vec![0i64; store.num_docs()];
        Self::with_base_timestamps(store, ts)
    }

    /// [`LiveDocStore::new`] with explicit base timestamps (snapshot
    /// reload, or a demo that backdates its seed documents).
    pub fn with_base_timestamps(store: Arc<DocStore>, timestamps: Vec<i64>) -> Self {
        assert_eq!(timestamps.len(), store.num_docs(), "one timestamp per document");
        let base = Segment {
            c: Arc::new(store.c.clone()),
            start: 0,
            timestamps: Arc::new(timestamps),
        };
        Self {
            store,
            inner: Mutex::new(LiveInner {
                view: EpochView { epoch: 0, segments: vec![base], deleted: Arc::new(Vec::new()) },
                compactions: 0,
                compaction_ms: 0,
            }),
        }
    }

    /// Restore a segmented state: `segment_starts` must begin at 0 and
    /// partition `0..store.num_docs()`; `deleted` columns are emptied
    /// copy-on-write. The WMDC v3 load path.
    pub fn from_snapshot(
        store: Arc<DocStore>,
        segment_starts: &[usize],
        timestamps: Vec<i64>,
        deleted: &[usize],
    ) -> Result<Self, String> {
        let n = store.num_docs();
        if timestamps.len() != n {
            return Err(format!("{} timestamps for {n} documents", timestamps.len()));
        }
        if segment_starts.first() != Some(&0) {
            return Err("segment starts must begin at 0".into());
        }
        for w in segment_starts.windows(2) {
            if w[0] >= w[1] {
                return Err("segment starts must be strictly increasing".into());
            }
        }
        if segment_starts.last().copied().unwrap_or(0) > n {
            return Err("segment start past the end of the corpus".into());
        }
        let mut dels: Vec<usize> = deleted.to_vec();
        dels.sort_unstable();
        dels.dedup();
        if dels.last().is_some_and(|&d| d >= n) {
            return Err("deleted document id out of range".into());
        }
        let ts = Arc::new(timestamps);
        let mut segments = Vec::with_capacity(segment_starts.len());
        for (i, &start) in segment_starts.iter().enumerate() {
            let end = segment_starts.get(i + 1).copied().unwrap_or(n);
            let local: Vec<usize> = dels
                .iter()
                .filter(|&&d| d >= start && d < end)
                .map(|&d| d - start)
                .collect();
            let mut c = store.c.slice_columns(start..end);
            if !local.is_empty() {
                c = c.with_columns_emptied(&local);
            }
            segments.push(Segment {
                c: Arc::new(c),
                start,
                timestamps: Arc::new(ts[start..end].to_vec()),
            });
        }
        // The epoch counts the mutations baked into this snapshot so a
        // freshly-loaded segmented store never aliases epoch 0 of the
        // same store loaded monolithically.
        let epoch = (segments.len() - 1 + dels.len()) as u64;
        Ok(Self {
            store,
            inner: Mutex::new(LiveInner {
                view: EpochView { epoch, segments, deleted: Arc::new(dels) },
                compactions: 0,
                compaction_ms: 0,
            }),
        })
    }

    /// The frozen parts: embeddings, vocabulary, query validation.
    pub fn store(&self) -> &Arc<DocStore> {
        &self.store
    }

    /// Pin the current epoch. The returned view stays consistent however
    /// many mutations land after this call.
    pub fn view(&self) -> EpochView {
        self.inner.lock().expect("live store lock").view.clone()
    }

    pub fn epoch(&self) -> u64 {
        self.inner.lock().expect("live store lock").view.epoch
    }

    pub fn num_docs(&self) -> usize {
        self.inner.lock().expect("live store lock").view.num_docs()
    }

    /// Append one delta segment (a `V × k` CSR drained from an
    /// [`crate::corpus::IngestBuilder`], plus one ingest timestamp per
    /// document). Returns the new epoch; the documents occupy global ids
    /// `old_num_docs..old_num_docs + k`.
    pub fn append(&self, c: Csr, timestamps: Vec<i64>) -> u64 {
        assert_eq!(
            c.nrows(),
            self.store.vocab_size(),
            "delta segment vocabulary does not match the store"
        );
        assert_eq!(timestamps.len(), c.ncols(), "one timestamp per appended document");
        let mut inner = self.inner.lock().expect("live store lock");
        let start = inner.view.num_docs();
        inner.view.segments.push(Segment {
            c: Arc::new(c),
            start,
            timestamps: Arc::new(timestamps),
        });
        inner.view.epoch += 1;
        inner.view.epoch
    }

    /// Tombstone a document: its column is emptied copy-on-write in the
    /// owning segment (so every subsequent solve sees `WMD = +inf`, the
    /// empty-document semantics) and its id joins the deleted list.
    /// Deleting an already-deleted document is a no-op returning the
    /// current epoch. `Err` on an out-of-range id.
    pub fn delete(&self, doc: usize) -> Result<u64, String> {
        let mut inner = self.inner.lock().expect("live store lock");
        let n = inner.view.num_docs();
        if doc >= n {
            return Err(format!("document {doc} out of range for {n} documents"));
        }
        match inner.view.deleted.binary_search(&doc) {
            Ok(_) => Ok(inner.view.epoch),
            Err(pos) => {
                let seg = inner.view.owning_segment(doc).expect("checked in range");
                let s = &inner.view.segments[seg];
                let emptied = s.c.with_columns_emptied(&[doc - s.start]);
                inner.view.segments[seg].c = Arc::new(emptied);
                let mut dels = inner.view.deleted.as_ref().clone();
                dels.insert(pos, doc);
                inner.view.deleted = Arc::new(dels);
                inner.view.epoch += 1;
                Ok(inner.view.epoch)
            }
        }
    }

    /// Fold the delta segments into the base CSR **off the query path**:
    /// the merge runs against a pinned view with no lock held, then the
    /// result is swapped in atomically at an epoch boundary. Mutations
    /// that land during the merge are reconciled at swap time — segments
    /// appended after the pin are retained as-is, documents deleted after
    /// the pin are re-emptied in the merged base. Returns the new epoch
    /// (unchanged when there was nothing to fold).
    pub fn compact(&self) -> u64 {
        let pinned = self.view();
        if pinned.segments.len() <= 1 {
            return pinned.epoch;
        }
        let t0 = Instant::now();
        let merged = pinned.rebuild_monolithic();
        let merged_ts: Vec<i64> = pinned
            .segments
            .iter()
            .flat_map(|s| s.timestamps.iter().copied())
            .collect();
        let pinned_docs = pinned.num_docs();
        let mut inner = self.inner.lock().expect("live store lock");
        let cur = &inner.view;
        // Deletes that landed inside the merged range while we were
        // merging: the pinned segments did not have them emptied yet.
        let late_deletes: Vec<usize> = cur
            .deleted
            .iter()
            .copied()
            .filter(|&d| d < pinned_docs && !pinned.is_deleted(d))
            .collect();
        let base_c = if late_deletes.is_empty() {
            merged
        } else {
            merged.with_columns_emptied(&late_deletes)
        };
        let mut segments = vec![Segment {
            c: Arc::new(base_c),
            start: 0,
            timestamps: Arc::new(merged_ts),
        }];
        // Segments appended after the pin survive as deltas.
        segments.extend(cur.segments.iter().filter(|s| s.start >= pinned_docs).cloned());
        inner.view = EpochView {
            epoch: cur.epoch + 1,
            segments,
            deleted: Arc::clone(&cur.deleted),
        };
        inner.compactions += 1;
        inner.compaction_ms += t0.elapsed().as_millis() as u64;
        inner.view.epoch
    }

    pub fn stats(&self) -> LiveStoreStats {
        let inner = self.inner.lock().expect("live store lock");
        let v = &inner.view;
        LiveStoreStats {
            epoch: v.epoch,
            segments: v.num_segments(),
            num_docs: v.num_docs(),
            deleted: v.deleted.len(),
            base_nnz: v.segments.first().map_or(0, |s| s.c.nnz()),
            delta_nnz: v.delta_nnz(),
            compactions: inner.compactions,
            compaction_ms: inner.compaction_ms,
        }
    }

    pub fn into_arc(self) -> Arc<Self> {
        Arc::new(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::SyntheticCorpus;
    use crate::sparse::Coo;

    fn corpus(num_docs: usize, seed: u64) -> SyntheticCorpus {
        SyntheticCorpus::builder()
            .vocab_size(300)
            .num_docs(num_docs)
            .embedding_dim(8)
            .num_queries(1)
            .query_words(4, 6)
            .seed(seed)
            .build()
    }

    fn delta(vocab: usize, docs: usize, seed: u64) -> Csr {
        let mut rng = crate::util::Pcg64::new(seed);
        let mut coo = Coo::new(vocab, docs);
        for j in 0..docs {
            for _ in 0..3 {
                coo.push(rng.below(vocab), j, rng.next_f64() + 0.1);
            }
        }
        Csr::from_coo(coo)
    }

    #[test]
    fn static_store_is_one_segment_at_epoch_zero() {
        let c = corpus(10, 1);
        let live = LiveDocStore::new(DocStore::from_synthetic(&c).into_arc());
        let v = live.view();
        assert_eq!(v.epoch, 0);
        assert_eq!(v.num_segments(), 1);
        assert_eq!(v.num_docs(), 10);
        assert_eq!(v.delta_nnz(), 0);
        assert!(v.allowed_mask(None).is_none(), "nothing deleted, no window → no mask");
        assert_eq!(&v.rebuild_monolithic(), v.segments[0].c.as_ref());
    }

    #[test]
    fn append_bumps_epoch_and_preserves_pinned_views() {
        let c = corpus(10, 2);
        let store = DocStore::from_synthetic(&c).into_arc();
        let live = LiveDocStore::new(Arc::clone(&store));
        let pinned = live.view();
        let e1 = live.append(delta(store.vocab_size(), 4, 11), vec![100; 4]);
        assert_eq!(e1, 1);
        let e2 = live.append(delta(store.vocab_size(), 3, 12), vec![200; 3]);
        assert_eq!(e2, 2);
        // The pinned view still sees the pre-append world.
        assert_eq!(pinned.num_docs(), 10);
        assert_eq!(pinned.epoch, 0);
        let now = live.view();
        assert_eq!(now.num_docs(), 17);
        assert_eq!(now.num_segments(), 3);
        assert_eq!(now.segments[1].range(), 10..14);
        assert_eq!(now.segments[2].range(), 14..17);
        assert_eq!(now.timestamp(12), 100);
        assert_eq!(now.timestamp(16), 200);
        assert!(now.delta_nnz() > 0);
    }

    #[test]
    fn delete_empties_the_column_and_masks_the_doc() {
        let c = corpus(8, 3);
        let store = DocStore::from_synthetic(&c).into_arc();
        let live = LiveDocStore::new(Arc::clone(&store));
        live.append(delta(store.vocab_size(), 4, 13), vec![50; 4]);
        // One base doc, one delta doc.
        live.delete(2).unwrap();
        live.delete(9).unwrap();
        let v = live.view();
        assert!(v.is_deleted(2) && v.is_deleted(9) && !v.is_deleted(3));
        let mono = v.rebuild_monolithic();
        let sums = mono.column_sums();
        assert_eq!(sums[2], 0.0, "deleted base column is empty");
        assert_eq!(sums[9], 0.0, "deleted delta column is empty");
        assert!(sums[3] > 0.0);
        let mask = v.allowed_mask(None).expect("deletions force a mask");
        assert!(!mask[2] && !mask[9] && mask[3]);
        // Idempotent: re-deleting does not bump the epoch.
        let e = v.epoch;
        assert_eq!(live.delete(2).unwrap(), e);
        assert!(live.delete(99).is_err());
    }

    #[test]
    fn compaction_folds_deltas_and_preserves_the_monolith() {
        let c = corpus(10, 4);
        let store = DocStore::from_synthetic(&c).into_arc();
        let live = LiveDocStore::new(Arc::clone(&store));
        live.append(delta(store.vocab_size(), 4, 14), vec![10; 4]);
        live.append(delta(store.vocab_size(), 2, 15), vec![20; 2]);
        live.delete(11).unwrap();
        let before = live.view();
        let mono_before = before.rebuild_monolithic();
        let e = live.compact();
        assert_eq!(e, before.epoch + 1);
        let after = live.view();
        assert_eq!(after.num_segments(), 1, "all deltas folded");
        assert_eq!(after.num_docs(), 16);
        assert_eq!(after.segments[0].c.as_ref(), &mono_before, "compaction must not move bits");
        assert_eq!(after.timestamp(0), 0);
        assert_eq!(after.timestamp(12), 10);
        assert_eq!(after.timestamp(15), 20);
        assert!(after.is_deleted(11), "tombstones survive compaction");
        let stats = live.stats();
        assert_eq!(stats.compactions, 1);
        assert_eq!(stats.segments, 1);
        assert_eq!(stats.delta_nnz, 0);
        // Nothing to fold → epoch unchanged.
        assert_eq!(live.compact(), e);
    }

    #[test]
    fn time_window_mask_filters_old_documents() {
        let c = corpus(5, 5);
        let store = DocStore::from_synthetic(&c).into_arc();
        let live = LiveDocStore::with_base_timestamps(Arc::clone(&store), vec![100; 5]);
        live.append(delta(store.vocab_size(), 3, 16), vec![500, 600, 700]);
        let v = live.view();
        let mask = v.allowed_mask(Some(600)).expect("window forces a mask");
        assert_eq!(&mask[..5], &[false; 5], "base docs predate the window");
        assert_eq!(&mask[5..], &[false, true, true]);
        assert!(v.allowed_mask(Some(0)).is_none(), "window admitting everything → no mask");
    }

    #[test]
    fn snapshot_roundtrip_restores_segments_and_deletions() {
        let c = corpus(12, 6);
        let store = DocStore::from_synthetic(&c).into_arc();
        let ts: Vec<i64> = (0..12).map(|i| 1000 + i as i64).collect();
        let live =
            LiveDocStore::from_snapshot(Arc::clone(&store), &[0, 7, 10], ts.clone(), &[3, 8])
                .unwrap();
        let v = live.view();
        assert_eq!(v.num_segments(), 3);
        assert_eq!(v.segments[1].range(), 7..10);
        assert!(v.is_deleted(3) && v.is_deleted(8));
        assert_eq!(v.timestamp(11), 1011);
        assert!(v.epoch > 0, "restored mutations are not epoch 0");
        let mono = v.rebuild_monolithic();
        assert_eq!(mono.column_sums()[3], 0.0);
        assert_eq!(mono.column_sums()[8], 0.0);
        // Undeleted columns match the flat store bit-for-bit.
        let reference = store.c.with_columns_emptied(&[3, 8]);
        assert_eq!(mono, reference);
        // Invalid snapshots are rejected.
        assert!(LiveDocStore::from_snapshot(Arc::clone(&store), &[1], ts.clone(), &[]).is_err());
        assert!(LiveDocStore::from_snapshot(Arc::clone(&store), &[0, 5, 5], ts.clone(), &[])
            .is_err());
        assert!(LiveDocStore::from_snapshot(Arc::clone(&store), &[0], ts[..5].to_vec(), &[])
            .is_err());
        assert!(LiveDocStore::from_snapshot(Arc::clone(&store), &[0], ts, &[12]).is_err());
    }

    #[test]
    fn compaction_reconciles_concurrent_deletes() {
        // Simulate "delete lands while the merge is running" by pinning a
        // view, mutating, then compacting from the pinned world: compact()
        // itself re-pins, so drive the race through its reconcile path by
        // deleting between two compactions.
        let c = corpus(6, 7);
        let store = DocStore::from_synthetic(&c).into_arc();
        let live = LiveDocStore::new(Arc::clone(&store));
        live.append(delta(store.vocab_size(), 2, 17), vec![1; 2]);
        live.delete(0).unwrap();
        live.compact();
        live.append(delta(store.vocab_size(), 2, 18), vec![2; 2]);
        live.delete(7).unwrap();
        live.compact();
        let v = live.view();
        assert_eq!(v.num_segments(), 1);
        let sums = v.segments[0].c.column_sums();
        assert_eq!(sums[0], 0.0);
        assert_eq!(sums[7], 0.0);
        assert_eq!(v.deleted.as_ref(), &vec![0, 7]);
    }
}
