//! The L3 coordinator: a one-to-many WMD query service.
//!
//! ```text
//!   submit(query) ──► Batcher ──► dispatcher thread ──► Router
//!                                      │                  │
//!                                      ▼                  ▼
//!                                  Pool (p threads)   backend choice:
//!                                  SparseSolver       sparse-rust (paper)
//!                                      │              dense-rust  (baseline)
//!                                      ▼              dense-PJRT  (L2 artifact)
//!                                  QueryResponse ◄────────┘
//! ```
//!
//! The paper's use-case ("finding whether a given tweet is similar to any
//! other tweets of a given day") is exactly this service: a fixed target
//! set, a stream of source queries, each answered with the WMD vector.
//!
//! With `ServiceConfig::shards ≥ 2` the sparse path runs **sharded**
//! ([`shard`]): the target set is split by column range into independent
//! slices, each with its own pool; every popped batch fans out to all
//! shards and the per-shard `wmd` slices are merged back into full-length
//! responses (fig. 5's multi-socket model as real multi-pool dispatch).

pub mod batcher;
pub mod live;
pub mod metrics;
pub mod pjrt_backend;
pub mod router;
pub mod service;
pub mod shard;
pub mod state;

pub use batcher::{BatchQueue, BatcherConfig};
pub use live::{EpochView, LiveDocStore, LiveStoreStats, Segment};
pub use metrics::{Metrics, MetricsSnapshot};
pub use pjrt_backend::PjrtBackend;
pub use router::{Backend, Router};
pub use service::{QueryRequest, QueryResponse, ServiceConfig, WmdService};
pub use shard::{DocShard, ShardBatchOutput, ShardSet, ShardedDocStore};
pub use state::{DocStore, PreparedCache, PreparedKey};
