//! The L3 coordinator: a one-to-many WMD query service.
//!
//! ```text
//!   submit(query) ──► Batcher ──► dispatcher thread ──► Router
//!                                      │                  │
//!                                      ▼                  ▼
//!                                  Pool (p threads)   backend choice:
//!                                  SparseSolver       sparse-rust (paper)
//!                                      │              dense-rust  (baseline)
//!                                      ▼              dense-PJRT  (L2 artifact)
//!                                  QueryResponse ◄────────┘
//! ```
//!
//! The paper's use-case ("finding whether a given tweet is similar to any
//! other tweets of a given day") is exactly this service: a fixed target
//! set, a stream of source queries, each answered with the WMD vector.

pub mod batcher;
pub mod metrics;
pub mod pjrt_backend;
pub mod router;
pub mod service;
pub mod state;

pub use batcher::{BatchQueue, BatcherConfig};
pub use metrics::{Metrics, MetricsSnapshot};
pub use pjrt_backend::PjrtBackend;
pub use router::{Backend, Router};
pub use service::{QueryRequest, QueryResponse, ServiceConfig, WmdService};
pub use state::{DocStore, PreparedCache, PreparedKey};
