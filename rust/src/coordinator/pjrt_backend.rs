//! The dense-PJRT backend: answers queries by executing the AOT-compiled
//! L2 JAX graph (`sinkhorn_solve` artifacts) through the PJRT CPU client.
//! This is the measured stand-in for the paper's Python/MKL baseline —
//! and proof that the three layers compose.

use super::router::Router;
use super::state::DocStore;
use crate::corpus::SparseVec;
use crate::runtime::{LoadedArtifact, Manifest, Runtime};
use crate::Real;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Loaded artifacts + pre-flattened store inputs.
pub struct PjrtBackend {
    _runtime: Runtime,
    /// `v_r` bucket → compiled solve graph.
    artifacts: BTreeMap<usize, LoadedArtifact>,
    router: Router,
    /// Dense row-major `vocab × n_docs` copy of `c` (an artifact input).
    c_flat: Vec<Real>,
    /// Flat `vocab × dim` embeddings (an artifact input).
    vecs_flat: Vec<Real>,
    vocab: usize,
    n_docs: usize,
    dim: usize,
}

impl PjrtBackend {
    /// Load every `sinkhorn_solve` artifact whose shape matches the store.
    /// Returns `Ok(None)` when the manifest has no matching artifacts
    /// (e.g. `make artifacts` was run for different sizes).
    pub fn load(dir: &Path, store: &DocStore) -> Result<Option<Self>> {
        let manifest = Manifest::read(dir)?;
        let vocab = store.vocab_size();
        let n_docs = store.num_docs();
        let dim = store.embeddings.ncols();
        let metas: Vec<_> = manifest
            .artifacts
            .iter()
            .filter(|a| {
                a.variant == "sinkhorn_solve"
                    && a.vocab == vocab
                    && a.n_docs == n_docs
                    && a.dim == dim
            })
            .collect();
        if metas.is_empty() {
            return Ok(None);
        }
        let runtime = Runtime::cpu()?;
        let mut artifacts = BTreeMap::new();
        for meta in metas {
            artifacts.insert(meta.v_r, runtime.load(dir, meta)?);
        }
        let buckets: Vec<usize> = artifacts.keys().copied().collect();
        // Flatten store inputs once.
        let c_dense = store.c.to_dense();
        Ok(Some(Self {
            _runtime: runtime,
            artifacts,
            router: Router::new(buckets),
            c_flat: c_dense.as_slice().to_vec(),
            vecs_flat: store.embeddings.as_slice().to_vec(),
            vocab,
            n_docs,
            dim,
        }))
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Max query size any bucket accepts.
    pub fn max_v_r(&self) -> usize {
        self.artifacts.keys().max().copied().unwrap_or(0)
    }

    /// Execute the solve graph for one query: pad to the bucket, gather the
    /// query-word embeddings, run, return the WMD vector.
    pub fn solve(&self, query: &SparseVec, embeddings: &crate::sparse::Dense) -> Result<Vec<Real>> {
        let bucket = self
            .router
            .bucket_for(query.nnz())
            .ok_or_else(|| anyhow!("query v_r={} exceeds all buckets", query.nnz()))?;
        let padded = self.router.pad_query(query, bucket);
        let art = &self.artifacts[&bucket];
        debug_assert_eq!(art.meta.v_r, bucket);
        // Gather query embeddings (bucket × dim).
        let mut qvecs = Vec::with_capacity(bucket * self.dim);
        for &w in &padded.idx {
            qvecs.extend_from_slice(embeddings.row(w as usize));
        }
        let outputs = art.run(&[&padded.val, &qvecs, &self.c_flat, &self.vecs_flat])?;
        let wmd = outputs.into_iter().next().expect("one output");
        debug_assert_eq!(wmd.len(), self.n_docs);
        let _ = self.vocab;
        Ok(wmd)
    }
}
