//! The WMD query service: batched dispatch of one-to-many WMD queries
//! over a shared worker pool, with pluggable backends and a bounded
//! prepared-factor cache so repeated queries skip the `dist` precompute.

use super::batcher::{BatchQueue, BatcherConfig};
use super::live::LiveDocStore;
use super::metrics::Metrics;
use super::pjrt_backend::PjrtBackend;
use super::router::Backend;
use super::shard::{ShardSet, ShardedDocStore};
use super::state::{DocStore, PreparedCache, PreparedKey};
use crate::corpus::SparseVec;
use crate::parallel::Pool;
use crate::prune::{merge_topk, CascadeRetrieval, CascadeSpec, PrunedTopK};
use crate::sinkhorn::{
    DenseSolver, Prepared, SinkhornConfig, SolveWorkspace, SparseSolver, WorkspaceStats,
};
use crate::sparse::Csr;
use crate::Real;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Worker threads in the solver pool (0 → all logical CPUs).
    pub threads: usize,
    pub sinkhorn: SinkhornConfig,
    pub batcher: BatcherConfig,
    /// Default backend preference (per-request override possible).
    pub prefer: Backend,
    /// Capacity (entries) of the prepared-factor cache keyed on query
    /// fingerprint; `0` disables caching. Each entry holds the three
    /// `V × v_r` factor matrices (~`24·V·v_r` bytes).
    pub prepare_cache: usize,
    /// Byte budget over the cached factors (LRU-evicted past it); `0`
    /// means entry-count bound only.
    pub prepare_cache_bytes: usize,
    /// Solve all sparse-backend queries of a popped batch in **one**
    /// fused pass over `c` ([`SparseSolver::solve_batch`]) instead of a
    /// per-query loop. `false` restores the per-query dispatch (the
    /// ablation baseline for `benches/batch_dispatch`).
    pub cross_query_batch: bool,
    /// Number of target-set shards. `1` (default) keeps the monolithic
    /// single-pool path; `S ≥ 2` splits the target CSR into `S`
    /// nnz-balanced column slices, each with its own solver pool
    /// ([`super::ShardSet`]); every sparse-backend batch fans out to all
    /// shards and the merged response is full-length. Dense and PJRT
    /// backends stay monolithic (they are built against the full set).
    pub shards: usize,
    /// Worker threads per shard pool when `shards ≥ 2`. `0` divides
    /// `threads` evenly across the shards (min 1 each); size it to one
    /// socket's cores to mirror the paper's multi-socket layout.
    pub shard_threads: usize,
    /// The retrieval cascade serving [`QueryRequest::top_k`] requests
    /// (config key `cascade = "wcd,lcrwmd,sinkhorn"`, per-stage budgets
    /// as `name:budget`). Runs shard-locally when `shards ≥ 2` and the
    /// local top-ks are merged.
    pub cascade: CascadeSpec,
    /// Background compaction threshold for a live store: when the view
    /// holds at least this many segments, the `wmd-compactor` thread
    /// folds the deltas back into one base CSR off the query path
    /// (atomic swap at an epoch boundary). `0` or `1` disables the
    /// compactor (the default — static deployments never spawn it).
    pub compact_segments: usize,
    /// Poll interval of the compactor thread, in milliseconds.
    pub compact_interval_ms: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            threads: 0,
            sinkhorn: SinkhornConfig::default(),
            batcher: BatcherConfig::default(),
            prefer: Backend::SparseRust,
            prepare_cache: 32,
            prepare_cache_bytes: 512 << 20,
            cross_query_batch: true,
            shards: 1,
            shard_threads: 0,
            cascade: CascadeSpec::default(),
            compact_segments: 0,
            compact_interval_ms: 250,
        }
    }
}

/// One query submission.
#[derive(Clone, Debug)]
pub struct QueryRequest {
    pub query: SparseVec,
    /// Override the service-level backend preference.
    pub prefer: Option<Backend>,
    /// `Some(k)` asks for the k nearest documents through the retrieval
    /// cascade instead of the full-length WMD vector; the answer arrives
    /// in [`QueryResponse::top`]. Always served by the sparse backend.
    pub top_k: Option<usize>,
    /// Time-windowed retrieval: only documents with ingest timestamp
    /// `>= since` are eligible for [`QueryRequest::top_k`] answers (the
    /// tweet-firehose "similar tweets of a given day" scenario). Ignored
    /// for full-vector solves, which always cover every column. Documents
    /// of a static store all carry timestamp 0.
    pub since: Option<i64>,
}

impl QueryRequest {
    pub fn new(query: SparseVec) -> Self {
        Self { query, prefer: None, top_k: None, since: None }
    }

    /// A top-k retrieval request (served by the cascade).
    pub fn top_k(query: SparseVec, k: usize) -> Self {
        Self { query, prefer: None, top_k: Some(k), since: None }
    }

    /// A top-k retrieval restricted to documents ingested at or after
    /// `since`.
    pub fn top_k_since(query: SparseVec, k: usize, since: i64) -> Self {
        Self { query, prefer: None, top_k: Some(k), since: Some(since) }
    }
}

/// The service's answer.
#[derive(Clone, Debug)]
pub struct QueryResponse {
    /// WMD to every target document (empty on error and for top-k
    /// requests).
    pub wmd: Vec<Real>,
    /// The k nearest documents, `(doc, wmd)` ascending — only for
    /// [`QueryRequest::top_k`] requests.
    pub top: Vec<(usize, Real)>,
    pub iterations: usize,
    pub backend: Backend,
    pub latency: Duration,
    pub error: Option<String>,
}

impl QueryResponse {
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }

    pub fn argmin(&self) -> Option<usize> {
        self.wmd
            .iter()
            .enumerate()
            .filter(|(_, v)| v.is_finite())
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
    }
}

struct Job {
    req: QueryRequest,
    reply: mpsc::Sender<QueryResponse>,
}

/// The single shape of an error reply (the backend field is nominal — no
/// solver ran).
fn error_response(msg: String, latency: Duration) -> QueryResponse {
    QueryResponse {
        wmd: vec![],
        top: vec![],
        iterations: 0,
        backend: Backend::SparseRust,
        latency,
        error: Some(msg),
    }
}

/// Handle to the running service. Dropping it shuts the dispatcher down.
pub struct WmdService {
    queue: Arc<BatchQueue<Job>>,
    metrics: Arc<Metrics>,
    live: Arc<LiveDocStore>,
    worker: Option<std::thread::JoinHandle<()>>,
    compactor: Option<std::thread::JoinHandle<()>>,
    compactor_stop: Arc<AtomicBool>,
}

impl WmdService {
    /// Start the dispatcher thread over a static target set. `pjrt_dir`
    /// optionally points at the AOT artifacts directory; the PJRT client
    /// is **not** `Send` (the `xla` crate wraps an `Rc`), so the backend
    /// is constructed on the dispatcher thread itself. Loading failures
    /// degrade to the sparse backend (logged to stderr), matching
    /// "artifacts not built yet".
    pub fn start(
        store: Arc<DocStore>,
        config: ServiceConfig,
        pjrt_dir: Option<std::path::PathBuf>,
    ) -> Self {
        Self::start_live(LiveDocStore::new(store).into_arc(), config, pjrt_dir)
    }

    /// [`WmdService::start`] over a **live** store: documents may be
    /// appended and deleted while the service answers queries. The
    /// dispatcher pins one [`super::EpochView`] per popped batch, so
    /// every answer in a batch reflects exactly one epoch — mutations
    /// landing mid-batch are picked up by the next batch. With
    /// [`ServiceConfig::compact_segments`] ≥ 2 a background
    /// `wmd-compactor` thread folds accumulated delta segments back into
    /// the base CSR off the query path.
    pub fn start_live(
        live: Arc<LiveDocStore>,
        config: ServiceConfig,
        pjrt_dir: Option<std::path::PathBuf>,
    ) -> Self {
        let queue = Arc::new(BatchQueue::new(config.batcher));
        let metrics = Arc::new(Metrics::new());
        let compactor_stop = Arc::new(AtomicBool::new(false));
        let compactor = (config.compact_segments >= 2).then(|| {
            let live = Arc::clone(&live);
            let stop = Arc::clone(&compactor_stop);
            let threshold = config.compact_segments;
            let interval = Duration::from_millis(config.compact_interval_ms.max(1));
            std::thread::Builder::new()
                .name("wmd-compactor".into())
                .spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        if live.view().num_segments() >= threshold {
                            live.compact();
                        }
                        std::thread::park_timeout(interval);
                    }
                })
                .expect("spawn compactor")
        });
        let worker = {
            let queue = Arc::clone(&queue);
            let metrics = Arc::clone(&metrics);
            let live = Arc::clone(&live);
            std::thread::Builder::new()
                .name("wmd-dispatch".into())
                .spawn(move || {
                    let pjrt = pjrt_dir.and_then(|dir| {
                        match PjrtBackend::load(&dir, live.store()) {
                            Ok(b) => b,
                            Err(e) => {
                                eprintln!("wmd-service: PJRT backend unavailable: {e:#}");
                                None
                            }
                        }
                    });
                    dispatcher(live, config, pjrt, queue, metrics)
                })
                .expect("spawn dispatcher")
        };
        Self { queue, metrics, live, worker: Some(worker), compactor, compactor_stop }
    }

    /// The live store behind the service — the append/delete handle.
    pub fn live(&self) -> &Arc<LiveDocStore> {
        &self.live
    }

    /// Submit a query; the response arrives on the returned channel.
    pub fn submit(&self, req: QueryRequest) -> mpsc::Receiver<QueryResponse> {
        let (tx, rx) = mpsc::channel();
        if !self.queue.push(Job { req, reply: tx.clone() }) {
            let _ = tx.send(error_response("service is shut down".into(), Duration::ZERO));
        }
        rx
    }

    /// Submit and block for the answer.
    pub fn submit_wait(&self, req: QueryRequest) -> QueryResponse {
        self.submit(req).recv().expect("dispatcher dropped the reply channel")
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Graceful shutdown: drain in-flight work, join the dispatcher and
    /// the compactor.
    pub fn shutdown(mut self) {
        self.stop_threads();
    }

    fn stop_threads(&mut self) {
        self.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.compactor_stop.store(true, Ordering::Relaxed);
        if let Some(c) = self.compactor.take() {
            c.thread().unpark();
            let _ = c.join();
        }
    }
}

impl Drop for WmdService {
    fn drop(&mut self) {
        self.stop_threads();
    }
}

fn dispatcher(
    live: Arc<LiveDocStore>,
    config: ServiceConfig,
    pjrt: Option<PjrtBackend>,
    queue: Arc<BatchQueue<Job>>,
    metrics: Arc<Metrics>,
) {
    // Embeddings, vocabulary and query validation are epoch-invariant
    // (appends reuse the vocabulary); only the target columns live behind
    // the epoch. The store handle serves the former, the pinned view the
    // latter.
    let store = Arc::clone(live.store());
    let nthreads = if config.threads == 0 { crate::util::num_cpus() } else { config.threads };
    let pool = Pool::new(nthreads);
    let sparse = SparseSolver::new(config.sinkhorn);
    let dense = DenseSolver::new(config.sinkhorn);
    // S ≥ 2: split the target set into nnz-balanced column slices, one
    // worker pool per shard. The dispatcher's own pool keeps serving the
    // prepare phase and the monolithic (dense/PJRT) backends. The set is
    // re-synced against the pinned view at every popped batch (no-op
    // while the epoch holds still).
    let mut shard_set = (config.shards >= 2).then(|| {
        let per_shard = if config.shard_threads == 0 {
            (nthreads / config.shards).max(1)
        } else {
            config.shard_threads
        };
        let sharded = ShardedDocStore::split(Arc::clone(&store), config.shards);
        ShardSet::start_with_cascade(sharded, config.sinkhorn, per_shard, config.cascade.clone())
    });
    // Top-k retrieval: the monolithic cascade plus one document-centroid
    // matrix per live segment, built lazily on the first top-k request so
    // solve-only deployments never pay for it, and keyed on the segment's
    // allocation identity so a replaced segment (delete, compaction) can
    // never serve stale centroids. Sharded deployments run the cascade
    // inside the shard workers instead (each owns its subs' centroids).
    let cascade = CascadeRetrieval::new(config.sinkhorn, config.cascade.clone());
    let mut seg_centroids: std::collections::HashMap<usize, crate::sparse::Dense> =
        std::collections::HashMap::new();
    // The cache lives on the dispatcher thread — no locking on the hot path.
    let mut cache = (config.prepare_cache > 0).then(|| {
        let cache = PreparedCache::new(config.prepare_cache);
        if config.prepare_cache_bytes > 0 {
            cache.with_max_bytes(config.prepare_cache_bytes)
        } else {
            cache
        }
    });
    // The dispatcher's own long-lived workspace: monolithic sparse solves,
    // the dense baseline and every prepare borrow scratch from it. Shard
    // workers own their own (sized to their slice); their latest counters
    // are folded into the `workspace:` metrics after each batch.
    let mut ws = SolveWorkspace::new();
    let mut shard_ws: Vec<WorkspaceStats> = Vec::new();
    while let Some(batch) = queue.next_batch() {
        metrics.record_batch(batch.len());
        // Pin ONE epoch view for the whole popped batch: every job below
        // resolves against `view`, so appends and deletes landing while
        // this batch solves are invisible to it (they are served by the
        // next batch's pin). Clones are cheap — Arc bumps per segment.
        let view = live.view();
        metrics.record_live(&live.stats());
        if let Some(shards) = shard_set.as_mut() {
            shards.sync(&view);
        }
        // A store that has ever mutated serves every solve through the
        // segmented sparse path: the dense/PJRT backends were built
        // against the epoch-0 monolith and would answer with stale (or
        // wrongly-sized) vectors, so they degrade to sparse.
        let mutated = view.epoch != 0;
        // Evict centroids of segments no longer in the view (replaced by
        // delete COW or folded away by compaction).
        if mutated && !seg_centroids.is_empty() {
            let alive: std::collections::HashSet<usize> =
                view.segments.iter().map(|s| Arc::as_ptr(&s.c) as usize).collect();
            seg_centroids.retain(|k, _| alive.contains(k));
        }
        // Phase 1: validate, route and prepare every job of the popped
        // batch. Sparse-backend jobs are deferred so the whole group runs
        // as ONE fused pass over `c` per Sinkhorn step; dense/PJRT jobs
        // (and everything when `cross_query_batch` is off) answer inline.
        let mut sparse_jobs: Vec<(Job, Arc<Prepared>, Instant)> = Vec::new();
        let mut retrieval_jobs: Vec<(Job, Arc<Prepared>, usize, Instant)> = Vec::new();
        for job in batch {
            let started = Instant::now();
            if let Err(msg) = store.check_query(&job.req.query) {
                metrics.record_error();
                let _ = job.reply.send(error_response(msg, started.elapsed()));
                continue;
            }
            if let Some(k) = job.req.top_k {
                if k == 0 {
                    metrics.record_error();
                    let _ = job
                        .reply
                        .send(error_response("top_k must be at least 1".into(), started.elapsed()));
                    continue;
                }
                // The cascade is sparse-backend only: it reuses the same
                // prepared factors as a full solve, so the cache applies.
                let prep = resolve_prepared(
                    &store,
                    &pool,
                    &sparse,
                    cache.as_mut(),
                    &metrics,
                    &mut ws,
                    &job.req.query,
                    view.epoch,
                );
                retrieval_jobs.push((job, prep, k, started));
                continue;
            }
            let prefer = job.req.prefer.unwrap_or(config.prefer);
            let backend = if mutated {
                Backend::SparseRust
            } else {
                resolve_backend(prefer, pjrt.as_ref(), &job.req.query)
            };
            let sharded = shard_set.is_some() && backend.supports_sharding();
            if backend == Backend::SparseRust
                && (config.cross_query_batch || sharded || mutated)
            {
                let query = &job.req.query;
                let prep = resolve_prepared(
                    &store,
                    &pool,
                    &sparse,
                    cache.as_mut(),
                    &metrics,
                    &mut ws,
                    query,
                    view.epoch,
                );
                sparse_jobs.push((job, prep, started));
                continue;
            }
            let response = answer(
                &store,
                backend,
                &pool,
                &sparse,
                &dense,
                pjrt.as_ref(),
                cache.as_mut(),
                &metrics,
                &mut ws,
                &job.req,
            );
            let latency = started.elapsed();
            match response {
                Ok((wmd, iterations, backend)) => {
                    metrics.record_query(latency, backend);
                    let _ = job.reply.send(QueryResponse {
                        wmd,
                        top: vec![],
                        iterations,
                        backend,
                        latency,
                        error: None,
                    });
                }
                Err(msg) => {
                    metrics.record_error();
                    let _ = job.reply.send(error_response(msg, latency));
                }
            }
        }
        // Phase 2: the deferred sparse solve — cross-query batched,
        // sharded, or both — fanned back out to the reply channels.
        if !sparse_jobs.is_empty() {
            let outs: Vec<crate::sinkhorn::SolveOutput> = match &shard_set {
                Some(shards) if config.cross_query_batch => {
                    let preps: Vec<Arc<Prepared>> =
                        sparse_jobs.iter().map(|(_, p, _)| Arc::clone(p)).collect();
                    let merged = shards.solve_batch(&preps);
                    metrics.record_sharded_solve(
                        shards.num_shards(),
                        merged.shard_iterations.iter().sum::<usize>() as u64,
                    );
                    shard_ws = merged.workspace.clone();
                    merged.outputs
                }
                Some(shards) => {
                    // Batching off but sharding on: every query still
                    // fans out across the shard pools, one at a time.
                    sparse_jobs
                        .iter()
                        .flat_map(|(_, p, _)| {
                            let merged = shards.solve_batch(&[Arc::clone(p)]);
                            metrics.record_sharded_solve(
                                shards.num_shards(),
                                merged.shard_iterations.iter().sum::<usize>() as u64,
                            );
                            shard_ws = merged.workspace.clone();
                            merged.outputs
                        })
                        .collect()
                }
                None => {
                    let preps: Vec<&Prepared> =
                        sparse_jobs.iter().map(|(_, p, _)| p.as_ref()).collect();
                    // Per-segment solves merged to full length; a
                    // single-segment (static) view takes the one-pass
                    // monolithic path inside solve_segments_in.
                    let segs: Vec<(usize, &Csr)> =
                        view.segments.iter().map(|s| (s.start, s.c.as_ref())).collect();
                    sparse.solve_segments_in(&mut ws, &preps, &segs, view.num_docs(), &pool)
                }
            };
            // Only count real fused batches: solve_batch falls back to a
            // per-query loop for kernels without a batched variant.
            if sparse_jobs.len() > 1
                && config.cross_query_batch
                && config.sinkhorn.kernel.has_batched_path()
            {
                metrics.record_batched_solve(sparse_jobs.len());
            }
            metrics.record_kernel_queries(config.sinkhorn.kernel, sparse_jobs.len() as u64);
            // Per-document convergence telemetry (frozen columns,
            // compactions, nnz traversed vs full, iterations-to-freeze
            // histogram) — sharded outputs arrive pre-merged.
            for out in &outs {
                metrics.record_convergence(&out.conv);
            }
            for ((job, _prep, started), out) in sparse_jobs.into_iter().zip(outs) {
                let latency = started.elapsed();
                metrics.record_query(latency, Backend::SparseRust);
                let _ = job.reply.send(QueryResponse {
                    wmd: out.wmd,
                    top: vec![],
                    iterations: out.iterations,
                    backend: Backend::SparseRust,
                    latency,
                    error: None,
                });
            }
        }
        // Phase 3: top-k retrieval through the bound cascade — shard-local
        // (merged) when the shard set is up, monolithic otherwise.
        for (job, prep, k, started) in retrieval_jobs {
            // The admission mask folds tombstones and the request's time
            // window together; `None` whenever everything is admitted, so
            // static stores keep the unmasked (bitwise-legacy) path.
            let allowed = view.allowed_mask(job.req.since).map(Arc::new);
            let topk = match &shard_set {
                Some(shards) => {
                    let (out, wss) =
                        shards.retrieve_topk_masked(&job.req.query, &prep, k, allowed);
                    shard_ws = wss;
                    out
                }
                None => {
                    let mut parts: Vec<(usize, PrunedTopK)> = Vec::new();
                    for seg in view.segments.iter().filter(|s| s.c.ncols() > 0) {
                        let key = Arc::as_ptr(&seg.c) as usize;
                        if !seg_centroids.contains_key(&key) {
                            seg_centroids.insert(
                                key,
                                crate::prune::centroids(&store.embeddings, &seg.c, &pool),
                            );
                        }
                        let cents = seg_centroids.get(&key).expect("just inserted");
                        let local = allowed
                            .as_deref()
                            .map(|m| &m[seg.start..seg.start + seg.c.ncols()]);
                        let out = cascade.retrieve_prepared_masked_in(
                            &mut ws,
                            &store.embeddings,
                            &job.req.query,
                            &prep,
                            &seg.c,
                            cents,
                            &pool,
                            k,
                            local,
                        );
                        parts.push((seg.start, out));
                    }
                    if parts.len() == 1 && parts[0].0 == 0 && allowed.is_none() {
                        // Static store, no mask: the single part IS the
                        // answer — skip the merge re-sort so the legacy
                        // ordering is preserved bit for bit.
                        parts.pop().expect("one part").1
                    } else {
                        merge_topk(&parts, k)
                    }
                }
            };
            metrics.record_cascade(&topk.stats);
            let latency = started.elapsed();
            metrics.record_query(latency, Backend::SparseRust);
            let _ = job.reply.send(QueryResponse {
                wmd: vec![],
                top: topk.top,
                iterations: 0,
                backend: Backend::SparseRust,
                latency,
                error: None,
            });
        }
        // Publish the workspace gauges: the dispatcher's own arena plus
        // the latest per-shard snapshots.
        let agg = shard_ws.iter().fold(ws.stats(), |acc, s| acc.merged(*s));
        metrics.record_workspace(agg);
    }
}

/// Per-request backend resolution: the PJRT preference degrades to the
/// sparse backend when the runtime is unavailable or the query's word
/// count fits no compiled bucket.
fn resolve_backend(
    prefer: Backend,
    pjrt: Option<&PjrtBackend>,
    query: &SparseVec,
) -> Backend {
    match (prefer, pjrt) {
        (Backend::DensePjrt, Some(b)) if b.router().bucket_for(query.nnz()).is_some() => {
            Backend::DensePjrt
        }
        (Backend::DensePjrt, _) => Backend::SparseRust,
        (other, _) => other,
    }
}

/// Resolve the prepared factors: cache hit, cache fill, or (cache
/// disabled) a one-shot prepare. The `Arc` lets the dispatcher hold a
/// whole batch of prepared queries across one batched solve. A cache
/// *miss* borrows the dispatcher workspace's dist-layer scratch for the
/// precompute's intermediates before committing the finished factors into
/// an `Arc<Prepared>` (the factor planes themselves are the cached
/// artifact — they are allocated once and retained by the cache, not by
/// the workspace).
#[allow(clippy::too_many_arguments)]
fn resolve_prepared(
    store: &DocStore,
    pool: &Pool,
    sparse: &SparseSolver,
    cache: Option<&mut PreparedCache>,
    metrics: &Metrics,
    ws: &mut SolveWorkspace,
    query: &SparseVec,
    epoch: u64,
) -> Arc<Prepared> {
    let prepare = || sparse.prepare_in(ws, &store.embeddings, query, pool);
    match cache {
        Some(cache) => {
            // The factors depend only on embeddings + query, but the key
            // carries the store epoch: entries admitted before a mutation
            // are unreachable afterwards, so staleness is structurally
            // impossible (and the LRU retires the dead epochs' entries).
            let key = PreparedKey::with_epoch(query, sparse.config().lambda, epoch);
            let (prep, hit) = cache.get_or_insert_with(key, prepare);
            metrics.record_prepare_cache(hit);
            prep
        }
        None => Arc::new(prepare()),
    }
}

#[allow(clippy::too_many_arguments)]
fn answer(
    store: &DocStore,
    backend: Backend,
    pool: &Pool,
    sparse: &SparseSolver,
    dense: &DenseSolver,
    pjrt: Option<&PjrtBackend>,
    cache: Option<&mut PreparedCache>,
    metrics: &Metrics,
    ws: &mut SolveWorkspace,
    req: &QueryRequest,
) -> Result<(Vec<Real>, usize, Backend), String> {
    // The PJRT graph bakes its own precompute in; only the in-process
    // solvers consume `dist` factors (and hence the cache).
    if backend == Backend::DensePjrt {
        let b = pjrt.expect("resolve_backend only picks an available PJRT runtime");
        let wmd = b
            .solve(&req.query, &store.embeddings)
            .map_err(|e| format!("pjrt backend: {e:#}"))?;
        return Ok((wmd, b.max_v_r(), backend));
    }
    // Both in-process solvers share the same factors — `precompute_factors`
    // with the service λ. This path is only reachable on a pristine store
    // (a mutated view degrades every job to the deferred segmented solve),
    // so the cache key is pinned to epoch 0.
    let prep = resolve_prepared(store, pool, sparse, cache, metrics, ws, &req.query, 0);
    match backend {
        Backend::SparseRust => {
            let out = sparse.solve_in(ws, &prep, &store.c, pool);
            Ok((out.wmd, out.iterations, backend))
        }
        Backend::DenseRust => {
            let (out, _times) = dense.solve_prepared_in(ws, &prep, &store.c, pool);
            Ok((out.wmd, out.iterations, backend))
        }
        Backend::DensePjrt => unreachable!("handled above"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::SyntheticCorpus;

    fn small_service() -> (WmdService, SyntheticCorpus) {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(500)
            .num_docs(40)
            .embedding_dim(16)
            .num_queries(4)
            .query_words(5, 10)
            .seed(3)
            .build();
        let store = DocStore::from_synthetic(&corpus).into_arc();
        let service = WmdService::start(
            store,
            ServiceConfig { threads: 2, ..Default::default() },
            None,
        );
        (service, corpus)
    }

    #[test]
    fn answers_queries() {
        let (service, corpus) = small_service();
        let resp = service.submit_wait(QueryRequest::new(corpus.query(0).clone()));
        assert!(resp.is_ok(), "{:?}", resp.error);
        assert_eq!(resp.wmd.len(), 40);
        assert!(resp.argmin().is_some());
        assert!(resp.latency > Duration::ZERO);
        service.shutdown();
    }

    #[test]
    fn rejects_invalid_query() {
        let (service, _corpus) = small_service();
        let bad = SparseVec::from_counts(7, &[(1, 1)]); // wrong dim
        let resp = service.submit_wait(QueryRequest::new(bad));
        assert!(!resp.is_ok());
        assert_eq!(service.metrics().snapshot().errors, 1);
        service.shutdown();
    }

    #[test]
    fn concurrent_submissions_batch() {
        let (service, corpus) = small_service();
        let receivers: Vec<_> = (0..4)
            .map(|i| service.submit(QueryRequest::new(corpus.query(i).clone())))
            .collect();
        for rx in receivers {
            let resp = rx.recv().unwrap();
            assert!(resp.is_ok());
        }
        let snap = service.metrics().snapshot();
        assert_eq!(snap.queries, 4);
        assert!(snap.batches >= 1);
        service.shutdown();
    }

    #[test]
    fn batched_dispatch_matches_per_query_solve() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(500)
            .num_docs(40)
            .embedding_dim(16)
            .num_queries(4)
            .query_words(5, 10)
            .seed(11)
            .build();
        let store = DocStore::from_synthetic(&corpus).into_arc();
        // One solver thread → the batched serial path is bitwise identical
        // to the per-query solve; a generous wait window + max_batch 4 so
        // all four submissions coalesce into one batched solve.
        let service = WmdService::start(
            store,
            ServiceConfig {
                threads: 1,
                batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_secs(10) },
                ..Default::default()
            },
            None,
        );
        let receivers: Vec<_> = (0..4)
            .map(|i| service.submit(QueryRequest::new(corpus.query(i).clone())))
            .collect();
        let responses: Vec<_> = receivers.into_iter().map(|rx| rx.recv().unwrap()).collect();
        let pool = Pool::new(1);
        let solver = SparseSolver::new(SinkhornConfig::default());
        for (i, resp) in responses.iter().enumerate() {
            assert!(resp.is_ok(), "{:?}", resp.error);
            let direct =
                solver.wmd_one_to_many(&corpus.embeddings, corpus.query(i), &corpus.c, &pool);
            assert_eq!(resp.wmd, direct.wmd, "query {i}");
            assert_eq!(resp.iterations, direct.iterations, "query {i}");
        }
        let snap = service.metrics().snapshot();
        assert_eq!(snap.queries, 4);
        assert_eq!(snap.batched_solves, 1, "four coalesced queries → one batched solve");
        assert_eq!(snap.batched_queries, 4);
        service.shutdown();
    }

    #[test]
    fn per_query_dispatch_when_batching_disabled() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(400)
            .num_docs(30)
            .embedding_dim(12)
            .num_queries(4)
            .query_words(5, 9)
            .seed(13)
            .build();
        let store = DocStore::from_synthetic(&corpus).into_arc();
        let service = WmdService::start(
            store,
            ServiceConfig { threads: 2, cross_query_batch: false, ..Default::default() },
            None,
        );
        let receivers: Vec<_> = (0..4)
            .map(|i| service.submit(QueryRequest::new(corpus.query(i).clone())))
            .collect();
        for rx in receivers {
            assert!(rx.recv().unwrap().is_ok());
        }
        let snap = service.metrics().snapshot();
        assert_eq!(snap.queries, 4);
        assert_eq!(snap.batched_solves, 0, "batching disabled must use the per-query loop");
        assert_eq!(snap.batched_queries, 0);
        service.shutdown();
    }

    #[test]
    fn no_batched_metrics_for_kernels_without_batched_path() {
        use crate::sinkhorn::IterateKernel;
        let corpus = SyntheticCorpus::builder()
            .vocab_size(400)
            .num_docs(30)
            .embedding_dim(12)
            .num_queries(3)
            .query_words(5, 9)
            .seed(31)
            .build();
        let store = DocStore::from_synthetic(&corpus).into_arc();
        let service = WmdService::start(
            store,
            ServiceConfig {
                threads: 1,
                sinkhorn: SinkhornConfig {
                    kernel: IterateKernel::Unfused,
                    ..Default::default()
                },
                batcher: BatcherConfig { max_batch: 3, max_wait: Duration::from_secs(10) },
                ..Default::default()
            },
            None,
        );
        let receivers: Vec<_> = (0..3)
            .map(|i| service.submit(QueryRequest::new(corpus.query(i).clone())))
            .collect();
        for rx in receivers {
            assert!(rx.recv().unwrap().is_ok());
        }
        let snap = service.metrics().snapshot();
        assert_eq!(snap.queries, 3);
        assert_eq!(
            snap.batched_solves, 0,
            "solve_batch fell back to per-query — metrics must not claim a fused batch"
        );
        service.shutdown();
    }

    #[test]
    fn invalid_query_in_batch_does_not_poison_the_batch() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(400)
            .num_docs(30)
            .embedding_dim(12)
            .num_queries(2)
            .query_words(5, 9)
            .seed(29)
            .build();
        let store = DocStore::from_synthetic(&corpus).into_arc();
        let service = WmdService::start(
            store,
            ServiceConfig {
                threads: 1,
                batcher: BatcherConfig { max_batch: 3, max_wait: Duration::from_secs(10) },
                ..Default::default()
            },
            None,
        );
        let good0 = service.submit(QueryRequest::new(corpus.query(0).clone()));
        let bad = service.submit(QueryRequest::new(SparseVec::from_counts(7, &[(1, 1)])));
        let good1 = service.submit(QueryRequest::new(corpus.query(1).clone()));
        assert!(good0.recv().unwrap().is_ok());
        assert!(!bad.recv().unwrap().is_ok());
        assert!(good1.recv().unwrap().is_ok());
        let snap = service.metrics().snapshot();
        assert_eq!(snap.errors, 1);
        assert_eq!(snap.queries, 2);
        assert_eq!(snap.batched_solves, 1);
        assert_eq!(snap.batched_queries, 2);
        service.shutdown();
    }

    #[test]
    fn sharded_dispatch_is_bitwise_identical_to_unsharded() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(500)
            .num_docs(40)
            .embedding_dim(16)
            .num_queries(4)
            .query_words(5, 10)
            .seed(43)
            .build();
        let store = DocStore::from_synthetic(&corpus).into_arc();
        // Fixed iterations + one thread everywhere → the merged sharded
        // answer must reproduce the monolithic answer bit for bit.
        let mk = |shards: usize| {
            WmdService::start(
                Arc::clone(&store),
                ServiceConfig {
                    threads: 1,
                    shards,
                    shard_threads: 1,
                    sinkhorn: SinkhornConfig {
                        tolerance: 0.0,
                        max_iter: 12,
                        ..Default::default()
                    },
                    ..Default::default()
                },
                None,
            )
        };
        let base = mk(1);
        let sharded = mk(3);
        for i in 0..4 {
            let a = base.submit_wait(QueryRequest::new(corpus.query(i).clone()));
            let b = sharded.submit_wait(QueryRequest::new(corpus.query(i).clone()));
            assert!(a.is_ok() && b.is_ok());
            assert_eq!(a.wmd, b.wmd, "query {i}: sharded result differs");
            assert_eq!(a.iterations, b.iterations, "query {i}");
        }
        let snap = sharded.metrics().snapshot();
        assert_eq!(snap.queries, 4);
        assert_eq!(snap.sharded_solves, 4, "every dispatch went through the shard set");
        assert_eq!(snap.shard_solves, 12, "4 dispatches × 3 shards");
        assert!(snap.shard_iterations > 0, "per-shard iteration counts folded in");
        assert_eq!(base.metrics().snapshot().sharded_solves, 0);
        base.shutdown();
        sharded.shutdown();
    }

    #[test]
    fn sharded_batch_coalesces_into_one_dispatch() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(400)
            .num_docs(30)
            .embedding_dim(12)
            .num_queries(4)
            .query_words(5, 9)
            .seed(47)
            .build();
        let store = DocStore::from_synthetic(&corpus).into_arc();
        let service = WmdService::start(
            store,
            ServiceConfig {
                threads: 1,
                shards: 2,
                shard_threads: 1,
                batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_secs(10) },
                ..Default::default()
            },
            None,
        );
        let receivers: Vec<_> = (0..4)
            .map(|i| service.submit(QueryRequest::new(corpus.query(i).clone())))
            .collect();
        for rx in receivers {
            let resp = rx.recv().unwrap();
            assert!(resp.is_ok(), "{:?}", resp.error);
            assert_eq!(resp.wmd.len(), 30, "merged response is full-length");
        }
        let snap = service.metrics().snapshot();
        assert_eq!(snap.queries, 4);
        assert_eq!(snap.sharded_solves, 1, "four coalesced queries → one sharded dispatch");
        assert_eq!(snap.shard_solves, 2);
        assert_eq!(snap.batched_solves, 1, "the fused batch is still counted");
        assert!(
            snap.workspace_checkouts >= 2,
            "each shard worker's workspace checkout must be folded into the gauges"
        );
        assert!(snap.workspace_bytes > 0);
        service.shutdown();
    }

    #[test]
    fn workspace_metrics_published_after_batches() {
        let (service, corpus) = small_service();
        // The same query twice: the second solve reruns identical shapes,
        // so it must check the warm arena out without growing it.
        for _ in 0..2 {
            let resp = service.submit_wait(QueryRequest::new(corpus.query(0).clone()));
            assert!(resp.is_ok(), "{:?}", resp.error);
        }
        let snap = service.metrics().snapshot();
        assert_eq!(snap.workspace_checkouts, 2, "one checkout per dispatched solve");
        assert!(snap.workspace_bytes > 0, "the dispatcher retains its arena");
        assert_eq!(snap.workspace_grows, 1, "only the cold solve grows the arena");
        service.shutdown();
    }

    #[test]
    fn dense_backend_agrees_with_sparse() {
        let (service, corpus) = small_service();
        let q = corpus.query(1).clone();
        let a = service.submit_wait(QueryRequest::new(q.clone()));
        let b = service.submit_wait(QueryRequest {
            query: q,
            prefer: Some(Backend::DenseRust),
            top_k: None,
            since: None,
        });
        assert!(a.is_ok() && b.is_ok());
        assert_eq!(b.backend, Backend::DenseRust);
        // Dense baseline runs fixed max_iter without early exit; compare
        // loosely (both near the fixed point).
        for (x, y) in a.wmd.iter().zip(&b.wmd) {
            assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "{x} vs {y}");
        }
        service.shutdown();
    }

    #[test]
    fn prepared_cache_hit_is_bitwise_identical_and_skips_precompute() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(400)
            .num_docs(30)
            .embedding_dim(12)
            .num_queries(2)
            .query_words(5, 9)
            .seed(19)
            .build();
        let store = DocStore::from_synthetic(&corpus).into_arc();
        // One solver thread → a fully deterministic solve, so a warm
        // answer must reproduce the cold answer bit for bit.
        let service = WmdService::start(
            store,
            ServiceConfig { threads: 1, ..Default::default() },
            None,
        );
        let q = corpus.query(0).clone();
        let cold = service.submit_wait(QueryRequest::new(q.clone()));
        let warm = service.submit_wait(QueryRequest::new(q));
        assert!(cold.is_ok() && warm.is_ok());
        let snap = service.metrics().snapshot();
        assert_eq!(snap.prepare_cache_misses, 1, "cold solve fills the cache");
        assert_eq!(snap.prepare_cache_hits, 1, "warm solve skips precompute_factors");
        assert_eq!(cold.wmd, warm.wmd, "cache hit must not perturb the WMD");
        // A different query is a miss, not a false hit.
        let other = service.submit_wait(QueryRequest::new(corpus.query(1).clone()));
        assert!(other.is_ok());
        let snap = service.metrics().snapshot();
        assert_eq!(snap.prepare_cache_misses, 2);
        assert_eq!(snap.prepare_cache_hits, 1);
        assert_ne!(other.wmd, cold.wmd);
        service.shutdown();
    }

    #[test]
    fn cache_disabled_still_answers() {
        let (service, corpus) = {
            let corpus = SyntheticCorpus::builder()
                .vocab_size(300)
                .num_docs(20)
                .embedding_dim(8)
                .num_queries(1)
                .query_words(4, 4)
                .seed(5)
                .build();
            let store = DocStore::from_synthetic(&corpus).into_arc();
            let service = WmdService::start(
                store,
                ServiceConfig { threads: 1, prepare_cache: 0, ..Default::default() },
                None,
            );
            (service, corpus)
        };
        let a = service.submit_wait(QueryRequest::new(corpus.query(0).clone()));
        let b = service.submit_wait(QueryRequest::new(corpus.query(0).clone()));
        assert!(a.is_ok() && b.is_ok());
        let snap = service.metrics().snapshot();
        assert_eq!(snap.prepare_cache_hits, 0);
        assert_eq!(snap.prepare_cache_misses, 0);
        assert_eq!(a.wmd, b.wmd);
        service.shutdown();
    }

    #[test]
    fn top_k_request_matches_direct_cascade() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(500)
            .num_docs(40)
            .embedding_dim(16)
            .num_queries(3)
            .query_words(5, 10)
            .seed(53)
            .build();
        let store = DocStore::from_synthetic(&corpus).into_arc();
        let service = WmdService::start(
            Arc::clone(&store),
            ServiceConfig { threads: 1, ..Default::default() },
            None,
        );
        let pool = Pool::new(1);
        let cascade =
            crate::prune::CascadeRetrieval::new(SinkhornConfig::default(), CascadeSpec::default());
        let cents = crate::prune::centroids(&store.embeddings, &store.c, &pool);
        for i in 0..3 {
            let resp = service.submit_wait(QueryRequest::top_k(corpus.query(i).clone(), 5));
            assert!(resp.is_ok(), "{:?}", resp.error);
            assert!(resp.wmd.is_empty(), "top-k responses carry no full vector");
            let direct = cascade.retrieve(
                &store.embeddings,
                corpus.query(i),
                &store.c,
                &cents,
                &pool,
                5,
            );
            assert_eq!(resp.top, direct.top, "query {i}");
        }
        let snap = service.metrics().snapshot();
        assert_eq!(snap.queries, 3);
        assert_eq!(snap.cascade_queries, 3);
        assert!(snap.pruned_solves > 0, "the bounds must have pruned something");
        service.shutdown();
    }

    #[test]
    fn sharded_top_k_matches_monolithic() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(500)
            .num_docs(40)
            .embedding_dim(16)
            .num_queries(3)
            .query_words(5, 10)
            .seed(59)
            .build();
        let store = DocStore::from_synthetic(&corpus).into_arc();
        let mk = |shards: usize| {
            WmdService::start(
                Arc::clone(&store),
                ServiceConfig { threads: 1, shards, shard_threads: 1, ..Default::default() },
                None,
            )
        };
        let base = mk(1);
        for shards in [2, 3] {
            let sharded = mk(shards);
            for i in 0..3 {
                let a = base.submit_wait(QueryRequest::top_k(corpus.query(i).clone(), 7));
                let b = sharded.submit_wait(QueryRequest::top_k(corpus.query(i).clone(), 7));
                assert!(a.is_ok() && b.is_ok());
                assert_eq!(a.top, b.top, "query {i}, {shards} shards");
            }
            sharded.shutdown();
        }
        base.shutdown();
    }

    #[test]
    fn top_k_of_zero_is_an_error() {
        let (service, corpus) = small_service();
        let resp = service.submit_wait(QueryRequest::top_k(corpus.query(0).clone(), 0));
        assert!(!resp.is_ok());
        assert_eq!(service.metrics().snapshot().errors, 1);
        service.shutdown();
    }

    #[test]
    fn shutdown_drains_in_flight_work() {
        let (service, corpus) = small_service();
        // Submit work, then shut down immediately: the dispatcher must
        // drain the queue before exiting, so every reply still arrives.
        let receivers: Vec<_> = (0..3)
            .map(|i| service.submit(QueryRequest::new(corpus.query(i).clone())))
            .collect();
        service.shutdown();
        for rx in receivers {
            let resp = rx.recv().expect("reply delivered before shutdown completed");
            assert!(resp.is_ok());
        }
    }

    /// `docs` synthetic delta documents over the same vocabulary, three
    /// words each — the live-service tests' append payload.
    fn delta_docs(vocab: usize, docs: usize, seed: u64) -> Csr {
        let mut rng = crate::util::Pcg64::new(seed);
        let mut coo = crate::sparse::Coo::new(vocab, docs);
        for j in 0..docs {
            for _ in 0..3 {
                coo.push(rng.below(vocab), j, rng.next_f64() + 0.1);
            }
        }
        Csr::from_coo(coo)
    }

    fn live_corpus(seed: u64) -> (Arc<LiveDocStore>, SyntheticCorpus) {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(500)
            .num_docs(40)
            .embedding_dim(16)
            .num_queries(2)
            .query_words(5, 10)
            .seed(seed)
            .build();
        let live = LiveDocStore::new(DocStore::from_synthetic(&corpus).into_arc()).into_arc();
        (live, corpus)
    }

    #[test]
    fn live_append_grows_the_answer_and_rekeys_the_cache() {
        let (live, corpus) = live_corpus(3);
        let service = WmdService::start_live(
            Arc::clone(&live),
            ServiceConfig { threads: 1, ..Default::default() },
            None,
        );
        let q = corpus.query(0).clone();
        let before = service.submit_wait(QueryRequest::new(q.clone()));
        assert!(before.is_ok(), "{:?}", before.error);
        assert_eq!(before.wmd.len(), 40);
        live.append(delta_docs(500, 6, 11), vec![100; 6]);
        let after = service.submit_wait(QueryRequest::new(q.clone()));
        assert!(after.is_ok(), "{:?}", after.error);
        assert_eq!(after.wmd.len(), 46, "the appended documents are answered");
        // Columns are independent, so the base prefix of the segmented
        // post-append solve reproduces the monolithic answer bit for bit.
        assert_eq!(&after.wmd[..40], &before.wmd[..]);
        // Epoch-keyed cache regression: the post-append solve must NOT be
        // served factors admitted at epoch 0 — the same query misses again.
        let snap = service.metrics().snapshot();
        assert_eq!(snap.prepare_cache_misses, 2, "one miss per epoch");
        assert_eq!(snap.prepare_cache_hits, 0, "no cross-epoch hit");
        // Same epoch, same query: now it hits.
        let warm = service.submit_wait(QueryRequest::new(q));
        assert!(warm.is_ok());
        let snap = service.metrics().snapshot();
        assert_eq!(snap.prepare_cache_hits, 1);
        assert_eq!(warm.wmd, after.wmd);
        service.shutdown();
    }

    #[test]
    fn since_window_restricts_top_k_to_fresh_documents() {
        let (live, corpus) = live_corpus(7);
        let service = WmdService::start_live(
            Arc::clone(&live),
            ServiceConfig { threads: 1, ..Default::default() },
            None,
        );
        live.append(delta_docs(500, 8, 23), vec![1_000; 8]);
        let all = service.submit_wait(QueryRequest::top_k(corpus.query(0).clone(), 5));
        assert!(all.is_ok(), "{:?}", all.error);
        assert_eq!(all.top.len(), 5);
        let fresh =
            service.submit_wait(QueryRequest::top_k_since(corpus.query(0).clone(), 5, 1_000));
        assert!(fresh.is_ok(), "{:?}", fresh.error);
        assert!(!fresh.top.is_empty());
        assert!(
            fresh.top.iter().all(|&(doc, _)| doc >= 40),
            "the window admits only appended documents: {:?}",
            fresh.top
        );
        service.shutdown();
    }

    #[test]
    fn deleted_document_is_unreachable() {
        let (live, corpus) = live_corpus(13);
        let service = WmdService::start_live(
            Arc::clone(&live),
            ServiceConfig { threads: 1, ..Default::default() },
            None,
        );
        live.delete(7).expect("document 7 is in range");
        let resp = service.submit_wait(QueryRequest::new(corpus.query(0).clone()));
        assert!(resp.is_ok(), "{:?}", resp.error);
        assert_eq!(resp.wmd.len(), 40, "the slot stays (ids are stable)");
        assert!(resp.wmd[7].is_infinite(), "a deleted document answers +inf");
        let topk = service.submit_wait(QueryRequest::top_k(corpus.query(0).clone(), 40));
        assert!(topk.is_ok(), "{:?}", topk.error);
        assert!(topk.top.iter().all(|&(doc, _)| doc != 7), "tombstones never surface");
        service.shutdown();
    }

    #[test]
    fn background_compactor_folds_segments() {
        let (live, corpus) = live_corpus(17);
        let service = WmdService::start_live(
            Arc::clone(&live),
            ServiceConfig {
                threads: 1,
                compact_segments: 2,
                compact_interval_ms: 2,
                ..Default::default()
            },
            None,
        );
        live.append(delta_docs(500, 4, 31), vec![10; 4]);
        live.append(delta_docs(500, 3, 37), vec![20; 3]);
        let deadline = Instant::now() + Duration::from_secs(30);
        while live.view().num_segments() > 1 {
            assert!(Instant::now() < deadline, "compactor never folded the segments");
            std::thread::sleep(Duration::from_millis(5));
        }
        let stats = live.stats();
        assert!(stats.compactions >= 1);
        assert_eq!(stats.delta_nnz, 0, "everything folded into the base");
        let resp = service.submit_wait(QueryRequest::new(corpus.query(0).clone()));
        assert!(resp.is_ok(), "{:?}", resp.error);
        assert_eq!(resp.wmd.len(), 47);
        service.shutdown();
    }
}
