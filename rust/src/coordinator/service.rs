//! The WMD query service: batched dispatch of one-to-many WMD queries
//! over a shared worker pool, with pluggable backends and a bounded
//! prepared-factor cache so repeated queries skip the `dist` precompute.

use super::batcher::{BatchQueue, BatcherConfig};
use super::metrics::Metrics;
use super::pjrt_backend::PjrtBackend;
use super::router::Backend;
use super::state::{DocStore, PreparedCache, PreparedKey};
use crate::corpus::SparseVec;
use crate::parallel::Pool;
use crate::sinkhorn::{DenseSolver, Prepared, SinkhornConfig, SparseSolver};
use crate::Real;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Worker threads in the solver pool (0 → all logical CPUs).
    pub threads: usize,
    pub sinkhorn: SinkhornConfig,
    pub batcher: BatcherConfig,
    /// Default backend preference (per-request override possible).
    pub prefer: Backend,
    /// Capacity (entries) of the prepared-factor cache keyed on query
    /// fingerprint; `0` disables caching. Each entry holds the three
    /// `V × v_r` factor matrices (~`24·V·v_r` bytes).
    pub prepare_cache: usize,
    /// Byte budget over the cached factors (LRU-evicted past it); `0`
    /// means entry-count bound only.
    pub prepare_cache_bytes: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            threads: 0,
            sinkhorn: SinkhornConfig::default(),
            batcher: BatcherConfig::default(),
            prefer: Backend::SparseRust,
            prepare_cache: 32,
            prepare_cache_bytes: 512 << 20,
        }
    }
}

/// One query submission.
#[derive(Clone, Debug)]
pub struct QueryRequest {
    pub query: SparseVec,
    /// Override the service-level backend preference.
    pub prefer: Option<Backend>,
}

impl QueryRequest {
    pub fn new(query: SparseVec) -> Self {
        Self { query, prefer: None }
    }
}

/// The service's answer.
#[derive(Clone, Debug)]
pub struct QueryResponse {
    /// WMD to every target document (empty on error).
    pub wmd: Vec<Real>,
    pub iterations: usize,
    pub backend: Backend,
    pub latency: Duration,
    pub error: Option<String>,
}

impl QueryResponse {
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }

    pub fn argmin(&self) -> Option<usize> {
        self.wmd
            .iter()
            .enumerate()
            .filter(|(_, v)| v.is_finite())
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
    }
}

struct Job {
    req: QueryRequest,
    reply: mpsc::Sender<QueryResponse>,
}

/// Handle to the running service. Dropping it shuts the dispatcher down.
pub struct WmdService {
    queue: Arc<BatchQueue<Job>>,
    metrics: Arc<Metrics>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl WmdService {
    /// Start the dispatcher thread. `pjrt_dir` optionally points at the
    /// AOT artifacts directory; the PJRT client is **not** `Send` (the
    /// `xla` crate wraps an `Rc`), so the backend is constructed on the
    /// dispatcher thread itself. Loading failures degrade to the sparse
    /// backend (logged to stderr), matching "artifacts not built yet".
    pub fn start(
        store: Arc<DocStore>,
        config: ServiceConfig,
        pjrt_dir: Option<std::path::PathBuf>,
    ) -> Self {
        let queue = Arc::new(BatchQueue::new(config.batcher));
        let metrics = Arc::new(Metrics::new());
        let worker = {
            let queue = Arc::clone(&queue);
            let metrics = Arc::clone(&metrics);
            std::thread::Builder::new()
                .name("wmd-dispatch".into())
                .spawn(move || {
                    let pjrt = pjrt_dir.and_then(|dir| match PjrtBackend::load(&dir, &store) {
                        Ok(b) => b,
                        Err(e) => {
                            eprintln!("wmd-service: PJRT backend unavailable: {e:#}");
                            None
                        }
                    });
                    dispatcher(store, config, pjrt, queue, metrics)
                })
                .expect("spawn dispatcher")
        };
        Self { queue, metrics, worker: Some(worker) }
    }

    /// Submit a query; the response arrives on the returned channel.
    pub fn submit(&self, req: QueryRequest) -> mpsc::Receiver<QueryResponse> {
        let (tx, rx) = mpsc::channel();
        if !self.queue.push(Job { req, reply: tx.clone() }) {
            let _ = tx.send(QueryResponse {
                wmd: vec![],
                iterations: 0,
                backend: Backend::SparseRust,
                latency: Duration::ZERO,
                error: Some("service is shut down".into()),
            });
        }
        rx
    }

    /// Submit and block for the answer.
    pub fn submit_wait(&self, req: QueryRequest) -> QueryResponse {
        self.submit(req).recv().expect("dispatcher dropped the reply channel")
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Graceful shutdown: drain in-flight work, join the dispatcher.
    pub fn shutdown(mut self) {
        self.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for WmdService {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn dispatcher(
    store: Arc<DocStore>,
    config: ServiceConfig,
    pjrt: Option<PjrtBackend>,
    queue: Arc<BatchQueue<Job>>,
    metrics: Arc<Metrics>,
) {
    let nthreads = if config.threads == 0 { crate::util::num_cpus() } else { config.threads };
    let pool = Pool::new(nthreads);
    let sparse = SparseSolver::new(config.sinkhorn);
    let dense = DenseSolver::new(config.sinkhorn);
    // The cache lives on the dispatcher thread — no locking on the hot path.
    let mut cache = (config.prepare_cache > 0).then(|| {
        let cache = PreparedCache::new(config.prepare_cache);
        if config.prepare_cache_bytes > 0 {
            cache.with_max_bytes(config.prepare_cache_bytes)
        } else {
            cache
        }
    });
    while let Some(batch) = queue.next_batch() {
        metrics.record_batch(batch.len());
        for job in batch {
            let started = Instant::now();
            let response = answer(
                &store,
                &config,
                &pool,
                &sparse,
                &dense,
                pjrt.as_ref(),
                cache.as_mut(),
                &metrics,
                &job.req,
            );
            let latency = started.elapsed();
            match &response {
                Ok((wmd, iterations, backend)) => {
                    metrics.record_query(latency, *backend);
                    let _ = job.reply.send(QueryResponse {
                        wmd: wmd.clone(),
                        iterations: *iterations,
                        backend: *backend,
                        latency,
                        error: None,
                    });
                }
                Err(msg) => {
                    metrics.record_error();
                    let _ = job.reply.send(QueryResponse {
                        wmd: vec![],
                        iterations: 0,
                        backend: Backend::SparseRust,
                        latency,
                        error: Some(msg.clone()),
                    });
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn answer(
    store: &DocStore,
    config: &ServiceConfig,
    pool: &Pool,
    sparse: &SparseSolver,
    dense: &DenseSolver,
    pjrt: Option<&PjrtBackend>,
    cache: Option<&mut PreparedCache>,
    metrics: &Metrics,
    req: &QueryRequest,
) -> Result<(Vec<Real>, usize, Backend), String> {
    store.check_query(&req.query)?;
    let prefer = req.prefer.unwrap_or(config.prefer);
    let backend = match (prefer, pjrt) {
        (Backend::DensePjrt, Some(b)) if b.router().bucket_for(req.query.nnz()).is_some() => {
            Backend::DensePjrt
        }
        (Backend::DensePjrt, _) => Backend::SparseRust,
        (other, _) => other,
    };
    // The PJRT graph bakes its own precompute in; only the in-process
    // solvers consume `dist` factors (and hence the cache).
    if backend == Backend::DensePjrt {
        let b = pjrt.expect("checked above");
        let wmd = b
            .solve(&req.query, &store.embeddings)
            .map_err(|e| format!("pjrt backend: {e:#}"))?;
        return Ok((wmd, b.max_v_r(), backend));
    }
    // Resolve the prepared factors: cache hit, cache fill, or (cache
    // disabled) a one-shot local prepare. Both solvers share the same
    // factors — `precompute_factors` with the service λ.
    let prepare = || sparse.prepare(&store.embeddings, &req.query, pool);
    let local;
    let prep: &Prepared = match cache {
        Some(cache) => {
            let key = PreparedKey::new(&req.query, config.sinkhorn.lambda);
            let (prep, hit) = cache.get_or_insert_with(key, prepare);
            metrics.record_prepare_cache(hit);
            prep
        }
        None => {
            local = prepare();
            &local
        }
    };
    match backend {
        Backend::SparseRust => {
            let out = sparse.solve(prep, &store.c, pool);
            Ok((out.wmd, out.iterations, backend))
        }
        Backend::DenseRust => {
            let (out, _times) = dense.solve_prepared(prep, &store.c, pool);
            Ok((out.wmd, out.iterations, backend))
        }
        Backend::DensePjrt => unreachable!("handled above"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::SyntheticCorpus;

    fn small_service() -> (WmdService, SyntheticCorpus) {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(500)
            .num_docs(40)
            .embedding_dim(16)
            .num_queries(4)
            .query_words(5, 10)
            .seed(3)
            .build();
        let store = DocStore::from_synthetic(&corpus).into_arc();
        let service = WmdService::start(
            store,
            ServiceConfig { threads: 2, ..Default::default() },
            None,
        );
        (service, corpus)
    }

    #[test]
    fn answers_queries() {
        let (service, corpus) = small_service();
        let resp = service.submit_wait(QueryRequest::new(corpus.query(0).clone()));
        assert!(resp.is_ok(), "{:?}", resp.error);
        assert_eq!(resp.wmd.len(), 40);
        assert!(resp.argmin().is_some());
        assert!(resp.latency > Duration::ZERO);
        service.shutdown();
    }

    #[test]
    fn rejects_invalid_query() {
        let (service, _corpus) = small_service();
        let bad = SparseVec::from_counts(7, &[(1, 1)]); // wrong dim
        let resp = service.submit_wait(QueryRequest::new(bad));
        assert!(!resp.is_ok());
        assert_eq!(service.metrics().snapshot().errors, 1);
        service.shutdown();
    }

    #[test]
    fn concurrent_submissions_batch() {
        let (service, corpus) = small_service();
        let receivers: Vec<_> = (0..4)
            .map(|i| service.submit(QueryRequest::new(corpus.query(i).clone())))
            .collect();
        for rx in receivers {
            let resp = rx.recv().unwrap();
            assert!(resp.is_ok());
        }
        let snap = service.metrics().snapshot();
        assert_eq!(snap.queries, 4);
        assert!(snap.batches >= 1);
        service.shutdown();
    }

    #[test]
    fn dense_backend_agrees_with_sparse() {
        let (service, corpus) = small_service();
        let q = corpus.query(1).clone();
        let a = service.submit_wait(QueryRequest::new(q.clone()));
        let b = service.submit_wait(QueryRequest { query: q, prefer: Some(Backend::DenseRust) });
        assert!(a.is_ok() && b.is_ok());
        assert_eq!(b.backend, Backend::DenseRust);
        // Dense baseline runs fixed max_iter without early exit; compare
        // loosely (both near the fixed point).
        for (x, y) in a.wmd.iter().zip(&b.wmd) {
            assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "{x} vs {y}");
        }
        service.shutdown();
    }

    #[test]
    fn prepared_cache_hit_is_bitwise_identical_and_skips_precompute() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(400)
            .num_docs(30)
            .embedding_dim(12)
            .num_queries(2)
            .query_words(5, 9)
            .seed(19)
            .build();
        let store = DocStore::from_synthetic(&corpus).into_arc();
        // One solver thread → a fully deterministic solve, so a warm
        // answer must reproduce the cold answer bit for bit.
        let service = WmdService::start(
            store,
            ServiceConfig { threads: 1, ..Default::default() },
            None,
        );
        let q = corpus.query(0).clone();
        let cold = service.submit_wait(QueryRequest::new(q.clone()));
        let warm = service.submit_wait(QueryRequest::new(q));
        assert!(cold.is_ok() && warm.is_ok());
        let snap = service.metrics().snapshot();
        assert_eq!(snap.prepare_cache_misses, 1, "cold solve fills the cache");
        assert_eq!(snap.prepare_cache_hits, 1, "warm solve skips precompute_factors");
        assert_eq!(cold.wmd, warm.wmd, "cache hit must not perturb the WMD");
        // A different query is a miss, not a false hit.
        let other = service.submit_wait(QueryRequest::new(corpus.query(1).clone()));
        assert!(other.is_ok());
        let snap = service.metrics().snapshot();
        assert_eq!(snap.prepare_cache_misses, 2);
        assert_eq!(snap.prepare_cache_hits, 1);
        assert_ne!(other.wmd, cold.wmd);
        service.shutdown();
    }

    #[test]
    fn cache_disabled_still_answers() {
        let (service, corpus) = {
            let corpus = SyntheticCorpus::builder()
                .vocab_size(300)
                .num_docs(20)
                .embedding_dim(8)
                .num_queries(1)
                .query_words(4, 4)
                .seed(5)
                .build();
            let store = DocStore::from_synthetic(&corpus).into_arc();
            let service = WmdService::start(
                store,
                ServiceConfig { threads: 1, prepare_cache: 0, ..Default::default() },
                None,
            );
            (service, corpus)
        };
        let a = service.submit_wait(QueryRequest::new(corpus.query(0).clone()));
        let b = service.submit_wait(QueryRequest::new(corpus.query(0).clone()));
        assert!(a.is_ok() && b.is_ok());
        let snap = service.metrics().snapshot();
        assert_eq!(snap.prepare_cache_hits, 0);
        assert_eq!(snap.prepare_cache_misses, 0);
        assert_eq!(a.wmd, b.wmd);
        service.shutdown();
    }

    #[test]
    fn shutdown_drains_in_flight_work() {
        let (service, corpus) = small_service();
        // Submit work, then shut down immediately: the dispatcher must
        // drain the queue before exiting, so every reply still arrives.
        let receivers: Vec<_> = (0..3)
            .map(|i| service.submit(QueryRequest::new(corpus.query(i).clone())))
            .collect();
        service.shutdown();
        for rx in receivers {
            let resp = rx.recv().expect("reply delivered before shutdown completed");
            assert!(resp.is_ok());
        }
    }
}
