//! Query routing: backend selection + size-bucket padding for the
//! shape-specialized PJRT artifacts.
//!
//! HLO artifacts are compiled for fixed `v_r` buckets (DESIGN.md §6). A
//! query with `v_r = 19` routed to the `v_r = 32` bucket is padded with
//! `ε`-mass words; the perturbation of the WMD is `O(ε)` (tested in
//! `rust/tests/coordinator_test.rs`).

use crate::corpus::SparseVec;
use crate::Real;

/// Which solver answers a query.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Backend {
    /// The paper's sparse fused SDDMM_SpMM solver (default).
    #[default]
    SparseRust,
    /// The dense in-Rust baseline (profiling / Table 1).
    DenseRust,
    /// The dense L2 JAX graph executed through PJRT.
    DensePjrt,
}

impl Backend {
    /// Whether the sharded (column-partitioned) dispatch path can serve
    /// this backend. Only the in-process sparse solver consumes target
    /// slices; the dense baseline and the PJRT artifacts are built
    /// against the full target set, so they stay monolithic even when
    /// the service runs sharded.
    pub fn supports_sharding(self) -> bool {
        matches!(self, Backend::SparseRust)
    }
}

/// Padding strategy: the query's heaviest word is **duplicated** into
/// `bucket − v_r + 1` co-located entries with its mass split equally.
/// Splitting a supply point into identical copies leaves the optimal
/// transport problem — and the Sinkhorn fixed point — *exactly* unchanged
/// (identical cost rows scale identically), unlike ε-mass ghost words,
/// whose `1/r` factors inject an O(1) shock into the iterate that decays
/// only at the (slow, λ-dependent) contraction rate.
pub const PAD_STRATEGY_NOTE: &str = "duplicate-split";

/// Router: owns the available `v_r` buckets (ascending) for the PJRT
/// backend and the padding policy.
#[derive(Clone, Debug, Default)]
pub struct Router {
    buckets: Vec<usize>,
}

impl Router {
    pub fn new(mut buckets: Vec<usize>) -> Self {
        buckets.sort_unstable();
        buckets.dedup();
        Self { buckets }
    }

    pub fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    /// Smallest bucket that fits `v_r`, if any.
    pub fn bucket_for(&self, v_r: usize) -> Option<usize> {
        self.buckets.iter().copied().find(|&b| b >= v_r)
    }

    /// Decide the backend: honour the preference when possible, fall back
    /// to the sparse solver (which handles any `v_r`).
    pub fn select(&self, query: &SparseVec, prefer: Backend) -> Backend {
        match prefer {
            Backend::DensePjrt if self.bucket_for(query.nnz()).is_some() => Backend::DensePjrt,
            Backend::DensePjrt => Backend::SparseRust,
            other => other,
        }
    }

    /// Pad a query up to `bucket` entries by duplicate-splitting its
    /// heaviest word (see [`PAD_STRATEGY_NOTE`]): total mass per word is
    /// preserved exactly, so the padded problem has the *same* WMD.
    /// Returns the query unchanged when it already has `bucket` words.
    ///
    /// The result may contain repeated indices (the duplicates); it is
    /// intended for solver/artifact input marshalling — `indices()` and
    /// `val` stay aligned, and both the Rust precompute and the JAX graph
    /// handle repeated rows by construction.
    pub fn pad_query(&self, query: &SparseVec, bucket: usize) -> SparseVec {
        assert!(bucket >= query.nnz(), "bucket smaller than query");
        if query.nnz() == bucket {
            return query.clone();
        }
        let extra = bucket - query.nnz();
        // Heaviest word: splitting it keeps every split mass as large as
        // possible (better conditioning of diag(1/r)).
        let heavy = query
            .val
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(pos, _)| pos)
            .expect("non-empty query");
        let word = query.idx[heavy];
        let split = query.val[heavy] / (extra + 1) as Real;
        let mut idx = Vec::with_capacity(bucket);
        let mut val = Vec::with_capacity(bucket);
        for (pos, (&i, &v)) in query.idx.iter().zip(&query.val).enumerate() {
            if pos == heavy {
                for _ in 0..=extra {
                    idx.push(word);
                    val.push(split);
                }
            } else {
                idx.push(i);
                val.push(v);
            }
        }
        SparseVec { dim: query.dim, idx, val }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(dim: usize, words: &[(usize, usize)]) -> SparseVec {
        SparseVec::from_counts(dim, words)
    }

    #[test]
    fn bucket_selection() {
        let r = Router::new(vec![32, 8, 16, 8]);
        assert_eq!(r.buckets(), &[8, 16, 32]);
        assert_eq!(r.bucket_for(5), Some(8));
        assert_eq!(r.bucket_for(8), Some(8));
        assert_eq!(r.bucket_for(9), Some(16));
        assert_eq!(r.bucket_for(33), None);
    }

    #[test]
    fn select_falls_back_when_no_bucket() {
        let r = Router::new(vec![8]);
        let small = q(100, &[(1, 1), (2, 1)]);
        let big_words: Vec<(usize, usize)> = (0..20).map(|i| (i, 1)).collect();
        let big = q(100, &big_words);
        assert_eq!(r.select(&small, Backend::DensePjrt), Backend::DensePjrt);
        assert_eq!(r.select(&big, Backend::DensePjrt), Backend::SparseRust);
        assert_eq!(r.select(&small, Backend::SparseRust), Backend::SparseRust);
    }

    #[test]
    fn padding_preserves_per_word_mass() {
        let r = Router::new(vec![8]);
        let query = q(50, &[(10, 3), (40, 1)]);
        let padded = r.pad_query(&query, 8);
        assert_eq!(padded.idx.len(), 8);
        assert!((padded.sum() - 1.0).abs() < 1e-12);
        // Indices stay sorted (non-decreasing: duplicates are adjacent).
        for w in padded.idx.windows(2) {
            assert!(w[0] <= w[1]);
        }
        // Per-word mass is exactly preserved (duplicate-split, not ε-mass).
        let mass_of = |word: u32, v: &SparseVec| -> f64 {
            v.idx.iter().zip(&v.val).filter(|(&i, _)| i == word).map(|(_, &m)| m).sum()
        };
        assert!((mass_of(10, &padded) - 0.75).abs() < 1e-15);
        assert!((mass_of(40, &padded) - 0.25).abs() < 1e-15);
        // The heaviest word (10) carries the duplicates: 7 entries.
        assert_eq!(padded.idx.iter().filter(|&&i| i == 10).count(), 7);
    }

    #[test]
    fn padding_noop_at_exact_size() {
        let r = Router::new(vec![2]);
        let query = q(10, &[(1, 1), (2, 1)]);
        assert_eq!(r.pad_query(&query, 2), query);
    }

    #[test]
    #[should_panic(expected = "bucket smaller")]
    fn padding_rejects_shrink() {
        let r = Router::new(vec![1]);
        let query = q(10, &[(1, 1), (2, 1)]);
        let _ = r.pad_query(&query, 1);
    }
}
