//! Sharded target-set dispatch — fig. 5's multi-socket model made real.
//!
//! The paper scales one query across the cores of a single shared-memory
//! node; its evaluation machine is 4 × 24-core sockets. This layer
//! partitions the *document* axis instead (the composition the PIUMA
//! follow-up, arXiv:2107.06433, and the LC-RWMD line, arXiv:1711.07227,
//! both use): the `V × N` target CSR is split by **column range** into
//! `S` independent slices, each owned by its own worker thread with its
//! own [`Pool`] (size a shard's pool to a socket and pin it there to
//! mirror the paper's topology). The coordinator fans each popped batch
//! out to every shard — reusing [`SparseSolver::solve_batch`] per shard —
//! and merges the per-shard `wmd` slices back into full-length responses
//! ([`SolveOutput::merge_shards`]).
//!
//! Prepared query factors ([`Prepared`]) depend only on the embeddings
//! and the query, **not** on the target slice, so they are shard-agnostic:
//! the dispatcher's `PreparedCache` keeps one entry per query and every
//! shard shares it through the same `Arc` — no per-shard precompute, no
//! per-shard cache key.

use super::live::EpochView;
use super::state::DocStore;
use crate::corpus::SparseVec;
use crate::parallel::Pool;
use crate::prune::{merge_topk, CascadeRetrieval, CascadeSpec, PrunedTopK};
use crate::sinkhorn::{
    Prepared, SinkhornConfig, SolveOutput, SolveWorkspace, SparseSolver, WorkspaceStats,
};
use crate::sparse::{Csr, Dense};
use std::ops::Range;
use std::sync::{mpsc, Arc};

/// One column slice of the target set.
#[derive(Clone, Debug)]
pub struct DocShard {
    /// Rebased `V × n_s` slice of the target CSR: local column `j`
    /// is global document `col_range.start + j`.
    pub c: Csr,
    /// The global column range this shard owns.
    pub col_range: Range<usize>,
}

/// The sharded view of a [`DocStore`]: the store itself (embeddings and
/// metadata are shard-agnostic and stay shared) plus `S` contiguous
/// column slices of its target matrix, in order.
#[derive(Clone, Debug)]
pub struct ShardedDocStore {
    store: Arc<DocStore>,
    shards: Vec<DocShard>,
}

impl ShardedDocStore {
    /// Split into `s` contiguous column ranges balanced by **non-zeros**:
    /// the per-shard iterate cost is O(nnz·v_r), so nnz — not column
    /// count — is the load to equalize (the same yardstick as the
    /// nnz-balanced row partitioner inside each pool). Falls back to an
    /// even column split for an all-empty matrix.
    pub fn split(store: Arc<DocStore>, s: usize) -> Self {
        assert!(s >= 1, "need at least one shard");
        let n = store.num_docs();
        let mut prefix = vec![0usize; n + 1];
        for &j in store.c.col_idx() {
            prefix[j as usize + 1] += 1;
        }
        for j in 0..n {
            prefix[j + 1] += prefix[j];
        }
        Self::with_ranges(store, nnz_balanced_ranges(&prefix, s))
    }

    /// Build from explicit ranges: they must tile `0..num_docs` in order
    /// (contiguous, no gaps or overlaps). Empty ranges are allowed — a
    /// zero-column shard answers immediately with an empty slice and the
    /// merge skips over it.
    pub fn with_ranges(store: Arc<DocStore>, ranges: Vec<Range<usize>>) -> Self {
        assert!(!ranges.is_empty(), "need at least one shard");
        let n = store.num_docs();
        let mut expect = 0usize;
        for r in &ranges {
            assert_eq!(r.start, expect, "shard ranges must be contiguous and in order");
            assert!(r.end >= r.start && r.end <= n, "shard range {r:?} out of bounds");
            expect = r.end;
        }
        assert_eq!(expect, n, "shard ranges must cover every target column");
        let shards = ranges
            .into_iter()
            .map(|r| DocShard { c: store.c.slice_columns(r.clone()), col_range: r })
            .collect();
        Self { store, shards }
    }

    pub fn store(&self) -> &Arc<DocStore> {
        &self.store
    }

    pub fn shards(&self) -> &[DocShard] {
        &self.shards
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn num_docs(&self) -> usize {
        self.store.num_docs()
    }

    /// Per-shard document centroids (the `prune` phase-1 precompute):
    /// shard `s`'s matrix equals rows `col_range` of the full-corpus
    /// [`crate::prune::centroids`], so shard-local pruned retrieval uses
    /// the same WCD/RWMD bounds it would see unsharded.
    pub fn shard_centroids(&self, pool: &Pool) -> Vec<Dense> {
        self.shards
            .iter()
            .map(|sh| crate::prune::centroids(&self.store.embeddings, &sh.c, pool))
            .collect()
    }
}

/// `S` contiguous column ranges balanced by non-zeros, from an nnz
/// prefix-sum over the columns (`prefix.len() == n + 1`): the per-shard
/// iterate cost is O(nnz·v_r), so nnz — not column count — is the load
/// to equalize. Falls back to an even column split when there are no
/// non-zeros at all.
fn nnz_balanced_ranges(prefix: &[usize], s: usize) -> Vec<Range<usize>> {
    let n = prefix.len() - 1;
    let total = prefix[n];
    let mut ranges = Vec::with_capacity(s);
    let mut start = 0usize;
    for k in 1..=s {
        let end = if k == s {
            n
        } else if total == 0 {
            crate::parallel::static_chunk(n, k - 1, s).end
        } else {
            // First column boundary whose nnz prefix reaches shard k's
            // fair share.
            let target = total * k / s;
            prefix.partition_point(|&p| p < target).clamp(start, n)
        };
        ranges.push(start..end);
        start = end;
    }
    ranges
}

/// One worker-held slice of the (possibly segmented) target set: a
/// column range of one epoch segment, with its global start. A static
/// store gives every worker exactly one sub-segment; live appends add
/// whole delta segments, so a worker may own several.
struct WorkerSub {
    c: Arc<Csr>,
    start: usize,
    /// Lazily-built centroid rows for this sub-segment's cascade. Tied to
    /// the sub's lifetime: replacing the sub (delete, rebalance) drops the
    /// centroids with it, so they can never go stale.
    centroids: Option<Dense>,
}

/// Per-sub-segment solve result: `(global col_start, one output per
/// prepared query)`.
type SolvePart = (usize, Vec<SolveOutput>);

enum ShardJob {
    /// One batched solve over every sub-segment this worker owns.
    Solve {
        preps: Vec<Arc<Prepared>>,
        reply: mpsc::Sender<(usize, Vec<SolvePart>, WorkspaceStats)>,
        shard: usize,
    },
    /// One cascade retrieval per owned sub-segment (top-k in global ids
    /// after the coordinator's merge). `allowed` is the global admission
    /// mask (deleted / out-of-window documents), sliced per sub.
    Retrieve {
        query: SparseVec,
        prep: Arc<Prepared>,
        k: usize,
        allowed: Option<Arc<Vec<bool>>>,
        reply: mpsc::Sender<(usize, Vec<(usize, PrunedTopK)>, WorkspaceStats)>,
        shard: usize,
    },
    /// Live append: take ownership of one whole delta segment.
    AddSegment { c: Arc<Csr>, start: usize },
    /// Live rebalance / delete: replace every owned sub-segment.
    Reset { subs: Vec<(Arc<Csr>, usize)> },
}

struct ShardWorker {
    tx: Option<mpsc::Sender<ShardJob>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// Coordinator-side record of one sub-segment assignment.
#[derive(Clone, Copy, Debug)]
struct SubMeta {
    start: usize,
    len: usize,
    nnz: usize,
}

/// Merged result of one sharded batch dispatch.
#[derive(Clone, Debug)]
pub struct ShardBatchOutput {
    /// One merged full-length [`SolveOutput`] per query (see
    /// [`SolveOutput::merge_shards`] for the merge semantics).
    pub outputs: Vec<SolveOutput>,
    /// Sinkhorn iterations executed per shard, summed over the batch's
    /// queries — the per-shard counts the service folds into its metrics.
    pub shard_iterations: Vec<usize>,
    /// Per-shard workspace counters (cumulative per worker, snapshotted
    /// after this batch) — each worker owns one long-lived
    /// [`SolveWorkspace`] sized to its column slice, and this is where
    /// its reuse is observable per shard.
    pub workspace: Vec<WorkspaceStats>,
}

/// Identity of the last [`EpochView`] the workers were synced to: the
/// epoch, one `(start, Arc pointer)` pair per segment, and the tombstone
/// count. Segments are immutable once published (deletes copy-on-write
/// into fresh allocations), so pointer equality is a sound and O(1)
/// "same segment" test.
struct SyncedView {
    epoch: u64,
    segments: Vec<(usize, usize)>,
    deleted: usize,
}

/// A running shard fleet: one worker thread per [`DocShard`], each owning
/// one or more sub-segments of the target set, its own [`Pool`] and a
/// [`SparseSolver`]. [`ShardSet::solve_batch`] fans one prepared batch
/// out to every shard concurrently and merges the slices;
/// [`ShardSet::sync`] follows a live store across epochs (appended delta
/// segments ship whole to the least-loaded worker; deletes and
/// compactions trigger a full nnz-rebalanced repartition). Dropping the
/// set shuts the workers down.
pub struct ShardSet {
    workers: Vec<ShardWorker>,
    total_docs: usize,
    /// Coordinator-side mirror of each worker's sub-segments — drives the
    /// least-loaded placement of appends and the rebalance decision.
    assigned: Vec<Vec<SubMeta>>,
    synced: Option<SyncedView>,
}

impl ShardSet {
    /// Spawn one worker per shard, each with a `threads_per_shard`-wide
    /// pool. With `threads_per_shard = 1` every shard solves serially,
    /// so a sharded run is bitwise-reproducible (the property the
    /// equivalence tests pin down).
    ///
    /// Consumes the sharded store: each shard's slice **moves** into its
    /// worker thread (the slices together are the size of the full
    /// target CSR — cloning them would transiently double that at
    /// startup).
    pub fn start(
        sharded: ShardedDocStore,
        config: SinkhornConfig,
        threads_per_shard: usize,
    ) -> Self {
        Self::start_with_cascade(sharded, config, threads_per_shard, CascadeSpec::default())
    }

    /// [`ShardSet::start`] with an explicit retrieval cascade: every
    /// worker builds its own [`CascadeRetrieval`] from `spec`, so
    /// [`ShardSet::retrieve_topk`] runs the same staged bounds
    /// shard-locally (per-shard budgets) and the merged top-k is exact at
    /// unbounded budgets.
    pub fn start_with_cascade(
        sharded: ShardedDocStore,
        config: SinkhornConfig,
        threads_per_shard: usize,
        spec: CascadeSpec,
    ) -> Self {
        assert!(threads_per_shard >= 1, "each shard pool needs at least one thread");
        let ShardedDocStore { store, shards } = sharded;
        let total_docs = store.num_docs();
        let mut assigned = Vec::with_capacity(shards.len());
        let workers = shards
            .into_iter()
            .enumerate()
            .map(|(idx, shard)| {
                let start = shard.col_range.start;
                let metas = if shard.c.ncols() == 0 {
                    Vec::new()
                } else {
                    vec![SubMeta { start, len: shard.c.ncols(), nnz: shard.c.nnz() }]
                };
                // A zero-column shard starts with no sub-segments: it
                // answers every job with zero parts and the merges skip
                // over it.
                let initial: Vec<(Arc<Csr>, usize)> = if shard.c.ncols() == 0 {
                    Vec::new()
                } else {
                    vec![(Arc::new(shard.c), start)]
                };
                assigned.push(metas);
                let (tx, rx) = mpsc::channel::<ShardJob>();
                let store = Arc::clone(&store);
                let spec = spec.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("wmd-shard-{idx}"))
                    .spawn(move || {
                        let pool = Pool::new(threads_per_shard);
                        let solver = SparseSolver::new(config);
                        let retrieval = CascadeRetrieval::new(config, spec);
                        let mut subs: Vec<WorkerSub> = initial
                            .into_iter()
                            .map(|(c, start)| WorkerSub { c, start, centroids: None })
                            .collect();
                        // One long-lived workspace per shard worker: its
                        // buffers grow to the largest sub-segment's shapes
                        // once, then every subsequent batch solves
                        // allocation-free.
                        let mut ws = SolveWorkspace::new();
                        while let Ok(job) = rx.recv() {
                            match job {
                                ShardJob::Solve { preps, reply, shard } => {
                                    let refs: Vec<&Prepared> =
                                        preps.iter().map(|p| p.as_ref()).collect();
                                    let mut parts: Vec<SolvePart> =
                                        Vec::with_capacity(subs.len());
                                    for sub in &subs {
                                        if sub.c.ncols() == 0 {
                                            continue;
                                        }
                                        let outs =
                                            solver.solve_batch_in(&mut ws, &refs, &sub.c, &pool);
                                        parts.push((sub.start, outs));
                                    }
                                    let _ = reply.send((shard, parts, ws.stats()));
                                }
                                ShardJob::Retrieve { query, prep, k, allowed, reply, shard } => {
                                    let mut parts = Vec::with_capacity(subs.len());
                                    for sub in &mut subs {
                                        if sub.c.ncols() == 0 {
                                            continue;
                                        }
                                        // Sub-local centroid rows for the
                                        // cascade's WCD stage, built on the
                                        // first retrieval (solve-only
                                        // deployments never pay for them).
                                        if sub.centroids.is_none() {
                                            sub.centroids = Some(crate::prune::centroids(
                                                &store.embeddings,
                                                &sub.c,
                                                &pool,
                                            ));
                                        }
                                        let cents =
                                            sub.centroids.as_ref().expect("just built");
                                        let local = allowed
                                            .as_deref()
                                            .map(|m| &m[sub.start..sub.start + sub.c.ncols()]);
                                        let out = retrieval.retrieve_prepared_masked_in(
                                            &mut ws,
                                            &store.embeddings,
                                            &query,
                                            &prep,
                                            &sub.c,
                                            cents,
                                            &pool,
                                            k,
                                            local,
                                        );
                                        parts.push((sub.start, out));
                                    }
                                    let _ = reply.send((shard, parts, ws.stats()));
                                }
                                ShardJob::AddSegment { c, start } => {
                                    subs.push(WorkerSub { c, start, centroids: None });
                                }
                                ShardJob::Reset { subs: next } => {
                                    subs = next
                                        .into_iter()
                                        .map(|(c, start)| WorkerSub { c, start, centroids: None })
                                        .collect();
                                }
                            }
                        }
                    })
                    .expect("spawn shard worker");
                ShardWorker { tx: Some(tx), handle: Some(handle) }
            })
            .collect();
        Self { workers, total_docs, assigned, synced: None }
    }

    pub fn num_shards(&self) -> usize {
        self.workers.len()
    }

    /// Fan one prepared batch out to every shard, wait for all slices,
    /// and merge back into one full-length [`SolveOutput`] per query.
    pub fn solve_batch(&self, preps: &[Arc<Prepared>]) -> ShardBatchOutput {
        let b = preps.len();
        let s = self.workers.len();
        if b == 0 {
            return ShardBatchOutput {
                outputs: Vec::new(),
                shard_iterations: vec![0; s],
                workspace: vec![WorkspaceStats::default(); s],
            };
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        for (idx, w) in self.workers.iter().enumerate() {
            w.tx
                .as_ref()
                .expect("shard worker running")
                .send(ShardJob::Solve {
                    preps: preps.to_vec(),
                    reply: reply_tx.clone(),
                    shard: idx,
                })
                .expect("shard worker alive");
        }
        drop(reply_tx);
        let mut per_shard: Vec<Option<Vec<SolvePart>>> = (0..s).map(|_| None).collect();
        let mut workspace = vec![WorkspaceStats::default(); s];
        for _ in 0..s {
            let (idx, parts, ws_stats) =
                reply_rx.recv().expect("a shard worker died mid-batch");
            debug_assert!(
                parts.iter().all(|(_, outs)| outs.len() == b),
                "shard {idx} answered a different batch size"
            );
            per_shard[idx] = Some(parts);
            workspace[idx] = ws_stats;
        }
        let per_shard: Vec<Vec<SolvePart>> =
            per_shard.into_iter().map(|o| o.expect("every shard replied")).collect();
        let shard_iterations: Vec<usize> = per_shard
            .iter()
            .map(|parts| {
                parts.iter().map(|(_, outs)| outs.iter().map(|o| o.iterations).sum::<usize>()).sum()
            })
            .collect();
        // One column of outputs per sub-segment part, consumed query by
        // query; `merge_shards` asserts the parts tile `0..total_docs`
        // exactly, so a worker/view mismatch is caught, not smeared.
        let mut columns: Vec<(usize, std::vec::IntoIter<SolveOutput>)> = per_shard
            .into_iter()
            .flatten()
            .map(|(start, outs)| (start, outs.into_iter()))
            .collect();
        let outputs = (0..b)
            .map(|_| {
                let parts: Vec<(usize, SolveOutput)> = columns
                    .iter_mut()
                    .map(|(start, it)| (*start, it.next().expect("one output per query")))
                    .collect();
                SolveOutput::merge_shards(self.total_docs, &parts)
            })
            .collect();
        ShardBatchOutput { outputs, shard_iterations, workspace }
    }

    /// Fan one top-k retrieval out to every shard's cascade and merge the
    /// shard-local top-ks into the global answer ([`merge_topk`] rebases
    /// local ids by each shard's column offset and sums the stage stats).
    /// Exactness: every shard keeps its local top `k` (sub-solve
    /// distances are per-candidate and thus shard-invariant), so the
    /// merged set contains the global top `k` whenever budgets are
    /// unbounded.
    pub fn retrieve_topk(
        &self,
        query: &SparseVec,
        prep: &Arc<Prepared>,
        k: usize,
    ) -> (PrunedTopK, Vec<WorkspaceStats>) {
        self.retrieve_topk_masked(query, prep, k, None)
    }

    /// [`ShardSet::retrieve_topk`] under a global admission mask:
    /// `allowed[j] == false` removes global document `j` from every
    /// shard-local candidate set (deleted documents, out-of-window
    /// timestamps). `None` is the unmasked fast path.
    pub fn retrieve_topk_masked(
        &self,
        query: &SparseVec,
        prep: &Arc<Prepared>,
        k: usize,
        allowed: Option<Arc<Vec<bool>>>,
    ) -> (PrunedTopK, Vec<WorkspaceStats>) {
        let s = self.workers.len();
        let (reply_tx, reply_rx) = mpsc::channel();
        for (idx, w) in self.workers.iter().enumerate() {
            w.tx
                .as_ref()
                .expect("shard worker running")
                .send(ShardJob::Retrieve {
                    query: query.clone(),
                    prep: Arc::clone(prep),
                    k,
                    allowed: allowed.clone(),
                    reply: reply_tx.clone(),
                    shard: idx,
                })
                .expect("shard worker alive");
        }
        drop(reply_tx);
        let mut per_shard: Vec<Option<Vec<(usize, PrunedTopK)>>> = (0..s).map(|_| None).collect();
        let mut workspace = vec![WorkspaceStats::default(); s];
        for _ in 0..s {
            let (idx, parts, ws_stats) =
                reply_rx.recv().expect("a shard worker died mid-retrieval");
            per_shard[idx] = Some(parts);
            workspace[idx] = ws_stats;
        }
        let parts: Vec<(usize, PrunedTopK)> = per_shard
            .into_iter()
            .flat_map(|p| p.expect("every shard replied"))
            .collect();
        (merge_topk(&parts, k), workspace)
    }

    /// Bring the workers up to date with a live store's `view`. Epoch
    /// unchanged ⇒ no-op. An **append-only** bump (same tombstone count,
    /// the previously-synced segments an identical prefix of the view's)
    /// ships each new delta segment whole to the worker with the least
    /// total nnz — per-shard delta segments, no resharding cost. Any
    /// other bump (delete's copy-on-write segment swap, compaction's
    /// base fold) repartitions all columns into `S` contiguous
    /// nnz-balanced ranges and resets every worker.
    ///
    /// Callers serialize `sync` with `solve_batch`/`retrieve_topk`
    /// (&mut self here, dispatcher-thread usage in practice), so a batch
    /// pinned to view `E` is fully answered before the workers move to
    /// `E+1` — the epoch-pinning contract.
    pub fn sync(&mut self, view: &EpochView) {
        if self.synced.as_ref().is_some_and(|s| s.epoch == view.epoch) {
            return;
        }
        let identity: Vec<(usize, usize)> = view
            .segments
            .iter()
            .map(|seg| (seg.start, Arc::as_ptr(&seg.c) as *const u8 as usize))
            .collect();
        let append_only = match &self.synced {
            Some(s) => {
                s.deleted == view.deleted.len()
                    && view.segments.len() >= s.segments.len()
                    && identity[..s.segments.len()] == s.segments[..]
            }
            // Never synced: the constructor's split mirrors the base
            // segment of an epoch-0 view exactly, so there is nothing to
            // ship yet. Any other first view (snapshot restore, prior
            // mutations) needs the full repartition below.
            None => view.epoch == 0,
        };
        if append_only {
            let start_at = self.synced.as_ref().map_or(1, |s| s.segments.len());
            for seg in &view.segments[start_at..] {
                let w = (0..self.workers.len())
                    .min_by_key(|&i| self.assigned[i].iter().map(|m| m.nnz).sum::<usize>())
                    .expect("at least one worker");
                self.workers[w]
                    .tx
                    .as_ref()
                    .expect("shard worker running")
                    .send(ShardJob::AddSegment { c: Arc::clone(&seg.c), start: seg.start })
                    .expect("shard worker alive");
                self.assigned[w].push(SubMeta {
                    start: seg.start,
                    len: seg.num_docs(),
                    nnz: seg.c.nnz(),
                });
            }
        } else {
            let n = view.num_docs();
            let mut prefix = vec![0usize; n + 1];
            for seg in &view.segments {
                for &j in seg.c.col_idx() {
                    prefix[seg.start + j as usize + 1] += 1;
                }
            }
            for j in 0..n {
                prefix[j + 1] += prefix[j];
            }
            let ranges = nnz_balanced_ranges(&prefix, self.workers.len());
            for (w, r) in ranges.into_iter().enumerate() {
                let mut subs: Vec<(Arc<Csr>, usize)> = Vec::new();
                let mut metas: Vec<SubMeta> = Vec::new();
                for seg in &view.segments {
                    let seg_r = seg.range();
                    let lo = seg_r.start.max(r.start);
                    let hi = seg_r.end.min(r.end);
                    if lo >= hi {
                        continue;
                    }
                    // A segment falling wholly inside one range ships by
                    // Arc clone; a straddling segment is sliced at the
                    // range boundary.
                    let (c, start) = if lo == seg_r.start && hi == seg_r.end {
                        (Arc::clone(&seg.c), seg.start)
                    } else {
                        (Arc::new(seg.c.slice_columns(lo - seg.start..hi - seg.start)), lo)
                    };
                    metas.push(SubMeta { start, len: hi - lo, nnz: c.nnz() });
                    subs.push((c, start));
                }
                self.workers[w]
                    .tx
                    .as_ref()
                    .expect("shard worker running")
                    .send(ShardJob::Reset { subs })
                    .expect("shard worker alive");
                self.assigned[w] = metas;
            }
        }
        self.total_docs = view.num_docs();
        self.synced =
            Some(SyncedView { epoch: view.epoch, segments: identity, deleted: view.deleted.len() });
    }
}

impl Drop for ShardSet {
    fn drop(&mut self) {
        // Close every channel first (workers exit their recv loop), then
        // join — closing one-by-one would serialize the shutdowns.
        for w in &mut self.workers {
            w.tx.take();
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::SyntheticCorpus;

    fn corpus() -> SyntheticCorpus {
        SyntheticCorpus::builder()
            .vocab_size(400)
            .num_docs(30)
            .embedding_dim(12)
            .num_queries(4)
            .query_words(5, 9)
            .seed(41)
            .build()
    }

    #[test]
    fn split_tiles_the_columns() {
        let corpus = corpus();
        let store = DocStore::from_synthetic(&corpus).into_arc();
        for s in [1usize, 2, 3, 5] {
            let sharded = ShardedDocStore::split(Arc::clone(&store), s);
            assert_eq!(sharded.num_shards(), s);
            let mut expect = 0usize;
            let mut nnz = 0usize;
            for sh in sharded.shards() {
                assert_eq!(sh.col_range.start, expect);
                assert_eq!(sh.c.ncols(), sh.col_range.len());
                assert_eq!(sh.c.nrows(), store.vocab_size());
                expect = sh.col_range.end;
                nnz += sh.c.nnz();
            }
            assert_eq!(expect, store.num_docs());
            assert_eq!(nnz, store.c.nnz(), "slices must partition the nnz");
        }
    }

    #[test]
    fn with_ranges_allows_empty_shards() {
        let corpus = corpus();
        let store = DocStore::from_synthetic(&corpus).into_arc();
        let n = store.num_docs();
        let sharded =
            ShardedDocStore::with_ranges(Arc::clone(&store), vec![0..0, 0..n, n..n]);
        assert_eq!(sharded.num_shards(), 3);
        assert_eq!(sharded.shards()[0].c.ncols(), 0);
        assert_eq!(sharded.shards()[1].c.ncols(), n);
        assert_eq!(sharded.shards()[2].c.ncols(), 0);
    }

    #[test]
    #[should_panic(expected = "contiguous")]
    fn with_ranges_rejects_gaps() {
        let corpus = corpus();
        let store = DocStore::from_synthetic(&corpus).into_arc();
        let n = store.num_docs();
        let _ = ShardedDocStore::with_ranges(store, vec![0..5, 6..n]);
    }

    #[test]
    fn shard_workers_reuse_their_workspaces_across_batches() {
        let corpus = corpus();
        let store = DocStore::from_synthetic(&corpus).into_arc();
        let sharded = ShardedDocStore::split(Arc::clone(&store), 2);
        let set = ShardSet::start(sharded, SinkhornConfig::default(), 1);
        let pool = Pool::new(1);
        let solver = SparseSolver::new(SinkhornConfig::default());
        let preps: Vec<Arc<Prepared>> = corpus
            .queries
            .iter()
            .map(|q| Arc::new(solver.prepare(&corpus.embeddings, q, &pool)))
            .collect();
        let first = set.solve_batch(&preps);
        assert_eq!(first.workspace.len(), 2);
        for ws in &first.workspace {
            assert_eq!(ws.checkouts, 1, "one batched solve per shard");
            assert_eq!(ws.grows, 1, "the cold checkout grows the buffers");
            assert!(ws.bytes_retained > 0);
        }
        // Same batch again: warm workspaces, no growth, same retention.
        let second = set.solve_batch(&preps);
        for (a, b) in first.workspace.iter().zip(&second.workspace) {
            assert_eq!(b.checkouts, 2);
            assert_eq!(b.grows, a.grows, "steady-state batch must not grow the workspace");
            assert_eq!(b.bytes_retained, a.bytes_retained);
        }
    }

    #[test]
    fn sharded_retrieve_topk_matches_monolithic_cascade() {
        // 1-thread shards + per-candidate sub-solves ⇒ shard-local
        // distances are bitwise equal to the monolithic cascade's, so the
        // merged top-k must match it exactly for S ∈ {1, 2, 3}.
        let corpus = corpus();
        let store = DocStore::from_synthetic(&corpus).into_arc();
        let pool = Pool::new(1);
        let config = SinkhornConfig::default();
        let solver = SparseSolver::new(config);
        let retrieval = CascadeRetrieval::new(config, CascadeSpec::default());
        let cents = crate::prune::centroids(&store.embeddings, &store.c, &pool);
        let k = 5;
        for s in [1usize, 2, 3] {
            let sharded = ShardedDocStore::split(Arc::clone(&store), s);
            let set = ShardSet::start(sharded, config, 1);
            for (qi, q) in corpus.queries.iter().enumerate() {
                let prep = Arc::new(solver.prepare(&store.embeddings, q, &pool));
                let (merged, ws) = set.retrieve_topk(q, &prep, k);
                assert_eq!(ws.len(), s);
                let mono = retrieval.retrieve_prepared_in(
                    &mut SolveWorkspace::new(),
                    &store.embeddings,
                    q,
                    &prep,
                    &store.c,
                    &cents,
                    &pool,
                    k,
                );
                assert_eq!(merged.top, mono.top, "s={s} q={qi}");
                assert_eq!(merged.stats.total_docs, store.num_docs(), "s={s} q={qi}");
            }
        }
    }

    #[test]
    fn retrieve_topk_tolerates_empty_shards() {
        let corpus = corpus();
        let store = DocStore::from_synthetic(&corpus).into_arc();
        let n = store.num_docs();
        let sharded =
            ShardedDocStore::with_ranges(Arc::clone(&store), vec![0..0, 0..n, n..n]);
        let set = ShardSet::start(sharded, SinkhornConfig::default(), 1);
        let pool = Pool::new(1);
        let solver = SparseSolver::new(SinkhornConfig::default());
        let q = corpus.query(0);
        let prep = Arc::new(solver.prepare(&store.embeddings, q, &pool));
        let (merged, _) = set.retrieve_topk(q, &prep, 4);
        assert_eq!(merged.top.len(), 4);
        assert_eq!(merged.stats.total_docs, n, "only the populated shard contributes docs");
    }

    fn delta(vocab: usize, docs: usize, seed: u64) -> Csr {
        let mut rng = crate::util::Pcg64::new(seed);
        let mut coo = crate::sparse::Coo::new(vocab, docs);
        for j in 0..docs {
            for _ in 0..3 {
                coo.push(rng.below(vocab), j, rng.next_f64() + 0.1);
            }
        }
        Csr::from_coo(coo)
    }

    #[test]
    fn sync_ships_appended_segments_and_matches_the_monolithic_solve() {
        // Append-only epoch bumps ship whole delta segments to workers;
        // with 1-thread pools and a zero tolerance the sharded solve over
        // base + deltas must be bitwise equal to one monolithic solve over
        // the rebuilt matrix, for every shard count.
        let corpus = corpus();
        let store = DocStore::from_synthetic(&corpus).into_arc();
        let config =
            SinkhornConfig { tolerance: 0.0, max_iter: 12, ..SinkhornConfig::default() };
        let pool = Pool::new(1);
        let solver = SparseSolver::new(config);
        let preps: Vec<Arc<Prepared>> = corpus
            .queries
            .iter()
            .map(|q| Arc::new(solver.prepare(&store.embeddings, q, &pool)))
            .collect();
        let refs: Vec<&Prepared> = preps.iter().map(|p| p.as_ref()).collect();
        for s in [1usize, 2, 3] {
            let live = crate::coordinator::LiveDocStore::new(Arc::clone(&store));
            let mut set =
                ShardSet::start(ShardedDocStore::split(Arc::clone(&store), s), config, 1);
            set.sync(&live.view());
            live.append(delta(store.vocab_size(), 7, 1000 + s as u64), vec![10; 7]);
            live.append(delta(store.vocab_size(), 5, 2000 + s as u64), vec![20; 5]);
            let view = live.view();
            set.sync(&view);
            let merged = set.solve_batch(&preps);
            let mono = solver.solve_batch_in(
                &mut SolveWorkspace::new(),
                &refs,
                &view.rebuild_monolithic(),
                &pool,
            );
            assert_eq!(merged.outputs.len(), mono.len());
            for (a, b) in merged.outputs.iter().zip(&mono) {
                assert_eq!(a.wmd, b.wmd, "s={s}");
                assert_eq!(a.iterations, b.iterations, "s={s}");
            }
        }
    }

    #[test]
    fn sync_rebalances_after_delete_and_the_mask_hides_the_document() {
        // A delete swaps a segment copy-on-write, which is not an
        // append-only bump: sync must repartition (the Reset path) and the
        // emptied column must answer +inf while the admission mask keeps
        // the document out of top-k.
        let corpus = corpus();
        let store = DocStore::from_synthetic(&corpus).into_arc();
        let config =
            SinkhornConfig { tolerance: 0.0, max_iter: 12, ..SinkhornConfig::default() };
        let pool = Pool::new(1);
        let solver = SparseSolver::new(config);
        let q = corpus.query(0);
        let prep = Arc::new(solver.prepare(&store.embeddings, q, &pool));
        let live = crate::coordinator::LiveDocStore::new(Arc::clone(&store));
        live.append(delta(store.vocab_size(), 6, 77), vec![0; 6]);
        let victim = 3usize;
        live.delete(victim).unwrap();
        let view = live.view();
        let mut set = ShardSet::start(ShardedDocStore::split(Arc::clone(&store), 2), config, 1);
        set.sync(&view);
        let merged = set.solve_batch(&[Arc::clone(&prep)]);
        assert_eq!(merged.outputs.len(), 1);
        assert_eq!(merged.outputs[0].wmd.len(), view.num_docs());
        assert!(
            merged.outputs[0].wmd[victim].is_infinite(),
            "deleted document must answer +inf"
        );
        let mask = view.allowed_mask(None).map(Arc::new);
        assert!(mask.is_some(), "a deletion forces a real mask");
        let (topk, ws) = set.retrieve_topk_masked(q, &prep, view.num_docs(), mask);
        assert_eq!(ws.len(), 2);
        assert!(topk.top.iter().all(|&(j, _)| j != victim), "victim must not be retrievable");
        assert_eq!(topk.stats.total_docs, view.num_docs());
    }

    #[test]
    fn sync_is_idempotent_at_a_fixed_epoch() {
        let corpus = corpus();
        let store = DocStore::from_synthetic(&corpus).into_arc();
        let config = SinkhornConfig::default();
        let live = crate::coordinator::LiveDocStore::new(Arc::clone(&store));
        live.append(delta(store.vocab_size(), 4, 9), vec![0; 4]);
        let view = live.view();
        let mut set = ShardSet::start(ShardedDocStore::split(Arc::clone(&store), 2), config, 1);
        set.sync(&view);
        let before: Vec<usize> = set.assigned.iter().map(|a| a.len()).collect();
        set.sync(&view);
        set.sync(&live.view());
        assert_eq!(
            before,
            set.assigned.iter().map(|a| a.len()).collect::<Vec<_>>(),
            "re-syncing an unchanged epoch must not move segments"
        );
    }

    #[test]
    fn shard_centroids_match_full_centroid_rows() {
        let corpus = corpus();
        let store = DocStore::from_synthetic(&corpus).into_arc();
        let pool = Pool::new(2);
        let full = crate::prune::centroids(&store.embeddings, &store.c, &pool);
        let sharded = ShardedDocStore::split(Arc::clone(&store), 3);
        let per_shard = sharded.shard_centroids(&pool);
        for (sh, cents) in sharded.shards().iter().zip(&per_shard) {
            assert_eq!(cents.nrows(), sh.col_range.len());
            for (local, global) in sh.col_range.clone().enumerate() {
                for w in 0..full.ncols() {
                    let a = cents.get(local, w);
                    let b = full.get(global, w);
                    assert!(
                        (a - b).abs() <= 1e-12 * (1.0 + b.abs()),
                        "centroid mismatch at doc {global} dim {w}: {a} vs {b}"
                    );
                }
            }
        }
    }
}
