//! Repo-specific lint gate: `cargo run --bin lint-rules [-- --self-test]`.
//!
//! Scans the crate sources, tests, benches, the `xla` stub crate, and the
//! top-level examples for violations of the conventions in
//! [`sinkhorn_wmd::testing::lint`] (NaN-unsafe comparisons on score paths,
//! `unsafe` outside the audited module list, missing safety paperwork).
//! Exits non-zero on any violation, so CI can gate on it.
//!
//! `--self-test` first seeds one violation per rule through the scanner and
//! fails loudly if any rule does NOT fire — proving a green tree scan means
//! "no violations", not "scanner broke".

use sinkhorn_wmd::testing::lint;
use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let self_test = std::env::args().any(|a| a == "--self-test");
    if self_test {
        match lint::self_test() {
            Ok(caught) => {
                println!("self-test: all {} rules fired on seeded violations:", caught.len());
                for v in &caught {
                    println!("  caught {v}");
                }
            }
            Err(why) => {
                eprintln!("lint-rules self-test FAILED: {why}");
                return ExitCode::FAILURE;
            }
        }
    }

    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let violations = match lint::lint_tree(manifest, lint::DEFAULT_ROOTS) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("lint-rules: scan failed: {e}");
            return ExitCode::FAILURE;
        }
    };
    if violations.is_empty() {
        println!("lint-rules: tree clean ({} roots scanned)", lint::DEFAULT_ROOTS.len());
        ExitCode::SUCCESS
    } else {
        eprintln!("lint-rules: {} violation(s):", violations.len());
        for v in &violations {
            eprintln!("  {v}");
        }
        ExitCode::FAILURE
    }
}
