//! A tiny **real** corpus with hand-crafted semantic embeddings — enough
//! to run the paper's motivating example end-to-end without the 2 GB
//! `crawl-300d-2M` download: "Obama speaks to the media in Illinois" must
//! come out closer to "The President greets the press in Chicago" than to
//! unrelated sentences (paper §2, Fig. 1).
//!
//! Words are embedded in a 12-dimensional interpretable feature space
//! (politics, person, city, media, speech-act, food, sport, tech, ...);
//! synonyms share feature patterns, so Euclidean distance reflects
//! semantic relatedness the same way word2vec neighborhoods do.

use super::histogram::SparseVec;
use super::vocab::Vocabulary;
use crate::sparse::Dense;
use crate::Real;

/// Feature dimensions of the hand-crafted embedding space.
pub const TINY_DIM: usize = 12;

// (word, 12-dim feature vector). Related words differ by small offsets.
#[rustfmt::skip]
const WORDS: &[(&str, [f32; TINY_DIM])] = &[
    // politics / people         pol  per  cit  med  spk  foo  spo  tec  nat  fin  art  x
    ("obama",      [ 1.0, 1.0, 0.0, 0.0, 0.1, 0.0, 0.0, 0.0, 0.2, 0.0, 0.0, 0.10]),
    ("president",  [ 1.0, 0.9, 0.0, 0.0, 0.1, 0.0, 0.0, 0.0, 0.2, 0.0, 0.0, 0.15]),
    ("senator",    [ 0.9, 0.9, 0.0, 0.0, 0.1, 0.0, 0.0, 0.0, 0.2, 0.0, 0.0, 0.25]),
    ("governor",   [ 0.9, 0.9, 0.1, 0.0, 0.1, 0.0, 0.0, 0.0, 0.2, 0.0, 0.0, 0.30]),
    ("minister",   [ 0.9, 0.9, 0.0, 0.0, 0.1, 0.0, 0.0, 0.0, 0.3, 0.0, 0.0, 0.35]),
    ("election",   [ 1.0, 0.1, 0.0, 0.1, 0.0, 0.0, 0.0, 0.0, 0.3, 0.0, 0.0, 0.40]),
    ("vote",       [ 0.9, 0.1, 0.0, 0.0, 0.1, 0.0, 0.0, 0.0, 0.2, 0.0, 0.0, 0.45]),
    // cities / places
    ("illinois",   [ 0.1, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.5, 0.0, 0.0, 0.10]),
    ("chicago",    [ 0.1, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.5, 0.0, 0.0, 0.15]),
    ("japan",      [ 0.1, 0.0, 0.9, 0.0, 0.0, 0.1, 0.0, 0.1, 0.9, 0.0, 0.0, 0.30]),
    ("bangladesh", [ 0.1, 0.0, 0.9, 0.0, 0.0, 0.1, 0.0, 0.0, 0.9, 0.0, 0.0, 0.35]),
    ("city",       [ 0.0, 0.0, 0.9, 0.0, 0.0, 0.0, 0.0, 0.0, 0.4, 0.0, 0.0, 0.40]),
    ("stadium",    [ 0.0, 0.0, 0.7, 0.0, 0.0, 0.0, 0.6, 0.0, 0.2, 0.0, 0.0, 0.45]),
    // media / speech acts
    ("media",      [ 0.1, 0.0, 0.0, 1.0, 0.3, 0.0, 0.0, 0.1, 0.0, 0.0, 0.1, 0.10]),
    ("press",      [ 0.1, 0.0, 0.0, 1.0, 0.3, 0.0, 0.0, 0.0, 0.0, 0.0, 0.1, 0.15]),
    ("journalist", [ 0.1, 0.5, 0.0, 0.9, 0.3, 0.0, 0.0, 0.0, 0.0, 0.0, 0.1, 0.20]),
    ("news",       [ 0.1, 0.0, 0.0, 0.9, 0.2, 0.0, 0.0, 0.1, 0.0, 0.0, 0.1, 0.25]),
    ("speaks",     [ 0.1, 0.2, 0.0, 0.3, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.10]),
    ("greets",     [ 0.1, 0.2, 0.0, 0.2, 0.9, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.15]),
    ("talks",      [ 0.1, 0.2, 0.0, 0.3, 0.9, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.20]),
    ("announces",  [ 0.2, 0.2, 0.0, 0.4, 0.9, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.25]),
    // food
    ("sushi",      [ 0.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.5, 0.0, 0.0, 0.10]),
    ("biriyani",   [ 0.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.5, 0.0, 0.0, 0.15]),
    ("restaurant", [ 0.0, 0.0, 0.3, 0.0, 0.0, 0.9, 0.0, 0.0, 0.1, 0.1, 0.0, 0.20]),
    ("chef",       [ 0.0, 0.6, 0.0, 0.0, 0.0, 0.9, 0.0, 0.0, 0.1, 0.0, 0.0, 0.25]),
    ("dinner",     [ 0.0, 0.0, 0.0, 0.0, 0.0, 0.9, 0.0, 0.0, 0.0, 0.0, 0.0, 0.30]),
    ("cooks",      [ 0.0, 0.2, 0.0, 0.0, 0.2, 0.9, 0.0, 0.0, 0.0, 0.0, 0.0, 0.35]),
    ("noodles",    [ 0.0, 0.0, 0.0, 0.0, 0.0, 0.9, 0.0, 0.0, 0.4, 0.0, 0.0, 0.40]),
    // sports
    ("football",   [ 0.0, 0.0, 0.0, 0.1, 0.0, 0.0, 1.0, 0.0, 0.1, 0.0, 0.0, 0.10]),
    ("match",      [ 0.0, 0.0, 0.0, 0.1, 0.0, 0.0, 0.9, 0.0, 0.0, 0.0, 0.0, 0.15]),
    ("team",       [ 0.0, 0.3, 0.0, 0.0, 0.0, 0.0, 0.9, 0.0, 0.0, 0.0, 0.0, 0.20]),
    ("player",     [ 0.0, 0.7, 0.0, 0.0, 0.0, 0.0, 0.9, 0.0, 0.0, 0.0, 0.0, 0.25]),
    ("wins",       [ 0.0, 0.1, 0.0, 0.1, 0.0, 0.0, 0.9, 0.0, 0.0, 0.1, 0.0, 0.30]),
    ("coach",      [ 0.0, 0.7, 0.0, 0.0, 0.2, 0.0, 0.9, 0.0, 0.0, 0.0, 0.0, 0.35]),
    // tech
    ("computer",   [ 0.0, 0.0, 0.0, 0.1, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.10]),
    ("software",   [ 0.0, 0.0, 0.0, 0.1, 0.0, 0.0, 0.0, 1.0, 0.0, 0.1, 0.0, 0.15]),
    ("algorithm",  [ 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.9, 0.0, 0.0, 0.1, 0.20]),
    ("startup",    [ 0.0, 0.2, 0.1, 0.0, 0.0, 0.0, 0.0, 0.9, 0.0, 0.5, 0.0, 0.25]),
    ("releases",   [ 0.0, 0.1, 0.0, 0.3, 0.3, 0.0, 0.0, 0.8, 0.0, 0.0, 0.0, 0.30]),
    ("engineer",   [ 0.0, 0.7, 0.0, 0.0, 0.0, 0.0, 0.0, 0.9, 0.0, 0.0, 0.0, 0.35]),
    // misc fillers
    ("amy",        [ 0.0, 0.9, 0.0, 0.1, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.6, 0.40]),
    ("adams",      [ 0.0, 0.9, 0.0, 0.1, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.6, 0.45]),
    ("deepfake",   [ 0.0, 0.1, 0.0, 0.4, 0.0, 0.0, 0.0, 0.8, 0.0, 0.0, 0.3, 0.50]),
    ("movie",      [ 0.0, 0.1, 0.0, 0.3, 0.0, 0.0, 0.0, 0.1, 0.0, 0.0, 0.9, 0.55]),
    ("actor",      [ 0.0, 0.8, 0.0, 0.2, 0.1, 0.0, 0.0, 0.0, 0.0, 0.0, 0.8, 0.60]),
    ("market",     [ 0.1, 0.0, 0.1, 0.1, 0.0, 0.1, 0.0, 0.1, 0.0, 0.9, 0.0, 0.65]),
    ("bank",       [ 0.1, 0.0, 0.2, 0.0, 0.0, 0.0, 0.0, 0.1, 0.0, 1.0, 0.0, 0.70]),
    ("stocks",     [ 0.1, 0.0, 0.0, 0.1, 0.0, 0.0, 0.0, 0.1, 0.0, 1.0, 0.0, 0.75]),
];

/// `(sentence, topic-label)` documents.
#[rustfmt::skip]
pub const SENTENCES: &[(&str, &str)] = &[
    ("The President greets the press in Chicago",        "politics"),
    ("The senator talks to journalists about the election", "politics"),
    ("The governor announces the vote in Illinois",      "politics"),
    ("The minister speaks to the media about the election", "politics"),
    ("The chef cooks sushi for dinner in Japan",         "food"),
    ("A restaurant in Bangladesh serves biriyani and noodles", "food"),
    ("The chef cooks noodles at the restaurant",         "food"),
    ("The team wins the football match at the stadium",  "sports"),
    ("The coach greets the player after the match",      "sports"),
    ("The player speaks to the press after the football match", "sports"),
    ("The startup releases new software for the computer", "tech"),
    ("An engineer talks about the algorithm and software", "tech"),
    ("The startup engineer releases a computer algorithm", "tech"),
    ("Amy Adams was in deepFake",                        "misc"),
    ("The actor speaks about the movie to the press",    "misc"),
    ("The bank announces stocks news to the market",     "finance"),
];

/// The loaded tiny corpus: vocabulary, embeddings, and labeled documents.
pub struct TinyCorpus {
    pub vocab: Vocabulary,
    pub embeddings: Dense,
    pub docs: Vec<SparseVec>,
    pub labels: Vec<&'static str>,
    pub sentences: Vec<&'static str>,
}

impl TinyCorpus {
    pub fn load() -> Self {
        let vocab = Vocabulary::from_words(WORDS.iter().map(|(w, _)| w.to_string()));
        let embeddings = Dense::from_fn(WORDS.len(), TINY_DIM, |i, j| {
            // Scale up so distances are O(1)-separated like real word2vec.
            WORDS[i].1[j] as Real * 3.0
        });
        let mut docs = Vec::new();
        let mut labels = Vec::new();
        let mut sentences = Vec::new();
        let tiny = Self {
            vocab: vocab.clone(),
            embeddings: embeddings.clone(),
            docs: vec![],
            labels: vec![],
            sentences: vec![],
        };
        for (text, label) in SENTENCES {
            let h = tiny.histogram(text).unwrap_or_else(|| {
                panic!("tiny corpus sentence has no in-vocabulary words: {text}")
            });
            docs.push(h);
            labels.push(*label);
            sentences.push(*text);
        }
        Self { vocab, embeddings, docs, labels, sentences }
    }

    /// Tokenize a sentence and build its normalized histogram over the
    /// tiny vocabulary (the shared [`Vocabulary::text_histogram`]
    /// pipeline). Returns `None` when no token is in-vocabulary.
    pub fn histogram(&self, text: &str) -> Option<SparseVec> {
        self.vocab.text_histogram(text).ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_with_consistent_shapes() {
        let t = TinyCorpus::load();
        assert_eq!(t.embeddings.nrows(), t.vocab.len());
        assert_eq!(t.embeddings.ncols(), TINY_DIM);
        assert_eq!(t.docs.len(), SENTENCES.len());
        for d in &t.docs {
            assert!((d.sum() - 1.0).abs() < 1e-12);
            assert!(d.nnz() >= 2);
        }
    }

    #[test]
    fn no_duplicate_words() {
        let t = TinyCorpus::load();
        for i in 0..t.vocab.len() {
            assert_eq!(t.vocab.id(t.vocab.word(i)), Some(i as u32));
        }
    }

    #[test]
    fn paper_analogy_geometry() {
        // m(media, press) < m(media, obama) — paper §2.
        let t = TinyCorpus::load();
        let d = |a: &str, b: &str| {
            let ia = t.vocab.id(a).unwrap() as usize;
            let ib = t.vocab.id(b).unwrap() as usize;
            t.embeddings
                .row(ia)
                .iter()
                .zip(t.embeddings.row(ib))
                .map(|(x, y)| (x - y) * (x - y))
                .sum::<f64>()
                .sqrt()
        };
        assert!(d("media", "press") < d("media", "obama"));
        assert!(d("obama", "president") < d("obama", "sushi"));
        assert!(d("illinois", "chicago") < d("illinois", "software"));
        // Japan:sushi ≈ Bangladesh:biriyani relational structure.
        assert!(d("japan", "sushi") < d("japan", "football"));
        assert!(d("bangladesh", "biriyani") < d("bangladesh", "computer"));
    }

    #[test]
    fn histogram_of_unknown_text_is_none() {
        let t = TinyCorpus::load();
        assert!(t.histogram("zzz qqq unknownword").is_none());
    }

    #[test]
    fn obama_sentence_histogram() {
        let t = TinyCorpus::load();
        let h = t.histogram("Obama speaks to the media in Illinois").unwrap();
        assert_eq!(h.nnz(), 4); // obama, speaks, media, illinois
    }
}
