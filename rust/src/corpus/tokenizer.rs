//! Tokenization + stop-word filtering — the paper's preprocessing:
//! *"throwing away the information about word order, capitalization and
//! removing the frequent and uninformative stop-words"* (§2).

/// The uninformative high-frequency words dropped before histogramming.
/// Matches the paper's example: A = "Obama speaks to the media in
/// Illinois" → ['illinois', 'media', 'speaks', 'obama'].
pub const STOPWORDS: &[&str] = &[
    "a", "an", "and", "are", "as", "at", "be", "but", "by", "for", "from", "has", "he", "her",
    "his", "i", "in", "is", "it", "its", "of", "on", "or", "our", "she", "that", "the", "their",
    "they", "this", "to", "was", "we", "were", "will", "with", "you",
];

/// Lowercase and split on non-alphanumeric boundaries.
pub fn tokenize(text: &str) -> Vec<String> {
    let mut tokens = Vec::new();
    let mut cur = String::new();
    for ch in text.chars() {
        if ch.is_alphanumeric() || ch == '\'' {
            cur.extend(ch.to_lowercase());
        } else if !cur.is_empty() {
            tokens.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        tokens.push(cur);
    }
    tokens
}

/// Tokenize and drop stop-words.
pub fn tokenize_filtered(text: &str) -> Vec<String> {
    tokenize(text)
        .into_iter()
        .filter(|t| !STOPWORDS.contains(&t.as_str()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_sentence_a() {
        let toks = tokenize_filtered("Obama speaks to the media in Illinois");
        assert_eq!(toks, vec!["obama", "speaks", "media", "illinois"]);
    }

    #[test]
    fn paper_example_sentence_b() {
        let toks = tokenize_filtered("The President greets the press in Chicago.");
        assert_eq!(toks, vec!["president", "greets", "press", "chicago"]);
    }

    #[test]
    fn punctuation_and_case() {
        assert_eq!(tokenize("Hello, WORLD! 42x"), vec!["hello", "world", "42x"]);
    }

    #[test]
    fn empty_input() {
        assert!(tokenize("").is_empty());
        assert!(tokenize("  ,.;  ").is_empty());
    }
}
