//! Tokenization + stop-word filtering — the paper's preprocessing:
//! *"throwing away the information about word order, capitalization and
//! removing the frequent and uninformative stop-words"* (§2).

/// The uninformative high-frequency words dropped before histogramming.
/// Matches the paper's example: A = "Obama speaks to the media in
/// Illinois" → ['illinois', 'media', 'speaks', 'obama'].
pub const STOPWORDS: &[&str] = &[
    "a", "an", "and", "are", "as", "at", "be", "but", "by", "for", "from", "has", "he", "her",
    "his", "i", "in", "is", "it", "its", "of", "on", "or", "our", "she", "that", "the", "their",
    "they", "this", "to", "was", "we", "were", "will", "with", "you",
];

/// Lowercase and split on non-alphanumeric boundaries. Apostrophes are
/// word characters only in the *interior* of a word (`don't`); quoting
/// apostrophes are stripped (`'hello'` → `hello`) and a run of bare
/// apostrophes (`''`) is no token at all.
pub fn tokenize(text: &str) -> Vec<String> {
    let mut tokens = Vec::new();
    let mut cur = String::new();
    for ch in text.chars() {
        if ch.is_alphanumeric() || ch == '\'' {
            cur.extend(ch.to_lowercase());
        } else {
            flush_token(&mut tokens, &mut cur);
        }
    }
    flush_token(&mut tokens, &mut cur);
    tokens
}

/// Emit the accumulated word, minus any leading/trailing apostrophes.
fn flush_token(tokens: &mut Vec<String>, cur: &mut String) {
    if cur.is_empty() {
        return;
    }
    let trimmed = cur.trim_matches('\'');
    if !trimmed.is_empty() {
        tokens.push(trimmed.to_string());
    }
    cur.clear();
}

/// Tokenize and drop stop-words.
pub fn tokenize_filtered(text: &str) -> Vec<String> {
    tokenize(text)
        .into_iter()
        .filter(|t| !STOPWORDS.contains(&t.as_str()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_sentence_a() {
        let toks = tokenize_filtered("Obama speaks to the media in Illinois");
        assert_eq!(toks, vec!["obama", "speaks", "media", "illinois"]);
    }

    #[test]
    fn paper_example_sentence_b() {
        let toks = tokenize_filtered("The President greets the press in Chicago.");
        assert_eq!(toks, vec!["president", "greets", "press", "chicago"]);
    }

    #[test]
    fn punctuation_and_case() {
        assert_eq!(tokenize("Hello, WORLD! 42x"), vec!["hello", "world", "42x"]);
    }

    #[test]
    fn empty_input() {
        assert!(tokenize("").is_empty());
        assert!(tokenize("  ,.;  ").is_empty());
    }

    #[test]
    fn interior_apostrophes_kept() {
        assert_eq!(tokenize("don't can't o'clock"), vec!["don't", "can't", "o'clock"]);
    }

    #[test]
    fn quoting_apostrophes_stripped() {
        // Regression: `'hello'` used to come back as the token `'hello'`.
        assert_eq!(tokenize("'hello'"), vec!["hello"]);
        assert_eq!(tokenize("he said 'hello world'"), vec!["he", "said", "hello", "world"]);
        assert_eq!(tokenize("'tis rock'n'roll'"), vec!["tis", "rock'n'roll"]);
    }

    #[test]
    fn all_apostrophe_runs_are_not_tokens() {
        // Regression: a bare `''` used to become an (empty-quote) token.
        assert!(tokenize("''").is_empty());
        assert!(tokenize("' '' '''").is_empty());
        assert_eq!(tokenize("a '' b"), vec!["a", "b"]);
    }
}
