//! Synthetic word embeddings — the stand-in for `crawl-300d-2M.vec`.
//!
//! Words are assigned to semantic clusters; each embedding is its cluster
//! center plus isotropic Gaussian noise. This preserves the property WMD
//! relies on: words in the same topic are close, topics are separated, and
//! the distance matrix `M` has realistic spread (neither degenerate nor
//! uniform). Deterministic from the seed.

use crate::sparse::Dense;
use crate::util::Pcg64;

/// Separation of cluster centers relative to intra-cluster noise (σ = 1).
const CENTER_SCALE: f64 = 4.0;

/// Generate `vocab_size × dim` embeddings grouped into `n_clusters`
/// topics. Returns the embedding matrix and each word's cluster id.
///
/// Words are assigned to clusters round-robin over a shuffled order so
/// every cluster contains both frequent (low-rank) and rare words — Zipf
/// sampling then produces documents whose words span the cluster.
pub fn synthetic_embeddings(
    vocab_size: usize,
    dim: usize,
    n_clusters: usize,
    seed: u64,
) -> (Dense, Vec<u32>) {
    assert!(n_clusters >= 1 && n_clusters <= vocab_size);
    let mut rng = Pcg64::new(seed);
    // Cluster centers.
    let centers = Dense::from_fn(n_clusters, dim, |_, _| rng.next_gaussian() * CENTER_SCALE);
    // Word → cluster assignment: shuffled round-robin.
    let mut order: Vec<usize> = (0..vocab_size).collect();
    rng.shuffle(&mut order);
    let mut cluster = vec![0u32; vocab_size];
    for (pos, &word) in order.iter().enumerate() {
        cluster[word] = (pos % n_clusters) as u32;
    }
    // Embeddings: center + N(0, 1) noise, scaled by 1/√dim so typical
    // pairwise distances are O(√(2(CENTER_SCALE²+1))) ≈ 5.8 regardless of
    // dimension — keeping K = exp(−λM) far from f64 underflow at the
    // paper's λ values (real word2vec distances are likewise O(1)).
    let scale = 1.0 / (dim as f64).sqrt();
    let mut emb = Dense::zeros(vocab_size, dim);
    for word in 0..vocab_size {
        let c = cluster[word] as usize;
        let row = emb.row_mut(word);
        for (k, x) in row.iter_mut().enumerate() {
            *x = (centers.get(c, k) + rng.next_gaussian()) * scale;
        }
    }
    (emb, cluster)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::dot;

    fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
    }

    #[test]
    fn deterministic() {
        let (a, ca) = synthetic_embeddings(100, 16, 4, 7);
        let (b, cb) = synthetic_embeddings(100, 16, 4, 7);
        assert_eq!(a, b);
        assert_eq!(ca, cb);
    }

    #[test]
    fn clusters_are_separated() {
        let (emb, cluster) = synthetic_embeddings(200, 32, 4, 11);
        // Mean intra-cluster distance << mean inter-cluster distance.
        let (mut intra, mut inter) = ((0.0, 0usize), (0.0, 0usize));
        for i in 0..100 {
            for j in (i + 1)..100 {
                let d = sq_dist(emb.row(i), emb.row(j));
                if cluster[i] == cluster[j] {
                    intra = (intra.0 + d, intra.1 + 1);
                } else {
                    inter = (inter.0 + d, inter.1 + 1);
                }
            }
        }
        let intra_mean = intra.0 / intra.1 as f64;
        let inter_mean = inter.0 / inter.1 as f64;
        assert!(
            inter_mean > 2.0 * intra_mean,
            "inter {inter_mean} vs intra {intra_mean}"
        );
    }

    #[test]
    fn every_cluster_populated() {
        let (_, cluster) = synthetic_embeddings(50, 8, 7, 3);
        let mut seen = vec![false; 7];
        for &c in &cluster {
            seen[c as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn embeddings_not_degenerate() {
        let (emb, _) = synthetic_embeddings(100, 16, 4, 13);
        for i in 0..emb.nrows() {
            assert!(dot(emb.row(i), emb.row(i)) > 0.0);
        }
    }
}
