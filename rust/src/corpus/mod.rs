//! Corpus substrate: vocabulary, tokenization, histograms, synthetic
//! embeddings and document generation — plus the **real-corpus ingestion
//! pipeline** (`.vec` embeddings + streaming documents).
//!
//! The paper's evaluation uses the `crawl-300d-2M` embeddings (100 k words
//! × 300 dims, fp64) and the first 5 000 dbpedia documents (c density
//! ≈ 0.0035 %, source docs of 19–43 words). This module provides both
//! statistically matched synthetic substitutes (see DESIGN.md §3) and the
//! real pipeline: [`vec`] parses word2vec/fastText text-format embeddings,
//! [`stream`] reads document streams (plaintext / JSONL) and assembles
//! them into a [`Corpus`] without materializing every document.

pub mod embedding;
pub mod generator;
pub mod histogram;
pub mod io;
pub mod stream;
pub mod tiny;
pub mod tokenizer;
pub mod vec;
pub mod vocab;

pub use embedding::synthetic_embeddings;
pub use generator::{CorpusBuilder, SyntheticCorpus};
pub use histogram::{docs_to_csr, SparseVec};
pub use stream::{ingest_corpus, DocFormat, DocReader, IngestBuilder, IngestStats};
pub use tiny::TinyCorpus;
pub use tokenizer::{tokenize, tokenize_filtered};
pub use vec::{load_vec_file, read_vec, VecEmbeddings};
pub use vocab::Vocabulary;

use crate::sparse::{Csr, Dense};

/// A serving-ready corpus: the common denominator that both
/// [`SyntheticCorpus`] and ingested real corpora lower into, and the
/// payload of the `WMDC` snapshot format ([`io`]).
///
/// Topic metadata and the vocabulary's word strings are optional (empty
/// when unknown): synthetic corpora carry topics but no words, ingested
/// corpora carry words but no topics, v1 snapshots carry whatever the
/// synthetic generator produced.
#[derive(Clone, Debug)]
pub struct Corpus {
    /// `V × w` word embeddings.
    pub embeddings: Dense,
    /// Word strings aligned with the embedding rows; **empty when
    /// unknown** (synthetic / v1 snapshots) — raw-text queries then
    /// cannot be histogrammed.
    pub vocab: Vocabulary,
    /// Topic id per vocabulary word (empty when unknown).
    pub word_topic: Vec<u32>,
    /// `V × N` normalized target histograms (CSR); empty documents are
    /// empty columns (`WMD = +inf`).
    pub c: Csr,
    /// Topic id per target document (empty when unknown).
    pub doc_topics: Vec<u32>,
    /// Pre-built query documents (may be empty for ingested corpora —
    /// queries then arrive as raw text via [`Corpus::text_query`]).
    pub queries: Vec<SparseVec>,
    /// Topic id per query (empty when unknown).
    pub query_topics: Vec<u32>,
}

impl Corpus {
    pub fn vocab_size(&self) -> usize {
        self.c.nrows()
    }

    pub fn num_docs(&self) -> usize {
        self.c.ncols()
    }

    pub fn density(&self) -> f64 {
        self.c.density()
    }

    /// Whether the vocabulary's word strings are known (required for
    /// raw-text queries).
    pub fn has_words(&self) -> bool {
        !self.vocab.is_empty()
    }

    /// Tokenize + stop-word-filter a raw text query and histogram it over
    /// this corpus's vocabulary ([`Vocabulary::text_histogram`] — the
    /// same pipeline the service uses). `Err` when the corpus has no
    /// word strings or nothing survives filtering.
    pub fn text_query(&self, text: &str) -> Result<SparseVec, String> {
        if !self.has_words() {
            return Err("corpus has no vocabulary words (synthetic or v1 snapshot) — \
                        raw-text queries need an ingested/v2 corpus"
                .into());
        }
        self.vocab.text_histogram(text)
    }
}

#[cfg(test)]
mod corpus_tests {
    use super::*;

    #[test]
    fn synthetic_lowers_into_corpus() {
        let syn = SyntheticCorpus::builder()
            .vocab_size(300)
            .num_docs(20)
            .embedding_dim(8)
            .num_queries(2)
            .query_words(4, 6)
            .seed(1)
            .build();
        let (c_ref, emb_ref, queries_ref) = (syn.c.clone(), syn.embeddings.clone(), syn.queries.clone());
        let corpus = syn.into_corpus();
        assert_eq!(corpus.c, c_ref);
        assert_eq!(corpus.embeddings, emb_ref);
        assert_eq!(corpus.queries, queries_ref);
        assert!(!corpus.has_words());
        assert_eq!(corpus.vocab_size(), 300);
        assert_eq!(corpus.num_docs(), 20);
        assert!(corpus.text_query("anything").is_err(), "no words → no text queries");
    }

    #[test]
    fn text_query_on_worded_corpus() {
        let tiny = TinyCorpus::load();
        let corpus = Corpus {
            embeddings: tiny.embeddings.clone(),
            vocab: tiny.vocab.clone(),
            word_topic: vec![],
            c: docs_to_csr(tiny.vocab.len(), &tiny.docs),
            doc_topics: vec![],
            queries: vec![],
            query_topics: vec![],
        };
        let q = corpus.text_query("Obama speaks to the media in Illinois").unwrap();
        assert_eq!(q.nnz(), 4);
        assert!((q.sum() - 1.0).abs() < 1e-12);
        assert!(corpus.text_query("zzz qqq").is_err());
    }
}
