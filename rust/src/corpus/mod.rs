//! Corpus substrate: vocabulary, tokenization, histograms, synthetic
//! embeddings and document generation.
//!
//! The paper's evaluation uses the `crawl-300d-2M` embeddings (100 k words
//! × 300 dims, fp64) and the first 5 000 dbpedia documents (c density
//! ≈ 0.0035 %, source docs of 19–43 words). Neither asset is available
//! offline, so this module provides statistically matched synthetic
//! substitutes (see DESIGN.md §3) plus a tiny *real* hand-embedded corpus
//! for semantic sanity tests (the paper's Obama/President example).

pub mod embedding;
pub mod generator;
pub mod histogram;
pub mod io;
pub mod tiny;
pub mod tokenizer;
pub mod vocab;

pub use embedding::synthetic_embeddings;
pub use generator::{CorpusBuilder, SyntheticCorpus};
pub use histogram::{docs_to_csr, SparseVec};
pub use tiny::TinyCorpus;
pub use tokenizer::{tokenize, tokenize_filtered};
pub use vocab::Vocabulary;
