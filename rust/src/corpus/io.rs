//! Binary persistence for corpora ("gen once, serve many"): a simple
//! little-endian container (`WMDC` magic) holding the embeddings, the CSR
//! target matrix, queries and topic metadata. No external serialization
//! crates exist offline; the format is versioned and length-prefixed.

use super::generator::SyntheticCorpus;
use super::histogram::SparseVec;
use crate::sparse::{Csr, Dense};
use crate::Real;
use std::io::{self, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"WMDC";
const VERSION: u32 = 1;

/// Cap on *pre*-allocation from an untrusted length prefix (elements, so
/// ≤ 8 MiB up front for f64/u64 payloads). A truncated or corrupted file
/// can claim any `n` it likes; growth beyond the cap only happens as
/// payload bytes actually arrive, so a lying prefix hits `read_exact`'s
/// `UnexpectedEof` instead of a multi-GB allocation.
const IO_PREALLOC_CAP: usize = 1 << 20;

fn write_u64(w: &mut impl Write, v: u64) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn read_u64(r: &mut impl Read) -> io::Result<u64> {
    let mut buf = [0u8; 8];
    r.read_exact(&mut buf)?;
    Ok(u64::from_le_bytes(buf))
}

fn write_f64s(w: &mut impl Write, xs: &[Real]) -> io::Result<()> {
    write_u64(w, xs.len() as u64)?;
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_f64s(r: &mut impl Read) -> io::Result<Vec<Real>> {
    let n = read_u64(r)? as usize;
    let mut out = Vec::with_capacity(n.min(IO_PREALLOC_CAP));
    let mut buf = [0u8; 8];
    for _ in 0..n {
        r.read_exact(&mut buf)?;
        out.push(Real::from_le_bytes(buf));
    }
    Ok(out)
}

fn write_u32s(w: &mut impl Write, xs: &[u32]) -> io::Result<()> {
    write_u64(w, xs.len() as u64)?;
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_u32s(r: &mut impl Read) -> io::Result<Vec<u32>> {
    let n = read_u64(r)? as usize;
    let mut out = Vec::with_capacity(n.min(IO_PREALLOC_CAP));
    let mut buf = [0u8; 4];
    for _ in 0..n {
        r.read_exact(&mut buf)?;
        out.push(u32::from_le_bytes(buf));
    }
    Ok(out)
}

fn write_usizes(w: &mut impl Write, xs: &[usize]) -> io::Result<()> {
    write_u64(w, xs.len() as u64)?;
    for &x in xs {
        write_u64(w, x as u64)?;
    }
    Ok(())
}

fn read_usizes(r: &mut impl Read) -> io::Result<Vec<usize>> {
    let n = read_u64(r)? as usize;
    let mut out = Vec::with_capacity(n.min(IO_PREALLOC_CAP));
    for _ in 0..n {
        out.push(read_u64(r)? as usize);
    }
    Ok(out)
}

fn write_dense(w: &mut impl Write, d: &Dense) -> io::Result<()> {
    write_u64(w, d.nrows() as u64)?;
    write_u64(w, d.ncols() as u64)?;
    write_f64s(w, d.as_slice())
}

fn read_dense(r: &mut impl Read) -> io::Result<Dense> {
    let nrows = read_u64(r)? as usize;
    let ncols = read_u64(r)? as usize;
    let data = read_f64s(r)?;
    if data.len() != nrows * ncols {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "dense shape mismatch"));
    }
    Ok(Dense::from_vec(nrows, ncols, data))
}

fn write_csr(w: &mut impl Write, m: &Csr) -> io::Result<()> {
    write_u64(w, m.nrows() as u64)?;
    write_u64(w, m.ncols() as u64)?;
    write_usizes(w, m.row_ptr())?;
    write_u32s(w, m.col_idx())?;
    write_f64s(w, m.values())
}

fn read_csr(r: &mut impl Read) -> io::Result<Csr> {
    let nrows = read_u64(r)? as usize;
    let ncols = read_u64(r)? as usize;
    let row_ptr = read_usizes(r)?;
    let col_idx = read_u32s(r)?;
    let values = read_f64s(r)?;
    // Full structural validation (lengths, row_ptr monotonicity, column
    // range/order): a corrupted-but-well-lengthed snapshot must come back
    // as InvalidData, never panic inside the constructor.
    Csr::try_from_parts(nrows, ncols, row_ptr, col_idx, values).map_err(|e| {
        io::Error::new(io::ErrorKind::InvalidData, format!("CSR structure invalid: {e}"))
    })
}

fn write_sparsevec(w: &mut impl Write, v: &SparseVec) -> io::Result<()> {
    write_u64(w, v.dim as u64)?;
    write_u32s(w, &v.idx)?;
    write_f64s(w, &v.val)
}

fn read_sparsevec(r: &mut impl Read) -> io::Result<SparseVec> {
    let dim = read_u64(r)? as usize;
    let idx = read_u32s(r)?;
    let val = read_f64s(r)?;
    if idx.len() != val.len() {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "sparse vec mismatch"));
    }
    Ok(SparseVec { dim, idx, val })
}

/// Serialize a full corpus to `path`.
pub fn save_corpus(path: &Path, corpus: &SyntheticCorpus) -> io::Result<()> {
    let file = std::fs::File::create(path)?;
    let mut w = io::BufWriter::new(file);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    write_dense(&mut w, &corpus.embeddings)?;
    write_u32s(&mut w, &corpus.word_topic)?;
    write_csr(&mut w, &corpus.c)?;
    write_u64(&mut w, corpus.docs.len() as u64)?;
    for d in &corpus.docs {
        write_sparsevec(&mut w, d)?;
    }
    write_u32s(&mut w, &corpus.doc_topics)?;
    write_u64(&mut w, corpus.queries.len() as u64)?;
    for q in &corpus.queries {
        write_sparsevec(&mut w, q)?;
    }
    write_u32s(&mut w, &corpus.query_topics)?;
    w.flush()
}

/// Load a corpus previously written by [`save_corpus`].
pub fn load_corpus(path: &Path) -> io::Result<SyntheticCorpus> {
    let file = std::fs::File::open(path)?;
    let mut r = io::BufReader::new(file);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "not a WMDC file"));
    }
    let mut ver = [0u8; 4];
    r.read_exact(&mut ver)?;
    if u32::from_le_bytes(ver) != VERSION {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "unsupported WMDC version"));
    }
    let embeddings = read_dense(&mut r)?;
    let word_topic = read_u32s(&mut r)?;
    let c = read_csr(&mut r)?;
    let ndocs = read_u64(&mut r)? as usize;
    let docs = (0..ndocs).map(|_| read_sparsevec(&mut r)).collect::<io::Result<Vec<_>>>()?;
    let doc_topics = read_u32s(&mut r)?;
    let nq = read_u64(&mut r)? as usize;
    let queries = (0..nq).map(|_| read_sparsevec(&mut r)).collect::<io::Result<Vec<_>>>()?;
    let query_topics = read_u32s(&mut r)?;
    Ok(SyntheticCorpus { embeddings, word_topic, c, docs, doc_topics, queries, query_topics })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_corpus() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(300)
            .num_docs(25)
            .embedding_dim(12)
            .num_queries(3)
            .query_words(4, 8)
            .seed(9)
            .build();
        let dir = std::env::temp_dir().join(format!("wmdc-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("corpus.wmdc");
        save_corpus(&path, &corpus).unwrap();
        let back = load_corpus(&path).unwrap();
        assert_eq!(back.embeddings, corpus.embeddings);
        assert_eq!(back.c, corpus.c);
        assert_eq!(back.queries, corpus.queries);
        assert_eq!(back.doc_topics, corpus.doc_topics);
        assert_eq!(back.word_topic, corpus.word_topic);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lying_length_prefix_errors_without_huge_allocation() {
        // A u64 prefix claiming ~2^61 elements followed by 8 payload
        // bytes: must fail with UnexpectedEof after a capped (≤ 8 MiB)
        // preallocation, not attempt a multi-EB Vec up front.
        let mut buf = Vec::new();
        write_u64(&mut buf, u64::MAX / 8).unwrap();
        buf.extend_from_slice(&1.0f64.to_le_bytes());
        let err = read_f64s(&mut &buf[..]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        let err = read_u32s(&mut &buf[..]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        let err = read_usizes(&mut &buf[..]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn corrupted_csr_structure_is_invalid_data_not_panic() {
        // Well-lengthed but structurally broken streams: every variant
        // must surface as InvalidData through read_csr.
        let encode = |nrows: u64, ncols: u64, row_ptr: &[usize], col_idx: &[u32], vals: &[Real]| {
            let mut buf = Vec::new();
            write_u64(&mut buf, nrows).unwrap();
            write_u64(&mut buf, ncols).unwrap();
            write_usizes(&mut buf, row_ptr).unwrap();
            write_u32s(&mut buf, col_idx).unwrap();
            write_f64s(&mut buf, vals).unwrap();
            buf
        };
        // Sanity: a well-formed stream parses.
        assert!(read_csr(&mut &encode(2, 3, &[0, 1, 2], &[1, 0], &[1.0, 2.0])[..]).is_ok());
        // Non-monotonic row_ptr (endpoints and lengths all consistent).
        let nonmono = encode(3, 3, &[0, 2, 1, 2], &[0, 1], &[1.0, 2.0]);
        let err = read_csr(&mut &nonmono[..]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // Out-of-range column index.
        let oob = encode(2, 3, &[0, 1, 2], &[1, 9], &[1.0, 2.0]);
        let err = read_csr(&mut &oob[..]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // Columns out of order within a row.
        let unsorted = encode(1, 3, &[0, 2], &[2, 0], &[1.0, 2.0]);
        let err = read_csr(&mut &unsorted[..]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // row_ptr pointing past the payload.
        let overrun = encode(2, 3, &[0, 9, 2], &[1, 0], &[1.0, 2.0]);
        let err = read_csr(&mut &overrun[..]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // nrows = u64::MAX with empty arrays: must not overflow `nrows+1`
        // (debug) or index an empty row_ptr (release).
        let huge = encode(u64::MAX, 1, &[], &[], &[]);
        let err = read_csr(&mut &huge[..]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn truncated_snapshot_errors_cleanly() {
        let corpus = SyntheticCorpus::builder()
            .vocab_size(200)
            .num_docs(10)
            .embedding_dim(8)
            .num_queries(2)
            .query_words(3, 5)
            .seed(4)
            .build();
        let dir = std::env::temp_dir().join(format!("wmdc-trunc-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("full.wmdc");
        save_corpus(&path, &corpus).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Cut the file at several depths (inside the header, the dense
        // block, the CSR block, the trailing metadata): every prefix must
        // load as Err, never panic or hang on allocation.
        for cut in [3, 9, 40, bytes.len() / 3, bytes.len() / 2, bytes.len() - 1] {
            let p = dir.join(format!("cut-{cut}.wmdc"));
            std::fs::write(&p, &bytes[..cut]).unwrap();
            assert!(load_corpus(&p).is_err(), "prefix of {cut} bytes must not load");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let dir = std::env::temp_dir().join(format!("wmdc-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.wmdc");
        std::fs::write(&path, b"not a corpus at all").unwrap();
        assert!(load_corpus(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
